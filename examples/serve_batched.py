"""Continuous-batching example: mixed-length requests with per-request
sampling settings, served through the engine (parallel prefill + one jitted
multi-slot decode with per-slot positions); the same batch again with
self-speculative decoding turned on; and a shared-system-prompt batch
served twice through a prefix cache — the second turn skips the system
prompt's prefill entirely.

See docs/serving.md for the engine API reference, the speculative decoding
knobs (``speculative=K``, ``draft_stride``) and the prefix-cache knobs
(``PrefixCache(budget_mb, ...)``, ``CachedSuffixFirst``).

    PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np
import jax

from repro.configs.all_configs import reduce_for_smoke
from repro.configs.base import get_config
from repro.distributed.plan import ParallelPlan
from repro.models import lm
from repro.serve import (CachedSuffixFirst, EngineConfig, PrefixCache,
                         Request, SamplingParams, ServeEngine)


def make_requests(cfg):
    # 6 requests with different prompt lengths and sampling settings served
    # on 4 slots: slots free up on finish and are refilled from the queue.
    rng = np.random.default_rng(0)
    prompt_lens = [5, 9, 3, 7, 12, 4]
    samplings = [
        SamplingParams(),                                   # greedy
        SamplingParams(temperature=0.8, top_k=40),
        SamplingParams(temperature=1.0, top_p=0.9),
        SamplingParams(),
        SamplingParams(temperature=0.7, top_k=20, top_p=0.95),
        SamplingParams(temperature=1.2),
    ]
    return [Request(id=i,
                    prompt=rng.integers(2, cfg.vocab_size, size=(n,)).tolist(),
                    max_new_tokens=16, sampling=sp)
            for i, (n, sp) in enumerate(zip(prompt_lens, samplings))], \
        max(prompt_lens)


def report(engine, results, cache_since=None):
    for r in sorted(results, key=lambda r: r.id):
        print(f"req{r.id} prompt[{r.prompt_len}] {r.finish_reason:>6} "
              f"ttft {r.ttft_s * 1e3:6.1f}ms -> {r.tokens[:12]}")
    s = engine.stats
    print(f"prefill {s['prefill_tokens']} tok / {s['prefill_s']:.3f}s | "
          f"decode {s['decode_tokens']} tok / "
          f"{s['decode_s'] + s['mixed_s']:.3f}s "
          f"in {s['decode_steps']} steps "
          f"({s['mixed_steps']} interleaved with prefill chunks)")
    if s["spec_rounds"]:
        sp = engine.spec_summary()
        print(f"speculative: {s['spec_rounds']} rounds, "
              f"acceptance {sp['acceptance_rate']:.2%}, "
              f"{sp['tokens_per_slot_round']:.2f} tok/slot/round")
    if engine.cache is not None:
        # cache.stats is lifetime-cumulative: report this run's delta so
        # the printed hit rate describes the turn above it, not history
        cs = engine.cache.summary()
        base = cache_since or {k: 0 for k in engine.cache.stats}
        hits = cs["hits"] - base["hits"]
        misses = cs["misses"] - base["misses"]
        print(f"prefix cache: hit rate {hits / max(hits + misses, 1):.2%}, "
              f"{s['cache_hit_tokens']} prompt tok skipped "
              f"(prefilled only {s['prefill_tokens']}), "
              f"{cs['snapshots']} snapshots / "
              f"{cs['bytes_used'] / 2 ** 20:.2f} MiB")


def main():
    cfg = reduce_for_smoke(get_config("recurrentgemma-2b")).replace(
        d_model=128)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    # Everything device-side goes through one ParallelPlan.  On a 1-CPU
    # container this is the single-device plan; with more devices, e.g.
    # XLA_FLAGS=--xla_force_host_platform_device_count=8, try
    # ParallelPlan.host(data=4) — decode slots then shard over the data
    # axis and greedy outputs stay bit-identical.
    plan = ParallelPlan.single_device()

    reqs, longest = make_requests(cfg)
    engine = ServeEngine(cfg, params, plan=plan,
                         engine=EngineConfig(max_slots=4,
                                             max_len=longest + 16, seed=0))
    report(engine, engine.run(reqs))

    # Same batch, self-speculatively: each decode dispatch drafts 3 tokens
    # with a layer-skip reduced model (every 2nd block) and verifies them
    # with one full-model pass — greedy requests get bit-identical tokens,
    # sampled requests stay unbiased (rejection-sampling acceptance).
    print("\n--- speculative (K=3, draft stride 2) ---")
    reqs, longest = make_requests(cfg)
    spec = ServeEngine(cfg, params, plan=plan,
                       engine=EngineConfig(max_slots=4,
                                           max_len=longest + 16, seed=0,
                                           speculative=3, draft_stride=2))
    report(spec, spec.run(reqs))

    # Shared system prompt through a prefix cache: every request carries
    # the same 24-token "system prompt" plus a short unique user turn.
    # Turn 1 pays the system prompt's prefill once per batched lane and
    # publishes its chunk-boundary snapshots into the radix tree; turn 2
    # restores them and prefills only each request's unique suffix —
    # greedy outputs are bit-identical to a cold run, just cheaper.
    print("\n--- prefix cache (shared system prompt, 2 turns) ---")
    rng = np.random.default_rng(1)
    system = rng.integers(2, cfg.vocab_size, size=(24,)).tolist()

    def turn():
        # rng advances between calls: same system prompt, fresh user turns
        return [Request(id=i,
                        prompt=system + rng.integers(
                            2, cfg.vocab_size, size=(n,)).tolist(),
                        max_new_tokens=12)
                for i, n in enumerate((4, 6, 3, 5))]

    cache = PrefixCache(budget_mb=32.0)
    cached = ServeEngine(cfg, params, plan=plan,
                         engine=EngineConfig(max_slots=4, max_len=64,
                                             seed=0),
                         prefix_cache=cache,
                         scheduler=CachedSuffixFirst(cache))
    print("turn 1 (cold cache):")
    report(cached, cached.run(turn()))
    cached.reset_stats()
    since = dict(cache.stats)
    print("turn 2 (warm cache — system prompt prefill skipped):")
    report(cached, cached.run(turn()), cache_since=since)


if __name__ == "__main__":
    main()
