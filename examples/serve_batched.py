"""Batched serving example: continuous batched decode over mixed-length
requests with per-slot position tracking (inference-side API demo).

    PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro import train as tr
from repro.configs.all_configs import reduce_for_smoke
from repro.configs.base import get_config
from repro.models import lm


def main():
    cfg = reduce_for_smoke(get_config("recurrentgemma-2b")).replace(
        d_model=128)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    serve = jax.jit(tr.make_serve_fn(cfg))

    # 4 requests with different prompt lengths, decoded as one batch.
    rng = np.random.default_rng(0)
    prompt_lens = [5, 9, 3, 7]
    B, max_new = len(prompt_lens), 16
    max_len = max(prompt_lens) + max_new
    prompts = [rng.integers(2, cfg.vocab_size, size=(n,)).tolist()
               for n in prompt_lens]

    state = lm.init_state(cfg, B, max_len, jnp.dtype(cfg.dtype))
    done_prompt = [False] * B
    outputs = [[] for _ in range(B)]
    # step the whole batch in lockstep; slots still consuming their prompt
    # feed the next prompt token, finished slots feed the model's sample.
    last = jnp.zeros((B, 1), jnp.int32)
    for pos in range(max_len - 1):
        feed = []
        for b in range(B):
            if pos < prompt_lens[b]:
                feed.append(prompts[b][pos])
            else:
                feed.append(int(last[b, 0]))
        nxt, logits, state = serve(params, state,
                                   jnp.asarray(feed)[:, None],
                                   jnp.int32(pos))
        last = nxt[:, None]
        for b in range(B):
            if pos >= prompt_lens[b] - 1 and len(outputs[b]) < max_new:
                outputs[b].append(int(nxt[b]))
    for b in range(B):
        print(f"req{b} prompt[{prompt_lens[b]}] -> {outputs[b][:12]}")


if __name__ == "__main__":
    main()
