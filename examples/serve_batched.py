"""Continuous-batching example: mixed-length requests with per-request
sampling settings, served through the engine (parallel prefill + one jitted
multi-slot decode with per-slot positions), then the same batch again with
self-speculative decoding turned on.

See docs/serving.md for the engine API reference and the speculative
decoding knobs (``speculative=K``, ``draft_stride``).

    PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np
import jax

from repro.configs.all_configs import reduce_for_smoke
from repro.configs.base import get_config
from repro.models import lm
from repro.serve import Request, SamplingParams, ServeEngine


def make_requests(cfg):
    # 6 requests with different prompt lengths and sampling settings served
    # on 4 slots: slots free up on finish and are refilled from the queue.
    rng = np.random.default_rng(0)
    prompt_lens = [5, 9, 3, 7, 12, 4]
    samplings = [
        SamplingParams(),                                   # greedy
        SamplingParams(temperature=0.8, top_k=40),
        SamplingParams(temperature=1.0, top_p=0.9),
        SamplingParams(),
        SamplingParams(temperature=0.7, top_k=20, top_p=0.95),
        SamplingParams(temperature=1.2),
    ]
    return [Request(id=i,
                    prompt=rng.integers(2, cfg.vocab_size, size=(n,)).tolist(),
                    max_new_tokens=16, sampling=sp)
            for i, (n, sp) in enumerate(zip(prompt_lens, samplings))], \
        max(prompt_lens)


def report(engine, results):
    for r in sorted(results, key=lambda r: r.id):
        print(f"req{r.id} prompt[{r.prompt_len}] {r.finish_reason:>6} "
              f"ttft {r.ttft_s * 1e3:6.1f}ms -> {r.tokens[:12]}")
    s = engine.stats
    print(f"prefill {s['prefill_tokens']} tok / {s['prefill_s']:.3f}s | "
          f"decode {s['decode_tokens']} tok / "
          f"{s['decode_s'] + s['mixed_s']:.3f}s "
          f"in {s['decode_steps']} steps "
          f"({s['mixed_steps']} interleaved with prefill chunks)")
    if s["spec_rounds"]:
        sp = engine.spec_summary()
        print(f"speculative: {s['spec_rounds']} rounds, "
              f"acceptance {sp['acceptance_rate']:.2%}, "
              f"{sp['tokens_per_slot_round']:.2f} tok/slot/round")


def main():
    cfg = reduce_for_smoke(get_config("recurrentgemma-2b")).replace(
        d_model=128)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    reqs, longest = make_requests(cfg)
    engine = ServeEngine(cfg, params, max_slots=4, max_len=longest + 16,
                         seed=0)
    report(engine, engine.run(reqs))

    # Same batch, self-speculatively: each decode dispatch drafts 3 tokens
    # with a layer-skip reduced model (every 2nd block) and verifies them
    # with one full-model pass — greedy requests get bit-identical tokens,
    # sampled requests stay unbiased (rejection-sampling acceptance).
    print("\n--- speculative (K=3, draft stride 2) ---")
    reqs, longest = make_requests(cfg)
    spec = ServeEngine(cfg, params, max_slots=4, max_len=longest + 16,
                       seed=0, speculative=3, draft_stride=2)
    report(spec, spec.run(reqs))


if __name__ == "__main__":
    main()
