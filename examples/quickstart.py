"""Quickstart: build a small RoM-Samba hybrid, train it, generate from it.

    PYTHONPATH=src python examples/quickstart.py

Runs in ~2 minutes on CPU.  Shows the three public API layers:
configs -> train-step factory -> serving engine.  See docs/architecture.md
for the layer map and docs/serving.md for the engine reference.
"""
import jax
import jax.numpy as jnp

from repro import train as tr
from repro.configs.base import (AttentionConfig, MambaConfig, ModelConfig,
                                RoMConfig)
from repro.data.pipeline import MarkovCorpus
from repro.serve import Request, ServeEngine


def main():
    # 1. A model is a block-pattern config.  This is a 4-deep Samba-style
    #    hybrid whose Mamba layers carry RoM projection experts (the paper's
    #    method): one shared router per layer routes Conv/Gate/Out experts.
    cfg = ModelConfig(
        name="quickstart-rom-samba", d_model=128, vocab_size=256,
        segments=((("rom_mamba", "mlp", "attn", "mlp"), 2),), d_ff=256,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=32,
                                  window=64),
        mamba=MambaConfig(d_state=8, chunk=32),
        rom=RoMConfig(num_experts=8, top_k=1, jitter_eps=0.01,
                      capacity_factor=2.0),
        dtype="float32")

    # 2. Train on the regime-mixture corpus (experts specialize per regime).
    corpus = MarkovCorpus(vocab_size=256, seq_len=128, batch=16, seed=0)
    hp = tr.TrainHParams(base_lr=3e-3, warmup_steps=10, total_steps=150)
    step = jax.jit(tr.make_train_fn(cfg, hp=hp))
    state = tr.init_train_state(cfg)
    for i in range(150):
        batch = {k: jnp.asarray(v) for k, v in corpus.batch_at(i).items()}
        state, m = step(state, batch)
        if i % 25 == 0 or i == 149:
            print(f"step {i:4d}  loss={float(m['loss']):.3f}  "
                  f"load_max={float(m['load_max']):.2f}  "
                  f"drop={float(m['drop_frac']):.3f}")

    # 3. Generate through the serving engine: parallel prefill (one
    #    training-style pass per power-of-two prompt chunk) + continuous-
    #    batching greedy decode.  docs/serving.md documents the engine API,
    #    including speculative decoding (ServeEngine(..., speculative=K)).
    B, prompt_len, gen_len = 2, 16, 24
    prompts = jnp.asarray(corpus.batch_at(999)["tokens"])[:B, :prompt_len]
    engine = ServeEngine(cfg, state["params"], max_slots=B,
                         max_len=prompt_len + gen_len + 1)
    results = engine.run([
        Request(id=i, prompt=prompts[i].tolist(), max_new_tokens=gen_len)
        for i in range(B)])
    by_id = {r.id: r for r in results}
    print("generated:", by_id[0].tokens)


if __name__ == "__main__":
    main()
