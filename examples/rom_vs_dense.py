"""Reproduce the paper's core quality claim at laptop scale:
RoM (shared router) beats dense and naive MoE-Mamba at equal ACTIVE params.

    PYTHONPATH=src python examples/rom_vs_dense.py [--steps 240]
"""
import argparse

from benchmarks.scaling_proxy import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=240)
    args = ap.parse_args()
    results = run(steps=args.steps)
    rom, dense = results["rom_mamba"], results["mamba"]
    print(f"\nRoM improves held-out PPL by "
          f"{100 * (dense - rom) / dense:.1f}% over the matched-active "
          f"dense Mamba (paper Figs. 3-4 direction).")


if __name__ == "__main__":
    main()
