"""End-to-end driver: fault-tolerant training of a ~100M-class RoM model.

    PYTHONPATH=src python examples/train_fault_tolerant.py \
        [--steps 300] [--full]

Default runs the reduced rom-mamba-115m family config for a few hundred
steps with checkpointing, an *injected mid-run failure*, and automatic
restart — demonstrating that recovery is bit-exact (the data pipeline is
stateless in (seed, step)).  ``--full`` trains the real 115M config (slow
on CPU; the paper-scale path).
"""
import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro import train as tr
from repro.configs.all_configs import reduce_for_smoke
from repro.configs.base import get_config
from repro.data.pipeline import MarkovCorpus
from repro.distributed.fault_tolerance import RunManager
from repro.launch.mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--fail-at", type=int, default=150)
    args = ap.parse_args()

    cfg = get_config("rom-mamba-115m")
    if not args.full:
        cfg = reduce_for_smoke(cfg).replace(d_model=128)
    mesh = make_host_mesh()
    corpus = MarkovCorpus(vocab_size=min(cfg.vocab_size, 256), seq_len=256,
                          batch=8, seed=0)
    # clip vocab for the corpus; model vocab stays as configured
    hp = tr.TrainHParams(base_lr=1e-3, warmup_steps=30,
                         total_steps=args.steps)
    step_fn = tr.make_train_step(cfg, mesh, hp=hp, donate=False)

    boom = {"armed": args.fail_at > 0}

    def data_fn(step):
        if step == args.fail_at and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("simulated preemption / node failure")
        return {k: jnp.asarray(v) for k, v in corpus.batch_at(step).items()}

    ckpt_dir = tempfile.mkdtemp(prefix="rom_ft_")
    try:
        mgr = RunManager(ckpt_dir, save_every=50, async_save=True)
        shapes = tr.train_state_shapes(cfg)
        shards = tr.state_shardings(shapes, mesh)
        state, hist = mgr.run(
            init_fn=lambda: tr.init_train_state(cfg),
            step_fn=step_fn, data_fn=data_fn, num_steps=args.steps,
            state_shardings=shards, log_every=50)
        print(f"\nfinal loss {float(hist[-1]['loss']):.4f} | "
              f"restarts={mgr.restarts} (1 expected) | "
              f"checkpoints kept: {len(hist) // 50 + 1}")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
