"""Training-throughput comparison (paper Table 11, CPU-relative form).

The paper reports RoM at ~80% of the matched-active dense model's tokens/s
on 8xA100 *without optimization*.  Hardware differs, but the *relative*
cost of routing + dispatch vs dense compute is measurable here: we time
samba-421m vs samba-421m-rom vs samba-511m at reduced width on CPU and
report tokens/s plus the RoM/dense ratio, alongside an analytic v5e
projection from the dry-run roofline terms (see EXPERIMENTS.md).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import train as tr
from repro.configs.all_configs import reduce_for_smoke
from repro.configs.base import get_config
from repro.data.pipeline import TokenCorpus


def tokens_per_s(cfg, steps=8, batch=8, seq=256, warmup=2):
    corpus = TokenCorpus(vocab_size=cfg.vocab_size, seq_len=seq, batch=batch)
    step = jax.jit(tr.make_train_fn(cfg))
    state = tr.init_train_state(cfg)
    b = {k: jnp.asarray(v) for k, v in corpus.batch_at(0).items()}
    for i in range(warmup):
        state, m = step(state, b)
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in corpus.batch_at(i + 1).items()}
        state, m = step(state, b)
    jax.block_until_ready(m["loss"])
    return steps * batch * seq / (time.perf_counter() - t0)


def run(out=print):
    rows = [("samba-421m", "dense expand=2"),
            ("samba-421m-rom", "+RoM (2.1x total params)"),
            ("samba-511m", "dense expand=4")]
    res = {}
    for name, label in rows:
        cfg = reduce_for_smoke(get_config(name))
        tps = tokens_per_s(cfg)
        res[name] = tps
        out(f"{name},{label},{tps:.0f} tok/s (CPU, reduced width)")
    rel = res["samba-421m-rom"] / res["samba-421m"]
    out(f"# RoM relative throughput vs matched-active dense: "
        f"{100 * rel:.0f}% (paper Table 11: ~80% on 8xA100)")
    return res
