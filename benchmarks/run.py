"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

  params   Tables 1/5/7 parameter accounting vs the paper's totals
  flops    Table 1 forward-FLOPs + the 23%-saving claim
  proxy    Figures 2/3 + Table 4 quality ordering at tiny scale
  tput     Table 11 relative training throughput
  roofline dry-run roofline summary (if dry-run records exist)
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the training-based proxy benchmark")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: params,flops,proxy,tput,"
                         "roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    t0 = time.time()
    print("== benchmarks ==", flush=True)

    if want("params"):
        print("\n-- params (paper Tables 1/5/7) --", flush=True)
        from benchmarks import params_tables
        params_tables.run()

    if want("flops"):
        print("\n-- flops (paper Table 1) --", flush=True)
        from benchmarks import flops
        flops.table1()

    if want("tput"):
        print("\n-- throughput (paper Table 11) --", flush=True)
        from benchmarks import throughput
        throughput.run()

    if want("proxy") and not args.fast:
        print("\n-- quality proxy (paper Figs 2/3, Table 4) --", flush=True)
        from benchmarks import scaling_proxy
        scaling_proxy.run()

    if want("roofline"):
        print("\n-- roofline (dry-run records) --", flush=True)
        try:
            from repro.launch.report import print_summary
            print_summary("single")
        except Exception as e:  # records may not exist yet
            print(f"(no dry-run records: {e})")

    print(f"\n== done in {time.time() - t0:.0f}s ==")


if __name__ == "__main__":
    main()
