"""Serving benchmark: a registry of named scenarios sharing one runner.

    PYTHONPATH=src python benchmarks/serving.py --smoke --mixer-sweep \
        --out BENCH_serving.json
    PYTHONPATH=src python benchmarks/serving.py --smoke --scenario kernels
    PYTHONPATH=src python benchmarks/serving.py --arch rom-mamba-115m \
        --prompt-len 128 --gen 32 --scenario engine --scenario load
    PYTHONPATH=src python benchmarks/serving.py --list

Each scenario is a ``@scenario("name")``-registered function taking the
shared ``BenchContext`` (config, params, plan, prompts) and returning a
JSON-ready dict; the runner selects scenarios via repeatable
``--scenario`` flags (default: all) and writes one report whose
``scenarios`` object holds each result.  The committed ``BENCH_serving.json``
at the repo root is the perf trajectory CI diffs against
(benchmarks/trajectory.py applies per-metric regression thresholds; see
docs/serving.md "Benchmark trajectory").

Scenarios:

  prefill        tokens/s prefilling via models/lm.prefill (the engine
                 path: one training-style pass per power-of-two chunk) vs
                 stepping the jitted decode path one token at a time (the
                 pre-engine baseline), and their ratio.
  engine         batch decode throughput + TTFT mean/p50/p95 through the
                 full ServeEngine.
  kernels        EngineConfig(kernels=...) A/B: decode tokens/s under the
                 "ref" oracles vs the "pallas" fused decode fast path
                 (per-mixer single-timestep recurrence kernels fused with
                 gate/out-proj, routed top-k expert projection without
                 dispatch machinery, greedy argmax folded into the output
                 projection), plus a greedy token-identity check between
                 the two.  ``--mixer-sweep`` adds the same A/B per
                 recurrent-mixer family (mamba2/gdn/rglru/mlstm/slstm) on
                 one reduced arch each.
  expert_library multi-tenant serving through an ExpertLibrary: requests
                 round-robin across the base expert set plus N tenant sets
                 with fewer binding rows than sets (hot swaps on the decode
                 path); decode tokens/s vs the single-set baseline, swap
                 counts, residency hit rate, and a per-tenant greedy
                 token-identity gate against dedicated single-set engines.
  load           staggered-arrival scenario: requests arrive in bursts
                 while decode is active, under both admission modes plus a
                 no-admission baseline; decode tokens/s, stall seconds,
                 TTFT p50/p95 overall and for mid-run arrivals.
  speculative    self-speculative decoding on vs off: decode tokens/s both
                 ways, draft acceptance rate, tokens per round.
  prefix_cache   shared-system-prompt workload against a warm PrefixCache
                 vs cache-off: hit rate, prefill tokens saved, TTFT both
                 ways.
  observability  telemetry overhead A/B: the same requests through an
                 engine with full telemetry (metrics + request tracing)
                 on vs ``Telemetry(enabled=False)``; end-to-end tokens/s
                 both ways, their ratio (gated >= 0.95 functionally by
                 trajectory.py), a greedy token-identity check, and the
                 exporter outputs (Prometheus lines, trace events) —
                 written as CI artifacts via ``--telemetry-artifacts``.

Latency percentiles (TTFT/ITL/e2e) are derived from the telemetry
histograms over a registry ``snapshot()``/``delta()`` window spanning
exactly the timed run — the same log-spaced buckets a live server
exports — not from ad-hoc per-result lists.

Every scenario dict carries an ``engine`` stamp built by the single
``engine_stamp`` helper (schema_version, jax/jaxlib versions, device
kind, plan, admission mode, speculative K, draft stride, slots, prefill
chunk, prefix-cache budget, scheduler, kernels impl, telemetry
config) so the per-PR
artifacts are self-describing; the full JSON schema is documented in
docs/serving.md.  ``--kernels-impl interpret`` swaps the fast side of
the kernels A/B to the real Pallas kernels under the interpreter — the
CI identity gate (benchmarks/trajectory.py --identity-only).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import train as tr
from repro.configs.all_configs import reduce_for_smoke
from repro.configs.base import get_config
from repro.data.pipeline import corpus_for
from repro.distributed.plan import ParallelPlan
from repro.models import lm
from repro.serve import (EngineConfig, Request, ServeEngine, Telemetry,
                         hist_mean, hist_quantile)


def _best_of(fn, iters):
    """Best-of-N timing: the best throughput sample is the least
    load-disturbed one (both timed regions here are short on smoke)."""
    return max(fn() for _ in range(iters))


#: Version of the benchmark JSON schema (stamped on every scenario via
#: ``engine_stamp``).  Bump when scenario keys change shape or meaning so
#: per-PR artifacts stay comparable across history.
#: v4: jax/jaxlib/device_kind in the stamp, per-mixer kernels sweep.
#: v5: telemetry config in the stamp, observability scenario, latency
#: percentiles (ttft/itl/e2e) derived from telemetry histograms.
SCHEMA_VERSION = 5


def engine_stamp(engine):
    """The one engine-config stamp every scenario dict attaches, so each
    benchmark artifact records exactly how it was produced.  Scenarios
    must build their stamp here — never inline — so fields (and
    ``schema_version``) stay consistent across the report.  ``plan``
    records the ParallelPlan (mesh shape + slot/expert partitions), making
    every perf artifact attributable to a topology; ``jax``/``jaxlib``/
    ``device_kind`` pin the software and device generation the numbers
    came from (trajectory.py warns — without failing — when the committed
    baseline was produced on a different device kind)."""
    import jaxlib
    return {
        "schema_version": SCHEMA_VERSION,
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "device_kind": jax.devices()[0].device_kind,
        "plan": engine.plan.describe(),
        "admission": engine.admission,
        "speculative_k": engine.spec.k if engine.spec else 0,
        "draft_stride": engine.spec.draft_stride if engine.spec else 0,
        "max_slots": engine.max_slots,
        "max_prefill_chunk": engine.max_prefill_chunk,
        "prefix_cache_mb": (round(engine.cache.budget_bytes / (1 << 20), 3)
                            if engine.cache is not None else 0),
        "cache_grain": (engine.cache.grain
                        if engine.cache is not None else 0),
        "scheduler": type(engine.scheduler).__name__,
        "kernels": engine.engine_config.kernels or "auto",
        "telemetry": engine.telemetry.describe(),
    }


# ---------------------------------------------------------------------------
# scenario registry: one decorator, one shared context, one runner
# ---------------------------------------------------------------------------

SCENARIOS: Dict[str, Callable[["BenchContext"], dict]] = {}


def scenario(name: str, features=()):
    """Register a benchmark scenario under ``name`` (selectable with
    ``--scenario name``; all registered scenarios run by default).
    ``features`` names the engine capabilities the scenario exercises —
    ``--list`` prints them so a reader knows what each number measures
    without opening the function."""
    def deco(fn):
        fn.scenario_name = name
        fn.features = tuple(features)
        SCENARIOS[name] = fn
        return fn
    return deco


@dataclasses.dataclass
class BenchContext:
    """Everything scenarios share: built once by the runner."""
    cfg: Any
    params: Any
    plan: ParallelPlan
    prompts: np.ndarray          # (batch, prompt_len) scenario prompts
    load_prompts: np.ndarray     # (n_load, prompt_len) for the load burst
    gen: int
    max_len: int
    chunk: int
    seed: int
    args: argparse.Namespace

    def engine(self, **overrides):
        """A ServeEngine on the shared config/params/plan with the
        context's default knobs, any of which a scenario may override."""
        kw = dict(max_slots=self.prompts.shape[0], max_len=self.max_len,
                  seed=self.seed, max_prefill_chunk=self.chunk)
        kw.update(overrides)
        extra = {k: kw.pop(k)
                 for k in ("prefix_cache", "scheduler", "expert_library",
                           "telemetry")
                 if k in kw}
        return ServeEngine(self.cfg, self.params, plan=self.plan,
                           engine=EngineConfig(**kw), **extra)

    def requests(self, prompts=None, gen=None, id0=0):
        prompts = self.prompts if prompts is None else prompts
        return [Request(id=id0 + i, prompt=prompts[i].tolist(),
                        max_new_tokens=gen or self.gen)
                for i in range(prompts.shape[0])]


def _decode_tps(stats):
    return stats["decode_tokens"] / max(stats["decode_s"] + stats["mixed_s"],
                                        1e-9)


def _pct(xs, p):
    return round(float(np.percentile(np.asarray(xs), p)), 4) if xs else 0.0


def _hist_latency(delta, name, prefix):
    """mean/p50/p95 of one latency histogram out of a registry delta:
    bucket-interpolated quantiles over exactly the timed window, the
    same numbers a live server's exporter would show."""
    h = delta[name]
    return {f"{prefix}_mean_s": round(hist_mean(h), 4),
            f"{prefix}_p50_s": round(hist_quantile(h, 0.50), 4),
            f"{prefix}_p95_s": round(hist_quantile(h, 0.95), 4)}


def _counter_window(delta, stat_counters):
    """Legacy-keyed counter readings from a registry ``delta`` — the
    windowed replacement for the old ``pre = dict(x.stats)`` arithmetic
    (``stat_counters`` is a component's legacy-key -> instrument map)."""
    return {key: delta.get(name, {}).get("value", 0)
            for key, (name, _) in stat_counters.items()}


# ---------------------------------------------------------------------------
# prefill: parallel chunked prefill vs per-token stepping
# ---------------------------------------------------------------------------

def pertoken_prefill_tps(cfg, params, prompts, max_len, iters=3):
    """The old serve path: prompts consumed one jitted decode step/token."""
    B, S = prompts.shape
    serve = jax.jit(tr.make_serve_fn(cfg))

    def once():
        state = lm.init_state(cfg, B, max_len, jnp.dtype(cfg.dtype))
        t0 = time.perf_counter()
        for pos in range(S):
            nxt, logits, state = serve(params, state,
                                       prompts[:, pos:pos + 1],
                                       jnp.int32(pos))
        jax.block_until_ready(nxt)
        return B * S / (time.perf_counter() - t0)

    once()                                   # compile outside timed region
    return _best_of(once, iters)


def parallel_prefill_tps(cfg, params, prompts, max_len, chunk, iters=3):
    """The engine path: chunked parallel prefill (state threads chunks)."""
    from repro.serve.engine import prefill_chunks
    B, S = prompts.shape
    pf = jax.jit(tr.make_prefill_step_fn(cfg))
    chunks = prefill_chunks(S, chunk)

    def once():
        state = lm.init_state(cfg, B, max_len, jnp.dtype(cfg.dtype))
        t0 = time.perf_counter()
        pos = 0
        for c in chunks:
            logits, state = pf(params, state, prompts[:, pos:pos + c],
                               jnp.int32(pos))
            pos += c
        jax.block_until_ready(logits)
        return B * S / (time.perf_counter() - t0)

    once()                                   # compile outside timed region
    return _best_of(once, iters)


@scenario("prefill", features=("chunked_prefill",))
def prefill_metrics(ctx: BenchContext):
    """Chunked parallel prefill tokens/s vs the token-by-token decode
    baseline, and their ratio."""
    prompts = jnp.asarray(ctx.prompts)
    par = parallel_prefill_tps(ctx.cfg, ctx.params, prompts, ctx.max_len,
                               ctx.chunk)
    per = pertoken_prefill_tps(ctx.cfg, ctx.params, prompts, ctx.max_len)
    return {
        "parallel_tps": round(par, 1),
        "pertoken_tps": round(per, 1),
        "speedup": round(par / per, 2),
        "engine": engine_stamp(ctx.engine()),
    }


# ---------------------------------------------------------------------------
# engine: batch decode throughput + TTFT through the full ServeEngine
# ---------------------------------------------------------------------------

@scenario("engine", features=("continuous_batching",))
def engine_metrics(ctx: BenchContext):
    """Batch decode throughput + TTFT/ITL/e2e percentiles through the
    full ServeEngine, read from the telemetry histograms over a registry
    delta spanning exactly the timed run (the warm/compile pass stays in
    the cumulative registry but out of the window)."""
    engine = ctx.engine()
    engine.run(ctx.requests())                  # compile + warm
    engine.reset_stats()
    pre = engine.telemetry.registry.snapshot()
    results = engine.run(ctx.requests())
    d = engine.telemetry.registry.delta(pre)
    out = {
        "decode_tps": round(_decode_tps(engine.stats), 1),
        "requests": len(results),
    }
    out.update(_hist_latency(d, "serve_ttft_seconds", "ttft"))
    out.update(_hist_latency(d, "serve_decode_step_seconds", "itl"))
    out.update(_hist_latency(d, "serve_e2e_seconds", "e2e"))
    out["engine"] = engine_stamp(engine)
    return out


# ---------------------------------------------------------------------------
# kernels: ref oracles vs the fused pallas decode fast path
# ---------------------------------------------------------------------------

def _step_time_s(cfg, params, kernels, batch, max_len, iters=5, steps=100):
    """Best-of greedy decode+sample step latency under an
    ``ops.default_impl`` scope, measured as one jitted ``lax.scan`` over
    ``steps`` steps — a single dispatch, so neither the engine's Python
    loop nor per-call host dispatch (identical across impls, and the
    dominant wall-clock terms at smoke scale) drowns the kernel
    difference.  The step is composed exactly as the engine's
    ``decode_core`` runs it: full logits + ``sample`` under "ref",
    pre-logits hidden row + the fused sampling epilogue (argmax inside
    the output projection, no softmax stats) under a kernel scope."""
    from repro.kernels import ops as kernel_ops
    from repro.serve.sampling import sample, sample_fused

    rt = lm.Runtime(shard=ParallelPlan.single_device().shard_ctx(),
                    rng=None, train=False)
    st = lm.init_state(cfg, batch, max_len, jnp.dtype(cfg.dtype))
    toks = jnp.full((batch, 1), 3, jnp.int32)
    rng = jax.random.PRNGKey(0)
    temp = jnp.zeros((batch,), jnp.float32)
    topk = jnp.zeros((batch,), jnp.int32)
    topp = jnp.ones((batch,), jnp.float32)

    def step_ref(p, s, t):
        logits, s2 = lm.decode_step(p, s, t, jnp.int32(0), cfg, rt)
        return sample(logits, rng, temp, topk, topp), s2

    def step_fused(p, s, t):
        hidden, s2 = lm.decode_step_hidden(p, s, t, jnp.int32(0), cfg, rt)
        table = p["embed"] if cfg.tie_embeddings else p["lm_head"]
        nxt = sample_fused(
            hidden[:, 0], table, cfg.tie_embeddings, cfg.logit_softcap,
            lambda: lm.logits_fn(p, hidden, cfg, rt)[:, 0],
            rng, temp, topk, topp)
        return nxt, s2

    with kernel_ops.default_impl(kernels):
        step = (step_ref if kernel_ops.active_default() is None
                or kernels == "ref" else step_fused)

        def body(s, _):
            nxt, s2 = step(params, s, toks)
            return s2, nxt

        fn = jax.jit(lambda s: jax.lax.scan(body, s, None, length=steps)[1])
        jax.block_until_ready(fn(st))                # compile outside timing
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(st))
            best = min(best, (time.perf_counter() - t0) / steps)
    return best


#: mixer family -> (registered arch carrying the family's hyperparams,
#: the layer kind the sweep stacks).  RoM variants where one exists, so
#: the routed projection fast path rides along; plain slstm has no RoM
#: form.  ``--mixer-sweep`` A/Bs each one.
MIXER_ARCHS = {
    "mamba2": ("mamba2-rom-353m", "rom_mamba2"),
    "gdn": ("gdn-rom-343m", "rom_gdn"),
    "rglru": ("rom-recurrentgemma-2b", "rom_rglru"),
    "mlstm": ("rom-xlstm-350m", "rom_mlstm"),
    "slstm": ("xlstm-350m", "slstm"),
}


def _mixer_ab(ctx: BenchContext, arch_name, kind, depth=4, prompt_len=16,
              gen=6, batch=2, steps=25):
    """One kernels A/B per mixer family: greedy token identity through the
    engine plus the jitted decode-step microbenchmark under kernels='ref'
    vs 'pallas'.  The model is a short pure stack of the family's layer
    kind (hyperparams from its registered arch) — a mixed-pattern arch
    would bury the mixer under the other layers, and a toy vocab would
    bury the fused sampling epilogue (whose saving is vocab-proportional),
    so the smoke reduction keeps a serving-sized vocab.  Workload is
    deliberately small — each sweep entry compiles its own model twice,
    and the step ratio (not the absolute number) is the signal."""
    cfg = get_config(arch_name)
    if ctx.args.smoke:
        cfg = reduce_for_smoke(cfg).replace(vocab_size=4096)
    cfg = cfg.replace(name=f"{cfg.name}-{kind}x{depth}",
                      segments=(((kind,), depth),))
    params = lm.init_params(jax.random.PRNGKey(ctx.seed), cfg)
    max_len = prompt_len + gen + 1
    rng = np.random.default_rng(ctx.seed)
    prompts = rng.integers(2, cfg.vocab_size, size=(batch, prompt_len))
    out = {"arch": cfg.name}
    fast = ctx.args.kernels_impl
    toks = {}
    for impl in ("ref", fast):
        eng = ServeEngine(cfg, params,
                          engine=EngineConfig(max_slots=batch,
                                              max_len=max_len, seed=ctx.seed,
                                              max_prefill_chunk=8,
                                              kernels=impl))
        res = eng.run([Request(id=i, prompt=prompts[i].tolist(),
                               max_new_tokens=gen) for i in range(batch)])
        toks[impl] = {r.id: r.tokens for r in res}
        step_s = _step_time_s(cfg, params, impl, batch, max_len, iters=3,
                              steps=steps)
        out[impl] = {"step_us": round(step_s * 1e6, 1),
                     "step_tps": round(batch / step_s, 1),
                     "engine": engine_stamp(eng)}
    out["step_tps_vs_ref"] = round(
        out[fast]["step_tps"] / max(out["ref"]["step_tps"], 1e-9), 3)
    out["greedy_identical"] = bool(toks["ref"] == toks[fast])
    return out


@scenario("kernels", features=("kernels", "fused_sampling"))
def kernels_metrics(ctx: BenchContext, iters=3):
    """EngineConfig(kernels=...) A/B on the same requests: "ref" decodes
    through the jnp oracles (O(E×) dense experts for RoM), "pallas"
    through the fused decode fast path (on TPU the Pallas kernels, off-TPU
    their fused jnp composites — either way skipping the MoE dispatch
    machinery per token, and folding greedy sampling into the output
    projection).  Greedy outputs must be token-identical.  Each impl
    carries two throughputs: ``decode_tps`` through the full engine
    (end-to-end, includes the impl-independent host loop) and ``step_tps``
    from a jitted decode-step microbenchmark (the kernel-level number —
    its ratio is the enforceable "measurably faster" claim).  With
    ``--mixer-sweep``, ``mixers`` adds the same A/B per recurrent-mixer
    family on its own arch (each with its own ``greedy_identical`` gate,
    enforced recursively by trajectory.py)."""
    out = {"arch": ctx.cfg.name}
    fast = ctx.args.kernels_impl
    toks = {}
    for impl in ("ref", fast):
        eng = ctx.engine(kernels=impl)
        results = eng.run(ctx.requests())            # compile + warm
        toks[impl] = {r.id: r.tokens for r in results}
        best = 0.0
        for _ in range(iters):
            eng.reset_stats()
            eng.run(ctx.requests())
            best = max(best, _decode_tps(eng.stats))
        step_s = _step_time_s(ctx.cfg, ctx.params, impl,
                              len(ctx.prompts), ctx.max_len)
        out[impl] = {"decode_tps": round(best, 1),
                     "step_us": round(step_s * 1e6, 1),
                     "step_tps": round(len(ctx.prompts) / step_s, 1),
                     "engine": engine_stamp(eng)}
    for m in ("decode_tps", "step_tps"):
        out[f"{m}_vs_ref"] = round(
            out[fast][m] / max(out["ref"][m], 1e-9), 3)
    out["greedy_identical"] = bool(toks["ref"] == toks[fast])
    if ctx.args.mixer_sweep:
        out["mixers"] = {name: _mixer_ab(ctx, arch, kind)
                         for name, (arch, kind) in sorted(
                             MIXER_ARCHS.items())}
    return out


# ---------------------------------------------------------------------------
# speculative: self-speculative decoding on vs off
# ---------------------------------------------------------------------------

@scenario("speculative", features=("speculative", "draft_stride"))
def speculative_metrics(ctx: BenchContext, iters=3):
    """Greedy decode of the same requests with speculative decoding on vs
    off: decode tokens/s for both, acceptance rate, tokens per round.
    Greedy outputs are bit-identical by construction (tested in
    tests/test_serve_engine.py); the benchmark records whether the draft is
    accurate enough for the K-token dispatches to win wall-clock."""
    k, stride = ctx.args.speculative_k, ctx.args.draft_stride
    out = {"k": int(k), "draft_stride": int(stride), "gen": int(ctx.gen)}

    def run_once(spec_k):
        eng = ctx.engine(speculative=spec_k, draft_stride=stride)
        eng.run(ctx.requests())                      # compile + warm
        best = None
        for _ in range(iters):
            eng.reset_stats()
            eng.run(ctx.requests())
            s = dict(eng.stats)
            tps = _decode_tps(s)
            if best is None or tps > best[0]:
                best = (tps, s, eng.spec_summary())
        return best + (engine_stamp(eng),)

    tps_off, _, _, stamp_off = run_once(0)
    tps_on, s, summ, stamp_on = run_once(k)
    out["baseline"] = {"decode_tps": round(tps_off, 1), "engine": stamp_off}
    out["speculative"] = {
        "decode_tps": round(tps_on, 1),
        "acceptance_rate": round(summ["acceptance_rate"], 4),
        # tokens emitted per slot per round — comparable to the 1..k+1 window
        "tokens_per_round": round(summ["tokens_per_slot_round"], 3),
        "rounds": s["spec_rounds"],
        "engine": stamp_on,
    }
    out["decode_tps_vs_baseline"] = round(tps_on / max(tps_off, 1e-9), 3)
    return out


# ---------------------------------------------------------------------------
# prefix_cache: shared-system-prompt workload
# ---------------------------------------------------------------------------

@scenario("prefix_cache", features=("prefix_cache", "scheduler"))
def prefix_cache_metrics(ctx: BenchContext, n_requests=6, tail_len=8,
                         max_slots=4, chunk=16, iters=3):
    """The workload prefix caching unlocks: every request shares a long
    system prompt (multi-turn chat, few-shot headers) and differs only in a
    short tail.  A warm request populates the radix tree, then the same
    batch runs with the cache on vs off: hit rate, prefill tokens actually
    computed (and the saved fraction), and TTFT p50/p95.  Greedy outputs
    are bit-identical by construction (tested per mixer pattern in
    tests/test_prefix_cache.py); the benchmark records how much prompt work
    the O(uncached suffix) cost model actually removes."""
    from repro.serve import CachedSuffixFirst, PrefixCache
    from repro.serve.cache import _STAT_COUNTERS as _CACHE_COUNTERS
    cfg, params, plan, seed = ctx.cfg, ctx.params, ctx.plan, ctx.seed
    budget_mb, grain = ctx.args.prefix_cache_mb, ctx.args.cache_grain
    shared_len = min(48, ctx.prompts.shape[1])
    max_len = shared_len + tail_len + ctx.gen + 1
    # slots must shard evenly over the plan's slot partition
    max_slots = plan.round_slots(max_slots)
    rng = np.random.default_rng(seed)
    shared = rng.integers(2, cfg.vocab_size, size=(shared_len,)).tolist()

    def requests():
        return [Request(id=i,
                        prompt=shared + rng.integers(
                            2, cfg.vocab_size, size=(tail_len,)).tolist(),
                        max_new_tokens=ctx.gen)
                for i in range(n_requests)]

    def run(cached):
        # one registry across engine + cache + scheduler: engine latency
        # histograms and cache counters come out of the same delta window
        telem = Telemetry()
        cache = (PrefixCache(budget_mb=budget_mb, grain=grain,
                             registry=telem.registry)
                 if cached else None)
        eng = ctx.engine(max_slots=max_slots, max_len=max_len,
                         max_prefill_chunk=chunk,
                         prefix_cache=cache, telemetry=telem,
                         scheduler=CachedSuffixFirst(cache) if cached
                         else None)
        if cached:
            # one warm request plants the shared-prefix boundaries — the
            # steady state of a server that has seen the system prompt
            eng.run([Request(id=-1, prompt=shared + [1],
                             max_new_tokens=1)])
        eng.run(requests())                        # compile + warm timings
        # the registry is cumulative over the stack's lifetime; the
        # reported counters must cover exactly the kept (best) iteration
        # — not the warm-up/compile runs, and not all iterations summed —
        # so each iteration reads a snapshot()/delta() window
        best = None
        for _ in range(iters):
            eng.reset_stats()
            pre = telem.registry.snapshot()
            eng.run(requests())
            d = telem.registry.delta(pre)
            s = dict(eng.stats)
            if best is None or (hist_quantile(d["serve_ttft_seconds"], 0.5)
                                < hist_quantile(
                                    best[0]["serve_ttft_seconds"], 0.5)):
                best = (d, s)
        d, s = best
        out = {
            "requests": n_requests,
            "prefill_tokens": s["prefill_tokens"],
            "cache_hit_tokens": s["cache_hit_tokens"],
            **_hist_latency(d, "serve_ttft_seconds", "ttft"),
            "engine": engine_stamp(eng),
        }
        if cached:
            cs = cache.summary()                   # snapshots/bytes: state
            cs.update(_counter_window(d, _CACHE_COUNTERS))
            cs["hit_rate"] = cs["hits"] / max(cs["hits"] + cs["misses"], 1)
            cs["token_hit_rate"] = (cs["hit_tokens"] /
                                    max(cs["lookup_tokens"], 1))
            out["cache"] = {k: (round(v, 4) if isinstance(v, float) else v)
                            for k, v in cs.items()}
        return out

    out = {"shared_len": int(shared_len), "tail_len": int(tail_len),
           "gen": int(ctx.gen), "max_slots": int(max_slots),
           "chunk": int(chunk), "budget_mb": budget_mb,
           "baseline": run(False), "cached": run(True)}
    base_tok = max(out["baseline"]["prefill_tokens"], 1)
    out["prefill_tokens_saved_frac"] = round(
        1.0 - out["cached"]["prefill_tokens"] / base_tok, 4)
    out["hit_rate"] = out["cached"]["cache"]["hit_rate"]
    out["ttft_p50_vs_baseline"] = round(
        out["cached"]["ttft_p50_s"] /
        max(out["baseline"]["ttft_p50_s"], 1e-9), 3)
    return out


# ---------------------------------------------------------------------------
# expert_library: multi-tenant serving with hot-swappable expert sets
# ---------------------------------------------------------------------------

@scenario("expert_library", features=("expert_library", "multi_tenant"))
def expert_library_metrics(ctx: BenchContext, n_tenants=2, max_bound=2,
                           iters=3):
    """Multi-tenant decode through an ExpertLibrary: requests round-robin
    across the base set plus ``n_tenants`` independently initialized expert
    sets, with only ``max_bound`` binding rows — fewer rows than sets, so
    admission hot-swaps sets on the live decode batch.  Reports decode
    tokens/s vs a single-set baseline engine, swap counts, and the
    library's residency counters (summed over the timed iterations, so the
    numbers are deterministic for a fixed workload).  The hard gate:
    every tenant's greedy tokens must be bit-identical to a dedicated
    single-set engine running that tenant's grafted params — the
    multi-tenant batch buys throughput, never output drift."""
    from repro.serve import ExpertLibrary
    from repro.serve.expert_library import _STAT_COUNTERS as _LIB_COUNTERS
    cfg = ctx.cfg
    # engine and library on one registry, so the library's residency
    # counters window with the same snapshot/delta as the engine metrics
    telem = Telemetry()
    library = ExpertLibrary(cfg, ctx.params,
                            budget_mb=ctx.args.expert_budget_mb,
                            max_bound=max_bound, plan=ctx.plan,
                            registry=telem.registry)
    for i in range(n_tenants):
        library.add(f"tenant{i}", lm.init_params(
            jax.random.PRNGKey(ctx.seed + 1000 + i), cfg))
    sets = [None] + [f"tenant{i}" for i in range(n_tenants)]
    n_req = ctx.prompts.shape[0]

    def tenant_requests():
        return [Request(id=i, prompt=ctx.prompts[i].tolist(),
                        max_new_tokens=ctx.gen,
                        expert_set=sets[i % len(sets)])
                for i in range(n_req)]

    eng = ctx.engine(expert_library=library, telemetry=telem)
    results = eng.run(tenant_requests())            # compile + warm
    toks = {r.id: r.tokens for r in results}

    # per-tenant identity gate against dedicated single-set engines
    identical = True
    for si, name in enumerate(sets):
        if name is None:
            params_t = ctx.params
        else:
            library.acquire(name)                   # ensure device-resident
            params_t = library.graft(ctx.params, [name])
            library.release(name)
        ded = ServeEngine(cfg, params_t, plan=ctx.plan,
                          engine=EngineConfig(max_slots=n_req,
                                              max_len=ctx.max_len,
                                              seed=ctx.seed,
                                              max_prefill_chunk=ctx.chunk))
        ids = [i for i in range(n_req) if i % len(sets) == si]
        res = ded.run([Request(id=i, prompt=ctx.prompts[i].tolist(),
                               max_new_tokens=ctx.gen) for i in ids])
        identical &= all(toks[r.id] == r.tokens for r in res)

    pre = telem.registry.snapshot()       # window: all timed iterations
    best = None
    for _ in range(iters):
        eng.reset_stats()
        eng.run(tenant_requests())
        s = dict(eng.stats)
        tps = _decode_tps(s)
        if best is None or tps > best[0]:
            best = (tps, s)
    tps_mt, s = best
    d = _counter_window(telem.registry.delta(pre), _LIB_COUNTERS)
    acq = d["hits"] + d["faults"]

    base_eng = ctx.engine()
    base_eng.run(ctx.requests())                    # compile + warm
    tps_base = 0.0
    for _ in range(iters):
        base_eng.reset_stats()
        base_eng.run(ctx.requests())
        tps_base = max(tps_base, _decode_tps(base_eng.stats))

    ls = library.summary()
    return {
        "tenants": int(n_tenants), "sets": len(sets),
        "max_bound": int(max_bound),
        "budget_mb": ctx.args.expert_budget_mb,
        "greedy_identical": bool(identical),
        "baseline": {"decode_tps": round(tps_base, 1),
                     "engine": engine_stamp(base_eng)},
        "multi_tenant": {
            "decode_tps": round(tps_mt, 1),
            "expert_swaps": s["expert_swaps"],
            "swaps_per_request": round(s["expert_swaps"] / max(n_req, 1), 3),
            "library": {"faults": d["faults"], "evictions": d["evictions"],
                        "residency_hit_rate": round(d["hits"] / max(acq, 1),
                                                    4),
                        "resident": ls["resident"],
                        "set_bytes_device": ls["bytes_device"]},
            "engine": engine_stamp(eng),
        },
        "decode_tps_vs_baseline": round(tps_mt / max(tps_base, 1e-9), 3),
    }


# ---------------------------------------------------------------------------
# load: staggered arrivals during active decode
# ---------------------------------------------------------------------------

def _drive(engine, initial, arrivals):
    """Run a scenario: ``initial`` requests submitted up front, ``arrivals``
    as (decode_step, request) pairs injected once decode reaches that step —
    i.e. while other requests are actively decoding."""
    for r in initial:
        engine.submit(r)
    pending = sorted(arrivals, key=lambda a: a[0])
    results = []
    t0 = time.perf_counter()
    while engine.busy() or pending:
        while pending and (engine.stats["decode_steps"] >= pending[0][0]
                           or not engine.busy()):
            engine.submit(pending.pop(0)[1])
        results.extend(engine.tick())
    wall = time.perf_counter() - t0
    return results, wall


def _scenario_requests(prompts, gen, n_initial):
    initial = [Request(id=i, prompt=prompts[i].tolist(),
                       max_new_tokens=2 * gen)
               for i in range(n_initial)]
    rest = list(range(n_initial, prompts.shape[0]))
    # one burst while the initial batch is mid-decode: batched prefill lanes
    # (all arrivals share one job) are what cut TTFT vs the sequential
    # engine's serialized per-request prefills
    arrivals = [(2, Request(id=i, prompt=prompts[i].tolist(),
                            max_new_tokens=gen))
                for i in rest]
    return initial, arrivals


@scenario("load", features=("admission", "submit_tick"))
def load_metrics(ctx: BenchContext, max_slots=6, n_initial=4, iters=5):
    """Staggered arrivals during active decode, run under both admission
    modes plus a no-admission baseline (warm-up pass first so jit
    compilation stays out of every timed region)."""
    gen, plan = ctx.gen, ctx.plan
    # slots must shard evenly over the plan's slot partition
    max_slots = plan.round_slots(max_slots)
    # short prompts, two chunks each: enough to interleave admission with
    # decode (stall-freedom needs chunks, not many of them) without paying
    # one dispatch overhead per tiny chunk on the admission critical path
    prompts = ctx.load_prompts[:, :min(ctx.load_prompts.shape[1], 32)]
    chunk = max(8, min(ctx.chunk, prompts.shape[1] // 2))
    n_burst = prompts.shape[0] - n_initial
    # the scenario's own parameters (they intentionally differ from the
    # top-level prompt_len/prefill-chunk args) ride in the report so the
    # per-PR artifact trail stays attributable
    out = {"prompt_len": int(prompts.shape[1]), "chunk": int(chunk),
           "gen": int(gen), "max_slots": int(max_slots),
           "n_initial": int(n_initial), "n_arrivals": int(n_burst)}
    for mode in ("interleaved", "sequential"):
        eng = ctx.engine(max_slots=max_slots, max_prefill_chunk=chunk,
                         admission=mode)
        _drive(eng, *_scenario_requests(prompts, gen, n_initial))  # compile
        best = None
        for _ in range(iters):
            eng.reset_stats()
            initial, arrivals = _scenario_requests(prompts, gen, n_initial)
            results, wall = _drive(eng, initial, arrivals)
            if best is None or wall < best[2]:
                best = (results, dict(eng.stats), wall, arrivals)
        results, s, wall, arrivals = best
        arr_ids = {r.id for _, r in arrivals}
        ttft_all = [r.ttft_s for r in results]
        ttft_arr = [r.ttft_s for r in results if r.id in arr_ids]
        out[mode] = {
            "requests": len(results),
            "decode_tps": round(_decode_tps(s), 1),
            "decode_stall_s": round(s["stall_s"], 4),
            "mixed_steps": s["mixed_steps"],
            "wall_s": round(wall, 4),
            "ttft_p50_s": _pct(ttft_all, 50),
            "ttft_p95_s": _pct(ttft_all, 95),
            "arrival_ttft_p50_s": _pct(ttft_arr, 50),
            "arrival_ttft_p95_s": _pct(ttft_arr, 95),
            "engine": engine_stamp(eng),
        }
        if mode == "interleaved":
            # no-admission baseline on the warm engine: initial batch only
            tps = 0.0
            for _ in range(iters):
                eng.reset_stats()
                initial, _ = _scenario_requests(prompts, gen, n_initial)
                _drive(eng, initial, [])
                tps = max(tps, _decode_tps(eng.stats))
            out["baseline_decode_tps"] = round(tps, 1)
    out["decode_tps_vs_baseline"] = round(
        out["interleaved"]["decode_tps"] /
        max(out["baseline_decode_tps"], 1e-9), 3)
    out["ttft_p50_vs_sequential"] = round(
        out["interleaved"]["ttft_p50_s"] /
        max(out["sequential"]["ttft_p50_s"], 1e-9), 3)
    out["ttft_p95_vs_sequential"] = round(
        out["interleaved"]["ttft_p95_s"] /
        max(out["sequential"]["ttft_p95_s"], 1e-9), 3)
    return out


# ---------------------------------------------------------------------------
# observability: telemetry overhead A/B + exporter artifacts
# ---------------------------------------------------------------------------

@scenario("observability", features=("telemetry",))
def observability_metrics(ctx: BenchContext, iters=10):
    """Telemetry overhead A/B: the same requests through an engine with
    full telemetry (metrics registry + per-request span tracing) vs
    ``Telemetry(enabled=False)`` (shared no-op instruments, no spans).
    Both arms are timed identically — wall clock around ``run()`` over
    generated-token counts — because the off arm has no engine counters
    to read (its ``stats`` view is all zeros by design).  The timed runs
    are **paired**: both engines are warmed first, then each iteration
    times one on-run immediately followed by one off-run, best-of over
    all pairs — a smoke run is ~60 ms, so machine drift (frequency,
    noisy neighbours) between unpaired arms would otherwise dwarf the
    real overhead.  ``telemetry_tps_ratio`` (on/off) is the enforceable
    overhead claim: trajectory.py gates it functionally at >=
    MIN_TELEMETRY_RATIO with no baseline needed.  Greedy tokens must be
    identical both ways — telemetry is host-side only and never enters
    jitted computation.  The on arm also drives every exporter (registry
    snapshot, Prometheus text, Chrome trace events) and, under
    ``--telemetry-artifacts PREFIX``, writes ``PREFIX.prom`` /
    ``PREFIX.trace.json`` for CI artifact upload."""
    telem_on = Telemetry(enabled=True)
    eng_on = ctx.engine(telemetry=telem_on)
    eng_off = ctx.engine(telemetry=Telemetry(enabled=False))
    toks_on = {r.id: r.tokens for r in eng_on.run(ctx.requests())}   # warm
    toks_off = {r.id: r.tokens for r in eng_off.run(ctx.requests())}

    def timed(eng):
        t0 = time.perf_counter()
        results = eng.run(ctx.requests())
        wall = time.perf_counter() - t0
        return sum(len(r.tokens) for r in results) / max(wall, 1e-9)

    tps_on = tps_off = 0.0
    for _ in range(iters):
        tps_on = max(tps_on, timed(eng_on))
        tps_off = max(tps_off, timed(eng_off))

    snap = telem_on.registry.snapshot()
    prom = telem_on.registry.to_prometheus(snap)
    trace = telem_on.tracer.chrome_trace()
    out = {
        "requests": int(ctx.prompts.shape[0]), "gen": int(ctx.gen),
        "iters": int(iters),
        "greedy_identical": bool(toks_on == toks_off),
        "on": {"e2e_tps": round(tps_on, 1),
               "instruments": len(snap),
               "prometheus_lines": prom.count("\n"),
               "trace_events": len(trace["traceEvents"]),
               "timelines": len(telem_on.tracer.timelines()),
               "engine": engine_stamp(eng_on)},
        "off": {"e2e_tps": round(tps_off, 1)},
        "telemetry_tps_ratio": round(tps_on / max(tps_off, 1e-9), 3),
    }
    prefix = ctx.args.telemetry_artifacts
    if prefix:
        with open(prefix + ".prom", "w") as f:
            f.write(prom)
        with open(prefix + ".trace.json", "w") as f:
            json.dump(trace, f)
        out["artifacts"] = [prefix + ".prom", prefix + ".trace.json"]
    return out


# ---------------------------------------------------------------------------
# fleet: disaggregated prefill/decode vs the monolithic engine
# ---------------------------------------------------------------------------

@scenario("fleet", features=("disaggregation", "snapshot_codec",
                             "cache_tier"))
def fleet_metrics(ctx: BenchContext, n_decode=2, iters=3):
    """Disaggregated serving A/B (serve/fleet/): the same requests through
    one monolithic engine vs a fleet of 1 prefill + ``n_decode`` decode
    replicas connected only by codec-serialized snapshots, routed by the
    FleetRouter over a shared prefix-cache tier.  Reports both arms'
    end-to-end tokens/s, the fleet's aggregate decode tokens/s, snapshot
    transfer volume, and router queue / snapshot transfer latency
    quantiles out of the ``fleet_*`` histograms (windowed over exactly
    the timed iterations).  The hard gate: fleet greedy tokens must be
    bit-identical to the monolithic engine — disaggregation moves state
    between processes, never changes it."""
    from repro.serve import PrefixCache, fleet

    cfg, n_req = ctx.cfg, ctx.prompts.shape[0]
    telem = Telemetry()
    peng = ctx.engine(prefix_cache=PrefixCache(budget_mb=16.0,
                                               registry=telem.registry),
                      telemetry=telem)
    codec = fleet.SnapshotCodec.for_store(peng.store)
    tier = fleet.SharedCacheTier(budget_mb=32.0, registry=telem.registry)
    peng.cache.attach_tier(tier, codec)
    pw = fleet.PrefillWorker("prefill0", peng, codec,
                             registry=telem.registry)
    dws = [fleet.DecodeWorker(f"decode{i}",
                              ctx.engine(telemetry=telem), codec,
                              registry=telem.registry)
           for i in range(n_decode)]
    router = fleet.FleetRouter([pw], dws, telemetry=telem)

    mono = ctx.engine()
    toks_mono = {r.id: r.tokens for r in mono.run(ctx.requests())}  # warm
    toks_fleet = {r.id: r.tokens for r in router.run(ctx.requests())}

    def timed_mono():
        t0 = time.perf_counter()
        results = mono.run(ctx.requests())
        return sum(len(r.tokens) for r in results) / max(
            time.perf_counter() - t0, 1e-9)

    def timed_fleet():
        t0 = time.perf_counter()
        results = router.run(ctx.requests())
        return sum(len(r.tokens) for r in results) / max(
            time.perf_counter() - t0, 1e-9)

    pre = telem.registry.snapshot()       # window: all timed iterations
    for w in dws:
        w.engine.reset_stats()
    tps_mono = tps_fleet = 0.0
    for _ in range(iters):                # paired, best-of (drift-robust)
        tps_mono = max(tps_mono, timed_mono())
        tps_fleet = max(tps_fleet, timed_fleet())
    d = telem.registry.delta(pre)
    dec_tokens = sum(w.engine.stats["decode_tokens"] for w in dws)
    dec_s = sum(w.engine.stats["decode_s"] + w.engine.stats["mixed_s"]
                for w in dws)
    v = lambda name: int(d.get(name, {}).get("value", 0))

    return {
        "requests": int(n_req), "gen": int(ctx.gen),
        "prefill_workers": 1, "decode_workers": int(n_decode),
        "iters": int(iters),
        "greedy_identical": bool(toks_fleet == toks_mono),
        "mono": {"e2e_tps": round(tps_mono, 1),
                 "engine": engine_stamp(mono)},
        "fleet": {
            "e2e_tps": round(tps_fleet, 1),
            "decode_tps": round(dec_tokens / max(dec_s, 1e-9), 1),
            "snapshot_admissions": v("fleet_admits_total"),
            "snapshot_transfer_bytes": v("fleet_snapshot_bytes_total"),
            "requeues": v("fleet_requeues_total"),
            "tier": {"entries": len(tier),
                     "bytes_used": tier.bytes_used,
                     "inserts": v("fleet_tier_inserts_total"),
                     "hits": v("fleet_tier_hits_total")},
            **_hist_latency(d, "fleet_router_queue_seconds",
                            "router_queue"),
            **_hist_latency(d, "fleet_transfer_seconds",
                            "snapshot_transfer"),
            "engine": engine_stamp(peng),
        },
        "e2e_tps_vs_mono": round(tps_fleet / max(tps_mono, 1e-9), 3),
    }


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def build_context(args) -> BenchContext:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    if cfg.kind == "encoder":
        raise SystemExit("encoder-only arch has no decode step")
    plan = ParallelPlan.parse(args.mesh)
    if args.batch % plan.data_size != 0:
        raise SystemExit(f"--batch {args.batch} must be a multiple of the "
                         f"plan's data axis ({plan.data_size})")
    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    max_len = args.prompt_len + 2 * args.gen + 1
    n_load = 6                      # 4 initial + one burst of 2 arrivals
    corpus = corpus_for(cfg, args.prompt_len + 1,
                        max(args.batch, n_load), args.seed)
    all_prompts = np.asarray(corpus.batch_at(0)["tokens"])[:,
                                                           :args.prompt_len]
    return BenchContext(cfg=cfg, params=params, plan=plan,
                        prompts=all_prompts[:args.batch],
                        load_prompts=all_prompts[:n_load],
                        gen=args.gen, max_len=max_len,
                        chunk=args.prefill_chunk, seed=args.seed, args=args)


def run_scenarios(args) -> dict:
    names = args.scenarios or sorted(SCENARIOS)
    unknown = sorted(set(names) - set(SCENARIOS))
    if unknown:
        raise SystemExit(f"unknown scenario(s) {unknown}; "
                         f"registered: {sorted(SCENARIOS)}")
    ctx = build_context(args)
    return {
        "arch": args.arch, "smoke": args.smoke,
        "schema_version": SCHEMA_VERSION,
        "plan": ctx.plan.describe(),
        "batch": args.batch, "prompt_len": args.prompt_len, "gen": args.gen,
        "scenarios": {name: SCENARIOS[name](ctx) for name in names},
    }


def list_scenarios() -> str:
    """One line per registered scenario: name, required engine features,
    first docstring sentence (what ``--list`` prints)."""
    width = max(len(n) for n in SCENARIOS)
    fwidth = max(len(",".join(f.features)) or 1
                 for f in SCENARIOS.values())
    lines = []
    for name in sorted(SCENARIOS):
        fn = SCENARIOS[name]
        feats = ",".join(fn.features) or "-"
        doc = (fn.__doc__ or "").strip().split("\n")[0]
        lines.append(f"{name:<{width}}  {feats:<{fwidth}}  {doc}")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rom-mamba-115m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=128)
    ap.add_argument("--scenario", action="append", dest="scenarios",
                    metavar="NAME", default=None,
                    help="run only this scenario (repeatable; default: "
                         f"all of {sorted(SCENARIOS)})")
    ap.add_argument("--list", action="store_true",
                    help="print registered scenarios with the engine "
                         "features each one exercises, then exit")
    ap.add_argument("--mixer-sweep", action="store_true",
                    help="extend the kernels scenario with a per-mixer "
                         "fused-step A/B (one reduced arch per family: "
                         f"{sorted(MIXER_ARCHS)})")
    ap.add_argument("--kernels-impl", default="pallas",
                    choices=("pallas", "interpret"),
                    help="fast-path impl the kernels scenario A/Bs against "
                         "'ref' — 'interpret' runs the actual Pallas "
                         "kernels under the interpreter on CPU (the CI "
                         "identity gate), 'pallas' takes the per-op "
                         "backend resolution")
    ap.add_argument("--speculative-k", type=int, default=3,
                    help="draft window of the speculative scenario")
    ap.add_argument("--draft-stride", type=int, default=2,
                    help="layer-skip stride of the speculative draft")
    ap.add_argument("--prefix-cache-mb", type=float, default=64.0,
                    help="snapshot byte budget of the prefix-cache scenario")
    ap.add_argument("--expert-budget-mb", type=float, default=256.0,
                    help="ExpertLibrary device-residency budget of the "
                         "expert_library scenario")
    ap.add_argument("--cache-grain", type=int, default=1,
                    help="prefix-cache snapshot alignment (publish only "
                         "multiples of G tokens; bounds radix-tree size)")
    ap.add_argument("--telemetry-artifacts", default="", metavar="PREFIX",
                    help="write the observability scenario's exporter "
                         "outputs to PREFIX.prom (Prometheus text) and "
                         "PREFIX.trace.json (Chrome trace events, "
                         "Perfetto-loadable) — what CI uploads")
    ap.add_argument("--mesh", default="",
                    help="ParallelPlan topology over this host's devices, "
                         "e.g. 'data=4' or 'data=2,model=2' (decode slots "
                         "shard over data, expert weights over model); "
                         "empty = single device")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="",
                    help="write JSON here (default: stdout only)")
    args = ap.parse_args(argv)

    if args.list:
        print(list_scenarios())
        return

    report = run_scenarios(args)
    text = json.dumps(report, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)


if __name__ == "__main__":
    main()
