"""Serving benchmark: parallel prefill vs per-token prefill, engine
throughput, and time-to-first-token; emits JSON.

    PYTHONPATH=src python benchmarks/serving.py --smoke
    PYTHONPATH=src python benchmarks/serving.py --arch rom-mamba-115m \
        --smoke --prompt-len 128 --gen 32 --out serving.json

Measures, on the same config and prompts:

  prefill_parallel_tps   tokens/s prefilling via models/lm.prefill (the
                         engine path: one training-style pass per
                         power-of-two chunk)
  prefill_pertoken_tps   tokens/s prefilling by stepping the jitted decode
                         path one token at a time (the pre-engine baseline)
  prefill_speedup        parallel / per-token
  decode_tps             engine decode tokens/s (all slots)
  ttft_mean_s            mean submit->first-token latency across requests
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import train as tr
from repro.configs.all_configs import reduce_for_smoke
from repro.configs.base import get_config
from repro.data.pipeline import corpus_for
from repro.models import lm
from repro.serve import Request, ServeEngine


def _best_of(fn, iters):
    """Best-of-N timing: the minimum wall time is the least load-disturbed
    sample (both timed regions here are short on the smoke config)."""
    return max(fn() for _ in range(iters))


def pertoken_prefill_tps(cfg, params, prompts, max_len, iters=3):
    """The old serve path: prompts consumed one jitted decode step/token."""
    B, S = prompts.shape
    serve = jax.jit(tr.make_serve_fn(cfg))

    def once():
        state = lm.init_state(cfg, B, max_len, jnp.dtype(cfg.dtype))
        t0 = time.perf_counter()
        for pos in range(S):
            nxt, logits, state = serve(params, state,
                                       prompts[:, pos:pos + 1],
                                       jnp.int32(pos))
        jax.block_until_ready(nxt)
        return B * S / (time.perf_counter() - t0)

    once()                                   # compile outside timed region
    return _best_of(once, iters)


def parallel_prefill_tps(cfg, params, prompts, max_len, chunk, iters=3):
    """The engine path: chunked parallel prefill (state threads chunks)."""
    from repro.serve.engine import prefill_chunks
    B, S = prompts.shape
    pf = jax.jit(tr.make_prefill_step_fn(cfg))
    chunks = prefill_chunks(S, chunk)

    def once():
        state = lm.init_state(cfg, B, max_len, jnp.dtype(cfg.dtype))
        t0 = time.perf_counter()
        pos = 0
        for c in chunks:
            logits, state = pf(params, state, prompts[:, pos:pos + c],
                               jnp.int32(pos))
            pos += c
        jax.block_until_ready(logits)
        return B * S / (time.perf_counter() - t0)

    once()                                   # compile outside timed region
    return _best_of(once, iters)


def engine_metrics(cfg, params, prompts, gen, max_len, chunk, seed=0):
    B = prompts.shape[0]
    engine = ServeEngine(cfg, params, max_slots=B, max_len=max_len,
                         seed=seed, max_prefill_chunk=chunk)
    reqs = [Request(id=i, prompt=prompts[i].tolist(), max_new_tokens=gen)
            for i in range(B)]
    results = engine.run(reqs)
    s = engine.stats
    return {
        "decode_tps": s["decode_tokens"] / max(s["decode_s"], 1e-9),
        "ttft_mean_s": float(np.mean([r.ttft_s for r in results])),
        "ttft_max_s": float(np.max([r.ttft_s for r in results])),
        "requests": len(results),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rom-mamba-115m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="",
                    help="write JSON here (default: stdout)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    if cfg.kind == "encoder":
        raise SystemExit("encoder-only arch has no decode step")
    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    max_len = args.prompt_len + args.gen
    corpus = corpus_for(cfg, args.prompt_len + 1, args.batch, args.seed)
    prompts = jnp.asarray(corpus.batch_at(0)["tokens"])[:, :args.prompt_len]

    par = parallel_prefill_tps(cfg, params, prompts, max_len,
                               args.prefill_chunk)
    per = pertoken_prefill_tps(cfg, params, prompts, max_len)
    eng = engine_metrics(cfg, params, np.asarray(prompts), args.gen, max_len,
                         args.prefill_chunk, args.seed)
    report = {
        "arch": args.arch, "smoke": args.smoke,
        "batch": args.batch, "prompt_len": args.prompt_len, "gen": args.gen,
        "prefill_parallel_tps": round(par, 1),
        "prefill_pertoken_tps": round(per, 1),
        "prefill_speedup": round(par / per, 2),
        **{k: (round(v, 4) if isinstance(v, float) else v)
           for k, v in eng.items()},
    }
    text = json.dumps(report, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)


if __name__ == "__main__":
    main()
