"""Tiny-scale quality proxy for the paper's Figures 2/3 + Table 4 ordering.

Trains matched-active-parameter models on the regime-mixture Markov corpus
(see data/pipeline.py): the latent regimes give routed experts something to
specialize on, reproducing the paper's ordering at laptop scale:

    RoM (shared router)  <  dense  and  RoM  <  MoE-Mamba (indep. routers)

(The paper's absolute SlimPajama PPLs need 20B tokens on 8xA100; this proxy
is the structural claim — shared routing beats naive per-projection MoE at
equal capacity — in a form CI can check.)
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import train as tr
from repro.configs.base import MambaConfig, ModelConfig, RoMConfig
from repro.data.pipeline import MarkovCorpus


def _cfg(kind, *, d=64, L=4, E=8):
    return ModelConfig(
        name=f"proxy-{kind}", d_model=d, vocab_size=256,
        segments=(((kind,), L),),
        mamba=MambaConfig(d_state=8, chunk=32),
        rom=RoMConfig(num_experts=E, top_k=1, jitter_eps=0.01,
                      capacity_factor=2.0),
        dtype="float32", scan_layers=True)


def train_ppl(cfg, steps=240, batch=32, seq=128, seed=0, eval_steps=8):
    corpus = MarkovCorpus(vocab_size=256, seq_len=seq, batch=batch,
                          seed=seed, num_regimes=8, branching=4)
    hp = tr.TrainHParams(base_lr=3e-3, warmup_steps=20, total_steps=steps)
    step = jax.jit(tr.make_train_fn(cfg, hp=hp))
    state = tr.init_train_state(cfg, seed)
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in corpus.batch_at(i).items()}
        state, m = step(state, b)
    # held-out eval (fresh steps beyond the training stream)
    from repro.distributed.sharding import ShardCtx
    from repro.models import lm
    rt = lm.Runtime(shard=ShardCtx(), rng=None, train=False)
    tot, cnt = 0.0, 0
    for i in range(10_000, 10_000 + eval_steps):
        b = {k: jnp.asarray(v) for k, v in corpus.batch_at(i).items()}
        loss, metrics = lm.loss_fn(state["params"], b, cfg, rt)
        tot += float(metrics["ce"]) * b["labels"].size
        cnt += b["labels"].size
    return float(np.exp(tot / cnt))


def run(out=print, steps=240):
    results = {}
    for kind in ("mamba", "moemamba", "rom_mamba"):
        t0 = time.time()
        ppl = train_ppl(_cfg(kind), steps=steps)
        results[kind] = ppl
        out(f"{kind},ppl={ppl:.3f},train_s={time.time() - t0:.0f}")
    out(f"# ordering: rom {results['rom_mamba']:.3f} vs "
        f"dense {results['mamba']:.3f} vs "
        f"moemamba {results['moemamba']:.3f} "
        f"(paper: RoM < dense <= MoE-Mamba)")
    return results
