"""Analytic forward-FLOPs counter (paper Table 1 convention).

Per-token forward FLOPs = 2 x active non-embedding matmul params
+ attention score/value FLOPs (4 x S_eff x d_attn per layer, where S_eff is
min(position, window) averaged over the sequence) + lm-head 2 x d x V.
SSM scan/conv elementwise terms are counted but are <1% at these dims.
Convention differences vs the paper's (unstated) counter are absorbed by
comparing *ratios* (the 23% claim), which are convention-free.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import lm


def _active_frac(name, cfg):
    if name.startswith(("e_w_", "e_b_", "ep_w_")):
        if name in ("e_w_up", "e_w_gate_ffn", "e_w_down",
                    "ep_w_up", "ep_w_gate_ffn", "ep_w_down"):
            m = cfg.moe
        elif name in ("e_w_q", "e_w_v", "e_w_o"):
            m = cfg.attn_moe
        else:
            m = cfg.rom
        return m.top_k / m.num_experts
    return 1.0


def forward_flops(cfg, seq_len: int) -> float:
    """Total forward FLOPs for ONE sequence of ``seq_len`` tokens."""
    shapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0),
                                                   cfg))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    matmul = 0.0
    for path, leaf in flat:
        name = None
        for e in reversed(path):
            k = getattr(e, "key", None)
            if isinstance(k, str):
                name = k
                break
        if leaf.ndim < 2 or name in ("embed",):  # lookups are not matmuls
            continue
        matmul += 2.0 * np.prod(leaf.shape) * _active_frac(name, cfg)
    total = matmul * seq_len
    # tied lm head
    if cfg.tie_embeddings:
        total += 2.0 * cfg.d_model * cfg.vocab_size * seq_len
    # attention scores+values: 4 * sum_t min(t, W) * d_attn per layer
    if cfg.attention is not None:
        a = cfg.attention
        d_attn = a.num_heads * a.head_dim
        W = a.window or seq_len
        s_eff = sum(min(t + 1, W) for t in range(seq_len))
        n_attn = sum(sum(1 for k in p if k in ("attn", "moa", "switchhead"))
                     * r for p, r in cfg.segments)
        total += 4.0 * s_eff * d_attn * n_attn
    # selective-scan state updates: ~8 flops per (t, De, N) element
    if cfg.mamba is not None:
        de = cfg.mamba.expand * cfg.d_model
        n_m = sum(sum(1 for k in p if "mamba" in k) * r
                  for p, r in cfg.segments)
        total += 8.0 * seq_len * de * cfg.mamba.d_state * n_m
    return total


def table1(out=print):
    rows = [
        ("llama2-438m", "Llama-2"),
        ("mamba-353m", "Mamba"),
        ("samba-421m", "Samba (expand=2)"),
        ("samba-421m-moa", "+ MoA"),
        ("samba-421m-switchhead", "+ SwitchHead"),
        ("samba-421m-moemamba", "+ MoE-Mamba (Conv,Gate,Out)"),
        ("samba-421m-rom", "+ RoM (Conv,Gate,Out)"),
        ("samba-511m", "Samba (expand=4)"),
        ("samba-511m-rom-gateout", "+ RoM (Gate,Out)"),
        ("samba-511m-rom", "+ RoM (Conv,Gate,Out)"),
        ("samba-511m-rom-all", "+ RoM (Conv,Gate,dt,x,Out)"),
    ]
    from repro.configs.all_configs import param_stats
    out("name,label,active_params,total_params,fwd_flops_4k")
    res = {}
    for name, label in rows:
        cfg = get_config(name)
        s = param_stats(cfg)
        f = forward_flops(cfg, 4096)
        res[name] = (s, f)
        out(f"{name},{label},{s['active'] / 1e6:.0f}M,"
            f"{s['total'] / 1e9:.2f}B,{f / 1e12:.2f}T")
    # the paper's 23% claim: RoM-on-expand2 vs dense expand4 FLOPs ratio
    ratio = res["samba-421m-rom"][1] / res["samba-511m"][1]
    out(f"# FLOPS saving of Samba+RoM vs Samba(expand=4): "
        f"{100 * (1 - ratio):.1f}% (paper: 23%)")
    return res, ratio
