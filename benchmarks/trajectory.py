"""Perf-trajectory regression gate over BENCH_serving.json reports.

    PYTHONPATH=src python benchmarks/trajectory.py \
        --baseline BENCH_serving.json --current BENCH_serving.current.json
    PYTHONPATH=src python benchmarks/trajectory.py --update \
        --baseline BENCH_serving.json --current BENCH_serving.current.json
    PYTHONPATH=src python benchmarks/trajectory.py --identity-only \
        --current BENCH_kernels.json

Compares the current benchmark report against the committed trajectory
with per-metric thresholds and exits non-zero on any regression, printing
a metric-by-metric table.  Every ``*greedy_identical`` flag anywhere in
the scenario tree is a hard functional gate regardless of thresholds;
``--identity-only`` applies just those gates with no baseline (the
per-mixer CI steps).  A baseline produced on a different ``device_kind``
prints a warning — the numbers moved with the machine, not the PR — but
never fails.  Only metric keys matching the THRESHOLDS
classification are gated; everything else in the report (engine stamps,
scenario parameters, counters) is informational.

Threshold classes (first match on the metric's dot-path wins):

  throughput   *_tps                      higher is better; fail when the
                                          current value drops more than 15%
                                          below baseline
  quality      acceptance_rate, hit_rate, higher is better; 25% relative
               *_saved_frac, token_hit_*  drop allowed (these are discrete
                                          ratios on smoke workloads)
  latency      ttft/itl/e2e_*_s, wall_s,  lower is better; 100% relative
               *stall_s                   growth allowed (absolute wall
                                          times on shared CI runners are
                                          noisy — the throughput gates are
                                          the sharp ones)

Ratios-of-throughputs (``*_vs_baseline``, ``*_vs_ref``, ``*_vs_mono``,
``speedup``) are
derived from gated quantities and CI-noisy in both numerator and
denominator, so they are reported but not gated.  One more hard
functional gate rides with the identity flags: the observability
scenario's ``telemetry_tps_ratio`` (decode throughput with full
telemetry on vs off) must stay >= ``MIN_TELEMETRY_RATIO`` — telemetry
is supposed to be near-free, and this catches an instrumentation change
that puts real work on the hot path.

``--update`` rewrites the baseline with the current report (the CI main
branch does this after a green run, so the committed trajectory always
reflects the current CI machine generation).
"""
from __future__ import annotations

import argparse
import json
import re
import shutil
import sys

#: (pattern over the metric dot-path, direction, allowed relative change)
THRESHOLDS = [
    (re.compile(r"(_vs_baseline|_vs_ref|_vs_sequential|_vs_mono|\bspeedup)$"),
     None, None),                           # derived ratios: report only
    (re.compile(r"_tps$"), "higher", 0.15),
    (re.compile(r"(acceptance_rate|hit_rate|_saved_frac|tokens_per_round)$"),
     "higher", 0.25),
    (re.compile(r"((ttft|itl|e2e)_\w*_s|wall_s|stall_s)$"), "lower", 1.00),
]

#: Floor on observability.telemetry_tps_ratio (throughput with full
#: telemetry enabled over disabled): a functional gate like the identity
#: flags — no baseline needed, telemetry may cost at most 5% throughput.
MIN_TELEMETRY_RATIO = 0.95


def classify(path: str):
    for pat, direction, tol in THRESHOLDS:
        if pat.search(path):
            return direction, tol
    return None, None


def numeric_leaves(obj, prefix=""):
    """Flatten nested dicts to {dot.path: number}; skips engine *stamps*
    (config echoes, not metrics) — recognized by their schema_version
    field, so the scenario named "engine" still contributes metrics."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            if (k == "engine" and isinstance(v, dict)
                    and "schema_version" in v):
                continue
            out.update(numeric_leaves(v, f"{prefix}.{k}" if prefix else k))
    elif isinstance(obj, bool):
        pass
    elif isinstance(obj, (int, float)):
        out[prefix] = float(obj)
    return out


def missing_scenarios(baseline: dict, current: dict):
    """Baseline scenarios absent from the current report.  Key
    intersection alone would silently drop them — a scenario that stops
    running (renamed, crashed, filtered out) would pass the gate exactly
    like a healthy one — so the runner must fail loudly instead."""
    base = baseline.get("scenarios", baseline)
    cur = current.get("scenarios", current)
    if not isinstance(base, dict) or not isinstance(cur, dict):
        return []
    return sorted(k for k, v in base.items()
                  if isinstance(v, dict) and k not in cur)


def compare(baseline: dict, current: dict):
    """Returns (rows, regressions): every gated metric present in both
    reports, with its relative change and verdict.  Scenario-level
    disappearance is NOT tolerated here by omission — ``main`` gates it
    via :func:`missing_scenarios`."""
    base = numeric_leaves(baseline.get("scenarios", baseline))
    cur = numeric_leaves(current.get("scenarios", current))
    rows, regressions = [], []
    for path in sorted(set(base) & set(cur)):
        direction, tol = classify(path)
        if direction is None:
            continue
        b, c = base[path], cur[path]
        if b == 0:
            continue
        rel = (c - b) / abs(b)
        bad = (rel < -tol) if direction == "higher" else (rel > tol)
        rows.append((path, b, c, rel, direction, tol, bad))
        if bad:
            regressions.append(rows[-1])
    return rows, regressions


def check_identity(current: dict):
    """Hard functional gates carried inside the benchmark report: every
    ``*greedy_identical`` key anywhere in the scenario tree (the top-level
    kernels A/B and each ``--mixer-sweep`` entry) must be true."""
    failures = []

    def walk(obj, prefix):
        if not isinstance(obj, dict):
            return
        for k, v in obj.items():
            path = f"{prefix}.{k}" if prefix else k
            if k.endswith("greedy_identical"):
                if v is not True:
                    failures.append(
                        f"{path} is not true: kernels='pallas' decode "
                        f"diverged from 'ref'")
            else:
                walk(v, path)

    walk(current.get("scenarios", {}), "scenarios")
    return failures


def check_telemetry_ratio(current):
    """The observability scenario's overhead floor: full telemetry must
    keep >= MIN_TELEMETRY_RATIO of the telemetry-off throughput.  Like
    the identity gates this needs no baseline — absent scenario, no
    gate (the smoke report may be filtered to other scenarios)."""
    scen = current.get("scenarios", {})
    obs = scen.get("observability") if isinstance(scen, dict) else None
    if not isinstance(obs, dict):
        return []
    r = obs.get("telemetry_tps_ratio")
    if isinstance(r, (int, float)) and r < MIN_TELEMETRY_RATIO:
        return [f"scenarios.observability.telemetry_tps_ratio {r} < "
                f"{MIN_TELEMETRY_RATIO}: full telemetry costs more than "
                f"{1 - MIN_TELEMETRY_RATIO:.0%} of decode throughput"]
    return []


def first_stamp(obj):
    """The first engine stamp (dict with a schema_version) found in a
    report — every scenario attaches one, so any is representative of the
    machine that produced the report."""
    if isinstance(obj, dict):
        if "schema_version" in obj and "device_kind" in obj:
            return obj
        for v in obj.values():
            found = first_stamp(v)
            if found is not None:
                return found
    return None


def warn_device_mismatch(baseline: dict, current: dict):
    """A baseline produced on a different device generation makes the
    relative thresholds apples-to-oranges; that is a property of the CI
    fleet, not of the PR under test — so warn, never fail."""
    b, c = first_stamp(baseline), first_stamp(current)
    bk = b.get("device_kind") if b else None
    ck = c.get("device_kind") if c else None
    if bk and ck and bk != ck:
        print(f"trajectory: WARNING baseline device_kind {bk!r} != current "
              f"{ck!r} — metric deltas reflect the machine change too; "
              f"refresh the baseline with --update on the new fleet")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline",
                    help="committed trajectory JSON (e.g. BENCH_serving.json;"
                         " required unless --identity-only)")
    ap.add_argument("--current", required=True,
                    help="freshly produced report to gate")
    ap.add_argument("--update", action="store_true",
                    help="overwrite the baseline with the current report "
                         "instead of gating (used on main after green CI)")
    ap.add_argument("--identity-only", action="store_true",
                    help="apply only the functional greedy-identity gates "
                         "(no baseline needed) — what the per-mixer CI "
                         "steps use, where throughput on shared runners is "
                         "noise but divergence is a bug")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    if args.identity_only:
        failures = check_identity(current) + check_telemetry_ratio(current)
        for msg in failures:
            print(f"FUNCTIONAL GATE FAILED: {msg}")
        if failures:
            return 1
        print("trajectory: greedy-identity gates green")
        return 0
    if not args.baseline:
        ap.error("--baseline is required unless --identity-only")
    if args.update:
        shutil.copyfile(args.current, args.baseline)
        print(f"trajectory: refreshed {args.baseline} from {args.current}")
        return 0
    with open(args.baseline) as f:
        baseline = json.load(f)

    if baseline.get("schema_version") != current.get("schema_version"):
        # the functional gates carry no baseline dependency, so a schema
        # bump must not waive them — only the metric diffs are skipped
        failures = check_identity(current) + check_telemetry_ratio(current)
        for msg in failures:
            print(f"FUNCTIONAL GATE FAILED: {msg}")
        print(f"trajectory: schema_version changed "
              f"({baseline.get('schema_version')} -> "
              f"{current.get('schema_version')}); skipping metric gates "
              f"(commit a fresh baseline)")
        return 1 if failures else 0

    warn_device_mismatch(baseline, current)
    missing = missing_scenarios(baseline, current)
    for name in missing:
        print(f"MISSING SCENARIO: {name!r} is in the baseline but absent "
              f"from the current report — it stopped running (renamed, "
              f"crashed, or filtered out); rerun it or refresh the "
              f"baseline with --update")
    rows, regressions = compare(baseline, current)
    failures = check_identity(current) + check_telemetry_ratio(current)
    width = max((len(r[0]) for r in rows), default=20)
    for path, b, c, rel, direction, tol, bad in rows:
        mark = "REGRESSED" if bad else "ok"
        print(f"{path:<{width}}  {b:>10.3f} -> {c:>10.3f}  "
              f"{rel:+7.1%}  ({direction} better, tol {tol:.0%})  {mark}")
    for msg in failures:
        print(f"FUNCTIONAL GATE FAILED: {msg}")
    if regressions or failures or missing:
        print(f"trajectory: {len(regressions)} metric regression(s), "
              f"{len(failures)} functional failure(s), "
              f"{len(missing)} missing scenario(s)")
        return 1
    print(f"trajectory: {len(rows)} gated metrics within thresholds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
