"""Perf-trajectory regression gate over BENCH_serving.json reports.

    PYTHONPATH=src python benchmarks/trajectory.py \
        --baseline BENCH_serving.json --current BENCH_serving.current.json
    PYTHONPATH=src python benchmarks/trajectory.py --update \
        --baseline BENCH_serving.json --current BENCH_serving.current.json

Compares the current benchmark report against the committed trajectory
with per-metric thresholds and exits non-zero on any regression, printing
a metric-by-metric table.  Only metric keys matching the THRESHOLDS
classification are gated; everything else in the report (engine stamps,
scenario parameters, counters) is informational.

Threshold classes (first match on the metric's dot-path wins):

  throughput   *_tps                      higher is better; fail when the
                                          current value drops more than 15%
                                          below baseline
  quality      acceptance_rate, hit_rate, higher is better; 25% relative
               *_saved_frac, token_hit_*  drop allowed (these are discrete
                                          ratios on smoke workloads)
  latency      ttft_*_s, wall_s, *stall_s lower is better; 100% relative
                                          growth allowed (absolute wall
                                          times on shared CI runners are
                                          noisy — the throughput gates are
                                          the sharp ones)

Ratios-of-throughputs (``*_vs_baseline``, ``*_vs_ref``, ``speedup``) are
derived from gated quantities and CI-noisy in both numerator and
denominator, so they are reported but not gated.

``--update`` rewrites the baseline with the current report (the CI main
branch does this after a green run, so the committed trajectory always
reflects the current CI machine generation).
"""
from __future__ import annotations

import argparse
import json
import re
import shutil
import sys

#: (pattern over the metric dot-path, direction, allowed relative change)
THRESHOLDS = [
    (re.compile(r"(_vs_baseline|_vs_ref|_vs_sequential|\bspeedup)$"),
     None, None),                           # derived ratios: report only
    (re.compile(r"_tps$"), "higher", 0.15),
    (re.compile(r"(acceptance_rate|hit_rate|_saved_frac|tokens_per_round)$"),
     "higher", 0.25),
    (re.compile(r"(ttft_\w*_s|wall_s|stall_s)$"), "lower", 1.00),
]


def classify(path: str):
    for pat, direction, tol in THRESHOLDS:
        if pat.search(path):
            return direction, tol
    return None, None


def numeric_leaves(obj, prefix=""):
    """Flatten nested dicts to {dot.path: number}; skips engine *stamps*
    (config echoes, not metrics) — recognized by their schema_version
    field, so the scenario named "engine" still contributes metrics."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            if (k == "engine" and isinstance(v, dict)
                    and "schema_version" in v):
                continue
            out.update(numeric_leaves(v, f"{prefix}.{k}" if prefix else k))
    elif isinstance(obj, bool):
        pass
    elif isinstance(obj, (int, float)):
        out[prefix] = float(obj)
    return out


def compare(baseline: dict, current: dict):
    """Returns (rows, regressions): every gated metric present in both
    reports, with its relative change and verdict."""
    base = numeric_leaves(baseline.get("scenarios", baseline))
    cur = numeric_leaves(current.get("scenarios", current))
    rows, regressions = [], []
    for path in sorted(set(base) & set(cur)):
        direction, tol = classify(path)
        if direction is None:
            continue
        b, c = base[path], cur[path]
        if b == 0:
            continue
        rel = (c - b) / abs(b)
        bad = (rel < -tol) if direction == "higher" else (rel > tol)
        rows.append((path, b, c, rel, direction, tol, bad))
        if bad:
            regressions.append(rows[-1])
    return rows, regressions


def check_identity(current: dict):
    """Hard functional gates carried inside the benchmark report: the
    kernels scenario's greedy A/B must match token-for-token."""
    failures = []
    kern = current.get("scenarios", {}).get("kernels")
    if kern is not None and kern.get("greedy_identical") is not True:
        failures.append("scenarios.kernels.greedy_identical is not true: "
                        "kernels='pallas' decode diverged from 'ref'")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed trajectory JSON (e.g. BENCH_serving.json)")
    ap.add_argument("--current", required=True,
                    help="freshly produced report to gate")
    ap.add_argument("--update", action="store_true",
                    help="overwrite the baseline with the current report "
                         "instead of gating (used on main after green CI)")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    if args.update:
        shutil.copyfile(args.current, args.baseline)
        print(f"trajectory: refreshed {args.baseline} from {args.current}")
        return 0
    with open(args.baseline) as f:
        baseline = json.load(f)

    if baseline.get("schema_version") != current.get("schema_version"):
        print(f"trajectory: schema_version changed "
              f"({baseline.get('schema_version')} -> "
              f"{current.get('schema_version')}); skipping metric gates "
              f"(commit a fresh baseline)")
        return 0

    rows, regressions = compare(baseline, current)
    failures = check_identity(current)
    width = max((len(r[0]) for r in rows), default=20)
    for path, b, c, rel, direction, tol, bad in rows:
        mark = "REGRESSED" if bad else "ok"
        print(f"{path:<{width}}  {b:>10.3f} -> {c:>10.3f}  "
              f"{rel:+7.1%}  ({direction} better, tol {tol:.0%})  {mark}")
    for msg in failures:
        print(f"FUNCTIONAL GATE FAILED: {msg}")
    if regressions or failures:
        print(f"trajectory: {len(regressions)} metric regression(s), "
              f"{len(failures)} functional failure(s)")
        return 1
    print(f"trajectory: {len(rows)} gated metrics within thresholds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
