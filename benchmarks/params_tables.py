"""Parameter-accounting reproduction of the paper's Tables 1/5/7.

The RoM scaling ladder totals (115M -> 710M, 353M -> 2.5B, 765M -> 5.5B,
1.3B -> 10B) are hard numbers from the paper; this benchmark asserts our
config math lands within tolerance of each.
"""
from __future__ import annotations

from repro.configs.all_configs import param_stats
from repro.configs.base import get_config

# (config, paper_total, tolerance)
PAPER_TOTALS = [
    ("mamba-115m", 115e6, 0.02),
    ("rom-mamba-115m", 710e6, 0.02),
    ("mamba-353m", 353e6, 0.02),
    ("rom-mamba-353m", 2.5e9, 0.02),
    ("mamba-765m", 765e6, 0.02),
    ("rom-mamba-765m", 5.5e9, 0.02),
    ("mamba-1.3b", 1.3e9, 0.05),
    ("rom-mamba-1.3b", 10e9, 0.05),
    # Samba internals are unspecified in [39]; our d_ff=4096 reading puts the
    # dense models ~8% above the quoted 421M/511M while every RoM *total*
    # lands on the paper's 1.0B / 1.3B / 1.7B (see DESIGN.md).
    ("samba-421m", 421e6, 0.12),
    ("samba-421m-rom", 1.0e9, 0.05),
    ("samba-511m", 511e6, 0.08),
    ("samba-511m-rom-gateout", 1.3e9, 0.05),
    ("samba-511m-rom", 1.7e9, 0.08),
    ("samba-511m-rom-all", 1.7e9, 0.05),
    ("mamba2-rom-353m", 2.5e9, 0.05),
    ("gdn-rom-343m", 2.5e9, 0.05),
]


def run(out=print):
    out("name,total,paper_total,rel_err,within_tol")
    worst = 0.0
    failures = []
    for name, paper, tol in PAPER_TOTALS:
        s = param_stats(get_config(name))
        rel = abs(s["total"] - paper) / paper
        ok = rel <= tol
        if not ok:
            failures.append(name)
        worst = max(worst, rel)
        out(f"{name},{s['total'] / 1e9:.3f}B,{paper / 1e9:.3f}B,"
            f"{rel * 100:.1f}%,{ok}")
    out(f"# worst rel err: {worst * 100:.1f}%; failures: {failures or 'none'}")
    assert not failures, failures
    return worst
