"""Train/serve step factories: pjit-sharded, grad-accumulated, remat-aware.

``make_train_step(cfg, mesh)`` returns a jit-compiled ``(state, batch) ->
(state, metrics)`` with in/out shardings resolved from the logical-axis
tables; ``make_serve_step`` the one-token decode analogue.  Both are what
the multi-pod dry-run lowers and what the examples run on CPU.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.distributed import sharding as shd
from repro.models import lm


def train_state_shapes(cfg, key=None):
    """abstract TrainState pytree via eval_shape (no allocation)."""
    def init():
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        opt_init, _ = optim.make_optimizer(cfg.optimizer)
        return {"params": params, "opt": opt_init(params),
                "step": jnp.zeros((), jnp.int32)}
    return jax.eval_shape(init)


def init_train_state(cfg, seed=0):
    params = lm.init_params(jax.random.PRNGKey(seed), cfg)
    opt_init, _ = optim.make_optimizer(cfg.optimizer)
    return {"params": params, "opt": opt_init(params),
            "step": jnp.zeros((), jnp.int32)}


def state_shardings(state_shapes, mesh, rules=None):
    rules = rules or shd.ShardingRules()
    specs = shd.param_specs(state_shapes, mesh, rules, lenient=True)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(batch_shapes, mesh):
    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
        if leaf.shape[0] % max(
                1, int(jnp.prod(jnp.array([mesh.shape[a] for a in dp])))) == 0 \
                and dp:
            return NamedSharding(mesh, P(dp, *([None] * (leaf.ndim - 1))))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map(one, batch_shapes)


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    base_lr: float = 4e-4
    warmup_steps: int = 95
    total_steps: int = 9535
    grad_clip: float = 1.0
    grad_accum: int = 1


def make_train_fn(cfg, mesh=None, rules=None, hp: TrainHParams = TrainHParams()):
    """The raw (state, batch) -> (state, metrics) function (un-jitted)."""
    rules = rules or shd.ShardingRules()
    _, opt_update = optim.make_optimizer(cfg.optimizer)

    def loss_for(params, batch, rng):
        rt = lm.Runtime(shard=shd.ShardCtx(mesh, rules), rng=rng, train=True)
        return lm.loss_fn(params, batch, cfg, rt)

    def train_step(state, batch):
        rng = jax.random.fold_in(jax.random.PRNGKey(17), state["step"])
        params = state["params"]
        if hp.grad_accum <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch, rng)
        else:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_for, has_aux=True)(
                    params, mb, rng)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), m

            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((hp.grad_accum,
                                     x.shape[0] // hp.grad_accum)
                                    + x.shape[1:]), batch)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), ms = jax.lax.scan(micro, (g0, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / hp.grad_accum, grads)
            loss = loss / hp.grad_accum
            metrics = jax.tree_util.tree_map(lambda x: x.mean(0), ms)
        grads, gnorm = optim.clip_by_global_norm(grads, hp.grad_clip)
        lr = optim.cosine_lr(state["step"], base_lr=hp.base_lr,
                             warmup_steps=hp.warmup_steps,
                             total_steps=hp.total_steps)
        new_params, new_opt = opt_update(grads, state["opt"], params, lr,
                                         state["step"])
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def make_train_step(cfg, mesh, rules=None, hp: TrainHParams = TrainHParams(),
                    donate=True):
    """jit-wrapped train step with explicit in/out shardings for ``mesh``."""
    rules = rules or shd.ShardingRules()
    fn = make_train_fn(cfg, mesh, rules, hp)
    shapes = train_state_shapes(cfg)
    st_sh = state_shardings(shapes, mesh, rules)
    return jax.jit(fn,
                   in_shardings=(st_sh, None),
                   out_shardings=(st_sh, None),
                   donate_argnums=(0,) if donate else ())


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def make_prefill_fn(cfg, mesh=None, rules=None):
    """Run the full-sequence forward to produce logits (no cache install —
    the dry-run uses this for the prefill_* shapes; serving uses
    ``make_prefill_step_fn`` below, which does install state)."""
    rules = rules or shd.ShardingRules()

    def prefill(params, batch):
        rt = lm.Runtime(shard=shd.ShardCtx(mesh, rules), rng=None,
                        train=False)
        logits, aux = lm.forward(params, batch, cfg, rt)
        return logits

    return prefill


def make_prefill_step_fn(cfg, mesh=None, rules=None):
    """Parallel-prefill step for serving: (params, state, tokens (B,S),
    pos0) -> (logits (B,S,V), new decode state).  One training-style forward
    over the whole prompt chunk replaces S sequential decode steps; the
    extracted state is bit-compatible with token-by-token stepping (tested
    per mixer in tests/test_prefill_decode.py)."""
    rules = rules or shd.ShardingRules()

    def prefill_step(params, state, tokens, pos0):
        rt = lm.Runtime(shard=shd.ShardCtx(mesh, rules), rng=None,
                        train=False)
        return lm.prefill(params, state, tokens, pos0, cfg, rt)

    return prefill_step


def make_serve_fn(cfg, mesh=None, rules=None):
    """One-token decode step; ``pos`` may be a scalar (lockstep batch) or a
    (B,) vector of per-slot positions (continuous batching)."""
    rules = rules or shd.ShardingRules()

    def serve_step(params, state, tokens_t, pos):
        rt = lm.Runtime(shard=shd.ShardCtx(mesh, rules), rng=None,
                        train=False)
        logits, new_state = lm.decode_step(params, state, tokens_t, pos,
                                           cfg, rt)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, new_state

    return serve_step


def serve_state_shardings(cfg, state_shapes, mesh, rules=None):
    rules = rules or shd.ShardingRules()
    a = cfg.attention
    m = mesh.shape.get("model", 1)
    heads_ok = a is not None and a.num_heads % m == 0 \
        and a.num_kv_heads % m == 0

    def one(path, leaf):
        la = lm.state_logical(path, leaf)
        if heads_ok and la[-3:] == ("act_kv_seq", None, None):
            # heads divide the model axis: shard cache heads, not seq
            la = la[:-3] + (None, "heads", None)
        elif heads_ok and la[-1:] == ("act_kv_seq",):
            la = la[:-1] + (None,)               # kpos follows the cache
        spec = shd.resolve_spec(leaf.shape, la, mesh, rules)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, state_shapes)
