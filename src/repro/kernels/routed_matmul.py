"""Pallas TPU kernel: routed top-k expert projection for decode.

The prefill grouped GEMM blocks capacity-padded token tiles and maps each
tile to its expert's weight block modulo E.  At decode there are only a
handful of tokens (one per slot), so capacity buffers and the dispatch
sort are pure overhead; instead the (token, top-k choice) pairs *are* the
grid, and each cell streams exactly its selected expert's weight block —
``expert_idx`` rides in scalar-prefetch SMEM and drives the weight
BlockSpec index map directly, the same trick the prefill kernel plays
with ``group_sizes``, applied per assignment instead of per tile.

Grid: (tokens, F tiles, top-k, D tiles) — k and D innermost/sequential,
accumulating the (1, tile_f) output row (scaled by the combine weight)
in an f32 VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, wts_ref, x_ref, w_ref, o_ref, acc_ref, *, nk, nd):
    t = pl.program_id(0)
    k = pl.program_id(2)
    d = pl.program_id(3)

    @pl.when((k == 0) & (d == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    contrib = jnp.dot(x_ref[...].astype(jnp.float32),
                      w_ref[0].astype(jnp.float32),
                      preferred_element_type=jnp.float32)
    acc_ref[...] += contrib * wts_ref[t, k]

    @pl.when((k == nk - 1) & (d == nd - 1))
    def _write():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("tile_f", "tile_k", "interpret"))
def routed_matmul_pallas(x, w, expert_idx, weights=None, *, tile_f=128,
                         tile_k=128, interpret=False):
    """x (T,D) @ w[expert_idx] -> (T,F), summed over the K choices.

    expert_idx (T,K) int32; weights (T,K) f32 combine weights (None for an
    unweighted sum — the x-side projections of SharedRouting).
    """
    T, D = x.shape
    E, _, F = w.shape
    K = expert_idx.shape[-1]
    tile_f = min(tile_f, F)
    tile_k = min(tile_k, D)

    def pad_to(a, axis, mult):
        r = (-a.shape[axis]) % mult
        if r == 0:
            return a
        pads = [(0, 0)] * a.ndim
        pads[axis] = (0, r)
        return jnp.pad(a, pads)

    xp = pad_to(x, 1, tile_k)
    wp = pad_to(pad_to(w, 1, tile_k), 2, tile_f)
    Dp = xp.shape[1]
    Fp = wp.shape[2]
    nk, nd = K, Dp // tile_k
    grid = (T, Fp // tile_f, K, nd)
    if weights is None:
        weights = jnp.ones((T, K), jnp.float32)

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, nd=nd),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, tile_k),
                             lambda t, j, k, d, idx, wts: (t, d)),
                pl.BlockSpec((1, tile_k, tile_f),
                             lambda t, j, k, d, idx, wts: (idx[t, k], d, j)),
            ],
            out_specs=pl.BlockSpec((1, tile_f),
                                   lambda t, j, k, d, idx, wts: (t, j)),
            scratch_shapes=[pltpu.VMEM((1, tile_f), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((T, Fp), x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(expert_idx.astype(jnp.int32), weights.astype(jnp.float32), xp, wp)
    return out[:, :F]
