"""Pallas TPU kernels: per-mixer single-timestep decode steps (phase 2).

One kernel per recurrent mixer family — mamba2 (SSD scalar decay per
head, flattened to per-channel like the mamba-1 kernel), gdn (delta
rule), rglru, mlstm and slstm — each advancing the f32 carried state and
folding the mixer's normalization / gating / output-projection tail into
the same launch, mirroring ``kernels/decode_step.py``: grid
(batch, feature tiles), tile axis sequential ("arbitrary") with the
output row accumulated across tiles in f32 VMEM scratch.

Mixers whose norm is *global* over the flattened feature dim (mamba2 and
gdn rmsnorm over all heads at once) factor it: every tile accumulates
its unnormalized gated row and a sum-of-squares scalar, and the last
tile applies the global ``rsqrt`` — numerically equal to the oracle up
to f32 rounding (gated allclose in interpret mode; engine-level greedy
bit-identity rides on the off-TPU 'fused' impl, which shares the
``kernels/ref.py`` math verbatim).  mlstm/slstm headnorms are per-head
and therefore tile-local.

Tile sizes default from ``kernels/autotune.py`` (committed tuning table
on real devices, static defaults under interpret/CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _silu(x):
    return x * jax.nn.sigmoid(x)


_DUMMY_SPEC = pl.BlockSpec((1, 1), lambda b, d: (0, 0))


# ---------------------------------------------------------------------------
# mamba2 — SSD scalar-decay step, flattened per-channel (decay/dt/D are
# broadcast from per-head to per-channel by ops.py), + global rmsnorm of
# the silu-gated output and optional out-projection.
# ---------------------------------------------------------------------------

def _mamba2_kernel(h_ref, x_ref, a_ref, dt_ref, b_ref, c_ref, d_ref,
                   z_ref, s_ref, w_ref, ho_ref, o_ref, acc_ref, ss_ref,
                   *, nde, de, eps, fused):
    d = pl.program_id(1)

    @pl.when(d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ss_ref[...] = jnp.zeros_like(ss_ref)

    f32 = jnp.float32
    h = (a_ref[0][:, None] * h_ref[0]
         + (x_ref[0] * dt_ref[0])[:, None] * b_ref[0].astype(f32)[None, :])
    y = jnp.sum(h * c_ref[0].astype(f32)[None, :], axis=1)
    y = y + x_ref[0] * d_ref[0]
    ho_ref[0] = h
    t = (y.astype(z_ref.dtype) * _silu(z_ref[0])).astype(f32)   # (TDe,)
    ss_ref[...] += jnp.sum(t * t).reshape(1, 1)
    ts = t * s_ref[0].astype(f32)
    if fused:
        acc_ref[...] += jnp.dot(ts[None, :], w_ref[...].astype(f32),
                                preferred_element_type=f32)
    else:
        acc_ref[0, pl.ds(d * ts.shape[0], ts.shape[0])] = ts

    @pl.when(d == nde - 1)
    def _write():
        r = jax.lax.rsqrt(ss_ref[0, 0] / de + eps)
        o_ref[...] = (acc_ref[...] * r).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("eps", "de_tile", "interpret"))
def mamba2_step_pallas(h, x, a, dt, B_t, C_t, D, z, scale, eps,
                       w_out=None, *, de_tile=256, interpret=False):
    """(h', out).  All per-channel (heads flattened): h (B,De,N) f32;
    x, a, dt (B,De) f32; B_t, C_t (B,N); D (De,) f32; z (B,De) io;
    scale (De,); w_out (De,Dm) or None (out is then the (B,De) normed y).
    """
    Bsz, De, N = h.shape
    fused = w_out is not None
    Dm = w_out.shape[-1] if fused else De
    nde = De // de_tile
    w = w_out if fused else jnp.zeros((1, 1), jnp.float32)
    w_spec = (pl.BlockSpec((de_tile, Dm), lambda b, d: (d, 0)) if fused
              else _DUMMY_SPEC)
    hs, out = pl.pallas_call(
        functools.partial(_mamba2_kernel, nde=nde, de=De, eps=eps,
                          fused=fused),
        grid=(Bsz, nde),
        in_specs=[
            pl.BlockSpec((1, de_tile, N), lambda b, d: (b, d, 0)),
            pl.BlockSpec((1, de_tile), lambda b, d: (b, d)),
            pl.BlockSpec((1, de_tile), lambda b, d: (b, d)),
            pl.BlockSpec((1, de_tile), lambda b, d: (b, d)),
            pl.BlockSpec((1, N), lambda b, d: (b, 0)),
            pl.BlockSpec((1, N), lambda b, d: (b, 0)),
            pl.BlockSpec((1, de_tile), lambda b, d: (0, d)),
            pl.BlockSpec((1, de_tile), lambda b, d: (b, d)),
            pl.BlockSpec((1, de_tile), lambda b, d: (0, d)),
            w_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, de_tile, N), lambda b, d: (b, d, 0)),
            pl.BlockSpec((1, Dm), lambda b, d: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, De, N), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, Dm), z.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, Dm), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(h, x, a, dt, B_t, C_t, D.reshape(1, De), z, scale.reshape(1, De), w)
    return hs, out


# ---------------------------------------------------------------------------
# gdn — delta-rule state update per head tile + global rmsnorm / gate.
# ---------------------------------------------------------------------------

def _gdn_kernel(s_ref, q_ref, k_ref, v_ref, a_ref, b_ref, z_ref, g_ref,
                w_ref, so_ref, o_ref, acc_ref, ss_ref,
                *, nh_tiles, dv, eps, fused):
    d = pl.program_id(1)

    @pl.when(d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ss_ref[...] = jnp.zeros_like(ss_ref)

    f32 = jnp.float32
    S = s_ref[0]                                          # (th,K,V) f32
    a = a_ref[0]                                          # (th,) f32
    b = b_ref[0]
    k = k_ref[0]                                          # (th,K) io
    Sk = jnp.einsum("hkv,hk->hv", S, k.astype(f32))
    S = (S * a[..., None, None]
         - jnp.einsum("hk,hv->hkv", (k * (a * b)[..., None]).astype(f32),
                      Sk)
         + jnp.einsum("hk,hv->hkv", (k * b[..., None]).astype(f32),
                      v_ref[0].astype(f32)))
    y = jnp.einsum("hkv,hk->hv", S, q_ref[0].astype(f32))  # (th,V)
    so_ref[0] = S
    t = (y.reshape(-1).astype(z_ref.dtype) * _silu(z_ref[0])).astype(f32)
    ss_ref[...] += jnp.sum(t * t).reshape(1, 1)
    ts = t * g_ref[0].astype(f32)
    if fused:
        acc_ref[...] += jnp.dot(ts[None, :], w_ref[...].astype(f32),
                                preferred_element_type=f32)
    else:
        acc_ref[0, pl.ds(d * ts.shape[0], ts.shape[0])] = ts

    @pl.when(d == nh_tiles - 1)
    def _write():
        r = jax.lax.rsqrt(ss_ref[0, 0] / dv + eps)
        o_ref[...] = (acc_ref[...] * r).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("eps", "h_tile", "interpret"))
def gdn_step_pallas(S, q, k, v, a, b, z, scale, eps, w_out=None, *,
                    h_tile=2, interpret=False):
    """(S', out).  S (B,H,K,V) f32; q, k (B,H,K) io; v (B,H,V) io;
    a, b (B,H) f32; z (B,H*V) io; scale (H*V,); w_out (H*V,Dm) or None.
    """
    Bsz, H, K, V = S.shape
    dv = H * V
    fused = w_out is not None
    Dm = w_out.shape[-1] if fused else dv
    nt = H // h_tile
    w = w_out if fused else jnp.zeros((1, 1), jnp.float32)
    w_spec = (pl.BlockSpec((h_tile * V, Dm), lambda b_, d: (d, 0)) if fused
              else _DUMMY_SPEC)
    so, out = pl.pallas_call(
        functools.partial(_gdn_kernel, nh_tiles=nt, dv=dv, eps=eps,
                          fused=fused),
        grid=(Bsz, nt),
        in_specs=[
            pl.BlockSpec((1, h_tile, K, V), lambda b_, d: (b_, d, 0, 0)),
            pl.BlockSpec((1, h_tile, K), lambda b_, d: (b_, d, 0)),
            pl.BlockSpec((1, h_tile, K), lambda b_, d: (b_, d, 0)),
            pl.BlockSpec((1, h_tile, V), lambda b_, d: (b_, d, 0)),
            pl.BlockSpec((1, h_tile), lambda b_, d: (b_, d)),
            pl.BlockSpec((1, h_tile), lambda b_, d: (b_, d)),
            pl.BlockSpec((1, h_tile * V), lambda b_, d: (b_, d)),
            pl.BlockSpec((1, h_tile * V), lambda b_, d: (0, d)),
            w_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, h_tile, K, V), lambda b_, d: (b_, d, 0, 0)),
            pl.BlockSpec((1, Dm), lambda b_, d: (b_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, H, K, V), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, Dm), z.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, Dm), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(S, q, k, v, a, b, z, scale.reshape(1, dv), w)
    return so, out


# ---------------------------------------------------------------------------
# rglru — elementwise gated linear recurrence + optional gelu-gate ×
# out-projection epilogue (the closest mirror of decode_step._fused_kernel).
# ---------------------------------------------------------------------------

def _rglru_kernel(h_ref, u_ref, la_ref, i_ref, g_ref, w_ref, ho_ref,
                  o_ref, acc_ref, *, nd, fused):
    d = pl.program_id(1)
    if fused:
        @pl.when(d == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

    f32 = jnp.float32
    a = jnp.exp(la_ref[0])
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-6))
    h = a * h_ref[0] + mult * i_ref[0] * u_ref[0].astype(f32)
    ho_ref[0] = h
    y = h.astype(u_ref.dtype)
    if not fused:
        o_ref[0] = y
        return
    zz = y * g_ref[0]
    acc_ref[...] += jnp.dot(zz[None, :], w_ref[...].astype(zz.dtype),
                            preferred_element_type=f32)

    @pl.when(d == nd - 1)
    def _write():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d_tile", "interpret"))
def rglru_step_pallas(h, u, log_a, i_gate, gate=None, w_out=None, *,
                      d_tile=512, interpret=False):
    """(h', out).  h (B,D) f32; u (B,D) io; log_a, i_gate (B,D) f32;
    gate (B,D) io + w_out (D,Dm) fold the gelu-gate × projection in.
    """
    Bsz, D = h.shape
    fused = w_out is not None
    Dm = w_out.shape[-1] if fused else D
    nd = D // d_tile
    g = gate if fused else jnp.zeros((1, 1), u.dtype)
    w = w_out if fused else jnp.zeros((1, 1), jnp.float32)
    g_spec = (pl.BlockSpec((1, d_tile), lambda b, d: (b, d)) if fused
              else _DUMMY_SPEC)
    w_spec = (pl.BlockSpec((d_tile, Dm), lambda b, d: (d, 0)) if fused
              else _DUMMY_SPEC)
    o_spec = (pl.BlockSpec((1, Dm), lambda b, d: (b, 0)) if fused
              else pl.BlockSpec((1, d_tile), lambda b, d: (b, d)))
    hs, out = pl.pallas_call(
        functools.partial(_rglru_kernel, nd=nd, fused=fused),
        grid=(Bsz, nd),
        in_specs=[
            pl.BlockSpec((1, d_tile), lambda b, d: (b, d)),
            pl.BlockSpec((1, d_tile), lambda b, d: (b, d)),
            pl.BlockSpec((1, d_tile), lambda b, d: (b, d)),
            pl.BlockSpec((1, d_tile), lambda b, d: (b, d)),
            g_spec,
            w_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, d_tile), lambda b, d: (b, d)),
            o_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, D), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, Dm), u.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, Dm), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(h, u, log_a, i_gate, g, w)
    return hs, out


# ---------------------------------------------------------------------------
# mlstm — matrix-memory cell update per head tile; headnorm is per-head
# (tile-local), so only the out-projection needs the accumulator.
# ---------------------------------------------------------------------------

def _mlstm_kernel(c_ref, n_ref, m_ref, q_ref, k_ref, v_ref, il_ref,
                  fl_ref, z_ref, g_ref, w_ref, co_ref, no_ref, mo_ref,
                  o_ref, acc_ref, *, nt, eps, fused):
    d = pl.program_id(1)
    if fused:
        @pl.when(d == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

    f32 = jnp.float32
    il = il_ref[0]                                        # (th,) f32
    fl = fl_ref[0]
    m = m_ref[0]
    k = k_ref[0]                                          # (th,K) f32
    m_new = jnp.maximum(fl + m, il)
    fpx = jnp.exp(fl + m - m_new)
    ipx = jnp.exp(il - m_new)
    C = (fpx[..., None, None] * c_ref[0]
         + ipx[..., None, None] * (k[..., :, None] * v_ref[0][..., None, :]))
    n = fpx[..., None] * n_ref[0] + ipx[..., None] * k
    num = jnp.einsum("hkv,hk->hv", C, q_ref[0])
    den = jnp.abs(jnp.einsum("hk,hk->h", n, q_ref[0]))
    y = num / jnp.maximum(den, 1.0)[..., None]            # (th,V) f32
    co_ref[0] = C
    no_ref[0] = n
    mo_ref[0] = m_new
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    yn = (y * jax.lax.rsqrt(var + eps)).reshape(-1)
    t = (yn * g_ref[0].astype(f32)).astype(z_ref.dtype) * _silu(z_ref[0])
    if not fused:
        o_ref[0] = t
        return
    acc_ref[...] += jnp.dot(t[None, :], w_ref[...].astype(t.dtype),
                            preferred_element_type=f32)

    @pl.when(d == nt - 1)
    def _write():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("eps", "h_tile", "interpret"))
def mlstm_step_pallas(C, n, m, q, k, v, il, fl, z, gn_scale, eps,
                      w_out=None, *, h_tile=2, interpret=False):
    """(C', n', m', out).  C (B,H,K,V), n (B,H,K), m (B,H) f32 state;
    q, k (B,H,K), v (B,H,V), il, fl (B,H) f32; z (B,H*V) io;
    gn_scale (H*V,); w_out (H*V,Dm) or None.
    """
    Bsz, H, K, V = C.shape
    inner = H * V
    fused = w_out is not None
    Dm = w_out.shape[-1] if fused else inner
    nt = H // h_tile
    w = w_out if fused else jnp.zeros((1, 1), jnp.float32)
    w_spec = (pl.BlockSpec((h_tile * V, Dm), lambda b, d: (d, 0)) if fused
              else _DUMMY_SPEC)
    o_spec = (pl.BlockSpec((1, Dm), lambda b, d: (b, 0)) if fused
              else pl.BlockSpec((1, h_tile * V), lambda b, d: (b, d)))
    co, no, mo, out = pl.pallas_call(
        functools.partial(_mlstm_kernel, nt=nt, eps=eps, fused=fused),
        grid=(Bsz, nt),
        in_specs=[
            pl.BlockSpec((1, h_tile, K, V), lambda b, d: (b, d, 0, 0)),
            pl.BlockSpec((1, h_tile, K), lambda b, d: (b, d, 0)),
            pl.BlockSpec((1, h_tile), lambda b, d: (b, d)),
            pl.BlockSpec((1, h_tile, K), lambda b, d: (b, d, 0)),
            pl.BlockSpec((1, h_tile, K), lambda b, d: (b, d, 0)),
            pl.BlockSpec((1, h_tile, V), lambda b, d: (b, d, 0)),
            pl.BlockSpec((1, h_tile), lambda b, d: (b, d)),
            pl.BlockSpec((1, h_tile), lambda b, d: (b, d)),
            pl.BlockSpec((1, h_tile * V), lambda b, d: (b, d)),
            pl.BlockSpec((1, h_tile * V), lambda b, d: (0, d)),
            w_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, h_tile, K, V), lambda b, d: (b, d, 0, 0)),
            pl.BlockSpec((1, h_tile, K), lambda b, d: (b, d, 0)),
            pl.BlockSpec((1, h_tile), lambda b, d: (b, d)),
            o_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, H, K, V), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, H, K), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, H), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, Dm), z.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, Dm), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(C, n, m, q, k, v, il, fl, z, gn_scale.reshape(1, inner), w)
    return co, no, mo, out


# ---------------------------------------------------------------------------
# slstm — scalar-memory cell update per head tile + headnorm, optionally
# fused with the block's gated-FFN tail (two accumulators: up + gate
# projections; the last tile contracts the down projection whole).
# ---------------------------------------------------------------------------

def _slstm_kernel(c_ref, n_ref, h_ref, m_ref, gx_ref, r_ref, b_ref,
                  g_ref, wu_ref, wg_ref, wd_ref, co_ref, no_ref, ho_ref,
                  mo_ref, o_ref, au_ref, ag_ref, *, nt, dh, eps, fused):
    d = pl.program_id(1)
    if fused:
        @pl.when(d == 0)
        def _init():
            au_ref[...] = jnp.zeros_like(au_ref)
            ag_ref[...] = jnp.zeros_like(ag_ref)

    f32 = jnp.float32
    h = h_ref[0]                                          # (th,dh) f32
    rec = jnp.einsum("hd,hdg->hg", h, r_ref[...])         # (th,4dh)
    th = h.shape[0]
    g = gx_ref[0].reshape(th, 4 * dh).astype(f32) + rec + b_ref[...]
    il, fp, zz, og = jnp.split(g, 4, axis=-1)             # (th,dh)
    fl = -jax.nn.softplus(-fp)
    m_new = jnp.maximum(fl + m_ref[0], il)
    i = jnp.exp(il - m_new)
    f = jnp.exp(fl + m_ref[0] - m_new)
    c_new = f * c_ref[0] + i * jnp.tanh(zz)
    n_new = f * n_ref[0] + i
    h_new = jax.nn.sigmoid(og) * c_new / jnp.maximum(n_new, 1.0)
    co_ref[0] = c_new
    no_ref[0] = n_new
    ho_ref[0] = h_new
    mo_ref[0] = m_new
    var = jnp.mean(h_new * h_new, axis=-1, keepdims=True)
    yn = (h_new * jax.lax.rsqrt(var + eps)).reshape(-1)
    t = (yn * g_ref[0].astype(f32)).astype(gx_ref.dtype)  # (th*dh,) io
    if not fused:
        o_ref[0] = t
        return
    au_ref[...] += jnp.dot(t[None, :], wu_ref[...].astype(t.dtype),
                           preferred_element_type=f32)
    ag_ref[...] += jnp.dot(t[None, :], wg_ref[...].astype(t.dtype),
                           preferred_element_type=f32)

    @pl.when(d == nt - 1)
    def _write():
        io = o_ref.dtype
        u = au_ref[...].astype(io) * _silu(ag_ref[...].astype(io))
        o_ref[...] = jnp.dot(u, wd_ref[...].astype(io),
                             preferred_element_type=f32).astype(io)


@functools.partial(jax.jit,
                   static_argnames=("eps", "h_tile", "interpret"))
def slstm_step_pallas(c, n, h, m, gx, r_w, b, gn_scale, eps, w_up=None,
                      w_gate=None, w_down=None, *, h_tile=2,
                      interpret=False):
    """(c', n', h', m', out).  c/n/h/m (B,H,Dh) f32 state; gx (B,4*H*Dh)
    io pre-gates; r_w (H,Dh,4Dh) f32; b (H,4Dh) f32 (pre-reshaped by the
    caller, preserving nn.xlstm's flat-bias layout); gn_scale (H*Dh,).
    With w_up/w_gate (H*Dh,F) + w_down (F,Dm) the gated-FFN tail is
    folded in; otherwise out is the (B,H*Dh) head-normed y.
    """
    Bsz, H, Dh = c.shape
    inner = H * Dh
    fused = w_up is not None
    F = w_up.shape[-1] if fused else 1
    Dm = w_down.shape[-1] if fused else inner
    nt = H // h_tile
    wu = w_up if fused else jnp.zeros((1, 1), jnp.float32)
    wg = w_gate if fused else jnp.zeros((1, 1), jnp.float32)
    wd = w_down if fused else jnp.zeros((1, 1), jnp.float32)
    pw = pl.BlockSpec((h_tile * Dh, F), lambda b_, d: (d, 0))
    wu_spec = pw if fused else _DUMMY_SPEC
    wg_spec = pw if fused else _DUMMY_SPEC
    wd_spec = (pl.BlockSpec((F, Dm), lambda b_, d: (0, 0)) if fused
               else _DUMMY_SPEC)
    o_spec = (pl.BlockSpec((1, Dm), lambda b_, d: (b_, 0)) if fused
              else pl.BlockSpec((1, h_tile * Dh), lambda b_, d: (b_, d)))
    st_spec = pl.BlockSpec((1, h_tile, Dh), lambda b_, d: (b_, d, 0))
    co, no, ho, mo, out = pl.pallas_call(
        functools.partial(_slstm_kernel, nt=nt, dh=Dh, eps=eps,
                          fused=fused),
        grid=(Bsz, nt),
        in_specs=[
            st_spec, st_spec, st_spec, st_spec,
            pl.BlockSpec((1, h_tile * 4 * Dh), lambda b_, d: (b_, d)),
            pl.BlockSpec((h_tile, Dh, 4 * Dh), lambda b_, d: (d, 0, 0)),
            pl.BlockSpec((h_tile, 4 * Dh), lambda b_, d: (d, 0)),
            pl.BlockSpec((1, h_tile * Dh), lambda b_, d: (0, d)),
            wu_spec, wg_spec, wd_spec,
        ],
        out_specs=[st_spec, st_spec, st_spec, st_spec, o_spec],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, H, Dh), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, H, Dh), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, H, Dh), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, H, Dh), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, Dm), gx.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, F), jnp.float32),
                        pltpu.VMEM((1, F), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(c, n, h, m, gx, r_w, b, gn_scale.reshape(1, inner), wu, wg, wd)
    return co, no, ho, mo, out
