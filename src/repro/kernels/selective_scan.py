"""Pallas TPU kernel: chunked Mamba selective scan.

TPU adaptation (vs Mamba's CUDA warp-scan): the grid's *last* dimension walks
sequence chunks sequentially (TPU grid order guarantees this), carrying the
SSM state ``h`` in a VMEM scratch accumulator across chunk iterations.  Each
chunk of (dt, u, B, C) is streamed HBM->VMEM by the BlockSpec pipeline while
the recurrence runs on the VPU over a (DE_TILE, N) state tile.  The D*u skip
term is applied outside the kernel (XLA fuses it).

Grid: (batch, De tiles, seq chunks)   -- chunks innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, o_ref, h_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...].astype(jnp.float32)                     # (TDe, N)
    chunk = u_ref.shape[1]

    def body(t, h):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)         # (TDe,)
        u_t = u_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)           # (N,)
        c_t = c_ref[0, t, :].astype(jnp.float32)
        a = jnp.exp(dt_t[:, None] * A)                     # (TDe, N)
        h = a * h + (dt_t * u_t)[:, None] * b_t[None, :]
        y = jnp.sum(h * c_t[None, :], axis=1)              # (TDe,)
        o_ref[0, t, :] = y.astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, body, h_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("chunk", "de_tile", "interpret"))
def selective_scan_pallas(u, dt, A, Bm, Cm, *, chunk=128, de_tile=512,
                          interpret=False):
    """y (no D*u term). u,dt (B,S,De); A (De,N); Bm,Cm (B,S,N)."""
    Bsz, S, De = u.shape
    N = A.shape[-1]
    chunk = min(chunk, S)
    de_tile = min(de_tile, De)
    assert S % chunk == 0, (S, chunk)
    assert De % de_tile == 0, (De, de_tile)
    grid = (Bsz, De // de_tile, S // chunk)

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, de_tile), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, chunk, de_tile), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, chunk, N), lambda b, d, s: (b, s, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, s: (b, s, 0)),
            pl.BlockSpec((de_tile, N), lambda b, d, s: (d, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, de_tile), lambda b, d, s: (b, s, d)),
        out_shape=jax.ShapeDtypeStruct((Bsz, S, De), u.dtype),
        scratch_shapes=[pltpu.VMEM((de_tile, N), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(u, dt, Bm, Cm, A)
    return out
