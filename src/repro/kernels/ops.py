"""Jit'd kernel wrappers with platform dispatch.

``impl`` resolution:
  None      -> 'pallas' on TPU, 'ref' elsewhere (the dry-run therefore
               compiles the mathematically identical jnp graphs, keeping XLA
               cost_analysis meaningful; see DESIGN.md §3).
  'ref'     -> pure-jnp oracle
  'pallas'  -> compiled Pallas TPU kernel
  'interpret' -> Pallas kernel body executed in interpret mode (CPU tests)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.grouped_matmul import grouped_matmul_pallas
from repro.kernels.selective_scan import selective_scan_pallas


def _resolve(impl):
    if impl is None:
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def selective_scan(u, dt, A, Bm, Cm, D=None, *, chunk=128, impl=None,
                   acc_dtype="float32", h0=None, return_state=False):
    impl = _resolve(impl)
    if h0 is not None or return_state:
        # stateful prefill path: only the ref oracle threads/returns the
        # recurrent state (the Pallas kernel computes outputs only)
        return _ref.selective_scan_ref(u, dt, A, Bm, Cm, D, chunk=chunk,
                                       acc_dtype=acc_dtype, h0=h0,
                                       return_state=return_state)
    if impl == "ref":
        return _ref.selective_scan_ref(u, dt, A, Bm, Cm, D, chunk=chunk,
                                       acc_dtype=acc_dtype)
    y = selective_scan_pallas(u, dt, A, Bm, Cm, chunk=chunk,
                              interpret=(impl == "interpret"))
    if D is not None:
        y = (y.astype(jnp.float32)
             + u.astype(jnp.float32) * D.astype(jnp.float32)).astype(y.dtype)
    return y


def grouped_matmul(x, w, group_sizes, *, impl=None, **tiles):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.grouped_matmul_ref(x, w, group_sizes)
    return grouped_matmul_pallas(x, w, group_sizes,
                                 interpret=(impl == "interpret"), **tiles)
