"""Jit'd kernel wrappers behind one impl-resolution registry.

Every op is registered with the set of implementations it offers and the
off-TPU fallback of its compiled path; ``resolve_impl(name, impl)`` picks
the implementation in a single place:

  explicit ``impl=`` argument            (strongest)
  > module-level default                 (``set_default_impl`` /
                                          ``default_impl`` scope — how
                                          ``EngineConfig.kernels`` threads
                                          one choice through every jitted
                                          serving step)
  > backend auto                         ('pallas' on TPU, 'ref' elsewhere,
                                          so the dry-run compiles the
                                          mathematically identical jnp
                                          graphs and XLA cost_analysis
                                          stays meaningful; DESIGN.md §3)

Implementation names:
  'ref'       pure-jnp oracle (kernels/ref.py — the correctness gate; for
              ``routed_matmul`` this is the honest O(E×) dense-expert path)
  'pallas'    compiled Pallas TPU kernel.  Off-TPU (Mosaic cannot compile)
              each op declares a fallback: the prefill ops fall back to
              'ref'; the decode-step ops fall back to 'fused'
  'fused'     fused jnp composite of the Pallas kernel's math — the
              decode fast path on hosts without a TPU (top-k gathered
              expert GEMM instead of the O(E×) oracle)
  'interpret' Pallas kernel body executed in interpret mode (CPU tests)

The pre-registry per-op ``impl=`` keywords keep working: they are now thin
deprecation shims over ``resolve_impl``.
"""
from __future__ import annotations

import contextlib
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels import mixer_steps as _mx
from repro.kernels import ref as _ref
from repro.kernels.decode_step import (decode_step_fused_pallas,
                                       decode_step_pallas)
from repro.kernels.grouped_matmul import grouped_matmul_pallas
from repro.kernels.routed_matmul import routed_matmul_pallas
from repro.kernels.sampling_epilogue import logits_step_pallas
from repro.kernels.selective_scan import selective_scan_pallas


# ---------------------------------------------------------------------------
# impl resolution: one registry, one module-level default
# ---------------------------------------------------------------------------

class _OpSpec:
    __slots__ = ("impls", "fallback")

    def __init__(self, impls, fallback):
        self.impls = frozenset(impls)
        self.fallback = dict(fallback)   # off-TPU remap, e.g. pallas->fused


_REGISTRY: Dict[str, _OpSpec] = {}

#: module-level default implementation (None = backend auto)
_DEFAULT_IMPL: Optional[str] = None


def register_op(name: str, impls, fallback=()) -> None:
    if name in _REGISTRY:
        raise ValueError(
            f"kernel op {name!r} is already registered; op names are "
            f"global — pick a distinct name or deregister first "
            f"(registered: {registered_ops()})")
    _REGISTRY[name] = _OpSpec(impls, fallback)


def registered_ops():
    """Registered op names (docs/tests introspection)."""
    return sorted(_REGISTRY)


def set_default_impl(impl: Optional[str]) -> Optional[str]:
    """Set the module-level default implementation for every op whose call
    site passes ``impl=None``; returns the previous default.  ``None``
    restores backend auto-selection.  Re-exported as
    ``repro.kernels.set_default_impl``."""
    global _DEFAULT_IMPL
    if impl is not None:
        known = set().union(*(s.impls for s in _REGISTRY.values()))
        if impl not in known:
            raise ValueError(f"unknown kernel impl {impl!r}; "
                             f"known: {sorted(known)}")
    prev, _DEFAULT_IMPL = _DEFAULT_IMPL, impl
    return prev


def active_default() -> Optional[str]:
    """The module-level default impl, or None under backend auto."""
    return _DEFAULT_IMPL


@contextlib.contextmanager
def default_impl(impl: Optional[str]):
    """Scope ``set_default_impl(impl)`` to a ``with`` block.  The serving
    engine wraps each jitted step in this scope, so the choice is active
    exactly while jax traces the step (``EngineConfig.kernels``)."""
    prev = set_default_impl(impl)
    try:
        yield
    finally:
        set_default_impl(prev)


def resolve_impl(name: str, impl: Optional[str] = None) -> str:
    """Resolve the implementation for op ``name``: explicit ``impl`` >
    module default > backend auto; then apply the op's off-TPU fallback
    ('pallas' only compiles on TPU)."""
    spec = _REGISTRY.get(name)
    if spec is None:
        raise KeyError(f"unknown kernel op {name!r}; "
                       f"registered: {registered_ops()}")
    if impl is None:
        impl = _DEFAULT_IMPL
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if jax.default_backend() != "tpu":
        impl = spec.fallback.get(impl, impl)
    if impl not in spec.impls:
        raise ValueError(f"op {name!r} has no impl {impl!r}; "
                         f"available: {sorted(spec.impls)}")
    return impl


register_op("selective_scan", ("ref", "pallas", "interpret"),
            fallback={"pallas": "ref"})
register_op("grouped_matmul", ("ref", "pallas", "interpret"),
            fallback={"pallas": "ref"})
register_op("selective_scan_step", ("ref", "fused", "pallas", "interpret"),
            fallback={"pallas": "fused"})
register_op("routed_matmul", ("ref", "fused", "pallas", "interpret"),
            fallback={"pallas": "fused"})
# phase-2 per-mixer fused decode steps + the sampling epilogue.  For all
# of them 'ref' and the off-TPU 'fused' alias are the same oracle
# composition, so EngineConfig(kernels="pallas") stays greedy
# bit-identical to "ref" on hosts without a TPU (the CPU-visible fused
# win comes from the RoM routed fast path these ops unlock, not from
# divergent math).
for _step_op in ("mamba2_step", "gdn_step", "rglru_step", "mlstm_step",
                 "slstm_step", "logits_step"):
    register_op(_step_op, ("ref", "fused", "pallas", "interpret"),
                fallback={"pallas": "fused"})


# ---------------------------------------------------------------------------
# prefill / training ops (signatures unchanged — deprecation shims over the
# registry)
# ---------------------------------------------------------------------------

def selective_scan(u, dt, A, Bm, Cm, D=None, *, chunk=128, impl=None,
                   acc_dtype="float32", h0=None, return_state=False):
    impl = resolve_impl("selective_scan", impl)
    if h0 is not None or return_state:
        # stateful prefill path: only the ref oracle threads/returns the
        # recurrent state (the Pallas kernel computes outputs only)
        return _ref.selective_scan_ref(u, dt, A, Bm, Cm, D, chunk=chunk,
                                       acc_dtype=acc_dtype, h0=h0,
                                       return_state=return_state)
    if impl == "ref":
        return _ref.selective_scan_ref(u, dt, A, Bm, Cm, D, chunk=chunk,
                                       acc_dtype=acc_dtype)
    y = selective_scan_pallas(u, dt, A, Bm, Cm, chunk=chunk,
                              interpret=(impl == "interpret"))
    if D is not None:
        y = (y.astype(jnp.float32)
             + u.astype(jnp.float32) * D.astype(jnp.float32)).astype(y.dtype)
    return y


def grouped_matmul(x, w, group_sizes, *, impl=None, **tiles):
    impl = resolve_impl("grouped_matmul", impl)
    if impl == "ref":
        return _ref.grouped_matmul_ref(x, w, group_sizes)
    return grouped_matmul_pallas(x, w, group_sizes,
                                 interpret=(impl == "interpret"), **tiles)


# ---------------------------------------------------------------------------
# decode-step ops (the per-slot hot path)
# ---------------------------------------------------------------------------

def selective_scan_step(h, u_t, dt_t, A, B_t, C_t, D=None, *, gate=None,
                        w_out=None, impl=None):
    """Single-timestep selective scan, optionally fused with the gating +
    output projection epilogue.

    h (B,De,N) f32; u_t, dt_t (B,De); A (De,N); B_t, C_t (B,N); D (De,).
    Without an epilogue returns ``(h', y)`` with y (B,De).  With
    ``gate`` (B,De) and ``w_out`` (De,Dm) returns ``(h', out)`` where
    ``out = dense(y * gate, w_out)`` — one kernel for the whole per-slot
    Mamba decode tail instead of scan + two elementwise passes + GEMM.
    """
    if (gate is None) != (w_out is None):
        raise ValueError("gate and w_out must be supplied together")
    impl = resolve_impl("selective_scan_step", impl)
    if impl in ("pallas", "interpret"):
        interp = impl == "interpret"
        if gate is None:
            return decode_step_pallas(h, u_t, dt_t, A, B_t, C_t, D,
                                      interpret=interp)
        return decode_step_fused_pallas(h, u_t, dt_t, A, B_t, C_t, D,
                                        gate, w_out, interpret=interp)
    # 'ref' and its off-TPU 'fused' alias share the oracle math exactly, so
    # EngineConfig(kernels="pallas") stays greedy bit-identical to "ref" on
    # hosts where the compiled kernel is unavailable.
    h2, y = _ref.selective_scan_step(h, u_t, dt_t, A, B_t, C_t, D)
    if gate is None:
        return h2, y
    from repro.nn.layers import dense
    return h2, dense(y * gate, w_out)


def routed_matmul(x, w, expert_idx, weights=None, *, impl=None):
    """Routed expert projection for decode-shaped token counts.

    x (T,D) tokens; w (E,D,F) expert weights; expert_idx (T,K) int32 top-k
    choices; weights (T,K) f32 combine weights or None (unweighted sum).
    Returns (T,F) = sum_k scale_k * (x_t @ w[expert_idx[t,k]]).

    'ref' is the O(E×) dense-expert oracle (mirrors
    ``moe_dispatch.dense_moe_linear``); 'fused'/'pallas' compute only the
    selected experts — the decode fast path that skips the capacity
    dispatch machinery (sort + offsets + gathers) entirely.
    """
    impl = resolve_impl("routed_matmul", impl)
    if impl == "ref":
        return _ref.routed_matmul_ref(x, w, expert_idx, weights)
    if impl in ("pallas", "interpret"):
        return routed_matmul_pallas(x, w, expert_idx, weights,
                                    interpret=(impl == "interpret"))
    return _ref.routed_matmul_fused(x, w, expert_idx, weights)


# ---------------------------------------------------------------------------
# per-mixer fused decode steps (phase 2).  Signatures mirror the oracles
# in kernels/ref.py; 'ref' and 'fused' share that math verbatim (see the
# registration comment), 'pallas'/'interpret' run kernels/mixer_steps.py
# with tile sizes from kernels/autotune.py.
# ---------------------------------------------------------------------------

def mamba2_step(h, xh, dt, A_log_h, B_t, C_t, D_h, z, scale, eps, *,
                w_out=None, impl=None):
    """Mamba-2 SSD decode step + rmsnorm/gate (and out-proj with w_out).

    h (B,H,P,N) f32; xh (B,H,P) f32; dt (B,H) f32; A_log_h, D_h (H,);
    B_t, C_t (B,N); z (B,De) io; scale (De,).  Returns ``(h', y|out)``.
    """
    impl = resolve_impl("mamba2_step", impl)
    if impl in ("pallas", "interpret"):
        Bsz, H, P, N = h.shape
        De = H * P
        f32 = jnp.float32
        # per-head scalars broadcast to per-channel so the kernel shares
        # the mamba-1 (channel, state) tile structure
        a = jnp.exp(dt * -jnp.exp(A_log_h))
        a_ch = jnp.broadcast_to(a[..., None], (Bsz, H, P)).reshape(Bsz, De)
        dt_ch = jnp.broadcast_to(dt[..., None], (Bsz, H, P)).reshape(Bsz, De)
        D_ch = jnp.broadcast_to(D_h.astype(f32)[:, None], (H, P)).reshape(De)
        tile = autotune.tile_for("mamba2_step", z.dtype, De, 256)
        h2, out = _mx.mamba2_step_pallas(
            h.reshape(Bsz, De, N), xh.reshape(Bsz, De), a_ch, dt_ch,
            B_t, C_t, D_ch, z, scale, float(eps), w_out,
            de_tile=tile, interpret=(impl == "interpret"))
        return h2.reshape(Bsz, H, P, N), out
    return _ref.mamba2_step(h, xh, dt, A_log_h, B_t, C_t, D_h, z, scale,
                            eps, w_out=w_out)


def gdn_step(S, q, k, v, a, b, z, scale, eps, *, w_out=None, impl=None):
    """Gated-DeltaNet decode step + rmsnorm/gate (and out-proj).

    S (B,H,K,V) f32; q, k (B,H,K) io; v (B,H,V) io; a, b (B,H) f32;
    z (B,H*V) io; scale (H*V,).  Returns ``(S', y|out)``.
    """
    impl = resolve_impl("gdn_step", impl)
    if impl in ("pallas", "interpret"):
        H = S.shape[1]
        tile = autotune.tile_for("gdn_step", z.dtype, H, 8)
        return _mx.gdn_step_pallas(S, q, k, v, a, b, z, scale, float(eps),
                                   w_out, h_tile=tile,
                                   interpret=(impl == "interpret"))
    return _ref.gdn_step(S, q, k, v, a, b, z, scale, eps, w_out=w_out)


def rglru_step(h, u, log_a, i_gate, *, gate=None, w_out=None, impl=None):
    """RG-LRU decode step, optionally fused with gelu-gate × out-proj.

    h (B,D) f32; u (B,D) io; log_a, i_gate (B,D) f32; gate (B,D) io +
    w_out (D,Dm) supplied together.  Returns ``(h', y|out)``.
    """
    if (gate is None) != (w_out is None):
        raise ValueError("gate and w_out must be supplied together")
    impl = resolve_impl("rglru_step", impl)
    if impl in ("pallas", "interpret"):
        tile = autotune.tile_for("rglru_step", u.dtype, h.shape[-1], 512)
        return _mx.rglru_step_pallas(h, u, log_a, i_gate, gate, w_out,
                                     d_tile=tile,
                                     interpret=(impl == "interpret"))
    return _ref.rglru_step(h, u, log_a, i_gate, gate=gate, w_out=w_out)


def mlstm_step(C, n, m, q, k, v, il, fl, z, gn_scale, eps, *, w_out=None,
               impl=None):
    """mLSTM cell update + headnorm/gate (and out-proj).

    C (B,H,K,V), n (B,H,K), m (B,H) f32; q, k (B,H,K), v (B,H,V),
    il, fl (B,H) f32; z (B,H*V) io; gn_scale (H*V,).  Returns
    ``(C', n', m', y|out)``.
    """
    impl = resolve_impl("mlstm_step", impl)
    if impl in ("pallas", "interpret"):
        H = C.shape[1]
        tile = autotune.tile_for("mlstm_step", z.dtype, H, 4)
        return _mx.mlstm_step_pallas(C, n, m, q, k, v, il, fl, z, gn_scale,
                                     float(eps), w_out, h_tile=tile,
                                     interpret=(impl == "interpret"))
    return _ref.mlstm_step(C, n, m, q, k, v, il, fl, z, gn_scale, eps,
                           w_out=w_out)


def slstm_step(c, n, h, m, gx, r_w, b, gn_scale, eps, *, w_up=None,
               w_gate=None, w_down=None, impl=None):
    """sLSTM cell update + headnorm, optionally fused with the gated FFN.

    c/n/h/m (B,H,Dh) f32; gx (B,4*inner) io; r_w (H,Dh,4Dh) f32; b the
    *flat* (4*inner,) bias (nn.xlstm layout); gn_scale (inner,).
    Returns ``(c', n', h', m', y|out)``.
    """
    ffn = (w_up is not None, w_gate is not None, w_down is not None)
    if any(ffn) and not all(ffn):
        raise ValueError("w_up, w_gate and w_down must be supplied together")
    impl = resolve_impl("slstm_step", impl)
    if impl in ("pallas", "interpret"):
        H, Dh = c.shape[1], c.shape[2]
        tile = autotune.tile_for("slstm_step", gx.dtype, H, 4)
        return _mx.slstm_step_pallas(c, n, h, m, gx, r_w,
                                     b.reshape(H, 4 * Dh), gn_scale,
                                     float(eps), w_up, w_gate, w_down,
                                     h_tile=tile,
                                     interpret=(impl == "interpret"))
    return _ref.slstm_step(c, n, h, m, gx, r_w, b, gn_scale, eps,
                           w_up=w_up, w_gate=w_gate, w_down=w_down)


def logits_step(hidden, table, *, tied, softcap=0.0, need_stats=True,
                impl=None):
    """Greedy argmax + (max, sum-exp) reductions over the final
    projection, without materializing the (B,V) logits row off-chip.

    hidden (B,D) io; table (V,D) when ``tied`` else (D,V).  Returns
    ``(argmax i32, vmax f32, sumexp f32)``, each (B,) — the argmax
    matches ``jnp.argmax`` over ``models.lm.logits_fn`` exactly
    (first-occurrence ties included), which is what the engine's greedy
    fast path rides on.  ``need_stats=False`` returns ``(argmax, None,
    None)`` and lets the jnp fallback skip the max/sum-exp reductions —
    on CPU the exp over the vocab row is real per-step cost the greedy
    path never uses, while in-kernel the stats ride the same tile pass
    for free.
    """
    impl = resolve_impl("logits_step", impl)
    if impl in ("pallas", "interpret"):
        V = table.shape[0] if tied else table.shape[1]
        tile = autotune.tile_for("logits_step", hidden.dtype, V, 1024)
        out = logits_step_pallas(hidden, table, tied=tied,
                                 softcap=float(softcap or 0.0),
                                 v_tile=tile,
                                 interpret=(impl == "interpret"))
        return out if need_stats else (out[0], None, None)
    if need_stats:
        return _ref.logits_step(hidden, table, tied=tied, softcap=softcap)
    return (_ref.logits_step_greedy(hidden, table, tied=tied,
                                    softcap=softcap), None, None)


# ---------------------------------------------------------------------------
# autotune sweeps — first real-device use of an op at an unseen
# (dtype, dim bucket) times the candidate tiles on synthetic shapes and
# commits the winner to kernels/tuning_table.json (no-ops off TPU).
# ---------------------------------------------------------------------------

def _sweep_batch():
    return 8


@autotune.register_sweep("mamba2_step")
def _sweep_mamba2(dtype, dim):
    B, N = _sweep_batch(), 128
    key = jax.random.PRNGKey(0)
    h = jnp.zeros((B, dim, N), jnp.float32)
    x = jax.random.normal(key, (B, dim), jnp.float32)
    z = x.astype(dtype)
    w = jnp.ones((dim, dim), dtype)
    one = jnp.ones((B, dim), jnp.float32)

    def run(tile):
        def f():
            return _mx.mamba2_step_pallas(
                h, x, one * 0.5, one, x[:, :N], x[:, :N], one[0], z,
                one[0], 1e-6, w, de_tile=tile)[1]
        return f
    return autotune.time_candidates(run, autotune.pow2_divisors(dim, 64))


@autotune.register_sweep("rglru_step")
def _sweep_rglru(dtype, dim):
    B = _sweep_batch()
    key = jax.random.PRNGKey(0)
    h = jnp.zeros((B, dim), jnp.float32)
    u = jax.random.normal(key, (B, dim)).astype(dtype)
    la = -jnp.ones((B, dim), jnp.float32)
    w = jnp.ones((dim, dim), dtype)

    def run(tile):
        def f():
            return _mx.rglru_step_pallas(h, u, la, -la, u, w,
                                         d_tile=tile)[1]
        return f
    return autotune.time_candidates(run, autotune.pow2_divisors(dim, 64))


@autotune.register_sweep("logits_step")
def _sweep_logits(dtype, dim):
    B, D = _sweep_batch(), 1024
    key = jax.random.PRNGKey(0)
    hdn = jax.random.normal(key, (B, D)).astype(dtype)
    tab = jax.random.normal(key, (dim, D)).astype(dtype)

    def run(tile):
        def f():
            return logits_step_pallas(hdn, tab, tied=True, v_tile=tile)[0]
        return f
    return autotune.time_candidates(run, autotune.pow2_divisors(dim, 128))
