"""Jit'd kernel wrappers behind one impl-resolution registry.

Every op is registered with the set of implementations it offers and the
off-TPU fallback of its compiled path; ``resolve_impl(name, impl)`` picks
the implementation in a single place:

  explicit ``impl=`` argument            (strongest)
  > module-level default                 (``set_default_impl`` /
                                          ``default_impl`` scope — how
                                          ``EngineConfig.kernels`` threads
                                          one choice through every jitted
                                          serving step)
  > backend auto                         ('pallas' on TPU, 'ref' elsewhere,
                                          so the dry-run compiles the
                                          mathematically identical jnp
                                          graphs and XLA cost_analysis
                                          stays meaningful; DESIGN.md §3)

Implementation names:
  'ref'       pure-jnp oracle (kernels/ref.py — the correctness gate; for
              ``routed_matmul`` this is the honest O(E×) dense-expert path)
  'pallas'    compiled Pallas TPU kernel.  Off-TPU (Mosaic cannot compile)
              each op declares a fallback: the prefill ops fall back to
              'ref'; the decode-step ops fall back to 'fused'
  'fused'     fused jnp composite of the Pallas kernel's math — the
              decode fast path on hosts without a TPU (top-k gathered
              expert GEMM instead of the O(E×) oracle)
  'interpret' Pallas kernel body executed in interpret mode (CPU tests)

The pre-registry per-op ``impl=`` keywords keep working: they are now thin
deprecation shims over ``resolve_impl`` (``_resolve`` remains as an alias
for external callers of the old helper).
"""
from __future__ import annotations

import contextlib
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.decode_step import (decode_step_fused_pallas,
                                       decode_step_pallas)
from repro.kernels.grouped_matmul import grouped_matmul_pallas
from repro.kernels.routed_matmul import routed_matmul_pallas
from repro.kernels.selective_scan import selective_scan_pallas


# ---------------------------------------------------------------------------
# impl resolution: one registry, one module-level default
# ---------------------------------------------------------------------------

class _OpSpec:
    __slots__ = ("impls", "fallback")

    def __init__(self, impls, fallback):
        self.impls = frozenset(impls)
        self.fallback = dict(fallback)   # off-TPU remap, e.g. pallas->fused


_REGISTRY: Dict[str, _OpSpec] = {}

#: module-level default implementation (None = backend auto)
_DEFAULT_IMPL: Optional[str] = None


def register_op(name: str, impls, fallback=()) -> None:
    _REGISTRY[name] = _OpSpec(impls, fallback)


def registered_ops():
    """Registered op names (docs/tests introspection)."""
    return sorted(_REGISTRY)


def set_default_impl(impl: Optional[str]) -> Optional[str]:
    """Set the module-level default implementation for every op whose call
    site passes ``impl=None``; returns the previous default.  ``None``
    restores backend auto-selection.  Re-exported as
    ``repro.kernels.set_default_impl``."""
    global _DEFAULT_IMPL
    if impl is not None:
        known = set().union(*(s.impls for s in _REGISTRY.values()))
        if impl not in known:
            raise ValueError(f"unknown kernel impl {impl!r}; "
                             f"known: {sorted(known)}")
    prev, _DEFAULT_IMPL = _DEFAULT_IMPL, impl
    return prev


def active_default() -> Optional[str]:
    """The module-level default impl, or None under backend auto."""
    return _DEFAULT_IMPL


@contextlib.contextmanager
def default_impl(impl: Optional[str]):
    """Scope ``set_default_impl(impl)`` to a ``with`` block.  The serving
    engine wraps each jitted step in this scope, so the choice is active
    exactly while jax traces the step (``EngineConfig.kernels``)."""
    prev = set_default_impl(impl)
    try:
        yield
    finally:
        set_default_impl(prev)


def resolve_impl(name: str, impl: Optional[str] = None) -> str:
    """Resolve the implementation for op ``name``: explicit ``impl`` >
    module default > backend auto; then apply the op's off-TPU fallback
    ('pallas' only compiles on TPU)."""
    spec = _REGISTRY.get(name)
    if spec is None:
        raise KeyError(f"unknown kernel op {name!r}; "
                       f"registered: {registered_ops()}")
    if impl is None:
        impl = _DEFAULT_IMPL
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if jax.default_backend() != "tpu":
        impl = spec.fallback.get(impl, impl)
    if impl not in spec.impls:
        raise ValueError(f"op {name!r} has no impl {impl!r}; "
                         f"available: {sorted(spec.impls)}")
    return impl


def _resolve(impl):
    """Deprecated pre-registry helper (use :func:`resolve_impl`): generic
    explicit/default/backend resolution without an op's fallback table."""
    if impl is None:
        impl = _DEFAULT_IMPL
    if impl is None:
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


register_op("selective_scan", ("ref", "pallas", "interpret"),
            fallback={"pallas": "ref"})
register_op("grouped_matmul", ("ref", "pallas", "interpret"),
            fallback={"pallas": "ref"})
register_op("selective_scan_step", ("ref", "fused", "pallas", "interpret"),
            fallback={"pallas": "fused"})
register_op("routed_matmul", ("ref", "fused", "pallas", "interpret"),
            fallback={"pallas": "fused"})


# ---------------------------------------------------------------------------
# prefill / training ops (signatures unchanged — deprecation shims over the
# registry)
# ---------------------------------------------------------------------------

def selective_scan(u, dt, A, Bm, Cm, D=None, *, chunk=128, impl=None,
                   acc_dtype="float32", h0=None, return_state=False):
    impl = resolve_impl("selective_scan", impl)
    if h0 is not None or return_state:
        # stateful prefill path: only the ref oracle threads/returns the
        # recurrent state (the Pallas kernel computes outputs only)
        return _ref.selective_scan_ref(u, dt, A, Bm, Cm, D, chunk=chunk,
                                       acc_dtype=acc_dtype, h0=h0,
                                       return_state=return_state)
    if impl == "ref":
        return _ref.selective_scan_ref(u, dt, A, Bm, Cm, D, chunk=chunk,
                                       acc_dtype=acc_dtype)
    y = selective_scan_pallas(u, dt, A, Bm, Cm, chunk=chunk,
                              interpret=(impl == "interpret"))
    if D is not None:
        y = (y.astype(jnp.float32)
             + u.astype(jnp.float32) * D.astype(jnp.float32)).astype(y.dtype)
    return y


def grouped_matmul(x, w, group_sizes, *, impl=None, **tiles):
    impl = resolve_impl("grouped_matmul", impl)
    if impl == "ref":
        return _ref.grouped_matmul_ref(x, w, group_sizes)
    return grouped_matmul_pallas(x, w, group_sizes,
                                 interpret=(impl == "interpret"), **tiles)


# ---------------------------------------------------------------------------
# decode-step ops (the per-slot hot path)
# ---------------------------------------------------------------------------

def selective_scan_step(h, u_t, dt_t, A, B_t, C_t, D=None, *, gate=None,
                        w_out=None, impl=None):
    """Single-timestep selective scan, optionally fused with the gating +
    output projection epilogue.

    h (B,De,N) f32; u_t, dt_t (B,De); A (De,N); B_t, C_t (B,N); D (De,).
    Without an epilogue returns ``(h', y)`` with y (B,De).  With
    ``gate`` (B,De) and ``w_out`` (De,Dm) returns ``(h', out)`` where
    ``out = dense(y * gate, w_out)`` — one kernel for the whole per-slot
    Mamba decode tail instead of scan + two elementwise passes + GEMM.
    """
    if (gate is None) != (w_out is None):
        raise ValueError("gate and w_out must be supplied together")
    impl = resolve_impl("selective_scan_step", impl)
    if impl in ("pallas", "interpret"):
        interp = impl == "interpret"
        if gate is None:
            return decode_step_pallas(h, u_t, dt_t, A, B_t, C_t, D,
                                      interpret=interp)
        return decode_step_fused_pallas(h, u_t, dt_t, A, B_t, C_t, D,
                                        gate, w_out, interpret=interp)
    # 'ref' and its off-TPU 'fused' alias share the oracle math exactly, so
    # EngineConfig(kernels="pallas") stays greedy bit-identical to "ref" on
    # hosts where the compiled kernel is unavailable.
    h2, y = _ref.selective_scan_step(h, u_t, dt_t, A, B_t, C_t, D)
    if gate is None:
        return h2, y
    from repro.nn.layers import dense
    return h2, dense(y * gate, w_out)


def routed_matmul(x, w, expert_idx, weights=None, *, impl=None):
    """Routed expert projection for decode-shaped token counts.

    x (T,D) tokens; w (E,D,F) expert weights; expert_idx (T,K) int32 top-k
    choices; weights (T,K) f32 combine weights or None (unweighted sum).
    Returns (T,F) = sum_k scale_k * (x_t @ w[expert_idx[t,k]]).

    'ref' is the O(E×) dense-expert oracle (mirrors
    ``moe_dispatch.dense_moe_linear``); 'fused'/'pallas' compute only the
    selected experts — the decode fast path that skips the capacity
    dispatch machinery (sort + offsets + gathers) entirely.
    """
    impl = resolve_impl("routed_matmul", impl)
    if impl == "ref":
        return _ref.routed_matmul_ref(x, w, expert_idx, weights)
    if impl in ("pallas", "interpret"):
        return routed_matmul_pallas(x, w, expert_idx, weights,
                                    interpret=(impl == "interpret"))
    return _ref.routed_matmul_fused(x, w, expert_idx, weights)
