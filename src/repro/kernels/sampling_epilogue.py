"""Pallas TPU kernel: fused sampling epilogue over the final projection.

Greedy decode needs only ``argmax(logits)`` — materializing the full
(B, V) logits row in HBM just to reduce it is wasted bandwidth at large
vocab.  This kernel walks the vocabulary in tiles inside the projection
itself: each (batch, vocab-tile) step contracts the hidden row against
one tile of the embedding/lm-head table, applies the logit softcap, and
combines into three running scalars per row — argmax index, max logit,
and the max-shifted sum-of-exponentials (the pair a temperature path
needs to normalize without a second pass).  Full logits never leave
VMEM.

The online argmax combine uses a strict ``>`` so ties keep the earliest
vocab index — matching ``jnp.argmax``'s first-occurrence rule (and thus
``serve.sampling.sample``'s greedy branch) exactly; the within-tile
argmax is itself first-occurrence via an iota-min.  The running sum-exp
is rescaled by ``exp(old_max - new_max)`` at every tile (classic online
softmax).  Oracle: ``kernels/ref.py::logits_step``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _softcap(x, cap):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def _kernel(h_ref, t_ref, idx_ref, max_ref, sum_ref, b_ref, s_ref, a_ref,
            *, nv, v_tile, tied, cap):
    d = pl.program_id(1)

    @pl.when(d == 0)
    def _init():
        b_ref[...] = jnp.full_like(b_ref, -jnp.inf)
        s_ref[...] = jnp.zeros_like(s_ref)
        a_ref[...] = jnp.zeros_like(a_ref)

    f32 = jnp.float32
    hrow = h_ref[...]                                     # (1,D) io
    tab = t_ref[...].astype(hrow.dtype)
    if tied:
        tab = tab.T                                       # (D, v_tile)
    lt = _softcap(jnp.dot(hrow, tab, preferred_element_type=f32),
                  cap).astype(f32)                        # (1, v_tile)
    tmax = jnp.max(lt)
    # first-occurrence within-tile argmax via iota-min (1D argmax needs a
    # 2D iota on TPU anyway)
    iota = jax.lax.broadcasted_iota(jnp.int32, lt.shape, 1)
    targ = jnp.min(jnp.where(lt == tmax, iota, v_tile))
    best = b_ref[0, 0]
    new_best = jnp.maximum(best, tmax)
    a_ref[...] = jnp.where(tmax > best, d * v_tile + targ,
                           a_ref[0, 0]).reshape(1, 1)
    s_ref[...] = (s_ref[0, 0] * jnp.exp(best - new_best)
                  + jnp.sum(jnp.exp(lt - new_best))).reshape(1, 1)
    b_ref[...] = new_best.reshape(1, 1)

    @pl.when(d == nv - 1)
    def _write():
        idx_ref[...] = a_ref[...]
        max_ref[...] = b_ref[...]
        sum_ref[...] = s_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("tied", "softcap", "v_tile",
                                    "interpret"))
def logits_step_pallas(hidden, table, *, tied, softcap=0.0, v_tile=1024,
                       interpret=False):
    """(argmax (B,) i32, vmax (B,) f32, sumexp (B,) f32).

    hidden (B,D) io; table (V,D) when ``tied`` (embedding reused as the
    output head) else (D,V); ``softcap`` the static logit softcap.
    """
    Bsz, D = hidden.shape
    V = table.shape[0] if tied else table.shape[1]
    nv = V // v_tile
    t_spec = (pl.BlockSpec((v_tile, D), lambda b, d: (d, 0)) if tied
              else pl.BlockSpec((D, v_tile), lambda b, d: (0, d)))
    idx, vmax, sumexp = pl.pallas_call(
        functools.partial(_kernel, nv=nv, v_tile=v_tile, tied=tied,
                          cap=softcap),
        grid=(Bsz, nv),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, d: (b, 0)),
            t_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda b, d: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, d: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, d: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, 1), jnp.int32),
            jax.ShapeDtypeStruct((Bsz, 1), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.int32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(hidden, table)
    return idx[:, 0], vmax[:, 0], sumexp[:, 0]
