"""Pure-jnp oracles for every Pallas kernel.

These are also the paths the multi-pod dry-run compiles (XLA cost_analysis is
blind inside Pallas custom-calls, so roofline FLOPs/bytes come from these
mathematically identical graphs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Mamba selective scan
#   h_t = exp(dt_t * A) * h_{t-1} + (dt_t * u_t) * B_t      (outer over N)
#   y_t = <C_t, h_t> + D * u_t
# shapes: u,dt (B,S,De); A (De,N); Bm,Cm (B,S,N); D (De,)
# ---------------------------------------------------------------------------

def selective_scan_ref(u, dt, A, Bm, Cm, D=None, *, chunk=128, h0=None,
                       return_state=False, acc_dtype=jnp.float32):
    Bsz, S, De = u.shape
    N = A.shape[-1]
    dtype = u.dtype
    chunk = min(chunk, S)
    if S % chunk != 0:
        pad = chunk - S % chunk
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = u.shape[1]
    nc = Sp // chunk

    f32 = jnp.dtype(acc_dtype)
    uc = u.reshape(Bsz, nc, chunk, De).astype(f32)
    dtc = dt.reshape(Bsz, nc, chunk, De).astype(f32)
    Bc = Bm.reshape(Bsz, nc, chunk, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, chunk, N).astype(f32)
    A = A.astype(f32)

    if h0 is None:
        h0 = jnp.zeros((Bsz, De, N), f32)
    else:
        h0 = h0.astype(f32)

    def per_chunk(h, xs):
        ucx, dtx, bx, cx = xs                      # (B, chunk, ...)
        a = jnp.exp(dtx[..., None] * A)            # (B,c,De,N), entries in (0,1]
        b = (dtx * ucx)[..., None] * bx[:, :, None, :]   # (B,c,De,N)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        A_cum, B_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
        hs = A_cum * h[:, None] + B_cum            # (B,c,De,N)
        y = jnp.einsum("bcdn,bcn->bcd", hs, cx)
        return hs[:, -1], y

    from repro.nn.layers import cost_scan
    h_last, ys = cost_scan(
        per_chunk, h0,
        (uc.transpose(1, 0, 2, 3), dtc.transpose(1, 0, 2, 3),
         Bc.transpose(1, 0, 2, 3), Cc.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3).reshape(Bsz, Sp, De)[:, :S]
    if D is not None:
        y = y + u[:, :S].astype(f32) * D.astype(f32)
    y = y.astype(dtype)
    if return_state:
        return y, h_last
    return y


def selective_scan_step(h, u_t, dt_t, A, B_t, C_t, D=None):
    """Single decode step. h (B,De,N); u_t,dt_t (B,De); B_t,C_t (B,N)."""
    f32 = jnp.float32
    a = jnp.exp(dt_t.astype(f32)[..., None] * A.astype(f32))
    h = a * h + (dt_t * u_t).astype(f32)[..., None] * B_t.astype(f32)[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, C_t.astype(f32))
    if D is not None:
        y = y + u_t.astype(f32) * D.astype(f32)
    return h, y.astype(u_t.dtype)


def selective_scan_naive(u, dt, A, Bm, Cm, D=None):
    """Step-by-step lax.scan oracle (slowest, most obviously correct)."""
    def step(h, xs):
        u_t, dt_t, b_t, c_t = xs
        h, y = selective_scan_step(h, u_t, dt_t, A, b_t, c_t, D)
        return h, y
    Bsz, S, De = u.shape
    h0 = jnp.zeros((Bsz, De, A.shape[-1]), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (u.transpose(1, 0, 2), dt.transpose(1, 0, 2),
                                    Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2)


# ---------------------------------------------------------------------------
# Diagonal linear recurrence  h_t = a_t * h_{t-1} + b_t   (RG-LRU, decays)
# a, b (B, S, D); log_a given for stability. Chunked like selective_scan_ref.
# ---------------------------------------------------------------------------

def diag_recurrence(log_a, b, *, chunk=256, h0=None, return_state=False):
    Bsz, S, D = b.shape
    dtype = b.dtype
    f32 = jnp.float32
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    ac = log_a.reshape(Bsz, nc, chunk, D).astype(f32)
    bc = b.reshape(Bsz, nc, chunk, D).astype(f32)
    if h0 is None:
        h0 = jnp.zeros((Bsz, D), f32)
    else:
        h0 = h0.astype(f32)

    def per_chunk(h, xs):
        ax, bx = xs

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al + ar, jnp.exp(ar) * bl + br

        A_cum, B_cum = jax.lax.associative_scan(combine, (ax, bx), axis=1)
        hs = jnp.exp(A_cum) * h[:, None] + B_cum
        return hs[:, -1], hs

    from repro.nn.layers import cost_scan
    h_last, ys = cost_scan(per_chunk, h0,
                           (ac.transpose(1, 0, 2, 3),
                            bc.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3).reshape(Bsz, Sp, D)[:, :S].astype(dtype)
    if return_state:
        return y, h_last
    return y


# ---------------------------------------------------------------------------
# Grouped (ragged) matmul — MegaBlocks-for-TPU oracle.
# x (E,C,D) capacity-padded tokens per expert; w (E,D,F); group_sizes (E,)
# rows c >= group_sizes[e] are padding and produce zeros.
# ---------------------------------------------------------------------------

def grouped_matmul_ref(x, w, group_sizes):
    """x may carry G*E groups against E weights (expert = group % E),
    mirroring the Pallas kernel's modulo weight-block mapping."""
    GE, C, D = x.shape
    E = w.shape[0]
    mask = (jnp.arange(C)[None, :] < group_sizes[:, None])  # (GE,C)
    xg = x.reshape(GE // E, E, C, D)
    y = jnp.einsum("gecd,edf->gecf", xg.astype(jnp.float32),
                   w.astype(jnp.float32),
                   preferred_element_type=jnp.float32).reshape(GE, C, -1)
    y = jnp.where(mask[..., None], y, 0.0)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Routed expert projection (decode-shaped token counts).
# x (T,D); w (E,D,F); expert_idx (T,K) int32; weights (T,K) f32 or None.
# ---------------------------------------------------------------------------

def routed_matmul_ref(x, w, expert_idx, weights=None):
    """O(E×) dense-expert oracle: compute every expert for every token,
    then mix with a one-hot (optionally weighted) selection.  Same float
    composition as ``moe_dispatch.dense_moe_linear`` so it doubles as the
    correctness gate for the capacity dispatch path."""
    E = w.shape[0]
    y_all = jnp.einsum("td,edf->tef", x, w.astype(x.dtype),
                       preferred_element_type=jnp.float32)  # (T,E,F) f32
    sel = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (T,K,E)
    if weights is not None:
        sel = sel * weights.astype(jnp.float32)[..., None]
    mix = sel.sum(axis=1)                                   # (T,E)
    return jnp.einsum("tef,te->tf", y_all, mix).astype(x.dtype)


def routed_matmul_fused(x, w, expert_idx, weights=None):
    """Top-k gathered composite — the decode fast path on hosts without a
    TPU: gather only the K selected expert matrices per token and contract
    once, skipping both the O(E×) oracle compute and the capacity dispatch
    machinery (sort + offsets + scatter/gather)."""
    T, K = expert_idx.shape
    w_sel = jnp.take(w.astype(x.dtype), expert_idx.reshape(-1),
                     axis=0).reshape(T, K, *w.shape[1:])     # (T,K,D,F)
    y = jnp.einsum("td,tkdf->tkf", x, w_sel,
                   preferred_element_type=jnp.float32)       # (T,K,F) f32
    if weights is not None:
        y = y * weights.astype(jnp.float32)[..., None]
    return y.sum(axis=1).astype(x.dtype)
