"""Pure-jnp oracles for every Pallas kernel.

These are also the paths the multi-pod dry-run compiles (XLA cost_analysis is
blind inside Pallas custom-calls, so roofline FLOPs/bytes come from these
mathematically identical graphs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Mamba selective scan
#   h_t = exp(dt_t * A) * h_{t-1} + (dt_t * u_t) * B_t      (outer over N)
#   y_t = <C_t, h_t> + D * u_t
# shapes: u,dt (B,S,De); A (De,N); Bm,Cm (B,S,N); D (De,)
# ---------------------------------------------------------------------------

def selective_scan_ref(u, dt, A, Bm, Cm, D=None, *, chunk=128, h0=None,
                       return_state=False, acc_dtype=jnp.float32):
    Bsz, S, De = u.shape
    N = A.shape[-1]
    dtype = u.dtype
    chunk = min(chunk, S)
    if S % chunk != 0:
        pad = chunk - S % chunk
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = u.shape[1]
    nc = Sp // chunk

    f32 = jnp.dtype(acc_dtype)
    uc = u.reshape(Bsz, nc, chunk, De).astype(f32)
    dtc = dt.reshape(Bsz, nc, chunk, De).astype(f32)
    Bc = Bm.reshape(Bsz, nc, chunk, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, chunk, N).astype(f32)
    A = A.astype(f32)

    if h0 is None:
        h0 = jnp.zeros((Bsz, De, N), f32)
    else:
        h0 = h0.astype(f32)

    def per_chunk(h, xs):
        ucx, dtx, bx, cx = xs                      # (B, chunk, ...)
        a = jnp.exp(dtx[..., None] * A)            # (B,c,De,N), entries in (0,1]
        b = (dtx * ucx)[..., None] * bx[:, :, None, :]   # (B,c,De,N)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        A_cum, B_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
        hs = A_cum * h[:, None] + B_cum            # (B,c,De,N)
        y = jnp.einsum("bcdn,bcn->bcd", hs, cx)
        return hs[:, -1], y

    from repro.nn.layers import cost_scan
    h_last, ys = cost_scan(
        per_chunk, h0,
        (uc.transpose(1, 0, 2, 3), dtc.transpose(1, 0, 2, 3),
         Bc.transpose(1, 0, 2, 3), Cc.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3).reshape(Bsz, Sp, De)[:, :S]
    if D is not None:
        y = y + u[:, :S].astype(f32) * D.astype(f32)
    y = y.astype(dtype)
    if return_state:
        return y, h_last
    return y


def selective_scan_step(h, u_t, dt_t, A, B_t, C_t, D=None):
    """Single decode step. h (B,De,N); u_t,dt_t (B,De); B_t,C_t (B,N)."""
    f32 = jnp.float32
    a = jnp.exp(dt_t.astype(f32)[..., None] * A.astype(f32))
    h = a * h + (dt_t * u_t).astype(f32)[..., None] * B_t.astype(f32)[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, C_t.astype(f32))
    if D is not None:
        y = y + u_t.astype(f32) * D.astype(f32)
    return h, y.astype(u_t.dtype)


def selective_scan_naive(u, dt, A, Bm, Cm, D=None):
    """Step-by-step lax.scan oracle (slowest, most obviously correct)."""
    def step(h, xs):
        u_t, dt_t, b_t, c_t = xs
        h, y = selective_scan_step(h, u_t, dt_t, A, b_t, c_t, D)
        return h, y
    Bsz, S, De = u.shape
    h0 = jnp.zeros((Bsz, De, A.shape[-1]), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (u.transpose(1, 0, 2), dt.transpose(1, 0, 2),
                                    Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2)


# ---------------------------------------------------------------------------
# Diagonal linear recurrence  h_t = a_t * h_{t-1} + b_t   (RG-LRU, decays)
# a, b (B, S, D); log_a given for stability. Chunked like selective_scan_ref.
# ---------------------------------------------------------------------------

def diag_recurrence(log_a, b, *, chunk=256, h0=None, return_state=False):
    Bsz, S, D = b.shape
    dtype = b.dtype
    f32 = jnp.float32
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    ac = log_a.reshape(Bsz, nc, chunk, D).astype(f32)
    bc = b.reshape(Bsz, nc, chunk, D).astype(f32)
    if h0 is None:
        h0 = jnp.zeros((Bsz, D), f32)
    else:
        h0 = h0.astype(f32)

    def per_chunk(h, xs):
        ax, bx = xs

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al + ar, jnp.exp(ar) * bl + br

        A_cum, B_cum = jax.lax.associative_scan(combine, (ax, bx), axis=1)
        hs = jnp.exp(A_cum) * h[:, None] + B_cum
        return hs[:, -1], hs

    from repro.nn.layers import cost_scan
    h_last, ys = cost_scan(per_chunk, h0,
                           (ac.transpose(1, 0, 2, 3),
                            bc.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3).reshape(Bsz, Sp, D)[:, :S].astype(dtype)
    if return_state:
        return y, h_last
    return y


# ---------------------------------------------------------------------------
# Grouped (ragged) matmul — MegaBlocks-for-TPU oracle.
# x (E,C,D) capacity-padded tokens per expert; w (E,D,F); group_sizes (E,)
# rows c >= group_sizes[e] are padding and produce zeros.
# ---------------------------------------------------------------------------

def grouped_matmul_ref(x, w, group_sizes):
    """x may carry G*E groups against E weights (expert = group % E),
    mirroring the Pallas kernel's modulo weight-block mapping."""
    GE, C, D = x.shape
    E = w.shape[0]
    mask = (jnp.arange(C)[None, :] < group_sizes[:, None])  # (GE,C)
    xg = x.reshape(GE // E, E, C, D)
    y = jnp.einsum("gecd,edf->gecf", xg.astype(jnp.float32),
                   w.astype(jnp.float32),
                   preferred_element_type=jnp.float32).reshape(GE, C, -1)
    y = jnp.where(mask[..., None], y, 0.0)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Routed expert projection (decode-shaped token counts).
# x (T,D); w (E,D,F); expert_idx (T,K) int32; weights (T,K) f32 or None.
# ---------------------------------------------------------------------------

def routed_matmul_ref(x, w, expert_idx, weights=None):
    """O(E×) dense-expert oracle: compute every expert for every token,
    then mix with a one-hot (optionally weighted) selection.  Same float
    composition as ``moe_dispatch.dense_moe_linear`` so it doubles as the
    correctness gate for the capacity dispatch path."""
    E = w.shape[0]
    y_all = jnp.einsum("td,edf->tef", x, w.astype(x.dtype),
                       preferred_element_type=jnp.float32)  # (T,E,F) f32
    sel = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (T,K,E)
    if weights is not None:
        sel = sel * weights.astype(jnp.float32)[..., None]
    mix = sel.sum(axis=1)                                   # (T,E)
    return jnp.einsum("tef,te->tf", y_all, mix).astype(x.dtype)


# ---------------------------------------------------------------------------
# Per-mixer single-timestep decode oracles (the phase-2 fused-step family).
#
# Each function is the exact float composition of the corresponding
# ``nn/*`` step — same cast order term for term — factored out so the
# Pallas kernels in kernels/mixer_steps.py have a bitwise gate, and so
# the off-TPU 'fused' impl can share this math verbatim (greedy decode
# stays bit-identical across EngineConfig kernels= choices on CPU).
# Epilogue keywords fold the mixer's gate/out-projection tail into the
# same op, mirroring ``selective_scan_step(gate=, w_out=)``.
# ---------------------------------------------------------------------------

def _headnorm(y, scale, eps):
    """Per-head RMS norm then flatten — replicates ``nn.xlstm._headnorm``
    (kept local: importing nn.xlstm here would cycle through ops.py)."""
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yn = yf * jax.lax.rsqrt(var + eps)
    return yn.reshape(*y.shape[:-2], -1) * scale


def mamba2_step(h, xh, dt, A_log_h, B_t, C_t, D_h, z, scale, eps, *,
                w_out=None):
    """Mamba-2 SSD decode step (scalar decay per head) + norm/gate tail.

    h (B,H,P,N) f32 carried state; xh (B,H,P) f32 pre-split conv'd input;
    dt (B,H) f32 softplus'd step; A_log_h (H,); B_t, C_t (B,N); D_h (H,);
    z (B,De) io-dtype gate; scale (De,) inner-rmsnorm scale.  Returns
    ``(h', y)`` with y (B,De) io, or ``(h', out)`` (B,Dm) when ``w_out``
    (De,Dm) folds the output projection in.
    """
    from repro.nn.layers import dense, rmsnorm, silu
    f32 = jnp.float32
    a = jnp.exp(dt * -jnp.exp(A_log_h))                        # (B,H)
    h = (h * a[..., None, None]
         + jnp.einsum("bhp,bn,bh->bhpn", xh, B_t.astype(f32), dt))
    y = jnp.einsum("bhpn,bn->bhp", h, C_t.astype(f32))
    y = y + xh * D_h[:, None]
    y = y.reshape(y.shape[0], -1).astype(z.dtype)
    y = rmsnorm({"scale": scale}, y * silu(z), eps)
    if w_out is None:
        return h, y
    return h, dense(y, w_out)


def gdn_step(S, q, k, v, a, b, z, scale, eps, *, w_out=None):
    """Gated DeltaNet decode step (delta-rule state update) + norm/gate.

    S (B,H,K,V) f32 carried state; q, k (B,H,K) io L2-normalized; v (B,H,V)
    io; a, b (B,H) f32 decay/write gates; z (B,Dv) io gate; scale (Dv,).
    Returns ``(S', y)`` y (B,Dv) io, or ``(S', out)`` with ``w_out``.
    """
    from repro.nn.layers import dense, rmsnorm, silu
    f32 = jnp.float32
    Sk = jnp.einsum("bhkv,bhk->bhv", S, k.astype(f32))
    S = (S * a[..., None, None]
         - jnp.einsum("bhk,bhv->bhkv", (k * (a * b)[..., None]).astype(f32),
                      Sk)
         + jnp.einsum("bhk,bhv->bhkv", (k * b[..., None]).astype(f32),
                      v.astype(f32)))
    y = jnp.einsum("bhkv,bhk->bhv", S, q.astype(f32))
    y = y.reshape(y.shape[0], -1)
    y = rmsnorm({"scale": scale}, y.astype(z.dtype) * silu(z), eps)
    if w_out is None:
        return S, y
    return S, dense(y, w_out)


def rglru_step(h, u, log_a, i_gate, *, gate=None, w_out=None):
    """RG-LRU decode step, optionally fused with the gelu-gate × out-proj.

    h (B,D) f32 carried state; u (B,D) io conv'd input; log_a, i_gate
    (B,D) f32 gates.  Returns ``(h', y)`` y (B,D) io, or ``(h', out)``
    where ``out = dense(y * gate, w_out)`` (gate (B,D) io, w_out (D,Dm)).
    """
    if (gate is None) != (w_out is None):
        raise ValueError("gate and w_out must be supplied together")
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-6))
    h = a * h + mult * i_gate * u.astype(jnp.float32)
    y = h.astype(u.dtype)
    if gate is None:
        return h, y
    from repro.nn.layers import dense
    return h, dense(y * gate, w_out)


def mlstm_step(C, n, m, q, k, v, il, fl, z, gn_scale, eps, *, w_out=None):
    """mLSTM matrix-memory cell update + headnorm/gate tail.

    C (B,H,K,V), n (B,H,K), m (B,H) f32 carried state; q, k (B,H,K) f32
    (k pre-scaled by dqk**-0.5); v (B,H,V) f32; il, fl (B,H) f32 log
    gates; z (B,inner) io gate; gn_scale (inner,).  Returns
    ``(C', n', m', y)`` y (B,inner) io, or ``(C', n', m', out)``.
    """
    from repro.nn.layers import dense, silu
    m_new = jnp.maximum(fl + m, il)
    fpx = jnp.exp(fl + m - m_new)
    ipx = jnp.exp(il - m_new)
    C = (fpx[..., None, None] * C
         + ipx[..., None, None] * (k[..., :, None] * v[..., None, :]))
    n = fpx[..., None] * n + ipx[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", C, q)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, q))
    y = num / jnp.maximum(den, 1.0)[..., None]
    y = _headnorm(y, gn_scale, eps).astype(z.dtype) * silu(z)
    if w_out is None:
        return C, n, m_new, y
    return C, n, m_new, dense(y, w_out)


def slstm_step(c, n, h, m, gx, r_w, b, gn_scale, eps, *, w_up=None,
               w_gate=None, w_down=None):
    """sLSTM scalar-memory cell update + headnorm, optionally fused with
    the block's gated-FFN tail.

    c, n, h, m (B,H,Dh) f32 carried state; gx (B,4*inner) io pre-gates;
    r_w (H,Dh,4Dh) f32 recurrent weights; b (4*inner,) flat bias —
    reshaped ``(H, 4*Dh)`` exactly like ``nn.xlstm._slstm_cell`` (the
    historical layout quirk is the gated behaviour); gn_scale (inner,).
    Returns ``(c', n', h', m', y)`` y (B,inner) io, or with all three of
    ``w_up``/``w_gate``/``w_down`` the fused
    ``dense(dense(y, w_up) * silu(dense(y, w_gate)), w_down)``.
    """
    from repro.nn.layers import dense, silu
    ffn = (w_up is not None, w_gate is not None, w_down is not None)
    if any(ffn) and not all(ffn):
        raise ValueError("w_up, w_gate and w_down must be supplied together")
    nh, dh = r_w.shape[0], r_w.shape[1]
    rec = jnp.einsum("bhd,hdg->bhg", h, r_w)                   # (B,H,4Dh)
    g = (gx.reshape(-1, nh, 4 * dh).astype(jnp.float32) + rec
         + b.reshape(nh, 4 * dh))
    il, fp, zz, o = jnp.split(g, 4, axis=-1)                   # (B,H,Dh)
    fl = -jax.nn.softplus(-fp)
    m_new = jnp.maximum(fl + m, il)
    i = jnp.exp(il - m_new)
    f = jnp.exp(fl + m - m_new)
    c_new = f * c + i * jnp.tanh(zz)
    n_new = f * n + i
    h_new = jax.nn.sigmoid(o) * c_new / jnp.maximum(n_new, 1.0)
    y = _headnorm(h_new, gn_scale, eps).astype(gx.dtype)
    if w_up is None:
        return c_new, n_new, h_new, m_new, y
    u = dense(y, w_up) * silu(dense(y, w_gate))
    return c_new, n_new, h_new, m_new, dense(u, w_down)


def _logits_f32(hidden, table, tied, softcap):
    """The exact f32 logits row ``models.lm.logits_fn`` produces for one
    decode position — same einsum *form* (singleton seq axis and all),
    softcap, and cast order, so the result is bit-for-bit identical and
    XLA compiles the identical dot (on CPU the 2-D ``bd,vd`` spelling of
    the same contraction picks a ~2x slower emitter layout)."""
    from repro.nn.layers import softcap as _softcap
    eq = "bsd,vd->bsv" if tied else "bsd,dv->bsv"
    logits = jnp.einsum(eq, hidden[:, None, :], table.astype(hidden.dtype),
                        preferred_element_type=jnp.float32)[:, 0]
    return _softcap(logits, softcap).astype(jnp.float32)


def logits_step(hidden, table, *, tied, softcap=0.0):
    """Greedy / temperature-ready reductions over the final projection.

    hidden (B,D) io; table (V,D) when ``tied`` (embedding reused) else
    (D,V).  Returns ``(argmax (B,) i32, vmax (B,) f32, sumexp (B,) f32)``
    — the argmax matches ``sample()``'s unfiltered greedy branch over
    ``models.lm.logits_fn`` bit-for-bit (same einsum/softcap/f32 casts,
    same first-occurrence tie rule), and (vmax, sumexp) are the max /
    sum-exp-shifted-by-max reductions a temperature path needs.
    """
    lf = _logits_f32(hidden, table, tied, softcap)
    vmax = jnp.max(lf, axis=-1)
    sumexp = jnp.sum(jnp.exp(lf - vmax[:, None]), axis=-1)
    return jnp.argmax(lf, axis=-1).astype(jnp.int32), vmax, sumexp


def logits_step_greedy(hidden, table, *, tied, softcap=0.0):
    """Argmax-only variant of :func:`logits_step` — identical token, no
    max/sum-exp reductions (the greedy fallback path's per-step saving)."""
    lf = _logits_f32(hidden, table, tied, softcap)
    return jnp.argmax(lf, axis=-1).astype(jnp.int32)


def routed_matmul_fused(x, w, expert_idx, weights=None):
    """Top-k gathered composite — the decode fast path on hosts without a
    TPU: gather only the K selected expert matrices per token and contract
    once, skipping both the O(E×) oracle compute and the capacity dispatch
    machinery (sort + offsets + scatter/gather)."""
    T, K = expert_idx.shape
    w_sel = jnp.take(w.astype(x.dtype), expert_idx.reshape(-1),
                     axis=0).reshape(T, K, *w.shape[1:])     # (T,K,D,F)
    y = jnp.einsum("td,tkdf->tkf", x, w_sel,
                   preferred_element_type=jnp.float32)       # (T,K,F) f32
    if weights is not None:
        y = y * weights.astype(jnp.float32)[..., None]
    return y.sum(axis=1).astype(x.dtype)
