"""Tile-shape autotuner for the decode-step Pallas kernel family.

Every fused-step kernel takes a tile size (channel / head / vocab tile);
the right value depends on the device generation and the model's
head/state dims.  Rather than hard-coding interpret-mode defaults, the
kernels ask :func:`tile_for` at trace time:

  * off TPU (CPU CI, interpret mode) -> the static default, always —
    CPU timings say nothing about a TPU's VMEM/MXU tradeoffs, so the
    table is never consulted or written there;
  * on TPU -> look up the committed tuning table
    (``kernels/tuning_table.json``) under the key
    ``"{op}/{dtype}/{pow2-bucket(dim)}"``; on a miss, run the op's
    registered sweep (synthetic shapes, best-of wall clock over the
    candidate tiles) once, record the winner into the table, and use it
    from then on.

The table is committed: refresh it on a real device with

    PYTHONPATH=src python -m repro.kernels.autotune

which sweeps every registered op over the standard dim buckets and
rewrites the JSON (it refuses to run off-TPU instead of recording
garbage).  Buckets are powers of two so one sweep covers every model
whose dim rounds to the same bucket.
"""
from __future__ import annotations

import json
import math
import pathlib
import time
from typing import Callable, Dict, Optional

import jax

TABLE_PATH = pathlib.Path(__file__).with_name("tuning_table.json")

_table: Optional[dict] = None

#: op name -> sweep callable ``(dtype, dim) -> winning tile``
_SWEEPS: Dict[str, Callable] = {}


def bucket(n: int) -> int:
    """Round a head/state/vocab dim up to its power-of-two bucket."""
    n = int(n)
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def table_key(op: str, dtype, dim: int) -> str:
    import jax.numpy as jnp
    return f"{op}/{jnp.dtype(dtype).name}/{bucket(dim)}"


def _load() -> dict:
    global _table
    if _table is None:
        try:
            _table = json.loads(TABLE_PATH.read_text())
        except (OSError, ValueError):
            _table = {"version": 1, "entries": {}}
    return _table


def _clamp(tile: int, dim: int) -> int:
    """Largest divisor of ``dim`` not exceeding ``tile`` (tiles must
    divide the dim exactly; gcd keeps the pow2 structure)."""
    return math.gcd(max(int(tile), 1), int(dim)) or int(dim)


def tile_for(op: str, dtype, dim: int, default: int) -> int:
    """Resolve the tile size for ``op`` at trace time.

    Returns the clamped static ``default`` off-TPU; on TPU consults the
    committed table and, on a miss, runs the op's registered sweep once
    and records the winner.
    """
    default = _clamp(default, dim)
    if jax.default_backend() != "tpu":
        return default
    entry = _load()["entries"].get(table_key(op, dtype, dim))
    if entry is not None:
        return _clamp(entry["tile"], dim)
    sweep = _SWEEPS.get(op)
    if sweep is None:
        return default
    tile = _clamp(sweep(dtype, dim), dim)
    record(op, dtype, dim, tile)
    return tile


def record(op: str, dtype, dim: int, tile: int,
           path: Optional[pathlib.Path] = None) -> None:
    """Write one winner into the (in-memory and on-disk) tuning table."""
    tab = _load()
    tab["entries"][table_key(op, dtype, dim)] = {"tile": int(tile)}
    target = path or TABLE_PATH
    try:
        target.write_text(json.dumps(tab, indent=2, sort_keys=True) + "\n")
    except OSError:
        pass                        # read-only checkout: keep the in-memory win


def register_sweep(op: str):
    """Decorator registering ``(dtype, dim) -> tile`` sweep for an op."""
    def deco(fn):
        _SWEEPS[op] = fn
        return fn
    return deco


def time_candidates(run: Callable[[int], Callable[[], object]],
                    candidates, *, iters: int = 10) -> int:
    """Best-of wall-clock over candidate tiles.  ``run(tile)`` returns a
    nullary compiled callable; the fastest tile wins."""
    best_tile, best_t = None, float("inf")
    for tile in candidates:
        try:
            fn = run(tile)
            fn()                                    # compile + warm
            t0 = time.perf_counter()
            for _ in range(iters):
                out = fn()
            jax.block_until_ready(out)
            dt = (time.perf_counter() - t0) / iters
        except Exception:
            continue                                # tile doesn't lower: skip
        if dt < best_t:
            best_tile, best_t = tile, dt
    if best_tile is None:
        raise RuntimeError("no candidate tile compiled")
    return best_tile


def pow2_divisors(dim: int, lo: int = 8):
    """Power-of-two tile candidates dividing ``dim``."""
    out = []
    t = 1
    while t <= dim:
        if dim % t == 0 and t >= lo:
            out.append(t)
        t <<= 1
    return out or [dim]


def main(argv=None) -> int:
    """Refresh the committed table on a real device (refuses off-TPU)."""
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dims", type=int, nargs="*", default=[256, 512, 1024,
                                                           2048, 4096],
                    help="feature-dim buckets to sweep per op")
    ap.add_argument("--dtypes", nargs="*", default=["float32", "bfloat16"])
    args = ap.parse_args(argv)
    if jax.default_backend() != "tpu":
        print("autotune: no TPU backend — interpret/CPU runs use static "
              "defaults; run this on a real device to refresh "
              f"{TABLE_PATH.name}")
        return 1
    from repro.kernels import ops as _ops            # registers the sweeps
    del _ops
    for op, sweep in sorted(_SWEEPS.items()):
        for dtype in args.dtypes:
            for dim in args.dims:
                tile = _clamp(sweep(dtype, dim), dim)
                record(op, dtype, dim, tile)
                print(f"{table_key(op, dtype, dim)} -> tile {tile}")
    print(f"autotune: wrote {TABLE_PATH}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
