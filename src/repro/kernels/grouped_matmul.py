"""Pallas TPU kernel: grouped (ragged) GEMM — MegaBlocks adapted for TPU.

MegaBlocks builds block-sparse CUDA GEMMs from a CSR topology.  The TPU-native
formulation: tokens arrive capacity-padded per expert, ``x (E, C, D)`` with
``group_sizes (E,)`` live rows per expert; the grid tiles (token tiles x F
tiles x K tiles) with MXU-aligned blocks, each token tile statically mapping
to its expert's weight block (C is a multiple of the token tile, so a tile
never spans experts).  Tiles whose rows are entirely padding skip their MXU
work (`pl.when`), which recovers MegaBlocks' dropless-sparsity compute saving;
group_sizes ride in scalar-prefetch SMEM.

Accumulation over K runs in a VMEM f32 scratch; the masked result is written
on the last K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(gs_ref, x_ref, w_ref, o_ref, acc_ref, *, tc, cap, nk):
    i = pl.program_id(0)
    k = pl.program_id(2)
    e = (i * tc) // cap
    row0 = (i * tc) % cap
    size = gs_ref[e]

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(row0 < size)          # tile has >= 1 live row: do the MXU work
    def _compute():
        acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                                w_ref[0].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _write():
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 0)
        o_ref[...] = jnp.where(rows < size, acc_ref[...], 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("tile_c", "tile_f", "tile_k", "interpret"))
def grouped_matmul_pallas(x, w, group_sizes, *, tile_c=128, tile_f=128,
                          tile_k=128, interpret=False):
    """x (E,C,D) @ w (E,D,F) ragged by group_sizes -> (E,C,F).

    ``x`` may also carry ``G*E`` groups (``group_sizes (G*E,)``) against
    ``E`` weights: token tiles map to their expert's weight block modulo
    ``E``, so callers with multiple dispatch groups per expert (MoE
    capacity buffers grouped over the data mesh) never materialize a
    G-fold broadcast of the weights.
    """
    E, C, D = x.shape
    Ew, _, F = w.shape
    if E % Ew != 0:
        raise ValueError(f"x carries {E} groups, not a multiple of the "
                         f"{Ew} experts in w")
    tile_c = min(tile_c, C)
    tile_f = min(tile_f, F)
    tile_k = min(tile_k, D)

    def pad_to(a, axis, mult):
        r = (-a.shape[axis]) % mult
        if r == 0:
            return a
        pads = [(0, 0)] * a.ndim
        pads[axis] = (0, r)
        return jnp.pad(a, pads)

    xp = pad_to(pad_to(x, 1, tile_c), 2, tile_k)
    wp = pad_to(pad_to(w, 1, tile_k), 2, tile_f)
    Ep, Cp, Dp = xp.shape
    Fp = wp.shape[2]
    xf = xp.reshape(E * Cp, Dp)
    nk = Dp // tile_k
    grid = (E * Cp // tile_c, Fp // tile_f, nk)

    kern = functools.partial(_kernel, tc=tile_c, cap=Cp, nk=nk)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tile_c, tile_k), lambda i, j, k, gs: (i, k)),
                pl.BlockSpec((1, tile_k, tile_f),
                             lambda i, j, k, gs:
                             (((i * tile_c) // Cp) % Ew, k, j)),
            ],
            out_specs=pl.BlockSpec((tile_c, tile_f),
                                   lambda i, j, k, gs: (i, j)),
            scratch_shapes=[pltpu.VMEM((tile_c, tile_f), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((E * Cp, Fp), x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(group_sizes.astype(jnp.int32), xf, wp)
    return out.reshape(E, Cp, Fp)[:, :C, :F]
