"""Pallas TPU kernel: single-timestep selective-scan decode step.

Decode advances every slot by one token, so the prefill kernel's
sequence-chunk pipeline degenerates to a single VPU recurrence update per
(batch, De-tile) cell.  The fused variant keeps going inside the same
kernel: the SiLU-gated elementwise product and the output projection GEMM
run on the state tile while it is still resident in VMEM, accumulating the
(1, Dm) output row across De tiles in an f32 scratch — one kernel launch
for the whole per-slot Mamba decode tail instead of scan + two elementwise
passes + GEMM (cf. BlackMamba's fused MoE-SSM inference step).

Grid: (batch, De tiles) — De tiles innermost/sequential for the fused
variant (output-row accumulation), fully parallel otherwise.  Float
composition matches ``kernels/ref.py::selective_scan_step`` + ``dense``
term-for-term so the ref oracle is a bitwise gate at f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _step_tile(h_ref, u_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, has_D):
    """Shared recurrence update for one (1, TDe, N) state tile.

    Returns (h', y) with h' (TDe, N) f32 and y (TDe,) f32, replicating the
    ref oracle's cast order exactly (dt*u multiplied in io dtype before the
    f32 cast; everything else accumulated in f32).
    """
    f32 = jnp.float32
    dt32 = dt_ref[0].astype(f32)                          # (TDe,)
    a = jnp.exp(dt32[:, None] * a_ref[...].astype(f32))   # (TDe, N)
    du = (dt_ref[0] * u_ref[0]).astype(f32)               # io-dtype product
    h = a * h_ref[0] + du[:, None] * b_ref[0].astype(f32)[None, :]
    y = jnp.sum(h * c_ref[0].astype(f32)[None, :], axis=1)
    if has_D:
        y = y + u_ref[0].astype(f32) * d_ref[0].astype(f32)
    return h, y


def _kernel(h_ref, u_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
            ho_ref, y_ref, *, has_D):
    h, y = _step_tile(h_ref, u_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
                      has_D)
    ho_ref[0] = h
    y_ref[0] = y.astype(y_ref.dtype)


def _fused_kernel(h_ref, u_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
                  g_ref, w_ref, ho_ref, o_ref, acc_ref, *, nde, has_D):
    d = pl.program_id(1)

    @pl.when(d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h, y = _step_tile(h_ref, u_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
                      has_D)
    ho_ref[0] = h
    # epilogue: out = dense(y.astype(io) * gate, w_out) — the projection
    # contracts this De tile's slice of w_out while h is still in VMEM
    z = y.astype(o_ref.dtype) * g_ref[0]
    acc_ref[...] += jnp.dot(z[None, :], w_ref[...].astype(z.dtype),
                            preferred_element_type=jnp.float32)

    @pl.when(d == nde - 1)
    def _write():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _prep(h, u_t, dt_t, A, B_t, C_t, D, de_tile):
    Bsz, De, N = h.shape
    de_tile = min(de_tile, De)
    assert De % de_tile == 0, (De, de_tile)
    has_D = D is not None
    Dv = (D if has_D else jnp.zeros((De,), jnp.float32)).reshape(1, De)
    return Bsz, De, N, de_tile, has_D, Dv


@functools.partial(jax.jit, static_argnames=("de_tile", "interpret"))
def decode_step_pallas(h, u_t, dt_t, A, B_t, C_t, D=None, *, de_tile=512,
                       interpret=False):
    """(h', y). h (B,De,N) f32; u_t,dt_t (B,De); A (De,N); B_t,C_t (B,N)."""
    Bsz, De, N, de_tile, has_D, Dv = _prep(h, u_t, dt_t, A, B_t, C_t, D,
                                           de_tile)
    grid = (Bsz, De // de_tile)
    hs, y = pl.pallas_call(
        functools.partial(_kernel, has_D=has_D),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, de_tile, N), lambda b, d: (b, d, 0)),
            pl.BlockSpec((1, de_tile), lambda b, d: (b, d)),
            pl.BlockSpec((1, de_tile), lambda b, d: (b, d)),
            pl.BlockSpec((de_tile, N), lambda b, d: (d, 0)),
            pl.BlockSpec((1, N), lambda b, d: (b, 0)),
            pl.BlockSpec((1, N), lambda b, d: (b, 0)),
            pl.BlockSpec((1, de_tile), lambda b, d: (0, d)),
        ],
        out_specs=[
            pl.BlockSpec((1, de_tile, N), lambda b, d: (b, d, 0)),
            pl.BlockSpec((1, de_tile), lambda b, d: (b, d)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, De, N), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, De), u_t.dtype),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(h, u_t, dt_t, A, B_t, C_t, Dv)
    return hs, y


@functools.partial(jax.jit, static_argnames=("de_tile", "interpret"))
def decode_step_fused_pallas(h, u_t, dt_t, A, B_t, C_t, D, gate, w_out, *,
                             de_tile=512, interpret=False):
    """(h', out) with out (B,Dm) = dense(y * gate, w_out) fused in-kernel.
    gate (B,De); w_out (De,Dm)."""
    Bsz, De, N, de_tile, has_D, Dv = _prep(h, u_t, dt_t, A, B_t, C_t, D,
                                           de_tile)
    Dm = w_out.shape[-1]
    nde = De // de_tile
    grid = (Bsz, nde)
    hs, out = pl.pallas_call(
        functools.partial(_fused_kernel, nde=nde, has_D=has_D),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, de_tile, N), lambda b, d: (b, d, 0)),
            pl.BlockSpec((1, de_tile), lambda b, d: (b, d)),
            pl.BlockSpec((1, de_tile), lambda b, d: (b, d)),
            pl.BlockSpec((de_tile, N), lambda b, d: (d, 0)),
            pl.BlockSpec((1, N), lambda b, d: (b, 0)),
            pl.BlockSpec((1, N), lambda b, d: (b, 0)),
            pl.BlockSpec((1, de_tile), lambda b, d: (0, d)),
            pl.BlockSpec((1, de_tile), lambda b, d: (b, d)),
            pl.BlockSpec((de_tile, Dm), lambda b, d: (d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, de_tile, N), lambda b, d: (b, d, 0)),
            pl.BlockSpec((1, Dm), lambda b, d: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, De, N), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, Dm), u_t.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, Dm), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(h, u_t, dt_t, A, B_t, C_t, Dv, gate, w_out)
    return hs, out
