# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

# Impl-resolution registry (kernels/ops.py): the package-level names are
# the public surface for choosing pallas/ref/fused/interpret globally.
from repro.kernels.ops import (active_default, default_impl, registered_ops,
                               resolve_impl, set_default_impl)

__all__ = ["active_default", "default_impl", "registered_ops",
           "resolve_impl", "set_default_impl"]
