"""Logical-axis sharding (MaxText-style) with divisibility-checked resolution.

Every parameter leaf has a globally meaningful name; ``AXES_BY_NAME`` maps a
leaf name to the *logical* axis of each of its dims.  ``ShardingRules`` maps
logical axes to mesh axes (with ordered fallbacks).  The resolver drops a
mesh-axis assignment whenever the dim size is not divisible by the mesh axis
size (jax requires divisibility for jit argument shardings) and whenever the
mesh axis was already consumed by an earlier dim of the same tensor.

A leaf whose ndim is one larger than its table entry is assumed to be stacked
over layers by the scan-over-layers machinery ('layers' logical axis, never
sharded).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# logical axes per parameter-leaf name (base, unstacked ndim)
# ---------------------------------------------------------------------------

AXES_BY_NAME: Dict[str, Tuple[str, ...]] = {
    # embeddings / head
    "embed": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    "frontend_proj": ("frontend", "embed"),
    "frontend_bias": ("embed",),
    "mask_embed": ("embed",),
    # norms
    "scale": ("embed",),
    "scale_inner": ("inner",),
    # attention
    "w_q": ("embed", "qkv"),
    "w_k": ("embed", "qkv"),
    "w_v": ("embed", "qkv"),
    "w_o": ("qkv", "embed"),
    "b_q": ("qkv",),
    "b_k": ("qkv",),
    "b_v": ("qkv",),
    # attention-MoE baselines (experts of heads / output projections)
    "e_w_q": ("experts", "embed", "qkv"),
    "e_w_v": ("experts", "embed", "qkv"),
    "e_w_o": ("experts", "qkv", "embed"),
    # mlp
    "w_up": ("embed", "mlp"),
    "w_gate_ffn": ("embed", "mlp"),
    "w_down": ("mlp", "embed"),
    # FFN-MoE experts (replicated — paper's no-EP design)
    "e_w_up": ("experts", "embed", "mlp"),
    "e_w_gate_ffn": ("experts", "embed", "mlp"),
    "e_w_down": ("experts", "mlp", "embed"),
    # FFN-MoE experts under explicit expert parallelism (sharded over data)
    "ep_w_up": ("experts_ep", "embed", "mlp"),
    "ep_w_gate_ffn": ("experts_ep", "embed", "mlp"),
    "ep_w_down": ("experts_ep", "mlp", "embed"),
    # routers
    "w_router": ("embed", "experts_router"),
    # mamba / ssm family
    "w_in": ("embed", "inner"),
    "w_gate": ("embed", "inner"),
    "w_out": ("inner", "embed"),
    "e_w_in": ("experts", "embed", "inner"),
    "e_w_gate": ("experts", "embed", "inner"),
    "e_w_out": ("experts", "inner", "embed"),
    "w_x": ("inner", "xproj"),
    "w_dt": ("dt_rank", "inner"),
    "b_dt": ("inner",),
    "e_w_x": ("experts", "inner", "xproj"),
    "e_w_dt": ("experts", "dt_rank", "inner"),
    "e_b_dt": ("experts", "inner"),
    "conv_w": ("conv", "inner"),
    "conv_b": ("inner",),
    "A_log": ("inner", "state"),
    "A_log_h": ("heads_inner",),
    "D": ("inner",),
    "D_h": ("heads_inner",),
    "dt_bias": ("heads_inner",),
    # mamba2 (heads_inner = De/head_dim heads)
    "w_zxbcdt": ("embed", "inner"),
    "e_w_zxbcdt": ("experts", "embed", "inner"),
    # gated deltanet
    "w_qkvz": ("embed", "inner"),
    "e_w_qkvz": ("experts", "embed", "inner"),
    "w_ab": ("embed", "heads_inner"),
    # rg-lru
    "w_rec_in": ("embed", "inner"),
    "w_rec_gate": ("embed", "inner"),
    "e_w_rec_in": ("experts", "embed", "inner"),
    "e_w_rec_gate": ("experts", "embed", "inner"),
    "w_a_gate": ("rnn_block", "inner_head", "gate2"),
    "w_x_gate": ("rnn_block", "inner_head", "gate2"),
    "b_a_gate": ("inner",),
    "b_x_gate": ("inner",),
    "a_param": ("inner",),
    # xlstm
    "w_if": ("inner", "gates"),
    "b_if": ("gates",),
    "w_qk": ("inner", "qk"),
    "w_v2": ("inner", "inner"),
    "gn_scale": ("inner",),
    "w_slstm": ("embed", "gates"),
    "r_slstm": ("heads_inner", "head_dim", "gates_head"),
    "b_slstm": ("gates",),
}

# logical axis -> ordered mesh-axis preferences (first divisible wins).
# None = replicate.
DEFAULT_RULES: Dict[str, Tuple[Optional[object], ...]] = {
    "batch": (("pod", "data"), ("data",), None),
    "vocab": ("model", None),
    "embed": ("data", None),        # ZeRO-3-style weight shard over data
    "mlp": ("model", None),
    "qkv": ("model", None),         # merged head*head_dim projection dim
    "heads": ("model", None),
    "head_dim": ("model", None),
    "inner": ("model", None),       # mamba D_e / rnn width
    "experts": (None,),             # paper: no expert parallelism for RoM
    "experts_ep": ("data", None),   # EP path (llama4/moonshot)
    "experts_router": (None,),
    "xproj": (None,),
    "dt_rank": (None,),
    "state": (None,),
    "conv": (None,),
    "heads_inner": ("model", None),
    "gates": (None,),
    "gates_head": (None,),
    "qk": ("model", None),
    "frontend": (None,),
    "rnn_block": (None,),
    "inner_head": (None,),
    "gate2": (None,),
    "layers": (None,),
    # activations
    "act_batch": (("pod", "data"), ("data",), None),
    "act_seq": (None,),
    "act_seq_shard": ("model", None),   # SP for B=1 long-context cells
    "act_embed": (None,),
    "act_inner": ("model", None),
    "act_mlp": ("model", None),
    "act_qkv": ("model", None),
    "act_vocab": ("model", None),
    "act_kv_seq": ("model", None),      # decode KV-cache sequence sharding
    "act_experts": (None,),             # MoE capacity buffers; serving plans
                                        # override to their expert partition
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: Tuple[Tuple[str, Tuple[Optional[object], ...]], ...] = tuple(
        sorted(DEFAULT_RULES.items())
    )

    def as_dict(self):
        return dict(self.rules)

    def override(self, **kw) -> "ShardingRules":
        d = self.as_dict()
        for k, v in kw.items():
            d[k] = v
        return ShardingRules(tuple(sorted(d.items())))


def _mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def resolve_spec(shape: Sequence[int], logical: Sequence[Optional[str]],
                 mesh: Mesh, rules: ShardingRules) -> P:
    """Pick a PartitionSpec for ``shape`` given logical dim names."""
    rd = rules.as_dict()
    used = set()
    out = []
    for dim, name in zip(shape, logical):
        choice = None
        for cand in rd.get(name, (None,)):
            if cand is None:
                break
            axes = cand if isinstance(cand, (tuple, list)) else (cand,)
            if any(a not in mesh.shape for a in axes):
                continue
            if any(a in used for a in axes):
                continue
            if dim % _mesh_axis_size(mesh, cand) != 0:
                continue
            choice = tuple(axes) if len(axes) > 1 else axes[0]
            used.update(axes)
            break
        out.append(choice)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def logical_axes_of(path, leaf_shape) -> Tuple[str, ...]:
    """Look up the logical axes for a param leaf by its key name + ndim."""
    name = None
    for entry in reversed(path):
        key = getattr(entry, "key", getattr(entry, "name", None))
        if key is not None:
            name = str(key)
            break
    if name is None:
        raise KeyError(f"param path {path} has no string key")
    if name not in AXES_BY_NAME:
        raise KeyError(f"param leaf {name!r} (path {jax.tree_util.keystr(path)}) "
                       f"missing from AXES_BY_NAME")
    base = AXES_BY_NAME[name]
    nd = len(leaf_shape)
    if nd == len(base):
        return base
    if nd == len(base) + 1:
        return ("layers",) + base
    raise ValueError(f"leaf {name!r} ndim {nd} incompatible with logical axes "
                     f"{base}")


def param_specs(params_shapes, mesh: Mesh, rules: ShardingRules,
                lenient: bool = False):
    """Pytree of PartitionSpec matching a pytree of ShapeDtypeStructs.

    ``lenient`` replicates leaves whose name/ndim is unknown — used for
    optimizer-state trees (e.g. adafactor's factored row/col stats, whose
    paths end in the param name but with reduced rank).
    """
    def one(path, leaf):
        try:
            la = logical_axes_of(path, leaf.shape)
        except (KeyError, ValueError):
            if lenient:
                return P()
            raise
        return resolve_spec(leaf.shape, la, mesh, rules)
    return jax.tree_util.tree_map_with_path(one, params_shapes)


def param_shardings(params_shapes, mesh: Mesh, rules: ShardingRules):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        param_specs(params_shapes, mesh, rules),
        is_leaf=lambda x: isinstance(x, P))


def constrain(x, mesh: Mesh, rules: ShardingRules, logical: Sequence[Optional[str]]):
    """with_sharding_constraint by logical axis names (no-op off-mesh)."""
    if mesh is None or mesh.empty:
        return x
    spec = resolve_spec(x.shape, logical, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


class ShardCtx:
    """Carries (mesh, rules) through model code; inert when mesh is None."""

    def __init__(self, mesh: Optional[Mesh] = None,
                 rules: Optional[ShardingRules] = None):
        self.mesh = mesh
        self.rules = rules or ShardingRules()

    def cons(self, x, *logical):
        if self.mesh is None:
            return x
        return constrain(x, self.mesh, self.rules, logical)

    def spec(self, shape, logical) -> P:
        if self.mesh is None:
            return P()
        return resolve_spec(shape, logical, self.mesh, self.rules)
