"""Fault-tolerant run manager: checkpoint/restart, stragglers, elasticity.

``RunManager.run`` drives a training loop with:

* periodic atomic checkpoints (async writer off the critical path),
* automatic restart-from-latest on *any* step exception, up to
  ``max_failures`` (on a real fleet the same path handles preemptions and
  node loss — the job scheduler relaunches, `run` resumes from the last
  committed step; the data pipeline is stateless in (seed, step) so the
  token stream is bit-identical across restarts),
* straggler detection: per-step wall time vs. a running median; slow steps
  are logged with their lag factor (on a fleet: feeds the hot-spare swap /
  re-scheduling policy; here: surfaced in metrics so tests can assert it),
* elasticity: ``restore`` re-resolves shardings against the *current* mesh,
  so a restart may bring up a different device count (tested by re-meshing
  between failures in tests/test_fault_tolerance.py).

Single-process container note: multi-host heartbeating is represented by a
heartbeat file the manager touches each step; a fleet supervisor would watch
it (documented, not simulated).
"""
from __future__ import annotations

import os
import statistics
import time
from typing import Callable, Optional

import jax

from repro import checkpoint as ckpt


class StragglerMonitor:
    def __init__(self, factor: float = 2.5, window: int = 32):
        self.factor = factor
        self.window = window
        self.times = []
        self.flags = []

    def record(self, dt: float, step: int):
        self.times.append(dt)
        self.times = self.times[-self.window:]
        med = statistics.median(self.times)
        if len(self.times) >= 8 and dt > self.factor * med:
            self.flags.append((step, dt / med))
            return dt / med
        return None


class RunManager:
    def __init__(self, ckpt_dir: str, save_every: int = 50,
                 max_failures: int = 3, async_save: bool = True,
                 heartbeat_path: Optional[str] = None):
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.max_failures = max_failures
        self.async_save = async_save
        self.heartbeat_path = heartbeat_path or os.path.join(
            ckpt_dir, "heartbeat")
        self.straggler = StragglerMonitor()
        self.failures = 0
        self.restarts = 0
        self._pending_save = None

    def _heartbeat(self, step: int):
        os.makedirs(os.path.dirname(self.heartbeat_path), exist_ok=True)
        with open(self.heartbeat_path, "w") as f:
            f.write(f"{step} {time.time()}\n")

    def _save(self, state, step: int, force=False):
        if step % self.save_every == 0 or force:
            if self._pending_save is not None:
                self._pending_save.join()
            self._pending_save = ckpt.save(self.ckpt_dir, step, state,
                                           async_=self.async_save)

    def run(self, *, init_fn: Callable[[], object],
            step_fn: Callable[[object, dict], tuple],
            data_fn: Callable[[int], dict],
            num_steps: int,
            state_shardings=None,
            log_every: int = 0):
        """Returns (final_state, history of metrics dicts)."""
        state = None
        start = 0
        last = ckpt.latest_step(self.ckpt_dir)
        if last is not None:
            target = jax.eval_shape(init_fn)
            state, start = ckpt.restore(self.ckpt_dir, target,
                                        shardings=state_shardings)
            self.restarts += 1
        if state is None:
            state = init_fn()
            ckpt.save(self.ckpt_dir, 0, state, async_=False)

        history = []
        step = start
        while step < num_steps:
            try:
                batch = data_fn(step)
                t0 = time.perf_counter()
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(
                    jax.tree_util.tree_leaves(metrics)[0])
                dt = time.perf_counter() - t0
                lag = self.straggler.record(dt, step)
                if lag is not None:
                    metrics = dict(metrics)
                    metrics["straggler_lag"] = lag
                history.append(jax.device_get(metrics))
                step += 1
                self._heartbeat(step)
                self._save(state, step)
                if log_every and step % log_every == 0:
                    m = history[-1]
                    print(f"step {step}: " + " ".join(
                        f"{k}={float(v):.4g}" for k, v in sorted(m.items())
                        if hasattr(v, "__float__") or isinstance(v, float)))
            except Exception as e:  # noqa: BLE001 — any step failure
                self.failures += 1
                if self.failures > self.max_failures:
                    raise
                print(f"[fault-tolerance] step {step} failed ({e!r}); "
                      f"restoring from latest checkpoint "
                      f"({self.failures}/{self.max_failures})")
                target = jax.eval_shape(init_fn)
                state, step = ckpt.restore(self.ckpt_dir, target,
                                           shardings=state_shardings)
                self.restarts += 1
        if self._pending_save is not None:
            self._pending_save.join()
        ckpt.save(self.ckpt_dir, step, state, async_=False)
        return state, history
