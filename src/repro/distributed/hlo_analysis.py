"""Compiled-HLO analysis: collective bytes + roofline terms (v5e model).

``cost_analysis()`` gives per-partition FLOPs and bytes but is blind to
communication, so collective volume is parsed from the partitioned HLO text:
every ``all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute`` (and their ``-start`` async forms) contributes its
result bytes under a ring model:

    all-gather       bytes * (g-1)/g            (result = gathered, per dev)
    reduce-scatter   bytes * (g-1)              (result = shard)
    all-reduce       2 * bytes * (g-1)/g        (reduce-scatter + all-gather)
    all-to-all       bytes * (g-1)/g
    collective-permute  bytes                   (one hop)

Link speed: ICI ~50 GB/s per link within a pod; collectives whose replica
groups span pods (group size > 256 on the production meshes) are charged at
the 25 GB/s DCN figure.  One link per collective (conservative: a 2D torus
has more; recorded as a modeling assumption in EXPERIMENTS.md).

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 25e9
POD_SIZE = 256

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\][^\s]*))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))               # [num_groups, group_size]<=[...]
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    if "source_target_pairs" in line:
        return 2
    return 1


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]            # raw result bytes (per device)
    wire_bytes_by_kind: Dict[str, float]     # ring-model bytes on the wire
    seconds: float                           # modeled collective seconds
    seconds_by_kind: Dict[str, float]
    ops: list                                # (kind, bytes, group, seconds)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    raw: Dict[str, int] = {}
    wire: Dict[str, float] = {}
    secs: Dict[str, float] = {}
    ops = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        if "-done" in line.split("=")[1][:40]:
            continue
        b = _shape_bytes(type_str)
        g = _group_size(line)
        if g <= 1:
            continue
        if kind == "all-gather":
            w = b * (g - 1) / g
        elif kind == "reduce-scatter":
            w = b * (g - 1)
        elif kind == "all-reduce":
            w = 2 * b * (g - 1) / g
        elif kind == "all-to-all":
            w = b * (g - 1) / g
        else:                                 # collective-permute
            w = float(b)
        bw = DCN_BW if g > POD_SIZE else ICI_BW
        t = w / bw
        counts[kind] = counts.get(kind, 0) + 1
        raw[kind] = raw.get(kind, 0) + b
        wire[kind] = wire.get(kind, 0.0) + w
        secs[kind] = secs.get(kind, 0.0) + t
        ops.append({"kind": kind, "bytes": b, "group": g, "seconds": t})
    return CollectiveStats(counts=counts, bytes_by_kind=raw,
                           wire_bytes_by_kind=wire,
                           seconds=sum(secs.values()), seconds_by_kind=secs,
                           ops=ops)


def roofline_terms(cost: dict, colls: CollectiveStats) -> dict:
    """cost: compiled.cost_analysis() dict (per-partition on this jax)."""
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    coll_s = colls.seconds
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s,
             "hlo_flops_per_device": flops,
             "hlo_bytes_per_device": bytes_acc,
             "collective_wire_bytes": sum(
                 colls.wire_bytes_by_kind.values())}
    dominant = max(("compute_s", "memory_s", "collective_s"),
                   key=lambda k: terms[k])
    terms["bottleneck"] = {"compute_s": "compute", "memory_s": "memory",
                           "collective_s": "collective"}[dominant]
    terms["step_s_model"] = max(compute_s, memory_s, coll_s)
    return terms


def model_flops(cfg, shape, n_devices: int) -> float:
    """MODEL_FLOPS convention: 6*N_active*tokens (train), 2*N_active*tokens
    (prefill/decode forward), per device."""
    from repro.configs.all_configs import param_stats
    stats = param_stats(cfg)
    n_active = stats["active"]
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:                                     # decode: one token per seq
        total = 2.0 * n_active * shape.global_batch
    return total / n_devices
