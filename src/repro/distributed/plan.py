"""ParallelPlan: the one mesh-aware execution plan for serving.

Before this module, every serving entry point decided device placement ad
hoc: the engine took raw ``mesh=``/``rules=`` kwargs it mostly ignored,
``StateStore`` allocated wherever jax defaulted, and expert placement for
RoM/MoE weights was a per-callsite accident.  A :class:`ParallelPlan`
resolves the whole topology **once** — mesh, sharding rules, the *slot
partition* (which mesh axis decode slots shard over) and the *expert
partition* (which mesh axis RoM/MoE expert weights shard over) — and is
threaded everywhere a device array is created:

  * ``StateStore`` allocates ``NamedSharding``-typed decode state
    (:meth:`slot_shardings` / :meth:`place_state`) and its slot primitives
    stay on-plan via jit ``out_shardings``;
  * ``ServeEngine``'s jitted mixed/speculative steps carry
    ``in_shardings``/``out_shardings`` built here, and prefill lane batches
    pad to a multiple of the slot partition (:meth:`lane_width`);
  * RoM decode dispatch routes tokens to expert shards through the grouped
    matmul under the plan's expert partition (``core/moe_dispatch``
    resolves the ``experts_ep`` logical axis against :attr:`rules`);
  * params are placed by :meth:`place_params`: **replicated except expert
    leaves** — replication keeps per-slot float math identical to
    single-device execution, so greedy decode under any plan is
    bit-identical to :meth:`single_device` (a tested invariant), while the
    expert dim is never a contraction dim and can shard freely.

Construct plans through the factories — they install the serving
resolution of the logical-axis tables (:func:`serving_rules`):

    plan = ParallelPlan.single_device()          # the compatibility default
    plan = ParallelPlan.host(data=4, model=2)    # this host's devices
    plan = ParallelPlan.parse("data=4,model=2")  # CLI --mesh spec
    plan = ParallelPlan.from_mesh(mesh)          # a mesh you already built
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd


def serving_rules(base: Optional[shd.ShardingRules],
                  slot_axis: Optional[str],
                  expert_axis: Optional[str]) -> shd.ShardingRules:
    """Serving resolution of the logical-axis table.

    Parameters replicate (no ZeRO/TP resharding on the decode path, and
    replicated weights keep per-slot float math bit-identical across
    topologies); batch/slot axes shard over the slot partition; the expert
    dim of RoM/MoE weights and dispatch buffers shards over the expert
    partition.  Everything else in ``base`` (default
    :class:`~repro.distributed.sharding.ShardingRules`) is untouched.
    """
    repl = (None,)
    slot = (slot_axis, None) if slot_axis else repl
    exp = (expert_axis, None) if expert_axis else repl
    over = dict(
        batch=slot, vocab=repl, embed=repl, mlp=repl, qkv=repl,
        heads=repl, head_dim=repl, inner=repl, heads_inner=repl, qk=repl,
        experts=exp, experts_ep=exp,
        act_batch=slot, act_seq_shard=repl, act_inner=repl, act_mlp=repl,
        act_qkv=repl, act_vocab=repl, act_kv_seq=repl, act_experts=exp,
    )
    return (base or shd.ShardingRules()).override(**over)


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    """Mesh + rules + slot/expert partitions, resolved once.

    mesh: the device mesh (None = single device, every helper is a no-op).
    rules: logical-axis -> mesh-axis resolution used for every sharding
        decision under this plan (activations, params, dispatch buffers).
    slot_axis: mesh axis the decode-slot dimension shards over (the
        engine's ``max_slots`` and prefill lane batches), or None.
    expert_axis: mesh axis the expert dim of RoM/MoE weights (and their
        dispatch/capacity buffers) shards over, or None.

    Use the factory classmethods — they install :func:`serving_rules`.
    """
    mesh: Optional[Mesh] = None
    rules: shd.ShardingRules = dataclasses.field(
        default_factory=shd.ShardingRules)
    slot_axis: Optional[str] = None
    expert_axis: Optional[str] = None

    # ------------------------------------------------------------ factories

    @classmethod
    def single_device(cls) -> "ParallelPlan":
        """The no-mesh plan: every placement helper is an identity.  The
        one-release compatibility default of every serving entry point."""
        return cls(mesh=None, rules=shd.ShardingRules())

    @classmethod
    def from_mesh(cls, mesh: Mesh, *, rules: Optional[shd.ShardingRules] = None,
                  slot_axis: Optional[str] = "data",
                  expert_axis: Optional[str] = "model") -> "ParallelPlan":
        """Plan over an existing mesh; partition axes missing from the mesh
        (or of size 1) are dropped to None."""
        def live(ax):
            return ax if (ax is not None and mesh.shape.get(ax, 1) > 1) \
                else None
        slot_axis, expert_axis = live(slot_axis), live(expert_axis)
        return cls(mesh=mesh,
                   rules=serving_rules(rules, slot_axis, expert_axis),
                   slot_axis=slot_axis, expert_axis=expert_axis)

    @classmethod
    def host(cls, data: int = 1, model: int = 1, *,
             rules: Optional[shd.ShardingRules] = None) -> "ParallelPlan":
        """Plan over this host's devices as a ``(data, model)`` mesh
        (divisibility-checked by ``make_host_mesh``)."""
        from repro.launch.mesh import make_host_mesh
        return cls.from_mesh(make_host_mesh((data, model)), rules=rules)

    @classmethod
    def parse(cls, spec: Optional[str], *,
              rules: Optional[shd.ShardingRules] = None) -> "ParallelPlan":
        """CLI ``--mesh`` spec -> plan: ``"data=4,model=2"`` (either key
        optional); empty/None/"single" -> :meth:`single_device`."""
        if not spec or spec in ("1", "single", "single_device"):
            return cls.single_device()
        kw = {}
        for part in spec.split(","):
            k, _, v = part.partition("=")
            k = k.strip()
            if k not in ("data", "model") or not v.strip().isdigit():
                raise ValueError(
                    f"bad mesh spec {spec!r}: expected 'data=N[,model=M]'")
            kw[k] = int(v.strip())
        return cls.host(**kw, rules=rules)

    # ------------------------------------------------------------ topology

    def _axis_size(self, ax: Optional[str]) -> int:
        if self.mesh is None or ax is None:
            return 1
        return int(self.mesh.shape.get(ax, 1))

    @property
    def data_size(self) -> int:
        """Size of the slot partition (1 when unpartitioned)."""
        return self._axis_size(self.slot_axis)

    @property
    def expert_size(self) -> int:
        """Size of the expert partition (1 when unpartitioned)."""
        return self._axis_size(self.expert_axis)

    def shard_ctx(self) -> shd.ShardCtx:
        """The (mesh, rules) context model code consumes (inert off-mesh)."""
        return shd.ShardCtx(self.mesh, self.rules)

    def describe(self) -> dict:
        """JSON-friendly stamp: mesh shape + both partitions.  Benchmarks
        attach this to every scenario so perf artifacts are attributable
        to a topology."""
        return {
            "mesh": (None if self.mesh is None else
                     {ax: int(n) for ax, n in self.mesh.shape.items()}),
            "slot_partition": self.slot_axis,
            "expert_partition": self.expert_axis,
        }

    def round_slots(self, n: int) -> int:
        """Smallest multiple of the slot partition >= ``n``.  The engine
        requires ``max_slots`` to divide over the partition; benchmark
        scenarios round their slot counts up through this."""
        d = self.data_size
        return -(-n // d) * d

    def lane_width(self, n: int) -> int:
        """Prefill lane-batch width for ``n`` admitted requests: next power
        of two (bounded jit specializations), padded up to a multiple of
        the slot partition so lane batches divide over the data axis."""
        return self.round_slots(1 << (max(n, 1) - 1).bit_length())

    # ------------------------------------------------------------ placement

    def replicated(self) -> Optional[NamedSharding]:
        """Fully-replicated sharding on this plan's mesh (None off-mesh)."""
        return None if self.mesh is None else NamedSharding(self.mesh, P())

    def slot_shardings(self, state, axes):
        """Per-leaf ``NamedSharding`` pytree for a decode-state pytree:
        each leaf's slot axis (``axes`` — ``StateStore.axes``) shards over
        the slot partition; leaves whose slot count doesn't divide the
        partition replicate (e.g. 1-slot side states).  None off-mesh."""
        if self.mesh is None:
            return None
        d = self.data_size

        def one(leaf, ax):
            if self.slot_axis is not None and d > 1 \
                    and leaf.shape[ax] % d == 0:
                spec = [None] * leaf.ndim
                spec[ax] = self.slot_axis
                return NamedSharding(self.mesh, P(*spec))
            return NamedSharding(self.mesh, P())

        return jax.tree_util.tree_map(one, state, axes)

    def place_state(self, state, axes):
        """Commit a decode-state pytree to :meth:`slot_shardings`."""
        sh = self.slot_shardings(state, axes)
        return state if sh is None else jax.device_put(state, sh)

    def param_shardings(self, params):
        """Per-leaf ``NamedSharding`` for a param pytree under this plan's
        rules: expert leaves shard their expert dim over the expert
        partition, everything else replicates (see module docstring)."""
        if self.mesh is None:
            return None
        return shd.param_shardings(params, self.mesh, self.rules)

    def place_params(self, params):
        """Commit params to :meth:`param_shardings` (identity off-mesh)."""
        if self.mesh is None:
            return params
        return jax.device_put(params, self.param_shardings(params))

    def commit_params(self, params):
        """Device-commit a param (sub)tree under this plan.  On a mesh this
        is :meth:`place_params` — expert leaves shard their expert dim over
        the expert partition, the rest replicates.  Off-mesh it still
        performs the host->device transfer (plain ``jax.device_put``,
        where :meth:`place_params` is an identity): the expert library
        faults host-resident expert sets in through this, so a cold set
        pays one transfer at admission instead of re-uploading from numpy
        on every dispatch."""
        if self.mesh is None:
            return jax.device_put(params)
        return jax.device_put(params, self.param_shardings(params))
