"""``repro.obs`` — the observability surface of the serving stack.

A thin, stable re-export of :mod:`repro.serve.telemetry` so tools
(dashboards, exporters, notebooks) depend on ``repro.obs`` rather than
on serving internals::

    from repro import obs

    telem = obs.Telemetry()                 # registry + tracer bundle
    ...   # build engine/cache/library against telem (see docs)
    print(telem.registry.to_prometheus())
    json_blob = telem.tracer.chrome_trace()   # Perfetto-loadable

See docs/observability.md for the full reference.
"""
from repro.serve.telemetry import (LATENCY_BUCKETS_S, Counter,
                                   EngineInstruments, Gauge, Histogram,
                                   MetricsRegistry, Span, Telemetry,
                                   Timeline, Tracer, hist_mean,
                                   hist_quantile, log_buckets)

__all__ = ["LATENCY_BUCKETS_S", "Counter", "EngineInstruments", "Gauge",
           "Histogram", "MetricsRegistry", "Span", "Telemetry", "Timeline",
           "Tracer", "hist_mean", "hist_quantile", "log_buckets"]
