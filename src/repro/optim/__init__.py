"""Optimizers (pure-pytree, no external deps) + schedules + clipping.

AdamW matches the paper's recipe (b1=0.9, b2=0.95, wd=0.1, clip 1.0, cosine
with warmup).  Adafactor (factored second moment) is the default for the
400B-class assigned arch, where full Adam state would not fit HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype),
                                  grads), gn


def cosine_lr(step, *, base_lr, warmup_steps, total_steps, min_ratio=0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum((step + 1) / jnp.maximum(warmup_steps, 1), 1.0)
    prog = jnp.clip((step - warmup_steps) /
                    jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * (min_ratio + (1 - min_ratio) * cos)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1


def adamw_init(params):
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params)}


def adamw_update(grads, opt, params, lr, cfg: AdamWConfig, count):
    b1, b2 = cfg.b1, cfg.b2
    c = count.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        step = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if p.ndim >= 1:        # decoupled weight decay (not on scalars)
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    out = jax.tree_util.tree_map(upd, grads, opt["m"], opt["v"], params)
    new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v}


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), factored second moment for matrices
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    decay: float = 0.8          # \hat{beta2}_t = 1 - t^-decay
    eps1: float = 1e-30
    eps2: float = 1e-3
    clip_threshold: float = 1.0
    weight_decay: float = 0.1


def _factored(p):
    return p.ndim >= 2


def adafactor_init(params):
    def one(p):
        if _factored(p):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
    return {"stats": jax.tree_util.tree_map(one, params)}


def adafactor_update(grads, opt, params, lr, cfg: AdafactorConfig, count):
    t = count.astype(jnp.float32) + 1.0
    beta2 = 1.0 - t ** (-cfg.decay)

    def upd(g, st, p):
        g = g.astype(jnp.float32)
        g2 = g * g + cfg.eps1
        if _factored(p):
            vr = beta2 * st["vr"] + (1 - beta2) * g2.mean(-1)
            vc = beta2 * st["vc"] + (1 - beta2) * g2.mean(-2)
            denom = jnp.maximum(vr.mean(-1, keepdims=True),
                                cfg.eps1)[..., None]     # (..., 1, 1)
            u = g * jax.lax.rsqrt(vr[..., None] / denom) \
                * jax.lax.rsqrt(vc[..., None, :])
            new_st = {"vr": vr, "vc": vc}
        else:
            v = beta2 * st["v"] + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(v)
            new_st = {"v": v}
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u / cfg.clip_threshold)
        newp = p.astype(jnp.float32) - lr * u
        if p.ndim >= 1:
            newp = newp - lr * cfg.weight_decay * p.astype(jnp.float32)
        return newp.astype(p.dtype), new_st

    flat = jax.tree_util.tree_map(
        upd, grads, opt["stats"], params,
        is_leaf=lambda x: isinstance(x, dict) and ("vr" in x or "v" in x))
    is_pair = lambda x: isinstance(x, tuple)
    new_p = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=is_pair)
    new_s = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=is_pair)
    return new_p, {"stats": new_s}


def make_optimizer(name: str):
    if name == "adamw":
        return (adamw_init,
                lambda g, o, p, lr, c: adamw_update(g, o, p, lr,
                                                    AdamWConfig(), c))
    if name == "adafactor":
        return (adafactor_init,
                lambda g, o, p, lr, c: adafactor_update(g, o, p, lr,
                                                        AdafactorConfig(), c))
    raise KeyError(name)
