"""Gradient compression for data-parallel all-reduce (beyond-paper trick).

bf16 compressed all-reduce with per-replica error feedback: each replica
adds its carried quantization residual to the fresh local gradient, rounds
to bf16, all-reduces in bf16 (half the collective bytes of fp32), and keeps
the new residual.  Over steps the accumulated gradient signal is unbiased
(1-bit-Adam / EF-SGD style).

Contract: gradients arrive *per-replica stacked* — leading dim R = number of
DP shards, sharded over the DP mesh axes — as produced by a shard_map'd
per-shard loss.  Returns the reduced mean gradient (replicated) and the
updated per-replica error state.

Halving DP-gradient collective bytes halves the roofline collective term of
any gradient-all-reduce-bound cell (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def ef_init_stacked(params, num_replicas: int):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((num_replicas,) + p.shape, jnp.float32), params)


def compressed_psum_grads(stacked_grads, stacked_err, mesh,
                          dp_axes=("pod", "data")):
    """stacked_grads/err: pytrees with leading replica dim R (DP-sharded)."""
    axes = tuple(a for a in dp_axes if a in mesh.shape)
    if not axes:
        mean = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32).mean(0), stacked_grads)
        return mean, stacked_err

    flat_g, treedef = jax.tree_util.tree_flatten(stacked_grads)
    flat_e = treedef.flatten_up_to(stacked_err)

    def body(*leaves):
        n = len(leaves) // 2
        reds, errs = [], []
        for g, e in zip(leaves[:n], leaves[n:]):
            corrected = g.astype(jnp.float32) + e      # (1, ...) local
            g16 = corrected.astype(jnp.bfloat16)
            errs.append(corrected - g16.astype(jnp.float32))
            red = g16
            for ax in axes:
                red = jax.lax.pmean(red, ax)
            reds.append(red[0].astype(jnp.float32))
        return tuple(reds) + tuple(errs)

    in_specs = tuple(P(axes) for _ in flat_g)
    out_specs = tuple(P() for _ in flat_g) + tuple(P(axes) for _ in flat_g)
    out = jax.shard_map(body, mesh=mesh, in_specs=in_specs * 2,
                        out_specs=out_specs, check_vma=False)(
        *flat_g, *flat_e)
    n = len(flat_g)
    mean = jax.tree_util.tree_unflatten(treedef, out[:n])
    new_e = jax.tree_util.tree_unflatten(treedef, out[n:])
    return mean, new_e
