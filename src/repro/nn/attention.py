"""GQA attention: blockwise (flash-style online-softmax) prefill + cached decode.

Blockwise prefill keeps memory at O(q_block x kv_block) via an online-softmax
inner scan; sliding-window attention statically slices only the in-window KV
span per query block (compute-optimal).  Full-causal blockwise computes all KV
blocks with masking (2x masked-FLOPs overhead vs a causal kernel — recorded in
the roofline's MODEL_FLOPS/HLO_FLOPs ratio and addressed in §Perf).

Decode uses either a full-length cache (full attention) or a ring buffer of
``window`` slots (windowed attention).  Cache sequence dims are sharded over
the ``model`` mesh axis when head sharding is indivisible (flash-decoding
style partial-softmax collectives are inserted by GSPMD).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.layers import (Runtime, apply_rope, cost_map, cost_scan, dense,
                             dense_init)
from repro.serve.state import StateSpec

NEG_INF = -1e30


def attention_init(key, cfg):
    a = cfg.attention
    d, qd, kvd = cfg.d_model, a.num_heads * a.head_dim, a.num_kv_heads * a.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "w_q": dense_init(ks[0], d, qd, dtype=cfg.param_dtype),
        "w_k": dense_init(ks[1], d, kvd, dtype=cfg.param_dtype),
        "w_v": dense_init(ks[2], d, kvd, dtype=cfg.param_dtype),
        "w_o": dense_init(ks[3], qd, d, dtype=cfg.param_dtype),
    }
    if a.qkv_bias:
        p["b_q"] = jnp.zeros((qd,), jnp.float32)
        p["b_k"] = jnp.zeros((kvd,), jnp.float32)
        p["b_v"] = jnp.zeros((kvd,), jnp.float32)
    return p


def _heads_logical(a, mesh):
    """Consistent sharding scheme for (B,S,H,Dh)/(B,S,KV,Dh) activations."""
    m = mesh.shape.get("model", 1) if mesh is not None else 1
    if a.num_heads % m == 0 and a.num_kv_heads % m == 0:
        return ("act_batch", "act_seq", "heads", None)
    if a.tp_fallback == "head_dim" and a.head_dim % m == 0:
        return ("act_batch", "act_seq", None, "head_dim")
    return ("act_batch", "act_seq", None, None)


def _project_qkv(params, x, cfg, rt: Runtime, positions):
    a = cfg.attention
    B, S, _ = x.shape
    q = dense(x, params["w_q"], params.get("b_q"))
    k = dense(x, params["w_k"], params.get("b_k"))
    v = dense(x, params["w_v"], params.get("b_v"))
    q = q.reshape(B, S, a.num_heads, a.head_dim)
    k = k.reshape(B, S, a.num_kv_heads, a.head_dim)
    v = v.reshape(B, S, a.num_kv_heads, a.head_dim)
    if a.use_rope:
        q = apply_rope(q, positions, a.rope_theta)
        k = apply_rope(k, positions, a.rope_theta)
    lg = _heads_logical(a, rt.shard.mesh)
    return rt.shard.cons(q, *lg), rt.shard.cons(k, *lg), rt.shard.cons(v, *lg)


def _block_attn(q, k, v, qpos, kpos, *, causal, window):
    """Core block attention. q (B,Sq,KV,G,Dh); k,v (B,Sk,KV,Dh);
    qpos (Sq,), kpos (Sk,). Returns (acc (B,Sq,KV,G,Dh) f32, m, l)."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqngd,bknd->bqngk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale      # (B,Sq,KV,G,Sk)
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    mask &= kpos[None, :] >= 0                               # padding slots
    logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1)                             # (B,Sq,KV,G)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bqngk,bknd->bqngd", p, v.astype(jnp.float32))
    return acc, m, l


def blockwise_attention(q, k, v, *, causal=True, window=None,
                        q_block=512, kv_block=1024, qpos=None, kpos=None):
    """q (B,S,H,Dh); k,v (B,Sk,KV,Dh) -> (B,S,H,Dh)."""
    B, S, H, Dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    if qpos is None:
        qpos = jnp.arange(S)
    if kpos is None:
        kpos = jnp.arange(Sk)

    if S <= q_block and Sk <= kv_block:          # single-block fast path
        qg = q.reshape(B, S, KV, G, Dh)
        acc, m, l = _block_attn(qg, k, v, qpos, kpos, causal=causal,
                                window=window)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(B, S, H, Dh).astype(q.dtype)

    q_block = min(q_block, S)
    if S % q_block or (Sk == S and Sk % min(kv_block, Sk)):
        # pad to tile multiples (padding keys are masked via kpos = -1)
        pad = (-S) % q_block
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        qpos_p = jnp.concatenate([qpos, qpos[-1] + 1 + jnp.arange(pad)])
        kpos_p = jnp.concatenate([kpos, jnp.full((pad,), -1, kpos.dtype)])
        out = blockwise_attention(
            qp, kp, vp, causal=causal, window=window, q_block=q_block,
            kv_block=q_block if Sk == S else kv_block,
            qpos=qpos_p, kpos=kpos_p)
        return out[:, :S]
    qg = q.reshape(B, S, KV, G, Dh)
    nqb = S // q_block

    if window is not None and Sk == S:
        # Sliding window: per q block, statically slice the in-window span.
        span = window + q_block                  # kv needed per q block
        kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))
        kpos_p = jnp.concatenate([jnp.full((window,), -1), kpos])

        def one_block(i):
            qb = jax.lax.dynamic_slice_in_dim(qg, i * q_block, q_block, 1)
            kb = jax.lax.dynamic_slice_in_dim(kp, i * q_block, span, 1)
            vb = jax.lax.dynamic_slice_in_dim(vp, i * q_block, span, 1)
            pb = jax.lax.dynamic_slice_in_dim(kpos_p, i * q_block, span, 0)
            qp = qpos[0] + i * q_block + jnp.arange(q_block)
            acc, m, l = _block_attn(qb, kb, vb, qp, pb, causal=causal,
                                    window=window)
            return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

        out = cost_map(one_block, nqb)                       # (nqb,B,qb,...)
        out = jnp.moveaxis(out, 0, 1).reshape(B, S, KV, G, Dh)
        return out.reshape(B, S, H, Dh)

    # Full attention: outer map over q blocks, inner online-softmax scan
    # over kv blocks (flash-style; masked blocks cost FLOPs — see module doc).
    kv_block = min(kv_block, Sk)
    assert Sk % kv_block == 0, (Sk, kv_block)
    nkb = Sk // kv_block

    def one_block(i):
        qb = jax.lax.dynamic_slice_in_dim(qg, i * q_block, q_block, 1)
        qp = qpos[0] + i * q_block + jnp.arange(q_block)

        def inner(carry, j):
            acc, m, l = carry
            kb = jax.lax.dynamic_slice_in_dim(k, j * kv_block, kv_block, 1)
            vb = jax.lax.dynamic_slice_in_dim(v, j * kv_block, kv_block, 1)
            pb = jax.lax.dynamic_slice_in_dim(kpos, j * kv_block, kv_block, 0)
            acc_j, m_j, l_j = _block_attn(qb, kb, vb, qp, pb, causal=causal,
                                          window=window)
            m_new = jnp.maximum(m, m_j)
            r, r_j = jnp.exp(m - m_new), jnp.exp(m_j - m_new)
            return (acc * r[..., None] + acc_j * r_j[..., None],
                    m_new, l * r + l_j * r_j), None

        z = jnp.zeros((B, q_block, KV, G, Dh), jnp.float32)
        m0 = jnp.full((B, q_block, KV, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_block, KV, G), jnp.float32)
        (acc, m, l), _ = cost_scan(inner, (z, m0, l0), jnp.arange(nkb))
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    out = cost_map(one_block, nqb)
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, KV, G, Dh)
    return out.reshape(B, S, H, Dh)


def _batched_attn(qg, k, v, qpos_b, kpos_b, *, causal, window):
    """Attention with *per-batch-row* positions (continuous batching).

    qg (B,Sq,KV,G,Dh); k,v (B,Sk,KV,Dh); qpos_b (B,Sq); kpos_b (B,Sk) with
    -1 marking invalid cache slots.  Returns (acc f32, m, l) partial-softmax
    triples like ``_block_attn`` so callers can combine across shards.
    """
    scale = qg.shape[-1] ** -0.5
    logits = jnp.einsum("bqngd,bknd->bqngk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale   # (B,Sq,KV,G,Sk)
    mask = kpos_b[:, None, :] >= 0
    if causal:
        mask &= qpos_b[:, :, None] >= kpos_b[:, None, :]
    if window is not None:
        mask &= qpos_b[:, :, None] - kpos_b[:, None, :] < window
    logits = jnp.where(mask[:, :, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bqngk,bknd->bqngd", p, v.astype(jnp.float32))
    return acc, m, l


def attention_apply(params, x, cfg, rt: Runtime):
    """Train/prefill attention."""
    a = cfg.attention
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :] + rt.pos_offset
    q, k, v = _project_qkv(params, x, cfg, rt, positions)
    if a.impl == "full" or S <= a.q_block:
        y = blockwise_attention(q, k, v, causal=a.causal, window=a.window,
                                q_block=max(S, 1), kv_block=max(S, 1))
    else:
        y = blockwise_attention(q, k, v, causal=a.causal, window=a.window,
                                q_block=a.q_block, kv_block=a.kv_block)
    y = dense(y.reshape(B, S, a.num_heads * a.head_dim), params["w_o"])
    return y, {}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def attention_init_state(cfg, batch, max_len, dtype):
    a = cfg.attention
    L = min(max_len, a.window) if a.window is not None else max_len
    return {
        "k": jnp.zeros((batch, L, a.num_kv_heads, a.head_dim), dtype),
        "v": jnp.zeros((batch, L, a.num_kv_heads, a.head_dim), dtype),
        # per-slot positions: each batch row decodes at its own position
        # under continuous batching, so slot validity is per (row, slot)
        "kpos": jnp.full((batch, L), -1, jnp.int32),
    }


#: KV cache + per-(slot, cache-slot) kpos validity; slots at axis 0 of every
#: leaf (the cache seq dim is axis 1, so generic slot gather/insert is safe).
#: Without a sliding window the cache is *append-only position-keyed*: entry
#: p is only ever written when decode is at position p and reads causally
#: mask kpos > qpos, so speculative rollback needs no per-depth snapshot —
#: stale rejected-draft entries are masked now and overwritten on arrival.
#: A sliding window breaks that (ring slot p % L: rejected future writes
#: destroy the oldest still-in-window entries), so windowed configs keep
#: per-depth snapshots.
attention_state_spec = StateSpec(
    init=attention_init_state,
    append_only=lambda cfg: (("k", "v", "kpos")
                             if cfg.attention.window is None else ()))


def attention_state_logical(cfg, mesh):
    """Logical axes for the KV cache: shard seq over model when heads can't."""
    lg = _heads_logical(cfg.attention, mesh)
    if lg[2] == "heads":
        seq_ax = "act_seq"
    else:
        seq_ax = "act_kv_seq"                    # -> 'model'
    return {"k": ("act_batch", seq_ax, None, None),
            "v": ("act_batch", seq_ax, None, None),
            "kpos": ("act_batch", seq_ax)}


def _use_seq_sharded_decode(a, mesh, L):
    """True when the KV cache seq dim is model-sharded (heads indivisible)
    and the flash-decoding step is enabled (§Perf cell C)."""
    if a.decode != "flash" or mesh is None or "model" not in mesh.shape:
        return False
    m = mesh.shape["model"]
    heads_ok = a.num_heads % m == 0 and a.num_kv_heads % m == 0
    return (not heads_ok) and L % m == 0 and m > 1


def _flash_decode_body(q, k, v, kpos, k_t, v_t, pos_b, *, a):
    """shard_map body: each device owns a contiguous seq chunk of the cache.

    The update lands only on the owning shard (no GSPMD resharding of the
    whole cache — the measured pathology in §Perf cell C); partial softmax
    stats combine across shards flash-decoding style.  ``pos_b`` (B,) is the
    per-slot decode position (continuous batching).
    """
    B = q.shape[0]
    n = jax.lax.axis_size("model")
    idx = jax.lax.axis_index("model")
    L_loc = k.shape[1]
    L = L_loc * n
    slot_g = pos_b % L if a.window is not None else pos_b       # (B,)
    slot = slot_g - idx * L_loc
    upd = jnp.arange(L_loc)[None, :] == slot[:, None]           # (B,L_loc)
    k = jnp.where(upd[..., None, None], k_t.astype(k.dtype), k)
    v = jnp.where(upd[..., None, None], v_t.astype(v.dtype), v)
    kpos = jnp.where(upd, pos_b[:, None], kpos)

    qg = q.reshape(B, 1, a.num_kv_heads, a.num_heads // a.num_kv_heads,
                   a.head_dim)
    acc, m, l = _batched_attn(qg, k, v, pos_b[:, None], kpos,
                              causal=a.causal, window=a.window)
    m_g = jax.lax.pmax(m, "model")
    scale = jnp.exp(m - m_g)
    acc = jax.lax.psum(acc * scale[..., None], "model")
    l = jax.lax.psum(l * scale, "model")
    y = acc / jnp.maximum(l, 1e-30)[..., None]
    return y.astype(q.dtype), k, v, kpos


def _pos_vector(pos, B):
    """Accept a scalar (lockstep batch) or (B,) per-slot position array."""
    pos = jnp.asarray(pos, jnp.int32)
    return jnp.broadcast_to(pos.reshape(-1), (B,))


def attention_step(params, x_t, state, pos, cfg, rt: Runtime):
    """x_t (B,1,D); pos: scalar int32 or (B,) per-slot absolute positions."""
    a = cfg.attention
    B = x_t.shape[0]
    mesh = rt.shard.mesh
    pos_b = _pos_vector(pos, B)
    positions = pos_b[:, None]
    q, k_t, v_t = _project_qkv(params, x_t, cfg, rt, positions)
    L = state["k"].shape[1]

    if _use_seq_sharded_decode(a, mesh, L):
        # flash-decoding over the model axis (seq-sharded cache)
        import functools
        from jax.sharding import PartitionSpec as P
        dp = tuple(ax for ax in ("pod", "data") if ax in mesh.shape)
        bspec = P(dp) if dp else P()
        cs = P(*bspec, "model", None, None)
        ts = P(*bspec, None, None, None)          # (B,1,KV,Dh) new k/v token
        y, k, v, kpos = jax.shard_map(
            functools.partial(_flash_decode_body, a=a), mesh=mesh,
            in_specs=(P(*bspec, None, None), cs, cs, P(*bspec, "model"),
                      ts, ts, P(*bspec)),
            out_specs=(P(*bspec, None, None, None, None), cs, cs,
                       P(*bspec, "model")),
            check_vma=False)(
            q[:, 0], state["k"], state["v"], state["kpos"],
            k_t, v_t, pos_b)
        y = y.astype(x_t.dtype)
    else:
        slot = pos_b % L if a.window is not None else pos_b     # (B,)
        upd = jnp.arange(L)[None, :] == slot[:, None]           # (B,L)
        k = jnp.where(upd[..., None, None], k_t.astype(state["k"].dtype),
                      state["k"])
        v = jnp.where(upd[..., None, None], v_t.astype(state["v"].dtype),
                      state["v"])
        kpos = jnp.where(upd, pos_b[:, None], state["kpos"])
        qg = q.reshape(B, 1, a.num_kv_heads, a.num_heads // a.num_kv_heads,
                       a.head_dim)
        acc, m, l = _batched_attn(qg, k, v, pos_b[:, None], kpos,
                                  causal=a.causal, window=a.window)
        y = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(x_t.dtype)
    y = dense(y.reshape(B, 1, a.num_heads * a.head_dim), params["w_o"])
    return y, {"k": k, "v": v, "kpos": kpos}, {}


# ---------------------------------------------------------------------------
# prefill: parallel attention over a whole prompt chunk + cache install
# ---------------------------------------------------------------------------

def attention_prefill(params, x, state, pos0, cfg, rt: Runtime):
    """x (B,S,D) prompt chunk at absolute positions [pos0, pos0+S).

    Runs the parallel (training-style) attention over the chunk — attending
    to any valid cached entries from earlier chunks — and installs the new
    K/V into the decode cache, so decode can continue token-by-token from
    ``pos0 + S``.  Returns (y, new_state, aux).  For a sliding-window cache
    only the last ``min(S, L)`` tokens are written (ring layout).
    """
    a = cfg.attention
    B, S, _ = x.shape
    pos0 = jnp.asarray(pos0, jnp.int32)
    positions = pos0 + jnp.arange(S)[None, :]                # (1,S)
    q, k, v = _project_qkv(params, x, cfg, rt, positions)
    kc, vc, kposc = state["k"], state["v"], state["kpos"]
    L = kc.shape[1]

    # attend over [cached entries | this chunk]; invalid slots carry kpos=-1
    k_all = jnp.concatenate([kc.astype(k.dtype), k], axis=1)
    v_all = jnp.concatenate([vc.astype(v.dtype), v], axis=1)
    kpos_new = jnp.broadcast_to(positions, (B, S))
    kpos_all = jnp.concatenate([kposc, kpos_new], axis=1)    # (B,L+S)
    qg = q.reshape(B, S, a.num_kv_heads, a.num_heads // a.num_kv_heads,
                   a.head_dim)
    acc, m, l = _batched_attn(qg, k_all, v_all,
                              jnp.broadcast_to(positions, (B, S)), kpos_all,
                              causal=a.causal, window=a.window)
    y = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(x.dtype)
    y = dense(y.reshape(B, S, a.num_heads * a.head_dim), params["w_o"])

    # cache install
    if a.window is None or S <= L:
        if a.window is None:
            # contiguous: requires pos0 + S <= L (engine admission invariant)
            k_new = jax.lax.dynamic_update_slice_in_dim(
                kc, k.astype(kc.dtype), pos0, 1)
            v_new = jax.lax.dynamic_update_slice_in_dim(
                vc, v.astype(vc.dtype), pos0, 1)
            kpos_out = jax.lax.dynamic_update_slice_in_dim(
                kposc, kpos_new, pos0, 1)
        else:
            slots = (pos0 + jnp.arange(S)) % L               # (S,) unique
            k_new = kc.at[:, slots].set(k.astype(kc.dtype))
            v_new = vc.at[:, slots].set(v.astype(vc.dtype))
            kpos_out = kposc.at[:, slots].set(kpos_new)
    else:
        # window ring smaller than the chunk: keep only the last L tokens
        T = L
        starts = pos0 + S - T + jnp.arange(T)
        slots = starts % L                                   # (T,) unique
        k_new = kc.at[:, slots].set(k[:, -T:].astype(kc.dtype))
        v_new = vc.at[:, slots].set(v[:, -T:].astype(vc.dtype))
        kpos_out = kposc.at[:, slots].set(
            jnp.broadcast_to(starts[None, :], (B, T)))
    return y, {"k": k_new, "v": v_new, "kpos": kpos_out}, {}
