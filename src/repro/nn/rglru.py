"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Structure (De et al., 2024): two input branches from d_model to d_rnn — a
GeLU gate branch and a recurrent branch (causal conv then RG-LRU) — merged
multiplicatively, then projected back.  The RG-LRU:

    r_t = sigmoid(W_a x_t + b_a)              (recurrence gate, block-diag)
    i_t = sigmoid(W_x x_t + b_x)              (input gate,      block-diag)
    a_t = a ** (c * r_t),  a = sigmoid(Lambda)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The diagonal recurrence runs through the chunked associative scan in
``kernels/ref.py``.  RoM expertizes ``w_rec_in`` / ``w_rec_gate`` / ``w_out``
(the large projections); gates, conv and Lambda stay shared across experts —
the same selective-expertization rule the paper applies to Mamba's small
dt/x projections (§4.3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ref import diag_recurrence
from repro.nn.layers import Runtime, dense, dense_init
from repro.nn.ssm import (causal_conv1d, causal_conv1d_prefill,
                          causal_conv1d_step)
from repro.serve.state import batch_spec


def rglru_dims(cfg):
    r = cfg.rglru
    d_rnn = r.d_rnn or cfg.d_model
    return d_rnn, r.num_heads, d_rnn // r.num_heads


def rglru_init_shared(key, cfg):
    """Conv + gates + Lambda — shared across RoM experts."""
    d_rnn, nh, dh = rglru_dims(cfg)
    r = cfg.rglru
    ks = jax.random.split(key, 4)
    u = jax.random.uniform(ks[3], (d_rnn,), jnp.float32, 0.9, 0.999)
    a = u ** (1.0 / r.c)                      # want a^c ~ U(0.9, 0.999)
    return {
        "conv_w": (jax.random.normal(ks[0], (r.conv_kernel, d_rnn)) *
                   (1.0 / r.conv_kernel)).astype(jnp.float32),
        "conv_b": jnp.zeros((d_rnn,), jnp.float32),
        "w_a_gate": (jax.random.normal(ks[1], (nh, dh, dh)) *
                     dh ** -0.5).astype(jnp.float32),
        "w_x_gate": (jax.random.normal(ks[2], (nh, dh, dh)) *
                     dh ** -0.5).astype(jnp.float32),
        "b_a_gate": jnp.zeros((d_rnn,), jnp.float32),
        "b_x_gate": jnp.zeros((d_rnn,), jnp.float32),
        "a_param": jnp.log(a / (1 - a)),      # logit(a)
    }


def rglru_init(key, cfg):
    ks = jax.random.split(key, 4)
    p = rglru_init_shared(ks[0], cfg)
    d_rnn, _, _ = rglru_dims(cfg)
    p["w_rec_in"] = dense_init(ks[1], cfg.d_model, d_rnn, dtype=cfg.param_dtype)
    p["w_rec_gate"] = dense_init(ks[2], cfg.d_model, d_rnn,
                                 dtype=cfg.param_dtype)
    p["w_out"] = dense_init(ks[3], d_rnn, cfg.d_model, dtype=cfg.param_dtype)
    return p


def _gates(shared, u, cfg):
    """u (..., d_rnn) -> (log_a_t, scaled input gate) in float32."""
    d_rnn, nh, dh = rglru_dims(cfg)
    uh = u.reshape(*u.shape[:-1], nh, dh).astype(jnp.float32)
    ra = jnp.einsum("...hd,hde->...he", uh, shared["w_a_gate"])
    rx = jnp.einsum("...hd,hde->...he", uh, shared["w_x_gate"])
    r = jax.nn.sigmoid(ra.reshape(*u.shape) + shared["b_a_gate"])
    i = jax.nn.sigmoid(rx.reshape(*u.shape) + shared["b_x_gate"])
    # log a_t = c * r_t * log sigmoid(Lambda) = -c * r_t * softplus(-Lambda)
    log_a = -cfg.rglru.c * r * jax.nn.softplus(-shared["a_param"])
    return log_a, i


def rglru_core(shared, u, cfg, rt: Runtime):
    """Recurrent branch: conv -> RG-LRU. u (B,S,R) -> (B,S,R)."""
    u = causal_conv1d(u, shared["conv_w"], shared["conv_b"])
    log_a, i = _gates(shared, u, cfg)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    b = mult * i * u.astype(jnp.float32)
    h = diag_recurrence(log_a, b, chunk=256)
    return h.astype(u.dtype)


def rglru_apply(params, x, cfg, rt: Runtime):
    u = dense(x, params["w_rec_in"])
    u = rt.shard.cons(u, "act_batch", "act_seq", "act_inner")
    h = rglru_core(params, u, cfg, rt)
    gate = jax.nn.gelu(dense(x, params["w_rec_gate"]))
    out = dense(h * gate, params["w_out"])
    return out, {}


def rglru_init_state(cfg, batch, dtype):
    d_rnn, _, _ = rglru_dims(cfg)
    k = cfg.rglru.conv_kernel
    return {"h": jnp.zeros((batch, d_rnn), jnp.float32),
            "conv": jnp.zeros((batch, k - 1, d_rnn), dtype)}


rglru_state_spec = batch_spec(rglru_init_state)


def rglru_core_step(shared, u_t, state, cfg, rt: Runtime, *, gate=None,
                    w_out=None):
    """Decode core.  With ``gate`` (B,R) and ``w_out`` (R,Dm) the gelu-gate ×
    output projection is handed to ``ops.rglru_step`` so the pallas impl
    fuses the whole tail; the result is then (B,Dm) instead of (B,R)."""
    u, conv_buf = causal_conv1d_step(u_t, state["conv"], shared["conv_w"],
                                     shared["conv_b"])
    log_a, i = _gates(shared, u, cfg)
    h, y = ops.rglru_step(state["h"], u, log_a, i, gate=gate, w_out=w_out)
    return y, {"h": h, "conv": conv_buf}


def rglru_step(params, x_t, state, pos, cfg, rt: Runtime):
    xt = x_t[:, 0]
    u_t = dense(xt, params["w_rec_in"])
    gate = jax.nn.gelu(dense(xt, params["w_rec_gate"]))
    out, state = rglru_core_step(params, u_t, state, cfg, rt, gate=gate,
                                 w_out=params["w_out"])
    return out[:, None], state, {}


def rglru_core_prefill(shared, u, state, cfg, rt: Runtime):
    """Parallel prefill over a prompt chunk: (h (B,S,R), terminal state)."""
    u_c, conv_buf = causal_conv1d_prefill(u, state["conv"], shared["conv_w"],
                                          shared["conv_b"])
    log_a, i = _gates(shared, u_c, cfg)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    b = mult * i * u_c.astype(jnp.float32)
    h, h_last = diag_recurrence(log_a, b, chunk=256, h0=state["h"],
                                return_state=True)
    return h.astype(u.dtype), {"h": h_last, "conv": conv_buf}


def rglru_prefill(params, x, state, pos0, cfg, rt: Runtime):
    u = dense(x, params["w_rec_in"])
    u = rt.shard.cons(u, "act_batch", "act_seq", "act_inner")
    h, state = rglru_core_prefill(params, u, state, cfg, rt)
    gate = jax.nn.gelu(dense(x, params["w_rec_gate"]))
    out = dense(h * gate, params["w_out"])
    return out, state, {}
