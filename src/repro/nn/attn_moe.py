"""Attention-MoE baselines from the paper's Table 1: MoA and SwitchHead.

Both are implemented in their mathematically exact *dense-compute* form
(every expert computes, masked combine).  These baselines exist for the
paper-comparison benchmarks (param/FLOP accounting + tiny-scale PPL proxy);
they are not perf-optimized — the paper's point is precisely that RoM beats
them at matched total parameters.

MoA (Mixture of Attention Heads, Zhang et al. 2022): experts are query-side
heads (W_q + W_o per expert); K/V are a single shared head (MQA-style).
Attention is linear in nothing here (softmax per expert), so experts run
densely and the router mixes their outputs.

SwitchHead (Csordas et al. 2023): per attention head, E value experts and E
output experts under one per-head router; Q/K are shared.  Because attention
is linear in V, mixing values *before* the attention product is exactly
equivalent to mixing expert outputs after — that identity makes the dense
form cheap: one attention per head, expert mixing on both sides.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.attention import blockwise_attention
from repro.nn.layers import Runtime, apply_rope, dense, dense_init


# ---------------------------------------------------------------------------
# MoA
# ---------------------------------------------------------------------------

def _moa_dim(cfg):
    # each MoA expert carries a full multi-head-width query/output transform
    # (the paper aligns MoA total params to RoM's 1.1B this way, Table 1)
    return cfg.attention.num_heads * cfg.attention.head_dim


def moa_init(key, cfg):
    a, m = cfg.attention, cfg.attn_moe
    d, dh = cfg.d_model, _moa_dim(cfg)
    ks = jax.random.split(key, 5)
    return {
        "e_w_q": (jax.random.normal(ks[0], (m.num_experts, d, dh)) *
                  d ** -0.5).astype(cfg.param_dtype),
        "e_w_o": (jax.random.normal(ks[1], (m.num_experts, dh, d)) *
                  dh ** -0.5).astype(cfg.param_dtype),
        "w_k": dense_init(ks[2], d, dh, dtype=cfg.param_dtype),
        "w_v": dense_init(ks[3], d, dh, dtype=cfg.param_dtype),
        "w_router": (jax.random.normal(ks[4], (d, m.num_experts)) *
                     d ** -0.5).astype(jnp.float32),
    }


def moa_apply(params, x, cfg, rt: Runtime):
    a, m = cfg.attention, cfg.attn_moe
    B, S, _ = x.shape
    E, dh = m.num_experts, _moa_dim(cfg)
    probs = jax.nn.softmax(
        (x.astype(jnp.float32) @ params["w_router"]), axis=-1)   # (B,S,E)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)
    mix = (jax.nn.one_hot(top_i, E, dtype=jnp.float32) *
           top_p[..., None]).sum(2)                              # (B,S,E)

    pos = jnp.arange(S)[None, :] + rt.pos_offset
    q = jnp.einsum("bsd,edh->bseh", x, params["e_w_q"].astype(x.dtype))
    k = dense(x, params["w_k"])[:, :, None, :]                   # (B,S,1,dh)
    v = dense(x, params["w_v"])[:, :, None, :]
    if a.use_rope:
        q = apply_rope(q, pos, a.rope_theta)
        k = apply_rope(k, pos, a.rope_theta)
    y = blockwise_attention(q, k, v, causal=a.causal, window=a.window,
                            q_block=a.q_block, kv_block=a.kv_block)
    # per-expert output proj, mixed by routing weights
    out = jnp.einsum("bseh,ehd,bse->bsd", y.astype(jnp.float32),
                     params["e_w_o"].astype(jnp.float32), mix)
    aux = {"entropy": -jnp.mean(jnp.sum(probs * jnp.log(probs + 1e-9), -1))}
    return out.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# SwitchHead
# ---------------------------------------------------------------------------

def switchhead_init(key, cfg):
    a, m = cfg.attention, cfg.attn_moe
    d, H, dh = cfg.d_model, a.num_heads, a.head_dim
    ks = jax.random.split(key, 5)
    return {
        "w_q": dense_init(ks[0], d, H * dh, dtype=cfg.param_dtype),
        "w_k": dense_init(ks[1], d, H * dh, dtype=cfg.param_dtype),
        "e_w_v": (jax.random.normal(ks[2], (m.num_experts, d, H * dh)) *
                  d ** -0.5).astype(cfg.param_dtype),
        "e_w_o": (jax.random.normal(ks[3], (m.num_experts, H * dh, d)) *
                  (H * dh) ** -0.5).astype(cfg.param_dtype),
        "w_router": (jax.random.normal(ks[4], (d, H * m.num_experts)) *
                     d ** -0.5).astype(jnp.float32),
    }


def switchhead_apply(params, x, cfg, rt: Runtime):
    a, m = cfg.attention, cfg.attn_moe
    B, S, _ = x.shape
    H, dh, E = a.num_heads, a.head_dim, m.num_experts
    logits = (x.astype(jnp.float32) @ params["w_router"]).reshape(B, S, H, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)
    mix = (jax.nn.one_hot(top_i, E, dtype=jnp.float32) *
           top_p[..., None]).sum(3)                              # (B,S,H,E)

    pos = jnp.arange(S)[None, :] + rt.pos_offset
    q = dense(x, params["w_q"]).reshape(B, S, H, dh)
    k = dense(x, params["w_k"]).reshape(B, S, H, dh)
    if a.use_rope:
        q = apply_rope(q, pos, a.rope_theta)
        k = apply_rope(k, pos, a.rope_theta)
    # value experts mixed pre-attention (exact: attention is linear in V)
    v_all = jnp.einsum("bsd,edh->bseh", x,
                       params["e_w_v"].astype(x.dtype))          # (B,S,E,H*dh)
    v_all = v_all.reshape(B, S, E, H, dh)
    v = jnp.einsum("bsehd,bshe->bshd", v_all.astype(jnp.float32),
                   mix).astype(x.dtype)
    y = blockwise_attention(q, k, v, causal=a.causal, window=a.window,
                            q_block=a.q_block, kv_block=a.kv_block)
    # output experts mixed post-attention (destination-side routing)
    yh = y.reshape(B, S, H, dh)
    o_all = jnp.einsum("bshd,ehdf->bshef", yh.astype(jnp.float32),
                       params["e_w_o"].astype(jnp.float32).reshape(
                           E, H, dh, cfg.d_model))
    out = jnp.einsum("bshef,bshe->bsf", o_all, mix)
    aux = {"entropy": -jnp.mean(jnp.sum(probs * jnp.log(probs + 1e-9), -1))}
    return out.astype(x.dtype), aux
