"""Base neural substrate: dense / norm / embedding / RoPE (pure JAX)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardCtx


@dataclasses.dataclass
class Runtime:
    """Per-call runtime context threaded through layer ``apply`` fns."""
    shard: ShardCtx
    rng: Optional[jax.Array] = None
    train: bool = False
    pos_offset: int = 0          # decode: absolute position of current token
    # multi-tenant serving (serve/expert_library.py): (B,) int32 — which of
    # the engine's bound expert sets each batch row (decode slot) uses.
    # None everywhere except the library-aware jitted decode steps, where
    # expert leaves arrive as per-set tuples and SharedRouting selects each
    # row's bound set's output.
    expert_sets: Optional[jax.Array] = None

    def with_rng(self, rng):
        return dataclasses.replace(self, rng=rng)


def _dtype(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# trace-time unroll mode: XLA cost_analysis counts loop bodies ONCE, so the
# dry-run's standalone block-cost lowering unrolls inner scans/maps to get
# exact per-layer FLOPs/bytes/collectives.  Bounded by ``cap`` (very long
# token-level recurrences stay loops; the residual undercount is recorded in
# EXPERIMENTS.md §Dry-run).  Never enabled for real execution.
# ---------------------------------------------------------------------------

_UNROLL = False
_UNROLL_CAP = 256


def set_unroll(flag: bool):
    global _UNROLL
    _UNROLL = bool(flag)


def unrolling() -> bool:
    return _UNROLL


def cost_scan(f, init, xs, length=None):
    """lax.scan that fully unrolls under cost-exact mode.

    ``unroll=True`` unrolls at HLO-build time (body traced once), so even
    hundreds of iterations lower quickly; trip counts beyond the cap stay
    loops (token-level recurrences) and their residual undercount is
    documented in EXPERIMENTS.md §Dry-run.
    """
    n = length
    if n is None:
        n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    unroll = bool(_UNROLL and n <= _UNROLL_CAP)
    return jax.lax.scan(f, init, xs, length=length, unroll=unroll)


def cost_map(f, n: int):
    """lax.map(f, arange(n)) that unrolls under cost-exact mode."""
    if not _UNROLL or n > _UNROLL_CAP:
        return jax.lax.map(f, jnp.arange(n))

    def body(carry, i):
        return carry, f(i)

    _, ys = jax.lax.scan(body, 0, jnp.arange(n), unroll=True)
    return ys


def dense_init(key, d_in, d_out, *, dtype="float32", scale=None):
    scale = (1.0 / (d_in ** 0.5)) if scale is None else scale
    w = jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out)) * scale
    return w.astype(_dtype(dtype))


def dense(x, w, b=None, *, compute_dtype=None):
    cd = compute_dtype or x.dtype
    y = jnp.einsum("...d,df->...f", x, w.astype(cd),
                   preferred_element_type=jnp.float32).astype(cd)
    if b is not None:
        y = y + b.astype(cd)
    return y


def rmsnorm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(x.dtype)


def embed_init(key, vocab, d, *, dtype="float32"):
    return jax.random.normal(key, (vocab, d)).astype(_dtype(dtype)) * 0.02


def embed_lookup(table, ids, compute_dtype):
    return jnp.take(table, ids, axis=0).astype(compute_dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (...,S,Dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                             # (...,S,1,Dh/2)
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


def softcap(x, cap):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap
