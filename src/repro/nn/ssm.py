"""SSM token mixers: Mamba (selective scan), Mamba-2 (SSD), Gated DeltaNet.

Each mixer is split into projections (the parts RoM expertizes) and a shared
core, so `core/rom.py` can reuse the cores with routed projections.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.nn.layers import Runtime, dense, dense_init, rmsnorm, silu
from repro.serve.state import batch_spec


# ---------------------------------------------------------------------------
# causal depthwise conv (kernel k, shared "Conv 1D" of the paper)
# ---------------------------------------------------------------------------

def causal_conv1d(x, w, b=None):
    """x (B,S,C); w (K,C). y_t = sum_k w[k] * x_{t-K+1+k}."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    S = x.shape[1]
    y = sum(xp[:, k:k + S, :] * w[k].astype(x.dtype) for k in range(K))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def causal_conv1d_step(x_t, buf, w, b=None):
    """x_t (B,C); buf (B,K-1,C) past inputs. Returns (y_t, new_buf)."""
    K = w.shape[0]
    win = jnp.concatenate([buf, x_t[:, None, :]], axis=1)       # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", win.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(x_t.dtype)
    if b is not None:
        y = y + b.astype(x_t.dtype)
    return y, win[:, 1:]


def causal_conv1d_prefill(x, buf, w, b=None):
    """Parallel conv over a whole prompt chunk, threading the decode buffer.

    x (B,S,C) new raw inputs; buf (B,K-1,C) past raw inputs (as kept by
    ``causal_conv1d_step``).  Returns (y (B,S,C), new_buf (B,K-1,C)) such
    that stepping token-by-token produces identical outputs and buffer.
    """
    K = w.shape[0]
    S = x.shape[1]
    xp = jnp.concatenate([buf.astype(x.dtype), x], axis=1)      # (B,K-1+S,C)
    y = sum(xp[:, k:k + S, :] * w[k].astype(x.dtype) for k in range(K))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y, xp[:, S:, :]


# ---------------------------------------------------------------------------
# Mamba (v1) — selective scan
# ---------------------------------------------------------------------------

def mamba_dims(cfg):
    m = cfg.mamba
    de = m.expand * cfg.d_model
    dt_rank = m.dt_rank or max(1, -(-cfg.d_model // 16))
    return de, dt_rank, m.d_state


def mamba_init_shared(key, cfg):
    """x Proj / dt Proj / Conv1D / A / D — shared across experts (§4.3)."""
    de, dt_rank, n = mamba_dims(cfg)
    m = cfg.mamba
    ks = jax.random.split(key, 4)
    dt = jnp.exp(jax.random.uniform(ks[3], (de,)) *
                 (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    b_dt = dt + jnp.log(-jnp.expm1(-dt))          # inverse softplus
    return {
        "conv_w": (jax.random.normal(ks[0], (m.conv_kernel, de)) *
                   (1.0 / m.conv_kernel)).astype(jnp.float32),
        "conv_b": jnp.zeros((de,), jnp.float32),
        "w_x": dense_init(ks[1], de, dt_rank + 2 * n, dtype=cfg.param_dtype),
        "w_dt": dense_init(ks[2], dt_rank, de, dtype=cfg.param_dtype,
                           scale=dt_rank ** -0.5),
        "b_dt": b_dt.astype(jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (de, 1))),
        "D": jnp.ones((de,), jnp.float32),
    }


def mamba_init(key, cfg):
    de, _, _ = mamba_dims(cfg)
    ks = jax.random.split(key, 4)
    p = mamba_init_shared(ks[0], cfg)
    p["w_in"] = dense_init(ks[1], cfg.d_model, de, dtype=cfg.param_dtype)
    p["w_gate"] = dense_init(ks[2], cfg.d_model, de, dtype=cfg.param_dtype)
    p["w_out"] = dense_init(ks[3], de, cfg.d_model, dtype=cfg.param_dtype)
    return p


def mamba_core(shared, h, cfg, rt: Runtime, *, x_proj_fn=None, dt_proj_fn=None):
    """Shared middle: conv -> x/dt proj -> selective scan. h (B,S,De) -> y."""
    de, dt_rank, n = mamba_dims(cfg)
    u = silu(causal_conv1d(h, shared["conv_w"], shared["conv_b"]))
    u = rt.shard.cons(u, "act_batch", "act_seq", "act_inner")
    xdbc = (x_proj_fn or (lambda t: dense(t, shared["w_x"])))(u)
    dt_in, Bm, Cm = jnp.split(xdbc, [dt_rank, dt_rank + n], axis=-1)
    dt_lin = (dt_proj_fn or (lambda t: dense(t, shared["w_dt"])))(dt_in)
    dt = jax.nn.softplus(dt_lin.astype(jnp.float32) + shared["b_dt"])
    A = -jnp.exp(shared["A_log"])
    y = ops.selective_scan(u, dt.astype(u.dtype), A, Bm, Cm, shared["D"],
                           chunk=cfg.mamba.chunk,
                           acc_dtype=cfg.mamba.scan_dtype)
    return rt.shard.cons(y, "act_batch", "act_seq", "act_inner")


def mamba_apply(params, x, cfg, rt: Runtime):
    h = dense(x, params["w_in"])
    h = rt.shard.cons(h, "act_batch", "act_seq", "act_inner")
    y = mamba_core(params, h, cfg, rt)
    g = silu(dense(x, params["w_gate"]))
    out = dense(y * g, params["w_out"])
    return out, {}


def mamba_init_state(cfg, batch, dtype):
    de, _, n = mamba_dims(cfg)
    k = cfg.mamba.conv_kernel
    return {"h": jnp.zeros((batch, de, n), jnp.float32),
            "conv": jnp.zeros((batch, k - 1, de), dtype)}


#: decode-state declaration (recurrent h + conv buffer, slots at axis 0)
mamba_state_spec = batch_spec(mamba_init_state)


def mamba_core_step(shared, h_t, state, cfg, rt: Runtime,
                    *, x_proj_fn=None, dt_proj_fn=None, gate=None,
                    w_out=None):
    """Decode core.  With ``gate`` (B,De) and ``w_out`` (De,Dm) the gating +
    output projection epilogue is handed to ``ops.selective_scan_step`` so
    the pallas impl fuses the whole tail into one kernel; the result is then
    the projected output (B,Dm) instead of the scan output (B,De)."""
    de, dt_rank, n = mamba_dims(cfg)
    u, conv_buf = causal_conv1d_step(h_t, state["conv"], shared["conv_w"],
                                     shared["conv_b"])
    u = silu(u)
    xdbc = (x_proj_fn or (lambda t: dense(t, shared["w_x"])))(u)
    dt_in, B_t, C_t = jnp.split(xdbc, [dt_rank, dt_rank + n], axis=-1)
    dt_lin = (dt_proj_fn or (lambda t: dense(t, shared["w_dt"])))(dt_in)
    dt = jax.nn.softplus(dt_lin.astype(jnp.float32) + shared["b_dt"])
    A = -jnp.exp(shared["A_log"])
    hs, y = ops.selective_scan_step(state["h"], u, dt.astype(u.dtype), A,
                                    B_t, C_t, shared["D"], gate=gate,
                                    w_out=w_out)
    return y, {"h": hs, "conv": conv_buf}


def mamba_step(params, x_t, state, pos, cfg, rt: Runtime):
    """x_t (B,1,D) decode step."""
    xt = x_t[:, 0]
    h_t = dense(xt, params["w_in"])
    g = silu(dense(xt, params["w_gate"]))
    out, state = mamba_core_step(params, h_t, state, cfg, rt, gate=g,
                                 w_out=params["w_out"])
    return out[:, None], state, {}


def mamba_core_prefill(shared, h, state, cfg, rt: Runtime,
                       *, x_proj_fn=None, dt_proj_fn=None):
    """Parallel-prefill core: one training-style scan over the whole chunk,
    returning (y (B,S,De), state) where state matches stepping token-by-token
    through ``mamba_core_step``.  Composable: threads an incoming state, so
    long prompts can be prefilled in fixed-size chunks."""
    de, dt_rank, n = mamba_dims(cfg)
    u_raw, conv_buf = causal_conv1d_prefill(h, state["conv"],
                                            shared["conv_w"],
                                            shared["conv_b"])
    u = silu(u_raw)
    u = rt.shard.cons(u, "act_batch", "act_seq", "act_inner")
    xdbc = (x_proj_fn or (lambda t: dense(t, shared["w_x"])))(u)
    dt_in, Bm, Cm = jnp.split(xdbc, [dt_rank, dt_rank + n], axis=-1)
    dt_lin = (dt_proj_fn or (lambda t: dense(t, shared["w_dt"])))(dt_in)
    dt = jax.nn.softplus(dt_lin.astype(jnp.float32) + shared["b_dt"])
    A = -jnp.exp(shared["A_log"])
    y, h_last = ops.selective_scan(u, dt.astype(u.dtype), A, Bm, Cm,
                                   shared["D"], chunk=cfg.mamba.chunk,
                                   acc_dtype=cfg.mamba.scan_dtype,
                                   h0=state["h"], return_state=True)
    y = rt.shard.cons(y, "act_batch", "act_seq", "act_inner")
    return y, {"h": h_last, "conv": conv_buf}


def mamba_prefill(params, x, state, pos0, cfg, rt: Runtime):
    """x (B,S,D) prompt chunk -> (y (B,S,D), terminal decode state, aux)."""
    h = dense(x, params["w_in"])
    h = rt.shard.cons(h, "act_batch", "act_seq", "act_inner")
    y, state = mamba_core_prefill(params, h, state, cfg, rt)
    g = silu(dense(x, params["w_gate"]))
    out = dense(y * g, params["w_out"])
    return out, state, {}


# ---------------------------------------------------------------------------
# Mamba-2 (SSD, scalar-per-head A), chunked dual form
# ---------------------------------------------------------------------------

def mamba2_dims(cfg):
    m = cfg.mamba2
    de = m.expand * cfg.d_model
    nheads = de // m.head_dim
    return de, nheads, m.head_dim, m.d_state


def mamba2_init(key, cfg):
    de, nh, hd, n = mamba2_dims(cfg)
    m = cfg.mamba2
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * de + 2 * n + nh                 # [z, x, B, C, dt]
    dt = jnp.exp(jax.random.uniform(ks[2], (nh,)) *
                 (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    return {
        "w_zxbcdt": dense_init(ks[0], cfg.d_model, d_in_proj,
                               dtype=cfg.param_dtype),
        "conv_w": (jax.random.normal(ks[1], (m.conv_kernel, de + 2 * n)) *
                   (1.0 / m.conv_kernel)).astype(jnp.float32),
        "conv_b": jnp.zeros((de + 2 * n,), jnp.float32),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "A_log_h": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D_h": jnp.ones((nh,), jnp.float32),
        "scale_inner": jnp.ones((de,), jnp.float32),
        "w_out": dense_init(ks[3], de, cfg.d_model, dtype=cfg.param_dtype),
    }


def _segsum(a):
    """a (...,c) -> (...,c,c) lower-tri cumulative sums: out[i,j]=sum(a[j+1..i])."""
    c = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, a_log, Bm, Cm, chunk, *, h0=None, return_state=False):
    """SSD dual form. x (B,S,H,P); a_log (B,S,H) (<=0); Bm,Cm (B,S,N).

    ``h0`` (B,H,P,N) threads an incoming recurrent state (prefill
    continuation); ``return_state`` additionally returns the terminal state.
    Zero-padded tail positions (x=0, a_log=0) are state-preserving, so S is
    padded up to a chunk multiple internally.
    """
    Bsz, S, H, Pd = x.shape
    N = Bm.shape[-1]
    c = min(chunk, S)
    if S % c:
        pad = c - S % c
        y = ssd_chunked(jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
                        jnp.pad(a_log, ((0, 0), (0, pad), (0, 0))),
                        jnp.pad(Bm, ((0, 0), (0, pad), (0, 0))),
                        jnp.pad(Cm, ((0, 0), (0, pad), (0, 0))),
                        chunk, h0=h0, return_state=return_state)
        if return_state:
            return y[0][:, :S], y[1]
        return y[:, :S]
    nc = S // c
    f32 = jnp.float32
    xc = x.reshape(Bsz, nc, c, H, Pd).astype(f32)
    ac = a_log.reshape(Bsz, nc, c, H).astype(f32)
    bc = Bm.reshape(Bsz, nc, c, N).astype(f32)
    cc = Cm.reshape(Bsz, nc, c, N).astype(f32)

    A_cum = jnp.cumsum(ac, axis=2)                              # (B,nc,c,H)
    L = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))              # (B,nc,H,c,c)
    # intra-chunk (diagonal blocks)
    scores = jnp.einsum("bzin,bzjn->bzij", cc, bc)              # (B,nc,c,c)
    y_diag = jnp.einsum("bzij,bzhij,bzjhp->bzihp", scores, L, xc)
    # chunk final states
    decay_states = jnp.exp(A_cum[:, :, -1:, :] - A_cum)         # (B,nc,c,H)
    states = jnp.einsum("bzjn,bzjh,bzjhp->bzhpn", bc, decay_states, xc)
    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(A_cum[:, :, -1, :])                   # (B,nc,H)

    def step(s_prev, inp):
        dec, st = inp                                           # (B,H), (B,H,P,N)
        s = s_prev * dec[..., None, None] + st
        return s, s_prev

    from repro.nn.layers import cost_scan
    s0 = h0.astype(f32) if h0 is not None else jnp.zeros((Bsz, H, Pd, N), f32)
    s_last, prev_states = cost_scan(
        step, s0, (chunk_decay.transpose(1, 0, 2),
                   states.transpose(1, 0, 2, 3, 4)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)          # (B,nc,H,P,N)
    state_decay = jnp.exp(A_cum)                                # (B,nc,c,H)
    y_off = jnp.einsum("bzin,bzih,bzhpn->bzihp", cc, state_decay, prev_states)
    y = (y_diag + y_off).reshape(Bsz, S, H, Pd)
    if return_state:
        return y.astype(x.dtype), s_last
    return y.astype(x.dtype)


def mamba2_core(shared, zxbcdt, cfg, rt: Runtime):
    """zxbcdt (B,S,2De+2N+H) -> y (B,S,De) (pre gated-norm)."""
    de, nh, hd, n = mamba2_dims(cfg)
    B_, S, _ = zxbcdt.shape
    z, xbc, dt_in = jnp.split(zxbcdt, [de, 2 * de + 2 * n], axis=-1)
    xbc = silu(causal_conv1d(xbc, shared["conv_w"], shared["conv_b"]))
    x, Bm, Cm = jnp.split(xbc, [de, de + n], axis=-1)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + shared["dt_bias"])
    A = -jnp.exp(shared["A_log_h"])                             # (H,)
    xh = x.reshape(B_, S, nh, hd)
    y = ssd_chunked(xh * dt[..., None].astype(x.dtype), dt * A, Bm, Cm,
                    cfg.mamba2.chunk)
    y = y + xh * shared["D_h"][:, None].astype(x.dtype)
    y = y.reshape(B_, S, de)
    y = rmsnorm({"scale": shared["scale_inner"]}, y * silu(z), cfg.norm_eps)
    return y


def mamba2_apply(params, x, cfg, rt: Runtime):
    zxbcdt = dense(x, params["w_zxbcdt"])
    y = mamba2_core(params, zxbcdt, cfg, rt)
    return dense(y, params["w_out"]), {}


def mamba2_init_state(cfg, batch, dtype):
    de, nh, hd, n = mamba2_dims(cfg)
    k = cfg.mamba2.conv_kernel
    return {"h": jnp.zeros((batch, nh, hd, n), jnp.float32),
            "conv": jnp.zeros((batch, k - 1, de + 2 * n), dtype)}


mamba2_state_spec = batch_spec(mamba2_init_state)


def mamba2_step(params, x_t, state, pos, cfg, rt: Runtime):
    de, nh, hd, n = mamba2_dims(cfg)
    xt = x_t[:, 0]
    zxbcdt = dense(xt, params["w_zxbcdt"])
    z, xbc, dt_in = jnp.split(zxbcdt, [de, 2 * de + 2 * n], axis=-1)
    xbc, conv_buf = causal_conv1d_step(xbc, state["conv"], params["conv_w"],
                                       params["conv_b"])
    xbc = silu(xbc)
    x_, B_t, C_t = jnp.split(xbc, [de, de + n], axis=-1)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + params["dt_bias"])
    xh = x_.reshape(-1, nh, hd).astype(jnp.float32)
    h, out = ops.mamba2_step(state["h"], xh, dt, params["A_log_h"], B_t, C_t,
                             params["D_h"], z, params["scale_inner"],
                             cfg.norm_eps, w_out=params["w_out"])
    return out[:, None], {"h": h, "conv": conv_buf}, {}


def mamba2_core_prefill(shared, zxbcdt, state, cfg, rt: Runtime):
    """zxbcdt (B,S,2De+2N+H) -> (y (B,S,De), terminal decode state)."""
    de, nh, hd, n = mamba2_dims(cfg)
    B_, S, _ = zxbcdt.shape
    z, xbc, dt_in = jnp.split(zxbcdt, [de, 2 * de + 2 * n], axis=-1)
    xbc_raw, conv_buf = causal_conv1d_prefill(xbc, state["conv"],
                                              shared["conv_w"],
                                              shared["conv_b"])
    xbc = silu(xbc_raw)
    x, Bm, Cm = jnp.split(xbc, [de, de + n], axis=-1)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + shared["dt_bias"])
    A = -jnp.exp(shared["A_log_h"])                             # (H,)
    xh = x.reshape(B_, S, nh, hd)
    y, h_last = ssd_chunked(xh * dt[..., None].astype(x.dtype), dt * A,
                            Bm, Cm, cfg.mamba2.chunk,
                            h0=state["h"], return_state=True)
    y = y + xh * shared["D_h"][:, None].astype(x.dtype)
    y = y.reshape(B_, S, de)
    y = rmsnorm({"scale": shared["scale_inner"]}, y * silu(z), cfg.norm_eps)
    return y, {"h": h_last, "conv": conv_buf}


def mamba2_prefill(params, x, state, pos0, cfg, rt: Runtime):
    zxbcdt = dense(x, params["w_zxbcdt"])
    y, state = mamba2_core_prefill(params, zxbcdt, state, cfg, rt)
    return dense(y, params["w_out"]), state, {}


# ---------------------------------------------------------------------------
# Gated DeltaNet:  S_t = a_t * S_{t-1} (I - b_t k_t k_t^T) + b_t v_t k_t^T
# ---------------------------------------------------------------------------

def gdn_dims(cfg):
    g = cfg.gdn
    dk = g.num_heads * g.head_dim
    dv = g.expand_v * dk
    return g.num_heads, g.head_dim, g.expand_v * g.head_dim, dk, dv


def gdn_init(key, cfg):
    nh, dk_h, dv_h, dk, dv = gdn_dims(cfg)
    g = cfg.gdn
    ks = jax.random.split(key, 4)
    return {
        "w_qkvz": dense_init(ks[0], cfg.d_model, 2 * dk + 2 * dv,
                             dtype=cfg.param_dtype),
        "w_ab": dense_init(ks[1], cfg.d_model, 2 * nh, dtype=cfg.param_dtype),
        "conv_w": (jax.random.normal(ks[2], (g.conv_kernel, 2 * dk + dv)) *
                   (1.0 / g.conv_kernel)).astype(jnp.float32),
        "conv_b": jnp.zeros((2 * dk + dv,), jnp.float32),
        "scale_inner": jnp.ones((dv,), jnp.float32),
        "w_out": dense_init(ks[3], dv, cfg.d_model, dtype=cfg.param_dtype),
    }


def _gdn_scan(q, k, v, a, b, *, S0=None, return_state=False):
    """q,k (B,S,H,Dk); v (B,S,H,Dv); a,b (B,S,H). Sequential delta rule."""
    f32 = jnp.float32

    def step(S, inp):
        qt, kt, vt, at, bt = inp
        # S (B,H,Dk,Dv)
        Sk = jnp.einsum("bhkv,bhk->bhv", S, kt)
        S = (S * at[..., None, None]
             - jnp.einsum("bhk,bhv->bhkv", kt * (at * bt)[..., None], Sk)
             + jnp.einsum("bhk,bhv->bhkv", kt * bt[..., None], vt))
        y = jnp.einsum("bhkv,bhk->bhv", S, qt)
        return S, y

    B_, S_, H, Dk = q.shape
    Dv = v.shape[-1]
    if S0 is None:
        S0 = jnp.zeros((B_, H, Dk, Dv), f32)
    xs = (q.transpose(1, 0, 2, 3).astype(f32), k.transpose(1, 0, 2, 3).astype(f32),
          v.transpose(1, 0, 2, 3).astype(f32), a.transpose(1, 0, 2).astype(f32),
          b.transpose(1, 0, 2).astype(f32))
    S_last, ys = jax.lax.scan(step, S0.astype(f32), xs)
    ys = ys.transpose(1, 0, 2, 3)                               # (B,S,H,Dv)
    if return_state:
        return ys, S_last
    return ys


def gdn_core(shared, qkvz, ab, cfg, rt: Runtime):
    nh, dk_h, dv_h, dk, dv = gdn_dims(cfg)
    B_, S, _ = qkvz.shape
    qkv, z = jnp.split(qkvz, [2 * dk + dv], axis=-1)
    qkv = silu(causal_conv1d(qkv, shared["conv_w"], shared["conv_b"]))
    q, k, v = jnp.split(qkv, [dk, 2 * dk], axis=-1)
    q = q.reshape(B_, S, nh, dk_h)
    k = k.reshape(B_, S, nh, dk_h)
    v = v.reshape(B_, S, nh, dv_h)
    q = q / jnp.linalg.norm(q, axis=-1, keepdims=True).clip(1e-6)
    k = k / jnp.linalg.norm(k, axis=-1, keepdims=True).clip(1e-6)
    a_in, b_in = jnp.split(ab, 2, axis=-1)
    a = jnp.exp(-jnp.exp(jnp.clip(a_in.astype(jnp.float32), -8, 3)))  # decay
    b = jax.nn.sigmoid(b_in.astype(jnp.float32))
    y = _gdn_scan(q, k, v, a, b).reshape(B_, S, dv).astype(qkvz.dtype)
    y = rmsnorm({"scale": shared["scale_inner"]}, y * silu(z), cfg.norm_eps)
    return y


def gdn_apply(params, x, cfg, rt: Runtime):
    qkvz = dense(x, params["w_qkvz"])
    ab = dense(x, params["w_ab"])
    y = gdn_core(params, qkvz, ab, cfg, rt)
    return dense(y, params["w_out"]), {}


def gdn_init_state(cfg, batch, dtype):
    nh, dk_h, dv_h, dk, dv = gdn_dims(cfg)
    return {"S": jnp.zeros((batch, nh, dk_h, dv_h), jnp.float32),
            "conv": jnp.zeros((batch, cfg.gdn.conv_kernel - 1, 2 * dk + dv),
                              dtype)}


gdn_state_spec = batch_spec(gdn_init_state)


def gdn_step(params, x_t, state, pos, cfg, rt: Runtime):
    nh, dk_h, dv_h, dk, dv = gdn_dims(cfg)
    xt = x_t[:, 0]
    qkvz = dense(xt, params["w_qkvz"])
    ab = dense(xt, params["w_ab"])
    qkv, z = jnp.split(qkvz, [2 * dk + dv], axis=-1)
    qkv, conv_buf = causal_conv1d_step(qkv, state["conv"], params["conv_w"],
                                       params["conv_b"])
    qkv = silu(qkv)
    q, k, v = jnp.split(qkv, [dk, 2 * dk], axis=-1)
    B_ = xt.shape[0]
    q = q.reshape(B_, nh, dk_h)
    k = k.reshape(B_, nh, dk_h)
    v = v.reshape(B_, nh, dv_h)
    q = q / jnp.linalg.norm(q, axis=-1, keepdims=True).clip(1e-6)
    k = k / jnp.linalg.norm(k, axis=-1, keepdims=True).clip(1e-6)
    a_in, b_in = jnp.split(ab, 2, axis=-1)
    a = jnp.exp(-jnp.exp(jnp.clip(a_in.astype(jnp.float32), -8, 3)))
    b = jax.nn.sigmoid(b_in.astype(jnp.float32))
    S, out = ops.gdn_step(state["S"], q, k, v, a, b, z,
                          params["scale_inner"], cfg.norm_eps,
                          w_out=params["w_out"])
    return out[:, None], {"S": S, "conv": conv_buf}, {}


def gdn_core_prefill(shared, qkvz, ab, state, cfg, rt: Runtime):
    """Parallel GDN prefill: (y (B,S,Dv), terminal decode state)."""
    nh, dk_h, dv_h, dk, dv = gdn_dims(cfg)
    B_, S, _ = qkvz.shape
    qkv, z = jnp.split(qkvz, [2 * dk + dv], axis=-1)
    qkv_raw, conv_buf = causal_conv1d_prefill(qkv, state["conv"],
                                              shared["conv_w"],
                                              shared["conv_b"])
    qkv = silu(qkv_raw)
    q, k, v = jnp.split(qkv, [dk, 2 * dk], axis=-1)
    q = q.reshape(B_, S, nh, dk_h)
    k = k.reshape(B_, S, nh, dk_h)
    v = v.reshape(B_, S, nh, dv_h)
    q = q / jnp.linalg.norm(q, axis=-1, keepdims=True).clip(1e-6)
    k = k / jnp.linalg.norm(k, axis=-1, keepdims=True).clip(1e-6)
    a_in, b_in = jnp.split(ab, 2, axis=-1)
    a = jnp.exp(-jnp.exp(jnp.clip(a_in.astype(jnp.float32), -8, 3)))
    b = jax.nn.sigmoid(b_in.astype(jnp.float32))
    ys, S_last = _gdn_scan(q, k, v, a, b, S0=state["S"], return_state=True)
    y = ys.reshape(B_, S, dv).astype(qkvz.dtype)
    y = rmsnorm({"scale": shared["scale_inner"]}, y * silu(z), cfg.norm_eps)
    return y, {"S": S_last, "conv": conv_buf}


def gdn_prefill(params, x, state, pos0, cfg, rt: Runtime):
    qkvz = dense(x, params["w_qkvz"])
    ab = dense(x, params["w_ab"])
    y, state = gdn_core_prefill(params, qkvz, ab, state, cfg, rt)
    return dense(y, params["w_out"]), state, {}
