"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory w/ recurrence).

mLSTM (Beck et al., 2024), per head with exponential gating + stabilizer m:

    C_t = f'_t C_{t-1} + i'_t v_t k_t^T      n_t = f'_t n_{t-1} + i'_t k_t
    y_t = C_t q_t / max(|n_t . q_t|, 1)
    m_t = max(logsigmoid(f~) + m_{t-1}, i~);  f' = exp(lsig(f~)+m_{t-1}-m_t)

Projections (RoM targets): ``w_in`` (up), ``w_gate`` (z branch), ``w_out``
(down).  qk/v/if projections + conv are shared across experts — the paper's
selective-expertization rule.

sLSTM keeps per-head block-diagonal *recurrent* gate weights (h_{t-1} feeds
the gates), so it is strictly sequential; it follows the original xLSTM
block layout with a small post-FFN folded in.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.nn.layers import Runtime, dense, dense_init, silu
from repro.nn.ssm import (causal_conv1d, causal_conv1d_prefill,
                          causal_conv1d_step)
from repro.serve.state import batch_spec


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_dims(cfg):
    c = cfg.xlstm
    inner = c.expand * cfg.d_model
    qk = int(c.qk_ratio * inner)
    nh = c.num_heads
    return inner, qk, nh, qk // nh, inner // nh


def mlstm_init_shared(key, cfg):
    inner, qk, nh, dqk, dv = mlstm_dims(cfg)
    c = cfg.xlstm
    ks = jax.random.split(key, 4)
    # forget-gate bias init: positive (remember by default)
    b_if = jnp.concatenate([jnp.full((nh,), -1.0), jnp.full((nh,), 3.0)])
    return {
        "conv_w": (jax.random.normal(ks[0], (c.conv_kernel, inner)) *
                   (1.0 / c.conv_kernel)).astype(jnp.float32),
        "conv_b": jnp.zeros((inner,), jnp.float32),
        "w_qk": dense_init(ks[1], inner, 2 * qk, dtype=cfg.param_dtype),
        "w_v2": dense_init(ks[2], inner, inner, dtype=cfg.param_dtype),
        "w_if": dense_init(ks[3], inner, 2 * nh, dtype=cfg.param_dtype),
        "b_if": b_if.astype(jnp.float32),
        "gn_scale": jnp.ones((inner,), jnp.float32),
    }


def mlstm_init(key, cfg):
    inner, *_ = mlstm_dims(cfg)
    ks = jax.random.split(key, 4)
    p = mlstm_init_shared(ks[0], cfg)
    p["w_in"] = dense_init(ks[1], cfg.d_model, inner, dtype=cfg.param_dtype)
    p["w_gate"] = dense_init(ks[2], cfg.d_model, inner, dtype=cfg.param_dtype)
    p["w_out"] = dense_init(ks[3], inner, cfg.d_model, dtype=cfg.param_dtype)
    return p


def _headnorm(y, scale, eps):
    """RMS norm within each head, then per-channel scale. y (...,H,Dv)."""
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yn = yf * jax.lax.rsqrt(var + eps)
    flat = yn.reshape(*y.shape[:-2], -1) * scale
    return flat


def _mlstm_scan(q, k, v, i_log, f_log, *, carry0=None, return_state=False):
    """q,k (B,S,H,Dqk); v (B,S,H,Dv); i_log,f_log (B,S,H) -> y (B,S,H,Dv)."""
    f32 = jnp.float32

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, il, fl = inp
        m_new = jnp.maximum(fl + m, il)
        fp = jnp.exp(fl + m - m_new)
        ip = jnp.exp(il - m_new)
        C = fp[..., None, None] * C + ip[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = fp[..., None] * n + ip[..., None] * kt
        num = jnp.einsum("bhkv,bhk->bhv", C, qt)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt))
        y = num / jnp.maximum(den, 1.0)[..., None]
        return (C, n, m_new), y

    B, S, H, Dqk = q.shape
    Dv = v.shape[-1]
    carry = carry0 if carry0 is not None else (
        jnp.zeros((B, H, Dqk, Dv), f32), jnp.zeros((B, H, Dqk), f32),
        jnp.zeros((B, H), f32))
    carry = tuple(c.astype(f32) for c in carry)
    xs = (q.transpose(1, 0, 2, 3).astype(f32),
          k.transpose(1, 0, 2, 3).astype(f32),
          v.transpose(1, 0, 2, 3).astype(f32),
          i_log.transpose(1, 0, 2).astype(f32),
          f_log.transpose(1, 0, 2).astype(f32))
    carry, ys = jax.lax.scan(step, carry, xs)
    ys = ys.transpose(1, 0, 2, 3)
    if return_state:
        return ys, carry
    return ys


def _mlstm_chunked(q, k, v, i_log, f_log, chunk, *, carry0=None,
                   return_state=False):
    """Chunkwise-parallel mLSTM (same math, O(S/c) sequential steps).

    Within a chunk the gated attention matrix D is formed directly from
    cumulative log-f; across chunks the (Dqk, Dv) state recurs once per
    chunk.  Beyond-paper perf path for long prefill (see EXPERIMENTS §Perf).
    ``carry0`` threads an incoming (C, n, m) state; ``return_state``
    additionally returns the terminal one.  Tail positions padded with
    i_log=-inf / f_log=0 are state-preserving, so S is padded internally.
    """
    f32 = jnp.float32
    B, S, H, Dqk = q.shape
    Dv = v.shape[-1]
    c = min(chunk, S)
    if S % c:
        pad = c - S % c
        padded = _mlstm_chunked(
            jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(i_log, ((0, 0), (0, pad), (0, 0)),
                    constant_values=-1e30),
            jnp.pad(f_log, ((0, 0), (0, pad), (0, 0))),
            chunk, carry0=carry0, return_state=return_state)
        if return_state:
            return padded[0][:, :S], padded[1]
        return padded[:, :S]
    nc = S // c
    qc = q.reshape(B, nc, c, H, Dqk).astype(f32)
    kc = k.reshape(B, nc, c, H, Dqk).astype(f32)
    vc = v.reshape(B, nc, c, H, Dv).astype(f32)
    il = i_log.reshape(B, nc, c, H).astype(f32)
    fl = f_log.reshape(B, nc, c, H).astype(f32)
    fcum = jnp.cumsum(fl, axis=2)                       # (B,nc,c,H)
    ftot = fcum[:, :, -1, :]                            # (B,nc,H)

    # intra-chunk: D[i,j] = exp(fcum_i - fcum_j + il_j), j <= i (stabilized)
    lj = il - fcum                                      # (B,nc,c,H)
    # stabilizer per (chunk, head): max over j of lj and the inbound state mag
    m_intra = jnp.max(lj, axis=2)                       # (B,nc,H)

    # inter-chunk recurrence over chunk boundary states
    def step(carry, inp):
        C, n, m = carry                                 # (B,H,Dqk,Dv) ...
        kcx, vcx, ljx, fcx, ftx, mix = inp
        # state scale entering the next chunk = sequential m at chunk end:
        # ftot + max(m_inbound, max_j lj_j)
        m_new = ftx + jnp.maximum(m, mix)               # (B,H)
        # this chunk's token contributions: exp(il_j + ftot - fcum_j - m_new)
        w = jnp.exp(ljx + ftx[:, None] - m_new[:, None])            # (B,c,H)
        C_new = jnp.exp(ftx + m - m_new)[..., None, None] * C + jnp.einsum(
            "bch,bchk,bchv->bhkv", w, kcx, vcx)
        n_new = jnp.exp(ftx + m - m_new)[..., None] * n + jnp.einsum(
            "bch,bchk->bhk", w, kcx)
        return (C_new, n_new, m_new), (C, n, m)

    # m starts at 0 (matching the sequential cell): the stabilizer enters the
    # value through max(|n.q|, exp(-m)), so the init is part of the function.
    if carry0 is None:
        carry0 = (jnp.zeros((B, H, Dqk, Dv), f32), jnp.zeros((B, H, Dqk), f32),
                  jnp.zeros((B, H), f32))
    carry0 = tuple(x.astype(f32) for x in carry0)
    from repro.nn.layers import cost_scan
    xs = (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
          lj.transpose(1, 0, 2, 3), fcum.transpose(1, 0, 2, 3),
          ftot.transpose(1, 0, 2), m_intra.transpose(1, 0, 2))
    carry_last, (C_in, n_in, m_in) = cost_scan(step, carry0, xs)
    C_in = C_in.transpose(1, 0, 2, 3, 4)                # (B,nc,H,Dqk,Dv)
    n_in = n_in.transpose(1, 0, 2, 3)
    m_in = m_in.transpose(1, 0, 2)                      # (B,nc,H)

    # per-position stabilizer: max(intra candidates j<=i, inbound state scale)
    m_run = jax.lax.cummax(lj, axis=2)                  # (B,nc,c,H)
    m_tok = fcum + jnp.maximum(m_in[:, :, None, :], m_run)  # (B,nc,c,H)

    # intra-chunk scores: exp(fcum_i + lj_j - m_tok_i) for j<=i
    sij = (fcum[:, :, :, None, :] + lj[:, :, None, :, :]
           - m_tok[:, :, :, None, :])                   # (B,nc,i,j,H)
    mask = jnp.tril(jnp.ones((c, c), bool))
    Dmat = jnp.where(mask[None, None, :, :, None], jnp.exp(sij), 0.0)
    scores = jnp.einsum("bzihk,bzjhk->bzijh", qc, kc)   # (B,nc,i,j,H)
    y_intra = jnp.einsum("bzijh,bzijh,bzjhv->bzihv", scores, Dmat, vc)
    qn_intra = jnp.einsum("bzijh,bzijh->bzih", scores, Dmat)   # q.n intra

    # inter-chunk: decay inbound state to position i
    dec = jnp.exp(fcum + m_in[:, :, None, :] - m_tok)   # (B,nc,c,H)
    y_inter = jnp.einsum("bzch,bzchk,bzhkv->bzchv", dec, qc, C_in)
    qn_inter = jnp.einsum("bzch,bzchk,bzhk->bzch", dec, qc, n_in)

    num = y_intra + y_inter                             # (B,nc,c,H,Dv)
    # sequential cell clamps the *scaled* denominator at 1 (its n, q carry
    # the exp(-m) scale already), so the chunked clamp is also exactly 1.
    den = jnp.maximum(jnp.abs(qn_intra + qn_inter), 1.0)
    y = num / den[..., None]
    y = y.reshape(B, S, H, Dv)
    if return_state:
        return y, carry_last
    return y


def mlstm_core(shared, h, z, cfg, rt: Runtime, *, chunked=False):
    """h (B,S,inner) pre-conv input branch; z gate branch."""
    inner, qk, nh, dqk, dv = mlstm_dims(cfg)
    B, S, _ = h.shape
    c = silu(causal_conv1d(h, shared["conv_w"], shared["conv_b"]))
    qkv = dense(c, shared["w_qk"])
    q, k = jnp.split(qkv, 2, axis=-1)
    v = dense(h, shared["w_v2"])
    q = q.reshape(B, S, nh, dqk)
    k = k.reshape(B, S, nh, dqk) * (dqk ** -0.5)
    v = v.reshape(B, S, nh, dv)
    if_ = dense(c, shared["w_if"]).astype(jnp.float32) + shared["b_if"]
    i_log, f_pre = jnp.split(if_, 2, axis=-1)           # (B,S,H)
    f_log = -jax.nn.softplus(-f_pre)                    # logsigmoid
    fn = _mlstm_chunked if chunked else _mlstm_scan
    if chunked:
        y = fn(q, k, v, i_log, f_log, cfg.xlstm.chunk)
    else:
        y = fn(q, k, v, i_log, f_log)
    y = _headnorm(y, shared["gn_scale"], cfg.norm_eps).astype(h.dtype)
    return y * silu(z)


def mlstm_apply(params, x, cfg, rt: Runtime):
    h = dense(x, params["w_in"])
    h = rt.shard.cons(h, "act_batch", "act_seq", "act_inner")
    z = dense(x, params["w_gate"])
    y = mlstm_core(params, h, z, cfg, rt, chunked=cfg.xlstm.chunk > 0)
    return dense(y, params["w_out"]), {}


def mlstm_init_state(cfg, batch, dtype):
    inner, qk, nh, dqk, dv = mlstm_dims(cfg)
    k = cfg.xlstm.conv_kernel
    return {"C": jnp.zeros((batch, nh, dqk, dv), jnp.float32),
            "n": jnp.zeros((batch, nh, dqk), jnp.float32),
            "m": jnp.zeros((batch, nh), jnp.float32),
            "conv": jnp.zeros((batch, k - 1, inner), dtype)}


mlstm_state_spec = batch_spec(mlstm_init_state)


def mlstm_core_step(shared, h_t, z_t, state, cfg, rt: Runtime, *, w_out=None):
    """Decode core.  With ``w_out`` (inner,Dm) the headnorm/gate + output
    projection tail runs inside ``ops.mlstm_step`` (fused on pallas); the
    result is then (B,Dm) instead of (B,inner)."""
    inner, qk, nh, dqk, dv = mlstm_dims(cfg)
    B = h_t.shape[0]
    c, conv_buf = causal_conv1d_step(h_t, state["conv"], shared["conv_w"],
                                     shared["conv_b"])
    c = silu(c)
    qkv = dense(c, shared["w_qk"])
    q, k = jnp.split(qkv, 2, axis=-1)
    v = dense(h_t, shared["w_v2"])
    q = q.reshape(B, nh, dqk).astype(jnp.float32)
    k = (k.reshape(B, nh, dqk) * (dqk ** -0.5)).astype(jnp.float32)
    v = v.reshape(B, nh, dv).astype(jnp.float32)
    if_ = dense(c, shared["w_if"]).astype(jnp.float32) + shared["b_if"]
    il, fp = jnp.split(if_, 2, axis=-1)
    fl = -jax.nn.softplus(-fp)
    C, n, m, y = ops.mlstm_step(state["C"], state["n"], state["m"], q, k, v,
                                il, fl, z_t, shared["gn_scale"],
                                cfg.norm_eps, w_out=w_out)
    return y, {"C": C, "n": n, "m": m, "conv": conv_buf}


def mlstm_step(params, x_t, state, pos, cfg, rt: Runtime):
    xt = x_t[:, 0]
    h_t = dense(xt, params["w_in"])
    z_t = dense(xt, params["w_gate"])
    out, state = mlstm_core_step(params, h_t, z_t, state, cfg, rt,
                                 w_out=params["w_out"])
    return out[:, None], state, {}


def mlstm_core_prefill(shared, h, z, state, cfg, rt: Runtime, *,
                       chunked=False):
    """Parallel prefill core: (y (B,S,inner), terminal decode state)."""
    inner, qk, nh, dqk, dv = mlstm_dims(cfg)
    B, S, _ = h.shape
    c_raw, conv_buf = causal_conv1d_prefill(h, state["conv"],
                                            shared["conv_w"],
                                            shared["conv_b"])
    c = silu(c_raw)
    qkv = dense(c, shared["w_qk"])
    q, k = jnp.split(qkv, 2, axis=-1)
    v = dense(h, shared["w_v2"])
    q = q.reshape(B, S, nh, dqk)
    k = k.reshape(B, S, nh, dqk) * (dqk ** -0.5)
    v = v.reshape(B, S, nh, dv)
    if_ = dense(c, shared["w_if"]).astype(jnp.float32) + shared["b_if"]
    i_log, f_pre = jnp.split(if_, 2, axis=-1)           # (B,S,H)
    f_log = -jax.nn.softplus(-f_pre)                    # logsigmoid
    carry0 = (state["C"], state["n"], state["m"])
    if chunked:
        y, carry = _mlstm_chunked(q, k, v, i_log, f_log, cfg.xlstm.chunk,
                                  carry0=carry0, return_state=True)
    else:
        y, carry = _mlstm_scan(q, k, v, i_log, f_log, carry0=carry0,
                               return_state=True)
    y = _headnorm(y, shared["gn_scale"], cfg.norm_eps).astype(h.dtype)
    C_l, n_l, m_l = carry
    return y * silu(z), {"C": C_l, "n": n_l, "m": m_l, "conv": conv_buf}


def mlstm_prefill(params, x, state, pos0, cfg, rt: Runtime):
    h = dense(x, params["w_in"])
    h = rt.shard.cons(h, "act_batch", "act_seq", "act_inner")
    z = dense(x, params["w_gate"])
    y, state = mlstm_core_prefill(params, h, z, state, cfg, rt,
                                  chunked=cfg.xlstm.chunk > 0)
    return dense(y, params["w_out"]), state, {}


# ---------------------------------------------------------------------------
# sLSTM — scalar memory, recurrent gates (strictly sequential)
# ---------------------------------------------------------------------------

def slstm_dims(cfg):
    nh = cfg.xlstm.num_heads
    inner = cfg.d_model
    return inner, nh, inner // nh


def slstm_init(key, cfg):
    inner, nh, dh = slstm_dims(cfg)
    ks = jax.random.split(key, 5)
    d_ff = int(cfg.xlstm.slstm_ff * cfg.d_model)
    b = jnp.zeros((4 * inner,), jnp.float32)
    b = b.at[inner:2 * inner].set(3.0)          # forget bias
    return {
        "w_slstm": dense_init(ks[0], cfg.d_model, 4 * inner,
                              dtype=cfg.param_dtype),
        "r_slstm": (jax.random.normal(ks[1], (nh, dh, 4 * dh)) *
                    dh ** -0.5).astype(jnp.float32),
        "b_slstm": b,
        "gn_scale": jnp.ones((inner,), jnp.float32),
        "w_up": dense_init(ks[2], inner, d_ff, dtype=cfg.param_dtype),
        "w_gate_ffn": dense_init(ks[3], inner, d_ff, dtype=cfg.param_dtype),
        "w_down": dense_init(ks[4], d_ff, inner, dtype=cfg.param_dtype),
    }


def _slstm_cell(params, gx, carry, cfg):
    """gx (B,4*inner) pre-activation from x; carry (c, n, h, m) heads (B,H,dh)."""
    inner, nh, dh = slstm_dims(cfg)
    c_, n_, h_, m_ = carry
    rec = jnp.einsum("bhd,hdg->bhg", h_, params["r_slstm"])      # (B,H,4dh)
    g = gx.reshape(-1, nh, 4 * dh).astype(jnp.float32) + rec \
        + params["b_slstm"].reshape(nh, 4 * dh)
    il, fp, z, o = jnp.split(g, 4, axis=-1)                      # (B,H,dh)
    fl = -jax.nn.softplus(-fp)                                   # logsigmoid
    m_new = jnp.maximum(fl + m_, il)
    i = jnp.exp(il - m_new)
    f = jnp.exp(fl + m_ - m_new)
    c_new = f * c_ + i * jnp.tanh(z)
    n_new = f * n_ + i
    h_new = jax.nn.sigmoid(o) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_apply(params, x, cfg, rt: Runtime):
    inner, nh, dh = slstm_dims(cfg)
    B, S, _ = x.shape
    gx = dense(x, params["w_slstm"])

    def step(carry, g_t):
        return _slstm_cell(params, g_t, carry, cfg)

    z0 = jnp.zeros((B, nh, dh), jnp.float32)
    carry = (z0, z0, z0, jnp.full((B, nh, dh), -1e30, jnp.float32))
    _, hs = jax.lax.scan(step, carry, gx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2, 3)                                 # (B,S,H,dh)
    h = _headnorm(h, params["gn_scale"], cfg.norm_eps).astype(x.dtype)
    # folded post-FFN (xLSTM block layout)
    u = dense(h, params["w_up"]) * silu(dense(h, params["w_gate_ffn"]))
    return dense(u, params["w_down"]), {}


def slstm_init_state(cfg, batch, dtype):
    inner, nh, dh = slstm_dims(cfg)
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return {"c": z, "n": z, "h": z,
            "m": jnp.full((batch, nh, dh), -1e30, jnp.float32)}


slstm_state_spec = batch_spec(slstm_init_state)


def slstm_step(params, x_t, state, pos, cfg, rt: Runtime):
    xt = x_t[:, 0]
    gx = dense(xt, params["w_slstm"])
    c, n, h, m, out = ops.slstm_step(state["c"], state["n"], state["h"],
                                     state["m"], gx, params["r_slstm"],
                                     params["b_slstm"], params["gn_scale"],
                                     cfg.norm_eps, w_up=params["w_up"],
                                     w_gate=params["w_gate_ffn"],
                                     w_down=params["w_down"])
    return out[:, None], {"c": c, "n": n, "h": h, "m": m}, {}


def slstm_prefill(params, x, state, pos0, cfg, rt: Runtime):
    """sLSTM is strictly sequential; prefill is one fused lax.scan over the
    chunk (still one jit call instead of S) threading the decode carry."""
    gx = dense(x, params["w_slstm"])

    def step(carry, g_t):
        return _slstm_cell(params, g_t, carry, cfg)

    carry = (state["c"], state["n"], state["h"], state["m"])
    carry, hs = jax.lax.scan(step, carry, gx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2, 3)                                 # (B,S,H,dh)
    h = _headnorm(h, params["gn_scale"], cfg.norm_eps).astype(x.dtype)
    u = dense(h, params["w_up"]) * silu(dense(h, params["w_gate_ffn"]))
    out = dense(u, params["w_down"])
    return out, dict(zip(("c", "n", "h", "m"), carry)), {}
