"""Dense channel mixers: SwiGLU / GeGLU / GELU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.layers import Runtime, dense, dense_init, silu


def mlp_init(key, cfg, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], cfg.d_model, d_ff, dtype=cfg.param_dtype),
         "w_down": dense_init(ks[1], d_ff, cfg.d_model, dtype=cfg.param_dtype)}
    if cfg.mlp_act in ("swiglu", "geglu"):
        p["w_gate_ffn"] = dense_init(ks[2], cfg.d_model, d_ff,
                                     dtype=cfg.param_dtype)
    return p


def mlp_apply(params, x, cfg, rt: Runtime):
    h = dense(x, params["w_up"])
    if cfg.mlp_act == "swiglu":
        h = h * silu(dense(x, params["w_gate_ffn"]))
    elif cfg.mlp_act == "geglu":
        h = h * jax.nn.gelu(dense(x, params["w_gate_ffn"]))
    else:
        h = jax.nn.gelu(h)
    h = rt.shard.cons(h, "act_batch", "act_seq", "act_mlp")
    return dense(h, params["w_down"]), {}
