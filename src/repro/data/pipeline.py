"""Deterministic synthetic data pipeline (restart-safe by construction).

Every batch is a pure function of ``(seed, step)`` — the pipeline carries no
state, so checkpoint/restart resumes *exactly* (a property the fault-
tolerance tests rely on), and elastic re-runs produce identical token
streams regardless of host count.

Two corpora:

``TokenCorpus``   packed LM documents: geometric doc lengths, EOS=1
                  separators — shape-realistic but unlearnable noise
                  (used for throughput/step benchmarks).

``MarkovCorpus``  R latent regimes, each a distinct random transition
                  matrix; documents sample a regime then a Markov chain.
                  Mixture structure is learnable and *specializable* — the
                  PPL-proxy benchmark uses it to reproduce the paper's
                  dense < MoE-Mamba < RoM quality ordering at tiny scale.

Encoder/VLM variants emit frame/patch embeddings per the spec's stubbed
modality frontends.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _rng(seed: int, step: int, salt: int = 0):
    return np.random.default_rng(
        np.random.SeedSequence([seed & 0x7FFFFFFF, step, salt]))


@dataclasses.dataclass
class TokenCorpus:
    vocab_size: int
    seq_len: int
    batch: int
    seed: int = 0
    eos: int = 1
    mean_doc: int = 512

    def batch_at(self, step: int) -> dict:
        r = _rng(self.seed, step)
        toks = r.integers(2, self.vocab_size, size=(self.batch,
                                                    self.seq_len + 1),
                          dtype=np.int32)
        # packed documents: EOS at geometric boundaries
        p = 1.0 / self.mean_doc
        seps = r.random((self.batch, self.seq_len + 1)) < p
        toks = np.where(seps, self.eos, toks)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass
class MarkovCorpus:
    vocab_size: int = 256
    seq_len: int = 256
    batch: int = 16
    seed: int = 0
    num_regimes: int = 8
    branching: int = 4          # out-degree per state (low entropy -> learnable)

    def __post_init__(self):
        r = _rng(self.seed, 0, salt=1)
        V, R, B = self.vocab_size, self.num_regimes, self.branching
        # per-regime sparse transition targets + logits
        self.targets = r.integers(0, V, size=(R, V, B), dtype=np.int32)
        self.logits = r.normal(size=(R, V, B)).astype(np.float32) * 2.0

    def batch_at(self, step: int) -> dict:
        r = _rng(self.seed, step)
        B, S, V = self.batch, self.seq_len, self.vocab_size
        regimes = r.integers(0, self.num_regimes, size=(B,))
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = r.integers(0, V, size=(B,))
        probs = np.exp(self.logits)
        probs /= probs.sum(-1, keepdims=True)
        u = r.random((B, S))
        for t in range(S):
            pr = probs[regimes, toks[:, t]]             # (B, branching)
            c = (u[:, t, None] < np.cumsum(pr, -1)).argmax(-1)
            toks[:, t + 1] = self.targets[regimes, toks[:, t], c]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass
class EncoderCorpus:
    """HuBERT-style masked-unit-prediction batches (frame frontend stub)."""
    vocab_size: int
    seq_len: int
    batch: int
    frontend_dim: int
    seed: int = 0
    mask_prob: float = 0.08
    mask_span: int = 10

    def batch_at(self, step: int) -> dict:
        r = _rng(self.seed, step)
        B, S = self.batch, self.seq_len
        frames = r.normal(size=(B, S, self.frontend_dim)).astype(np.float32)
        labels = r.integers(0, self.vocab_size, size=(B, S), dtype=np.int32)
        starts = r.random((B, S)) < self.mask_prob / self.mask_span
        # HuBERT-style guarantee: every utterance has >= 1 masked span
        forced = r.integers(0, max(S - self.mask_span, 1), size=(B,))
        starts[np.arange(B), forced] |= ~starts.any(axis=1)
        mask = np.zeros((B, S), bool)
        for off in range(self.mask_span):
            mask[:, off:] |= starts[:, :S - off] if off else starts
        return {"frames": frames, "labels": labels, "mask": mask}


@dataclasses.dataclass
class VLMCorpus:
    """Text + patch-embedding batches (patch frontend stub)."""
    vocab_size: int
    seq_len: int               # text length (excl. patches)
    batch: int
    num_patches: int
    frontend_dim: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        r = _rng(self.seed, step)
        B = self.batch
        toks = r.integers(2, self.vocab_size, size=(B, self.seq_len + 1),
                          dtype=np.int32)
        patches = r.normal(size=(B, self.num_patches,
                                 self.frontend_dim)).astype(np.float32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                "patches": patches}


def corpus_for(cfg, seq_len: int, batch: int, seed: int = 0):
    """Pick the right corpus for a model kind (shapes per input_specs)."""
    if cfg.kind == "encoder":
        return EncoderCorpus(cfg.vocab_size, seq_len, batch,
                             cfg.frontend_dim, seed)
    if cfg.kind == "vlm":
        return VLMCorpus(cfg.vocab_size, seq_len - cfg.num_prefix_embeds,
                         batch, cfg.num_prefix_embeds, cfg.frontend_dim, seed)
    return TokenCorpus(cfg.vocab_size, seq_len, batch, seed)
