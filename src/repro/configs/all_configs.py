"""All model configs: the paper's families + the 10 assigned architectures.

Paper families (SlimPajama vocab 32000, dims from Appendix A.2 Table 5):
  mamba-{115m,353m,765m,1.3b}        dense Mamba scaling ladder
  rom-mamba-*                        + RoM(Conv,Gate,Out; 8 experts, top-1)
  moemamba-353m                      naive MoE-Mamba baseline
  samba-421m[-rom|-moemamba|-moa|-switchhead|-ffnmoe]   (expand=2 hybrids)
  samba-511m[-rom|-rom-gateout|-rom-all|-rom-ffnmoe]    (expand=4 hybrids)
  mamba2-rom-353m, gdn-rom-343m      Table 3 rows
  llama2-438m                        Table 1 attention baseline

Assigned architectures (``--arch <id>``): exact dims from the task spec;
deviations (moonshot layer count vs its name, llama4 dense/MoE interleave)
are recorded in DESIGN.md §6.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (AttentionConfig, AttnMoEConfig, GDNConfig,
                                Mamba2Config, MambaConfig, ModelConfig,
                                MoEConfig, RGLRUConfig, RoMConfig,
                                XLSTMConfig, register)

_ROM = RoMConfig(num_experts=8, top_k=1, targets=("conv", "gate", "out"))


# ---------------------------------------------------------------------------
# paper: Mamba scaling ladder (Table 5) + RoM variants
# ---------------------------------------------------------------------------

def _mamba_cfg(name, L, d, *, kind="mamba", rom=None, expand=2):
    return ModelConfig(
        name=name, d_model=d, vocab_size=32000,
        segments=(((kind,), L),),
        mamba=MambaConfig(expand=expand, d_state=16),
        rom=rom, max_seq_len=16384,
        remat="dots" if d >= 1536 else "none")


for _n, _L, _d in (("115m", 24, 768), ("353m", 48, 1024),
                   ("765m", 48, 1536), ("1.3b", 48, 2048)):
    register(lambda _n=_n, _L=_L, _d=_d:
             _mamba_cfg(f"mamba-{_n}", _L, _d))
    register(lambda _n=_n, _L=_L, _d=_d:
             _mamba_cfg(f"rom-mamba-{_n}", _L, _d, kind="rom_mamba",
                        rom=_ROM))

register(lambda: _mamba_cfg("moemamba-353m", 48, 1024, kind="moemamba",
                            rom=_ROM))


@register
def _mamba2_rom():
    return ModelConfig(
        name="mamba2-rom-353m", d_model=1024, vocab_size=32000,
        segments=((("rom_mamba2",), 48),),
        mamba2=Mamba2Config(expand=2, d_state=64, head_dim=64),
        rom=dataclasses.replace(_ROM, targets=("in", "out")),
        max_seq_len=16384)


@register
def _gdn_rom():
    return ModelConfig(
        name="gdn-rom-343m", d_model=1024, vocab_size=32000,
        segments=((("rom_gdn",), 48),),
        gdn=GDNConfig(num_heads=6, head_dim=128, expand_v=2),
        rom=dataclasses.replace(_ROM, targets=("in", "out")),
        max_seq_len=16384)


# ---------------------------------------------------------------------------
# paper: Samba hybrids (Mamba -> MLP -> SWA -> MLP), d=1024, 12 blocks
# ---------------------------------------------------------------------------

_SWA = AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=64,
                       window=2048)


def _samba(name, mixer, *, expand=2, mlp2="mlp", attnk="attn", rom=None,
           moe=None, attn_moe=None):
    return ModelConfig(
        name=name, d_model=1024, vocab_size=32000,
        segments=(((mixer, "mlp", attnk, mlp2), 12),),
        d_ff=4096, attention=_SWA,
        mamba=MambaConfig(expand=expand, d_state=16),
        rom=rom, moe=moe, attn_moe=attn_moe, max_seq_len=16384)


register(lambda: _samba("samba-421m", "mamba"))
register(lambda: _samba("samba-421m-rom", "rom_mamba", rom=_ROM))
register(lambda: _samba("samba-421m-moemamba", "moemamba", rom=_ROM))
register(lambda: _samba("samba-421m-moa", "mamba", attnk="moa",
                        attn_moe=AttnMoEConfig(num_experts=32, top_k=1)))
register(lambda: _samba("samba-421m-switchhead", "mamba", attnk="switchhead",
                        attn_moe=AttnMoEConfig(num_experts=32, top_k=1)))
register(lambda: _samba(
    "samba-421m-ffnmoe", "mamba", mlp2="moe",
    moe=MoEConfig(num_experts=16, top_k=1, d_ff=4096)))
register(lambda: _samba("samba-511m", "mamba", expand=4))
register(lambda: _samba("samba-511m-rom", "rom_mamba", expand=4, rom=_ROM))
register(lambda: _samba("samba-511m-rom-gateout", "rom_mamba", expand=4,
                        rom=dataclasses.replace(_ROM,
                                                targets=("gate", "out"))))
register(lambda: _samba(
    "samba-511m-rom-all", "rom_mamba", expand=4,
    rom=dataclasses.replace(_ROM, targets=("conv", "gate", "dt", "x", "out"))))
register(lambda: _samba(
    "samba-511m-rom-ffnmoe", "rom_mamba", expand=4, mlp2="moe", rom=_ROM,
    moe=MoEConfig(num_experts=8, top_k=1, d_ff=4096, share_rom_router=True)))


@register
def _llama2_438m():
    return ModelConfig(
        name="llama2-438m", d_model=1024, vocab_size=32000,
        segments=((("attn", "mlp"), 24),), d_ff=4096,
        attention=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=64),
        max_seq_len=16384)


# ---------------------------------------------------------------------------
# assigned architectures (10)
# ---------------------------------------------------------------------------

@register
def qwen15_4b():
    return ModelConfig(
        name="qwen1.5-4b", d_model=2560, vocab_size=151936,
        segments=((("attn", "mlp"), 40),), d_ff=6912,
        attention=AttentionConfig(num_heads=20, num_kv_heads=20,
                                  head_dim=128, qkv_bias=True),
        remat="dots", max_seq_len=32768)


@register
def yi_34b():
    return ModelConfig(
        name="yi-34b", d_model=7168, vocab_size=64000,
        segments=((("attn", "mlp"), 60),), d_ff=20480,
        attention=AttentionConfig(num_heads=56, num_kv_heads=8,
                                  head_dim=128),
        remat="dots", max_seq_len=32768)


@register
def qwen25_14b():
    return ModelConfig(
        name="qwen2.5-14b", d_model=5120, vocab_size=152064,
        segments=((("attn", "mlp"), 48),), d_ff=13824,
        attention=AttentionConfig(num_heads=40, num_kv_heads=8,
                                  head_dim=128, qkv_bias=True),
        remat="dots", max_seq_len=32768)


@register
def qwen15_05b():
    return ModelConfig(
        name="qwen1.5-0.5b", d_model=1024, vocab_size=151936,
        segments=((("attn", "mlp"), 24),), d_ff=2816,
        attention=AttentionConfig(num_heads=16, num_kv_heads=16,
                                  head_dim=64, qkv_bias=True),
        max_seq_len=32768)


@register
def pixtral_12b():
    return ModelConfig(
        name="pixtral-12b", d_model=5120, vocab_size=131072,
        segments=((("attn", "mlp"), 40),), d_ff=14336,
        attention=AttentionConfig(num_heads=32, num_kv_heads=8,
                                  head_dim=160),
        kind="vlm", frontend="patch", frontend_dim=1024,
        num_prefix_embeds=256, remat="dots", max_seq_len=32768)


@register
def xlstm_350m():
    return ModelConfig(
        name="xlstm-350m", d_model=1024, vocab_size=50304,
        segments=(((("mlstm",) * 7 + ("slstm",)), 3),),   # 7:1, 24 layers
        xlstm=XLSTMConfig(num_heads=4, expand=2, qk_ratio=0.5, chunk=64),
        max_seq_len=32768)


@register
def rom_xlstm_350m():
    base = xlstm_350m()
    return base.replace(
        name="rom-xlstm-350m",
        segments=(((("rom_mlstm",) * 7 + ("slstm",)), 3),),
        rom=dataclasses.replace(_ROM, targets=("in", "gate", "out")))


@register
def moonshot_16b():
    return ModelConfig(
        name="moonshot-v1-16b-a3b", d_model=2048, vocab_size=163840,
        segments=((("attn", "moe"), 48),),
        attention=AttentionConfig(num_heads=16, num_kv_heads=16,
                                  head_dim=128),
        moe=MoEConfig(num_experts=64, top_k=6, d_ff=1408,
                      capacity_factor=1.25, impl="capacity"),
        remat="dots", max_seq_len=32768)


@register
def llama4_maverick():
    return ModelConfig(
        name="llama4-maverick-400b-a17b", d_model=5120, vocab_size=202048,
        segments=((("attn", "mlp", "attn", "moe"), 24),), d_ff=16384,
        attention=AttentionConfig(num_heads=40, num_kv_heads=8,
                                  head_dim=128),
        moe=MoEConfig(num_experts=128, top_k=1, d_ff=8192,
                      num_shared_experts=1, capacity_factor=1.25, impl="ep"),
        optimizer="adafactor", remat="dots", max_seq_len=32768)


@register
def hubert_xlarge():
    return ModelConfig(
        name="hubert-xlarge", d_model=1280, vocab_size=504,
        segments=((("attn", "mlp"), 48),), d_ff=5120,
        attention=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=80,
                                  causal=False, use_rope=False),
        kind="encoder", frontend="frame", frontend_dim=512,
        tie_embeddings=False, remat="dots", max_seq_len=32768)


_RG_ATTN = AttentionConfig(num_heads=10, num_kv_heads=1, head_dim=256,
                           window=2048)


@register
def recurrentgemma_2b():
    return ModelConfig(
        name="recurrentgemma-2b", d_model=2560, vocab_size=256000,
        segments=(
            (("rglru", "mlp", "rglru", "mlp", "attn", "mlp"), 8),
            (("rglru", "mlp", "rglru", "mlp"), 1),
        ), d_ff=7680,
        attention=_RG_ATTN,
        rglru=RGLRUConfig(num_heads=10),
        remat="dots", max_seq_len=524288)


@register
def rom_recurrentgemma_2b():
    base = recurrentgemma_2b()
    return base.replace(
        name="rom-recurrentgemma-2b",
        segments=(
            (("rom_rglru", "mlp", "rom_rglru", "mlp", "attn", "mlp"), 8),
            (("rom_rglru", "mlp", "rom_rglru", "mlp"), 1),
        ),
        rom=dataclasses.replace(_ROM, targets=("in", "gate", "out")))


# ---------------------------------------------------------------------------
# smoke reduction: same family, tiny dims, runs one step on CPU
# ---------------------------------------------------------------------------

def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    kw = dict(
        name=cfg.name + "-smoke", d_model=64, vocab_size=256,
        d_ff=128 if cfg.d_ff else 0,
        segments=tuple((p, min(2, r)) for p, r in cfg.segments),
        remat="none", max_seq_len=64, dtype="float32",
        frontend_dim=32 if cfg.frontend else 0,
        num_prefix_embeds=8 if cfg.kind == "vlm" else 0,
    )
    if cfg.attention:
        kw["attention"] = dataclasses.replace(
            cfg.attention, num_heads=4,
            num_kv_heads=1 if cfg.attention.num_kv_heads == 1 else 2,
            head_dim=16, window=16 if cfg.attention.window else None,
            q_block=32, kv_block=32)
    if cfg.mamba:
        kw["mamba"] = dataclasses.replace(cfg.mamba, d_state=4, chunk=16)
    if cfg.mamba2:
        kw["mamba2"] = dataclasses.replace(cfg.mamba2, d_state=8,
                                           head_dim=16, chunk=8)
    if cfg.gdn:
        kw["gdn"] = dataclasses.replace(cfg.gdn, num_heads=2, head_dim=16)
    if cfg.rglru:
        kw["rglru"] = dataclasses.replace(cfg.rglru, num_heads=2)
    if cfg.xlstm:
        kw["xlstm"] = dataclasses.replace(cfg.xlstm, num_heads=2, chunk=8)
    if cfg.rom:
        kw["rom"] = dataclasses.replace(cfg.rom, num_experts=4,
                                        capacity_factor=4.0)
    if cfg.moe:
        # Eq. 14-15 shared routing requires matching expert counts
        n_e = 4 if (cfg.moe.share_rom_router and cfg.rom) else 8
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=n_e, top_k=min(2, cfg.moe.top_k), d_ff=32,
            capacity_factor=4.0)
    return cfg.replace(**kw)


# ---------------------------------------------------------------------------
# parameter accounting (paper Tables 1/5/7: total vs active)
# ---------------------------------------------------------------------------

def param_stats(cfg: ModelConfig) -> dict:
    """Analytic total/active parameter counts from the abstract init tree."""
    import jax
    import numpy as np
    from repro.models import lm

    shapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0),
                                                   cfg))
    total = 0
    active = 0
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        name = None
        for e in reversed(path):
            k = getattr(e, "key", None)
            if isinstance(k, str):
                name = k
                break
        total += n
        if name and (name.startswith("e_w_") or name.startswith("e_b_")
                     or name.startswith("ep_w_")):
            # expert leaf: active fraction = top_k / num_experts
            if name in ("e_w_up", "e_w_gate_ffn", "e_w_down",
                        "ep_w_up", "ep_w_gate_ffn", "ep_w_down"):
                mcfg = cfg.moe
            elif name in ("e_w_q", "e_w_v", "e_w_o"):
                mcfg = cfg.attn_moe
            else:
                mcfg = cfg.rom
            active += n * mcfg.top_k // mcfg.num_experts
        else:
            active += n
    return {"total": total, "active": active}
