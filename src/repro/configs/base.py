"""Config system: frozen dataclasses + registry.

A model is a stack of *segments*; each segment is a block ``pattern`` (a tuple
of sub-layer kind strings) repeated ``repeats`` times.  Segments with
``repeats > 1`` are executed with ``lax.scan`` over stacked parameters so the
compiled HLO stays small at 60-layer scale.

Sub-layer kinds (token mixers and channel mixers):
  attn          full / sliding-window GQA attention (cfg.attention)
  mlp           dense SwiGLU / GELU MLP (cfg.d_ff)
  moe           FFN mixture-of-experts (cfg.moe)
  mamba         dense Mamba (selective SSM) (cfg.mamba)
  rom_mamba     Mamba with RoM projection experts (cfg.mamba + cfg.rom)
  moemamba      naive MoE-Mamba baseline: independent routers per projection
  mamba2        Mamba-2 (SSD) (cfg.mamba2)
  rom_mamba2    Mamba-2 with comprehensive RoM expertization
  gdn           Gated DeltaNet (cfg.gdn)
  rom_gdn       Gated DeltaNet with RoM
  rglru         RG-LRU recurrent block (RecurrentGemma/Griffin) (cfg.rglru)
  rom_rglru     RG-LRU with RoM projection experts
  mlstm, slstm  xLSTM blocks (cfg.xlstm)
  rom_mlstm     mLSTM with RoM projection experts
  moa, switchhead   attention-MoE baselines (cfg.attention + cfg.attn_moe)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    window: Optional[int] = None        # sliding-window size; None = full
    causal: bool = True
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    q_block: int = 512                  # blockwise-attention query tile
    kv_block: int = 1024                # blockwise-attention kv tile
    impl: str = "blockwise"             # blockwise | full
    # TP layout when heads don't divide the model axis:
    #   head_dim  - shard head_dim (psum per attention tile — measured
    #               pathological in §Perf; kept as the recorded baseline)
    #   replicate - replicate attention internals; TP stays in projections
    tp_fallback: str = "head_dim"
    # decode cache update: "dus" (GSPMD dynamic_update_slice, baseline) or
    # "flash" (shard_map seq-sharded cache + flash-decoding combine, §Perf)
    decode: str = "dus"


@dataclass(frozen=True)
class MambaConfig:
    expand: int = 2
    d_state: int = 16
    dt_rank: int = 0                    # 0 -> ceil(d_model / 16)
    conv_kernel: int = 4
    chunk: int = 128                    # ref-path scan chunk
    scan_dtype: str = "float32"         # scan accumulation dtype (perf knob)


@dataclass(frozen=True)
class Mamba2Config:
    expand: int = 2
    d_state: int = 64
    head_dim: int = 64
    chunk: int = 64
    conv_kernel: int = 4


@dataclass(frozen=True)
class GDNConfig:
    num_heads: int = 4
    head_dim: int = 128                 # key dim per head
    expand_v: int = 2
    conv_kernel: int = 4


@dataclass(frozen=True)
class RGLRUConfig:
    d_rnn: int = 0                      # 0 -> d_model
    conv_kernel: int = 4
    num_heads: int = 1                  # gate heads (block-diag input/forget gates)
    c: float = 8.0                      # RG-LRU time-constant scale


@dataclass(frozen=True)
class XLSTMConfig:
    num_heads: int = 4
    expand: int = 2                     # mLSTM inner = expand * d_model
    qk_ratio: float = 0.5               # qk dim = qk_ratio * inner
    slstm_ff: float = 4.0 / 3.0         # sLSTM post-FFN expansion
    conv_kernel: int = 4
    chunk: int = 64


@dataclass(frozen=True)
class RoMConfig:
    """Routing Mamba: shared-router projection experts (the paper's core)."""
    num_experts: int = 8
    top_k: int = 1
    # which projections are expertized ('conv','gate','out' (+'dt','x') for
    # mamba; 'in','out' = comprehensive for mamba2/gdn/rglru/mlstm)
    targets: Tuple[str, ...] = ("conv", "gate", "out")
    jitter_eps: float = 0.01            # multiplicative router-logit noise
    aux_loss_weight: float = 0.0        # paper default: no balance loss
    capacity_factor: float = 2.0        # capacity dispatch path only
    impl: str = "capacity"              # dense | capacity | grouped
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MoEConfig:
    """FFN mixture-of-experts (baseline + assigned MoE archs)."""
    num_experts: int = 8
    top_k: int = 1
    d_ff: int = 0                       # per-expert hidden
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    impl: str = "capacity"              # dense | capacity | ep
    aux_loss_weight: float = 0.0
    jitter_eps: float = 0.0
    share_rom_router: bool = False      # Eq. 14-15: reuse preceding RoM decisions


@dataclass(frozen=True)
class AttnMoEConfig:
    """MoA / SwitchHead baselines."""
    num_experts: int = 8
    top_k: int = 1


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    vocab_size: int
    segments: Tuple[Tuple[Tuple[str, ...], int], ...]
    d_ff: int = 0
    mlp_act: str = "swiglu"             # swiglu | geglu | gelu
    attention: Optional[AttentionConfig] = None
    mamba: Optional[MambaConfig] = None
    mamba2: Optional[Mamba2Config] = None
    gdn: Optional[GDNConfig] = None
    rglru: Optional[RGLRUConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    rom: Optional[RoMConfig] = None
    moe: Optional[MoEConfig] = None
    attn_moe: Optional[AttnMoEConfig] = None
    kind: str = "decoder"               # decoder | encoder | vlm
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"             # activation dtype
    param_dtype: str = "float32"
    max_seq_len: int = 4096
    # modality frontends (stubbed per spec: input_specs provides embeddings)
    frontend: Optional[str] = None      # patch | frame
    frontend_dim: int = 0               # incoming embedding dim
    num_prefix_embeds: int = 0          # e.g. image patches prepended (vlm)
    # training-system knobs
    optimizer: str = "adamw"            # adamw | adafactor
    remat: str = "none"                 # none | full | dots
    scan_layers: bool = True
    logit_softcap: float = 0.0

    # ---- derived helpers -------------------------------------------------
    def num_sublayers(self) -> int:
        return sum(len(p) * r for p, r in self.segments)

    def mixer_layers(self, kinds=("attn", "mamba", "rom_mamba", "moemamba",
                                  "mamba2", "rom_mamba2", "gdn", "rom_gdn",
                                  "rglru", "rom_rglru", "mlstm", "slstm",
                                  "moa", "switchhead")) -> int:
        return sum(sum(1 for k in p if k in kinds) * r for p, r in self.segments)

    def is_subquadratic(self) -> bool:
        """True if no full (unwindowed) attention layer exists."""
        has_full_attn = any(
            any(k in ("attn", "moa", "switchhead") for k in p)
            for p, _ in self.segments
        ) and (self.attention is None or self.attention.window is None)
        return not has_full_attn

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes assigned to every architecture (spec: 4 shapes / arch).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                           # train | prefill | decode


SHAPES = {
    "train_4k":    InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k":  InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k":   InputShape("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig):
    """Per-spec skip rules: encoder-only archs skip decode shapes; pure
    full-attention archs skip long_500k (needs sub-quadratic attention)."""
    out = {}
    for name, s in SHAPES.items():
        if cfg.kind == "encoder" and s.mode == "decode":
            out[name] = (None, "encoder-only: no decode step")
        elif name == "long_500k" and not cfg.is_subquadratic():
            out[name] = (None, "pure full-attention arch: 512K decode skipped")
        else:
            out[name] = (s, None)
    return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY = {}


def register(fn):
    """Decorator: register ``fn() -> ModelConfig`` under the config's name."""
    cfg = fn()
    _REGISTRY[cfg.name] = fn
    return fn


def get_config(name: str) -> ModelConfig:
    import repro.configs.all_configs  # noqa: F401  (populate registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown config {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs():
    import repro.configs.all_configs  # noqa: F401
    return sorted(_REGISTRY)


ASSIGNED_ARCHS = (
    "qwen1.5-4b", "yi-34b", "qwen2.5-14b", "qwen1.5-0.5b", "pixtral-12b",
    "xlstm-350m", "moonshot-v1-16b-a3b", "llama4-maverick-400b-a17b",
    "hubert-xlarge", "recurrentgemma-2b",
)
