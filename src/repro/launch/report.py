"""Roofline summary tables from the dry-run JSON records."""
from __future__ import annotations

import glob
import json
import os

from repro.launch.dryrun_lib import OUT_ROOT


def load_records(mesh="single"):
    recs = []
    for p in sorted(glob.glob(os.path.join(OUT_ROOT, mesh, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r):
    if "skipped" in r:
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped | "
                f"{r['skipped']} |")
    t = r["roofline"]
    tag = f" `{r['tag']}`" if r.get("tag") else ""
    return ("| {arch}{tag} | {shape} | {c:.4f} | {m:.4f} | {x:.4f} | "
            "{ratio} | {bn} | {frac} |").format(
        arch=r["arch"], tag=tag, shape=r["shape"],
        c=t["compute_s"], m=t["memory_s"], x=t["collective_s"],
        ratio=(f"{t['useful_flops_ratio']:.2f}"
               if t.get("useful_flops_ratio") else "—"),
        bn=t["bottleneck"],
        frac=(f"{t['roofline_fraction']:.3f}"
              if t.get("roofline_fraction") else "—"))


HEADER = ("| arch | shape | compute s | memory s | collective s | "
          "MODEL/HLO flops | bottleneck | roofline frac |\n"
          "|---|---|---|---|---|---|---|---|")


def print_summary(mesh="single"):
    recs = load_records(mesh)
    if not recs:
        print(f"no records under {OUT_ROOT}/{mesh}")
        return
    print(f"### Roofline table — {mesh}-pod mesh "
          f"({'256' if mesh == 'single' else '512'} chips)\n")
    print(HEADER)
    for r in recs:
        print(fmt_row(r))


def markdown_summary(mesh="single") -> str:
    recs = load_records(mesh)
    lines = [HEADER] + [fmt_row(r) for r in recs]
    return "\n".join(lines)
