"""Production mesh definitions (per the multi-pod dry-run spec).

Functions, not module-level constants — importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def _axis_types_kw(n):
    """axis_types only exists on newer JAX; older versions default to Auto."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_host_mesh(shape=None):
    """A ``("data", "model")`` mesh over this host's devices.

    ``shape=None`` keeps the historical default — all devices on the data
    axis (``(n, 1)``), so the same sharded code paths run end-to-end in
    examples/tests on a 1-CPU container.  A requested ``(data, model)``
    shape is validated: the host's device count must be divisible by the
    requested total (the mesh takes the first ``data*model`` devices), and
    an impossible request fails loudly instead of silently building
    ``(n, 1)``.
    """
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1)
    shape = tuple(int(s) for s in shape)
    if len(shape) != 2 or any(s < 1 for s in shape):
        raise ValueError(f"host mesh shape must be (data, model) with "
                         f"positive sizes, got {shape}")
    total = shape[0] * shape[1]
    if total > n or n % total != 0:
        raise ValueError(
            f"requested host mesh {{'data': {shape[0]}, 'model': "
            f"{shape[1]}}} needs {total} devices, but this host platform "
            f"has {n} (device count must be a multiple; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N to fake "
            f"more CPU devices)")
    return jax.make_mesh(shape, ("data", "model"), **_axis_types_kw(2))
