"""Production mesh definitions (per the multi-pod dry-run spec).

Functions, not module-level constants — importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def _axis_types_kw(n):
    """axis_types only exists on newer JAX; older versions default to Auto."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_host_mesh():
    """Whatever this host has (1 CPU device in the container): (1, 1) mesh
    so the same sharded code paths run end-to-end in examples/tests."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"), **_axis_types_kw(2))
