"""Batched decode driver: prefill a prompt through decode steps, then
generate.  CPU-runnable with --smoke (reduced same-family config).

    PYTHONPATH=src python -m repro.launch.serve --arch rom-mamba-115m \
        --smoke --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import train as tr
from repro.configs.all_configs import reduce_for_smoke
from repro.configs.base import get_config
from repro.data.pipeline import corpus_for
from repro.launch.mesh import make_host_mesh
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    if cfg.kind == "encoder":
        raise SystemExit("encoder-only arch has no decode step")
    mesh = make_host_mesh()

    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    serve = jax.jit(tr.make_serve_fn(cfg, mesh))
    max_len = args.prompt_len + args.gen
    state = lm.init_state(cfg, args.batch, max_len, jnp.dtype(cfg.dtype))

    corpus = corpus_for(cfg, args.prompt_len + 1, args.batch, args.seed)
    prompt = jnp.asarray(corpus.batch_at(0)["tokens"])[:, :args.prompt_len]

    # prefill by stepping the decode path (exercises SSM/KV caches exactly)
    t0 = time.perf_counter()
    tok = prompt[:, :1]
    for pos in range(args.prompt_len):
        tok_in = prompt[:, pos:pos + 1]
        nxt, logits, state = serve(params, state, tok_in, jnp.int32(pos))
    t1 = time.perf_counter()
    outs = []
    tok = nxt[:, None]
    for pos in range(args.prompt_len, max_len):
        nxt, logits, state = serve(params, state, tok, jnp.int32(pos))
        outs.append(nxt)
        tok = nxt[:, None]
    jax.block_until_ready(tok)
    t2 = time.perf_counter()
    gen = jnp.stack(outs, axis=1)
    print(f"prefill {args.prompt_len} steps: {t1 - t0:.3f}s | "
          f"decode {args.gen} steps: {t2 - t1:.3f}s "
          f"({args.gen * args.batch / (t2 - t1):.1f} tok/s)")
    print("sample generations:", gen[:2, :16].tolist())


if __name__ == "__main__":
    main()
