"""Serving driver on the continuous-batching engine.

Prompts are prefilled with the parallel training-style forward (one pass per
power-of-two chunk instead of one decode step per token) and decoded with
per-slot positions; finished slots are refilled from the request queue.
``--speculative K`` decodes self-speculatively (layer-skip draft +
full-model verify); ``--prefix-cache-mb`` skips prefill for cached prompt
prefixes (radix tree of chunk-boundary state snapshots) and
``--cache-policy cached-suffix`` admits cache hits first (see
docs/serving.md).  CPU-runnable with --smoke (reduced same-family config).

Device topology is resolved once into a
:class:`~repro.distributed.plan.ParallelPlan` (``--mesh data=N,model=M``:
decode slots shard over the data axis, RoM/MoE expert weights over the
model axis) and threaded through the engine, state store and cache — the
default is single-device.

    PYTHONPATH=src python -m repro.launch.serve --arch rom-mamba-115m \
        --smoke --batch 4 --prompt-len 32 --gen 32 \
        --speculative 4 --draft-stride 2 \
        --prefix-cache-mb 64 --cache-policy cached-suffix \
        --mesh data=1
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.all_configs import reduce_for_smoke
from repro.configs.base import get_config
from repro.data.pipeline import corpus_for
from repro.distributed.plan import ParallelPlan
from repro.models import lm
from repro.serve import (CachedSuffixFirst, EngineConfig, ExpertLibrary,
                         PrefixCache, Request, SamplingParams, ServeEngine,
                         ShortestPromptFirst, Telemetry)
from repro.serve import fleet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slots (and #requests unless --requests)")
    ap.add_argument("--requests", type=int, default=0,
                    help="total requests to serve (default: --batch)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--admission", default="interleaved",
                    choices=("interleaved", "sequential"),
                    help="stall-free chunked admission (default) vs the "
                         "full-prefill-per-request baseline")
    ap.add_argument("--speculative", type=int, default=0, metavar="K",
                    help="self-speculative decoding: draft K tokens per "
                         "round with a layer-skip reduced model, verify in "
                         "one full-model pass (0 = off)")
    ap.add_argument("--draft-stride", type=int, default=2,
                    help="layer-skip stride of the draft model (keep every "
                         "Nth block; 1 = full model)")
    ap.add_argument("--prefix-cache-mb", type=float, default=0.0,
                    metavar="MB",
                    help="prefix-cache snapshot budget in MiB (0 = off): "
                         "admission restores the longest cached prompt "
                         "prefix from a radix tree of chunk-boundary state "
                         "snapshots and prefills only the uncached suffix")
    ap.add_argument("--cache-policy", default="fifo",
                    choices=("fifo", "spf", "cached-suffix"),
                    help="scheduler: fifo, shortest-prompt-first, or "
                         "cached-suffix-first (ranks by *uncached* suffix "
                         "length; requires --prefix-cache-mb > 0)")
    ap.add_argument("--cache-grain", type=int, default=1, metavar="G",
                    help="prefix-cache snapshot alignment: only publish "
                         "boundaries at multiples of G tokens (bounds the "
                         "radix tree; 1 = every chunk boundary)")
    ap.add_argument("--tenants", type=int, default=0, metavar="N",
                    help="multi-tenant serving: register N extra expert "
                         "sets (independently initialized RoM projections) "
                         "in an ExpertLibrary and round-robin requests "
                         "across them plus the base set (0 = single-tenant; "
                         "requires an arch with RoM/MoE-Mamba blocks)")
    ap.add_argument("--expert-budget-mb", type=float, default=256.0,
                    metavar="MB",
                    help="ExpertLibrary device-residency budget in MiB; "
                         "unpinned LRU sets past it are evicted and fault "
                         "back in on demand (advisory: bound sets always "
                         "fit)")
    ap.add_argument("--max-bound", type=int, default=2, metavar="R",
                    help="expert-set binding rows per decode batch: how "
                         "many distinct sets one jitted decode step serves "
                         "simultaneously (more rows = fewer hot swaps, "
                         "bigger routed GEMM fan-out)")
    ap.add_argument("--mesh", default="", metavar="SPEC",
                    help="ParallelPlan topology, e.g. 'data=4' or "
                         "'data=2,model=2' over this host's devices "
                         "(decode slots shard over data, expert weights "
                         "over model); empty = single device")
    ap.add_argument("--kernels", default="auto",
                    choices=["auto", "ref", "pallas", "interpret"],
                    help="kernel impl for the jitted serving steps "
                         "(EngineConfig.kernels): 'pallas' enables the "
                         "fused decode fast path, 'ref' pins the jnp "
                         "oracles, 'auto' picks by backend")
    ap.add_argument("--metrics-out", default="", metavar="PATH",
                    help="write the final telemetry registry snapshot: "
                         "Prometheus text format when PATH ends in .prom, "
                         "structured JSON otherwise")
    ap.add_argument("--metrics-interval", type=float, default=0.0,
                    metavar="S",
                    help="print a registry-delta stats line every S "
                         "seconds while serving (0 = only the final "
                         "summary)")
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="write per-request span timelines as Chrome "
                         "trace_event JSON — load in Perfetto "
                         "(ui.perfetto.dev) or chrome://tracing")
    ap.add_argument("--trace-dir", default="", metavar="DIR",
                    help="capture a jax.profiler trace of the run into "
                         "DIR (TensorBoard/Perfetto-loadable), with "
                         "TraceAnnotation markers around the engine's "
                         "jitted serving dispatches")
    ap.add_argument("--role", default="mono",
                    choices=("mono", "prefill", "decode", "router"),
                    help="serving role (serve/fleet/): 'mono' is the "
                         "monolithic engine; 'prefill' prefills prompts "
                         "and writes admit messages to --snapshots-out; "
                         "'decode' admits purely from --snapshots-in "
                         "messages; 'router' runs an in-process fleet "
                         "(1 prefill + --fleet-decode decode replicas)")
    ap.add_argument("--fleet-decode", type=int, default=2, metavar="N",
                    help="decode replicas in the --role router fleet")
    ap.add_argument("--snapshots-out", default="", metavar="DIR",
                    help="--role prefill: write one admit message "
                         "(request meta + encoded snapshot) per request "
                         "into DIR")
    ap.add_argument("--snapshots-in", default="", metavar="DIR",
                    help="--role decode: admit every *.msg file in DIR "
                         "(a --snapshots-out directory, possibly produced "
                         "on a different mesh)")
    ap.add_argument("--cache-save", default="", metavar="PATH",
                    help="after serving, persist the prefix cache (all "
                         "namespaces, codec-encoded) to PATH")
    ap.add_argument("--cache-load", default="", metavar="PATH",
                    help="before serving, load a --cache-save file into "
                         "the prefix cache (fingerprint-checked; a warm "
                         "cache survives restarts and topology changes)")
    ap.add_argument("--assert-cache-hit", action="store_true",
                    help="exit non-zero unless the run served at least "
                         "one prefix-cache hit (CI gate for --cache-load)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    if cfg.kind == "encoder":
        raise SystemExit("encoder-only arch has no decode step")
    plan = ParallelPlan.parse(args.mesh)

    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    max_len = args.prompt_len + args.gen
    # one Telemetry bundle for the whole stack: cache/library/scheduler
    # report into the engine's registry, so --metrics-out is one unified
    # snapshot and the trace timeline covers every subsystem
    telem = Telemetry(profiler=bool(args.trace_dir))
    cache = (PrefixCache(budget_mb=args.prefix_cache_mb,
                         grain=args.cache_grain, registry=telem.registry)
             if args.prefix_cache_mb > 0 else None)
    if args.cache_policy == "cached-suffix":
        if cache is None:
            raise SystemExit("--cache-policy cached-suffix needs "
                             "--prefix-cache-mb > 0")
        scheduler = CachedSuffixFirst(cache)
    elif args.cache_policy == "spf":
        scheduler = ShortestPromptFirst()
    else:
        scheduler = None                          # engine default: FIFO
    library = None
    tenant_names = [None]
    if args.tenants > 0:
        library = ExpertLibrary(cfg, params,
                                budget_mb=args.expert_budget_mb,
                                max_bound=args.max_bound, plan=plan,
                                registry=telem.registry)
        for i in range(args.tenants):
            library.add(f"tenant{i}", lm.init_params(
                jax.random.PRNGKey(args.seed + 1000 + i), cfg))
        tenant_names += [f"tenant{i}" for i in range(args.tenants)]
    if args.role in ("decode", "router") and args.cache_policy != "fifo":
        raise SystemExit(f"--cache-policy only applies to the prefill "
                         f"side, not --role {args.role}")
    engine_cfg = EngineConfig(max_slots=args.batch, max_len=max_len,
                              seed=args.seed, admission=args.admission,
                              speculative=args.speculative,
                              draft_stride=args.draft_stride,
                              kernels=(None if args.kernels == "auto"
                                       else args.kernels))
    engine = ServeEngine(
        cfg, params, plan=plan, engine=engine_cfg,
        prefix_cache=cache if args.role != "decode" else None,
        scheduler=scheduler, expert_library=library, telemetry=telem)

    print(f"plan: {plan.describe()} | kernels: {args.kernels} | "
          f"role: {args.role}")
    n_req = args.requests or args.batch
    corpus = corpus_for(cfg, args.prompt_len + 1, n_req, args.seed)
    prompts = np.asarray(corpus.batch_at(0)["tokens"])[:, :args.prompt_len]
    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                        top_p=args.top_p)
    reqs = [Request(id=i, prompt=prompts[i].tolist(),
                    max_new_tokens=args.gen, sampling=sp,
                    expert_set=tenant_names[i % len(tenant_names)])
            for i in range(n_req)]

    codec = fleet.SnapshotCodec.for_store(engine.store)
    if args.cache_load:
        if cache is None:
            raise SystemExit("--cache-load needs --prefix-cache-mb > 0")
        n = fleet.load_prefix_cache(cache, codec, args.cache_load)
        print(f"prefix cache: loaded {n} snapshots from {args.cache_load}")

    # everything from here serves traffic; exporter writes live in the
    # finally so an interrupted or crashed run still produces artifacts
    try:
        if args.role == "mono":
            out = _run_mono(args, engine, telem, reqs)
        else:
            out = _run_fleet_role(args, engine, engine_cfg, codec,
                                  telem, reqs, cfg, params, plan,
                                  library)
        if out is not None:
            results, wall = out
            _report(args, engine, cache, library, results, wall)
        if args.cache_save:
            if cache is None:
                raise SystemExit("--cache-save needs --prefix-cache-mb > 0")
            n = fleet.save_prefix_cache(cache, codec, args.cache_save)
            print(f"prefix cache: saved {n} snapshots to {args.cache_save}")
        if args.assert_cache_hit:
            hits = int(telem.registry.value("cache_hits_total"))
            print(f"cache hits served: {hits}")
            if hits == 0:
                raise SystemExit("--assert-cache-hit: the run served "
                                 "zero prefix-cache hits")
    finally:
        _write_exports(args, telem)


def _run_mono(args, engine, telem, reqs):
    """The monolithic serving loop (the original driver path)."""
    if args.trace_dir:
        jax.profiler.start_trace(args.trace_dir)
    t0 = time.perf_counter()
    if args.metrics_interval > 0:
        # drive tick-by-tick so a periodic registry-delta line can land
        # between dispatches (the engine itself never prints)
        for r in reqs:
            engine.submit(r)
        results = []
        reg = telem.registry
        win = reg.snapshot()
        t_next = t0 + args.metrics_interval
        while engine.busy():
            results.extend(engine.tick())
            now = time.perf_counter()
            if now >= t_next:
                d = reg.delta(win)

                def rate(name, n=now - t_next + args.metrics_interval):
                    return d[name]["value"] / max(n, 1e-9) \
                        if name in d else 0.0
                print(f"[t+{now - t0:6.1f}s] "
                      f"decode {rate('serve_decode_tokens_total'):8.1f} "
                      f"tok/s | prefill "
                      f"{rate('serve_prefill_tokens_total'):8.1f} tok/s | "
                      f"active {reg.value('serve_active_slots')} slots | "
                      f"queue {reg.value('sched_queue_depth')} | "
                      f"finished {reg.value('serve_requests_finished_total')}"
                      f"/{reg.value('serve_requests_submitted_total')}")
                win = reg.snapshot()
                t_next = now + args.metrics_interval
        results.extend(engine._drain())
    else:
        results = engine.run(reqs)
    wall = time.perf_counter() - t0
    if args.trace_dir:
        jax.profiler.stop_trace()
        print(f"jax.profiler trace written to {args.trace_dir}")
    return results, wall


def _run_fleet_role(args, engine, engine_cfg, codec, telem, reqs, cfg,
                    params, plan, library):
    """The disaggregated roles (serve/fleet/).  ``engine`` plays the
    prefill side (router/prefill roles) or the decode side (decode
    role); extra decode replicas get their own engines."""
    import collections
    import glob
    import os

    if args.role == "prefill":
        if not args.snapshots_out:
            raise SystemExit("--role prefill needs --snapshots-out DIR")
        os.makedirs(args.snapshots_out, exist_ok=True)
        worker = fleet.PrefillWorker("prefill0", engine, codec,
                                     registry=telem.registry)
        total = 0
        for req in reqs:
            admit = worker.process(fleet.encode_request(req))
            path = os.path.join(args.snapshots_out, f"admit_{req.id:05d}.msg")
            with open(path, "wb") as f:
                f.write(admit)
            total += len(admit)
        print(f"prefilled {len(reqs)} prompts -> {len(reqs)} admit "
              f"messages ({total / 2 ** 20:.2f} MiB) in "
              f"{args.snapshots_out}")
        return None

    if args.role == "decode":
        if not args.snapshots_in:
            raise SystemExit("--role decode needs --snapshots-in DIR")
        paths = sorted(glob.glob(os.path.join(args.snapshots_in, "*.msg")))
        if not paths:
            raise SystemExit(f"no *.msg admit messages in "
                             f"{args.snapshots_in}")
        worker = fleet.DecodeWorker("decode0", engine, codec,
                                    registry=telem.registry)
        pending = collections.deque()
        for p in paths:
            with open(p, "rb") as f:
                pending.append(f.read())
        t0 = time.perf_counter()
        results = []
        while pending or worker.busy():
            while pending and worker.try_admit(pending[0]):
                pending.popleft()
            for msg in worker.step():
                results.append(fleet.decode_result(msg))
        print(f"admitted {len(paths)} snapshots from {args.snapshots_in} "
              "(no prefill ran on this replica)")
        return results, time.perf_counter() - t0

    # router: in-process fleet — this engine prefills, N fresh engines
    # decode, a shared tier keeps the fleet's prefix cache warm
    if engine.cache is not None:
        tier = fleet.SharedCacheTier(budget_mb=args.prefix_cache_mb,
                                     registry=telem.registry)
        engine.cache.attach_tier(tier, codec)
    pw = fleet.PrefillWorker("prefill0", engine, codec,
                             registry=telem.registry)
    dws = []
    for i in range(max(args.fleet_decode, 1)):
        deng = ServeEngine(cfg, params, plan=plan, engine=engine_cfg,
                           expert_library=library, telemetry=telem)
        dws.append(fleet.DecodeWorker(f"decode{i}", deng, codec,
                                      registry=telem.registry))
    router = fleet.FleetRouter([pw], dws, telemetry=telem)
    t0 = time.perf_counter()
    results = router.run(reqs)
    wall = time.perf_counter() - t0
    v = telem.registry.value
    print(f"fleet: 1 prefill + {len(dws)} decode replicas | "
          f"{int(v('fleet_admits_total'))} snapshot admissions, "
          f"{int(v('fleet_snapshot_bytes_total')) / 2 ** 20:.2f} MiB "
          f"transferred, {int(v('fleet_requeues_total'))} requeues")
    return results, wall


def _report(args, engine, cache, library, results, wall):
    s = engine.stats
    gen_tok = sum(len(r.tokens) for r in results)
    ttfts = [r.ttft_s for r in results]
    dec_s = s["decode_s"] + s["mixed_s"]       # mixed steps advance decode too
    print(f"served {len(results)} requests ({gen_tok} generated tok) "
          f"in {wall:.3f}s | "
          f"prefill {s['prefill_tokens']} tok in {s['prefill_s']:.3f}s "
          f"({s['prefill_tokens'] / max(s['prefill_s'], 1e-9):.1f} tok/s) | "
          f"decode {s['decode_tokens']} tok in {dec_s:.3f}s "
          f"({s['decode_tokens'] / max(dec_s, 1e-9):.1f} tok/s) | "
          f"{s['mixed_steps']} mixed steps, stall {s['stall_s']:.3f}s")
    if args.speculative:
        sp = engine.spec_summary()
        print(f"speculative K={args.speculative} stride={args.draft_stride}: "
              f"{s['spec_rounds']} rounds, "
              f"acceptance {sp['acceptance_rate']:.2%}, "
              f"{s['spec_emitted']} tok emitted "
              f"({sp['tokens_per_slot_round']:.2f}/slot/round)")
    if cache is not None:
        cs = cache.summary()
        print(f"prefix cache ({args.prefix_cache_mb:g} MiB): "
              f"hit rate {cs['hit_rate']:.2%}, "
              f"{s['cache_hit_tokens']} prompt tok skipped, "
              f"{cs['snapshots']} snapshots "
              f"({cs['bytes_used'] / 2 ** 20:.2f} MiB), "
              f"{cs['evictions']} evictions")
    if library is not None:
        ls = library.summary()
        print(f"expert library ({args.expert_budget_mb:g} MiB, "
              f"{args.max_bound} binding rows): {ls['sets']} sets, "
              f"{ls['resident']} resident "
              f"({ls['bytes_device'] / 2 ** 20:.2f} MiB), "
              f"{s['expert_swaps']} swaps, {ls['faults']} faults, "
              f"{ls['evictions']} evictions, "
              f"residency hit rate {ls['residency_hit_rate']:.2%}")
    if ttfts:
        print(f"TTFT mean {np.mean(ttfts) * 1e3:.1f}ms "
              f"p50 {np.percentile(ttfts, 50) * 1e3:.1f}ms "
              f"max {np.max(ttfts) * 1e3:.1f}ms")
    by_id = {r.id: r for r in results}
    print("sample generations:",
          [by_id[i].tokens[:16] for i in sorted(by_id)[:2]])


def _write_exports(args, telem):
    """Exporter flush — runs in a ``finally`` so KeyboardInterrupt and
    crashes still leave the --metrics-out/--trace-out artifacts behind
    (an interrupted run is exactly the one worth inspecting)."""
    if args.metrics_out:
        if args.metrics_out.endswith(".prom"):
            body = telem.registry.to_prometheus()
        else:
            body = json.dumps(telem.registry.snapshot(), indent=2)
        with open(args.metrics_out, "w") as f:
            f.write(body)
        print(f"metrics snapshot written to {args.metrics_out}")
    if args.trace_out:
        with open(args.trace_out, "w") as f:
            json.dump(telem.tracer.chrome_trace(), f)
        print(f"request trace ({len(telem.tracer.timelines())} timelines) "
              f"written to {args.trace_out} — load in ui.perfetto.dev")


if __name__ == "__main__":
    main()
