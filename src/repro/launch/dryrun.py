import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (see MULTI-POD DRY-RUN spec).

Lowers + compiles every (architecture x input-shape) cell against the
production meshes — (16,16) single-pod and (2,16,16) multi-pod — and records
memory/cost/collective analyses for §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-4b --shape train_4k
  python -m repro.launch.dryrun --arch rom-mamba-1.3b --shape train_4k \
      --multi-pod --set rom.capacity_factor=1.25 --tag cf125
  python -m repro.launch.dryrun --all [--multi-pod] [--force] [--paper]
  python -m repro.launch.dryrun --summary
"""

import argparse
import json
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--paper", action="store_true",
                    help="include paper archs in --all")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config override a.b=v (repeatable)")
    ap.add_argument("--rule", action="append", default=[],
                    help="sharding-rule override logical=axis (repeatable)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--no-correction", action="store_true",
                    help="skip scan-body cost correction (compile-only pass)")
    ap.add_argument("--summary", action="store_true")
    args = ap.parse_args()

    if args.summary:
        from repro.launch.report import print_summary
        print_summary()
        return

    if args.all:
        from repro.launch import dryrun_lib as dl
        cells = dl.all_cells(include_paper=args.paper)
        mesh_name = "multi" if args.multi_pod else "single"
        for arch, shape in cells:
            out = os.path.join(dl.OUT_ROOT, mesh_name,
                               f"{arch}__{shape}.json")
            if os.path.exists(out) and not args.force:
                print(f"skip (exists): {arch} x {shape} [{mesh_name}]")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape]
            if args.multi_pod:
                cmd.extend(["--multi-pod", "--no-correction"])
            print(f"=== {arch} x {shape} [{mesh_name}] ===", flush=True)
            r = subprocess.run(cmd)
            if r.returncode != 0:
                print(f"FAILED: {arch} x {shape}", flush=True)
        return

    from repro.launch import dryrun_lib as dl
    rec = dl.run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                      overrides=args.set, rules_over=args.rule,
                      tag=args.tag, grad_accum=args.grad_accum,
                      correct=not args.no_correction)
    if "skipped" in rec:
        print(f"SKIPPED: {rec['skipped']}")
        return
    print(json.dumps({k: rec[k] for k in
                      ("arch", "shape", "mesh", "n_devices", "lower_s",
                       "compile_s", "memory", "roofline")},
                     indent=1, default=str))
    # the two prints the spec asks for:
    print("memory_analysis:", rec["memory"])
    print("cost_analysis flops/bytes per device:",
          rec["roofline"]["hlo_flops_per_device"],
          rec["roofline"]["hlo_bytes_per_device"])


if __name__ == "__main__":
    main()
