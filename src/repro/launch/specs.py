"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns (abstract batch, mode) for train/prefill
cells; ``decode_specs`` the (tokens_t, pos) pair; state/TrainState shapes
come from ``jax.eval_shape`` over the real init functions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Abstract train/prefill batch for one (arch x input-shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.kind == "encoder":
        return {"frames": SDS((B, S, cfg.frontend_dim), jnp.bfloat16),
                "labels": SDS((B, S), jnp.int32),
                "mask": SDS((B, S), jnp.bool_)}
    if cfg.kind == "vlm":
        P = cfg.num_prefix_embeds
        return {"tokens": SDS((B, S - P), jnp.int32),
                "labels": SDS((B, S - P), jnp.int32),
                "patches": SDS((B, P, cfg.frontend_dim), jnp.bfloat16)}
    return {"tokens": SDS((B, S), jnp.int32),
            "labels": SDS((B, S), jnp.int32)}


def decode_specs(cfg: ModelConfig, shape: InputShape):
    """(tokens_t, pos) abstract inputs for a serve_step cell."""
    B = shape.global_batch
    return SDS((B, 1), jnp.int32), SDS((), jnp.int32)


def decode_state_shapes(cfg: ModelConfig, shape: InputShape):
    from repro.models import lm
    B, S = shape.global_batch, shape.seq_len
    return jax.eval_shape(
        lambda: lm.init_state(cfg, B, S, jnp.dtype(cfg.dtype)))
