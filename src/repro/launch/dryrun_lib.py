"""Dry-run machinery (import-safe: never touches device-count env).

``run_cell`` lowers + compiles one (arch x input-shape x mesh) cell with
``.lower().compile()`` on abstract ShapeDtypeStructs — no allocation — and
extracts memory analysis, cost analysis, and the parsed collective schedule
into a JSON record for EXPERIMENTS.md §Dry-run / §Roofline.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs.base import (ASSIGNED_ARCHS, applicable_shapes,
                                get_config, SHAPES)
from repro.distributed import hlo_analysis as hlo
from repro.distributed.sharding import ShardingRules
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro import train as tr

PAPER_ARCHS = ("mamba-1.3b", "rom-mamba-1.3b", "samba-421m",
               "samba-421m-rom", "samba-511m", "samba-511m-rom")

OUT_ROOT = os.environ.get("REPRO_DRYRUN_DIR",
                          os.path.join(os.path.dirname(__file__),
                                       "..", "..", "..",
                                       "experiments", "dryrun"))


def _set_nested(cfg, dotted: str, value):
    """cfg override: 'rom.capacity_factor=1.25' / 'remat=full' etc."""
    try:
        value = ast.literal_eval(value)
    except (ValueError, SyntaxError):
        pass
    parts = dotted.split(".")
    if len(parts) == 1:
        return cfg.replace(**{parts[0]: value})
    sub = getattr(cfg, parts[0])
    sub = dataclasses.replace(sub, **{parts[1]: value})
    return cfg.replace(**{parts[0]: sub})


def apply_overrides(cfg, sets):
    for s in sets or ():
        k, v = s.split("=", 1)
        cfg = _set_nested(cfg, k, v)
    return cfg


def rule_overrides(rules: ShardingRules, sets):
    kw = {}
    for s in sets or ():
        k, v = s.split("=", 1)
        if v in ("None", "none", ""):
            kw[k] = (None,)
        else:
            axes = tuple(a.strip() for a in v.split("+"))
            kw[k] = ((axes if len(axes) > 1 else axes[0]), None)
    return rules.override(**kw) if kw else rules


def _mem_dict(mem):
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    if not out:
        out["repr"] = str(mem)
    return out


def _param_bytes_per_device(shapes, shardings, n_dev):
    import numpy as np
    total = 0
    for leaf, sh in zip(jax.tree_util.tree_leaves(shapes),
                        jax.tree_util.tree_leaves(
                            shardings, is_leaf=lambda x: hasattr(x, "spec"))):
        n = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        spec = sh.spec
        shard = 1
        for ax in spec:
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            for a in axes:
                shard *= sh.mesh.shape[a]
        total += n // max(shard, 1)
    return total


def _block_cost(cfg, pattern, repeats, shape, mesh, rules):
    """Per-layer-block cost/collectives, lowered standalone under the same
    mesh — corrects XLA cost_analysis counting ``lax.scan`` bodies once.

    corrected_total = program_cost + (repeats - 1) * block_cost
    (validated against a fully unrolled compile in tests/benchmarks).

    The block is lowered in ``cost_scan`` unroll mode so *inner* loops
    (attention tiles, scan chunks) are also counted exactly.
    """
    from jax.sharding import NamedSharding
    from repro.distributed import sharding as shd
    from repro.models import lm
    from repro.nn.layers import set_unroll

    cfg_one = cfg.replace(segments=((pattern, 1),), scan_layers=False)
    mode = shape.mode
    B, S = shape.global_batch, shape.seq_len
    if mode == "decode":
        S = 1
    x_sds = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
    rng_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    bp_shapes = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0),
                               cfg_one))["segments"][0][0]
    bp_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        shd.param_specs(bp_shapes, mesh, rules, lenient=True),
        is_leaf=lambda v: hasattr(v, "index"))
    x_sh = NamedSharding(mesh, shd.resolve_spec(
        x_sds.shape, ("act_batch", "act_seq", "act_embed"), mesh, rules))
    rt = lm.Runtime(shard=shd.ShardCtx(mesh, rules), rng=None,
                    train=(mode == "train"))

    set_unroll(True)
    try:
        if mode in ("train", "prefill"):
            blk = lm._remat(
                lambda bp, x, rng: lm._block_apply(pattern, cfg, bp, x, rt,
                                                   rng),
                cfg)

            def fwd(bp, x, rng):
                y, aux = blk(bp, x, rng)
                return jnp.sum(y.astype(jnp.float32))

            if mode == "train":
                fn = jax.grad(fwd, argnums=(0, 1))
            else:
                fn = fwd
            jf = jax.jit(fn, in_shardings=(bp_sh, x_sh, None))
            lowered = jf.lower(bp_shapes, x_sds, rng_sds)
        else:
            st_shapes = jax.eval_shape(
                lambda: lm.init_state(cfg_one, B, shape.seq_len,
                                      jnp.dtype(cfg.dtype)))["segments"][0][0]
            from repro import train as _tr
            st_sh = _tr.serve_state_shardings(cfg, st_shapes, mesh, rules)

            def step(bp, bst, x, pos):
                y, st, aux = lm._block_step(pattern, cfg, bp, bst, x, pos, rt)
                return y, st

            jf = jax.jit(step, in_shardings=(bp_sh, st_sh, x_sh, None),
                         out_shardings=(x_sh, st_sh))
            lowered = jf.lower(bp_shapes, st_shapes, x_sds,
                               jax.ShapeDtypeStruct((), jnp.int32))
    finally:
        set_unroll(False)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    colls = hlo.parse_collectives(compiled.as_text())
    return cost, colls


def _corrected(cost, colls, block_costs):
    """Add (repeats-1) x block cost to the scan-once program cost."""
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    secs = colls.seconds
    wire = sum(colls.wire_bytes_by_kind.values())
    counts = dict(colls.counts)
    for (bcost, bcolls), extra in block_costs:
        flops += extra * float(bcost.get("flops", 0.0))
        bytes_acc += extra * float(bcost.get("bytes accessed", 0.0))
        secs += extra * bcolls.seconds
        wire += extra * sum(bcolls.wire_bytes_by_kind.values())
        for k, v in bcolls.counts.items():
            counts[k] = counts.get(k, 0) + extra * v
    return {"flops": flops, "bytes accessed": bytes_acc}, secs, wire, counts


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               overrides=None, rules_over=None, grad_accum: int = 1):
    """Build and lower one cell; returns (lowered, cfg, shape, mesh, extras)."""
    cfg = apply_overrides(get_config(arch), overrides)
    shape, skip = applicable_shapes(cfg)[shape_name]
    if skip:
        return None, cfg, None, None, {"skipped": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rule_overrides(ShardingRules(), rules_over)
    extras = {}
    if shape.mode == "train":
        hp = tr.TrainHParams(grad_accum=grad_accum)
        fn = tr.make_train_fn(cfg, mesh, rules, hp)
        st_shapes = tr.train_state_shapes(cfg)
        st_sh = tr.state_shardings(st_shapes, mesh, rules)
        batch = sp.input_specs(cfg, shape)
        b_sh = tr.batch_shardings(batch, mesh)
        jf = jax.jit(fn, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, None))
        lowered = jf.lower(st_shapes, batch)
        extras["param_bytes_per_device"] = _param_bytes_per_device(
            st_shapes["params"], st_sh["params"], mesh.devices.size)
        extras["state_bytes_per_device"] = _param_bytes_per_device(
            st_shapes, st_sh, mesh.devices.size)
    elif shape.mode == "prefill":
        fn = tr.make_prefill_fn(cfg, mesh, rules)
        from repro.models import lm
        p_shapes = jax.eval_shape(
            lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        p_sh = tr.state_shardings(p_shapes, mesh, rules)
        batch = {k: v for k, v in sp.input_specs(cfg, shape).items()
                 if k != "labels"}
        b_sh = tr.batch_shardings(batch, mesh)
        jf = jax.jit(fn, in_shardings=(p_sh, b_sh))
        lowered = jf.lower(p_shapes, batch)
        extras["param_bytes_per_device"] = _param_bytes_per_device(
            p_shapes, p_sh, mesh.devices.size)
    else:  # decode
        fn = tr.make_serve_fn(cfg, mesh, rules)
        from repro.models import lm
        p_shapes = jax.eval_shape(
            lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        p_sh = tr.state_shardings(p_shapes, mesh, rules)
        st_shapes = sp.decode_state_shapes(cfg, shape)
        st_sh = tr.serve_state_shardings(cfg, st_shapes, mesh, rules)
        tok, pos = sp.decode_specs(cfg, shape)
        tok_sh = tr.batch_shardings({"t": tok}, mesh)["t"]
        jf = jax.jit(fn, in_shardings=(p_sh, st_sh, tok_sh, None),
                     out_shardings=(None, None, st_sh))
        lowered = jf.lower(p_shapes, st_shapes, tok, pos)
        extras["param_bytes_per_device"] = _param_bytes_per_device(
            p_shapes, p_sh, mesh.devices.size)
        extras["cache_bytes_per_device"] = _param_bytes_per_device(
            st_shapes, st_sh, mesh.devices.size)
    return lowered, cfg, shape, mesh, extras


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             overrides=None, rules_over=None, tag: str = "",
             out_dir: str = None, grad_accum: int = 1,
             save: bool = True, correct: bool = True) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    t0 = time.time()
    lowered, cfg, shape, mesh, extras = lower_cell(
        arch, shape_name, multi_pod=multi_pod, overrides=overrides,
        rules_over=rules_over, grad_accum=grad_accum)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "tag": tag, "overrides": list(overrides or ()),
           "rules": list(rules_over or ())}
    if lowered is None:
        rec.update({"skipped": extras["skipped"]})
    else:
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        cost = compiled.cost_analysis()
        txt = compiled.as_text()
        colls = hlo.parse_collectives(txt)

        # scan-body trip-count correction (XLA counts loop bodies once);
        # skipped for the multi-pod pass (compile success + memory +
        # raw collectives are its deliverable; rooflines are single-pod)
        block_costs = []
        rules = rule_overrides(ShardingRules(), rules_over)
        if cfg.scan_layers and correct:
            for pattern, repeats in cfg.segments:
                if repeats > 1:
                    bc = _block_cost(cfg, pattern, repeats, shape, mesh,
                                     rules)
                    block_costs.append((bc, repeats - 1))
        if block_costs:
            rec["raw_uncorrected"] = {
                "flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "collective_s": colls.seconds,
            }
            cost, secs, wire, counts = _corrected(cost, colls, block_costs)
            colls = hlo.CollectiveStats(
                counts=counts, bytes_by_kind=colls.bytes_by_kind,
                wire_bytes_by_kind={"corrected_total": wire},
                seconds=secs, seconds_by_kind=colls.seconds_by_kind,
                ops=[])
        terms = hlo.roofline_terms(cost, colls)
        n_dev = mesh.devices.size
        mf = hlo.model_flops(cfg, shape, n_dev)
        terms["model_flops_per_device"] = mf
        terms["useful_flops_ratio"] = (
            mf / terms["hlo_flops_per_device"]
            if terms["hlo_flops_per_device"] else None)
        terms["roofline_fraction"] = (
            (mf / hlo.PEAK_FLOPS) / terms["step_s_model"]
            if terms["step_s_model"] else None)
        rec.update({
            "n_devices": n_dev,
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "memory": _mem_dict(compiled.memory_analysis()),
            "cost_keys": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
            "collectives": {
                "counts": colls.counts,
                "bytes_by_kind": colls.bytes_by_kind,
                "wire_bytes_by_kind": colls.wire_bytes_by_kind,
                "seconds_by_kind": colls.seconds_by_kind,
            },
            "roofline": terms,
            **extras,
        })
    if save:
        out_dir = out_dir or os.path.join(OUT_ROOT, mesh_name)
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        path = os.path.join(out_dir, f"{arch}__{shape_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
        rec["path"] = path
    return rec


def all_cells(include_paper: bool = True):
    out = []
    for a in ASSIGNED_ARCHS:
        for s in SHAPES:
            out.append((a, s))
    if include_paper:
        for a in PAPER_ARCHS:                 # extra rows beyond the spec
            for s in ("train_4k", "long_500k"):
                out.append((a, s))
    return out
