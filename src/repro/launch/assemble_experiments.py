"""Assemble EXPERIMENTS.md tables from dry-run records (idempotent)."""
from __future__ import annotations

import glob
import json
import os

from repro.launch.dryrun_lib import OUT_ROOT
from repro.launch.report import markdown_summary

EXP = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "EXPERIMENTS.md")


def dryrun_stats(mesh):
    recs = []
    for p in sorted(glob.glob(os.path.join(OUT_ROOT, mesh, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    base = [r for r in recs if not r.get("tag")]
    ok = [r for r in base if "skipped" not in r]
    sk = [r for r in base if "skipped" in r]
    return recs, ok, sk


def dryrun_section():
    _, ok_s, sk_s = dryrun_stats("single")
    _, ok_m, sk_m = dryrun_stats("multi")
    lines = [
        f"**Status**: single-pod (16,16): {len(ok_s)} cells compiled, "
        f"{len(sk_s)} spec-mandated skips; multi-pod (2,16,16): "
        f"{len(ok_m)} cells compiled, {len(sk_m)} skips.",
        "",
        "Per-device state bytes (exact, from resolved shardings) for the",
        "largest cells — the fits-in-HBM evidence (v5e: 16 GB):",
        "",
        "| arch | shape | mesh | params+opt GB/dev | cache GB/dev |",
        "|---|---|---|---|---|",
    ]
    for mesh in ("single", "multi"):
        _, ok, _ = dryrun_stats(mesh)
        for r in ok:
            sb = r.get("state_bytes_per_device") or \
                r.get("param_bytes_per_device")
            cb = r.get("cache_bytes_per_device")
            if sb and sb > 2e9 or (cb and cb > 5e8):
                lines.append(
                    f"| {r['arch']} | {r['shape']} | {mesh} | "
                    f"{(sb or 0) / 1e9:.2f} | "
                    f"{(cb or 0) / 1e9:.2f} |")
    lines += ["", "Multi-pod records confirm the `pod` axis shards: batch "
              "collectives span 512 devices (group > 256 → DCN-rated in "
              "the model); see `experiments/dryrun/multi/*.json`.", ""]
    return "\n".join(lines)


def main():
    with open(EXP) as f:
        text = f.read()
    text = text.replace("<!-- DRYRUN_TABLE -->", dryrun_section())
    roof = markdown_summary("single")
    text = text.replace("<!-- ROOFLINE_TABLE -->", roof)
    with open(EXP, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md assembled.")


if __name__ == "__main__":
    main()
