"""End-to-end fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch rom-mamba-115m \
        --steps 200 --batch 8 --seq 512 --ckpt /tmp/run1 --smoke

``--smoke`` swaps in the reduced config of the same family (CPU-friendly);
the full configs are exercised via the dry-run.  The loop runs under
``RunManager``: atomic checkpoints, restart-on-failure, straggler flags.
XLA latency-hiding-scheduler flags for real TPU runs are set below (no-ops
on CPU) — they overlap ZeRO all-gathers with compute.
"""
from __future__ import annotations

import os

# Overlap-friendly XLA flags for real TPU fleets (harmless on CPU).
os.environ.setdefault(
    "XLA_FLAGS_TPU_APPEND",
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_enable_async_all_gather=true")

import argparse

import jax
import jax.numpy as jnp

from repro import train as tr
from repro.configs.all_configs import reduce_for_smoke
from repro.configs.base import get_config
from repro.data.pipeline import corpus_for
from repro.distributed.fault_tolerance import RunManager
from repro.launch.mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=4e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="train the reduced same-family config")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    mesh = make_host_mesh()
    hp = tr.TrainHParams(base_lr=args.lr, warmup_steps=args.warmup,
                         total_steps=args.steps, grad_accum=args.grad_accum)
    step_fn = tr.make_train_step(cfg, mesh, hp=hp, donate=False)
    corpus = corpus_for(cfg, args.seq, args.batch, args.seed)

    def data_fn(step):
        return {k: jnp.asarray(v) for k, v in corpus.batch_at(step).items()}

    def init_fn():
        return tr.init_train_state(cfg, args.seed)

    shapes = tr.train_state_shapes(cfg)
    shards = tr.state_shardings(shapes, mesh)
    mgr = RunManager(args.ckpt, save_every=args.save_every)
    state, history = mgr.run(init_fn=init_fn, step_fn=step_fn,
                             data_fn=data_fn, num_steps=args.steps,
                             state_shardings=shards,
                             log_every=args.log_every)
    final = history[-1] if history else {}
    print(f"done: {args.steps} steps; final loss="
          f"{float(final.get('loss', float('nan'))):.4f}; "
          f"restarts={mgr.restarts} straggler_flags={len(mgr.straggler.flags)}")


if __name__ == "__main__":
    main()
