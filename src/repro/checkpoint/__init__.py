"""Sharded checkpoints: atomic commit, async save, elastic restore.

Layout: ``<dir>/step_<N>/ckpt.npz`` + ``meta.json``; a checkpoint becomes
visible only when its directory is atomically renamed from ``.tmp`` —
a crash mid-save never corrupts the latest restorable step.

Elastic restore: the checkpoint stores *logical* content (full arrays keyed
by pytree path — on a multi-host fleet this generalizes to one file per
host-shard with the same commit protocol); ``restore`` re-resolves shardings
against whatever mesh the *new* job brings up, so restarting on a different
device count just re-shards (tested in tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        out[jax.tree_util.keystr(path)] = np.asarray(leaf)
    return out, treedef


def save(ckpt_dir: str, step: int, state, meta: Optional[dict] = None,
         async_: bool = False):
    os.makedirs(ckpt_dir, exist_ok=True)
    host_state = jax.device_get(state)          # snapshot before async write

    def write():
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}")
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat, _ = _flatten(host_state)
        np.savez(os.path.join(tmp, "ckpt.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **(meta or {})}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                  # atomic commit

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def available_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "ckpt.npz")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, target, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for the *current* mesh (elastic re-shard on load)."""
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "ckpt.npz")
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    sh_flat = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None else [None] * len(flat))
    for (p, leaf), sh in zip(flat, sh_flat):
        key = jax.tree_util.keystr(p)
        arr = data[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                       leaf.shape)
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), leaves), step


def meta_for(ckpt_dir: str, step: int) -> dict:
    with open(os.path.join(ckpt_dir, f"step_{step:08d}", "meta.json")) as f:
        return json.load(f)
