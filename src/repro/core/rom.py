"""Routing Mamba (RoM): shared-router projection experts — the paper's core.

One router per layer (Eq. 9).  Its top-K decision is reused by every
expertized projection:

  Mamba (selective expertization, §4.3):
      Conv Proj  H = sum_{i in TopK} X W_in,i          (Eq. 11, unweighted)
      Gate Proj  G = SiLU(sum_{i in TopK} X W_g,i)     (Eq. 10, unweighted)
      Out  Proj  O = sum_i R_i(X) (Y*G) W_out,i        (Eq. 12-13, weighted)
      x Proj / dt Proj / Conv1D / A / D shared across experts (MQA analogy);
      optionally expertized via targets ('x', 'dt') for the Table-1 ablation.

  Mamba-2 / GDN / RG-LRU / mLSTM (comprehensive expertization, §5.4):
      the fused input projection(s) and the output projection are all
      experts under the same routing decision.

The *shared* decision is also what makes this cheap: one sort + one inverse
permutation + one dispatched input buffer serve all input-side projections
(see moe_dispatch.SharedMoELinear).  A naive per-projection MoE (MoE-Mamba
baseline, core/moe_mamba.py) pays routing + dispatch per projection and —
per the paper — loses quality too.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import moe_dispatch as md
from repro.core import router as rtr
from repro.kernels import ops as kops
from repro.nn import rglru as rgl
from repro.nn import ssm
from repro.nn import xlstm as xl
from repro.nn.layers import Runtime, dense, dense_init, silu
from repro.serve.state import batch_spec


# ---------------------------------------------------------------------------
# token grouping: groups shard exactly over the DP mesh axes so all MoE
# dispatch compute stays device-local (the paper's no-EP design).
# ---------------------------------------------------------------------------

def dp_size(rt: Runtime) -> int:
    mesh = rt.shard.mesh
    if mesh is None:
        return 1
    s = 1
    for ax in ("pod", "data"):
        s *= mesh.shape.get(ax, 1)
    return s


def num_groups(batch: int, rt: Runtime) -> int:
    return math.gcd(batch, dp_size(rt))


class SharedRouting:
    """Route once; project many.  Binds (routing, dispatch, impl) and exposes
    ``proj(t, w, weighted, tag)`` for any (B,S,·) tensor under the *same*
    decision — Conv/Gate share the dispatched X buffer via the tag."""

    def __init__(self, w_router, x, rom, rt: Runtime, rng=None):
        # Multi-tenant serving (serve/expert_library.py): the engine binds
        # expert leaves as per-set tuples and a (B,) set index on
        # ``rt.expert_sets``.  A tuple router fans out into one
        # sub-SharedRouting per bound set — each running the *identical*
        # single-set path below, at the identical shapes a dedicated
        # single-set engine would trace, which is what makes per-tenant
        # greedy decode bitwise identical — and ``proj`` selects each
        # slot's bound set's output row.  One routed GEMM per live set.
        if isinstance(w_router, tuple):
            self.subs = tuple(SharedRouting(w, x, rom, rt, rng=rng)
                              for w in w_router)
            self.sel = jnp.asarray(rt.expert_sets, jnp.int32)
            self.B, self.S = self.subs[0].B, self.subs[0].S
            self.rom = rom
            return
        self.subs = None
        B, S, D = x.shape
        self.B, self.S = B, S
        self.G = num_groups(B, rt)
        self.g = B * S // self.G
        self.rom = rom
        xt = x.reshape(self.G, self.g, D)
        self.routing = rtr.route(
            w_router, xt, num_experts=rom.num_experts, top_k=rom.top_k,
            jitter_eps=rom.jitter_eps, aux_loss_weight=rom.aux_loss_weight,
            rng=rng, train=rt.train)
        self.impl = rom.impl
        # decode fast path: when an explicit kernel impl is active
        # (EngineConfig.kernels via kernels.default_impl) and the batch is
        # decode-shaped (S == 1, one token per slot), skip the capacity
        # dispatch machinery entirely — every projection goes through
        # ops.routed_matmul on the raw top-k (indices, weights), which at
        # these token counts beats sort + offsets + capacity gathers
        self.fast = kops.active_default() is not None and S == 1
        if self.impl == "dense" or self.fast:
            self.lin = None
        else:
            dsp = md.make_dispatch(self.routing, rom.capacity_factor)
            # the shard context carries the live plan's expert partition:
            # dispatch buffers are constrained (and the grouped kernel
            # shard_mapped) so tokens route to the shards owning their
            # experts' weights — a no-op under the replicated training
            # default and off-mesh
            self.lin = md.SharedMoELinear(dsp, impl=self.impl,
                                          shard=rt.shard)

    def proj(self, t, w, *, weighted: bool, tag: str):
        """t (B,S,Din) -> (B,S,Dout) through the routed experts w (E,Din,Dout)."""
        if self.subs is not None:
            # per-set fan-out: tuple weights pair up with the sub-routings;
            # a plain array broadcasts (a leaf the library does not swap,
            # e.g. the FFN-MoE reusing this routing via ctx)
            ws = w if isinstance(w, tuple) else (w,) * len(self.subs)
            ys = [sub.proj(t, wi, weighted=weighted, tag=tag)
                  for sub, wi in zip(self.subs, ws)]
            return md.select_per_set(ys, self.sel)
        B, S, Din = t.shape
        if self.fast:
            T = self.G * self.g                      # = B*S decode tokens
            K = self.routing.top_k
            y = kops.routed_matmul(
                t.reshape(T, Din), w,
                self.routing.expert_idx.reshape(T, K),
                self.routing.weights.reshape(T, K) if weighted else None)
            return y.reshape(B, S, -1)
        tt = t.reshape(self.G, self.g, Din)
        if self.impl == "dense":
            y = md.dense_moe_linear(self.routing, tt, w, weighted=weighted)
        elif self.impl == "ragged":
            y = md.ragged_moe_linear(self.lin.dsp, tt, w, weighted=weighted)
        else:
            y = self.lin(tt, w, weighted=weighted, tag=tag)
        return y.reshape(B, S, -1)

    def metrics(self) -> dict:
        if self.subs is not None:
            # aux metrics are training-time diagnostics; serving never
            # feeds them back into logits, so the first set's are
            # representative enough for the stats stream
            return self.subs[0].metrics()
        m = dict(self.routing.metrics)
        if self.lin is not None:
            m["drop_frac"] = self.lin.dsp.drop_frac
        return m


def _expert_init(key, E, d_in, d_out, dtype):
    ks = jax.random.split(key, E)
    return jax.vmap(lambda k: dense_init(k, d_in, d_out, dtype=dtype))(ks)


def _fold_rng(rt: Runtime):
    return rt.rng


# ---------------------------------------------------------------------------
# RoM-Mamba (the paper's main configuration)
# ---------------------------------------------------------------------------

def rom_mamba_init(key, cfg):
    rom = cfg.rom
    de, dt_rank, n = ssm.mamba_dims(cfg)
    ks = jax.random.split(key, 8)
    p = ssm.mamba_init_shared(ks[0], cfg)
    E, pd = rom.num_experts, cfg.param_dtype
    t = rom.targets
    p["w_router"] = rtr.router_init(ks[1], cfg.d_model, E, rom.router_dtype)
    if "conv" in t:
        p["e_w_in"] = _expert_init(ks[2], E, cfg.d_model, de, pd)
    else:
        p["w_in"] = dense_init(ks[2], cfg.d_model, de, dtype=pd)
    if "gate" in t:
        p["e_w_gate"] = _expert_init(ks[3], E, cfg.d_model, de, pd)
    else:
        p["w_gate"] = dense_init(ks[3], cfg.d_model, de, dtype=pd)
    if "out" in t:
        p["e_w_out"] = _expert_init(ks[4], E, de, cfg.d_model, pd)
    else:
        p["w_out"] = dense_init(ks[4], de, cfg.d_model, dtype=pd)
    if "x" in t:
        p["e_w_x"] = _expert_init(ks[5], E, de, dt_rank + 2 * n, pd)
        del p["w_x"]
    if "dt" in t:
        p["e_w_dt"] = jax.vmap(
            lambda k: dense_init(k, dt_rank, de, dtype=pd,
                                 scale=dt_rank ** -0.5))(
            jax.random.split(ks[6], E))
        del p["w_dt"]
    return p


def _rom_proj_fns(sr: SharedRouting, params, targets):
    """Optionally expertized x/dt projections for the Table-1 ablation."""
    x_fn = (lambda u: sr.proj(u, params["e_w_x"], weighted=False, tag="u")) \
        if "x" in targets else None
    dt_fn = (lambda v: sr.proj(v, params["e_w_dt"], weighted=False, tag="dt")) \
        if "dt" in targets else None
    return x_fn, dt_fn


def rom_mamba_apply(params, x, cfg, rt: Runtime, ctx=None):
    rom = cfg.rom
    t = rom.targets
    sr = SharedRouting(params["w_router"], x, rom, rt, rng=_fold_rng(rt))
    if ctx is not None:
        ctx["rom_routing"] = sr                     # Eq. 14-15 reuse
    if "conv" in t:
        h = sr.proj(x, params["e_w_in"], weighted=False, tag="x")
    else:
        h = dense(x, params["w_in"])
    h = rt.shard.cons(h, "act_batch", "act_seq", "act_inner")
    x_fn, dt_fn = _rom_proj_fns(sr, params, t)
    y = ssm.mamba_core(params, h, cfg, rt, x_proj_fn=x_fn, dt_proj_fn=dt_fn)
    if "gate" in t:
        g = silu(sr.proj(x, params["e_w_gate"], weighted=False, tag="x"))
    else:
        g = silu(dense(x, params["w_gate"]))
    z = y * g
    if "out" in t:
        out = sr.proj(z, params["e_w_out"], weighted=True, tag="z")
    else:
        out = dense(z, params["w_out"])
    return out, sr.metrics()


def rom_mamba_init_state(cfg, batch, dtype):
    return ssm.mamba_init_state(cfg, batch, dtype)


# RoM routes projections only; the recurrent/conv decode state is the
# wrapped core's, so every RoM variant shares its core's StateSpec.
rom_mamba_state_spec = batch_spec(rom_mamba_init_state)
rom_mamba2_state_spec = ssm.mamba2_state_spec


def rom_mamba_prefill(params, x, state, pos0, cfg, rt: Runtime, ctx=None):
    """Parallel prefill with the same per-token routing decisions the decode
    step would make (router is deterministic at inference: no jitter, no
    rng), so the prefill->decode boundary is routing-consistent."""
    rom = cfg.rom
    t = rom.targets
    sr = SharedRouting(params["w_router"], x, rom, rt, rng=None)
    if ctx is not None:
        ctx["rom_routing"] = sr
    if "conv" in t:
        h = sr.proj(x, params["e_w_in"], weighted=False, tag="x")
    else:
        h = dense(x, params["w_in"])
    h = rt.shard.cons(h, "act_batch", "act_seq", "act_inner")
    x_fn, dt_fn = _rom_proj_fns(sr, params, t)
    y, state = ssm.mamba_core_prefill(params, h, state, cfg, rt,
                                      x_proj_fn=x_fn, dt_proj_fn=dt_fn)
    if "gate" in t:
        g = silu(sr.proj(x, params["e_w_gate"], weighted=False, tag="x"))
    else:
        g = silu(dense(x, params["w_gate"]))
    z = y * g
    if "out" in t:
        out = sr.proj(z, params["e_w_out"], weighted=True, tag="z")
    else:
        out = dense(z, params["w_out"])
    return out, state, sr.metrics()


def rom_mamba_step(params, x_t, state, pos, cfg, rt: Runtime, ctx=None):
    rom = cfg.rom
    t = rom.targets
    sr = SharedRouting(params["w_router"], x_t, rom, rt, rng=None)
    if ctx is not None:
        ctx["rom_routing"] = sr
    if "conv" in t:
        h = sr.proj(x_t, params["e_w_in"], weighted=False, tag="x")[:, 0]
    else:
        h = dense(x_t[:, 0], params["w_in"])
    x_fn = (lambda u: sr.proj(u[:, None], params["e_w_x"], weighted=False,
                              tag="u")[:, 0]) if "x" in t else None
    dt_fn = (lambda v: sr.proj(v[:, None], params["e_w_dt"], weighted=False,
                               tag="dt")[:, 0]) if "dt" in t else None
    y, state = ssm.mamba_core_step(params, h, state, cfg, rt,
                                   x_proj_fn=x_fn, dt_proj_fn=dt_fn)
    if "gate" in t:
        g = silu(sr.proj(x_t, params["e_w_gate"], weighted=False,
                         tag="x")[:, 0])
    else:
        g = silu(dense(x_t[:, 0], params["w_gate"]))
    z = (y * g)[:, None]
    if "out" in t:
        out = sr.proj(z, params["e_w_out"], weighted=True, tag="z")
    else:
        out = dense(z, params["w_out"])
    return out, state, sr.metrics()


# ---------------------------------------------------------------------------
# Comprehensive expertization (§5.4): Mamba-2, Gated DeltaNet, RG-LRU, mLSTM.
# All large projections become experts under one shared routing decision.
# ---------------------------------------------------------------------------

def rom_mamba2_init(key, cfg):
    rom = cfg.rom
    de, nh, hd, n = ssm.mamba2_dims(cfg)
    ks = jax.random.split(key, 4)
    p = ssm.mamba2_init(ks[0], cfg)
    d_in = p["w_zxbcdt"].shape[1]
    E, pd = rom.num_experts, cfg.param_dtype
    p["e_w_zxbcdt"] = _expert_init(ks[1], E, cfg.d_model, d_in, pd)
    p["e_w_out"] = _expert_init(ks[2], E, de, cfg.d_model, pd)
    del p["w_zxbcdt"], p["w_out"]
    p["w_router"] = rtr.router_init(ks[3], cfg.d_model, E, rom.router_dtype)
    return p


def rom_mamba2_apply(params, x, cfg, rt: Runtime, ctx=None):
    sr = SharedRouting(params["w_router"], x, cfg.rom, rt, rng=_fold_rng(rt))
    if ctx is not None:
        ctx["rom_routing"] = sr
    zxbcdt = sr.proj(x, params["e_w_zxbcdt"], weighted=False, tag="x")
    y = ssm.mamba2_core(params, zxbcdt, cfg, rt)
    out = sr.proj(y, params["e_w_out"], weighted=True, tag="y")
    return out, sr.metrics()


def rom_mamba2_step(params, x_t, state, pos, cfg, rt: Runtime, ctx=None):
    sr = SharedRouting(params["w_router"], x_t, cfg.rom, rt, rng=None)
    if ctx is not None:
        ctx["rom_routing"] = sr
    de, nh, hd, n = ssm.mamba2_dims(cfg)
    zxbcdt = sr.proj(x_t, params["e_w_zxbcdt"], weighted=False, tag="x")[:, 0]
    # replicate mamba2_step's core on the routed projection
    z, xbc, dt_in = jnp.split(zxbcdt, [de, 2 * de + 2 * n], axis=-1)
    xbc, conv_buf = ssm.causal_conv1d_step(xbc, state["conv"],
                                           params["conv_w"], params["conv_b"])
    xbc = silu(xbc)
    x_, B_t, C_t = jnp.split(xbc, [de, de + n], axis=-1)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + params["dt_bias"])
    xh = x_.reshape(-1, nh, hd).astype(jnp.float32)
    # core-only fused step (no w_out: the out-projection is routed below)
    h, y = kops.mamba2_step(state["h"], xh, dt, params["A_log_h"], B_t, C_t,
                            params["D_h"], z, params["scale_inner"],
                            cfg.norm_eps)
    out = sr.proj(y[:, None], params["e_w_out"], weighted=True, tag="y")
    return out, {"h": h, "conv": conv_buf}, sr.metrics()


def rom_mamba2_prefill(params, x, state, pos0, cfg, rt: Runtime, ctx=None):
    sr = SharedRouting(params["w_router"], x, cfg.rom, rt, rng=None)
    if ctx is not None:
        ctx["rom_routing"] = sr
    zxbcdt = sr.proj(x, params["e_w_zxbcdt"], weighted=False, tag="x")
    y, state = ssm.mamba2_core_prefill(params, zxbcdt, state, cfg, rt)
    out = sr.proj(y, params["e_w_out"], weighted=True, tag="y")
    return out, state, sr.metrics()


def rom_gdn_init(key, cfg):
    rom = cfg.rom
    nh, dk_h, dv_h, dk, dv = ssm.gdn_dims(cfg)
    ks = jax.random.split(key, 4)
    p = ssm.gdn_init(ks[0], cfg)
    E, pd = rom.num_experts, cfg.param_dtype
    p["e_w_qkvz"] = _expert_init(ks[1], E, cfg.d_model, 2 * dk + 2 * dv, pd)
    p["e_w_out"] = _expert_init(ks[2], E, dv, cfg.d_model, pd)
    del p["w_qkvz"], p["w_out"]
    p["w_router"] = rtr.router_init(ks[3], cfg.d_model, E, rom.router_dtype)
    return p


def rom_gdn_apply(params, x, cfg, rt: Runtime, ctx=None):
    sr = SharedRouting(params["w_router"], x, cfg.rom, rt, rng=_fold_rng(rt))
    if ctx is not None:
        ctx["rom_routing"] = sr
    qkvz = sr.proj(x, params["e_w_qkvz"], weighted=False, tag="x")
    ab = dense(x, params["w_ab"])                   # small proj stays shared
    y = ssm.gdn_core(params, qkvz, ab, cfg, rt)
    out = sr.proj(y, params["e_w_out"], weighted=True, tag="y")
    return out, sr.metrics()


def rom_gdn_init_state(cfg, batch, dtype):
    return ssm.gdn_init_state(cfg, batch, dtype)


rom_gdn_state_spec = batch_spec(rom_gdn_init_state)


def rom_gdn_step(params, x_t, state, pos, cfg, rt: Runtime, ctx=None):
    sr = SharedRouting(params["w_router"], x_t, cfg.rom, rt, rng=None)
    if ctx is not None:
        ctx["rom_routing"] = sr
    nh, dk_h, dv_h, dk, dv = ssm.gdn_dims(cfg)
    xt = x_t[:, 0]
    qkvz = sr.proj(x_t, params["e_w_qkvz"], weighted=False, tag="x")[:, 0]
    ab = dense(xt, params["w_ab"])
    qkv, z = jnp.split(qkvz, [2 * dk + dv], axis=-1)
    qkv, conv_buf = ssm.causal_conv1d_step(qkv, state["conv"],
                                           params["conv_w"], params["conv_b"])
    qkv = silu(qkv)
    q, k, v = jnp.split(qkv, [dk, 2 * dk], axis=-1)
    B_ = xt.shape[0]
    q = q.reshape(B_, nh, dk_h)
    k = k.reshape(B_, nh, dk_h)
    v = v.reshape(B_, nh, dv_h)
    q = q / jnp.linalg.norm(q, axis=-1, keepdims=True).clip(1e-6)
    k = k / jnp.linalg.norm(k, axis=-1, keepdims=True).clip(1e-6)
    a_in, b_in = jnp.split(ab, 2, axis=-1)
    a = jnp.exp(-jnp.exp(jnp.clip(a_in.astype(jnp.float32), -8, 3)))
    b = jax.nn.sigmoid(b_in.astype(jnp.float32))
    # core-only fused step (no w_out: the out-projection is routed below)
    S, y = kops.gdn_step(state["S"], q, k, v, a, b, z,
                         params["scale_inner"], cfg.norm_eps)
    out = sr.proj(y[:, None], params["e_w_out"], weighted=True, tag="y")
    return out, {"S": S, "conv": conv_buf}, sr.metrics()


def rom_gdn_prefill(params, x, state, pos0, cfg, rt: Runtime, ctx=None):
    sr = SharedRouting(params["w_router"], x, cfg.rom, rt, rng=None)
    if ctx is not None:
        ctx["rom_routing"] = sr
    qkvz = sr.proj(x, params["e_w_qkvz"], weighted=False, tag="x")
    ab = dense(x, params["w_ab"])
    y, state = ssm.gdn_core_prefill(params, qkvz, ab, state, cfg, rt)
    out = sr.proj(y, params["e_w_out"], weighted=True, tag="y")
    return out, state, sr.metrics()


def rom_rglru_init(key, cfg):
    rom = cfg.rom
    d_rnn, _, _ = rgl.rglru_dims(cfg)
    ks = jax.random.split(key, 5)
    p = rgl.rglru_init_shared(ks[0], cfg)
    E, pd = rom.num_experts, cfg.param_dtype
    p["e_w_rec_in"] = _expert_init(ks[1], E, cfg.d_model, d_rnn, pd)
    p["e_w_rec_gate"] = _expert_init(ks[2], E, cfg.d_model, d_rnn, pd)
    p["e_w_out"] = _expert_init(ks[3], E, d_rnn, cfg.d_model, pd)
    p["w_router"] = rtr.router_init(ks[4], cfg.d_model, E, rom.router_dtype)
    return p


def rom_rglru_apply(params, x, cfg, rt: Runtime, ctx=None):
    sr = SharedRouting(params["w_router"], x, cfg.rom, rt, rng=_fold_rng(rt))
    if ctx is not None:
        ctx["rom_routing"] = sr
    u = sr.proj(x, params["e_w_rec_in"], weighted=False, tag="x")
    u = rt.shard.cons(u, "act_batch", "act_seq", "act_inner")
    h = rgl.rglru_core(params, u, cfg, rt)
    gate = jax.nn.gelu(sr.proj(x, params["e_w_rec_gate"], weighted=False,
                               tag="x"))
    out = sr.proj(h * gate, params["e_w_out"], weighted=True, tag="z")
    return out, sr.metrics()


def rom_rglru_init_state(cfg, batch, dtype):
    return rgl.rglru_init_state(cfg, batch, dtype)


rom_rglru_state_spec = batch_spec(rom_rglru_init_state)


def rom_rglru_step(params, x_t, state, pos, cfg, rt: Runtime, ctx=None):
    sr = SharedRouting(params["w_router"], x_t, cfg.rom, rt, rng=None)
    if ctx is not None:
        ctx["rom_routing"] = sr
    u_t = sr.proj(x_t, params["e_w_rec_in"], weighted=False, tag="x")[:, 0]
    h, state = rgl.rglru_core_step(params, u_t, state, cfg, rt)
    gate = jax.nn.gelu(sr.proj(x_t, params["e_w_rec_gate"], weighted=False,
                               tag="x")[:, 0])
    out = sr.proj((h * gate)[:, None], params["e_w_out"], weighted=True,
                  tag="z")
    return out, state, sr.metrics()


def rom_rglru_prefill(params, x, state, pos0, cfg, rt: Runtime, ctx=None):
    sr = SharedRouting(params["w_router"], x, cfg.rom, rt, rng=None)
    if ctx is not None:
        ctx["rom_routing"] = sr
    u = sr.proj(x, params["e_w_rec_in"], weighted=False, tag="x")
    u = rt.shard.cons(u, "act_batch", "act_seq", "act_inner")
    h, state = rgl.rglru_core_prefill(params, u, state, cfg, rt)
    gate = jax.nn.gelu(sr.proj(x, params["e_w_rec_gate"], weighted=False,
                               tag="x"))
    out = sr.proj(h * gate, params["e_w_out"], weighted=True, tag="z")
    return out, state, sr.metrics()


def rom_mlstm_init(key, cfg):
    rom = cfg.rom
    inner, *_ = xl.mlstm_dims(cfg)
    ks = jax.random.split(key, 5)
    p = xl.mlstm_init_shared(ks[0], cfg)
    E, pd = rom.num_experts, cfg.param_dtype
    p["e_w_in"] = _expert_init(ks[1], E, cfg.d_model, inner, pd)
    p["e_w_gate"] = _expert_init(ks[2], E, cfg.d_model, inner, pd)
    p["e_w_out"] = _expert_init(ks[3], E, inner, cfg.d_model, pd)
    p["w_router"] = rtr.router_init(ks[4], cfg.d_model, E, rom.router_dtype)
    return p


def rom_mlstm_apply(params, x, cfg, rt: Runtime, ctx=None):
    sr = SharedRouting(params["w_router"], x, cfg.rom, rt, rng=_fold_rng(rt))
    if ctx is not None:
        ctx["rom_routing"] = sr
    h = sr.proj(x, params["e_w_in"], weighted=False, tag="x")
    h = rt.shard.cons(h, "act_batch", "act_seq", "act_inner")
    z = sr.proj(x, params["e_w_gate"], weighted=False, tag="x")
    y = xl.mlstm_core(params, h, z, cfg, rt, chunked=cfg.xlstm.chunk > 0)
    out = sr.proj(y, params["e_w_out"], weighted=True, tag="y")
    return out, sr.metrics()


def rom_mlstm_init_state(cfg, batch, dtype):
    return xl.mlstm_init_state(cfg, batch, dtype)


rom_mlstm_state_spec = batch_spec(rom_mlstm_init_state)


def rom_mlstm_step(params, x_t, state, pos, cfg, rt: Runtime, ctx=None):
    sr = SharedRouting(params["w_router"], x_t, cfg.rom, rt, rng=None)
    if ctx is not None:
        ctx["rom_routing"] = sr
    h_t = sr.proj(x_t, params["e_w_in"], weighted=False, tag="x")[:, 0]
    z_t = sr.proj(x_t, params["e_w_gate"], weighted=False, tag="x")[:, 0]
    y, state = xl.mlstm_core_step(params, h_t, z_t, state, cfg, rt)
    out = sr.proj(y[:, None], params["e_w_out"], weighted=True, tag="y")
    return out, state, sr.metrics()


def rom_mlstm_prefill(params, x, state, pos0, cfg, rt: Runtime, ctx=None):
    sr = SharedRouting(params["w_router"], x, cfg.rom, rt, rng=None)
    if ctx is not None:
        ctx["rom_routing"] = sr
    h = sr.proj(x, params["e_w_in"], weighted=False, tag="x")
    h = rt.shard.cons(h, "act_batch", "act_seq", "act_inner")
    z = sr.proj(x, params["e_w_gate"], weighted=False, tag="x")
    y, state = xl.mlstm_core_prefill(params, h, z, state, cfg, rt,
                                     chunked=cfg.xlstm.chunk > 0)
    out = sr.proj(y, params["e_w_out"], weighted=True, tag="y")
    return out, state, sr.metrics()
