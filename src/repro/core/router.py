"""Shared router — the heart of RoM (paper Eq. 9).

One router per RoM layer produces a single top-K decision that is *reused*
by every expertized projection in the layer (Conv/Gate/Out for Mamba; the
fused in/out projections for Mamba-2 / GDN / RG-LRU / mLSTM; and optionally
by a following FFN-MoE, Eq. 14-15).  Routing math runs in float32.

Combine weights follow Eq. 9 exactly by default (raw softmax probability,
masked to the top-K set): for top-1 this keeps d(loss)/d(router) alive, the
same choice Switch Transformer makes.  ``normalize_weights=True`` gives the
"normalize over the selected K" variant described in the paper's prose.

Router-gradient estimation: the paper uses SparseMixer [28,29]; we provide a
straight-through multiplier (``grad_est='ste'``) that scales each expert
output by ``p_i / stop_grad(p_i)`` so the router receives a first-order
gradient even when combine weights are normalized — the same role SparseMixer
plays, in its simplest consistent form (documented deviation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


METRIC_KEYS = ("aux_loss", "router_z", "drop_frac", "load_max", "entropy")


def pack_metrics(d: dict) -> jnp.ndarray:
    return jnp.stack([jnp.asarray(d.get(k, 0.0), jnp.float32)
                      for k in METRIC_KEYS])


def unpack_metrics(v) -> dict:
    return {k: v[i] for i, k in enumerate(METRIC_KEYS)}


@dataclasses.dataclass
class Routing:
    """A routing decision over (G groups, g tokens/group, K choices)."""
    num_experts: int
    top_k: int
    weights: jnp.ndarray        # (G, g, K) float32 combine weights
    expert_idx: jnp.ndarray     # (G, g, K) int32
    probs: jnp.ndarray          # (G, g, E) float32 softmax probabilities
    metrics: dict               # python dict of scalar jnp metrics


def router_init(key, d_model, num_experts, dtype="float32"):
    w = jax.random.normal(key, (d_model, num_experts)) * (d_model ** -0.5)
    return w.astype(jnp.dtype(dtype))


def route(w_router, x, *, num_experts, top_k, jitter_eps=0.0,
          aux_loss_weight=0.0, normalize_weights=False, grad_est="plain",
          rng: Optional[jax.Array] = None, train: bool = False) -> Routing:
    """x (G, g, D) tokens -> Routing.

    Jitter (Switch-style multiplicative input noise) is applied only when
    ``train`` and an rng is supplied — it implicitly samples experts [25].
    """
    G, g, D = x.shape
    xr = x.astype(jnp.float32)
    if train and jitter_eps and rng is not None:
        noise = jax.random.uniform(rng, xr.shape, jnp.float32,
                                   1.0 - jitter_eps, 1.0 + jitter_eps)
        xr = xr * noise
    logits = xr @ w_router.astype(jnp.float32)              # (G, g, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)              # (G, g, K)

    if normalize_weights:
        weights = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    else:
        weights = top_p                                     # Eq. 9

    if grad_est == "ste":
        # straight-through: value unchanged, gradient flows through top_p.
        weights = weights * (top_p / jax.lax.stop_gradient(top_p))

    # ---- metrics + (optional) load-balance auxiliary loss (Eq. 16) -------
    onehot = jax.nn.one_hot(top_i, num_experts, dtype=jnp.float32)
    load = onehot.sum((1, 2)) / (g * top_k)                 # (G, E) fraction
    mean_prob = probs.mean(1)                               # (G, E)
    aux = num_experts * jnp.mean(jnp.sum(load * mean_prob, -1))
    lse = jax.nn.logsumexp(logits, axis=-1)
    router_z = jnp.mean(lse ** 2)
    entropy = -jnp.mean(jnp.sum(probs * jnp.log(probs + 1e-9), -1))
    metrics = {
        "aux_loss": aux_loss_weight * aux,
        "router_z": router_z,
        "load_max": jnp.max(load.mean(0)),
        "entropy": entropy,
    }
    return Routing(num_experts=num_experts, top_k=top_k, weights=weights,
                   expert_idx=top_i, probs=probs, metrics=metrics)
