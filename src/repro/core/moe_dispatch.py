"""MoE dispatch engines — MegaBlocks rethought for TPU + GSPMD.

The paper trains *without* expert parallelism (experts replicated, FSDP
outside), using MegaBlocks' grouped GEMM for dropless compute.  The TPU-native
formulation used here:

``capacity`` (default, pjit/GSPMD path, scatter-free)
    Tokens are viewed as (G groups, g tokens); the group dim is laid out so it
    shards over the DP mesh axes, keeping *all* dispatch compute local to a
    device (zero MoE collectives — exactly the paper's no-EP design).  Within
    a group, assignments are sorted by expert with a single fused integer key
    (stable), expert run offsets come from ``searchsorted``, and the capacity
    buffer (E, C, ·) is built by *gathers only* — no scatters, which GSPMD
    partitions poorly.  The inverse permutation (another argsort) drives the
    combine gather.  Assignments beyond capacity are dropped (cf=2 default
    ≈ never in practice; drop fraction is a tracked metric).

``dense``
    Every expert computes every token; mask+sum.  O(E×) FLOPs — the oracle
    for tests and the honest baseline for tiny models.

``grouped``
    Same sort as ``capacity`` but the expert matmul runs the Pallas ragged
    GEMM (kernels/grouped_matmul.py), skipping all-padding tiles — the
    MegaBlocks dropless-sparsity saving on TPU.  Validated in interpret mode.

``ragged``
    True dropless via ``jax.lax.ragged_dot`` on sorted tokens (G=1 only);
    reference path for single-host training examples.

Expert parallelism (beyond the paper — needed for the assigned 400B-class
MoE archs) lives in ``ep_shard_map`` in ``core/rom_ffn.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.router import Routing


def capacity_for(g: int, top_k: int, num_experts: int, cf: float,
                 multiple: int = 8) -> int:
    c = int(-(-g * top_k * cf // num_experts))
    c = -(-c // multiple) * multiple
    return max(multiple, min(c, g * top_k))


@dataclasses.dataclass
class Dispatch:
    """Sorted-assignment dispatch plan shared by every projection of a layer.

    Building this once and reusing it for Conv/Gate/Out is where the shared
    router pays off computationally: one sort, one inverse, one set of
    offsets for three expert projections.
    """
    routing: Routing
    capacity: int
    token_for_slot: jnp.ndarray   # (G, E*C) int32  token index feeding a slot
    asn_for_slot: jnp.ndarray     # (G, E*C) int32  assignment index per slot
    slot_valid: jnp.ndarray       # (G, E*C) bool   slot holds a live token
    slot_for_asn: jnp.ndarray     # (G, g*K) int32  slot of each assignment
    asn_valid: jnp.ndarray        # (G, g*K) bool   assignment not dropped
    group_sizes: jnp.ndarray      # (G, E) int32

    @property
    def drop_frac(self):
        return 1.0 - jnp.mean(self.asn_valid.astype(jnp.float32))


def make_dispatch(routing: Routing, capacity_factor: float,
                  capacity_multiple: int = 8) -> Dispatch:
    G, g, K = routing.expert_idx.shape
    E = routing.num_experts
    C = capacity_for(g, K, E, capacity_factor, capacity_multiple)
    a = routing.expert_idx.reshape(G, g * K).astype(jnp.int32)  # assignments
    n = g * K
    # stable sort by expert via fused key (expert-major, token-order minor)
    key = a * n + jnp.arange(n, dtype=jnp.int32)[None, :]
    sort_idx = jnp.argsort(key, axis=1).astype(jnp.int32)       # (G, n)
    a_sorted = jnp.take_along_axis(a, sort_idx, axis=1)
    offsets = jax.vmap(
        lambda s: jnp.searchsorted(s, jnp.arange(E, dtype=jnp.int32),
                                   side="left"))(a_sorted).astype(jnp.int32)
    ends = jax.vmap(
        lambda s: jnp.searchsorted(s, jnp.arange(E, dtype=jnp.int32),
                                   side="right"))(a_sorted).astype(jnp.int32)
    group_sizes = ends - offsets                                 # (G, E)

    # slot (e, c) <- sorted position offsets[e] + c   (gather, no scatter)
    c_idx = jnp.arange(C, dtype=jnp.int32)
    src = offsets[:, :, None] + c_idx[None, None, :]             # (G, E, C)
    slot_valid = c_idx[None, None, :] < group_sizes[:, :, None]
    src = jnp.minimum(src, n - 1).reshape(G, E * C)
    asn_for_slot = jnp.take_along_axis(sort_idx, src, axis=1)    # (G, E*C)
    token_for_slot = asn_for_slot // K

    # assignment j -> its slot (for combine)
    inv_sort = jnp.argsort(sort_idx, axis=1).astype(jnp.int32)   # (G, n)
    rank = inv_sort - jnp.take_along_axis(offsets, a, axis=1)    # (G, n)
    asn_valid = rank < C
    slot_for_asn = a * C + jnp.minimum(rank, C - 1)

    return Dispatch(routing=routing, capacity=C,
                    token_for_slot=token_for_slot,
                    asn_for_slot=asn_for_slot,
                    slot_valid=slot_valid.reshape(G, E * C),
                    slot_for_asn=slot_for_asn, asn_valid=asn_valid,
                    group_sizes=group_sizes)


def dispatch_tokens(dsp: Dispatch, x: jnp.ndarray) -> jnp.ndarray:
    """x (G, g, D) -> capacity buffer (G, E, C, D); padding slots are zero."""
    G, g, D = x.shape
    E, C = dsp.routing.num_experts, dsp.capacity
    buf = jnp.take_along_axis(x, dsp.token_for_slot[:, :, None], axis=1)
    buf = jnp.where(dsp.slot_valid[:, :, None], buf, 0)
    return buf.reshape(G, E, C, D)


def dispatch_assignments(dsp: Dispatch, v: jnp.ndarray) -> jnp.ndarray:
    """Per-*assignment* payload v (G, g*K, ...) -> (G, E, C, ...).

    Unlike ``dispatch_tokens`` (which maps slots to tokens), this keeps the
    (token, k)-assignment identity — needed to ship per-assignment metadata
    (e.g. target-expert ids) through an all_to_all in the EP path.
    """
    G, n = v.shape[:2]
    E, C = dsp.routing.num_experts, dsp.capacity
    idx = dsp.asn_for_slot.reshape(G, E * C, *([1] * (v.ndim - 2)))
    buf = jnp.take_along_axis(v, jnp.broadcast_to(
        idx, (G, E * C, *v.shape[2:])), axis=1)
    mask = dsp.slot_valid.reshape(G, E * C, *([1] * (v.ndim - 2)))
    buf = jnp.where(mask, buf, 0)
    return buf.reshape(G, E, C, *v.shape[2:])


def combine_tokens(dsp: Dispatch, y_buf: jnp.ndarray,
                   weighted: bool) -> jnp.ndarray:
    """y_buf (G, E, C, F) -> (G, g, F).

    ``weighted=False`` sums selected experts' outputs (Conv/Gate projections,
    Eq. 10-11); ``weighted=True`` applies the router combine weights
    (Out projection, Eq. 12).
    """
    G, E, C, F = y_buf.shape
    K = dsp.routing.top_k
    g = dsp.slot_for_asn.shape[1] // K
    yf = y_buf.reshape(G, E * C, F)
    y = jnp.take_along_axis(yf, dsp.slot_for_asn[:, :, None], axis=1)
    scale = dsp.asn_valid.astype(y.dtype)
    if weighted:
        scale = scale * dsp.routing.weights.reshape(G, g * K).astype(y.dtype)
    y = y * scale[:, :, None]
    return y.reshape(G, g, K, F).sum(axis=2)


# ---------------------------------------------------------------------------
# expert matmuls
# ---------------------------------------------------------------------------

def expert_partition(shard, num_experts: int):
    """Mesh axis (name or tuple) the live rules shard the expert dim over,
    or None when experts are replicated.

    Resolved from the ``experts`` logical axis — the axis the dispatched
    ``e_w_*`` weights carry — against the shard context's rules: the
    training default replicates (the paper's no-EP design), while a
    serving :class:`~repro.distributed.plan.ParallelPlan` points both
    ``experts`` and ``experts_ep`` at its expert partition.  The usual
    divisibility check applies (an expert count that doesn't divide the
    axis replicates).
    """
    if shard is None or getattr(shard, "mesh", None) is None:
        return None
    spec = shard.spec((num_experts,), ("experts",))
    return spec[0] if len(spec) else None


def _expert_sharded_grouped(buf, w, group_sizes, mesh, axis, group_axis):
    """Grouped GEMM with the expert dim sharded over ``axis``: shard_map
    routes each expert's capacity rows to its owning shard and runs the
    grouped-matmul kernel on the local expert slice — compute and weights
    both stay shard-local; only the combine gather (outside this function)
    crosses shards.  ``group_axis`` keeps the dispatch-group (slot/batch)
    dim on its own partition too, so data shards never recompute each
    other's slots.  Inside shard_map the kernel runs via ``impl=None``
    (Pallas on TPU, the jnp oracle elsewhere — Pallas interpret mode is
    not shard_map-safe)."""
    from repro.kernels import ops
    G, E, C, D = buf.shape
    F = w.shape[-1]

    def local(b, wl, gs):
        g, e = b.shape[0], b.shape[1]
        y = ops.grouped_matmul(b.reshape(g * e, C, D), wl,
                               gs.reshape(g * e))
        return y.reshape(g, e, C, F)

    sm = getattr(jax, "shard_map", None)
    if sm is None:                       # pinned-jax fallback location
        from jax.experimental.shard_map import shard_map as sm
    return sm(local, mesh=mesh,
              in_specs=(P(group_axis, axis, None, None),
                        P(axis, None, None), P(group_axis, axis)),
              out_specs=P(group_axis, axis, None, None))(buf, w,
                                                         group_sizes)


def expert_matmul(buf: jnp.ndarray, w: jnp.ndarray, group_sizes=None,
                  impl: str = "capacity", *, shard=None) -> jnp.ndarray:
    """buf (G, E, C, D) @ w (E, D, F) -> (G, E, C, F).

    ``shard`` (a :class:`~repro.distributed.sharding.ShardCtx`, e.g. from
    ``plan.shard_ctx()``) enables the expert partition: when its rules map
    the ``experts`` logical axis onto a live mesh axis (see
    :func:`expert_partition`), the grouped impl shard_maps the kernel over
    the expert shards and the einsum impls constrain the buffers so GSPMD
    keeps expert compute shard-local.
    """
    if impl == "grouped":
        from repro.kernels import ops
        G, E, C, D = buf.shape
        ax = expert_partition(shard, E)
        if ax is not None:
            # keep the group (slot/batch) dim on its own partition, too:
            # resolve act_batch for G with the usual divisibility check
            gspec = shard.spec((G, 1, 1, 1), ("act_batch",) + (None,) * 3)
            gax = gspec[0] if len(gspec) else None
            return _expert_sharded_grouped(buf, w, group_sizes,
                                           shard.mesh, ax, gax)
        # w rides unexpanded: the kernel maps token tiles to expert weight
        # blocks modulo E, so no G-fold weight broadcast is materialized
        y = ops.grouped_matmul(
            buf.reshape(G * E, C, D), w, group_sizes.reshape(G * E),
            impl="interpret" if jax.default_backend() != "tpu" else None)
        return y.reshape(G, E, C, -1)
    cd = buf.dtype
    y = jnp.einsum("gecd,edf->gecf", buf, w.astype(cd),
                   preferred_element_type=jnp.float32).astype(cd)
    if shard is not None:
        y = shard.cons(y, "act_batch", "act_experts", None, None)
    return y


# ---------------------------------------------------------------------------
# a MoE linear layer under a shared routing decision
# ---------------------------------------------------------------------------

class SharedMoELinear:
    """Applies expertized linear projections that all reuse one Dispatch.

    ``__call__(x_or_none, w, weighted)``: if ``x`` is the same tensor already
    dispatched (``reuse=True`` path) the cached capacity buffer is reused —
    Conv Proj and Gate Proj both project the layer input X, so RoM pays for a
    single dispatch gather serving both (see DESIGN.md §Perf).

    ``shard`` (a ShardCtx, e.g. ``plan.shard_ctx()``) routes tokens to
    expert shards: the capacity buffer is constrained over the plan's
    expert partition (``act_experts``) so the expert matmul computes on
    the shard owning each expert's weights.
    """

    def __init__(self, dsp: Dispatch, impl: str = "capacity", shard=None):
        self.dsp = dsp
        self.impl = impl
        self.shard = shard
        self._cache = {}

    def dispatch(self, x: jnp.ndarray, tag: str = "x") -> jnp.ndarray:
        if tag not in self._cache:
            buf = dispatch_tokens(self.dsp, x)
            if self.shard is not None:
                buf = self.shard.cons(buf, "act_batch", "act_experts",
                                      None, None)
            self._cache[tag] = buf
        return self._cache[tag]

    def __call__(self, x: jnp.ndarray, w: jnp.ndarray, *, weighted: bool,
                 tag: str = "x") -> jnp.ndarray:
        buf = self.dispatch(x, tag)
        y = expert_matmul(buf, w, self.dsp.group_sizes, self.impl,
                          shard=self.shard)
        return combine_tokens(self.dsp, y, weighted)


def dense_moe_linear(routing: Routing, x: jnp.ndarray, w: jnp.ndarray, *,
                     weighted: bool) -> jnp.ndarray:
    """O(E×) oracle: every expert computes every token. x (G,g,D), w (E,D,F)."""
    G, g, D = x.shape
    E, K = routing.num_experts, routing.top_k
    y_all = jnp.einsum("gtd,edf->gtef", x, w.astype(x.dtype),
                       preferred_element_type=jnp.float32)     # (G,g,E,F)
    sel = jax.nn.one_hot(routing.expert_idx, E, dtype=jnp.float32)  # (G,g,K,E)
    if weighted:
        sel = sel * routing.weights[..., None]
    mix = sel.sum(axis=2)                                      # (G,g,E)
    return jnp.einsum("gtef,gte->gtf", y_all, mix).astype(x.dtype)


def ragged_moe_linear(dsp: Dispatch, x: jnp.ndarray, w: jnp.ndarray, *,
                      weighted: bool) -> jnp.ndarray:
    """True dropless via jax.lax.ragged_dot (G=1 only). x (1,g,D), w (E,D,F)."""
    G, g, D = x.shape
    assert G == 1, "ragged impl supports a single dispatch group"
    K = dsp.routing.top_k
    n = g * K
    a = dsp.routing.expert_idx.reshape(n)
    key = a * n + jnp.arange(n, dtype=jnp.int32)
    sort_idx = jnp.argsort(key)
    tok = jnp.take(x[0], sort_idx // K, axis=0)               # (n, D) sorted
    sizes = dsp.group_sizes[0]
    y_sorted = jax.lax.ragged_dot(tok, w.astype(tok.dtype), sizes)
    y = jnp.take(y_sorted, jnp.argsort(sort_idx), axis=0)     # back to asn order
    scale = jnp.ones((n,), y.dtype)
    if weighted:
        scale = dsp.routing.weights.reshape(n).astype(y.dtype)
    y = y * scale[:, None]
    return y.reshape(1, g, K, -1).sum(axis=2)

def select_per_set(ys, sel: jnp.ndarray) -> jnp.ndarray:
    """Per-slot selection across per-expert-set projection outputs.

    ``ys`` is a sequence of identically-shaped ``(B, S, F)`` arrays — one
    per bound expert set, each produced by the *unmodified* single-set
    projection path (serve/expert_library.py binds expert leaves as per-set
    tuples) — and ``sel`` is ``(B,)`` int32 mapping each batch row (decode
    slot) to its bound set.  Returns ``(B, S, F)`` where row ``b`` is taken
    verbatim from ``ys[sel[b]]``.

    Written as a ``where``-chain over sets rather than ``stack`` + gather:
    rows of ``ys[i]`` pass through *bitwise* unchanged (the per-tenant
    identity guarantee rides on this), and with a single bound set the
    selection is the identity — the non-library trace.
    """
    ys = list(ys)
    if len(ys) == 1:
        return ys[0]
    mask_shape = (-1,) + (1,) * (ys[0].ndim - 1)
    out = ys[0]
    for i in range(1, len(ys)):
        out = jnp.where((sel == i).reshape(mask_shape), ys[i], out)
    return out
