"""FFN mixture-of-experts (SwiGLU experts) + the paper's hybrid coupling.

Three dispatch implementations:

``dense`` / ``capacity`` / ``grouped`` / ``ragged``
    Experts replicated (the paper's no-EP setting), dispatch via
    core/moe_dispatch (one sort reused for up/gate projections).

``ep``
    Explicit expert parallelism via ``jax.shard_map`` + two ``all_to_all``
    hops over the ``data`` mesh axis, with tensor parallelism (``model``
    axis psum) inside each expert — a GShard-style capacity-bounded path.
    This is a *beyond-paper* extension required by the assigned 400B-class
    MoE architectures (llama4-maverick), where replicating 128 experts per
    device cannot fit.

Hybrid RoM + FFN-MoE (paper Eq. 14-15): when ``cfg.moe.share_rom_router`` is
set and the block context carries a RoM routing decision, the FFN experts
reuse that decision (indicator *and* weights) instead of learning their own
router — "shared routing decisions strategy from the Gate projection layer
in the previous RoM layer".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import moe_dispatch as md
from repro.core import router as rtr
from repro.core.rom import SharedRouting, _expert_init, _fold_rng, num_groups
from repro.nn.layers import Runtime, dense, dense_init, silu
from repro.nn.mlp import mlp_apply, mlp_init


def moe_ffn_init(key, cfg):
    moe = cfg.moe
    if moe.share_rom_router and cfg.rom is not None:
        assert moe.num_experts == cfg.rom.num_experts, \
            "Eq. 14-15 shared routing requires matching expert counts"
    E, pd, d = moe.num_experts, cfg.param_dtype, cfg.d_model
    ff = moe.d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    prefix = "ep_" if moe.impl == "ep" else "e_"
    p = {
        prefix + "w_up": _expert_init(ks[0], E, d, ff, pd),
        prefix + "w_gate_ffn": _expert_init(ks[1], E, d, ff, pd),
        prefix + "w_down": _expert_init(ks[2], E, ff, d, pd),
    }
    if not moe.share_rom_router:
        p["w_router"] = rtr.router_init(ks[3], d, E)
    if moe.num_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg, d_ff=ff * moe.num_shared_experts)
    return p


def _swiglu_buffers(lin: md.SharedMoELinear, xt, wu, wg, wd):
    """Expert SwiGLU on dispatched buffers; up/gate reuse the same buffer."""
    buf = lin.dispatch(xt, "x")
    sh = lin.shard
    up = md.expert_matmul(buf, wu, lin.dsp.group_sizes, lin.impl, shard=sh)
    gate = md.expert_matmul(buf, wg, lin.dsp.group_sizes, lin.impl, shard=sh)
    hidden = up * silu(gate)
    y = md.expert_matmul(hidden, wd, lin.dsp.group_sizes, lin.impl, shard=sh)
    return md.combine_tokens(lin.dsp, y, weighted=True)


def _ffn_routed(routing, x, params, moe, rt: Runtime):
    """The expert-FFN body for one routing decision.  Returns
    ``(y (B,S,D), drop_frac or None)``."""
    B, S, D = x.shape
    G = routing.expert_idx.shape[0]
    xt = x.reshape(G, B * S // G, D)
    wu = params["e_w_up"]
    wg = params["e_w_gate_ffn"]
    wd = params["e_w_down"]
    if moe.impl == "dense":
        # dense oracle computes hidden per expert; recompute exactly:
        y_all = jnp.einsum("gtd,edf->gtef", xt, wu.astype(xt.dtype))
        g_all = jnp.einsum("gtd,edf->gtef", xt, wg.astype(xt.dtype))
        h_all = y_all * silu(g_all)
        o_all = jnp.einsum("gtef,efd->gted", h_all, wd.astype(xt.dtype))
        sel = jax.nn.one_hot(routing.expert_idx, moe.num_experts,
                             dtype=jnp.float32)
        mix = (sel * routing.weights[..., None]).sum(2)
        y = jnp.einsum("gted,gte->gtd", o_all.astype(jnp.float32),
                       mix).astype(x.dtype)
        return y.reshape(B, S, D), None
    dsp = md.make_dispatch(routing, moe.capacity_factor)
    lin = md.SharedMoELinear(dsp, impl=moe.impl, shard=rt.shard)
    y = _swiglu_buffers(lin, xt, wu, wg, wd)
    return y.reshape(B, S, D), dsp.drop_frac


def moe_ffn_apply(params, x, cfg, rt: Runtime, ctx=None):
    moe = cfg.moe
    if moe.impl == "ep":
        return moe_ffn_ep_apply(params, x, cfg, rt, ctx)
    B, S, D = x.shape

    if moe.share_rom_router and ctx is not None and "rom_routing" in ctx:
        sr: SharedRouting = ctx["rom_routing"]        # Eq. 14-15
        if sr.subs is not None:
            # multi-tenant serving: the shared decision is per expert set
            # (the rom block's router weights are tenant-swapped), so the
            # FFN — whose own experts are NOT swapped — fans out once per
            # bound set and selects per slot, mirroring SharedRouting.proj
            ys = [_ffn_routed(sub.routing, x, params, moe, rt)[0]
                  for sub in sr.subs]
            out = md.select_per_set(ys, sr.sel)
            if moe.num_shared_experts:
                shared, _ = mlp_apply(params["shared"], x, cfg, rt)
                out = out + shared
            return out, {}
        routing = sr.routing
        metrics = {}
    else:
        G = num_groups(B, rt)
        xt = x.reshape(G, B * S // G, D)
        routing = rtr.route(
            params["w_router"], xt, num_experts=moe.num_experts,
            top_k=moe.top_k, jitter_eps=moe.jitter_eps,
            aux_loss_weight=moe.aux_loss_weight, rng=_fold_rng(rt),
            train=rt.train)
        metrics = dict(routing.metrics)

    out, drop = _ffn_routed(routing, x, params, moe, rt)
    if drop is not None:
        metrics["drop_frac"] = drop
    if moe.num_shared_experts:
        shared, _ = mlp_apply(params["shared"], x, cfg, rt)
        out = out + shared
    return out, metrics


# ---------------------------------------------------------------------------
# Expert parallelism: shard_map + all_to_all over 'data', TP psum over 'model'
# ---------------------------------------------------------------------------

def _ep_local(x_l, wr, wu, wg, wd, *, cfg, ep_axis, reduce_axes):
    """Per-device body. x_l (B_l, S, D); wu (E_l, D, F_l)."""
    moe = cfg.moe
    B_l, S, D = x_l.shape
    T = B_l * S
    E = moe.num_experts
    ep = jax.lax.axis_size(ep_axis)
    E_l = E // ep
    xt = x_l.reshape(1, T, D)

    routing = rtr.route(wr, xt, num_experts=E, top_k=moe.top_k,
                        jitter_eps=0.0, aux_loss_weight=moe.aux_loss_weight,
                        rng=None, train=False)
    dest = routing.expert_idx // E_l              # (1, T, K) target device
    local_e = routing.expert_idx % E_l

    # hop 1: group assignments by destination device (capacity-bounded)
    r1 = rtr.Routing(num_experts=ep, top_k=moe.top_k,
                     weights=routing.weights, expert_idx=dest,
                     probs=routing.probs, metrics={})
    dsp1 = md.make_dispatch(r1, moe.capacity_factor)
    send_x = md.dispatch_tokens(dsp1, xt)[0]                       # (ep,C,D)
    send_e = md.dispatch_assignments(
        dsp1, local_e.reshape(1, -1, 1).astype(jnp.int32))[0, ..., 0]
    send_valid = md.dispatch_assignments(
        dsp1, jnp.ones((1, dest.size, 1), jnp.int32))[0, ..., 0]
    recv_x = jax.lax.all_to_all(send_x, ep_axis, 0, 0, tiled=False)
    recv_e = jax.lax.all_to_all(send_e, ep_axis, 0, 0, tiled=False)
    recv_valid = jax.lax.all_to_all(send_valid, ep_axis, 0, 0, tiled=False)

    # hop 2: local dispatch among my E_l experts; invalid slots -> id E_l
    C1 = recv_x.shape[1]
    T2 = ep * C1
    e2 = jnp.where(recv_valid.reshape(T2) > 0, recv_e.reshape(T2), E_l)
    r2 = rtr.Routing(num_experts=E_l, top_k=1,
                     weights=jnp.ones((1, T2, 1), jnp.float32),
                     expert_idx=e2.reshape(1, T2, 1),
                     probs=jnp.ones((1, T2, E_l), jnp.float32) / E_l,
                     metrics={})
    dsp2 = md.make_dispatch(r2, moe.capacity_factor)
    buf = md.dispatch_tokens(dsp2, recv_x.reshape(1, T2, D))       # (1,El,C2,D)
    up = md.expert_matmul(buf, wu)
    gate = md.expert_matmul(buf, wg)
    y = md.expert_matmul(up * silu(gate), wd)                      # (1,El,C2,D)
    if "model" in reduce_axes:
        y = jax.lax.psum(y, "model")          # contract sharded F dim
    back = md.combine_tokens(dsp2, y, weighted=False)              # (1,T2,D)

    # hop 1 return trip + weighted combine with the *original* weights
    ret = jax.lax.all_to_all(back.reshape(ep, C1, D), ep_axis, 0, 0)
    out = md.combine_tokens(dsp1, ret[None], weighted=True)        # (1,T,D)
    drop = 1.0 - jnp.mean(dsp1.asn_valid.astype(jnp.float32))
    metrics = jnp.stack([routing.metrics["aux_loss"], drop])
    for ax in reduce_axes:
        metrics = jax.lax.pmean(metrics, ax)
    return out.reshape(B_l, S, D), metrics


def moe_ffn_ep_apply(params, x, cfg, rt: Runtime, ctx=None):
    import dataclasses
    import functools
    mesh = rt.shard.mesh
    moe = cfg.moe
    if (mesh is None or "data" not in mesh.shape
            or moe.num_experts % mesh.shape["data"] != 0):
        # single-device / indivisible fallback: capacity path, aliased names
        alias = {k.replace("ep_w", "e_w"): v for k, v in params.items()}
        cfg2 = cfg.replace(moe=dataclasses.replace(cfg.moe, impl="capacity"))
        return moe_ffn_apply(alias, x, cfg2, rt, ctx)

    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    has_tp = "model" in mesh.shape
    reduce_axes = dp_axes + (("model",) if has_tp else ())
    in_specs = (
        P(dp_axes, None, None),                        # x
        P(),                                           # router
        P("data", None, "model" if has_tp else None),  # wu
        P("data", None, "model" if has_tp else None),  # wg
        P("data", "model" if has_tp else None, None),  # wd
    )
    out_specs = (P(dp_axes, None, None), P())

    body = functools.partial(_ep_local, cfg=cfg, ep_axis="data",
                             reduce_axes=reduce_axes)
    out, metrics = jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)(
        x, params["w_router"], params["ep_w_up"], params["ep_w_gate_ffn"],
        params["ep_w_down"])
    m = {"aux_loss": metrics[0], "drop_frac": metrics[1]}
    if moe.num_shared_experts:
        shared, _ = mlp_apply(params["shared"], x, cfg, rt)
        out = out + shared
    return out, m
