"""Naive MoE-Mamba baseline [37]: *independent* routers per projection.

This is the strategy the paper shows to degrade quality (Fig. 2, Table 4):
each targeted projection (Conv / Gate / Out) gets its own router and its own
dispatch, so routing decisions are uncoordinated across the functionally
interdependent projections, and all outputs are combined with each router's
own weights.  Implemented with the same dispatch engine as RoM so that the
comparison isolates exactly the paper's variable: shared vs independent
routing.
"""
from __future__ import annotations

import jax

from repro.core import router as rtr
from repro.core.rom import SharedRouting, _expert_init, _fold_rng
from repro.nn import ssm
from repro.nn.layers import Runtime, dense, dense_init, silu
from repro.serve.state import batch_spec


def moemamba_init(key, cfg):
    rom = cfg.rom
    de, dt_rank, n = ssm.mamba_dims(cfg)
    ks = jax.random.split(key, 8)
    p = ssm.mamba_init_shared(ks[0], cfg)
    E, pd = rom.num_experts, cfg.param_dtype
    t = rom.targets
    if "conv" in t:
        p["conv_router"] = {
            "w_router": rtr.router_init(ks[1], cfg.d_model, E)}
        p["e_w_in"] = _expert_init(ks[2], E, cfg.d_model, de, pd)
    else:
        p["w_in"] = dense_init(ks[2], cfg.d_model, de, dtype=pd)
    if "gate" in t:
        p["gate_router"] = {
            "w_router": rtr.router_init(ks[3], cfg.d_model, E)}
        p["e_w_gate"] = _expert_init(ks[4], E, cfg.d_model, de, pd)
    else:
        p["w_gate"] = dense_init(ks[4], cfg.d_model, de, dtype=pd)
    if "out" in t:
        p["out_router"] = {
            "w_router": rtr.router_init(ks[5], cfg.d_model, E)}
        p["e_w_out"] = _expert_init(ks[6], E, de, cfg.d_model, pd)
    else:
        p["w_out"] = dense_init(ks[6], de, cfg.d_model, dtype=pd)
    return p


def _sum_metrics(ms):
    out = {}
    for m in ms:
        for k, v in m.items():
            out[k] = out.get(k, 0.0) + v
    n = max(len(ms), 1)
    return {k: v / n for k, v in out.items()}


def moemamba_apply(params, x, cfg, rt: Runtime, ctx=None):
    rom = cfg.rom
    t = rom.targets
    rng = _fold_rng(rt)
    rngs = jax.random.split(rng, 3) if rng is not None else (None,) * 3
    metrics = []

    if "conv" in t:
        sr_c = SharedRouting(params["conv_router"]["w_router"], x, rom, rt,
                             rng=rngs[0])
        h = sr_c.proj(x, params["e_w_in"], weighted=False, tag="x")
        metrics.append(sr_c.metrics())
    else:
        h = dense(x, params["w_in"])
    h = rt.shard.cons(h, "act_batch", "act_seq", "act_inner")
    y = ssm.mamba_core(params, h, cfg, rt)
    if "gate" in t:
        sr_g = SharedRouting(params["gate_router"]["w_router"], x, rom, rt,
                             rng=rngs[1])
        g = silu(sr_g.proj(x, params["e_w_gate"], weighted=False, tag="x"))
        metrics.append(sr_g.metrics())
    else:
        g = silu(dense(x, params["w_gate"]))
    z = y * g
    if "out" in t:
        sr_o = SharedRouting(params["out_router"]["w_router"], x, rom, rt,
                             rng=rngs[2])
        out = sr_o.proj(z, params["e_w_out"], weighted=True, tag="z")
        metrics.append(sr_o.metrics())
    else:
        out = dense(z, params["w_out"])
    return out, _sum_metrics(metrics)


def moemamba_init_state(cfg, batch, dtype):
    return ssm.mamba_init_state(cfg, batch, dtype)


moemamba_state_spec = batch_spec(moemamba_init_state)


def moemamba_prefill(params, x, state, pos0, cfg, rt: Runtime, ctx=None):
    """Parallel prefill mirroring ``moemamba_step`` routing (no jitter)."""
    rom = cfg.rom
    t = rom.targets
    metrics = []
    if "conv" in t:
        sr_c = SharedRouting(params["conv_router"]["w_router"], x, rom, rt)
        h = sr_c.proj(x, params["e_w_in"], weighted=False, tag="x")
        metrics.append(sr_c.metrics())
    else:
        h = dense(x, params["w_in"])
    h = rt.shard.cons(h, "act_batch", "act_seq", "act_inner")
    y, state = ssm.mamba_core_prefill(params, h, state, cfg, rt)
    if "gate" in t:
        sr_g = SharedRouting(params["gate_router"]["w_router"], x, rom, rt)
        g = silu(sr_g.proj(x, params["e_w_gate"], weighted=False, tag="x"))
        metrics.append(sr_g.metrics())
    else:
        g = silu(dense(x, params["w_gate"]))
    z = y * g
    if "out" in t:
        sr_o = SharedRouting(params["out_router"]["w_router"], x, rom, rt)
        out = sr_o.proj(z, params["e_w_out"], weighted=True, tag="z")
        metrics.append(sr_o.metrics())
    else:
        out = dense(z, params["w_out"])
    return out, state, _sum_metrics(metrics)


def moemamba_step(params, x_t, state, pos, cfg, rt: Runtime, ctx=None):
    rom = cfg.rom
    t = rom.targets
    metrics = []
    if "conv" in t:
        sr_c = SharedRouting(params["conv_router"]["w_router"], x_t, rom, rt)
        h = sr_c.proj(x_t, params["e_w_in"], weighted=False, tag="x")[:, 0]
        metrics.append(sr_c.metrics())
    else:
        h = dense(x_t[:, 0], params["w_in"])
    y, state = ssm.mamba_core_step(params, h, state, cfg, rt)
    if "gate" in t:
        sr_g = SharedRouting(params["gate_router"]["w_router"], x_t, rom, rt)
        g = silu(sr_g.proj(x_t, params["e_w_gate"], weighted=False,
                           tag="x")[:, 0])
        metrics.append(sr_g.metrics())
    else:
        g = silu(dense(x_t[:, 0], params["w_gate"]))
    z = (y * g)[:, None]
    if "out" in t:
        sr_o = SharedRouting(params["out_router"]["w_router"], x_t, rom, rt)
        out = sr_o.proj(z, params["e_w_out"], weighted=True, tag="z")
        metrics.append(sr_o.metrics())
    else:
        out = dense(z, params["w_out"])
    return out, state, _sum_metrics(metrics)
