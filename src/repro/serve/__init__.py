"""Serving subsystem: parallel prefill + continuous batching.

``ServeEngine`` holds a fixed number of decode *slots* and drives one jitted
multi-slot decode step with per-slot positions; prompts are prefilled with
the parallel training-style forward (``models/lm.prefill``) in power-of-two
chunks, and the extracted state is inserted into the request's slot.  Slots
are re-admitted from a FIFO queue as requests finish (EOS / length caps).
"""
from repro.serve.engine import Request, RequestResult, ServeEngine
from repro.serve.sampling import SamplingParams, sample
from repro.serve.scheduler import FIFOScheduler

__all__ = ["Request", "RequestResult", "ServeEngine", "SamplingParams",
           "sample", "FIFOScheduler"]
