"""Serving subsystem: parallel prefill, stall-free continuous batching, and
self-speculative decoding.  See ``docs/serving.md`` for the full reference.

``ServeEngine`` holds a fixed number of decode *slots* over a generic
:class:`~repro.serve.state.StateStore` and drives one jitted step per tick.
Admission is stall-free by default: pending prompts prefill in power-of-two
chunks *interleaved* with decode — one **mixed step** advances every active
decode slot and one prefill chunk in the same dispatch — and multiple queued
requests share batched prefill lanes.  A ``sequential`` admission mode keeps
the PR-1 behaviour (full prefill per request, decode stalled) for A/B runs.
``ServeEngine(..., speculative=K)`` drafts K tokens per round with a
layer-skip reduced model and verifies them in one full-model pass
(``repro.serve.speculative``), emitting up to K+1 tokens per slot per
dispatch.  ``ServeEngine(..., prefix_cache=PrefixCache(...))`` skips
prefill for shared prompt prefixes: a radix tree of chunk-boundary state
snapshots (``repro.serve.cache``) turns prefill cost from O(prompt) into
O(uncached suffix), with byte-budgeted LRU eviction.

Telemetry (``repro.serve.telemetry``, re-exported as ``repro.obs``)
unifies observability: a :class:`~repro.serve.telemetry.MetricsRegistry`
of typed instruments shared across engine / cache / library / scheduler
(legacy ``stats`` dicts remain as derived views), a per-request span
:class:`~repro.serve.telemetry.Tracer` (queued → admitted → prefill
chunks → decode/spec rounds → finish), and exporters: JSON
snapshot/delta, Prometheus text, Chrome ``trace_event`` (Perfetto), and
an opt-in ``jax.profiler`` annotation hook.  See
``docs/observability.md``.

Device placement is resolved **once** by a
:class:`~repro.distributed.plan.ParallelPlan` passed as
``ServeEngine(cfg, params, plan=...)`` (default: single device): it shards
decode slots over the plan's data axis, expert weights over its expert
partition, and is threaded through the StateStore, every jitted step and
the prefix cache — no serving module takes a raw mesh.  Scalar knobs are
grouped on :class:`~repro.serve.engine.EngineConfig`.

``engine`` and ``speculative`` are imported lazily: mixer modules declare
their ``StateSpec`` via ``repro.serve.state``, so an eager import here would
cycle through ``models/lm`` back into the partially-initialized mixer
module.
"""
from repro.serve.cache import PrefixCache
from repro.serve.sampling import (SamplingParams, filtered_logits, sample,
                                  spec_accept)
from repro.serve.scheduler import (CachedSuffixFirst, FIFOScheduler,
                                   ShortestPromptFirst)
from repro.serve.state import (StateSpec, StateStore, adopt_slots,
                               append_only_mask, gather_slots, init_slots,
                               insert_slots, restore_slots, select_window,
                               slot_axes, snapshot_slots, state_nbytes)
from repro.serve.telemetry import (Counter, Gauge, Histogram,
                                   MetricsRegistry, Span, Telemetry,
                                   Tracer, hist_mean, hist_quantile,
                                   log_buckets)

_ENGINE_NAMES = ("EngineConfig", "Request", "RequestResult", "ServeEngine")
_SPEC_NAMES = ("SpecConfig", "make_spec_fn")
# lazy for the same reason as ``engine``: the library walks models/lm's
# mixer registry to find the expert-swappable blocks
_LIBRARY_NAMES = ("ExpertLibrary",)

__all__ = ["EngineConfig", "ExpertLibrary", "Request", "RequestResult",
           "ServeEngine", "SamplingParams",
           "sample", "spec_accept", "filtered_logits", "FIFOScheduler",
           "ShortestPromptFirst", "CachedSuffixFirst", "PrefixCache",
           "SpecConfig", "make_spec_fn", "StateSpec",
           "StateStore", "adopt_slots", "append_only_mask", "gather_slots",
           "init_slots", "insert_slots", "restore_slots", "select_window",
           "slot_axes", "snapshot_slots", "state_nbytes",
           "Counter", "Gauge", "Histogram", "MetricsRegistry", "Span",
           "Telemetry", "Tracer", "hist_mean", "hist_quantile",
           "log_buckets"]


def __getattr__(name):
    if name in _ENGINE_NAMES:
        from repro.serve import engine
        return getattr(engine, name)
    if name in _SPEC_NAMES:
        from repro.serve import speculative
        return getattr(speculative, name)
    if name in _LIBRARY_NAMES:
        from repro.serve import expert_library
        return getattr(expert_library, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
