"""Request scheduling policies for the serving engine.

The engine asks the scheduler which waiting request(s) to admit whenever
decode slots free up (or, in interleaved admission, whenever it can start a
new batched prefill job).  Ordering is a property of *pop time*, not
enqueue time: every ``pop_next`` decides over everything currently queued,
so requests arriving mid-run compete with older ones instead of being
appended behind a stale ordering.  ``peek_next`` returns the request
``pop_next`` would return without removing it — the engine peeks while
assembling a batched prefill job so it can stop admitting at a group
boundary (prefix-cache admission groups lanes by cached-prefix length)
without perturbing the queue.

FIFO is the default; ``ShortestPromptFirst`` trades fairness for lower mean
TTFT under mixed prompt lengths (shorter prefills first);
``CachedSuffixFirst`` is prefix-cache-aware — it ranks by *uncached suffix*
length, so a long prompt whose prefix is already cached admits before a
short cold one.

Every scheduler reports queue telemetry through a
:class:`~repro.serve.telemetry.MetricsRegistry` once one is bound
(``bind_registry``; the engine binds its own registry at construction
unless the caller bound another first): ``sched_added_total`` /
``sched_popped_total`` counters and the ``sched_queue_depth`` gauge.
Unbound schedulers drive no-op instruments — zero behaviour change.
"""
from __future__ import annotations

import heapq
from collections import deque

from repro.serve.telemetry import MetricsRegistry

_UNBOUND = MetricsRegistry(enabled=False)      # shared no-op instruments


class _SchedulerMetrics:
    """Queue-depth/add/pop instruments, no-op until ``bind_registry``."""

    def __init__(self):
        self._registry = None
        self._wire(_UNBOUND)

    def _wire(self, reg: MetricsRegistry) -> None:
        self._m_added = reg.counter("sched_added_total",
                                    "requests enqueued to the scheduler")
        self._m_popped = reg.counter("sched_popped_total",
                                     "requests popped for admission")
        self._m_depth = reg.gauge("sched_queue_depth",
                                  "requests currently waiting")

    def bind_registry(self, registry: MetricsRegistry) -> None:
        """Adopt ``registry`` for queue metrics.  First binding wins — the
        engine binds its registry at construction, but a caller that bound
        another one beforehand keeps it."""
        if self._registry is not None:
            return
        self._registry = registry
        self._wire(registry)


class FIFOScheduler(_SchedulerMetrics):
    """First-in-first-out admission."""

    def __init__(self):
        super().__init__()
        self._q = deque()

    def add(self, request) -> None:
        self._q.append(request)
        self._m_added.inc()
        self._m_depth.set(len(self._q))

    def peek_next(self):
        return self._q[0] if self._q else None

    def pop_next(self):
        if not self._q:
            return None
        self._m_popped.inc()
        req = self._q.popleft()
        self._m_depth.set(len(self._q))
        return req

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)


class ShortestPromptFirst(_SchedulerMetrics):
    """Admit the waiting request with the shortest prompt (min mean TTFT).

    Backed by a heap keyed on (prompt length, arrival order): a request
    submitted mid-run is ranked against every request still waiting the
    moment the engine next admits — not slotted into an ordering frozen when
    the queue was first built — and equal-length prompts keep FIFO order.
    """

    def __init__(self):
        super().__init__()
        self._h = []
        self._n = 0                     # arrival counter: stable tiebreak

    def add(self, request) -> None:
        heapq.heappush(self._h, (len(request.prompt), self._n, request))
        self._n += 1
        self._m_added.inc()
        self._m_depth.set(len(self._h))

    def peek_next(self):
        return self._h[0][2] if self._h else None

    def pop_next(self):
        if not self._h:
            return None
        self._m_popped.inc()
        req = heapq.heappop(self._h)[2]
        self._m_depth.set(len(self._h))
        return req

    def __len__(self) -> int:
        return len(self._h)

    def __bool__(self) -> bool:
        return bool(self._h)


class CachedSuffixFirst(_SchedulerMetrics):
    """Admit the request with the shortest *uncached* prompt suffix.

    Prefix-cache-aware ShortestPromptFirst: the effective prefill cost of a
    request is ``len(prompt) - cached_prefix_len``, so a long prompt whose
    prefix is already in the :class:`~repro.serve.cache.PrefixCache`
    outranks a short cold prompt.  Hits admitting first compounds: their
    prefill completes sooner, publishes deeper boundaries, and upgrades the
    hit length of queued requests sharing the prefix — so ranking must
    happen at *pop time* against the live tree, never be frozen at enqueue.
    A plain list scanned per pop does exactly that (heap keys would go
    stale as the tree fills and evicts); equal suffixes keep FIFO order.
    """

    def __init__(self, cache):
        super().__init__()
        self._cache = cache
        self._q = []
        self._n = 0
        self._peeked = None             # memo: (entry, cache.version)

    def _wire(self, reg: MetricsRegistry) -> None:
        super()._wire(reg)
        self._m_memo_hits = reg.counter(
            "sched_peek_memo_hits_total",
            "pops that reused the preceding peek's ranking scan")

    def _key(self, entry):
        order, req = entry
        # Clamp the hit to len-1, exactly like admission's ``lookup``: a
        # full-prompt snapshot still forces >= 1 token of prefill (the
        # first sampled token needs fresh logits), so ranking by an
        # unclamped hit would order/group lanes by a prefix length
        # admission can never actually restore.  Rank against the
        # request's own cache namespace (its expert set, for multi-tenant
        # engines): a prefix cached under another tenant's weights is not
        # a hit this request can restore.
        ns = getattr(req, "expert_set", None)
        hit = min(self._cache.peek_len(req.prompt, ns=ns),
                  len(req.prompt) - 1)
        return (len(req.prompt) - max(hit, 0), order)

    def add(self, request) -> None:
        self._q.append((self._n, request))
        self._n += 1
        self._peeked = None             # new arrival may outrank the memo
        self._m_added.inc()
        self._m_depth.set(len(self._q))

    def peek_next(self):
        if not self._q:
            return None
        entry = min(self._q, key=self._key)
        self._peeked = (entry, self._cache.version)
        return entry[1]

    def pop_next(self):
        """Pop the best entry.  A peek directly followed by a pop (the
        engine's admission loop) reuses the peek's ranking instead of
        re-scanning the queue — one O(queue) pass with a radix walk per
        entry, not two.  The memo is dropped when an arrival or any radix
        mutation (``cache.version``) could change the ranking, so pops
        always reflect the live tree."""
        if not self._q:
            return None
        if (self._peeked is not None
                and self._peeked[1] == self._cache.version):
            entry = self._peeked[0]
            self._m_memo_hits.inc()
        else:
            entry = min(self._q, key=self._key)
        self._peeked = None
        self._q.remove(entry)
        self._m_popped.inc()
        self._m_depth.set(len(self._q))
        return entry[1]

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)
