"""Request scheduling policies for the serving engine.

The engine asks the scheduler which waiting request to admit whenever a slot
frees up.  FIFO is the default; ``ShortestPromptFirst`` trades fairness for
lower mean TTFT under mixed prompt lengths (shorter prefills first).
"""
from __future__ import annotations

from collections import deque
from typing import Optional


class FIFOScheduler:
    """First-in-first-out admission."""

    def __init__(self):
        self._q = deque()

    def add(self, request) -> None:
        self._q.append(request)

    def pop_next(self):
        return self._q.popleft() if self._q else None

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)


class ShortestPromptFirst(FIFOScheduler):
    """Admit the waiting request with the shortest prompt (min mean TTFT)."""

    def pop_next(self):
        if not self._q:
            return None
        best = min(range(len(self._q)), key=lambda i: len(self._q[i].prompt))
        self._q.rotate(-best)
        req = self._q.popleft()
        self._q.rotate(best)
        return req
