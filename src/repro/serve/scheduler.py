"""Request scheduling policies for the serving engine.

The engine asks the scheduler which waiting request(s) to admit whenever
decode slots free up (or, in interleaved admission, whenever it can start a
new batched prefill job).  Ordering is a property of *pop time*, not
enqueue time: every ``pop_next`` decides over everything currently queued,
so requests arriving mid-run compete with older ones instead of being
appended behind a stale ordering.

FIFO is the default; ``ShortestPromptFirst`` trades fairness for lower mean
TTFT under mixed prompt lengths (shorter prefills first).
"""
from __future__ import annotations

import heapq
from collections import deque


class FIFOScheduler:
    """First-in-first-out admission."""

    def __init__(self):
        self._q = deque()

    def add(self, request) -> None:
        self._q.append(request)

    def pop_next(self):
        return self._q.popleft() if self._q else None

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)


class ShortestPromptFirst:
    """Admit the waiting request with the shortest prompt (min mean TTFT).

    Backed by a heap keyed on (prompt length, arrival order): a request
    submitted mid-run is ranked against every request still waiting the
    moment the engine next admits — not slotted into an ordering frozen when
    the queue was first built — and equal-length prompts keep FIFO order.
    """

    def __init__(self):
        self._h = []
        self._n = 0                     # arrival counter: stable tiebreak

    def add(self, request) -> None:
        heapq.heappush(self._h, (len(request.prompt), self._n, request))
        self._n += 1

    def pop_next(self):
        return heapq.heappop(self._h)[2] if self._h else None

    def __len__(self) -> int:
        return len(self._h)

    def __bool__(self) -> bool:
        return bool(self._h)
