"""Continuous-batching serving engine with stall-free chunked admission.

Design (vLLM-style, sized for a single host or one model replica):

  * ``max_slots`` decode lanes share one jitted multi-slot decode step with
    *per-slot positions* — each lane is at its own point in its own request.
  * Slot state is managed through the generic
    :class:`~repro.serve.state.StateStore`: every mixer declares its
    decode-state pytree and slot axis once (``state_spec`` on the Mixer
    registry), so admission/eviction never special-cases a mixer.
  * Admission is **stall-free** (``admission="interleaved"``, the default):
    queued prompts prefill in descending power-of-two chunks (jit
    specializations stay O(log max_chunk)) *interleaved* with decode — one
    jitted **mixed step** advances every active decode slot and one prefill
    chunk in the same dispatch, so decode lanes never wait for a prompt.
    When several requests are queued, up to ``prefill_lanes`` of them share
    **batched prefill lanes**: one job prefills them together (lane batch
    padded to a power of two so lane-count specializations stay logarithmic
    too), and each request's terminal state is adopted into its slot the
    chunk its prompt completes.
  * ``admission="sequential"`` keeps the PR-1 behaviour — full prefill per
    request while decode stalls — as the A/B baseline for the benchmark.
  * With a :class:`~repro.serve.cache.PrefixCache`, admission first looks
    up the longest cached prefix of each prompt, restores its boundary
    snapshot into the prefill lane, and prefills only the uncached suffix
    (serving cost O(uncached suffix), not O(prompt)); crossing new chunk
    boundaries publishes snapshots back to the tree.  Batched lanes group
    by cached-prefix length, since a job's lanes advance in lockstep.
  * The first token is sampled from the last prompt logit inside the same
    dispatch that finishes the prompt (that instant is the request's TTFT).
  * Slots retire on EOS / max-new-tokens / cache exhaustion and are refilled
    from the scheduler queue — decode never restarts for the other lanes.

Everything device-side is functional (state in, state out); host-side
bookkeeping is plain numpy.
"""
from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.plan import ParallelPlan
from repro.kernels import ops as kernel_ops
from repro.models import lm
from repro.serve.sampling import SamplingParams, sample, sample_fused
from repro.serve.scheduler import FIFOScheduler
from repro.serve.speculative import SpecConfig, make_spec_fn
from repro.serve.state import StateStore
from repro.serve.telemetry import EngineInstruments, Telemetry


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """The engine's scalar knobs, grouped (formerly a growing kwarg pile).

    max_slots: decode lanes (with a plan, a multiple of its slot partition).
    max_len: per-slot position capacity (prompt + generation).
    seed: sampling PRNG seed.
    max_prefill_chunk: largest power-of-two prefill chunk per dispatch.
    admission: "interleaved" (stall-free mixed steps, default) or
        "sequential" (full prefill per request, the PR-1 A/B baseline).
    prefill_lanes: max requests sharing one batched prefill job
        (default: max_slots).
    speculative: draft window K for self-speculative decoding (0 = off).
    draft_stride: layer-skip stride of the speculative draft model.
    kernels: kernel implementation for the jitted serving steps — None
        (backend auto), "ref" (jnp oracles), "pallas" (fused decode
        kernels; off-TPU the decode ops fall back to their fused jnp
        composites, still skipping the MoE dispatch machinery), or
        "interpret" (Pallas bodies on CPU, for tests).  Applied as the
        ``repro.kernels`` default-impl scope around every step dispatch,
        so it threads through decode/mixed/spec tracing without per-op
        plumbing.
    """
    max_slots: int = 4
    max_len: int = 128
    seed: int = 0
    max_prefill_chunk: int = 128
    admission: str = "interleaved"
    prefill_lanes: Optional[int] = None
    speculative: int = 0
    draft_stride: int = 2
    kernels: Optional[str] = None


@dataclasses.dataclass
class Request:
    """One generation request.

    prompt: token ids (len < engine max_len); max_new_tokens: decode budget;
    sampling: per-request temperature/top-k/top-p applied inside the jitted
    step; eos_id: optional stop token (kept in the output when hit);
    expert_set: name of the :class:`~repro.serve.expert_library.
    ExpertLibrary` expert set this request decodes with (None = the
    library's default set, and the only valid value without a library).
    """
    id: int
    prompt: Sequence[int]
    max_new_tokens: int = 16
    sampling: SamplingParams = SamplingParams()
    eos_id: Optional[int] = None
    expert_set: Optional[str] = None


@dataclasses.dataclass
class RequestResult:
    """Terminal record for one request, returned by ``run``/``tick``."""
    id: int
    prompt_len: int
    tokens: List[int]                   # generated tokens (incl. EOS if hit)
    finish_reason: str                  # eos | length | max_len
    ttft_s: float                       # submit -> first token
    latency_s: float                    # submit -> finish


@dataclasses.dataclass
class _Lane:
    req: Request
    tokens: List[int]
    t_submit: float
    t_first: float


@dataclasses.dataclass
class _PrefillLane:
    """One request inside an in-flight batched prefill job."""
    req: Request
    slot: int                           # reserved decode slot
    row: int                            # row in the job's lane batch
    t_submit: float
    remaining: int                      # prompt tokens not yet prefilled
    done: bool = False
    set_row: int = 0                    # expert-library binding row


def prefill_chunks(n: int, max_chunk: int) -> List[int]:
    """Greedy descending power-of-two decomposition of a prompt length.

    Bounds jit specializations of the prefill step to log2(max_chunk)+1
    shapes while keeping the number of passes per prompt logarithmic.
    """
    out = []
    while n > 0:
        c = min(1 << (n.bit_length() - 1), max_chunk)
        out.append(c)
        n -= c
    return out


class _PrefillJob:
    """A batched admission in flight: up to ``width`` requests prefilled
    together, one chunk per engine tick, all lanes advancing in lockstep
    from position ``pos0`` (0 cold; the shared cached-prefix length when
    admission restored a prefix-cache snapshot — lanes in one job always
    share it, which is why cache-aware admission groups by hit length).
    Each chunk is the largest power of two that every still-active lane can
    consume (the min of their next greedy chunks), so chunk sizes stay
    powers of two <= max_chunk and lanes with shorter prompts drop out at
    chunk boundaries — their terminal state is adopted into their slot
    while longer lanes keep prefilling."""

    def __init__(self, lanes: List[_PrefillLane], width: int, state,
                 max_chunk: int, pos0: int = 0, ns=None, params=None):
        self.lanes = lanes
        self.width = width
        self.state = state
        self.pos = pos0
        self.max_chunk = max_chunk
        # multi-tenant admission: all lanes of one job share an expert set
        # (one prefill dispatch runs one set's weights).  ``ns`` is the
        # request's raw ``expert_set`` — the prefix-cache namespace this
        # job reads/publishes under; ``params`` the single-set graft the
        # job's prefill dispatches run on (None = the engine's base params)
        self.ns = ns
        self.params = params
        self.prompts = {l.row: np.asarray(l.req.prompt, np.int32)
                        for l in lanes}
        self.temp = np.zeros((width,), np.float32)
        self.topk = np.zeros((width,), np.int32)
        self.topp = np.ones((width,), np.float32)
        for l in lanes:
            sp = l.req.sampling
            self.temp[l.row] = sp.temperature
            self.topk[l.row] = sp.top_k
            self.topp[l.row] = sp.top_p

    def active(self) -> List[_PrefillLane]:
        return [l for l in self.lanes if not l.done]

    def next_chunk(self) -> int:
        return min(min(1 << (l.remaining.bit_length() - 1)
                       for l in self.active()), self.max_chunk)

    def token_block(self, c: int) -> np.ndarray:
        """(width, c) token block: each active lane's next c prompt tokens.
        Finished/padding rows feed token 0 — their output and state rows are
        never read (the terminal state was adopted when the lane finished)."""
        blk = np.zeros((self.width, c), np.int32)
        for l in self.active():
            blk[l.row] = self.prompts[l.row][self.pos:self.pos + c]
        return blk

    def finished(self) -> bool:
        return all(l.done for l in self.lanes)


class ServeEngine:
    """Continuous-batching engine over a fixed-slot decode state.

    ``speculative=K`` (K >= 1) turns on self-speculative decoding: every
    decode dispatch drafts K tokens with a layer-skip reduced model
    (``draft_stride``), verifies them with one full-model pass, and emits
    1..K+1 tokens per slot (see ``serve/speculative.py``).  Greedy outputs
    are bit-identical to ``speculative=0``; sampled outputs stay unbiased
    via rejection-sampling acceptance.

    ``prefix_cache`` (a :class:`~repro.serve.cache.PrefixCache`) turns on
    prefix caching: admission skips prefill for the longest cached prefix
    of each prompt by restoring a chunk-boundary state snapshot, and
    publishes new boundaries as prefill crosses them.  Cache-hit greedy
    outputs are bit-identical to a cold prefill (chunk-boundary snapshots
    restore exactly).  Pair with
    :class:`~repro.serve.scheduler.CachedSuffixFirst` to admit hits first.
    A cache's snapshots are only shape-valid for one (cfg, max_len, dtype)
    combination — share it across engines of the same configuration only.

    Device placement is decided by the ``plan`` — a
    :class:`~repro.distributed.plan.ParallelPlan` resolved once and
    threaded through the store, every jitted step
    (``in_shardings``/``out_shardings``), prefill lane widths (padded to a
    multiple of the slot partition) and RoM expert dispatch.  The default
    :meth:`~repro.distributed.plan.ParallelPlan.single_device` keeps
    existing scripts working unchanged.  Scalar knobs live on
    :class:`EngineConfig` (``engine=``); passing them as keywords
    (``max_slots=8``) overrides the matching ``EngineConfig`` field.
    """

    def __init__(self, cfg, params, *, plan: Optional[ParallelPlan] = None,
                 engine: Optional[EngineConfig] = None, scheduler=None,
                 prefix_cache=None, expert_library=None,
                 telemetry: Optional[Telemetry] = None, **knobs):
        if "mesh" in knobs or "rules" in knobs:
            raise TypeError(
                "ServeEngine no longer takes mesh=/rules= — resolve the "
                "topology once with repro.distributed.plan.ParallelPlan "
                "and pass plan=...")
        ec = engine if engine is not None else EngineConfig()
        if knobs:
            valid = {f.name for f in dataclasses.fields(EngineConfig)}
            unknown = sorted(set(knobs) - valid)
            if unknown:
                raise TypeError(f"unknown engine option(s) {unknown}; "
                                f"valid EngineConfig fields: {sorted(valid)}")
            ec = dataclasses.replace(ec, **knobs)
        if cfg.kind == "encoder":
            raise ValueError("encoder-only configs have no decode path")
        if ec.admission not in ("interleaved", "sequential"):
            raise ValueError(f"unknown admission mode {ec.admission!r}")
        if ec.speculative < 0:
            raise ValueError(
                f"speculative K must be >= 0, got {ec.speculative}")
        if ec.kernels not in (None, "ref", "fused", "pallas", "interpret"):
            raise ValueError(f"unknown kernels impl {ec.kernels!r}; choose "
                             "None, 'ref', 'fused', 'pallas' or 'interpret'")
        self.plan = plan if plan is not None else ParallelPlan.single_device()
        if ec.max_slots % self.plan.data_size != 0:
            raise ValueError(
                f"max_slots={ec.max_slots} must be a multiple of the "
                f"plan's slot partition (data axis size "
                f"{self.plan.data_size}) so decode slots shard evenly")
        self.engine_config = ec
        self.cfg = cfg
        self.max_slots = max_slots = ec.max_slots
        self.max_len = max_len = ec.max_len
        self.dtype = jnp.dtype(cfg.dtype)
        self.max_prefill_chunk = ec.max_prefill_chunk
        self.admission = ec.admission
        self.prefill_lanes = min(ec.prefill_lanes or max_slots, max_slots)
        self.spec = (SpecConfig(k=ec.speculative,
                                draft_stride=ec.draft_stride)
                     if ec.speculative else None)
        self.cache = prefix_cache
        # everything device-side goes through the plan: params placement,
        # state allocation, jit shardings, the model code's shard context
        self.params = self.plan.place_params(params)
        self.store = StateStore(cfg, max_slots, max_len, self.dtype,
                                plan=self.plan)
        # multi-tenant serving: an ExpertLibrary makes the swappable expert
        # leaves a per-dispatch input.  The engine holds ``max_bound``
        # *binding rows* — named set slots its jitted steps fan out over —
        # all boot-bound (and pinned) to the library's default set;
        # admission rebinds a free row when a request names a cold set.
        # ``_graft_cache`` is the lazily rebuilt multi-set param tree the
        # decode/spec dispatches run on (tuple expert leaves, one entry per
        # *distinct* bound set so each dispatch pays one routed GEMM per
        # live set).
        self.library = expert_library
        if self.library is not None:
            if self.library.plan is None:
                self.library.plan = self.plan
            self._bound: List[str] = ([self.library.default]
                                      * self.library.max_bound)
            for name in self._bound:
                self.library.acquire(name)
            self._graft_cache = None
            self._graft_names: Optional[List[str]] = None
        st_sh = self.store.shardings            # None on single_device()
        shard_ctx = self.plan.shard_ctx()

        from repro import train as tr
        prefill_fn = tr.make_prefill_step_fn(cfg, self.plan.mesh,
                                             self.plan.rules)

        def decode_core(params, state, toks, pos, rng, temp, topk, topp,
                        sets=None):
            # ``sets`` (B,) i32: per-slot expert-library binding rows —
            # params then carry per-set tuple expert leaves and
            # SharedRouting selects each slot's set; None without a library
            rt = lm.Runtime(shard=shard_ctx, rng=None, train=False,
                            expert_sets=sets)
            if kernel_ops.active_default() is None:
                logits, new_state = lm.decode_step(params, state, toks, pos,
                                                   cfg, rt)
                return sample(logits, rng, temp, topk, topp), new_state
            # kernel scope active: stop at the pre-logits hidden row and let
            # the sampling epilogue fold argmax into the output projection
            # for all-greedy batches (full logits only when a slot samples)
            hidden, new_state = lm.decode_step_hidden(params, state, toks,
                                                      pos, cfg, rt)
            table = (params["embed"] if cfg.tie_embeddings
                     else params["lm_head"])
            nxt = sample_fused(
                hidden[:, 0], table, cfg.tie_embeddings, cfg.logit_softcap,
                lambda: lm.logits_fn(params, hidden, cfg, rt)[:, 0],
                rng, temp, topk, topp)
            return nxt, new_state

        def pf_core(params, pf_state, toks, pos0, rng, temp, topk, topp):
            logits, new_state = prefill_fn(params, pf_state, toks, pos0)
            first = sample(logits[:, -1], rng, temp, topk, topp)
            return first, new_state

        def mixed_fn(params, state, toks, pos, rng_d, temp, topk, topp,
                     pf_state, pf_toks, pf_pos, rng_p, pf_temp, pf_topk,
                     pf_topp, sets=None, pf_params=None):
            """The mixed step: every decode slot + one prefill chunk, one
            dispatch — admission costs no decode stall.  With a library,
            decode runs the multi-set graft (``params`` + ``sets``) while
            the prefill half runs the job's single-set graft
            (``pf_params``) — the prefill path stays plain-leaved."""
            nxt, new_state = decode_core(params, state, toks, pos, rng_d,
                                         temp, topk, topp, sets)
            first, new_pf = pf_core(
                params if pf_params is None else pf_params,
                pf_state, pf_toks, pf_pos, rng_p, pf_temp, pf_topk, pf_topp)
            return nxt, new_state, first, new_pf

        def sharded_jit(fn, state_arg=None, state_outs=(), n_outs=1):
            """jit with the canonical state arg/outputs pinned to the
            plan's slot shardings (plain jit off-mesh; prefill lane states
            keep their committed shardings from ``store.fresh``)."""
            if st_sh is None or state_arg is None:
                return jax.jit(fn)
            ins = [None] * len(inspect.signature(fn).parameters)
            ins[state_arg] = st_sh
            outs = [st_sh if i in state_outs else None
                    for i in range(n_outs)]
            return jax.jit(fn, in_shardings=tuple(ins),
                           out_shardings=(tuple(outs) if n_outs > 1
                                          else outs[0]))

        def kscope(fn):
            """Enter the engine's kernel-impl scope around a jitted step:
            the scope is live while jax traces (first call per shape), so
            ``ec.kernels`` reaches every ops.* resolution in the traced
            graph; cached executions just pay a context-manager enter."""
            if ec.kernels is None:
                return fn

            def call(*args):
                with kernel_ops.default_impl(ec.kernels):
                    return fn(*args)
            return call

        self._prefill = kscope(jax.jit(prefill_fn))  # sequential admission
        self._decode = kscope(sharded_jit(decode_core, state_arg=1,
                                          state_outs=(1,), n_outs=2))
        self._pf = kscope(jax.jit(pf_core))          # prefill + first token
        self._mixed = kscope(sharded_jit(mixed_fn, state_arg=1,
                                         state_outs=(1,), n_outs=4))

        if self.spec is not None:
            spec_core = make_spec_fn(cfg, self.plan, self.spec,
                                     self.store.axes,
                                     self.store.append_only)

            def spec_mixed_fn(params, state, last, pos, rng_d, temp, topk,
                              topp, pf_state, pf_toks, pf_pos, rng_p,
                              pf_temp, pf_topk, pf_topp, sets=None,
                              pf_params=None):
                """Speculative mixed step: one dispatch advances every
                decode slot by up to K+1 tokens *and* one prefill chunk."""
                toks, n_emit, new_state = spec_core(
                    params, state, last, pos, rng_d, temp, topk, topp, sets)
                first, new_pf = pf_core(
                    params if pf_params is None else pf_params,
                    pf_state, pf_toks, pf_pos, rng_p, pf_temp, pf_topk,
                    pf_topp)
                return toks, n_emit, new_state, first, new_pf

            self._spec = kscope(sharded_jit(spec_core, state_arg=1,
                                            state_outs=(2,), n_outs=3))
            self._spec_mixed = kscope(sharded_jit(spec_mixed_fn, state_arg=1,
                                                  state_outs=(2,), n_outs=5))
        else:
            self._spec = self._spec_mixed = None
        self._lanes: List[Optional[_Lane]] = [None] * max_slots
        self._job: Optional[_PrefillJob] = None
        self._reserved: set = set()                  # slots held by the job
        self._pos = np.zeros((max_slots,), np.int32)
        self._last = np.zeros((max_slots,), np.int32)
        self._temp = np.zeros((max_slots,), np.float32)
        self._topk = np.zeros((max_slots,), np.int32)
        self._topp = np.ones((max_slots,), np.float32)
        self._rng = jax.random.PRNGKey(ec.seed)
        self._tick = 0
        self._finished: List[RequestResult] = []
        self._submit_t: Dict[int, float] = {}
        self.scheduler = scheduler if scheduler is not None else FIFOScheduler()
        # telemetry: one registry of typed instruments (the semantics that
        # used to live as comments on the old ad-hoc ``stats`` dict are now
        # the instruments' help strings in serve/telemetry.py) plus the
        # per-request span tracer.  ``self.stats`` remains as a
        # compatibility view derived from the registry.  Disabled telemetry
        # hands out shared no-op instruments, so every instrumentation
        # site below is unconditional.
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self._metrics = EngineInstruments(self.telemetry.registry)
        self._tracer = self.telemetry.tracer
        self._stats_base: Dict[str, Any] = {}
        # share the engine's registry with a scheduler that can report
        # queue metrics (no-op for schedulers without bind_registry, and
        # for schedulers the caller already bound to another registry)
        bind = getattr(self.scheduler, "bind_registry", None)
        if bind is not None:
            bind(self.telemetry.registry)

    @property
    def state(self):
        """The canonical ``max_slots``-wide decode state pytree (slot b of
        every leaf — along the store's per-leaf slot axis — belongs to
        decode lane b)."""
        return self.store.state

    @state.setter
    def state(self, value):
        self.store.state = value

    # ------------------------------------------------------------------ API

    def submit(self, req: Request) -> None:
        """Queue a request (prompt must be non-empty and < max_len); its
        TTFT clock starts now."""
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.id}: empty prompt")
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"request {req.id}: prompt len {len(req.prompt)} >= "
                f"engine max_len {self.max_len}")
        if req.expert_set is not None:
            if self.library is None:
                raise ValueError(
                    f"request {req.id} names expert_set "
                    f"{req.expert_set!r} but the engine has no "
                    "ExpertLibrary (pass expert_library=)")
            if req.expert_set not in self.library:
                raise KeyError(
                    f"request {req.id}: unknown expert set "
                    f"{req.expert_set!r}; library has "
                    f"{self.library.names()}")
        t = time.perf_counter()
        self._submit_t[req.id] = t
        self._metrics.submitted.inc()
        self._tracer.begin(req.id, t, prompt_len=len(req.prompt),
                           expert_set=req.expert_set)
        self.scheduler.add(req)

    @property
    def stats(self) -> Dict[str, Any]:
        """Legacy counters view, derived from the telemetry registry: each
        key is its registry counter minus the value it had at the last
        :meth:`reset_stats` (so existing callers keep their re-timing
        semantics), with the historical int/float typing preserved.  All
        zeros when telemetry is disabled.  The registry itself
        (``engine.telemetry.registry``) is cumulative and never resets —
        windowed readings come from ``snapshot()``/``delta(prev)``."""
        return self._metrics.stats_view(self._stats_base)

    def reset_stats(self) -> None:
        """Re-baseline the ``stats`` view (benchmark iterations re-time a
        warm engine): subsequent reads report only activity after this
        call.  The underlying registry stays cumulative — this never
        zeroes an instrument, it just moves the subtraction baseline.
        Cache/library/scheduler metrics (their own ``stats`` dicts, and
        their instruments when they share this registry) are cumulative
        over component lifetime and deliberately untouched — window them
        with ``registry.snapshot()`` before / ``registry.delta(prev)``
        after the timed region, as benchmarks/serving.py does."""
        self._stats_base = self._metrics.stats_base()

    def spec_summary(self) -> Dict[str, float]:
        """Derived speculative-decoding stats: ``acceptance_rate`` =
        accepted / drafted, ``slot_rounds`` = (slot, round) pairs
        (drafted / K), ``tokens_per_slot_round`` = emitted tokens per slot
        per round, in [1, K+1].  Zeros when speculation is off or idle."""
        s = self.stats
        k = self.spec.k if self.spec else 0
        slot_rounds = s["spec_drafted"] / k if k else 0.0
        return {
            "acceptance_rate": s["spec_accepted"] / max(s["spec_drafted"], 1),
            "slot_rounds": slot_rounds,
            "tokens_per_slot_round": s["spec_emitted"] / max(slot_rounds, 1),
        }

    def busy(self) -> bool:
        """True while any work remains: queued requests, an in-flight
        prefill job, or live decode lanes."""
        return (bool(self.scheduler) or self._job is not None
                or any(l is not None for l in self._lanes))

    def run(self, requests: Optional[Sequence[Request]] = None
            ) -> List[RequestResult]:
        """Drive the engine until the queue, prefill jobs and lanes drain."""
        for r in (requests or ()):
            self.submit(r)
        results: List[RequestResult] = []
        while self.busy():
            results.extend(self.tick())
        results.extend(self._drain())
        return results

    def tick(self) -> List[RequestResult]:
        """One scheduling iteration: admit, then one dispatch that advances
        every active decode slot and (interleaved mode) one prefill chunk of
        the in-flight admission job.  Returns newly finished requests."""
        self._admit()
        active = [b for b, l in enumerate(self._lanes) if l is not None]
        m = self._metrics
        m.active_slots.set(len(active))
        if active:
            m.active_ticks.inc()
        job = self._job
        if job is not None:
            c = job.next_chunk()
            toks = jnp.asarray(job.token_block(c))
            live = len(job.active())
            dp, sets = self._decode_params()
            t0 = time.perf_counter()
            if active and self._spec is not None:
                with self.telemetry.annotate("serve/spec_mixed_step"):
                    sp_toks, n_emit, self.state, first, job.state = \
                        self._spec_mixed(
                            dp, self.state, jnp.asarray(self._last),
                            jnp.asarray(self._pos), self._next_rng(),
                            jnp.asarray(self._temp), jnp.asarray(self._topk),
                            jnp.asarray(self._topp),
                            job.state, toks, jnp.int32(job.pos),
                            self._next_rng(), jnp.asarray(job.temp),
                            jnp.asarray(job.topk), jnp.asarray(job.topp),
                            sets, job.params)
                    sp_toks = np.asarray(sp_toks)    # sync point
                    n_emit = np.asarray(n_emit)
                    first = np.asarray(first)
                t1 = time.perf_counter()
                m.mixed_steps.inc()
                m.mixed_s.inc(t1 - t0)
                m.decode_steps.inc()
                m.decode_step_s.observe(t1 - t0)
                self._apply_spec(sp_toks, n_emit, active, t0, t1)
            elif active:
                with self.telemetry.annotate("serve/mixed_step"):
                    nxt, self.state, first, job.state = self._mixed(
                        dp, self.state,
                        jnp.asarray(self._last)[:, None],
                        jnp.asarray(self._pos),
                        self._next_rng(), jnp.asarray(self._temp),
                        jnp.asarray(self._topk), jnp.asarray(self._topp),
                        job.state, toks, jnp.int32(job.pos),
                        self._next_rng(),
                        jnp.asarray(job.temp), jnp.asarray(job.topk),
                        jnp.asarray(job.topp), sets, job.params)
                    nxt = np.asarray(nxt)            # sync point
                    first = np.asarray(first)
                t1 = time.perf_counter()
                m.mixed_steps.inc()
                m.mixed_s.inc(t1 - t0)
                m.decode_steps.inc()
                m.decode_step_s.observe(t1 - t0)
                m.decode_tokens.inc(len(active))
                if self._tracer.enabled:
                    for b in active:
                        self._tracer.add(self._lanes[b].req.id, "decode",
                                         t0, t1, pos=int(self._pos[b]))
                self._apply_decode(nxt, active)
            else:
                with self.telemetry.annotate("serve/prefill_chunk"):
                    first, job.state = self._pf(
                        self.params if job.params is None else job.params,
                        job.state, toks, jnp.int32(job.pos),
                        self._next_rng(), jnp.asarray(job.temp),
                        jnp.asarray(job.topk), jnp.asarray(job.topp))
                    first = np.asarray(first)        # sync point
                t1 = time.perf_counter()
                m.prefill_s.inc(t1 - t0)
                if active:
                    # a prefill-only dispatch while decode lanes are live
                    # is exactly a stall (never taken by the current
                    # scheduler; counted so regressions surface in stats)
                    m.stall_s.inc(t1 - t0)
            m.prefill_tokens.inc(live * c)
            m.prefill_chunk_s.observe(t1 - t0)
            self._advance_job(c, first, t1, t0)
        elif active:
            if self._spec is not None:
                self._spec_only(active)
            else:
                self._decode_only(active)
        return self._drain()

    # ------------------------------------------- disaggregated fleet API

    def prefill_to_snapshot(self, req: Request):
        """Run one request's prefill to completion and return
        ``(first_token, snapshot)`` — the prefill half of disaggregated
        serving (``serve/fleet/``), never touching a decode slot.

        The snapshot is the host-side 1-slot decode state for the *full*
        prompt and ``first_token`` is sampled from the last prompt logit,
        exactly as monolithic admission does — so a decode replica that
        restores the pair continues bit-identically to a monolithic
        engine.  Cache-assisted like every admission: the longest cached
        prefix (local or shared tier) is restored first and new chunk
        boundaries publish back."""
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.id}: empty prompt")
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"request {req.id}: prompt len {len(req.prompt)} >= "
                f"engine max_len {self.max_len}")
        t0 = time.perf_counter()
        prompt = np.asarray(req.prompt, np.int32)[None, :]       # (1,S)
        S = prompt.shape[1]
        ns = req.expert_set
        pf_params = self.params
        acquired = None
        if self.library is not None:
            name = self._resolve_set(req)
            self.library.acquire(name)
            acquired = name
            pf_params = self.library.graft(self.params, [name])
        try:
            st = self.store.fresh(1)
            pos = 0
            if self.cache is not None:
                hit, snap = self.cache.lookup(req.prompt, ns=ns)
                if snap is not None:
                    st = self.store.restore_rows(st, snap, [0])
                    pos = hit
                    self._metrics.cache_hit_tokens.inc(hit)
            pos0 = pos
            logits = None
            for c in prefill_chunks(S - pos0, self.max_prefill_chunk):
                with self.telemetry.annotate("serve/fleet_prefill"):
                    logits, st = self._prefill(
                        pf_params, st, jnp.asarray(prompt[:, pos:pos + c]),
                        jnp.int32(pos))
                pos += c
                if self.cache is not None and self.cache.capture:
                    self.cache.insert(
                        tuple(req.prompt[:pos]),
                        lambda s=st: self.store.snapshot_rows(s, [0]),
                        ns=ns)
            sp = req.sampling
            first = sample(logits[:, -1], self._next_rng(),
                           jnp.full((1,), sp.temperature, jnp.float32),
                           jnp.full((1,), sp.top_k, jnp.int32),
                           jnp.full((1,), sp.top_p, jnp.float32))
            first_tok = int(np.asarray(first)[0])                # sync point
            snapshot = self.store.snapshot_rows(st, [0])
        finally:
            if acquired is not None:
                self.library.release(acquired)
        self._metrics.prefill_tokens.inc(S - pos0)
        self._metrics.prefill_s.inc(time.perf_counter() - t0)
        return first_tok, snapshot

    def admit_from_snapshot(self, req: Request, snap, first_token: int,
                            t_submit: Optional[float] = None) -> bool:
        """Admit a request whose prefill already happened elsewhere: the
        decode half of disaggregated serving.  ``snap`` is a 1-slot host
        snapshot of the full-prompt decode state and ``first_token`` the
        token its producer sampled from the last prompt logit — together
        the pair a :meth:`prefill_to_snapshot` call (possibly on another
        mesh, shipped through the fleet codec) produced.

        Returns False — admit nothing, caller requeues — when no decode
        slot is free or (multi-tenant) every expert binding row is
        pinned; True once the slot is live.  This engine never runs
        prefill for the request: admission is purely a state transfer,
        which is what keeps decode replicas stall-free."""
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"request {req.id}: prompt len {len(req.prompt)} >= "
                f"engine max_len {self.max_len}")
        if req.expert_set is not None and (
                self.library is None or req.expert_set not in self.library):
            raise KeyError(
                f"request {req.id}: unknown expert set {req.expert_set!r} "
                "on this decode replica")
        free = self._free_slots()
        if not free:
            return False
        set_row = 0
        if self.library is not None:
            row = self._bind_row(self._resolve_set(req))
            if row is None:
                return False
            set_row = row
        slot = free[0]
        now = time.perf_counter()
        t_submit = self._submit_t.pop(req.id, t_submit)
        if t_submit is None:
            t_submit = now
        self.store.restore_slot(slot, snap)
        self.store.expert_set[slot] = set_row
        self._tracer.begin(req.id, t_submit, prompt_len=len(req.prompt),
                           expert_set=req.expert_set)
        self._tracer.admitted(req.id, now, time.perf_counter(),
                              hit=len(req.prompt), ns=req.expert_set,
                              mode="snapshot", slot=slot)
        self._activate(slot, req, int(first_token), t_submit, now)
        return True

    # ------------------------------------------------------------- internals

    def _next_rng(self):
        self._tick += 1
        return jax.random.fold_in(self._rng, self._tick)

    def _drain(self) -> List[RequestResult]:
        out, self._finished = self._finished, []
        return out

    def _free_slots(self) -> List[int]:
        return [i for i, l in enumerate(self._lanes)
                if l is None and i not in self._reserved]

    # -------------------------------------------------- expert-library paths

    def _decode_params(self):
        """(params, sets) for the decode half of the next dispatch.

        Without a library: the engine's base params and ``sets=None`` (the
        jitted cores keep their non-tenant trace).  With one: the multi-set
        graft over the *distinct* bound sets (tuple expert leaves, one
        entry per live set, so each dispatch pays one routed GEMM per set —
        duplicate binding rows collapse) plus the per-slot selector mapping
        each slot's binding row through the distinct-set index.  The graft
        is a host-side tree rebuild cached until a rebind changes the
        distinct-name list; trace count is bounded by ``max_bound`` tuple
        lengths."""
        if self.library is None:
            return self.params, None
        uniq = list(dict.fromkeys(self._bound))
        if self._graft_cache is None or self._graft_names != uniq:
            self._graft_cache = self.library.graft(self.params, uniq)
            self._graft_names = uniq
        row2u = np.asarray([uniq.index(n) for n in self._bound], np.int32)
        return self._graft_cache, jnp.asarray(row2u[self.store.expert_set])

    def _bind_row(self, name: str) -> Optional[int]:
        """Return a binding row serving expert set ``name``, rebinding a
        free row (one no live decode lane or in-flight prefill lane still
        reads) if needed.  None = every row is busy with other sets; the
        caller stops admitting this tick — slots retire, rows free up, no
        deadlock.  A rebind releases the old set's pin, faults in / pins
        the new one, and invalidates the decode graft cache."""
        if name in self._bound:
            return self._bound.index(name)
        used = {int(self.store.expert_set[b])
                for b, l in enumerate(self._lanes) if l is not None}
        if self._job is not None:
            used.update(l.set_row for l in self._job.lanes if not l.done)
        for r, old in enumerate(self._bound):
            if r in used:
                continue
            self.library.release(old)
            self.library.acquire(name)
            self._bound[r] = name
            self._graft_cache = None
            self._metrics.expert_swaps.inc()
            return r
        return None

    def _resolve_set(self, req: Request) -> str:
        return (req.expert_set if req.expert_set is not None
                else self.library.default)

    def _admit(self) -> None:
        if self.admission == "sequential":
            # PR-1 behaviour: full prefill per request, decode stalled
            while self.scheduler:
                free = self._free_slots()
                if not free:
                    return
                if not self._admit_sequential(free[0],
                                              self.scheduler.pop_next()):
                    return          # no free expert binding row this tick
            return
        if self._job is not None or not self.scheduler:
            return
        free = self._free_slots()
        n = min(len(free), len(self.scheduler), self.prefill_lanes)
        if n == 0:
            return
        # assemble the job by peeking: lanes in a batched job advance in
        # lockstep from one position, so with a prefix cache every admitted
        # request must share the same cached-prefix length — stop at the
        # first request whose hit length differs (it leads the next job).
        # With an expert library, one job's prefill dispatch runs one set's
        # weights, so lanes must also share the request's *raw*
        # ``expert_set`` (raw, not resolved: it doubles as the job's cache
        # namespace, and None vs the default set's name are distinct
        # namespaces).  Cache-off + library-off keeps the plain pop loop
        # (and the PR-2 scheduler protocol, which had no peek_next).
        take: List[Request] = []
        pos0, ns0, set_row = 0, None, 0
        t_admit0 = time.perf_counter()
        if self.cache is None and self.library is None:
            take = [self.scheduler.pop_next() for _ in range(n)]
        else:
            while len(take) < n and self.scheduler:
                req = self.scheduler.peek_next()
                ns = req.expert_set
                hit = (self.cache.peek_len(req.prompt, ns=ns)
                       if self.cache is not None else 0)
                if not take:
                    pos0, ns0 = hit, ns
                    if self.library is not None:
                        name = self._resolve_set(req)
                        cold = name not in self._bound
                        row = self._bind_row(name)
                        if row is not None and cold:
                            self._tracer.event(req.id, "expert_swap",
                                               set=name, row=row)
                        if row is None:
                            # every binding row is pinned under live lanes
                            # or in-flight prefills: admit nothing this
                            # tick — slots retire, rows free up
                            return
                        set_row = row
                elif hit != pos0 or ns != ns0:
                    break
                self.scheduler.pop_next()
                take.append(req)
        # batched prefill lanes: lane batch padded to a power of two so jit
        # specializes on O(log lanes x log chunk) shapes, not one per count,
        # then up to a multiple of the plan's slot partition so lane
        # batches divide over the data axis
        width = self.plan.lane_width(len(take))
        lanes = []
        t_now = time.perf_counter()
        for row, req in enumerate(take):
            slot = free[row]
            lanes.append(_PrefillLane(
                req=req, slot=slot, row=row,
                t_submit=self._submit_t.pop(req.id, t_now),
                remaining=len(req.prompt) - pos0, set_row=set_row))
            self._reserved.add(slot)
        state = self.store.fresh(width)
        if self.cache is not None:
            rows, snaps = [], []
            for l in lanes:
                hit, snap = self.cache.lookup(l.req.prompt, ns=ns0)
                # grouping above guarantees hit == pos0 (tree unchanged
                # since the peek); lanes may still hold *different*
                # equal-length prefixes, hence one snapshot per lane
                if snap is not None:
                    rows.append(l.row)
                    snaps.append(snap)
                    self._metrics.cache_hit_tokens.inc(hit)
            if rows:
                # one host->device transfer + one insert for the whole
                # job: concatenate the 1-slot snapshots along each leaf's
                # slot axis into a len(rows)-slot source state
                src = jax.tree_util.tree_map(
                    lambda ax, *leaves: np.concatenate(leaves, axis=ax),
                    self.store.axes, *snaps)
                state = self.store.restore_rows(state, src, rows)
        # the job's prefill dispatches run a plain single-set graft — the
        # prefill model code never sees tuple leaves; regenerated per job
        # (never cached) so it cannot outlive the set's device residency
        pf_params = (self.library.graft(self.params,
                                        [self._bound[set_row]])
                     if self.library is not None else None)
        self._job = _PrefillJob(lanes, width, state,
                                self.max_prefill_chunk, pos0=pos0,
                                ns=ns0, params=pf_params)
        if self._tracer.enabled:
            t_admit1 = time.perf_counter()
            for l in lanes:
                self._tracer.admitted(l.req.id, t_admit0, t_admit1,
                                      hit=pos0, ns=ns0,
                                      mode="interleaved", slot=l.slot)

    def _advance_job(self, c: int, first: np.ndarray, t_done: float,
                     t_start: float) -> None:
        job = self._job
        job.pos += c
        finished = []
        crossed = []                    # lanes that consumed this chunk
        for l in job.lanes:
            if l.done:
                continue
            crossed.append(l)
            l.remaining -= c
            if l.remaining == 0:
                finished.append(l)
        if self._tracer.enabled:
            for l in crossed:
                self._tracer.add(l.req.id, "prefill_chunk", t_start, t_done,
                                 tokens=c, pos=job.pos)
        if self.cache is not None and self.cache.capture:
            # publish this boundary's snapshots: each crossing lane's state
            # row is the exact decode state for prompt[:job.pos] (full
            # prompt for lanes finishing now).  Prefixes already in the
            # tree are skipped with a walk; the rest share one batched
            # gather + device->host transfer, split host-side per lane
            # (mirrors the one-transfer batching on the restore side).
            new = [(l, tuple(l.req.prompt[:job.pos])) for l in crossed]
            # pre-filter (cache.wants: capture/min_tokens/grain, counting
            # grain refusals; plus dedup) so refused boundaries never pay
            # the batched gather + device->host transfer below.  Snapshots
            # publish under the job's expert-set namespace: a prefix
            # prefilled with tenant X's weights is only a hit for X.
            new = [(l, p) for l, p in new
                   if self.cache.wants(p)
                   and not self.cache.contains(p, ns=job.ns)]
            if new:
                snap = self.store.snapshot_rows(job.state,
                                                [l.row for l, _ in new])
                for i, (l, prefix) in enumerate(new):
                    one = jax.tree_util.tree_map(
                        lambda ax, leaf, i=i: np.take(leaf, [i], axis=ax),
                        self.store.axes, snap)
                    self.cache.insert(prefix, lambda s=one: s, ns=job.ns)
        if finished:
            # adopt the finished lanes' terminal prefill state into their
            # slots; ``first`` holds each lane's token sampled from its last
            # prompt logit inside the dispatch that completed the prompt
            self.store.adopt(job.state, [l.row for l in finished],
                             [l.slot for l in finished])
            for l in finished:
                l.done = True
                self._reserved.discard(l.slot)
                # record the slot's expert-set binding row before the lane
                # goes live: the next decode dispatch's ``sets`` selector
                # reads it
                self.store.expert_set[l.slot] = l.set_row
                self._activate(l.slot, l.req, int(first[l.row]),
                               l.t_submit, t_done)
        if job.finished():
            self._job = None

    def _activate(self, slot: int, req: Request, first_tok: int,
                  t_submit: float, t_first: float) -> None:
        sp = req.sampling
        self._metrics.ttft.observe(t_first - t_submit)
        self._tracer.event(req.id, "first_token", t_first)
        self._lanes[slot] = _Lane(req=req, tokens=[first_tok],
                                  t_submit=t_submit, t_first=t_first)
        self._pos[slot] = len(req.prompt)
        self._last[slot] = first_tok
        self._temp[slot] = sp.temperature
        self._topk[slot] = sp.top_k
        self._topp[slot] = sp.top_p
        # the very first token may already finish the request
        reason = self._finish_reason(slot)
        if reason:
            self._retire(slot, reason)

    def _admit_sequential(self, slot: int, req: Request) -> bool:
        t0 = time.perf_counter()
        # TTFT counts queue wait: clock starts at submit, not admission
        t_submit = self._submit_t.pop(req.id, t0)
        prompt = np.asarray(req.prompt, np.int32)[None, :]       # (1,S)
        S = prompt.shape[1]
        ns = req.expert_set
        set_row = 0
        pf_params = self.params
        if self.library is not None:
            name = self._resolve_set(req)
            cold = name not in self._bound
            row = self._bind_row(name)
            if row is None:
                # no free binding row: requeue and stall this admission
                # until decode lanes retire
                self._submit_t[req.id] = t_submit
                self.scheduler.add(req)
                return False
            if cold:
                self._tracer.event(req.id, "expert_swap", set=name, row=row)
            set_row = row
            pf_params = self.library.graft(self.params,
                                           [self._bound[set_row]])
        st = self.store.fresh(1)
        pos = 0
        if self.cache is not None:
            hit, snap = self.cache.lookup(req.prompt, ns=ns)
            if snap is not None:
                st = self.store.restore_rows(st, snap, [0])
                pos = hit
                self._metrics.cache_hit_tokens.inc(hit)
        pos0 = pos
        self._tracer.admitted(req.id, t0, time.perf_counter(),
                              hit=pos0, ns=ns, mode="sequential", slot=slot)
        logits = None
        for c in prefill_chunks(S - pos0, self.max_prefill_chunk):
            t_c0 = time.perf_counter()
            with self.telemetry.annotate("serve/prefill"):
                logits, st = self._prefill(
                    pf_params, st, jnp.asarray(prompt[:, pos:pos + c]),
                    jnp.int32(pos))
            pos += c
            # dispatch-timed (no device sync per chunk in sequential mode);
            # the final sync lands in the first-token sample below
            self._tracer.add(req.id, "prefill_chunk", t_c0,
                             time.perf_counter(), tokens=c, pos=pos)
            if self.cache is not None and self.cache.capture:
                self.cache.insert(
                    tuple(req.prompt[:pos]),
                    lambda s=st: self.store.snapshot_rows(s, [0]), ns=ns)
        sp = req.sampling
        first = sample(logits[:, -1], self._next_rng(),
                       jnp.full((1,), sp.temperature, jnp.float32),
                       jnp.full((1,), sp.top_k, jnp.int32),
                       jnp.full((1,), sp.top_p, jnp.float32))
        first_tok = int(np.asarray(first)[0])                    # sync point
        t1 = time.perf_counter()
        self.store.adopt(st, [0], [slot])
        self._metrics.prefill_tokens.inc(S - pos0)
        self._metrics.prefill_s.inc(t1 - t0)
        if any(l is not None for l in self._lanes):
            # decode lanes sat idle for this whole prefill: that is the
            # stall the interleaved mixed step eliminates
            self._metrics.stall_s.inc(t1 - t0)
        self.store.expert_set[slot] = set_row
        self._activate(slot, req, first_tok, t_submit, t1)
        return True

    def _finish_reason(self, slot: int) -> Optional[str]:
        lane = self._lanes[slot]
        if lane.req.eos_id is not None and lane.tokens[-1] == lane.req.eos_id:
            return "eos"
        if len(lane.tokens) >= lane.req.max_new_tokens:
            return "length"
        if self._pos[slot] + 1 >= self.max_len:
            return "max_len"
        return None

    def _retire(self, slot: int, reason: str) -> None:
        lane = self._lanes[slot]
        now = time.perf_counter()
        self._finished.append(RequestResult(
            id=lane.req.id, prompt_len=len(lane.req.prompt),
            tokens=list(lane.tokens), finish_reason=reason,
            ttft_s=lane.t_first - lane.t_submit,
            latency_s=now - lane.t_submit))
        self._metrics.e2e.observe(now - lane.t_submit)
        self._metrics.finished.inc()
        self._tracer.finish(lane.req.id, reason, now)
        # a request admitted straight from submit() had its entry popped at
        # admission; evictions and requeue races leave one behind — clean
        # up here so a long-running server's _submit_t cannot grow
        self._submit_t.pop(lane.req.id, None)
        self._lanes[slot] = None

    def _apply_decode(self, nxt: np.ndarray, active: List[int]) -> None:
        for b in active:
            tok = int(nxt[b])
            self._pos[b] += 1
            self._last[b] = tok
            self._lanes[b].tokens.append(tok)
            reason = self._finish_reason(b)
            if reason:
                self._retire(b, reason)

    def _decode_only(self, active: List[int]) -> None:
        dp, sets = self._decode_params()
        t0 = time.perf_counter()
        with self.telemetry.annotate("serve/decode_step"):
            nxt, self.state = self._decode(
                dp, self.state,
                jnp.asarray(self._last)[:, None], jnp.asarray(self._pos),
                self._next_rng(), jnp.asarray(self._temp),
                jnp.asarray(self._topk), jnp.asarray(self._topp), sets)
            nxt = np.asarray(nxt)                                # sync point
        t1 = time.perf_counter()
        m = self._metrics
        m.decode_tokens.inc(len(active))
        m.decode_s.inc(t1 - t0)
        m.decode_steps.inc()
        m.decode_step_s.observe(t1 - t0)
        if self._tracer.enabled:
            for b in active:
                self._tracer.add(self._lanes[b].req.id, "decode",
                                 t0, t1, pos=int(self._pos[b]))
        self._apply_decode(nxt, active)

    # -------------------------------------------------- speculative decoding

    def _spec_only(self, active: List[int]) -> None:
        """One speculative round (draft K + verify + commit), no prefill."""
        dp, sets = self._decode_params()
        t0 = time.perf_counter()
        with self.telemetry.annotate("serve/spec_step"):
            toks, n_emit, self.state = self._spec(
                dp, self.state,
                jnp.asarray(self._last), jnp.asarray(self._pos),
                self._next_rng(), jnp.asarray(self._temp),
                jnp.asarray(self._topk), jnp.asarray(self._topp), sets)
            toks = np.asarray(toks)                              # sync point
            n_emit = np.asarray(n_emit)
        t1 = time.perf_counter()
        self._metrics.decode_s.inc(t1 - t0)
        self._metrics.decode_steps.inc()
        self._metrics.decode_step_s.observe(t1 - t0)
        self._apply_spec(toks, n_emit, active, t0, t1)

    def _apply_spec(self, toks: np.ndarray, n_emit: np.ndarray,
                    active: List[int], t0: float, t1: float) -> None:
        """Apply one speculative round's tokens: up to ``n_emit[b]`` tokens
        per slot, re-checking finish conditions after every token so EOS /
        max-tokens / max_len inside the window truncate emission (the
        rejected or post-finish suffix of the window is simply dropped —
        the slot retires and its committed state is never read again).
        ``t0``/``t1`` bound the round's dispatch — the per-slot
        ``spec_round`` trace spans reuse them (no extra clock reads)."""
        k = self.spec.k
        m = self._metrics
        m.spec_rounds.inc()
        m.spec_drafted.inc(k * len(active))
        for b in active:
            accepted = int(n_emit[b]) - 1
            m.spec_accepted.inc(accepted)
            req_id = self._lanes[b].req.id
            emitted = 0
            finish = None
            for j in range(int(n_emit[b])):
                tok = int(toks[b, j])
                self._pos[b] += 1
                self._last[b] = tok
                self._lanes[b].tokens.append(tok)
                emitted += 1
                finish = self._finish_reason(b)
                if finish:
                    break
            m.spec_emitted.inc(emitted)
            m.decode_tokens.inc(emitted)
            if self._tracer.enabled:
                # span before any retire, so a request finishing inside
                # the window still records its last spec_round
                self._tracer.add(req_id, "spec_round", t0, t1,
                                 drafted=k, accepted=accepted,
                                 emitted=emitted)
            if finish:
                self._retire(b, finish)
