"""Continuous-batching serving engine.

Design (vLLM-style, sized for a single host or one model replica):

  * ``max_slots`` decode lanes share one jitted multi-slot decode step with
    *per-slot positions* — each lane is at its own point in its own request.
  * A prompt is prefilled with the parallel training-style forward
    (``models/lm.prefill``) in descending power-of-two chunks, so jit
    specializes on at most log2(max chunk) distinct shapes instead of one
    per prompt length, and the recurrent/conv/KV state threads through the
    chunks exactly as token-by-token stepping would produce it.
  * The terminal prefill state is inserted into the request's slot of the
    batched decode state; the first token is sampled from the last prompt
    logit (that instant is the request's TTFT).
  * Slots retire on EOS / max-new-tokens / cache exhaustion and are refilled
    from the scheduler queue — decode never restarts for the other lanes.

Everything device-side is functional (state in, state out); host-side
bookkeeping is plain numpy.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.models import lm
from repro.serve.sampling import SamplingParams, sample
from repro.serve.scheduler import FIFOScheduler


@dataclasses.dataclass
class Request:
    """One generation request."""
    id: int
    prompt: Sequence[int]
    max_new_tokens: int = 16
    sampling: SamplingParams = SamplingParams()
    eos_id: Optional[int] = None


@dataclasses.dataclass
class RequestResult:
    id: int
    prompt_len: int
    tokens: List[int]                   # generated tokens (incl. EOS if hit)
    finish_reason: str                  # eos | length | max_len
    ttft_s: float                       # submit -> first token
    latency_s: float                    # submit -> finish


@dataclasses.dataclass
class _Lane:
    req: Request
    tokens: List[int]
    t_submit: float
    t_first: float


def prefill_chunks(n: int, max_chunk: int) -> List[int]:
    """Greedy descending power-of-two decomposition of a prompt length.

    Bounds jit specializations of the prefill step to log2(max_chunk)+1
    shapes while keeping the number of passes per prompt logarithmic.
    """
    out = []
    while n > 0:
        c = min(1 << (n.bit_length() - 1), max_chunk)
        out.append(c)
        n -= c
    return out


class ServeEngine:
    """Continuous-batching engine over a fixed-slot decode state."""

    def __init__(self, cfg, params, *, max_slots: int = 4,
                 max_len: int = 128, mesh=None, rules=None, seed: int = 0,
                 max_prefill_chunk: int = 128, scheduler=None):
        if cfg.kind == "encoder":
            raise ValueError("encoder-only configs have no decode path")
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.dtype = jnp.dtype(cfg.dtype)
        self.max_prefill_chunk = max_prefill_chunk
        rules = rules or shd.ShardingRules()

        from repro import train as tr
        prefill_fn = tr.make_prefill_step_fn(cfg, mesh, rules)

        def decode_fn(params, state, toks, pos, rng, temp, topk, topp):
            rt = lm.Runtime(shard=shd.ShardCtx(mesh, rules), rng=None,
                            train=False)
            logits, new_state = lm.decode_step(params, state, toks, pos,
                                               cfg, rt)
            nxt = sample(logits, rng, temp, topk, topp)
            return nxt, new_state

        def insert_fn(batch_state, one_state, slot):
            def upd(axis):
                return lambda b, o: jax.lax.dynamic_update_slice_in_dim(
                    b, o.astype(b.dtype), slot, axis)
            segs = []
            for bseg, oseg in zip(batch_state["segments"],
                                  one_state["segments"]):
                if isinstance(bseg, list):      # unstacked: batch at axis 0
                    segs.append([jax.tree_util.tree_map(upd(0), bb, oo)
                                 for bb, oo in zip(bseg, oseg)])
                else:                           # lax.scan-stacked: (layers,B,…)
                    segs.append(jax.tree_util.tree_map(upd(1), bseg, oseg))
            return {"segments": segs}

        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)
        self._insert = jax.jit(insert_fn)

        self.state = lm.init_state(cfg, max_slots, max_len, self.dtype)
        self._lanes: List[Optional[_Lane]] = [None] * max_slots
        self._pos = np.zeros((max_slots,), np.int32)
        self._last = np.zeros((max_slots,), np.int32)
        self._temp = np.zeros((max_slots,), np.float32)
        self._topk = np.zeros((max_slots,), np.int32)
        self._topp = np.ones((max_slots,), np.float32)
        self._rng = jax.random.PRNGKey(seed)
        self._tick = 0
        self._finished: List[RequestResult] = []
        self._submit_t: Dict[int, float] = {}
        self.scheduler = scheduler if scheduler is not None else FIFOScheduler()
        self.stats: Dict[str, Any] = {
            "prefill_tokens": 0, "prefill_s": 0.0,
            "decode_tokens": 0, "decode_s": 0.0, "decode_steps": 0,
        }

    # ------------------------------------------------------------------ API

    def submit(self, req: Request) -> None:
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.id}: empty prompt")
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"request {req.id}: prompt len {len(req.prompt)} >= "
                f"engine max_len {self.max_len}")
        self._submit_t[req.id] = time.perf_counter()
        self.scheduler.add(req)

    def run(self, requests: Optional[Sequence[Request]] = None
            ) -> List[RequestResult]:
        """Drive the engine until the queue and all lanes drain."""
        for r in (requests or ()):
            self.submit(r)
        results: List[RequestResult] = []
        while True:
            self._admit()
            results.extend(self._drain())
            if not any(l is not None for l in self._lanes):
                break
            results.extend(self.step())
        return results

    # ------------------------------------------------------------- internals

    def _next_rng(self):
        self._tick += 1
        return jax.random.fold_in(self._rng, self._tick)

    def _drain(self) -> List[RequestResult]:
        out, self._finished = self._finished, []
        return out

    def _admit(self) -> None:
        """Fill free slots from the queue (a request whose very first token
        finishes frees its slot immediately, so keep admitting)."""
        while self.scheduler:
            free = [i for i, l in enumerate(self._lanes) if l is None]
            if not free:
                return
            self._admit_into(free[0], self.scheduler.pop_next())

    def _admit_into(self, slot: int, req: Request) -> None:
        t0 = time.perf_counter()
        # TTFT counts queue wait: clock starts at submit, not admission
        t_submit = self._submit_t.pop(req.id, t0)
        prompt = np.asarray(req.prompt, np.int32)[None, :]       # (1,S)
        S = prompt.shape[1]
        st = lm.init_state(self.cfg, 1, self.max_len, self.dtype)
        pos = 0
        logits = None
        for c in prefill_chunks(S, self.max_prefill_chunk):
            logits, st = self._prefill(self.params, st,
                                       jnp.asarray(prompt[:, pos:pos + c]),
                                       jnp.int32(pos))
            pos += c
        sp = req.sampling
        first = sample(logits[:, -1], self._next_rng(),
                       jnp.full((1,), sp.temperature, jnp.float32),
                       jnp.full((1,), sp.top_k, jnp.int32),
                       jnp.full((1,), sp.top_p, jnp.float32))
        first_tok = int(np.asarray(first)[0])                    # sync point
        t1 = time.perf_counter()
        self.state = self._insert(self.state, st, jnp.int32(slot))
        self.stats["prefill_tokens"] += S
        self.stats["prefill_s"] += t1 - t0

        lane = _Lane(req=req, tokens=[first_tok], t_submit=t_submit,
                     t_first=t1)
        self._lanes[slot] = lane
        self._pos[slot] = S
        self._last[slot] = first_tok
        self._temp[slot] = sp.temperature
        self._topk[slot] = sp.top_k
        self._topp[slot] = sp.top_p
        # the very first token may already finish the request
        reason = self._finish_reason(slot)
        if reason:
            self._retire(slot, reason)

    def _finish_reason(self, slot: int) -> Optional[str]:
        lane = self._lanes[slot]
        if lane.req.eos_id is not None and lane.tokens[-1] == lane.req.eos_id:
            return "eos"
        if len(lane.tokens) >= lane.req.max_new_tokens:
            return "length"
        if self._pos[slot] + 1 >= self.max_len:
            return "max_len"
        return None

    def _retire(self, slot: int, reason: str) -> None:
        lane = self._lanes[slot]
        now = time.perf_counter()
        self._finished.append(RequestResult(
            id=lane.req.id, prompt_len=len(lane.req.prompt),
            tokens=list(lane.tokens), finish_reason=reason,
            ttft_s=lane.t_first - lane.t_submit,
            latency_s=now - lane.t_submit))
        self._lanes[slot] = None

    def step(self) -> List[RequestResult]:
        """One decode step for every active lane; returns newly finished."""
        active = [b for b, l in enumerate(self._lanes) if l is not None]
        if not active:
            return []
        t0 = time.perf_counter()
        nxt, self.state = self._decode(
            self.params, self.state,
            jnp.asarray(self._last)[:, None], jnp.asarray(self._pos),
            self._next_rng(), jnp.asarray(self._temp),
            jnp.asarray(self._topk), jnp.asarray(self._topp))
        nxt = np.asarray(nxt)                                    # sync point
        t1 = time.perf_counter()
        self.stats["decode_tokens"] += len(active)
        self.stats["decode_s"] += t1 - t0
        self.stats["decode_steps"] += 1
        for b in active:
            tok = int(nxt[b])
            self._pos[b] += 1
            self._last[b] = tok
            self._lanes[b].tokens.append(tok)
            reason = self._finish_reason(b)
            if reason:
                self._retire(b, reason)
        return self._drain()
