"""Disaggregated prefill/decode serving (the "fleet" subsystem).

Prefill workers and decode workers are separate roles connected only by
serialized artifacts: ``codec.py`` defines the versioned snapshot wire
format, ``cache_tier.py`` the shared (and persistable) prefix-cache
tier, ``worker.py`` the two worker roles, ``router.py`` the fleet
router.  ``python -m repro.serve.fleet.inspect <file>`` prints any fleet
artifact.  See docs/serving.md (Disaggregated serving)."""
from repro.serve.fleet.cache_tier import (SharedCacheTier, load_prefix_cache,
                                          save_prefix_cache)
from repro.serve.fleet.codec import (CODEC_VERSION, CodecError, CorruptError,
                                     FingerprintError, SchemaError,
                                     SnapshotCodec, config_fingerprint,
                                     pack_message, read_header,
                                     unpack_message)
from repro.serve.fleet.router import FleetRouter
from repro.serve.fleet.worker import (DecodeWorker, PrefillWorker,
                                      WorkerDrained, decode_result,
                                      encode_request, encode_result,
                                      request_from_meta, request_meta)

__all__ = [
    "CODEC_VERSION", "CodecError", "CorruptError", "DecodeWorker",
    "FingerprintError", "FleetRouter", "PrefillWorker", "SchemaError",
    "SharedCacheTier", "SnapshotCodec", "WorkerDrained",
    "config_fingerprint", "decode_result", "encode_request",
    "encode_result", "load_prefix_cache", "pack_message", "read_header",
    "request_from_meta", "request_meta", "save_prefix_cache",
    "unpack_message",
]
