"""SharedCacheTier: a fleet-wide second tier under the radix prefix cache.

The :class:`~repro.serve.cache.PrefixCache` is per-engine: its snapshots
are live host pytrees addressed by a radix tree.  A fleet of replicas
wants one *shared* warm set — prefill worker A publishes a boundary,
decode worker B admits from it, and a restarted replica reattaches to
yesterday's cache.  The tier provides exactly that, holding **encoded**
snapshots (``fleet/codec.py`` blobs) keyed by ``(namespace, token
prefix)``:

  * attached caches fall through on lookup — local radix miss (or a
    shorter local hit) -> tier probe -> decode + promote into the local
    tree — and publish freshly captured boundaries back;
  * entries are opaque validated bytes, so the tier is trivially
    process-shareable and persistable: :meth:`save` / :meth:`load` write
    one ``b"RMCT"``-framed file (header: version + fingerprint + entry
    table; payload: concatenated blobs) and a load onto a different mesh
    still serves hits, because the blobs inside are topology-portable
    host snapshots;
  * eviction is byte-budgeted LRU over blob sizes, independent of any
    attached cache's budget.

Probing is by descending prefix length (one dict hit per candidate
length, capped at ``len(prompt) - 1`` like the radix walk), which keeps
the tier a plain ordered dict instead of a second radix tree — exactness
over the same boundary grain the caches publish.
"""
from __future__ import annotations

import collections
import os
import zlib
from typing import Any, Dict, List, Optional, Tuple

from repro.serve.fleet.codec import (CACHE_MAGIC, CODEC_VERSION, CorruptError,
                                     FingerprintError, SchemaError, _frame,
                                     _unframe)
from repro.serve.telemetry import MetricsRegistry


class SharedCacheTier:
    """Byte-budgeted LRU store of encoded snapshots, shared across caches.

    budget_mb: blob byte budget; inserting past it evicts least-recently
        used entries (an entry larger than the whole budget is refused).
    registry: optional shared :class:`MetricsRegistry` for the
        ``fleet_tier_*`` instruments (default: a private one).
    """

    def __init__(self, budget_mb: float = 128.0,
                 registry: Optional[MetricsRegistry] = None):
        if budget_mb <= 0:
            raise ValueError(f"budget_mb must be > 0, got {budget_mb}")
        self.budget_bytes = int(budget_mb * (1 << 20))
        # (ns, tokens tuple) -> encoded snapshot; order = LRU (oldest first)
        self._d: "collections.OrderedDict[Tuple[Any, Tuple[int, ...]], bytes]"
        self._d = collections.OrderedDict()
        self._bytes = 0
        self.registry = (registry if registry is not None
                         else MetricsRegistry())
        c, g = self.registry.counter, self.registry.gauge
        self._c_hits = c("fleet_tier_hits_total",
                         "tier probes that returned a blob")
        self._c_misses = c("fleet_tier_misses_total",
                           "tier probes with no stored prefix")
        self._c_inserts = c("fleet_tier_inserts_total",
                            "new blobs stored in the tier")
        self._c_dedup = c("fleet_tier_dedup_skips_total",
                          "puts skipped because the prefix was stored")
        self._c_evict = c("fleet_tier_evictions_total",
                          "blobs evicted (LRU)")
        self._c_oversize = c("fleet_tier_oversize_total",
                             "blobs refused: larger than the whole budget")
        self._g_bytes = g("fleet_tier_bytes_used",
                          "encoded snapshot bytes currently held")
        self._g_entries = g("fleet_tier_entries",
                            "snapshots currently held in the tier")

    # ------------------------------------------------------------- queries

    def __len__(self) -> int:
        return len(self._d)

    @property
    def bytes_used(self) -> int:
        return self._bytes

    def get(self, tokens, ns=None) -> Optional[bytes]:
        """Exact-prefix probe; LRU-touches on hit."""
        key = (ns, tuple(tokens))
        blob = self._d.get(key)
        if blob is None:
            self._c_misses.inc()
            return None
        self._d.move_to_end(key)
        self._c_hits.inc()
        return blob

    def longest_prefix(self, tokens, cap: Optional[int] = None,
                       ns=None) -> Tuple[int, Optional[bytes]]:
        """Longest stored prefix of ``tokens`` no longer than ``cap``
        (default ``len(tokens) - 1``, the admission cap):
        ``(prefix_len, blob)`` or ``(0, None)``.  LRU-touches the hit."""
        cap = self._cap(tokens, cap)
        for n in range(cap, 0, -1):
            key = (ns, tuple(tokens[:n]))
            if key in self._d:
                self._d.move_to_end(key)
                self._c_hits.inc()
                return n, self._d[key]
        self._c_misses.inc()
        return 0, None

    def peek_len(self, tokens, cap: Optional[int] = None, ns=None) -> int:
        """Longest stored prefix length, side-effect free (no LRU touch,
        no stats) — for schedulers and admission grouping."""
        cap = self._cap(tokens, cap)
        for n in range(cap, 0, -1):
            if (ns, tuple(tokens[:n])) in self._d:
                return n
        return 0

    @staticmethod
    def _cap(tokens, cap: Optional[int]) -> int:
        return max(len(tokens) - 1, 0) if cap is None else min(
            cap, len(tokens))

    # ------------------------------------------------------------- updates

    def put(self, tokens, blob: bytes, ns=None) -> bool:
        """Store one encoded snapshot; True iff newly stored (existing
        entries are LRU-touched, never overwritten — a prefix's snapshot
        is deterministic for a fingerprint, so first write wins)."""
        key = (ns, tuple(tokens))
        if key in self._d:
            self._d.move_to_end(key)
            self._c_dedup.inc()
            return False
        if len(blob) > self.budget_bytes:
            self._c_oversize.inc()
            return False
        self._d[key] = blob
        self._bytes += len(blob)
        self._c_inserts.inc()
        while self._bytes > self.budget_bytes and len(self._d) > 1:
            _, old = self._d.popitem(last=False)
            self._bytes -= len(old)
            self._c_evict.inc()
        self._g_bytes.set(self._bytes)
        self._g_entries.set(len(self._d))
        return True

    # ------------------------------------------------------------- reports

    def summary(self) -> Dict[str, Any]:
        per_ns: Dict[str, Dict[str, int]] = {}
        for (ns, _tokens), blob in self._d.items():
            row = per_ns.setdefault("default" if ns is None else str(ns),
                                    {"entries": 0, "bytes_used": 0})
            row["entries"] += 1
            row["bytes_used"] += len(blob)
        v = self.registry.value
        return {
            "entries": len(self._d),
            "bytes_used": self._bytes,
            "budget_bytes": self.budget_bytes,
            "per_namespace": per_ns,
            "hits": int(v("fleet_tier_hits_total")),
            "misses": int(v("fleet_tier_misses_total")),
            "inserts": int(v("fleet_tier_inserts_total")),
            "evictions": int(v("fleet_tier_evictions_total")),
        }

    def items(self) -> List[Tuple[Any, Tuple[int, ...], bytes]]:
        """Every (ns, prefix, blob) held, LRU order (oldest first)."""
        return [(ns, tokens, blob)
                for (ns, tokens), blob in self._d.items()]

    # --------------------------------------------------------- persistence

    def save(self, path: str, fingerprint: str) -> int:
        """Write the tier to one file (atomic rename); returns the entry
        count.  ``fingerprint`` pins the engine configuration the blobs
        belong to — :meth:`load` refuses files from a different one."""
        entries, payloads = [], []
        for (ns, tokens), blob in self._d.items():
            if ns is not None and not isinstance(ns, str):
                raise CorruptError(
                    "cache-tier namespaces must be None or str to "
                    f"persist, got {type(ns).__name__} ({ns!r})")
            entries.append({"ns": ns, "tokens": list(tokens),
                            "nbytes": len(blob),
                            "crc32": zlib.crc32(blob)})
            payloads.append(blob)
        header = {"version": CODEC_VERSION, "fingerprint": fingerprint,
                  "entries": entries}
        data = _frame(CACHE_MAGIC, header, b"".join(payloads))
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
        return len(entries)

    def load(self, path: str, fingerprint: Optional[str] = None) -> int:
        """Load a :meth:`save` file into this tier (existing entries kept;
        duplicates dedup-skipped), validating framing, version, per-entry
        crc and — when ``fingerprint`` is given — the configuration pin.
        Returns the number of entries newly stored."""
        with open(path, "rb") as f:
            data = f.read()
        header, payload = _unframe(CACHE_MAGIC, data, "cache file")
        if header.get("version") != CODEC_VERSION:
            raise SchemaError(f"cache file schema version "
                              f"{header.get('version')!r} != {CODEC_VERSION}")
        if fingerprint is not None and header.get("fingerprint") != \
                fingerprint:
            raise FingerprintError(
                f"cache file fingerprint {header.get('fingerprint')!r} "
                f"does not match this engine's {fingerprint!r}")
        entries = header.get("entries")
        if not isinstance(entries, list):
            raise CorruptError("cache file header has no entry table")
        total = sum(int(e.get("nbytes", -1)) for e in entries)
        if total != len(payload) or any(
                int(e.get("nbytes", -1)) < 0 for e in entries):
            raise CorruptError(f"cache file payload length {len(payload)} "
                               f"!= entry table total {total}")
        loaded, off = 0, 0
        for e in entries:
            n = int(e["nbytes"])
            blob = payload[off:off + n]
            off += n
            if zlib.crc32(blob) != e.get("crc32"):
                raise CorruptError(
                    f"cache file entry {e.get('tokens')!r}: crc mismatch")
            tokens = e.get("tokens")
            if not isinstance(tokens, list):
                raise CorruptError("cache file entry has no token prefix")
            if self.put(tuple(int(t) for t in tokens), blob,
                        ns=e.get("ns")):
                loaded += 1
        return loaded


# ---------------------------------------------------------------------------
# PrefixCache persistence (``--cache-save`` / ``--cache-load``): the cache's
# live snapshots travel through the codec into one tier file and back —
# the same wire format the shared tier persists, so a saved mono cache can
# later seed a fleet tier (and vice versa).
# ---------------------------------------------------------------------------

def save_prefix_cache(cache, codec, path: str) -> int:
    """Serialize every snapshot a :class:`PrefixCache` holds (all
    namespaces) into one cache-tier file; returns the entry count."""
    staging = SharedCacheTier(
        budget_mb=max(1.0, 2.0 * cache.bytes_used / (1 << 20) + 1.0))
    for ns in cache.namespaces():
        for prefix, snap in cache.snapshot_items(ns):
            staging.put(prefix, codec.encode(snap), ns=ns)
    staging.save(path, codec.fingerprint)
    return len(staging)


def load_prefix_cache(cache, codec, path: str) -> int:
    """Load a saved cache file into a :class:`PrefixCache` (entries decode
    through ``codec`` — wrong fingerprints are rejected before any
    restore).  The cache's own byte budget still governs; returns the
    number of snapshots adopted."""
    staging = SharedCacheTier(
        budget_mb=max(1.0, 2.0 * os.path.getsize(path) / (1 << 20) + 1.0))
    staging.load(path, codec.fingerprint)
    n = 0
    for ns, tokens, blob in staging.items():
        if cache.adopt_snapshot(tokens, codec.decode(blob), ns=ns):
            n += 1
    return n
