"""FleetRouter: request routing over prefill and decode worker pools.

The router is the only component that sees the whole fleet.  It owns the
request queue and two policies:

  * **prefill assignment** — maximize the cached-prefix length the
    serving worker can skip (each worker reports ``cached_len``, which
    includes any shared tier it is attached to), tie-broken by least
    work served; this is what makes a shared cache tier pay off at the
    fleet level;
  * **decode assignment** — expert-set affinity first (a replica whose
    engine already binds the request's set avoids a hot swap), then
    least live decode lanes.

Failure handling: a worker that raises (``WorkerDrained``, or anything
else — a failure is a failure) costs the request one retry; the router
requeues it to the next-best peer, up to ``max_retries`` per request,
then surfaces the last error.  An admission that is merely *refused*
(``try_admit`` -> False: no free slot yet) is not a failure — the
message stays queued while the router keeps stepping decode workers so
lanes retire and capacity reappears.

Two drive modes: :meth:`run` is cooperative (deterministic
single-threaded interleaving — the CI mode) and :meth:`run` with
``threaded=True`` runs every worker on its own thread over
``queue.Queue`` channels (the honest concurrent rehearsal; results are
identical because workers only ever exchange codec bytes).  All
cross-worker traffic in both modes is serialized messages.
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.serve.engine import Request, RequestResult
from repro.serve.fleet.codec import unpack_message
from repro.serve.fleet.worker import (DecodeWorker, PrefillWorker,
                                      decode_result, encode_request)
from repro.serve.telemetry import FleetInstruments, Telemetry


class FleetRouter:
    """Routes requests through prefill replicas into decode replicas."""

    def __init__(self, prefill_workers: Sequence[PrefillWorker],
                 decode_workers: Sequence[DecodeWorker],
                 telemetry: Optional[Telemetry] = None,
                 max_retries: int = 2):
        if not prefill_workers or not decode_workers:
            raise ValueError("a fleet needs at least one prefill and one "
                             "decode worker")
        self.prefill_workers = list(prefill_workers)
        self.decode_workers = list(decode_workers)
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self._m = FleetInstruments(self.telemetry.registry)
        self._tracer = self.telemetry.tracer
        self.max_retries = max_retries
        # (request message bytes, retries) — requests awaiting prefill
        self._queue: collections.deque = collections.deque()
        self._m.prefill_workers.set(len(self.prefill_workers))
        self._m.decode_workers.set(len(self.decode_workers))

    # ----------------------------------------------------------- submit

    def submit(self, req: Request) -> None:
        """Accept one request: serialized at this boundary — past this
        call the fleet only ever sees the wire form."""
        self._queue.append((encode_request(req), 0))
        self._m.queue_depth.set(len(self._queue))

    # ----------------------------------------------------- assignment

    @staticmethod
    def _peek(msg: bytes) -> Dict[str, Any]:
        meta, _ = unpack_message(msg)
        return meta

    def _pick_prefill(self, req_meta: Dict[str, Any]) -> List[PrefillWorker]:
        """Candidate prefill workers, best first: longest cached prefix,
        then least served."""
        prompt = req_meta["prompt"]
        ns = req_meta.get("expert_set")
        live = [w for w in self.prefill_workers if not w.drained]
        return sorted(live or self.prefill_workers,
                      key=lambda w: (-w.cached_len(prompt, ns=ns), w.load))

    def _pick_decode(self, req_meta: Dict[str, Any]) -> List[DecodeWorker]:
        """Candidate decode workers, best first: expert-set affinity,
        then least live lanes."""
        wanted = req_meta.get("expert_set")
        live = [w for w in self.decode_workers if not w.drained]

        def key(w: DecodeWorker) -> Tuple[int, int]:
            affine = wanted is not None and wanted in w.bound_sets()
            return (0 if affine else 1, w.load)

        return sorted(live or self.decode_workers, key=key)

    # ----------------------------------------------------------- drive

    def run(self, requests: Optional[Sequence[Request]] = None,
            threaded: bool = False) -> List[RequestResult]:
        """Drive the fleet until every submitted request finishes."""
        for r in (requests or ()):
            self.submit(r)
        results = (self._run_threaded() if threaded
                   else self._run_cooperative())
        self._m.queue_depth.set(len(self._queue))
        return results

    def _prefill_one(self, msg: bytes, tries: int) -> Tuple[bytes, int]:
        """Route one request message through a prefill worker, retrying
        across peers on worker failure.  Returns (admit message, tries)."""
        meta = self._peek(msg)
        t_sub = meta.get("t_submit")
        last_err: Optional[BaseException] = None
        for worker in self._pick_prefill(meta["request"]):
            if tries > self.max_retries:
                break
            try:
                admit = worker.process(msg)
            except Exception as e:          # drained or failed: retry peer
                self._m.failures.inc()
                self._m.requeues.inc()
                tries += 1
                last_err = e
                continue
            if t_sub is not None:
                self._m.queue_s.observe(time.perf_counter() - t_sub)
            return admit, tries
        raise RuntimeError(
            f"request {meta['request']['id']}: no prefill worker could "
            f"serve it after {tries} attempt(s)") from last_err

    def _run_cooperative(self) -> List[RequestResult]:
        results: List[RequestResult] = []
        # (admit message, retries) — snapshots awaiting a decode slot
        admits: collections.deque = collections.deque()
        while (self._queue or admits
               or any(w.busy() for w in self.decode_workers)):
            while self._queue:
                msg, tries = self._queue.popleft()
                self._m.queue_depth.set(len(self._queue))
                admits.append(self._prefill_one(msg, tries))
            for _ in range(len(admits)):
                msg, tries = admits.popleft()
                meta = self._peek(msg)
                admitted, failed = False, False
                for worker in self._pick_decode(meta["request"]):
                    if tries > self.max_retries:
                        break
                    try:
                        admitted = worker.try_admit(msg)
                    except Exception:
                        self._m.failures.inc()
                        self._m.requeues.inc()
                        tries += 1
                        failed = True
                        continue
                    if admitted:
                        break
                    # refused = fleet at capacity, not a failure: stop
                    # probing peers (they are sorted busiest-last anyway)
                    break
                if not admitted:
                    if failed and tries > self.max_retries:
                        raise RuntimeError(
                            f"request {meta['request']['id']}: no decode "
                            f"worker admitted it after {tries} attempt(s)")
                    admits.append((msg, tries))
            stepped = False
            for worker in self.decode_workers:
                for res_msg in worker.step():
                    results.append(decode_result(res_msg))
                    stepped = True
                stepped = stepped or worker.busy()
            if admits and not stepped and not self._queue:
                raise RuntimeError(
                    f"{len(admits)} admit message(s) stuck with every "
                    "decode worker idle — fleet misconfigured "
                    "(all drained, or zero free slots at rest)")
        return results

    # ------------------------------------------------------- threaded

    def _run_threaded(self) -> List[RequestResult]:
        """Every worker on its own thread; channels carry only message
        bytes.  The router thread does assignment exactly like the
        cooperative mode; worker errors propagate after join."""
        admit_q: "queue.Queue[Tuple[bytes, int]]" = queue.Queue()
        result_q: "queue.Queue[bytes]" = queue.Queue()
        errors: List[BaseException] = []
        n_requests = len(self._queue)

        def prefill_loop(msg: bytes, tries: int) -> None:
            try:
                admit_q.put(self._prefill_one(msg, tries))
            except BaseException as e:
                errors.append(e)
                admit_q.put((b"", -1))              # unblock the router

        decode_chans: Dict[str, "queue.Queue[Optional[bytes]]"] = {
            w.name: queue.Queue() for w in self.decode_workers}

        def decode_loop(worker: DecodeWorker) -> None:
            chan = decode_chans[worker.name]
            pending: collections.deque = collections.deque()
            closing = False
            try:
                while True:
                    try:
                        item = chan.get(timeout=0.001)
                        if item is None:
                            closing = True
                        else:
                            pending.append(item)
                    except queue.Empty:
                        pass
                    while pending and worker.try_admit(pending[0]):
                        pending.popleft()
                    for res_msg in worker.step():
                        result_q.put(res_msg)
                    if closing and not pending and not worker.busy():
                        return
            except BaseException as e:
                errors.append(e)

        threads = [threading.Thread(target=decode_loop, args=(w,),
                                    daemon=True)
                   for w in self.decode_workers]
        while self._queue:
            msg, tries = self._queue.popleft()
            threads.append(threading.Thread(target=prefill_loop,
                                            args=(msg, tries), daemon=True))
        self._m.queue_depth.set(0)
        for t in threads:
            t.start()
        results: List[RequestResult] = []
        served = 0
        while served < n_requests and not errors:
            msg, tries = admit_q.get()
            if tries < 0:
                break
            meta = self._peek(msg)
            worker = self._pick_decode(meta["request"])[0]
            decode_chans[worker.name].put(msg)
            served += 1
        for chan in decode_chans.values():
            chan.put(None)                           # close every channel
        for t in threads:
            t.join(timeout=600)
        if errors:
            raise errors[0]
        while not result_q.empty():
            results.append(decode_result(result_q.get()))
        return results
