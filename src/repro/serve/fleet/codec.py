"""Versioned wire format for decode-state snapshots (the fleet codec).

Disaggregated serving connects workers only through serialized artifacts:
a prefill replica publishes the decode state at a prompt boundary, and a
decode replica (possibly on a different mesh, possibly in a different
process days later) restores it.  The snapshots themselves are the
host-side numpy pytrees ``StateStore.snapshot_rows`` produces — already
topology-portable — so the codec's job is purely representational:

  * **self-describing** — a JSON header carries the pytree *skeleton*
    (dict/list structure with leaves replaced by payload indices) plus a
    per-leaf table of dtype / shape / byte length / crc32 / append-only
    flag, so a blob can be decoded (and inspected: ``python -m
    repro.serve.fleet.inspect``) with no model code in scope;
  * **versioned** — ``CODEC_VERSION`` in the header; decoding a blob from
    a different schema raises :class:`SchemaError`, never mis-restores;
  * **fingerprinted** — snapshots are only shape-valid for one
    (cfg, max_len, dtype) combination, so the header pins
    :func:`config_fingerprint` and decode rejects mismatches
    (:class:`FingerprintError`) before touching a single payload byte;
  * **strict** — header crc, per-leaf crc, dtype/shape/byte-length
    consistency and total payload length are all validated on decode;
    any tamper or truncation raises :class:`CorruptError`.

Only stdlib + numpy: no pickle (a snapshot from an untrusted peer must
not execute code), no jax (the inspect tool and cache-tier persistence
run without an accelerator runtime in scope).

Layout (all integers little-endian u32)::

    b"RMSN" | header_len | crc32(header) | header JSON | leaf payloads

with leaf ``i``'s payload occupying ``nbytes[i]`` C-contiguous bytes at
offset ``sum(nbytes[:i])`` past the header.  :func:`pack_message` wraps
the same framing (magic ``b"RMMS"``) around a JSON meta dict + opaque
blob for the fleet's request/admit/result messages.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import struct
import zlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

SNAPSHOT_MAGIC = b"RMSN"
MESSAGE_MAGIC = b"RMMS"
CACHE_MAGIC = b"RMCT"
CODEC_VERSION = 1

_U32 = struct.Struct("<I")


class CodecError(ValueError):
    """Base class: a blob this codec refuses to decode."""


class SchemaError(CodecError):
    """Wrong magic or schema version — a different (or future) format."""


class FingerprintError(CodecError):
    """Valid blob for a *different* (cfg, max_len, dtype) — restoring it
    would be shape-valid garbage at worst; always rejected."""


class CorruptError(CodecError):
    """Truncated, tampered or internally inconsistent blob."""


def config_fingerprint(cfg, max_len: int, dtype) -> str:
    """Digest pinning the snapshot-compatibility domain: two engines share
    snapshots iff their (cfg, max_len, dtype) fingerprints match.  The cfg
    is canonicalized through ``dataclasses.asdict`` (frozen nested
    dataclasses) with sorted keys; non-JSON scalars stringify."""
    body = dataclasses.asdict(cfg) if dataclasses.is_dataclass(cfg) else cfg
    doc = {"cfg": body, "max_len": int(max_len),
           "dtype": np.dtype(dtype).str}
    blob = json.dumps(doc, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def _flatten(tree, path="") -> Tuple[Any, List[Tuple[str, np.ndarray]]]:
    """(skeleton, [(path, leaf)]): the skeleton mirrors the pytree with
    each leaf replaced by its index into the leaf list.  Only dict / list
    / tuple containers and array-like leaves are representable — the
    codec never needs more, and anything else is an error, not a guess."""
    leaves: List[Tuple[str, np.ndarray]] = []

    def rec(node, path):
        if isinstance(node, dict):
            return {str(k): rec(v, f"{path}/{k}") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return [rec(v, f"{path}/{i}") for i, v in enumerate(node)]
        if isinstance(node, (np.ndarray, np.generic)):
            leaves.append((path or "/", np.asarray(node)))
            return len(leaves) - 1
        raise CodecError(
            f"unencodable leaf at {path or '/'}: {type(node).__name__} "
            "(snapshots are dict/list pytrees of numpy arrays)")

    return rec(tree, path), leaves


def _unflatten(skel, leaves: List[np.ndarray]):
    if isinstance(skel, dict):
        return {k: _unflatten(v, leaves) for k, v in skel.items()}
    if isinstance(skel, list):
        return [_unflatten(v, leaves) for v in skel]
    if isinstance(skel, int) and 0 <= skel < len(leaves):
        return leaves[skel]
    raise CorruptError(f"skeleton references invalid leaf index {skel!r}")


def _frame(magic: bytes, header: Dict[str, Any],
           payload: bytes = b"") -> bytes:
    hdr = json.dumps(header, sort_keys=True).encode()
    return b"".join([magic, _U32.pack(len(hdr)),
                     _U32.pack(zlib.crc32(hdr)), hdr, payload])


def _unframe(magic: bytes, blob: bytes,
             what: str) -> Tuple[Dict[str, Any], bytes]:
    if len(blob) < 12:
        raise CorruptError(f"{what}: {len(blob)} bytes is shorter than "
                           "the fixed framing")
    if blob[:4] != magic:
        raise SchemaError(f"{what}: bad magic {blob[:4]!r} "
                          f"(expected {magic!r})")
    (hdr_len,) = _U32.unpack_from(blob, 4)
    (hdr_crc,) = _U32.unpack_from(blob, 8)
    if len(blob) < 12 + hdr_len:
        raise CorruptError(f"{what}: truncated header "
                           f"({len(blob)} < {12 + hdr_len} bytes)")
    hdr = blob[12:12 + hdr_len]
    if zlib.crc32(hdr) != hdr_crc:
        raise CorruptError(f"{what}: header crc mismatch")
    try:
        header = json.loads(hdr.decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise CorruptError(f"{what}: unparseable header ({e})") from None
    if not isinstance(header, dict):
        raise CorruptError(f"{what}: header is not an object")
    return header, blob[12 + hdr_len:]


def read_header(blob: bytes) -> Dict[str, Any]:
    """Parse and validate a snapshot blob's header (no payload checks) —
    the inspect tool's entry point."""
    header, _ = _unframe(SNAPSHOT_MAGIC, blob, "snapshot")
    if header.get("version") != CODEC_VERSION:
        raise SchemaError(f"snapshot schema version "
                          f"{header.get('version')!r} != {CODEC_VERSION}")
    if not isinstance(header.get("leaves"), list):
        raise CorruptError("snapshot header has no leaf table")
    return header


class SnapshotCodec:
    """Encoder/decoder bound to one engine configuration.

    fingerprint: the :func:`config_fingerprint` of the (cfg, max_len,
        dtype) whose snapshots this codec handles; stamped on encode,
        enforced on decode.
    flags: optional bool pytree (``StateStore.append_only``) matching the
        snapshot structure — each leaf's append-only flag travels in the
        header (decode replicas may treat append-only leaves differently;
        today it is validated metadata + inspect-tool signal).
    """

    def __init__(self, fingerprint: str, flags: Any = None):
        self.fingerprint = fingerprint
        self._flags: Optional[Dict[str, bool]] = None
        if flags is not None:
            _, flag_leaves = _flatten(
                _map_bools(flags))
            self._flags = {path: bool(leaf) for path, leaf in flag_leaves}

    @classmethod
    def for_store(cls, store) -> "SnapshotCodec":
        """Codec for a :class:`~repro.serve.state.StateStore`'s snapshots
        (fingerprint + append-only flags derived from the store)."""
        return cls(config_fingerprint(store.cfg, store.max_len, store.dtype),
                   flags=store.append_only)

    # ------------------------------------------------------------- encode

    def encode(self, snap) -> bytes:
        """Serialize one host-side snapshot pytree."""
        skel, leaves = _flatten(snap)
        table, payloads = [], []
        for path, leaf in leaves:
            raw = np.ascontiguousarray(leaf).tobytes()
            table.append({
                "path": path,
                "dtype": leaf.dtype.str,
                "shape": list(leaf.shape),
                "nbytes": len(raw),
                "crc32": zlib.crc32(raw),
                "append_only": bool(self._flags.get(path, False)
                                    if self._flags else False),
            })
            payloads.append(raw)
        header = {"version": CODEC_VERSION, "fingerprint": self.fingerprint,
                  "skeleton": skel, "leaves": table}
        return _frame(SNAPSHOT_MAGIC, header, b"".join(payloads))

    # ------------------------------------------------------------- decode

    def decode(self, blob: bytes):
        """Strictly validate and deserialize a snapshot blob.  Raises
        :class:`SchemaError` / :class:`FingerprintError` /
        :class:`CorruptError`; on success returns the snapshot pytree
        bit-identical to the one encoded."""
        header, payload = _unframe(SNAPSHOT_MAGIC, blob, "snapshot")
        if header.get("version") != CODEC_VERSION:
            raise SchemaError(
                f"snapshot schema version {header.get('version')!r} "
                f"!= supported {CODEC_VERSION}")
        if header.get("fingerprint") != self.fingerprint:
            raise FingerprintError(
                f"snapshot fingerprint {header.get('fingerprint')!r} does "
                f"not match this engine's {self.fingerprint!r} "
                "(different cfg / max_len / dtype)")
        table = header.get("leaves")
        if not isinstance(table, list):
            raise CorruptError("snapshot header has no leaf table")
        total = sum(int(e.get("nbytes", -1)) for e in table)
        if total != len(payload) or any(
                int(e.get("nbytes", -1)) < 0 for e in table):
            raise CorruptError(
                f"payload length {len(payload)} != leaf table total {total}")
        leaves, off = [], 0
        for e in table:
            n = int(e["nbytes"])
            raw = payload[off:off + n]
            off += n
            if zlib.crc32(raw) != e.get("crc32"):
                raise CorruptError(f"leaf {e.get('path')!r}: payload crc "
                                   "mismatch")
            try:
                dt = np.dtype(e["dtype"])
                shape = tuple(int(s) for s in e["shape"])
            except (TypeError, ValueError, KeyError):
                raise CorruptError(
                    f"leaf {e.get('path')!r}: invalid dtype/shape "
                    "metadata") from None
            expect = dt.itemsize * int(np.prod(shape, dtype=np.int64))
            if expect != n:
                raise CorruptError(
                    f"leaf {e.get('path')!r}: {n} payload bytes but "
                    f"dtype/shape implies {expect}")
            if self._flags is not None:
                want = self._flags.get(e.get("path"))
                if want is None or want != bool(e.get("append_only")):
                    raise CorruptError(
                        f"leaf {e.get('path')!r}: append-only flag "
                        "disagrees with this engine's StateSpec")
            leaves.append(np.frombuffer(raw, dtype=dt).reshape(shape))
        return _unflatten(header.get("skeleton"), leaves)


def _map_bools(tree):
    """Normalize a bool pytree (append-only mask) to 0-d numpy leaves so
    it flattens with the same paths as the snapshot it describes."""
    if isinstance(tree, dict):
        return {k: _map_bools(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_map_bools(v) for v in tree]
    return np.asarray(bool(tree))


# ---------------------------------------------------------------------------
# message framing: JSON meta + opaque blob (requests, admits, results)
# ---------------------------------------------------------------------------

def pack_message(meta: Dict[str, Any], blob: bytes = b"") -> bytes:
    """One fleet wire message: a JSON-serializable ``meta`` dict plus an
    opaque payload (usually an encoded snapshot; empty for control and
    result messages)."""
    header = {"version": CODEC_VERSION, "meta": meta, "blob_len": len(blob),
              "blob_crc32": zlib.crc32(blob)}
    return _frame(MESSAGE_MAGIC, header, blob)


def unpack_message(data: bytes) -> Tuple[Dict[str, Any], bytes]:
    """Validate and split a :func:`pack_message` frame -> (meta, blob)."""
    header, payload = _unframe(MESSAGE_MAGIC, data, "message")
    if header.get("version") != CODEC_VERSION:
        raise SchemaError(f"message schema version "
                          f"{header.get('version')!r} != {CODEC_VERSION}")
    n = header.get("blob_len")
    if not isinstance(n, int) or n != len(payload):
        raise CorruptError(f"message payload length {len(payload)} != "
                           f"declared {n!r}")
    if zlib.crc32(payload) != header.get("blob_crc32"):
        raise CorruptError("message payload crc mismatch")
    meta = header.get("meta")
    if not isinstance(meta, dict):
        raise CorruptError("message meta is not an object")
    return meta, payload
