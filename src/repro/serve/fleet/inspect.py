"""Inspect fleet wire artifacts: ``python -m repro.serve.fleet.inspect
<file>`` prints the header, leaf table and byte breakdown of a snapshot
blob (``RMSN``), a fleet message (``RMMS``) or a saved cache-tier file
(``RMCT``) — the debugging aid for the disaggregated wire format.

Deliberately free of jax/model imports: it must work on any artifact a
fleet wrote, anywhere, with nothing but the repo on PYTHONPATH."""
from __future__ import annotations

import argparse
import sys
from typing import List

from repro.serve.fleet.codec import (CACHE_MAGIC, MESSAGE_MAGIC,
                                     SNAPSHOT_MAGIC, _unframe, read_header,
                                     unpack_message)


def _fmt_bytes(n: int) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{n} B"
        n /= 1024
    return f"{n} B"


def _table(rows: List[List[str]], headers: List[str]) -> str:
    rows = [headers] + rows
    widths = [max(len(r[i]) for r in rows) for i in range(len(headers))]
    lines = ["  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip()
             for r in rows]
    lines.insert(1, "  ".join("-" * w for w in widths))
    return "\n".join(lines)


def describe_snapshot(blob: bytes, out=None) -> None:
    out = sys.stdout if out is None else out
    header = read_header(blob)
    leaves = header["leaves"]
    total = sum(int(e["nbytes"]) for e in leaves)
    print(f"snapshot  codec v{header['version']}  "
          f"fingerprint {header['fingerprint']}", file=out)
    print(f"  {len(leaves)} leaves, {_fmt_bytes(total)} payload, "
          f"{_fmt_bytes(len(blob))} framed", file=out)
    rows = [[e["path"], e["dtype"], "x".join(map(str, e["shape"])),
             _fmt_bytes(int(e["nbytes"])),
             "append-only" if e.get("append_only") else ""]
            for e in sorted(leaves, key=lambda e: -int(e["nbytes"]))]
    print(_table(rows, ["leaf", "dtype", "shape", "bytes", "flags"]),
          file=out)


def describe_message(data: bytes, out=None) -> None:
    out = sys.stdout if out is None else out
    meta, blob = unpack_message(data)
    kind = meta.get("kind", "?")
    print(f"message  kind={kind}  meta keys {sorted(meta)}  "
          f"blob {_fmt_bytes(len(blob))}", file=out)
    req = meta.get("request")
    if isinstance(req, dict):
        print(f"  request id={req.get('id')} "
              f"prompt_len={len(req.get('prompt', []))} "
              f"expert_set={req.get('expert_set')!r}", file=out)
    if blob[:4] == SNAPSHOT_MAGIC:
        describe_snapshot(blob, out=out)


def describe_cache_file(data: bytes, out=None) -> None:
    out = sys.stdout if out is None else out
    header, payload = _unframe(CACHE_MAGIC, data, "cache file")
    entries = header.get("entries", [])
    print(f"cache tier  codec v{header.get('version')}  "
          f"fingerprint {header.get('fingerprint')}", file=out)
    print(f"  {len(entries)} entries, {_fmt_bytes(len(payload))} payload",
          file=out)
    per_ns = {}
    rows = []
    for e in entries:
        ns = e.get("ns") or "default"
        per_ns.setdefault(ns, [0, 0])
        per_ns[ns][0] += 1
        per_ns[ns][1] += int(e["nbytes"])
        rows.append([ns, str(len(e.get("tokens", []))),
                     _fmt_bytes(int(e["nbytes"]))])
    print(_table(rows, ["namespace", "prefix_len", "bytes"]), file=out)
    print("per-namespace:", file=out)
    for ns, (n, b) in sorted(per_ns.items()):
        print(f"  {ns}: {n} entries, {_fmt_bytes(b)}", file=out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve.fleet.inspect",
        description="print the header / leaf table / byte breakdown of a "
                    "fleet snapshot, message or cache-tier file")
    ap.add_argument("path", help="artifact to inspect")
    args = ap.parse_args(argv)
    with open(args.path, "rb") as f:
        data = f.read()
    magic = data[:4]
    if magic == SNAPSHOT_MAGIC:
        describe_snapshot(data)
    elif magic == MESSAGE_MAGIC:
        describe_message(data)
    elif magic == CACHE_MAGIC:
        describe_cache_file(data)
    else:
        print(f"unrecognized magic {magic!r} (expected "
              f"{SNAPSHOT_MAGIC!r}, {MESSAGE_MAGIC!r} or {CACHE_MAGIC!r})",
              file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
