"""Fleet workers: the prefill and decode halves of disaggregated serving.

A :class:`PrefillWorker` wraps a full :class:`~repro.serve.engine.
ServeEngine` but only ever runs its ``prefill_to_snapshot`` path: it
consumes **request messages**, prefills the prompt (cache-assisted, so a
shared tier keeps fleets warm), and publishes an **admit message** — the
request meta + first token + the codec-encoded terminal snapshot.  A
:class:`DecodeWorker` wraps another engine (typically on a *different*
ParallelPlan) and admits purely by snapshot transfer
(``admit_from_snapshot``) — it never runs prefill, so its decode lanes
never stall on a prompt.

Everything crossing a worker boundary is ``bytes`` produced by
``fleet/codec.py`` (:func:`~repro.serve.fleet.codec.pack_message`
frames): no live Python object is ever shared between workers, which is
what makes the in-process CI topology an honest rehearsal of the
multi-host one — swapping the transport for sockets changes no worker
code.

Message kinds (the ``meta["kind"]`` field):

  ``request``  router -> prefill: ``{"kind", "request", "t_submit"}``
  ``admit``    prefill -> decode: ``{"kind", "request", "first_token",
               "pos", "t_submit"}`` + encoded snapshot blob
  ``result``   decode -> router: ``{"kind", "result"}``
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional

from repro.serve.engine import Request, RequestResult
from repro.serve.fleet.codec import (SnapshotCodec, pack_message,
                                     unpack_message)
from repro.serve.sampling import SamplingParams
from repro.serve.telemetry import FleetInstruments, MetricsRegistry


class WorkerDrained(RuntimeError):
    """The worker is draining (rolling restart / scale-down) and accepts
    no new work; the router requeues to a peer."""


def request_meta(req: Request) -> Dict[str, Any]:
    """JSON-serializable wire form of a :class:`Request`."""
    return {
        "id": int(req.id),
        "prompt": [int(t) for t in req.prompt],
        "max_new_tokens": int(req.max_new_tokens),
        "sampling": {"temperature": float(req.sampling.temperature),
                     "top_k": int(req.sampling.top_k),
                     "top_p": float(req.sampling.top_p)},
        "eos_id": None if req.eos_id is None else int(req.eos_id),
        "expert_set": req.expert_set,
    }


def request_from_meta(meta: Dict[str, Any]) -> Request:
    sp = meta.get("sampling") or {}
    return Request(
        id=int(meta["id"]), prompt=list(meta["prompt"]),
        max_new_tokens=int(meta.get("max_new_tokens", 16)),
        sampling=SamplingParams(
            temperature=float(sp.get("temperature", 0.0)),
            top_k=int(sp.get("top_k", 0)),
            top_p=float(sp.get("top_p", 1.0))),
        eos_id=meta.get("eos_id"),
        expert_set=meta.get("expert_set"))


def encode_request(req: Request,
                   t_submit: Optional[float] = None) -> bytes:
    """The router->prefill wire message for one request."""
    return pack_message({"kind": "request", "request": request_meta(req),
                         "t_submit": (time.perf_counter()
                                      if t_submit is None else t_submit)})


def encode_result(res: RequestResult) -> bytes:
    return pack_message({"kind": "result",
                         "result": dataclasses.asdict(res)})


def decode_result(msg: bytes) -> RequestResult:
    meta, _ = unpack_message(msg)
    body = dict(meta["result"])
    body["tokens"] = [int(t) for t in body["tokens"]]
    return RequestResult(**body)


class PrefillWorker:
    """One prefill replica: request message in, admit message out."""

    def __init__(self, name: str, engine, codec: SnapshotCodec,
                 registry: Optional[MetricsRegistry] = None):
        self.name = name
        self.engine = engine
        self.codec = codec
        self.drained = False
        self._m = FleetInstruments(registry if registry is not None
                                   else engine.telemetry.registry)
        self._served = 0
        # threaded fleets may route two requests to one replica
        # concurrently; the engine is not reentrant, the worker is
        self._lock = threading.Lock()

    def drain(self) -> None:
        """Stop accepting work (the engine stays intact — a drained
        worker can be undrained after a topology change)."""
        self.drained = True

    def cached_len(self, prompt, ns=None) -> int:
        """Router affinity signal: how much of this prompt the worker's
        cache (incl. an attached shared tier) can skip."""
        cache = self.engine.cache
        return cache.peek_len(prompt, ns=ns) if cache is not None else 0

    @property
    def load(self) -> int:
        return self._served

    def process(self, request_msg: bytes) -> bytes:
        """Prefill one request message into an admit message."""
        if self.drained:
            raise WorkerDrained(f"prefill worker {self.name} is draining")
        meta, _ = unpack_message(request_msg)
        req = request_from_meta(meta["request"])
        with self._lock:
            first_tok, snap = self.engine.prefill_to_snapshot(req)
        blob = self.codec.encode(snap)
        self._served += 1
        self._m.prefills.inc()
        self._m.snapshots_out.inc()
        out = pack_message({"kind": "admit", "request": meta["request"],
                            "first_token": int(first_tok),
                            "pos": len(req.prompt),
                            "t_submit": meta.get("t_submit")}, blob)
        self._m.snapshot_bytes.inc(len(out))
        return out


class DecodeWorker:
    """One decode replica: admit messages in, result messages out.

    Admission is strictly a snapshot transfer; the wrapped engine's
    prefill path is never exercised (the engine still *has* one — a
    decode worker is an ordinary engine playing a role, which is what
    lets a fleet degrade to monolithic serving by re-roling replicas)."""

    def __init__(self, name: str, engine, codec: SnapshotCodec,
                 registry: Optional[MetricsRegistry] = None):
        self.name = name
        self.engine = engine
        self.codec = codec
        self.drained = False
        self._m = FleetInstruments(registry if registry is not None
                                   else engine.telemetry.registry)

    def drain(self) -> None:
        self.drained = True

    @property
    def load(self) -> int:
        """Live decode lanes (the router's least-loaded signal)."""
        return sum(1 for l in self.engine._lanes if l is not None)

    def bound_sets(self) -> List[str]:
        """Expert sets currently bound on this replica's engine (router
        affinity: admitting a request to a replica already serving its
        set avoids an expert swap)."""
        lib = self.engine.library
        return list(self.engine._bound) if lib is not None else []

    def try_admit(self, admit_msg: bytes) -> bool:
        """Decode + restore one admit message; False when the engine has
        no capacity right now (the router requeues and keeps stepping
        this worker until lanes retire)."""
        if self.drained:
            raise WorkerDrained(f"decode worker {self.name} is draining")
        t0 = time.perf_counter()
        meta, blob = unpack_message(admit_msg)
        snap = self.codec.decode(blob)
        req = request_from_meta(meta["request"])
        ok = self.engine.admit_from_snapshot(
            req, snap, int(meta["first_token"]),
            t_submit=meta.get("t_submit"))
        if ok:
            self._m.admits.inc()
            self._m.snapshot_bytes.inc(len(admit_msg))
            self._m.transfer_s.observe(time.perf_counter() - t0)
        else:
            self._m.admit_rejects.inc()
        return ok

    def busy(self) -> bool:
        return self.engine.busy()

    def step(self) -> List[bytes]:
        """One engine tick; finished requests come back as serialized
        result messages (the router never touches a RequestResult this
        worker created — results cross the boundary as bytes too)."""
        if not self.engine.busy():
            return []
        out = []
        for res in self.engine.tick():
            self._m.results.inc()
            out.append(encode_result(res))
        return out
