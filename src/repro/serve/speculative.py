"""Self-speculative decoding over the slot-state store.

The draft model is the serving model itself with blocks skipped: a
layer-skip stride over the existing ``Mixer`` stack
(:func:`repro.models.lm.draft_layers`) keeps every ``draft_stride``-th
block and passes the residual stream through the rest.  Because the draft
reuses each mixer's declared ``state_spec``, the draft state is just the
(functional) slot state the engine already holds — no second model, no
second store.

One speculative round per engine tick, all inside a single jitted dispatch
(:func:`make_spec_fn` builds it):

  1. **Draft**: a ``lax.scan`` of K layer-skip decode steps proposes
     ``d_1..d_K`` per slot, sampled with each slot's own sampling params
     (greedy slots propose argmax).  The draft's state updates are
     discarded — drafting never touches the committed slot state.
  2. **Verify**: a ``lax.scan`` of K+1 *full-model* decode steps consumes
     ``[last, d_1..d_K]`` at per-slot positions, emitting the target
     logits for every window position *and a state snapshot per depth* —
     but only for leaves that actually need one.  Leaves a mixer declares
     ``append_only`` on its :class:`~repro.serve.state.StateSpec`
     (attention K/V/kpos without a sliding window) are position-keyed
     caches whose rollback is free: rejected-draft entries sit at future
     positions, are causally masked until decode reaches them, and are
     then overwritten — so the verify scan stacks only the recurrent
     leaves (constant-size per slot) and the KV caches ride through from
     the final verify step uncopied.  The stacked recurrent subset is the
     multi-snapshot gather the StateStore's :func:`~repro.serve.state.
     select_window` consumes.
  3. **Accept**: :func:`repro.serve.sampling.spec_accept` takes the longest
     agreeing prefix per slot — exact argmax agreement for greedy slots,
     rejection sampling for temperature slots (unbiased under top-k/top-p
     because both distributions are filtered identically).
  4. **Commit**: the snapshot at each slot's accepted depth becomes the new
     slot state (``select_window`` over the recurrent subset, recombined
     with the final verify state's cache leaves).  Rollback is free:
     rejected depths are simply never adopted.  RoM/SSM mixers make the
     snapshots cheap — the recurrent state is constant-size per slot (the
     paper's headline inference property), so a K-deep window costs K
     small copies; hybrid patterns with non-windowed ``attn`` blocks pay
     nothing extra for the KV cache (append-only classification), and
     only sliding-window attention still replicates its cache per depth.

Slots at different accepted depths advance together: the engine applies
``n_emit[b]`` in [1, K+1] tokens to slot ``b`` from one dispatch, so its
position/eviction bookkeeping runs per emitted token (EOS or max-len inside
the window truncates emission and retires the slot; the committed state for
a retired slot is never read again).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.serve.sampling import sample, spec_accept
from repro.serve.state import select_window


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs.

    k: draft tokens proposed per round; each round emits 1..k+1 tokens per
       slot in a single dispatch (k=0 disables speculation in the engine).
    draft_stride: block stride of the layer-skip draft — the draft runs
       blocks whose global index is a multiple of this (1 = full model,
       i.e. the draft always agrees and every round emits k+1 tokens).
    """
    k: int = 4
    draft_stride: int = 2


def make_spec_fn(cfg, plan, spec: SpecConfig, axes, append_only=None):
    """Build the one-dispatch speculative round.

    Returns ``spec_fn(params, state, last, pos, rng, temp, topk, topp[,
    sets]) -> (tokens (B,K+1) i32, n_emit (B,) i32, new_state)`` where
    ``state`` is
    the engine's full B-slot decode state, ``last`` (B,) the slots' last
    sampled tokens, ``pos`` (B,) their per-slot positions, and
    temp/topk/topp the per-slot sampling params.  ``plan`` is the
    engine's :class:`~repro.distributed.plan.ParallelPlan` — its shard
    context threads the mesh/rules through draft and verify steps, so
    slot-partitioned state stays on its shards across the scans.  ``axes``
    is the store's per-leaf slot-axis pytree (``StateStore.axes``) used to
    select each slot's accepted-depth snapshot; ``append_only`` the
    matching bool pytree (``StateStore.append_only``) marking leaves whose
    per-depth snapshot is skipped — they are taken from the final verify
    step instead (rollback via position masking).  ``append_only=None``
    snapshots every leaf (the pre-classification behaviour).
    """
    shard_ctx = plan.shard_ctx()
    keep = lm.draft_layers(cfg, spec.draft_stride)
    K = spec.k
    if K < 1:
        raise ValueError(f"speculative k must be >= 1, got {K}")
    ax_leaves = jax.tree_util.tree_leaves(axes)
    ao_leaves = (jax.tree_util.tree_leaves(append_only)
                 if append_only is not None else [False] * len(ax_leaves))
    # leaf indices (in canonical tree_leaves order, shared by state/axes/
    # append_only — all three have identical structure) that need a
    # per-depth snapshot in the verify scan
    rec_idx = tuple(i for i, ao in enumerate(ao_leaves) if not ao)
    rec_axes = tuple(ax_leaves[i] for i in rec_idx)

    def spec_fn(params, state, last, pos, rng, temp, topk, topp, sets=None):
        # ``sets`` (B,) int32: per-slot expert-set binding rows when the
        # engine serves through an ExpertLibrary (params then carry
        # per-set tuple expert leaves); None otherwise
        rt = lm.Runtime(shard=shard_ctx, rng=None, train=False,
                        expert_sets=sets)
        pos = jnp.asarray(pos, jnp.int32)
        last = jnp.asarray(last, jnp.int32)

        def draft_body(carry, j):
            st, tok = carry
            logits, st = lm.decode_step(params, st, tok[:, None], pos + j,
                                        cfg, rt, keep=keep)
            d = sample(logits, jax.random.fold_in(rng, j), temp, topk, topp)
            return (st, d), (d, logits)

        (_, _), (d_toks, d_logits) = jax.lax.scan(
            draft_body, (state, last), jnp.arange(K))
        # d_toks (K,B); d_logits (K,B,V); draft state dropped (never adopted)

        def verify_body(st, xs):
            tok, j = xs
            logits, st = lm.decode_step(params, st, tok[:, None], pos + j,
                                        cfg, rt)
            leaves = jax.tree_util.tree_leaves(st)
            return st, (logits, tuple(leaves[i] for i in rec_idx))

        v_in = jnp.concatenate([last[None, :], d_toks], axis=0)   # (K+1,B)
        final, (t_logits, snaps) = jax.lax.scan(
            verify_body, state, (v_in, jnp.arange(K + 1)))
        # t_logits (K+1,B,V); snaps = per-depth snapshots of the recurrent
        # leaves only (window axis leading each) — the multi-snapshot gather
        # select_window eats; append-only cache leaves skip the stack and
        # ride through in ``final``

        toks, n_emit = spec_accept(
            jnp.moveaxis(t_logits, 0, 1), jnp.moveaxis(d_logits, 0, 1),
            d_toks.T, jax.random.fold_in(rng, K + 1), temp, topk, topp)
        sel = select_window(snaps, rec_axes, n_emit - 1)
        leaves = list(jax.tree_util.tree_leaves(final))
        for i, leaf in zip(rec_idx, sel):
            leaves[i] = leaf
        new_state = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(state), leaves)
        return toks, n_emit, new_state

    return spec_fn
