"""Radix-tree prefix cache over the StateStore: skip prefill for shared
prompt prefixes.

Mamba/RoM decode state is **constant-size per slot** (the paper's headline
inference property), so caching the model state at a token-prefix boundary
costs O(1) bytes per entry regardless of prefix length — prefix caching is
*cheaper* for SSMs than the transformer KV-cache schemes it is modeled on
(hybrid patterns additionally snapshot their fixed-size KV cache + kpos
leaves, so restore stays exact for every mixer).

Structure: a radix tree over token-id sequences.  Each edge is labeled with
a token run; a node represents the prompt prefix spelled by the path from
the root and *may* hold a snapshot — a host-side copy (``snapshot_slots``)
of the full decode-state pytree captured at a prefill **chunk boundary**.
Chunk-boundary capture is what makes restore exact: the engine's prefill is
bit-compatible across chunk decompositions (property-tested per mixer), so
restoring a boundary snapshot and prefilling only the uncached suffix
yields bit-identical greedy output to a cold prefill.

Admission flow (wired through ``ServeEngine``):

  * lookup the longest cached prefix of an incoming prompt (capped at
    ``len(prompt) - 1`` — the last prompt token must be prefilled to
    produce the first-token logits);
  * restore the snapshot into the prefill lane via ``insert_slots`` and
    prefill only the suffix, starting at the cached position;
  * as prefill crosses chunk boundaries, publish new snapshots back into
    the tree (deduplicated: a boundary already in the tree is only
    LRU-touched, never re-copied from device).

Eviction is byte-budgeted LRU over snapshots: ``state_nbytes`` accounts
every leaf of a snapshot, and inserting past ``budget_bytes`` evicts the
least-recently-used snapshots until the tree fits.  Evicting a snapshot
prunes/merges now-redundant radix nodes, so the tree stays compact.

The cache is deliberately model-agnostic — it maps token tuples to host
pytrees and never inspects leaves beyond byte accounting — so one
implementation serves every mixer pattern.  Snapshots are only shape-valid
for the (cfg, max_len, dtype) they were captured under: use one cache per
engine configuration.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.serve.state import state_nbytes
from repro.serve.telemetry import MetricsRegistry

#: legacy ``PrefixCache.stats`` key -> (registry counter name, help)
_STAT_COUNTERS = {
    "hits": ("cache_hits_total", "lookups that restored a snapshot"),
    "misses": ("cache_misses_total", "lookups with no cached prefix"),
    "hit_tokens": ("cache_hit_tokens_total",
                   "prefix tokens served from snapshots"),
    "lookup_tokens": ("cache_lookup_tokens_total",
                      "prompt tokens presented to lookup()"),
    "inserts": ("cache_inserts_total", "new boundary snapshots stored"),
    "dedup_skips": ("cache_dedup_skips_total",
                    "inserts skipped because the prefix was cached"),
    "evictions": ("cache_evictions_total", "snapshots evicted (LRU)"),
    "oversize": ("cache_oversize_total",
                 "snapshots refused: larger than the whole budget"),
    "grain_skips": ("cache_grain_skips_total",
                    "boundaries refused by grain alignment"),
}


@dataclasses.dataclass(eq=False)      # identity hash: nodes live in sets
class _Node:
    """One radix-tree node: ``edge`` labels the path from the parent; the
    node spells the prefix of length ``depth``; ``snap`` (if any) is the
    host-side decode-state snapshot for exactly that prefix."""
    edge: Tuple[int, ...]
    depth: int
    parent: Optional["_Node"]
    children: Dict[int, "_Node"] = dataclasses.field(default_factory=dict)
    snap: Any = None
    nbytes: int = 0
    used: int = 0                       # LRU clock value of the last touch


def _common_len(a: Sequence[int], b: Sequence[int]) -> int:
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return i
    return n


class PrefixCache:
    """Byte-budgeted radix-tree prefix cache of decode-state snapshots.

    budget_mb: snapshot byte budget; inserting past it evicts LRU
        snapshots (a single snapshot larger than the whole budget is
        refused and counted in ``stats['oversize']``).
    min_tokens: shortest prefix worth publishing (boundaries below it are
        not captured — they save too little prefill to pay the transfer).
    capture: master switch for publishing new snapshots; lookups still
        serve hits when False (a frozen, pre-warmed cache).
    grain: snapshot alignment — only prefix lengths that are multiples of
        ``grain`` are published (refusals counted in
        ``stats['grain_skips']``), bounding the radix tree to
        O(prompt/grain) nodes per distinct prompt instead of one per
        chunk boundary.  ``grain=1`` (default) keeps every boundary.
        Restores are unaffected: admission still resumes prefill from the
        deepest published multiple.

    Snapshots are host-side numpy and therefore **topology-portable**: a
    store under any :class:`~repro.distributed.plan.ParallelPlan` gathers
    the per-shard device slices on capture (``StateStore.snapshot_rows``)
    and re-places restored rows onto the plan's shards
    (``StateStore.restore_rows``), so one warm cache serves engines on
    different meshes of the same (cfg, max_len, dtype).
    """

    def __init__(self, budget_mb: float = 64.0, min_tokens: int = 1,
                 capture: bool = True, grain: int = 1,
                 registry: Optional[MetricsRegistry] = None):
        if budget_mb <= 0:
            raise ValueError(f"budget_mb must be > 0, got {budget_mb}")
        if grain < 1:
            raise ValueError(f"grain must be >= 1, got {grain}")
        self.budget_bytes = int(budget_mb * (1 << 20))
        self.min_tokens = min_tokens
        self.capture = capture
        self.grain = grain
        self._root = _Node(edge=(), depth=0, parent=None)
        # namespaces (``ns=`` on queries/updates): decode-state snapshots
        # depend on the weights that produced them, so a multi-tenant
        # engine (serve/expert_library.py) keys each request's prefixes by
        # its expert-set name — one radix tree per namespace, sharing this
        # cache's byte budget, LRU clock, stats and version.  ``ns=None``
        # (the default, and every non-library engine) is the original root.
        self._ns_roots: Dict[Any, _Node] = {}
        self._snaps: set = set()        # nodes currently holding a snapshot
        self._bytes = 0
        self._clock = 0
        #: bumped on every snapshot attach/evict; rankings derived from the
        #: tree (CachedSuffixFirst's peek memo) are valid while it holds
        self.version = 0
        # telemetry: counters back the legacy ``stats`` dict (a derived
        # view); pass ``registry=`` to report into a shared serving-stack
        # registry (one cache per shared registry — instrument names are
        # not namespaced per instance), default is a private one.  The
        # registry is cumulative; window it with snapshot()/delta().
        self.registry = (registry if registry is not None
                         else MetricsRegistry())
        self._m = {key: self.registry.counter(name, help)
                   for key, (name, help) in _STAT_COUNTERS.items()}
        self._g_bytes = self.registry.gauge(
            "cache_bytes_used", "bytes of snapshots currently held")
        self._g_snaps = self.registry.gauge(
            "cache_snapshots", "snapshots currently held")
        # per-namespace gauges (multi-tenant operators see node/byte
        # counts per expert-set namespace, not just the aggregate);
        # created lazily as namespaces appear, refreshed on every
        # insert/evict — the radix trees are small, a full walk is cheap
        self._ns_gauges: Dict[str, Any] = {}
        # optional shared tier (fleet serving): a second, process-shareable
        # store of *encoded* snapshots this cache falls through to on
        # local misses and publishes fresh boundaries into
        self._tier = None
        self._tier_codec = None

    def attach_tier(self, tier, codec) -> None:
        """Attach a :class:`~repro.serve.fleet.cache_tier.SharedCacheTier`.

        ``codec`` (a :class:`~repro.serve.fleet.codec.SnapshotCodec`)
        translates between this cache's live host pytrees and the tier's
        validated blobs; its fingerprint is what keeps a shared tier from
        ever serving a snapshot across incompatible engine configs.
        Afterwards: ``lookup``/``peek_len`` consult the tier past the
        local radix tree (tier hits decode + promote into the tree) and
        ``insert`` publishes every newly stored boundary back."""
        self._tier = tier
        self._tier_codec = codec

    @property
    def stats(self) -> Dict[str, int]:
        """Legacy counters view, derived from the telemetry registry
        (cumulative over the cache's lifetime; all zeros when the shared
        registry is disabled)."""
        return {key: int(self.registry.value(name))
                for key, (name, _) in _STAT_COUNTERS.items()}

    # ------------------------------------------------------------- queries

    def __len__(self) -> int:
        return len(self._snaps)

    @property
    def bytes_used(self) -> int:
        return self._bytes

    def _root_for(self, ns) -> _Node:
        if ns is None:
            return self._root
        root = self._ns_roots.get(ns)
        if root is None:
            root = self._ns_roots[ns] = _Node(edge=(), depth=0, parent=None)
        return root

    def _walk_best(self, tokens: Sequence[int], cap: int,
                   ns=None) -> Optional[_Node]:
        """Deepest snapshot-holding node spelling a prefix of ``tokens``
        no longer than ``cap``; None on a total miss."""
        node, best, i = self._root_for(ns), None, 0
        while True:
            if node.snap is not None and node.depth <= cap:
                best = node
            if node.depth > cap or i >= len(tokens):
                return best
            nxt = node.children.get(tokens[i])
            if nxt is None:
                return best
            m = _common_len(tokens[i:], nxt.edge)
            if m < len(nxt.edge):
                return best             # diverged mid-edge
            i += m
            node = nxt

    def peek_len(self, tokens: Sequence[int], ns=None) -> int:
        """Longest cached-prefix length for this prompt, side-effect free
        (no LRU touch, no stats) — for schedulers and admission grouping.
        With a tier attached this includes tier-only prefixes: admission
        groups by the length a subsequent :meth:`lookup` will actually
        restore, wherever the snapshot currently lives."""
        cap = max(len(tokens) - 1, 0)
        best = self._walk_best(tokens, cap, ns)
        local = best.depth if best is not None else 0
        if self._tier is not None:
            return max(local, self._tier.peek_len(tokens, cap, ns=ns))
        return local

    def lookup(self, tokens: Sequence[int], ns=None) -> Tuple[int, Any]:
        """Longest cached prefix strictly shorter than the prompt:
        ``(prefix_len, snapshot)``, or ``(0, None)`` on a miss.  Touches
        LRU and records hit/miss stats — call once per admitted request.

        With a tier attached, a local miss (or a shorter local hit) falls
        through: the tier's longest stored prefix is decoded and promoted
        into the local radix tree, so the next lookup is a pure local
        hit.  Tier decode failures never mis-restore — a corrupt or
        mismatched blob raises out of the codec."""
        cap = max(len(tokens) - 1, 0)
        self._m["lookup_tokens"].inc(len(tokens))
        best = self._walk_best(tokens, cap, ns)
        local = best.depth if best is not None else 0
        if self._tier is not None and \
                self._tier.peek_len(tokens, cap, ns=ns) > local:
            depth, blob = self._tier.longest_prefix(tokens, cap, ns=ns)
            if blob is not None:        # racy tier: entry may have evicted
                snap = self._tier_codec.decode(blob)
                self.adopt_snapshot(tuple(tokens[:depth]), snap, ns=ns)
                self._m["hits"].inc()
                self._m["hit_tokens"].inc(depth)
                return depth, snap
        if best is None:
            self._m["misses"].inc()
            return 0, None
        self._clock += 1
        best.used = self._clock
        self._m["hits"].inc()
        self._m["hit_tokens"].inc(best.depth)
        return best.depth, best.snap

    def contains(self, tokens: Sequence[int], ns=None) -> bool:
        """True iff exactly this prefix holds a snapshot."""
        best = self._walk_best(tokens, len(tokens), ns)
        return best is not None and best.depth == len(tokens)

    # ------------------------------------------------------------- updates

    def wants(self, tokens: Sequence[int]) -> bool:
        """Would :meth:`insert` publish this prefix (capture / min_tokens
        / grain gates; dedup aside)?  Grain refusals are counted here
        (``stats['grain_skips']``), so engines that pre-filter boundaries
        with ``wants`` — to keep refused boundaries off the batched
        device->host transfer — keep the counter consistent with calling
        ``insert`` directly."""
        if not self.capture or len(tokens) < self.min_tokens:
            return False
        if len(tokens) % self.grain != 0:
            self._m["grain_skips"].inc()
            return False
        return True

    def insert(self, tokens: Sequence[int],
               snap_fn: Callable[[], Any], ns=None) -> bool:
        """Publish a boundary snapshot for ``tokens``.

        ``snap_fn`` produces the host-side snapshot and is only called if
        the prefix is new (dedup keeps device->host copies off the hot
        path for already-cached prefixes, which are LRU-touched instead).
        Returns True iff a new snapshot was stored.
        """
        if not self.wants(tokens):
            return False
        node = self._ensure_node(tuple(tokens), self._root_for(ns))
        self._clock += 1
        node.used = self._clock
        if node.snap is not None:
            self._m["dedup_skips"].inc()
            return False
        snap = snap_fn()
        nbytes = state_nbytes(snap)
        if nbytes > self.budget_bytes:
            self._m["oversize"].inc()
            self._prune(node)
            return False
        node.snap, node.nbytes = snap, nbytes
        self._snaps.add(node)
        self._bytes += nbytes
        self.version += 1
        self._m["inserts"].inc()
        self._evict_to_budget(keep=node)
        self._g_bytes.set(self._bytes)
        self._g_snaps.set(len(self._snaps))
        self._refresh_ns_gauges()
        if self._tier is not None:
            # publish the fresh boundary fleet-wide (encoded through the
            # codec — the tier never holds a live Python object)
            self._tier.put(tuple(tokens), self._tier_codec.encode(snap),
                           ns=ns)
        return True

    def adopt_snapshot(self, tokens: Sequence[int], snap, ns=None) -> bool:
        """Store an *externally produced* snapshot (a tier promotion or a
        persistence load): bypasses the capture/min_tokens/grain gates —
        the publishing cache already applied its own — and never
        republishes to the tier (the entry came from there).  True iff
        newly stored locally."""
        node = self._ensure_node(tuple(tokens), self._root_for(ns))
        self._clock += 1
        node.used = self._clock
        if node.snap is not None:
            return False
        nbytes = state_nbytes(snap)
        if nbytes > self.budget_bytes:
            self._m["oversize"].inc()
            self._prune(node)
            return False
        node.snap, node.nbytes = snap, nbytes
        self._snaps.add(node)
        self._bytes += nbytes
        self.version += 1
        self._evict_to_budget(keep=node)
        self._g_bytes.set(self._bytes)
        self._g_snaps.set(len(self._snaps))
        self._refresh_ns_gauges()
        return True

    def _ensure_node(self, tokens: Tuple[int, ...],
                     root: Optional[_Node] = None) -> _Node:
        """Find-or-create the node spelling ``tokens``, splitting edges."""
        node, i = (root if root is not None else self._root), 0
        while i < len(tokens):
            nxt = node.children.get(tokens[i])
            if nxt is None:
                child = _Node(edge=tokens[i:], depth=len(tokens),
                              parent=node)
                node.children[tokens[i]] = child
                return child
            m = _common_len(tokens[i:], nxt.edge)
            if m == len(nxt.edge):
                node, i = nxt, i + m
                continue
            # split nxt's edge at m: node -> mid -> nxt
            mid = _Node(edge=nxt.edge[:m], depth=nxt.depth - len(nxt.edge)
                        + m, parent=node, children={nxt.edge[m]: nxt})
            nxt.edge = nxt.edge[m:]
            nxt.parent = mid
            node.children[tokens[i]] = mid
            node, i = mid, i + m
        return node

    def _evict_to_budget(self, keep: Optional[_Node] = None) -> None:
        while self._bytes > self.budget_bytes and self._snaps:
            victims = self._snaps - {keep} if keep in self._snaps \
                else self._snaps
            if not victims:
                return
            self._evict(min(victims, key=lambda n: n.used))

    def _evict(self, node: _Node) -> None:
        self._bytes -= node.nbytes
        node.snap, node.nbytes = None, 0
        self._snaps.discard(node)
        self.version += 1
        self._m["evictions"].inc()
        self._g_bytes.set(self._bytes)
        self._g_snaps.set(len(self._snaps))
        self._prune(node)
        self._refresh_ns_gauges()

    def _prune(self, node: _Node) -> None:
        """Drop snapshot-less leaf chains and merge pass-through nodes so
        the tree stays a proper radix tree after eviction."""
        while (node.parent is not None and node.snap is None
               and not node.children):
            parent = node.parent
            del parent.children[node.edge[0]]
            node = parent
        if (node.parent is not None and node.snap is None
                and len(node.children) == 1):
            (child,) = node.children.values()
            child.edge = node.edge + child.edge
            child.parent = node.parent
            node.parent.children[node.edge[0]] = child

    # ------------------------------------------------------------- reports

    def _ns_stats(self, ns) -> Dict[str, int]:
        """One namespace tree's node / snapshot / byte counts (root node
        excluded from the node count — it spells the empty prefix)."""
        row = {"nodes": 0, "snapshots": 0, "bytes_used": 0}

        def rec(node):
            if node.parent is not None:
                row["nodes"] += 1
            if node.snap is not None:
                row["snapshots"] += 1
                row["bytes_used"] += node.nbytes
            for c in node.children.values():
                rec(c)

        rec(self._root_for(ns))
        return row

    def per_namespace(self) -> Dict[str, Dict[str, int]]:
        """Per-namespace node/snapshot/byte counts (the ``ns=None`` root
        reports as ``"default"``) — multi-tenant operators see where the
        budget actually sits, not just the aggregate."""
        return {("default" if ns is None else str(ns)): self._ns_stats(ns)
                for ns in self.namespaces()}

    def _refresh_ns_gauges(self) -> None:
        for key, row in self.per_namespace().items():
            gauges = self._ns_gauges.get(key)
            if gauges is None:
                safe = "".join(ch if ch.isalnum() or ch == "_" else "_"
                               for ch in key)
                gauges = self._ns_gauges[key] = (
                    self.registry.gauge(
                        f"cache_ns_snapshots_{safe}",
                        f"snapshots held in cache namespace {key!r}"),
                    self.registry.gauge(
                        f"cache_ns_bytes_used_{safe}",
                        f"snapshot bytes held in cache namespace {key!r}"))
            gauges[0].set(row["snapshots"])
            gauges[1].set(row["bytes_used"])

    def summary(self) -> Dict[str, Any]:
        """Derived stats: ``hit_rate`` over lookups, ``token_hit_rate``
        (cached prefix tokens / prompt tokens looked up), byte usage,
        plus ``per_namespace`` node/byte counts."""
        s = self.stats
        lookups = s["hits"] + s["misses"]
        return {
            "snapshots": len(self),
            "bytes_used": self._bytes,
            "budget_bytes": self.budget_bytes,
            "grain": self.grain,
            "namespaces": 1 + len(self._ns_roots),
            "per_namespace": self.per_namespace(),
            "hit_rate": s["hits"] / max(lookups, 1),
            "token_hit_rate": s["hit_tokens"] / max(s["lookup_tokens"], 1),
            **s,
        }

    def namespaces(self) -> List[Any]:
        """Every namespace key with a tree (``None`` first — the default
        root always exists, even when empty)."""
        return [None] + list(self._ns_roots)

    # introspection used by tests: every (prefix, nbytes) currently held
    # in one namespace's tree (default: the ``ns=None`` root)
    def snapshot_prefixes(self, ns=None) -> List[Tuple[Tuple[int, ...], int]]:
        return [(p, state_nbytes(s)) for p, s in self.snapshot_items(ns)]

    def snapshot_items(self, ns=None) -> List[Tuple[Tuple[int, ...], Any]]:
        """Every (prefix, snapshot) currently held in one namespace's
        tree, sorted by prefix — the persistence walk."""
        out = []

        def rec(node, prefix):
            prefix = prefix + node.edge
            if node.snap is not None:
                out.append((prefix, node.snap))
            for c in node.children.values():
                rec(c, prefix)

        rec(self._root_for(ns), ())
        return sorted(out, key=lambda kv: kv[0])
