"""Telemetry for the serving stack: one metrics registry, one per-request
trace timeline, and the exporters the serving tools ship them through.

Two complementary views of the same engine:

  * **Metrics** (:class:`MetricsRegistry`) answer *how much / how fast in
    aggregate*: typed :class:`Counter` / :class:`Gauge` /
    :class:`Histogram` instruments registered by name.  Histograms use
    fixed log-spaced latency buckets (:data:`LATENCY_BUCKETS_S`) so two
    snapshots are always mergeable/diffable bucket-by-bucket.  The
    registry is **cumulative** for its lifetime; windowed readings are
    derived, never destructive: ``snapshot()`` captures the current
    values and ``delta(prev)`` subtracts a previous snapshot (counters
    and histogram buckets subtract; gauges and min/max are
    point-in-time and pass through).  That is the contract
    ``ServeEngine.reset_stats()`` and the benchmark timed iterations are
    built on — nothing ever zeroes the registry.
  * **Traces** (:class:`Tracer`) answer *what happened to request 17*:
    every request owns a timeline of spans — ``request`` (root) ⊃
    ``queued`` → ``admitted`` (cache-restore hit length + namespace) →
    ``prefill_chunk``* → ``decode``/``spec_round``* → terminal
    ``finish`` — with monotonic ``time.perf_counter`` timestamps and
    parent/child nesting.  Finished timelines are kept in a bounded
    deque (``max_traces``) so a long-running server never grows without
    bound.

Both are **host-side only**: no instrument or span ever enters jitted
computation, which is why greedy decode tokens are bit-identical with
telemetry enabled or disabled (tested in tests/test_telemetry.py).
Disabled instruments (``MetricsRegistry(enabled=False)``) are shared
no-op singletons — a disabled registry costs one attribute load and a
no-op call per instrumentation site.

Exporters:

  * ``registry.snapshot()`` / ``registry.delta(prev)`` — structured
    JSON-ready dicts (what ``--metrics-out`` writes).
  * ``registry.to_prometheus()`` — Prometheus text exposition format
    (counter/gauge/histogram with cumulative ``_bucket{le=...}`` lines).
  * ``tracer.chrome_trace()`` — Chrome ``trace_event`` JSON: one trace
    thread per request, one complete (``"ph": "X"``) event per span —
    load the file in Perfetto (https://ui.perfetto.dev) or
    ``chrome://tracing``.
  * :meth:`Telemetry.annotate` — opt-in ``jax.profiler.TraceAnnotation``
    context around the engine's jitted dispatches, so a
    ``jax.profiler`` capture (``--trace-dir``) shows named
    decode/mixed/spec/prefill regions on the host timeline.

See docs/observability.md for the full reference.
"""
from __future__ import annotations

import bisect
import contextlib
import time
from collections import OrderedDict, deque
from typing import Any, Dict, List, Optional, Sequence, Tuple


def log_buckets(lo: float, hi: float, per_decade: int = 3) -> Tuple[float, ...]:
    """Fixed log-spaced histogram boundaries from ``lo`` to >= ``hi``
    with ``per_decade`` buckets per decade.  Deterministic for given
    arguments, so snapshots taken by different processes line up."""
    if lo <= 0 or hi <= lo:
        raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
    out, k = [], 0
    while True:
        b = lo * 10.0 ** (k / per_decade)
        out.append(float(f"{b:.6g}"))            # stable repr across runs
        if b >= hi:
            return tuple(out)
        k += 1


#: The default latency buckets: 10 microseconds to 100 seconds, three per
#: decade (22 finite buckets + the implicit +Inf).  Fixed — every latency
#: histogram in the serving stack shares them, so cross-metric and
#: cross-run bucket arithmetic is always aligned.
LATENCY_BUCKETS_S = log_buckets(1e-5, 100.0, per_decade=3)


class Counter:
    """Monotonic counter.  ``inc`` with ints keeps the value an int
    (token/step counts); float increments make it a float (seconds)."""
    __slots__ = ("name", "help", "value")
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0

    def inc(self, v=1) -> None:
        self.value += v

    def snap(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Point-in-time value (queue depth, live slots, resident bytes)."""
    __slots__ = ("name", "help", "value")
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def inc(self, v=1) -> None:
        self.value += v

    def snap(self) -> dict:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket histogram (log-spaced latency buckets by default).

    ``counts[i]`` counts observations <= ``buckets[i]`` and > the
    previous boundary; ``counts[-1]`` is the +Inf overflow bucket.  Also
    tracks count/sum (means) and lifetime min/max (quantile clamping)."""
    __slots__ = ("name", "help", "buckets", "counts", "count", "sum",
                 "min", "max")
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = LATENCY_BUCKETS_S):
        self.name, self.help = name, help
        self.buckets = tuple(buckets)
        self.counts = [0] * (len(self.buckets) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.count += 1
        self.sum += v
        if self.min is None or v < self.min:
            self.min = v
        if self.max is None or v > self.max:
            self.max = v

    def snap(self) -> dict:
        return {"type": "histogram", "buckets": list(self.buckets),
                "counts": list(self.counts), "count": self.count,
                "sum": self.sum, "min": self.min, "max": self.max}


class _Null:
    """Shared no-op instrument: what a disabled registry hands out."""
    __slots__ = ()

    def inc(self, v=1):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass


_NULL = _Null()
_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Named, typed instruments with snapshot/delta and exporters.

    Instrument getters are find-or-create and idempotent: asking twice
    for the same name returns the same instrument (asking with a
    different kind raises).  ``enabled=False`` makes every getter return
    a shared no-op — the cheap-off switch for code instrumented
    unconditionally.  The registry itself is cumulative; see the module
    docstring for the snapshot/delta windowing contract."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._instruments: "OrderedDict[str, Any]" = OrderedDict()

    def _get(self, kind: str, name: str, help: str, **kw):
        if not self.enabled:
            return _NULL
        inst = self._instruments.get(name)
        if inst is None:
            inst = self._instruments[name] = _KINDS[kind](name, help, **kw)
        elif inst.kind != kind:
            raise ValueError(f"instrument {name!r} already registered as "
                             f"{inst.kind}, not {kind}")
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get("counter", name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get("gauge", name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = LATENCY_BUCKETS_S) -> Histogram:
        return self._get("histogram", name, help, buckets=buckets)

    def value(self, name: str, default=0):
        """Current scalar value of a counter/gauge (0 when absent or
        disabled) — how compatibility ``stats`` views read the registry."""
        inst = self._instruments.get(name)
        return default if inst is None else inst.value

    # ---------------------------------------------------------- snapshots

    def snapshot(self) -> Dict[str, dict]:
        """JSON-ready cumulative state of every instrument."""
        return {name: inst.snap()
                for name, inst in self._instruments.items()}

    def delta(self, prev: Dict[str, dict]) -> Dict[str, dict]:
        """Current snapshot minus ``prev``: counters and histogram
        counts/count/sum subtract; gauges (point-in-time) and histogram
        min/max (lifetime extremes) pass through from the current state.
        Instruments born after ``prev`` delta against zero."""
        out = {}
        for name, cur in self.snapshot().items():
            p = prev.get(name)
            if p is None or cur["type"] == "gauge":
                out[name] = cur
            elif cur["type"] == "counter":
                out[name] = {"type": "counter",
                             "value": cur["value"] - p["value"]}
            else:
                out[name] = {
                    "type": "histogram", "buckets": cur["buckets"],
                    "counts": [c - q for c, q in zip(cur["counts"],
                                                     p["counts"])],
                    "count": cur["count"] - p["count"],
                    "sum": cur["sum"] - p["sum"],
                    "min": cur["min"], "max": cur["max"],
                }
        return out

    # ---------------------------------------------------------- exporters

    def to_prometheus(self, snap: Optional[Dict[str, dict]] = None) -> str:
        """Prometheus text exposition format.  ``snap`` defaults to the
        live cumulative state; pass a ``delta`` for windowed exposition
        (unusual for Prometheus, which expects cumulative counters, but
        useful for per-benchmark-iteration dumps)."""
        snap = self.snapshot() if snap is None else snap
        helps = {n: i.help for n, i in self._instruments.items()}
        lines: List[str] = []
        for name, s in snap.items():
            if helps.get(name):
                lines.append(f"# HELP {name} {helps[name]}")
            lines.append(f"# TYPE {name} {s['type']}")
            if s["type"] in ("counter", "gauge"):
                lines.append(f"{name} {_fmt(s['value'])}")
                continue
            cum = 0
            for le, c in zip(s["buckets"], s["counts"]):
                cum += c
                lines.append(f'{name}_bucket{{le="{_fmt(le)}"}} {cum}')
            cum += s["counts"][-1]
            lines.append(f'{name}_bucket{{le="+Inf"}} {cum}')
            lines.append(f"{name}_sum {_fmt(s['sum'])}")
            lines.append(f"{name}_count {s['count']}")
        return "\n".join(lines) + "\n"


def _fmt(v) -> str:
    return repr(v) if isinstance(v, float) else str(v)


def hist_quantile(h: dict, q: float) -> float:
    """Quantile estimate from a histogram snapshot/delta entry: find the
    bucket holding the q-th observation and interpolate linearly inside
    it (clamped to the recorded min/max where available, so single-value
    distributions don't smear across a log bucket).  0.0 when empty."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must be in [0, 1], got {q}")
    total = h["count"]
    if total <= 0:
        return 0.0
    rank = q * total
    cum, lo = 0.0, 0.0
    bounds = list(h["buckets"]) + [h["buckets"][-1]]   # overflow: clamp
    for le, c in zip(bounds, h["counts"]):
        if cum + c >= rank and c > 0:
            frac = (rank - cum) / c
            v = lo + frac * (le - lo)
            break
        cum += c
        lo = le
    else:
        v = bounds[-1]
    if h.get("min") is not None:
        v = min(max(v, h["min"]), h["max"])
    return v


def hist_mean(h: dict) -> float:
    """Mean of a histogram snapshot/delta entry (exact: sum/count)."""
    return h["sum"] / h["count"] if h["count"] else 0.0


# ---------------------------------------------------------------------------
# per-request trace timelines
# ---------------------------------------------------------------------------


class Span:
    """One timeline interval: ``[t0, t1]`` (``perf_counter`` seconds),
    nested under ``parent`` (a span id; None for the root)."""
    __slots__ = ("name", "req", "sid", "parent", "t0", "t1", "attrs")

    def __init__(self, name, req, sid, parent, t0, t1=None, attrs=None):
        self.name, self.req, self.sid = name, req, sid
        self.parent, self.t0, self.t1 = parent, t0, t1
        self.attrs = attrs

    def to_dict(self) -> dict:
        return {"name": self.name, "req": self.req, "sid": self.sid,
                "parent": self.parent, "t0": self.t0, "t1": self.t1,
                "attrs": self.attrs or {}}


class Timeline:
    """All spans of one request, root first.  ``open`` maps span name ->
    still-open span (the engine keeps at most ``request`` + ``queued``
    open at any instant)."""
    __slots__ = ("req", "spans", "open")

    def __init__(self, req):
        self.req = req
        self.spans: List[Span] = []
        self.open: Dict[str, Span] = {}

    @property
    def root(self) -> Span:
        return self.spans[0]

    def terminal(self) -> Optional[Span]:
        """The ``finish`` span, if the request reached one."""
        for s in reversed(self.spans):
            if s.name == "finish":
                return s
        return None


class Tracer:
    """Per-request span timelines with bounded retention.

    The engine drives the semantic helpers (``begin`` / ``admitted`` /
    ``add`` / ``event`` / ``finish``); generic ``start``/``end`` exist
    for other span shapes.  All methods no-op when disabled.  Finished
    timelines land in :attr:`finished` (a deque capped at
    ``max_traces`` — old requests fall off a long-running server).
    Timestamps are ``time.perf_counter`` seconds; callers that already
    timed a region pass its endpoints so tracing adds no clock reads on
    the hot path."""

    def __init__(self, enabled: bool = True, max_traces: int = 1024):
        self.enabled = enabled
        self.max_traces = max_traces
        self._live: Dict[Any, Timeline] = {}
        self._sid = 0
        self.finished: "deque[Timeline]" = deque(maxlen=max_traces)
        self.dropped = 0                 # re-begun ids whose trace was lost

    # ------------------------------------------------------------- plumbing

    def _next_sid(self) -> int:
        self._sid += 1
        return self._sid

    def _span(self, tl: Timeline, name, parent, t0, t1=None, attrs=None):
        s = Span(name, tl.req, self._next_sid(), parent, t0, t1, attrs)
        tl.spans.append(s)
        return s

    def live(self) -> List[Any]:
        return list(self._live)

    def timelines(self) -> List[Timeline]:
        """Finished timelines, oldest first (bounded by ``max_traces``)."""
        return list(self.finished)

    # ------------------------------------------------------------ semantics

    def begin(self, req, t: Optional[float] = None, **attrs) -> None:
        """Open a request timeline: root ``request`` span plus its
        ``queued`` child (a request is queued from submit until
        admission).  Re-beginning a live id drops the old timeline."""
        if not self.enabled:
            return
        t = time.perf_counter() if t is None else t
        if req in self._live:
            self.dropped += 1
        tl = self._live[req] = Timeline(req)
        root = self._span(tl, "request", None, t, attrs=attrs or None)
        q = self._span(tl, "queued", root.sid, t)
        tl.open["request"] = root
        tl.open["queued"] = q

    def admitted(self, req, t0: float, t1: float, **attrs) -> None:
        """Close ``queued`` at ``t0`` and record the ``admitted`` span
        over the admission work itself (cache lookup/restore, expert-set
        binding, lane setup).  ``attrs`` carry the cache-restore facts:
        ``hit`` (restored prefix length), ``ns`` (cache namespace),
        ``mode``, ``expert_set``."""
        tl = self._live.get(req)
        if tl is None:
            return
        q = tl.open.pop("queued", None)
        if q is not None:
            q.t1 = t0
        self._span(tl, "admitted", tl.root.sid, t0, t1, attrs or None)

    def add(self, req, name: str, t0: float, t1: float, **attrs) -> None:
        """Record a completed child span (``prefill_chunk``, ``decode``,
        ``spec_round``) under the request root — the hot-path call."""
        tl = self._live.get(req)
        if tl is not None:
            self._span(tl, name, tl.root.sid, t0, t1, attrs or None)

    def event(self, req, name: str, t: Optional[float] = None,
              **attrs) -> None:
        """Zero-duration marker (``first_token``, ``expert_swap``)."""
        tl = self._live.get(req)
        if tl is not None:
            t = time.perf_counter() if t is None else t
            self._span(tl, name, tl.root.sid, t, t, attrs or None)

    def start(self, req, name: str, t: Optional[float] = None,
              **attrs) -> None:
        """Generic open span by name (closed by :meth:`end`)."""
        tl = self._live.get(req)
        if tl is not None:
            t = time.perf_counter() if t is None else t
            tl.open[name] = self._span(tl, name, tl.root.sid, t,
                                       attrs=attrs or None)

    def end(self, req, name: str, t: Optional[float] = None) -> None:
        tl = self._live.get(req)
        if tl is None:
            return
        s = tl.open.pop(name, None)
        if s is not None:
            s.t1 = time.perf_counter() if t is None else t

    def finish(self, req, reason: str, t: Optional[float] = None) -> None:
        """Terminal span: close every open span and the root at ``t``,
        append a ``finish`` marker carrying ``reason`` (eos / length /
        max_len / evicted), and retire the timeline to ``finished``."""
        tl = self._live.pop(req, None)
        if tl is None:
            return
        t = time.perf_counter() if t is None else t
        for s in tl.open.values():
            s.t1 = t
        tl.open.clear()
        self._span(tl, "finish", tl.root.sid, t, t, {"reason": reason})
        self.finished.append(tl)

    # ------------------------------------------------------------ exporter

    def chrome_trace(self) -> dict:
        """Chrome ``trace_event`` JSON of every finished (and still-live)
        timeline: one trace thread per request, one complete event per
        span, microsecond timestamps normalized to the earliest root.
        Load in Perfetto or ``chrome://tracing``."""
        tls = self.timelines() + [self._live[r] for r in self._live]
        events: List[dict] = []
        if not tls:
            return {"traceEvents": [], "displayTimeUnit": "ms"}
        t_origin = min(tl.root.t0 for tl in tls)
        for tl in tls:
            events.append({"ph": "M", "name": "thread_name", "pid": 0,
                           "tid": tl.req,
                           "args": {"name": f"request {tl.req}"}})
            for s in tl.spans:
                t1 = s.t1 if s.t1 is not None else tl.root.t1 or s.t0
                events.append({
                    "ph": "X", "pid": 0, "tid": tl.req, "name": s.name,
                    "ts": (s.t0 - t_origin) * 1e6,
                    "dur": max(t1 - s.t0, 0.0) * 1e6,
                    "args": dict(s.attrs) if s.attrs else {},
                })
        return {"traceEvents": events, "displayTimeUnit": "ms"}


# ---------------------------------------------------------------------------
# the bundle the engine threads through
# ---------------------------------------------------------------------------

_NULL_CTX = contextlib.nullcontext()


class Telemetry:
    """The telemetry bundle one serving stack shares.

    enabled: master switch — False makes the registry hand out no-op
        instruments and the tracer drop everything (true zero-cost off;
        the engine's ``stats`` view then reads all zeros).
    trace: per-request span timelines (default: follows ``enabled``).
        Metrics keep working with ``trace=False`` — the cheap mode for
        latency-critical serving.
    max_traces: finished timelines retained (bounded memory).
    profiler: wrap the engine's jitted dispatches in
        ``jax.profiler.TraceAnnotation`` so a profiler capture shows
        named decode/mixed/spec/prefill regions (off by default: the
        annotations cost a context manager per dispatch).
    registry: share an existing :class:`MetricsRegistry` (one registry
        across engine + cache + library + scheduler gives one unified
        export); default is a fresh one.
    """

    def __init__(self, enabled: bool = True, trace: Optional[bool] = None,
                 max_traces: int = 1024, profiler: bool = False,
                 registry: Optional[MetricsRegistry] = None):
        self.enabled = enabled
        self.registry = (registry if registry is not None
                         else MetricsRegistry(enabled=enabled))
        self.tracer = Tracer(enabled=enabled and (trace is None or trace),
                             max_traces=max_traces)
        self.profiler = profiler and enabled

    def annotate(self, name: str):
        """Context manager naming a host region in ``jax.profiler``
        captures; a shared no-op unless ``profiler=True``."""
        if not self.profiler:
            return _NULL_CTX
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)

    def describe(self) -> Dict[str, Any]:
        """The engine-stamp view: how telemetry was configured (so
        benchmark artifacts stay apples-to-apples across PRs)."""
        return {"enabled": self.enabled, "trace": self.tracer.enabled,
                "profiler": self.profiler}


# ---------------------------------------------------------------------------
# engine instrument bundle (names + help strings live here, not in engine.py)
# ---------------------------------------------------------------------------


class EngineInstruments:
    """Every instrument ``ServeEngine`` drives, created against one
    registry.  Counter names are the single source of truth for the
    engine's legacy ``stats`` compatibility view (``STAT_COUNTERS``)."""

    #: legacy ``ServeEngine.stats`` key -> (registry counter, int-valued)
    STAT_COUNTERS = {
        "prefill_tokens": ("serve_prefill_tokens_total", True),
        "prefill_s": ("serve_prefill_seconds_total", False),
        "decode_tokens": ("serve_decode_tokens_total", True),
        "decode_s": ("serve_decode_seconds_total", False),
        "decode_steps": ("serve_decode_steps_total", True),
        "mixed_steps": ("serve_mixed_steps_total", True),
        "mixed_s": ("serve_mixed_seconds_total", False),
        "active_ticks": ("serve_active_ticks_total", True),
        "stall_s": ("serve_stall_seconds_total", False),
        "spec_rounds": ("serve_spec_rounds_total", True),
        "spec_drafted": ("serve_spec_drafted_total", True),
        "spec_accepted": ("serve_spec_accepted_total", True),
        "spec_emitted": ("serve_spec_emitted_total", True),
        "cache_hit_tokens": ("serve_cache_hit_tokens_total", True),
        "expert_swaps": ("serve_expert_swaps_total", True),
    }

    def __init__(self, registry: MetricsRegistry):
        self.registry = registry
        c, g, h = registry.counter, registry.gauge, registry.histogram
        self.prefill_tokens = c("serve_prefill_tokens_total",
                                "prompt tokens prefilled (uncached suffixes)")
        self.prefill_s = c("serve_prefill_seconds_total",
                           "seconds in prefill-only dispatches")
        self.decode_tokens = c("serve_decode_tokens_total",
                               "tokens emitted by decode slots")
        self.decode_s = c("serve_decode_seconds_total",
                          "seconds in decode-only dispatches")
        self.decode_steps = c("serve_decode_steps_total",
                              "dispatches that advanced decode slots")
        self.mixed_steps = c("serve_mixed_steps_total",
                             "mixed decode+prefill dispatches")
        self.mixed_s = c("serve_mixed_seconds_total",
                         "seconds in mixed dispatches")
        self.active_ticks = c("serve_active_ticks_total",
                              "ticks that began with live decode lanes")
        self.stall_s = c("serve_stall_seconds_total",
                         "seconds live decode lanes spent not advancing")
        self.spec_rounds = c("serve_spec_rounds_total",
                             "speculative draft+verify rounds")
        self.spec_drafted = c("serve_spec_drafted_total",
                              "tokens drafted by the layer-skip model")
        self.spec_accepted = c("serve_spec_accepted_total",
                               "drafted tokens surviving verification")
        self.spec_emitted = c("serve_spec_emitted_total",
                              "tokens emitted by speculative rounds")
        self.cache_hit_tokens = c("serve_cache_hit_tokens_total",
                                  "prompt tokens skipped via cache restore")
        self.expert_swaps = c("serve_expert_swaps_total",
                              "expert-set binding-row rebinds")
        self.submitted = c("serve_requests_submitted_total",
                           "requests accepted by submit()")
        self.finished = c("serve_requests_finished_total",
                          "requests that reached a terminal state")
        self.active_slots = g("serve_active_slots",
                              "decode lanes live at the last tick")
        self.ttft = h("serve_ttft_seconds",
                      "submit -> first token, per request")
        self.e2e = h("serve_e2e_seconds",
                     "submit -> finish, per request")
        self.decode_step_s = h("serve_decode_step_seconds",
                               "latency of decode-advancing dispatches "
                               "(the inter-token latency per slot)")
        self.prefill_chunk_s = h("serve_prefill_chunk_seconds",
                                 "latency of prefill chunk dispatches")

    def stats_view(self, base: Dict[str, Any]) -> Dict[str, Any]:
        """The legacy ``ServeEngine.stats`` dict, derived from the
        registry: each counter minus its value at the last
        ``reset_stats()`` (``base``), with the historical int/float
        typing preserved."""
        v = self.registry.value
        return {key: (int if is_int else float)(v(name) - base.get(key, 0))
                for key, (name, is_int) in self.STAT_COUNTERS.items()}

    def stats_base(self) -> Dict[str, Any]:
        """Raw counter values keyed by legacy stat name — what
        ``reset_stats()`` stores as the subtraction baseline."""
        return {key: self.registry.value(name)
                for key, (name, _) in self.STAT_COUNTERS.items()}


class FleetInstruments:
    """Every instrument the disaggregated fleet drives (``serve/fleet/``:
    router, prefill workers, decode workers), created against one shared
    registry so a fleet exports next to its engines' and caches'
    instruments.  The ``fleet_tier_*`` family lives on
    :class:`~repro.serve.fleet.cache_tier.SharedCacheTier` — this class
    covers the request path."""

    def __init__(self, registry: MetricsRegistry):
        self.registry = registry
        c, g, h = registry.counter, registry.gauge, registry.histogram
        self.prefills = c("fleet_prefills_total",
                          "prompts prefilled by prefill workers")
        self.snapshots_out = c("fleet_snapshots_published_total",
                               "boundary snapshots shipped prefill->decode")
        self.snapshot_bytes = c("fleet_snapshot_bytes_total",
                                "encoded admit-message bytes transferred")
        self.admits = c("fleet_admits_total",
                        "decode admissions served from snapshots")
        self.admit_rejects = c("fleet_admit_rejects_total",
                               "snapshot admissions refused (no slot / "
                               "no binding row / drained)")
        self.requeues = c("fleet_requeues_total",
                          "requests requeued to another worker")
        self.failures = c("fleet_worker_failures_total",
                          "worker errors the router retried around")
        self.results = c("fleet_results_total",
                         "finished results returned through the router")
        self.queue_depth = g("fleet_queue_depth",
                             "requests waiting for a prefill assignment")
        self.prefill_workers = g("fleet_prefill_workers",
                                 "prefill replicas attached to the router")
        self.decode_workers = g("fleet_decode_workers",
                                "decode replicas attached to the router")
        self.queue_s = h("fleet_router_queue_seconds",
                         "submit -> prefill assignment, per request")
        self.transfer_s = h("fleet_transfer_seconds",
                            "encode + admit transfer latency, per request")
