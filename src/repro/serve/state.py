"""Unified slot-state store: generic decode-state management for serving.

Every mixer declares a :class:`StateSpec` — its decode-state pytree factory
and the axis that carries the slot (batch) dimension — once, next to its
step/prefill functions.  ``models/lm.py`` threads the spec through the
``Mixer`` registry, and the engine manipulates *any* model's state through
four slot-generic primitives:

  ``init_slots``     allocate an n-slot state for the whole model
  ``gather_slots``   pull selected slots out as a smaller state
  ``insert_slots``   write a smaller state into selected slots
  ``adopt_slots``    gather rows from a source state (e.g. a prefill lane
                     batch) and insert them into destination slots in one go

This replaces the per-mixer ``insert_fn`` closures the engine used to carry
(axis special-casing for attention KV vs recurrent state), and is the API
surface later serving features (speculative decoding over the SSM state)
build on: they need exactly "give me slot i's state" / "put this state into
slot i", independent of which mixers the model stacks.

Slot-axis bookkeeping: a mixer's ``slot_axis`` refers to its *own* state
leaves; when a segment is ``lax.scan``-stacked, every leaf gains a leading
``layers`` axis and the slot axis shifts by one.  ``slot_axes`` resolves
this per leaf from the config's segment layout.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class StateSpec:
    """A mixer's decode-state declaration.

    init: (cfg, batch, max_len, dtype) -> decode-state pytree with ``batch``
        slots along ``slot_axis`` of every leaf.
    slot_axis: axis carrying the slot dimension in every leaf of the pytree
        (before any segment-level layer stacking).
    append_only: leaf names (or a ``cfg -> names`` callable for
        config-dependent cases) of *append-only, position-keyed* cache
        leaves: entries are only ever written at their own absolute
        position and reads mask invalid/future positions, so speculative
        rollback never needs per-depth snapshots of them — stale entries
        from rejected drafts are masked now and overwritten when decode
        reaches their position.  Constant-size recurrent state (overwritten
        in place every step) must NOT be listed here.
    """
    init: Callable[..., Any]
    slot_axis: int = 0
    append_only: Any = ()


def append_only_leaves(spec: StateSpec, cfg):
    """Resolve a spec's append-only leaf names for this config."""
    ao = spec.append_only
    return frozenset(ao(cfg) if callable(ao) else ao)


def batch_spec(init_fn) -> StateSpec:
    """Adapt a (cfg, batch, dtype) state init — constant-size recurrent
    state, no per-token cache, so ``max_len`` is irrelevant — to StateSpec."""
    return StateSpec(init=lambda cfg, batch, max_len, dtype:
                     init_fn(cfg, batch, dtype))


#: Spec for mixers with no decode state (MLP / FFN-MoE): empty pytree.
STATELESS = StateSpec(init=lambda cfg, batch, max_len, dtype: {})


# ---------------------------------------------------------------------------
# slot-generic primitives over the whole-model state pytree
# ---------------------------------------------------------------------------

def _block_axes(pattern, bst, shift):
    from repro.models import lm
    out = {}
    for i, kind in enumerate(pattern):
        spec = lm.MIXERS[kind].state_spec
        key = f"l{i}_{kind}"
        out[key] = jax.tree_util.tree_map(
            lambda _leaf, ax=spec.slot_axis: ax + shift, bst[key])
    return out


def slot_axes(cfg, state):
    """Per-leaf slot-axis pytree matching ``state``'s structure exactly.

    Unstacked segments keep each mixer's declared ``slot_axis``; scan-stacked
    segments shift it by one for the leading ``layers`` axis.
    """
    segs = []
    for (pattern, repeats), sst in zip(cfg.segments, state["segments"]):
        if isinstance(sst, list):
            segs.append([_block_axes(pattern, bst, 0) for bst in sst])
        else:
            segs.append(_block_axes(pattern, sst, 1))
    return {"segments": segs}


def _leaf_name(path):
    for entry in reversed(path):
        k = getattr(entry, "key", getattr(entry, "name", None))
        if isinstance(k, str):
            return k
    return None


def _block_append_only(pattern, bst, cfg):
    from repro.models import lm
    out = {}
    for i, kind in enumerate(pattern):
        ao = append_only_leaves(lm.MIXERS[kind].state_spec, cfg)
        key = f"l{i}_{kind}"
        out[key] = jax.tree_util.tree_map_with_path(
            lambda p, _leaf: _leaf_name(p) in ao, bst[key])
    return out


def append_only_mask(cfg, state):
    """Per-leaf bool pytree matching ``state``: True where the leaf is an
    append-only position-keyed cache (see :class:`StateSpec`).  Structure
    mirrors :func:`slot_axes`; consumers (speculative verify) use it to skip
    per-depth snapshots of leaves whose rollback is free."""
    segs = []
    for (pattern, repeats), sst in zip(cfg.segments, state["segments"]):
        if isinstance(sst, list):
            segs.append([_block_append_only(pattern, bst, cfg)
                         for bst in sst])
        else:
            segs.append(_block_append_only(pattern, sst, cfg))
    return {"segments": segs}


def init_slots(cfg, n, max_len, dtype):
    """Fresh n-slot decode state for the whole model (every mixer's
    ``state_spec.init``, stacked per the segment layout)."""
    from repro.models import lm
    return lm.init_state(cfg, n, max_len, dtype)


def gather_slots(state, axes, slots):
    """Pull ``slots`` (int array (m,)) out of every leaf's slot axis,
    producing an m-slot state with the same structure."""
    slots = jnp.asarray(slots, jnp.int32)
    return jax.tree_util.tree_map(
        lambda leaf, ax: jnp.take(leaf, slots, axis=ax), state, axes)


def insert_slots(dst, src, axes, slots):
    """Write the m-slot ``src`` state into ``slots`` (int array (m,)) of
    ``dst`` along every leaf's slot axis; returns the updated state."""
    slots = jnp.asarray(slots, jnp.int32)

    def one(d, s, ax):
        idx = (slice(None),) * ax + (slots,)
        return d.at[idx].set(s.astype(d.dtype))

    return jax.tree_util.tree_map(one, dst, src, axes)


def adopt_slots(dst, src, axes, rows, slots):
    """``insert_slots(dst, gather_slots(src, rows), slots)``: move rows of a
    source state (a prefill lane batch) into destination slots."""
    return insert_slots(dst, gather_slots(src, axes, rows), axes, slots)


def select_window(stacked, axes, depth):
    """Per-slot snapshot selection over a K-token speculative window.

    ``stacked`` is a state pytree whose every leaf carries a leading
    *window* axis of length W — the per-step state snapshots a
    ``lax.scan`` of decode steps emits (leaf shape ``(W,) + leaf.shape``,
    so each leaf's slot axis is shifted by one).  ``axes`` is the
    *unstacked* per-leaf slot-axis pytree (:func:`slot_axes`); ``depth``
    is an ``(B,)`` int32 array selecting, independently per slot, which
    snapshot to keep.  Returns the unstacked state where slot ``b``'s
    rows come from window index ``depth[b]`` of every leaf — i.e. the
    model state as if slot ``b`` had consumed exactly ``depth[b] + 1``
    of the window's tokens.  This is the speculative-decoding rollback
    primitive: committing the snapshot at each slot's accepted depth is
    acceptance, and the rejected suffix is simply never adopted.
    """
    depth = jnp.asarray(depth, jnp.int32)

    def one(leaf, ax):
        moved = jnp.moveaxis(leaf, ax + 1, 1)        # (W, B, ...)
        sel = moved[depth, jnp.arange(depth.shape[0])]   # (B, ...)
        return jnp.moveaxis(sel, 0, ax)

    return jax.tree_util.tree_map(one, stacked, axes)


# ---------------------------------------------------------------------------
# host-side snapshots (prefix cache, state migration)
# ---------------------------------------------------------------------------

def state_nbytes(tree) -> int:
    """Per-leaf byte accounting: total bytes a state pytree occupies (host
    or device).  The prefix cache budgets its snapshots with this."""
    return sum(int(leaf.size) * jnp.dtype(leaf.dtype).itemsize
               for leaf in jax.tree_util.tree_leaves(tree))


def snapshot_slots(state, axes, slots):
    """Host-side copy of ``slots``' rows: ``gather_slots`` then a device ->
    host transfer, so the snapshot survives device-state mutation and costs
    no device memory.  Inverse of :func:`restore_slots`."""
    return jax.device_get(gather_slots(state, axes, slots))


def restore_slots(dst, src, axes, slots):
    """Write a host-side snapshot (from :func:`snapshot_slots`) back into
    ``slots`` of the device state ``dst``; returns the updated state."""
    return insert_slots(dst, src, axes, slots)


# ---------------------------------------------------------------------------
# store
# ---------------------------------------------------------------------------

class StateStore:
    """The engine's batched decode state plus its per-leaf slot axes.

    Holds the canonical ``max_slots``-wide state and exposes slot-generic
    operations; ``fresh(n)`` allocates side states (prefill lane batches)
    with the same structure so ``adopt`` can move rows between them.

    With a :class:`~repro.distributed.plan.ParallelPlan` the store is
    **shard-aware**: the canonical state (and every ``fresh`` side state
    whose slot count divides the plan's slot partition) is allocated as
    ``NamedSharding``-typed arrays with the slot axis over the plan's data
    axis, ``shardings`` exposes the per-leaf placement, and the jitted
    ``adopt`` carries ``out_shardings`` so the canonical state never
    drifts off-plan.  ``snapshot_rows``/``restore_rows`` address the
    per-shard device slices transparently (``device_get`` gathers from the
    owning shards; restore re-places rows through the committed ``dst``).
    """

    def __init__(self, cfg, max_slots, max_len, dtype, plan=None):
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.dtype = dtype
        self.plan = plan
        self.state = init_slots(cfg, max_slots, max_len, dtype)
        self.axes = slot_axes(cfg, self.state)
        self.append_only = append_only_mask(cfg, self.state)
        # multi-tenant serving: which expert-library *binding row* each
        # decode slot's tokens route through (serve/expert_library.py).
        # Host-side like the engine's per-slot sampling params — written at
        # slot adoption, read by the engine when assembling the per-slot
        # set-selection vector for the jitted steps.  All-zero (the
        # default/boot binding) when no library is attached.
        self.expert_set = np.zeros((max_slots,), np.int32)
        if plan is not None and plan.mesh is not None:
            self.shardings = plan.slot_shardings(self.state, self.axes)
            self.state = jax.device_put(self.state, self.shardings)
        else:
            self.shardings = None
        # axes are static python ints: close over them so jit sees concrete
        # index tuples (retraces only per (m,) shape of rows/slots)
        self._adopt = jax.jit(lambda dst, src, rows, slots: adopt_slots(
            dst, src, self.axes, rows, slots),
            out_shardings=self.shardings)
        self._gather = jax.jit(lambda st, slots: gather_slots(
            st, self.axes, slots))

    def fresh(self, n):
        """A zero-initialized n-slot state with this model's structure
        (same pytree, n instead of max_slots along every slot axis) —
        used for prefill lane batches and speculative draft copies.  On a
        plan, slot-divisible widths come back sharded over the slot
        partition (indivisible ones — e.g. 1-slot sequential-admission
        lanes — replicate)."""
        st = init_slots(self.cfg, n, self.max_len, self.dtype)
        if self.plan is not None and self.plan.mesh is not None:
            st = self.plan.place_state(st, self.axes)
        return st

    def gather(self, slots):
        """An m-slot copy of the given slots' state: leaf shapes keep
        their structure with ``len(slots)`` along each slot axis."""
        return self._gather(self.state, jnp.asarray(slots, jnp.int32))

    def adopt(self, src_state, rows, slots):
        """Install ``src_state``'s ``rows`` into this store's ``slots``
        (``rows`` and ``slots`` are equal-length int sequences indexing
        the source's and this store's slot axes respectively)."""
        self.state = self._adopt(self.state, src_state,
                                 jnp.asarray(rows, jnp.int32),
                                 jnp.asarray(slots, jnp.int32))

    def snapshot_rows(self, state, rows):
        """Host-side copy of ``rows`` of a state with this store's
        structure (the canonical state or a ``fresh`` side state)."""
        return jax.device_get(self._gather(state,
                                           jnp.asarray(rows, jnp.int32)))

    def restore_rows(self, state, snap, rows):
        """Write a host snapshot into ``rows`` of a state with this
        store's structure; returns the updated state."""
        return restore_slots(state, snap, self.axes, rows)

    # ---------------------------------------------------- snapshot export
    # (fleet serving: one slot in/out as a host pytree — the unit the
    # snapshot codec serializes and disaggregated admission transfers)

    def snapshot_slot(self, slot):
        """Host-side snapshot of one canonical slot's decode state (a
        1-slot pytree; topology-portable like every host snapshot)."""
        return self.snapshot_rows(self.state, [slot])

    def restore_slot(self, slot, snap):
        """Install a 1-slot host snapshot into canonical ``slot``.

        Routed through a ``fresh(1)`` side state + the jitted ``adopt``
        (which carries ``out_shardings``), so on a ParallelPlan the
        canonical state never drifts off its committed placement."""
        side = self.restore_rows(self.fresh(1), snap, [0])
        self.adopt(side, [0], [slot])
