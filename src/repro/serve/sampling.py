"""Per-slot token sampling: temperature / top-k / top-p, fully vectorized —
plus the speculative-decoding acceptance rule (greedy + rejection sampling).

Every parameter is a per-slot array so one jitted call samples for the whole
continuous batch, with each slot carrying its own request's settings:

  temperature <= 0  -> greedy (argmax), the rest of the pipeline is skipped
  top_k == 0        -> no top-k truncation
  top_p >= 1        -> no nucleus truncation

Filters compose in the usual order (temperature scale -> top-k -> top-p),
then a Gumbel-max draw picks the token.  ``spec_accept`` applies the same
filters to both the draft and the target distributions, so speculative
decoding stays exactly unbiased under every sampling setting (and exactly
argmax-matching under greedy).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling settings (host-side convenience container).

    The engine broadcasts these into per-slot (B,) arrays so every slot of
    the continuous batch samples with its own request's settings inside one
    jitted call.
    """
    temperature: float = 0.0            # 0 -> greedy
    top_k: int = 0                      # 0 -> disabled
    top_p: float = 1.0                  # 1.0 -> disabled


def filtered_logits(lf, temperature, top_k, top_p):
    """Temperature-scaled, top-k / top-p-masked logits.

    lf (B,V) f32; temperature (B,) f32; top_k (B,) i32; top_p (B,) f32
    -> (B,V) f32 with filtered-out tokens at -inf.  ``softmax`` of the
    result is the per-slot sampling distribution (greedy slots are handled
    by the callers, not here).
    """
    V = lf.shape[-1]
    scaled = lf / jnp.maximum(temperature, 1e-6)[:, None]
    # top-k: keep the k highest-scoring tokens per row
    desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    k = jnp.where(top_k > 0, jnp.clip(top_k, 1, V), V)
    kth = jnp.take_along_axis(desc, (k - 1)[:, None], axis=-1)   # (B,1)
    scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    # top-p (nucleus): smallest prefix of the sorted distribution whose
    # mass reaches top_p; implemented as a probability threshold so it maps
    # back to the unsorted layout without a scatter
    probs = jax.nn.softmax(scaled, axis=-1)
    ps = jnp.sort(probs, axis=-1)[:, ::-1]
    cum = jnp.cumsum(ps, axis=-1)
    # lower clamp keeps the top-1 token at top_p=0 (else all tokens mask)
    keep = (cum - ps) < jnp.clip(top_p, 1e-6, 1.0)[:, None]      # (B,V)
    cutoff = jnp.min(jnp.where(keep, ps, jnp.inf), axis=-1, keepdims=True)
    return jnp.where(probs < cutoff, -jnp.inf, scaled)


def sample(logits, rng, temperature, top_k, top_p):
    """logits (B,V); temperature (B,) f32; top_k (B,) i32; top_p (B,) f32
    -> sampled token ids (B,) i32.  Greedy (temperature <= 0) slots take the
    unfiltered argmax; the rest Gumbel-max-sample the filtered distribution.
    """
    lf = logits.astype(jnp.float32)
    greedy = temperature <= 0.0
    scaled = filtered_logits(lf, temperature, top_k, top_p)
    g = jax.random.gumbel(rng, scaled.shape, jnp.float32)
    sampled = jnp.argmax(scaled + g, axis=-1)
    return jnp.where(greedy, jnp.argmax(lf, axis=-1),
                     sampled).astype(jnp.int32)


def sample_fused(hidden, table, tied, cap, full_logits_fn, rng,
                 temperature, top_k, top_p):
    """Sample the next token from the *pre-logits* hidden row.

    hidden (B,D) post-final-norm; table the embedding/lm-head matrix;
    ``full_logits_fn`` a nullary returning the full (B,V) logits row.

    When every slot is greedy (temperature <= 0) the token comes from
    ``kernels.ops.logits_step`` — argmax computed inside the output
    projection, so the (B,V) logits row never materializes.  Its oracle
    applies the identical f32 projection + softcap with first-occurrence
    tie-breaking, so the result matches :func:`sample`'s unfiltered argmax
    bit-for-bit.  A batch with any sampled slot falls back to
    ``full_logits_fn()`` + :func:`sample` (today's exact path).
    """
    from repro.kernels import ops as kernel_ops

    def greedy_branch(_):
        idx, _, _ = kernel_ops.logits_step(hidden, table, tied=tied,
                                           softcap=cap, need_stats=False)
        return idx

    def full_branch(_):
        return sample(full_logits_fn(), rng, temperature, top_k, top_p)

    return jax.lax.cond(jnp.all(temperature <= 0.0), greedy_branch,
                        full_branch, None)


def _window_probs(logits, temperature, top_k, top_p):
    """Filtered softmax over a (B,S,V) window of logits, applying each
    slot's sampling params at every window position."""
    B, S, V = logits.shape
    flat = filtered_logits(logits.astype(jnp.float32).reshape(B * S, V),
                           jnp.repeat(temperature, S),
                           jnp.repeat(top_k, S), jnp.repeat(top_p, S))
    return jax.nn.softmax(flat, axis=-1).reshape(B, S, V)


def spec_accept(target_logits, draft_logits, draft_toks, rng,
                temperature, top_k, top_p):
    """Speculative-decoding acceptance: longest agreeing prefix + correction.

    target_logits (B,K+1,V)  full-model logits over the verify window
                             (position j conditions on the K-token draft
                             prefix d_1..d_j)
    draft_logits  (B,K,V)    draft-model logits the proposals were sampled
                             from (position j proposes d_{j+1})
    draft_toks    (B,K)      proposed tokens d_1..d_K
    temperature / top_k / top_p: per-slot (B,) sampling params

    Returns ``(tokens (B,K+1) i32, n_emit (B,) i32)``: per slot, the first
    ``n_emit`` entries of ``tokens`` are the accepted draft prefix followed
    by one token from the full model (a resample on rejection, the bonus
    K+1-th token on full acceptance), ``n_emit`` in [1, K+1].

    Greedy slots (temperature <= 0) accept d_i iff it equals the target
    argmax, and the trailing token *is* the target argmax — so greedy
    speculative decoding emits bit-identical tokens to plain decoding.
    Sampled slots use rejection sampling (Leviathan et al., 2023): accept
    d_i with prob min(1, p(d_i)/q(d_i)) where p/q are the *filtered* target
    and draft distributions, and resample rejections from
    normalize(max(p - q, 0)) — the emitted sequence is distributed exactly
    as sampling the full model token-by-token.
    """
    B, Kp1, V = target_logits.shape
    K = Kp1 - 1
    tf = target_logits.astype(jnp.float32)
    greedy = temperature <= 0.0                                   # (B,)

    p = _window_probs(tf, temperature, top_k, top_p)              # (B,K+1,V)
    q = _window_probs(draft_logits, temperature, top_k, top_p)    # (B,K,V)

    # per-position acceptance
    tgt_argmax = jnp.argmax(tf, axis=-1)                          # (B,K+1)
    accept_g = draft_toks == tgt_argmax[:, :K]
    p_d = jnp.take_along_axis(p[:, :K], draft_toks[..., None],
                              axis=-1)[..., 0]                    # (B,K)
    q_d = jnp.take_along_axis(q, draft_toks[..., None], axis=-1)[..., 0]
    rng_u, rng_r = jax.random.split(rng)
    u = jax.random.uniform(rng_u, (B, K))
    # p_d > 0 guards the q_d == 0 corner (a proposal outside the draft's own
    # filtered support, impossible for tokens actually sampled from q): a
    # token with zero target probability must never be accepted
    accept_s = (u * q_d <= p_d) & (p_d > 0)
    accept = jnp.where(greedy[:, None], accept_g, accept_s)       # (B,K)

    # m = length of the accepted prefix (leading run of accepts)
    m = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=-1),
                axis=-1)                                          # (B,) 0..K

    # trailing token from the full model at depth m.  Padding q with a zero
    # row makes the m == K case fall out of the same formula: the residual
    # max(p_K - 0, 0) *is* the bonus-token distribution p_K.
    q_pad = jnp.concatenate([q, jnp.zeros((B, 1, V), q.dtype)], axis=1)
    p_m = jnp.take_along_axis(p, m[:, None, None], axis=1)[:, 0]  # (B,V)
    q_m = jnp.take_along_axis(q_pad, m[:, None, None], axis=1)[:, 0]
    resid = jnp.maximum(p_m - q_m, 0.0)
    rsum = jnp.sum(resid, axis=-1, keepdims=True)
    # numerical guard: an all-zero residual (p == q to rounding) can only be
    # reached with vanishing probability; fall back to p_m
    resid = jnp.where(rsum > 0, resid / jnp.maximum(rsum, 1e-30), p_m)
    g = jax.random.gumbel(rng_r, (B, V), jnp.float32)
    sampled_tail = jnp.argmax(jnp.log(jnp.maximum(resid, 1e-38)) + g,
                              axis=-1)
    greedy_tail = jnp.take_along_axis(tgt_argmax, m[:, None],
                                      axis=1)[:, 0]
    tail = jnp.where(greedy, greedy_tail, sampled_tail).astype(jnp.int32)

    out = jnp.concatenate(
        [draft_toks, jnp.zeros((B, 1), jnp.int32)], axis=1)       # (B,K+1)
    out = out.at[jnp.arange(B), m].set(tail)
    return out.astype(jnp.int32), (m + 1).astype(jnp.int32)
