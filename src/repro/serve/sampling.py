"""Per-slot token sampling: temperature / top-k / top-p, fully vectorized.

Every parameter is a per-slot array so one jitted call samples for the whole
continuous batch, with each slot carrying its own request's settings:

  temperature <= 0  -> greedy (argmax), the rest of the pipeline is skipped
  top_k == 0        -> no top-k truncation
  top_p >= 1        -> no nucleus truncation

Filters compose in the usual order (temperature scale -> top-k -> top-p),
then a Gumbel-max draw picks the token.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling settings (host-side convenience container)."""
    temperature: float = 0.0            # 0 -> greedy
    top_k: int = 0                      # 0 -> disabled
    top_p: float = 1.0                  # 1.0 -> disabled


def sample(logits, rng, temperature, top_k, top_p):
    """logits (B,V); temperature (B,) f32; top_k (B,) i32; top_p (B,) f32
    -> sampled token ids (B,) i32."""
    V = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    greedy = temperature <= 0.0

    scaled = lf / jnp.maximum(temperature, 1e-6)[:, None]
    # top-k: keep the k highest-scoring tokens per row
    desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    k = jnp.where(top_k > 0, jnp.clip(top_k, 1, V), V)
    kth = jnp.take_along_axis(desc, (k - 1)[:, None], axis=-1)   # (B,1)
    scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    # top-p (nucleus): smallest prefix of the sorted distribution whose
    # mass reaches top_p; implemented as a probability threshold so it maps
    # back to the unsorted layout without a scatter
    probs = jax.nn.softmax(scaled, axis=-1)
    ps = jnp.sort(probs, axis=-1)[:, ::-1]
    cum = jnp.cumsum(ps, axis=-1)
    # lower clamp keeps the top-1 token at top_p=0 (else all tokens mask)
    keep = (cum - ps) < jnp.clip(top_p, 1e-6, 1.0)[:, None]      # (B,V)
    cutoff = jnp.min(jnp.where(keep, ps, jnp.inf), axis=-1, keepdims=True)
    scaled = jnp.where(probs < cutoff, -jnp.inf, scaled)

    g = jax.random.gumbel(rng, scaled.shape, jnp.float32)
    sampled = jnp.argmax(scaled + g, axis=-1)
    return jnp.where(greedy, jnp.argmax(lf, axis=-1),
                     sampled).astype(jnp.int32)
