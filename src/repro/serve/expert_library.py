"""ExpertLibrary: named, hot-swappable RoM expert sets for multi-tenant
serving.

RoM expertizes the *projections* (paper Eq. 9-13), which makes the expert
weights the one parameter subtree the serving stack already treats
specially — :meth:`~repro.distributed.plan.ParallelPlan.place_params`
shards their expert dim over the expert partition, and the routed-matmul
decode fast path consumes them directly.  An :class:`ExpertLibrary` takes
that one step further: it holds *named* expert sets (domain-adapted
projection experts + their shared router, per swappable block — see
``models/lm.py:EXPERT_SWAPPABLE``) and lets one
:class:`~repro.serve.engine.ServeEngine` serve many tenants, each request
selecting its set by name (``Request.expert_set``).

An expert set is a **sparse mirror** of the model's param pytree: the same
``{"segments": [...]}`` nesting, but only the swappable blocks' ``e_w_*``
and ``w_router`` leaves (moemamba's nested per-projection router dicts
included).  Keeping the nested structure — rather than flat keys — means
the existing name-based sharding resolution
(:func:`repro.distributed.sharding.param_shardings`) applies to a set
verbatim, so a faulted-in set lands with the same ``model``-axis expert
partition as the base weights.

Residency is byte-budgeted LRU in the
:class:`~repro.serve.cache.PrefixCache` mold, with two serving-driven
differences: the host (numpy) copy of every set is always retained
(eviction only frees device bytes — a set can always fault back in), and
the budget is an *advisory floor* rather than a hard refusal — a set an
engine binds is always admitted even if it alone exceeds the budget
(counted in ``stats["overcommit"]``), because refusing would deadlock
admission.  Bound sets are pinned (per engine binding row) and never
evicted while any decode slot can still reference them.

Library transforms derive new sets host-side: :meth:`merge` (a weighted
average — model-soup style domain interpolation) and :meth:`subset`
(selected expert rows from one set, the rest from another — e.g. keep a
tenant's two specialist experts on top of the base generalists).

The engine-side contract (``serve/engine.py``):

  * ``graft(params, [name])`` returns params with plain swapped leaves —
    the exact tree a dedicated single-set engine would hold; prefill jobs
    run on this, so the prefill path needs no model-code awareness.
  * ``graft(params, names)`` with several names returns per-set *tuple*
    leaves; ``SharedRouting`` fans out over them (one routed GEMM per
    bound set per dispatch) and selects per slot via
    ``Runtime.expert_sets`` — each set tracing the identical single-set
    code path, which is what makes per-tenant greedy decode bitwise
    identical to a dedicated engine.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.serve.state import state_nbytes
from repro.serve.telemetry import MetricsRegistry

#: legacy ``ExpertLibrary.stats`` key -> (registry counter name, help)
_STAT_COUNTERS = {
    "hits": ("lib_hits_total", "acquires served by a resident set"),
    "faults": ("lib_faults_total", "acquires that faulted a set onto "
                                   "the device"),
    "evictions": ("lib_evictions_total",
                  "unpinned sets evicted from device residency"),
    "overcommit": ("lib_overcommit_total",
                   "admissions past the budget with no evictable set"),
}


def _leaf_wanted(name: str) -> bool:
    return name.startswith("e_w_") or name == "w_router"


def _extract_block(subtree) -> dict:
    """Sparse copy of one block's swappable leaves, keeping nesting (the
    moemamba per-projection ``*_router`` dicts stay dicts)."""
    out = {}
    for k, v in subtree.items():
        if isinstance(v, dict):
            sub = _extract_block(v)
            if sub:
                out[k] = sub
        elif _leaf_wanted(k):
            out[k] = v
    return out


def _overlay_block(dst: dict, mirrors: List[dict]) -> dict:
    """``dst`` with every leaf present in the mirrors replaced — by the
    single mirror's leaf, or by a per-set tuple when several are bound."""
    out = dict(dst)
    for k, v in mirrors[0].items():
        if isinstance(v, dict):
            out[k] = _overlay_block(dst[k], [m[k] for m in mirrors])
        elif len(mirrors) == 1:
            out[k] = v
        else:
            out[k] = tuple(m[k] for m in mirrors)
    return out


def _experts_axis(name: str, leaf) -> int:
    """The expert dim of a swappable leaf: ``e_w_*`` are (E, din, dout)
    (+1 leading ``layers`` axis when scan-stacked), ``w_router`` is
    (d_model, E) (ditto)."""
    return leaf.ndim - 1 if name == "w_router" else leaf.ndim - 3


def _map_named(tree, fn):
    """tree_map with the leaf's dict key: ``fn(name, leaf)`` (sets are
    all-dict pytrees, so the innermost dict key is the leaf name)."""
    if isinstance(tree, dict):
        return {k: _map_named(v, fn) if isinstance(v, (dict, list))
                else fn(k, v) for k, v in tree.items()}
    if isinstance(tree, list):
        return [_map_named(v, fn) for v in tree]
    raise TypeError(f"unexpected expert-set node {type(tree)}")


class ExpertLibrary:
    """Named expert sets with byte-budgeted LRU device residency.

    cfg: the model config (block layout decides which leaves swap).
    base_params: full param pytree whose swappable leaves become the
        ``default`` set (the engine's boot binding and the fallback for
        requests that name no set).
    budget_mb: advisory device-byte floor for resident sets; admission
        past it evicts unpinned LRU sets, but never refuses (see module
        docstring).
    max_bound: engine binding rows — how many *distinct* sets one engine
        can decode with concurrently (its jitted step carries one tuple
        slot per row).
    plan: :class:`~repro.distributed.plan.ParallelPlan` placing faulted-in
        sets; the engine installs its own plan if left None.
    """

    def __init__(self, cfg, base_params, *, budget_mb: float = 256.0,
                 max_bound: int = 4, default: str = "base", plan=None,
                 registry: Optional[MetricsRegistry] = None):
        if budget_mb <= 0:
            raise ValueError(f"budget_mb must be > 0, got {budget_mb}")
        if max_bound < 1:
            raise ValueError(f"max_bound must be >= 1, got {max_bound}")
        from repro.models import lm
        self.cfg = cfg
        self.plan = plan
        self.budget_bytes = int(budget_mb * (1 << 20))
        self.max_bound = max_bound
        self.default = default
        self._blocks = lm.expert_block_keys(cfg)
        if not self._blocks:
            raise ValueError(
                "model has no swappable expert blocks (rom_*/moemamba) — "
                f"segments: {cfg.segments}")
        self._host: Dict[str, Any] = {}          # always-retained numpy trees
        self._device: "OrderedDict[str, Any]" = OrderedDict()   # LRU order
        self._pins: Dict[str, int] = {}
        self._nbytes: Dict[str, int] = {}
        self._ref_structure = None               # congruence check template
        # telemetry: counters back the legacy ``stats`` dict (a derived
        # view); pass ``registry=`` to report into a shared serving-stack
        # registry (one library per shared registry), default is private.
        self.registry = (registry if registry is not None
                         else MetricsRegistry())
        self._m = {key: self.registry.counter(name, help)
                   for key, (name, help) in _STAT_COUNTERS.items()}
        self._g_bytes = self.registry.gauge(
            "lib_bytes_device", "bytes of device-resident expert sets")
        self.add(default, base_params)

    @property
    def stats(self) -> Dict[str, int]:
        """Legacy counters view, derived from the telemetry registry
        (cumulative over the library's lifetime; all zeros when the shared
        registry is disabled)."""
        return {key: int(self.registry.value(name))
                for key, (name, _) in _STAT_COUNTERS.items()}

    # ------------------------------------------------------------ contents

    def __contains__(self, name: str) -> bool:
        return name in self._host

    def __len__(self) -> int:
        return len(self._host)

    def names(self) -> List[str]:
        return sorted(self._host)

    def nbytes(self, name: str) -> int:
        return self._nbytes[name]

    @property
    def bytes_device(self) -> int:
        return sum(self._nbytes[n] for n in self._device)

    def resident(self) -> List[str]:
        """Device-resident set names, least-recently-used first."""
        return list(self._device)

    # ------------------------------------------------------------ build

    def extract(self, params) -> Any:
        """The sparse expert-set mirror of a full param pytree: only the
        swappable blocks' ``e_w_*``/``w_router`` leaves, same nesting."""
        keys_by_seg: Dict[int, List[str]] = {}
        for si, key in self._blocks:
            keys_by_seg.setdefault(si, []).append(key)
        segs = []
        for si, seg in enumerate(params["segments"]):
            keys = keys_by_seg.get(si, [])
            if isinstance(seg, list):
                segs.append([{k: _extract_block(bp[k]) for k in keys}
                             for bp in seg])
            else:
                segs.append({k: _extract_block(seg[k]) for k in keys})
        return {"segments": segs}

    def add(self, name: str, source) -> None:
        """Register a set: ``source`` is a full param pytree (extracted) or
        an expert-set mirror (stored as-is).  Host numpy copies are kept
        for the library's lifetime; the set faults onto the device on
        first :meth:`acquire`.  Every set must be congruent with the
        default — same tree structure, leaf shapes and dtypes — so the
        engine's jitted steps never retrace on a swap."""
        if self._pins.get(name, 0) > 0:
            raise ValueError(
                f"cannot replace expert set {name!r} while an engine "
                "binding row pins it")
        tree = source if (isinstance(source, dict)
                          and set(source) == {"segments"}
                          and self._is_mirror(source)) else None
        if tree is None:
            tree = self.extract(source)
        tree = jax.device_get(tree)              # host numpy, detached
        leaves, structure = jax.tree_util.tree_flatten(tree)
        if not leaves:
            raise ValueError(f"expert set {name!r} has no leaves")
        sig = (structure, tuple((l.shape, np.dtype(l.dtype)) for l in leaves))
        if self._ref_structure is None:
            self._ref_structure = sig
        elif sig != self._ref_structure:
            raise ValueError(
                f"expert set {name!r} is not congruent with {self.default!r}"
                " (tree structure / leaf shapes / dtypes differ)")
        self._host[name] = tree
        self._nbytes[name] = state_nbytes(tree)
        self._pins.setdefault(name, 0)
        self._device.pop(name, None)             # stale residency, if any

    def _is_mirror(self, source) -> bool:
        """A segments-tree whose first swappable block holds only swapped
        leaves is a mirror, not full params (full blocks carry e.g. conv
        or A/D leaves too)."""
        si, key = self._blocks[0]
        seg = source["segments"][si]
        block = (seg[0] if isinstance(seg, list) else seg).get(key)
        if not isinstance(block, dict):
            return False

        def only_swapped(d):
            return all(only_swapped(v) if isinstance(v, dict)
                       else _leaf_wanted(k) for k, v in d.items())
        return only_swapped(block)

    # ------------------------------------------------------- transforms

    def merge(self, name: str, sources: Sequence[str],
              weights: Optional[Sequence[float]] = None) -> None:
        """Register ``name`` as the weighted average of existing sets
        (uniform by default) — model-soup style domain interpolation,
        computed host-side in float32 and cast back per leaf."""
        if not sources:
            raise ValueError("merge needs at least one source set")
        trees = [self._host[s] for s in sources]
        if weights is None:
            weights = [1.0 / len(sources)] * len(sources)
        if len(weights) != len(sources):
            raise ValueError("merge weights/sources length mismatch")
        total = float(sum(weights))
        ws = [float(w) / total for w in weights]

        def avg(*ls):
            acc = sum(w * l.astype(np.float32) for w, l in zip(ws, ls))
            return acc.astype(ls[0].dtype)

        self.add(name, jax.tree_util.tree_map(avg, *trees))

    def subset(self, name: str, source: str, experts: Sequence[int],
               fill: Optional[str] = None) -> None:
        """Register ``name`` with the listed expert rows taken from
        ``source`` and every other row from ``fill`` (default set when
        None) — along each leaf's expert dim, router columns included, so
        the derived set routes consistently with its weights."""
        src = self._host[source]
        base = self._host[fill if fill is not None else self.default]
        idx = np.asarray(sorted(set(int(e) for e in experts)), np.int64)

        def pick(path_name, pair):
            s, b = pair
            ax = _experts_axis(path_name, s)
            if idx.size and (idx.min() < 0 or idx.max() >= s.shape[ax]):
                raise ValueError(
                    f"subset experts {idx.tolist()} out of range for "
                    f"{path_name} with {s.shape[ax]} experts")
            out = np.array(b)
            sl = [slice(None)] * s.ndim
            sl[ax] = idx
            out[tuple(sl)] = s[tuple(sl)]
            return out

        paired = jax.tree_util.tree_map(lambda a, b: (a, b), src, base,
                                        is_leaf=lambda x: isinstance(
                                            x, np.ndarray))
        self.add(name, _map_named(paired, pick))

    # ------------------------------------------------------- residency

    def acquire(self, name: str) -> None:
        """Pin ``name`` for one engine binding row, faulting it onto the
        device if cold (placed via the plan so the expert partition
        applies) and evicting unpinned LRU sets past the budget.  The
        requested set is always admitted — the budget is advisory."""
        if name not in self._host:
            raise KeyError(f"unknown expert set {name!r}; "
                           f"have {self.names()}")
        if name in self._device:
            self._device.move_to_end(name)
            self._m["hits"].inc()
        else:
            host = self._host[name]
            placed = (self.plan.commit_params(host) if self.plan is not None
                      else jax.device_put(host))
            self._device[name] = placed
            self._m["faults"].inc()
            self._evict_to_budget(keep=name)
            self._g_bytes.set(self.bytes_device)
        self._pins[name] += 1

    def release(self, name: str) -> None:
        """Drop one binding-row pin; a fully unpinned set becomes an LRU
        eviction candidate (its host copy survives regardless)."""
        if self._pins.get(name, 0) <= 0:
            raise ValueError(f"release of unpinned expert set {name!r}")
        self._pins[name] -= 1

    def device_tree(self, name: str):
        """The resident device tree for a bound set (acquire first)."""
        return self._device[name]

    def _evict_to_budget(self, keep: str) -> None:
        while self.bytes_device > self.budget_bytes:
            victim = next((n for n in self._device
                           if n != keep and self._pins.get(n, 0) == 0), None)
            if victim is None:
                # every other resident set is pinned (or this set alone
                # exceeds the budget): admit anyway — refusing a bound
                # set would wedge admission — and record the overshoot
                self._m["overcommit"].inc()
                return
            del self._device[victim]
            self._m["evictions"].inc()
            self._g_bytes.set(self.bytes_device)

    # ------------------------------------------------------------ graft

    def graft(self, params, names: Sequence[str]):
        """Params with the swappable leaves replaced by the named sets'.

        One name grafts plain arrays — structurally the tree a dedicated
        single-set engine holds (the prefill path).  Several names graft
        per-set tuples for ``SharedRouting``'s fan-out (the multi-tenant
        decode path); tuple order is binding-row order, matching the
        engine's per-slot ``Runtime.expert_sets`` indices.  All named
        sets must be device-resident (the engine holds a pin per bound
        row, so bound sets always are)."""
        sets = [self._device[n] for n in names]
        segs = []
        for si, seg in enumerate(params["segments"]):
            mirrors = [s["segments"][si] for s in sets]
            if isinstance(seg, list):
                segs.append([_overlay_block(bp, [m[bi] for m in mirrors])
                             for bi, bp in enumerate(seg)])
            else:
                segs.append(_overlay_block(seg, mirrors))
        out = dict(params)
        out["segments"] = segs
        return out

    # ------------------------------------------------------------ reports

    def summary(self) -> Dict[str, Any]:
        """Derived stats: residency hit rate over acquires, device bytes
        vs budget, per-set pin counts."""
        s = self.stats
        acquires = s["hits"] + s["faults"]
        return {
            "sets": len(self),
            "resident": self.resident(),
            "bytes_device": self.bytes_device,
            "budget_bytes": self.budget_bytes,
            "residency_hit_rate": s["hits"] / max(acquires, 1),
            "pinned": {n: c for n, c in sorted(self._pins.items()) if c},
            **s,
        }
