"""Decoder LM assembled from block-pattern segments.

A model is ``cfg.segments = (((kind, ...), repeats), ...)``.  Segments with
``repeats > 1`` run under ``lax.scan`` over stacked parameters (HLO stays
small at 60-layer scale); pre-norm + residual wrap every sub-layer.  A block
context dict threads RoM routing decisions to a following FFN-MoE
(paper Eq. 14-15).

Decode mirrors the same structure with per-layer state pytrees (stacked for
scanned segments) and a single-token ``step``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import moe_mamba, rom, rom_ffn
from repro.core.router import METRIC_KEYS, pack_metrics
from repro.nn import attention as attn
from repro.nn import attn_moe
from repro.nn import mlp as mlp_mod
from repro.nn import rglru as rgl
from repro.nn import ssm
from repro.nn import xlstm as xl
from repro.nn.layers import (Runtime, embed_init, embed_lookup, rmsnorm,
                             rmsnorm_init, softcap)
from repro.serve.state import STATELESS, StateSpec


# ---------------------------------------------------------------------------
# mixer registry
# ---------------------------------------------------------------------------

def _noctx(fn):
    return lambda p, x, cfg, rt, ctx: fn(p, x, cfg, rt)


def _noctx_step(fn):
    return lambda p, x, st, pos, cfg, rt, ctx: fn(p, x, st, pos, cfg, rt)


def _stateless_step(apply_fn):
    def step(p, x_t, st, pos, cfg, rt, ctx):
        y, aux = apply_fn(p, x_t, cfg, rt, ctx)
        return y, st, aux
    return step


def _stateless_prefill(apply_fn):
    def prefill(p, x, st, pos0, cfg, rt, ctx):
        y, aux = apply_fn(p, x, cfg, rt, ctx)
        return y, st, aux
    return prefill


def _noctx_prefill(fn):
    return lambda p, x, st, pos0, cfg, rt, ctx: fn(p, x, st, pos0, cfg, rt)


def _mlp_apply(p, x, cfg, rt, ctx):
    return mlp_mod.mlp_apply(p, x, cfg, rt)


@dataclasses.dataclass(frozen=True)
class Mixer:
    init: Any
    apply: Any                       # (p, x, cfg, rt, ctx) -> (y, aux)
    state_spec: StateSpec = None     # decode-state pytree factory + slot axis
    #   (declared once in the mixer's own module; None -> train/prefill only)
    step: Any = None                 # (p, x_t, st, pos, cfg, rt, ctx)
    prefill: Any = None              # (p, x, st, pos0, cfg, rt, ctx)
    #   -> (y (B,S,D), terminal decode state, aux): the parallel
    #   training-style forward over a prompt chunk, whose extracted state
    #   matches stepping token-by-token through ``step``


MIXERS: Dict[str, Mixer] = {
    "attn": Mixer(attn.attention_init, _noctx(attn.attention_apply),
                  attn.attention_state_spec,
                  _noctx_step(attn.attention_step),
                  _noctx_prefill(attn.attention_prefill)),
    "mlp": Mixer(lambda k, cfg: mlp_mod.mlp_init(k, cfg), _mlp_apply,
                 STATELESS,
                 _stateless_step(_mlp_apply),
                 _stateless_prefill(_mlp_apply)),
    "moe": Mixer(rom_ffn.moe_ffn_init, rom_ffn.moe_ffn_apply,
                 STATELESS,
                 _stateless_step(rom_ffn.moe_ffn_apply),
                 _stateless_prefill(rom_ffn.moe_ffn_apply)),
    "mamba": Mixer(ssm.mamba_init, _noctx(ssm.mamba_apply),
                   ssm.mamba_state_spec, _noctx_step(ssm.mamba_step),
                   _noctx_prefill(ssm.mamba_prefill)),
    "mamba2": Mixer(ssm.mamba2_init, _noctx(ssm.mamba2_apply),
                    ssm.mamba2_state_spec, _noctx_step(ssm.mamba2_step),
                    _noctx_prefill(ssm.mamba2_prefill)),
    "gdn": Mixer(ssm.gdn_init, _noctx(ssm.gdn_apply),
                 ssm.gdn_state_spec, _noctx_step(ssm.gdn_step),
                 _noctx_prefill(ssm.gdn_prefill)),
    "rglru": Mixer(rgl.rglru_init, _noctx(rgl.rglru_apply),
                   rgl.rglru_state_spec, _noctx_step(rgl.rglru_step),
                   _noctx_prefill(rgl.rglru_prefill)),
    "mlstm": Mixer(xl.mlstm_init, _noctx(xl.mlstm_apply),
                   xl.mlstm_state_spec, _noctx_step(xl.mlstm_step),
                   _noctx_prefill(xl.mlstm_prefill)),
    "slstm": Mixer(xl.slstm_init, _noctx(xl.slstm_apply),
                   xl.slstm_state_spec, _noctx_step(xl.slstm_step),
                   _noctx_prefill(xl.slstm_prefill)),
    "rom_mamba": Mixer(rom.rom_mamba_init, rom.rom_mamba_apply,
                       rom.rom_mamba_state_spec, rom.rom_mamba_step,
                       rom.rom_mamba_prefill),
    "rom_mamba2": Mixer(rom.rom_mamba2_init, rom.rom_mamba2_apply,
                        rom.rom_mamba2_state_spec, rom.rom_mamba2_step,
                        rom.rom_mamba2_prefill),
    "rom_gdn": Mixer(rom.rom_gdn_init, rom.rom_gdn_apply,
                     rom.rom_gdn_state_spec, rom.rom_gdn_step,
                     rom.rom_gdn_prefill),
    "rom_rglru": Mixer(rom.rom_rglru_init, rom.rom_rglru_apply,
                       rom.rom_rglru_state_spec, rom.rom_rglru_step,
                       rom.rom_rglru_prefill),
    "rom_mlstm": Mixer(rom.rom_mlstm_init, rom.rom_mlstm_apply,
                       rom.rom_mlstm_state_spec, rom.rom_mlstm_step,
                       rom.rom_mlstm_prefill),
    "moemamba": Mixer(moe_mamba.moemamba_init, moe_mamba.moemamba_apply,
                      moe_mamba.moemamba_state_spec,
                      moe_mamba.moemamba_step,
                      moe_mamba.moemamba_prefill),
    "moa": Mixer(attn_moe.moa_init, _noctx(attn_moe.moa_apply)),
    "switchhead": Mixer(attn_moe.switchhead_init,
                        _noctx(attn_moe.switchhead_apply)),
}

#: Mixer kinds whose projection-expert leaves (``e_w_*``) and shared router
#: (``w_router`` — including moemamba's nested per-projection routers) are
#: hot-swappable at serve time through
#: :class:`repro.serve.expert_library.ExpertLibrary`.  FFN-MoE (``moe``)
#: experts are deliberately excluded: RoM's claim is about the projection
#: experts, and the library swaps exactly those.
EXPERT_SWAPPABLE = tuple(sorted(
    [k for k in MIXERS if k.startswith("rom_")] + ["moemamba"]))


def expert_block_keys(cfg):
    """Block keys holding swappable expert leaves, per segment:
    ``[(segment_index, "l{i}_{kind}"), ...]`` over ``cfg.segments``.  The
    expert library's extraction/graft walk — and its congruence checks —
    derive the swappable subtree of a param pytree from this."""
    out = []
    for si, (pattern, _repeats) in enumerate(cfg.segments):
        for i, kind in enumerate(pattern):
            if kind in EXPERT_SWAPPABLE:
                out.append((si, f"l{i}_{kind}"))
    return out


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(key, cfg):
    n_seg = len(cfg.segments)
    keys = jax.random.split(key, n_seg + 3)
    params: Dict[str, Any] = {}
    params["embed"] = embed_init(keys[0], cfg.vocab_size, cfg.d_model,
                                 dtype=cfg.param_dtype)
    if cfg.frontend is not None:
        from repro.nn.layers import dense_init
        k1, k2 = jax.random.split(keys[1])
        params["frontend_proj"] = dense_init(k1, cfg.frontend_dim,
                                             cfg.d_model,
                                             dtype=cfg.param_dtype)
        params["frontend_bias"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if cfg.kind == "encoder":
            params["mask_embed"] = (jax.random.normal(k2, (cfg.d_model,))
                                    * 0.02).astype(cfg.param_dtype)
    segs = []
    for si, (pattern, repeats) in enumerate(cfg.segments):
        def block_init(k, pattern=pattern):
            ks = jax.random.split(k, len(pattern))
            bp = {}
            for i, kind in enumerate(pattern):
                bp[f"l{i}_norm"] = rmsnorm_init(cfg.d_model)
                bp[f"l{i}_{kind}"] = MIXERS[kind].init(ks[i], cfg)
            return bp
        bkeys = jax.random.split(keys[2 + si], repeats)
        if repeats > 1 and cfg.scan_layers:
            segs.append(jax.vmap(block_init)(bkeys))
        else:
            segs.append([block_init(k) for k in bkeys])
    params["segments"] = segs
    params["final_norm"] = rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        from repro.nn.layers import dense_init
        params["lm_head"] = dense_init(keys[-1], cfg.d_model, cfg.vocab_size,
                                       dtype=cfg.param_dtype)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _block_apply(pattern, cfg, bp, x, rt: Runtime, rng):
    ctx: Dict[str, Any] = {}
    aux = jnp.zeros((len(METRIC_KEYS),), jnp.float32)
    rngs = jax.random.split(rng, len(pattern))
    for i, kind in enumerate(pattern):
        h = rmsnorm(bp[f"l{i}_norm"], x, cfg.norm_eps)
        y, a = MIXERS[kind].apply(bp[f"l{i}_{kind}"], h, cfg,
                                  rt.with_rng(rngs[i]), ctx)
        x = (x + y.astype(x.dtype))
        x = rt.shard.cons(x, "act_batch", "act_seq", "act_embed")
        aux = aux + pack_metrics(a)
    return x, aux


def _remat(fn, cfg):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def backbone(params, x, cfg, rt: Runtime):
    """x (B,S,D) embedded inputs -> (hidden (B,S,D), aux metrics vector)."""
    rng = rt.rng if rt.rng is not None else jax.random.PRNGKey(0)
    aux_total = jnp.zeros((len(METRIC_KEYS),), jnp.float32)
    for (pattern, repeats), seg in zip(cfg.segments, params["segments"]):
        blk = functools.partial(_block_apply, pattern, cfg)
        fn = _remat(lambda bp, h, r, blk=blk: blk(bp, h, rt, r), cfg)
        if isinstance(seg, list):
            rngs = jax.random.split(rng, repeats + 1)
            rng = rngs[0]
            for bp, r in zip(seg, rngs[1:]):
                x, aux = fn(bp, x, r)
                aux_total = aux_total + aux
        else:
            rngs = jax.random.split(rng, repeats + 1)
            rng = rngs[0]

            def body(carry, xs, fn=fn):
                bp, r = xs
                y, aux = fn(bp, carry, r)
                return y, aux

            x, auxs = jax.lax.scan(body, x, (seg, rngs[1:]))
            aux_total = aux_total + auxs.sum(0)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux_total


def embed_inputs(params, batch, cfg, rt: Runtime):
    """Return (B, S, D) embedded sequence from the model-kind's raw inputs."""
    cd = jnp.dtype(cfg.dtype)
    if cfg.kind == "encoder":
        x = (batch["frames"].astype(cd) @ params["frontend_proj"].astype(cd)
             + params["frontend_bias"].astype(cd))
        x = jnp.where(batch["mask"][..., None],
                      params["mask_embed"].astype(cd), x)
        return x
    tok = embed_lookup(params["embed"], batch["tokens"], cd)
    if cfg.kind == "vlm":
        pre = (batch["patches"].astype(cd)
               @ params["frontend_proj"].astype(cd)
               + params["frontend_bias"].astype(cd))
        tok = jnp.concatenate([pre, tok], axis=1)
    return tok


def logits_fn(params, hidden, cfg, rt: Runtime):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", hidden,
                            table.astype(hidden.dtype),
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("bsd,dv->bsv", hidden,
                            table.astype(hidden.dtype),
                            preferred_element_type=jnp.float32)
    logits = softcap(logits, cfg.logit_softcap)
    return rt.shard.cons(logits, "act_batch", "act_seq", "act_vocab")


def forward(params, batch, cfg, rt: Runtime):
    x = embed_inputs(params, batch, cfg, rt)
    x = rt.shard.cons(x, "act_batch", "act_seq", "act_embed")
    h, aux = backbone(params, x, cfg, rt)
    if cfg.kind == "vlm":
        h = h[:, batch["patches"].shape[1]:]
    logits = logits_fn(params, h, cfg, rt)
    return logits, aux


def loss_fn(params, batch, cfg, rt: Runtime):
    """Token cross-entropy (+ router aux losses). Returns (loss, metrics)."""
    logits, aux = forward(params, batch, cfg, rt)
    labels = batch["labels"]
    valid = (labels >= 0)
    if cfg.kind == "encoder":
        valid = valid & batch["mask"]
    lab = jnp.maximum(labels, 0)
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, lab[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * valid
    ntok = jnp.maximum(valid.sum(), 1)
    ce = nll.sum() / ntok
    metrics = {k: aux[i] for i, k in enumerate(METRIC_KEYS)}
    loss = ce + metrics["aux_loss"]          # aux summed over layers already
    metrics["ce"] = ce
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_state(cfg, batch, max_len, dtype):
    segs = []
    for pattern, repeats in cfg.segments:
        def block_state(pattern=pattern):
            st = {}
            for i, kind in enumerate(pattern):
                mx = MIXERS[kind]
                if mx.state_spec is None:
                    raise NotImplementedError(
                        f"{kind} has no decode state (train/prefill only)")
                st[f"l{i}_{kind}"] = mx.state_spec.init(cfg, batch, max_len,
                                                        dtype)
            return st
        if repeats > 1 and cfg.scan_layers:
            one = block_state()
            segs.append(jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (repeats,) + a.shape), one))
        else:
            segs.append([block_state() for _ in range(repeats)])
    return {"segments": segs}


def draft_layers(cfg, stride):
    """Static per-segment block-keep masks for the layer-skip draft model.

    Self-speculative decoding (serve/speculative.py) drafts tokens with a
    cheap reduced model: the same parameters and the same decode state, but
    only every ``stride``-th *block* (one repeat of a segment pattern) is
    applied.  Returns ``((keep_bool, ...), ...)`` — one tuple per segment,
    one bool per repeat — counting blocks globally across segments so the
    kept set is a uniform stride over depth.  Block 0 is always kept;
    ``stride=1`` keeps every block (the draft degenerates to the full
    model).  Pass the result as ``decode_step(..., keep=...)``.
    """
    if stride < 1:
        raise ValueError(f"draft stride must be >= 1, got {stride}")
    keeps, g = [], 0
    for _pattern, repeats in cfg.segments:
        seg = []
        for _ in range(repeats):
            seg.append(g % stride == 0)
            g += 1
        keeps.append(tuple(seg))
    return tuple(keeps)


def _block_step(pattern, cfg, bp, bst, x_t, pos, rt: Runtime):
    ctx: Dict[str, Any] = {}
    aux = jnp.zeros((len(METRIC_KEYS),), jnp.float32)
    new_st = {}
    for i, kind in enumerate(pattern):
        h = rmsnorm(bp[f"l{i}_norm"], x_t, cfg.norm_eps)
        key = f"l{i}_{kind}"
        y, st, a = MIXERS[kind].step(bp[key], h, bst[key], pos, cfg, rt, ctx)
        new_st[key] = st
        x_t = x_t + y.astype(x_t.dtype)
        aux = aux + pack_metrics(a)
    return x_t, new_st, aux


def decode_step_hidden(params, state, tokens_t, pos, cfg, rt: Runtime,
                       keep=None):
    """tokens_t (B, 1) int32; pos scalar int32 or (B,) per-slot positions.
    Returns (hidden (B, 1, D) post-final-norm, new_state) — the pre-logits
    split of :func:`decode_step`, for callers that fold the output
    projection into a fused sampling epilogue (``kernels.ops.logits_step``).

    ``keep`` (optional) is a per-segment tuple of per-repeat bools (see
    :func:`draft_layers`): blocks with ``False`` are skipped — the residual
    stream passes through unchanged and their state leaves are returned
    untouched — so the returned state keeps the full model's pytree
    structure and remains interchangeable with the serving
    :class:`~repro.serve.state.StateStore`.  Scan-stacked segments slice the
    kept repeats out of the stacked params/state with static indices, scan
    over the subset, and scatter the updated per-layer states back.
    """
    cd = jnp.dtype(cfg.dtype)
    x = embed_lookup(params["embed"], tokens_t, cd)
    x = rt.shard.cons(x, "act_batch", None, "act_embed")
    new_segs = []
    for si, ((pattern, repeats), seg, sst) in enumerate(
            zip(cfg.segments, params["segments"], state["segments"])):
        kseg = None if keep is None else keep[si]
        fn = functools.partial(_block_step, pattern, cfg)
        if isinstance(seg, list):
            outs = []
            for bi, (bp, bst) in enumerate(zip(seg, sst)):
                if kseg is not None and not kseg[bi]:
                    outs.append(bst)                 # skipped: state as-is
                    continue
                x, st, _ = fn(bp, bst, x, pos, rt)
                outs.append(st)
            new_segs.append(outs)
        else:
            def body(carry, xs, fn=fn):
                bp, bst = xs
                y, st, aux = fn(bp, bst, carry, pos, rt)
                return y, st

            if kseg is None or all(kseg):
                x, sts = jax.lax.scan(body, x, (seg, sst))
                new_segs.append(sts)
            elif not any(kseg):
                new_segs.append(sst)
            else:
                idx = jnp.asarray([i for i, k in enumerate(kseg) if k])
                sub_p = jax.tree_util.tree_map(lambda a: a[idx], seg)
                sub_s = jax.tree_util.tree_map(lambda a: a[idx], sst)
                x, sub_new = jax.lax.scan(body, x, (sub_p, sub_s))
                new_segs.append(jax.tree_util.tree_map(
                    lambda full, sub: full.at[idx].set(sub), sst, sub_new))
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return h, {"segments": new_segs}


def decode_step(params, state, tokens_t, pos, cfg, rt: Runtime, keep=None):
    """tokens_t (B, 1) int32 -> (logits (B, V), new_state).  See
    :func:`decode_step_hidden` for the pre-logits split."""
    h, new_state = decode_step_hidden(params, state, tokens_t, pos, cfg, rt,
                                      keep=keep)
    logits = logits_fn(params, h, cfg, rt)
    return logits[:, 0], new_state


# ---------------------------------------------------------------------------
# prefill: parallel forward over a prompt chunk, extracting decode state
# ---------------------------------------------------------------------------

def _block_prefill(pattern, cfg, bp, bst, x, pos0, rt: Runtime):
    ctx: Dict[str, Any] = {}
    aux = jnp.zeros((len(METRIC_KEYS),), jnp.float32)
    new_st = {}
    for i, kind in enumerate(pattern):
        h = rmsnorm(bp[f"l{i}_norm"], x, cfg.norm_eps)
        key = f"l{i}_{kind}"
        mx = MIXERS[kind]
        if mx.prefill is None:
            raise NotImplementedError(f"{kind} has no prefill path")
        y, st, a = mx.prefill(bp[key], h, bst[key], pos0, cfg, rt, ctx)
        new_st[key] = st
        x = x + y.astype(x.dtype)
        x = rt.shard.cons(x, "act_batch", "act_seq", "act_embed")
        aux = aux + pack_metrics(a)
    return x, new_st, aux


def prefill(params, state, tokens, pos0, cfg, rt: Runtime):
    """Parallel prefill: tokens (B,S) int32 at absolute positions
    [pos0, pos0+S) -> (logits (B,S,V), new decode state).

    Runs the training-style (whole-sequence) forward through every layer and
    extracts the terminal recurrent/conv/KV state, replacing S sequential
    decode steps with one parallel pass.  Composable over chunks: feed the
    returned state back in with ``pos0 += S`` to prefill long prompts in
    fixed-size chunks (bounded jit specializations).
    """
    cd = jnp.dtype(cfg.dtype)
    x = embed_lookup(params["embed"], tokens, cd)
    x = rt.shard.cons(x, "act_batch", "act_seq", "act_embed")
    new_segs = []
    for (pattern, repeats), seg, sst in zip(cfg.segments, params["segments"],
                                            state["segments"]):
        fn = functools.partial(_block_prefill, pattern, cfg)
        if isinstance(seg, list):
            outs = []
            for bp, bst in zip(seg, sst):
                x, st, _ = fn(bp, bst, x, pos0, rt)
                outs.append(st)
            new_segs.append(outs)
        else:
            def body(carry, xs, fn=fn):
                bp, bst = xs
                y, st, aux = fn(bp, bst, carry, pos0, rt)
                return y, st

            x, sts = jax.lax.scan(body, x, (seg, sst))
            new_segs.append(sts)
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_fn(params, h, cfg, rt)
    return logits, {"segments": new_segs}


# ---------------------------------------------------------------------------
# logical axes for decode-state leaves (mirrors sharding.AXES_BY_NAME)
# ---------------------------------------------------------------------------

STATE_AXES = {
    ("k", 4): ("act_batch", "act_kv_seq", None, None),
    ("v", 4): ("act_batch", "act_kv_seq", None, None),
    ("kpos", 2): ("act_batch", "act_kv_seq"),
    ("h", 2): ("act_batch", "act_inner"),             # rglru (B,R)
    ("h", 3): ("act_batch", "act_inner", None),       # mamba (B,De,N); slstm
    ("h", 4): ("act_batch", None, None, None),        # mamba2 (B,H,P,N)
    ("conv", 3): ("act_batch", None, "act_inner"),
    ("S", 4): ("act_batch", None, None, None),        # gdn
    ("C", 4): ("act_batch", None, None, None),        # mlstm
    ("n", 3): ("act_batch", None, None),              # mlstm/slstm
    ("m", 2): ("act_batch", None),                    # mlstm
    ("m", 3): ("act_batch", None, None),              # slstm
    ("c", 3): ("act_batch", None, None),              # slstm
}


def state_logical(path, leaf):
    name = None
    for entry in reversed(path):
        k = getattr(entry, "key", getattr(entry, "name", None))
        if isinstance(k, str):
            name = k
            break
    nd = len(leaf.shape)
    for cand in ((name, nd), (name, nd - 1)):
        if cand in STATE_AXES:
            ax = STATE_AXES[cand]
            if cand[1] == nd - 1:
                return ("layers",) + ax
            return ax
    # slstm 'h' 3-dim collides with mamba 'h' 3-dim; both resolve above.
    return (None,) * nd
