"""Encoder-only (HuBERT-style) model — thin façade over models/lm.py.

The encoder path is implemented inside ``models/lm.py`` (``cfg.kind ==
"encoder"``): the frame frontend is a stub projection per the task spec
(``input_specs`` provides precomputed frame embeddings), masked positions
are replaced by a learned ``mask_embed``, attention is bidirectional
(``causal=False``), and the loss is computed only at masked positions
(masked-unit prediction over ``vocab_size`` cluster units).

This module exposes the encoder-specific pieces under their natural names.
"""
from __future__ import annotations

from repro.models.lm import (embed_inputs, forward, init_params,  # noqa: F401
                             loss_fn)


def masked_accuracy(params, batch, cfg, rt):
    """Prediction accuracy at masked positions (eval metric)."""
    import jax.numpy as jnp

    logits, _ = forward(params, batch, cfg, rt)
    pred = jnp.argmax(logits, axis=-1)
    ok = (pred == batch["labels"]) & batch["mask"]
    return ok.sum() / jnp.maximum(batch["mask"].sum(), 1)
