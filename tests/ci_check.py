"""CI gate: run the tier-1 suite and fail only on regressions vs the
recorded seed baseline.

    python tests/ci_check.py [extra pytest args...]

Runs ``pytest -m "not slow"`` over tests/, then compares failures against
``tests/known_failures.txt``:

  * any collection error                       -> red
  * any failing test not in the known list     -> red  (regression)
  * known failure still failing                -> green (status quo)
  * known failure now passing                  -> green + notice to shrink
                                                  the list

A known-failures entry without a ``[param]`` suffix covers every
parametrization of that test.
"""
from __future__ import annotations

import re
import subprocess
import sys
from pathlib import Path

HERE = Path(__file__).resolve().parent
REPO = HERE.parent


def load_known():
    known = set()
    for line in (HERE / "known_failures.txt").read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            known.add(line)
    return known


def base_id(node_id: str) -> str:
    return re.sub(r"\[.*\]$", "", node_id)


def is_known(node_id: str, known) -> bool:
    return node_id in known or base_id(node_id) in known


def main(argv):
    cmd = [sys.executable, "-m", "pytest", "-q", "-rf", "--tb=line",
           "-m", "not slow", *argv]
    print("+", " ".join(cmd), flush=True)
    r = subprocess.run(cmd, cwd=REPO, capture_output=True, text=True)
    out = r.stdout + r.stderr
    sys.stdout.write(out)

    failed = re.findall(r"^FAILED ([^\s]+)", out, re.M)
    errors = re.findall(r"^ERROR ([^\s]+)", out, re.M)
    known = load_known()

    tail = out.strip().splitlines()[-1] if out.strip() else ""
    if errors or re.search(r"\d+ errors?\b", tail):
        print(f"\nCI: RED — collection/internal errors: {errors or tail}")
        return 1
    if r.returncode not in (0, 1):
        print(f"\nCI: RED — pytest exited {r.returncode} "
              "(usage error / interrupted)")
        return 1

    new = [f for f in failed if not is_known(f, known)]
    still_known = [f for f in failed if is_known(f, known)]
    fixed = sorted(k for k in known
                   if not any(is_known(f, {k}) for f in failed))

    if still_known:
        print(f"\nCI: {len(still_known)} known (seed-baseline) failures "
              "tolerated:")
        for f in still_known:
            print(f"  known: {f}")
    if fixed:
        print(f"\nCI: {len(fixed)} known-failure entries no longer fail — "
              "please remove them from tests/known_failures.txt:")
        for f in fixed:
            print(f"  fixed: {f}")
    if new:
        print(f"\nCI: RED — {len(new)} regression(s) vs seed baseline:")
        for f in new:
            print(f"  NEW FAILURE: {f}")
        return 1
    print("\nCI: GREEN — no regressions vs the recorded seed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
