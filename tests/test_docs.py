"""Docs cannot rot: every fenced code block in docs/*.md is checked.

Python blocks must parse, their import lines must execute (so renamed or
removed public symbols fail CI), and their top-level ``assert`` lines must
hold (docs snippets use asserts to state registry facts).  Bash blocks
must only reference script paths that exist.  README.md links to docs/
are checked too.
"""
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOCS = sorted((ROOT / "docs").glob("*.md"))

FENCE = re.compile(r"```(\w*)\n(.*?)```", re.DOTALL)


def blocks(path, lang):
    text = path.read_text()
    return [(m.start(), m.group(2)) for m in FENCE.finditer(text)
            if m.group(1) == lang]


def test_docs_exist_and_are_linked_from_readme():
    assert (ROOT / "docs" / "architecture.md").is_file()
    assert (ROOT / "docs" / "serving.md").is_file()
    readme = (ROOT / "README.md").read_text()
    assert "docs/architecture.md" in readme
    assert "docs/serving.md" in readme


@pytest.mark.parametrize("doc", DOCS, ids=[d.name for d in DOCS])
def test_python_snippets_parse(doc):
    found = blocks(doc, "python")
    for off, src in found:
        compile(src, f"{doc.name}@{off}", "exec")


@pytest.mark.parametrize("doc", DOCS, ids=[d.name for d in DOCS])
def test_python_snippet_setup_lines_execute(doc):
    """Execute each snippet's import and assert lines in a shared namespace
    per block: a renamed engine kwarg won't be caught, but every public
    symbol the docs name must exist where the docs say it lives."""
    for off, src in blocks(doc, "python"):
        ns = {}
        for stmt in _logical_lines(src):
            if stmt.startswith(("import ", "from ", "assert ")):
                exec(compile(stmt, f"{doc.name}@{off}", "exec"), ns)


def _logical_lines(src):
    """Top-level logical lines of a snippet (continuations joined by
    bracket balance, indented lines folded into their opener)."""
    out, buf, depth = [], [], 0
    for line in src.splitlines():
        if not line.strip() or line.lstrip().startswith("#"):
            continue
        if depth == 0 and line[:1].isspace():
            continue                                 # body of a def/if: skip
        buf.append(line)
        depth += sum(line.count(c) for c in "([{")
        depth -= sum(line.count(c) for c in ")]}")
        if depth <= 0:
            out.append("\n".join(buf))
            buf, depth = [], 0
    return out


@pytest.mark.parametrize("doc", DOCS, ids=[d.name for d in DOCS])
def test_bash_snippets_reference_real_entry_points(doc):
    """`python path/to/script.py` targets and `python -m repro.x` modules
    named in bash blocks must exist in the tree."""
    for _off, src in blocks(doc, "bash"):
        for tok in re.findall(r"(\S+\.py)\b", src):
            assert (ROOT / tok).is_file(), tok
        for mod in re.findall(r"python -m ([\w.]+)", src):
            rel = mod.replace(".", "/")
            p = ROOT / "src" / rel
            assert p.with_suffix(".py").is_file() or \
                (p / "__init__.py").is_file(), mod
