"""Prefill/decode equivalence: the serving engine's core correctness
obligation.  For every mixer kind and RoM dispatch impl, logits and state
from (parallel prefill -> N decode steps) must match per-token stepping
within dtype tolerance — including RoM expert routing decisions at the
prefill->decode boundary."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (AttentionConfig, GDNConfig, Mamba2Config,
                                MambaConfig, ModelConfig, RGLRUConfig,
                                RoMConfig, XLSTMConfig)
from repro.core import moe_mamba, rom
from repro.distributed.sharding import ShardCtx
from repro.nn import attention as attn
from repro.nn import rglru as rgl
from repro.nn import ssm
from repro.nn import xlstm as xl
from repro.nn.layers import Runtime

RT = Runtime(shard=ShardCtx())
B, S = 2, 13            # deliberately not a multiple of any chunk size


def _cfg(**kw):
    base = dict(name="t", d_model=32, vocab_size=64,
                segments=((("mamba",), 1),),
                mamba=MambaConfig(d_state=4, chunk=8),
                mamba2=Mamba2Config(d_state=8, head_dim=16, chunk=8),
                gdn=GDNConfig(num_heads=2, head_dim=8),
                rglru=RGLRUConfig(num_heads=2),
                xlstm=XLSTMConfig(num_heads=2, chunk=8),
                attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                          head_dim=8),
                rom=RoMConfig(num_experts=4, top_k=1, jitter_eps=0.0,
                              capacity_factor=4.0, impl="capacity"),
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def _step_reference(step, params, x, init_state, cfg, with_ctx):
    st = init_state(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        a = (params, x[:, t:t + 1], st, jnp.int32(t), cfg, RT)
        y, st, _ = step(*a, None) if with_ctx else step(*a)
        outs.append(y)
    return jnp.concatenate(outs, 1), st


def _assert_match(prefill, step, params, x, init_state, cfg, with_ctx,
                  tol):
    y_steps, st_steps = _step_reference(step, params, x, init_state, cfg,
                                        with_ctx)
    st0 = init_state(cfg, B, jnp.float32)
    a = (params, x, st0, jnp.int32(0), cfg, RT)
    y_pre, st_pre, _ = prefill(*a, None) if with_ctx else prefill(*a)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_steps),
                               atol=tol, rtol=tol)
    for k in st_steps:
        np.testing.assert_allclose(np.asarray(st_pre[k]),
                                   np.asarray(st_steps[k]),
                                   atol=tol, rtol=tol, err_msg=k)


MIX = [
    ("mamba", ssm.mamba_init, ssm.mamba_init_state, ssm.mamba_step,
     ssm.mamba_prefill, 5e-4),
    ("mamba2", ssm.mamba2_init, ssm.mamba2_init_state, ssm.mamba2_step,
     ssm.mamba2_prefill, 1e-3),
    ("gdn", ssm.gdn_init, ssm.gdn_init_state, ssm.gdn_step,
     ssm.gdn_prefill, 1e-3),
    ("rglru", rgl.rglru_init, rgl.rglru_init_state, rgl.rglru_step,
     rgl.rglru_prefill, 5e-4),
    ("mlstm", xl.mlstm_init, xl.mlstm_init_state, xl.mlstm_step,
     xl.mlstm_prefill, 1e-3),
    ("slstm", xl.slstm_init, xl.slstm_init_state, xl.slstm_step,
     xl.slstm_prefill, 5e-4),
]


@pytest.mark.parametrize("name,init,init_state,step,prefill,tol", MIX)
def test_prefill_matches_stepping(name, init, init_state, step, prefill,
                                  tol):
    cfg = _cfg()
    params = init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 32)) * 0.5
    _assert_match(prefill, step, params, x, init_state, cfg, False, tol)


ROM = [
    ("rom_mamba", rom.rom_mamba_init, rom.rom_mamba_init_state,
     rom.rom_mamba_step, rom.rom_mamba_prefill),
    ("rom_mamba2", rom.rom_mamba2_init, ssm.mamba2_init_state,
     rom.rom_mamba2_step, rom.rom_mamba2_prefill),
    ("rom_gdn", rom.rom_gdn_init, rom.rom_gdn_init_state,
     rom.rom_gdn_step, rom.rom_gdn_prefill),
    ("rom_rglru", rom.rom_rglru_init, rom.rom_rglru_init_state,
     rom.rom_rglru_step, rom.rom_rglru_prefill),
    ("rom_mlstm", rom.rom_mlstm_init, rom.rom_mlstm_init_state,
     rom.rom_mlstm_step, rom.rom_mlstm_prefill),
    ("moemamba", moe_mamba.moemamba_init, moe_mamba.moemamba_init_state,
     moe_mamba.moemamba_step, moe_mamba.moemamba_prefill),
]


@pytest.mark.parametrize("name,init,init_state,step,prefill", ROM)
@pytest.mark.parametrize("impl", ["dense", "capacity"])
def test_rom_prefill_matches_stepping(name, init, init_state, step, prefill,
                                      impl):
    """Routing decisions at the prefill->decode boundary must agree: the
    router is deterministic at inference, and capacity is sized so neither
    path drops tokens."""
    cfg = _cfg(rom=RoMConfig(num_experts=4, top_k=2, jitter_eps=0.0,
                             capacity_factor=8.0, impl=impl))
    params = init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 32)) * 0.5
    _assert_match(prefill, step, params, x, init_state, cfg, True, 2e-3)


@pytest.mark.parametrize("window", [None, 6])
def test_attention_prefill_matches_stepping(window):
    cfg = _cfg(attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                         head_dim=8, window=window))
    params = attn.attention_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 32)) * 0.5
    max_len = 20
    st = attn.attention_init_state(cfg, B, max_len, jnp.float32)
    outs = []
    for t in range(S):
        y, st, _ = attn.attention_step(params, x[:, t:t + 1], st,
                                       jnp.int32(t), cfg, RT)
        outs.append(y)
    y_steps = jnp.concatenate(outs, 1)
    st0 = attn.attention_init_state(cfg, B, max_len, jnp.float32)
    y_pre, st_pre, _ = attn.attention_prefill(params, x, st0, jnp.int32(0),
                                              cfg, RT)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_steps),
                               atol=5e-4, rtol=5e-4)
    for k in st:
        np.testing.assert_allclose(np.asarray(st_pre[k]), np.asarray(st[k]),
                                   atol=5e-4, rtol=5e-4, err_msg=k)


def test_chunked_prefill_composes():
    """Prefilling 13 tokens as 8+4+1 power-of-two chunks (the engine's
    decomposition) threads state identically to one pass / per-token."""
    cfg = _cfg()
    params = ssm.mamba_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 32)) * 0.5
    y_steps, st_steps = _step_reference(ssm.mamba_step, params, x,
                                        ssm.mamba_init_state, cfg, False)
    st = ssm.mamba_init_state(cfg, B, jnp.float32)
    ys, pos = [], 0
    for c in (8, 4, 1):
        y, st, _ = ssm.mamba_prefill(params, x[:, pos:pos + c], st,
                                     jnp.int32(pos), cfg, RT)
        ys.append(y)
        pos += c
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_steps), atol=5e-4, rtol=5e-4)
    for k in st_steps:
        np.testing.assert_allclose(np.asarray(st[k]),
                                   np.asarray(st_steps[k]),
                                   atol=5e-4, rtol=5e-4)


def test_model_prefill_then_decode_matches_full_stepping():
    """Whole-model check on a hybrid block (mamba + attn + mlp): prefill the
    prompt in one pass, then decode; logits must match stepping everything."""
    import repro.train as tr
    from repro.models import lm

    cfg = _cfg(segments=((("mamba", "attn", "mlp"), 2),), d_ff=64)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 2,
                              cfg.vocab_size)
    max_len = S + 4
    serve = tr.make_serve_fn(cfg)
    st = lm.init_state(cfg, B, max_len, jnp.dtype(cfg.dtype))
    for t in range(S):
        nxt, logits_ref, st = serve(params, st, toks[:, t:t + 1],
                                    jnp.int32(t))
    pf = tr.make_prefill_step_fn(cfg)
    st0 = lm.init_state(cfg, B, max_len, jnp.dtype(cfg.dtype))
    logits_pre, st_pre = pf(params, st0, toks, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(logits_pre[:, -1]),
                               np.asarray(logits_ref), atol=2e-3, rtol=2e-3)
    # continuing decode from either state gives the same next logits
    _, l1, _ = serve(params, st, toks[:, -1:], jnp.int32(S))
    _, l2, _ = serve(params, st_pre, toks[:, -1:], jnp.int32(S))
    np.testing.assert_allclose(np.asarray(l2), np.asarray(l1), atol=2e-3,
                               rtol=2e-3)
