"""Serving engine behaviour: continuous batching, per-slot positions,
admission/eviction (interleaved + sequential), slot-state store, sampling,
scheduling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.train as tr
from repro.configs.base import (AttentionConfig, GDNConfig, Mamba2Config,
                                MambaConfig, ModelConfig, RGLRUConfig,
                                RoMConfig, XLSTMConfig)
from repro.models import lm
from repro.serve import (FIFOScheduler, Request, SamplingParams, ServeEngine,
                         StateStore, sample)
from repro.serve.engine import prefill_chunks
from repro.serve.scheduler import ShortestPromptFirst


def _cfg(**kw):
    base = dict(name="t", d_model=32, vocab_size=64,
                segments=((("mamba", "attn"), 1),),
                mamba=MambaConfig(d_state=4, chunk=8),
                attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                          head_dim=8),
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def _greedy_reference(cfg, params, prompt, gen, max_len):
    serve = jax.jit(tr.make_serve_fn(cfg))
    st = lm.init_state(cfg, 1, max_len, jnp.dtype(cfg.dtype))
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    for t in range(toks.shape[1]):
        nxt, _, st = serve(params, st, toks[:, t:t + 1], jnp.int32(t))
    out, pos = [int(nxt[0])], toks.shape[1]
    while len(out) < gen:
        nxt, _, st = serve(params, st, nxt[:, None], jnp.int32(pos))
        out.append(int(nxt[0]))
        pos += 1
    return out


def test_engine_continuous_batching_matches_pertoken_greedy():
    """5 mixed-length requests on 3 slots (forces slot reuse): every
    request's greedy output must equal its isolated per-token decode."""
    cfg = _cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    max_len = 32
    rng = np.random.default_rng(0)
    lens = [4, 9, 3, 7, 11]
    reqs = [Request(id=i,
                    prompt=rng.integers(2, cfg.vocab_size, size=(n,)).tolist(),
                    max_new_tokens=6)
            for i, n in enumerate(lens)]
    eng = ServeEngine(cfg, params, max_slots=3, max_len=max_len, seed=0)
    results = {r.id: r for r in eng.run(reqs)}
    assert set(results) == set(range(5))
    for req in reqs:
        ref = _greedy_reference(cfg, params, req.prompt, 6, max_len)
        assert results[req.id].tokens == ref, req.id
        assert results[req.id].finish_reason == "length"
        assert results[req.id].ttft_s >= 0.0


def test_engine_eos_and_maxlen_eviction():
    cfg = _cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = [5, 6, 7]
    ref = _greedy_reference(cfg, params, prompt, 8, 32)
    eos = ref[2]                       # force an EOS hit at the 3rd token
    eng = ServeEngine(cfg, params, max_slots=2, max_len=32, seed=0)
    res = eng.run([Request(id=0, prompt=prompt, max_new_tokens=8,
                           eos_id=eos)])[0]
    assert res.finish_reason == "eos"
    assert res.tokens == ref[:3]
    # cache exhaustion: prompt 3 + decode to max_len ends the request
    eng2 = ServeEngine(cfg, params, max_slots=1, max_len=8, seed=0)
    res2 = eng2.run([Request(id=1, prompt=prompt, max_new_tokens=100)])[0]
    assert res2.finish_reason == "max_len"
    assert len(res2.tokens) == 8 - 3


def test_engine_rejects_bad_requests():
    cfg = _cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_slots=1, max_len=8)
    with pytest.raises(ValueError):
        eng.submit(Request(id=0, prompt=[]))
    with pytest.raises(ValueError):
        eng.submit(Request(id=1, prompt=list(range(8))))


def test_prefill_chunks_power_of_two():
    assert prefill_chunks(13, 64) == [8, 4, 1]
    assert prefill_chunks(64, 16) == [16, 16, 16, 16]
    assert prefill_chunks(1, 64) == [1]
    for n in range(1, 200):
        cs = prefill_chunks(n, 32)
        assert sum(cs) == n
        assert all(c & (c - 1) == 0 and c <= 32 for c in cs)


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def _sample(logits, rng, t, k, p):
    B = logits.shape[0]
    return np.asarray(sample(
        jnp.asarray(logits), rng,
        jnp.full((B,), t, jnp.float32),
        jnp.full((B,), k, jnp.int32),
        jnp.full((B,), p, jnp.float32)))


def test_sampling_greedy_is_argmax():
    logits = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (4, 32)))
    toks = _sample(logits, jax.random.PRNGKey(1), 0.0, 0, 1.0)
    np.testing.assert_array_equal(toks, logits.argmax(-1))


def test_sampling_topk_restricts_support():
    logits = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (2, 64)))
    top2 = np.argsort(logits, -1)[:, -2:]
    for i in range(20):
        toks = _sample(logits, jax.random.PRNGKey(i), 1.5, 2, 1.0)
        for b in range(2):
            assert toks[b] in top2[b]


def test_sampling_topp_restricts_support():
    # one dominant token (p=0.99 mass): nucleus 0.5 must always pick it
    logits = np.zeros((1, 16), np.float32)
    logits[0, 3] = 10.0
    for i in range(20):
        toks = _sample(logits, jax.random.PRNGKey(i), 1.0, 0, 0.5)
        assert toks[0] == 3


def test_sampling_topp_zero_is_top1():
    """top_p=0 must degenerate to top-1, not mask every token."""
    logits = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (3, 32)))
    for i in range(10):
        toks = _sample(logits, jax.random.PRNGKey(i), 1.0, 0, 0.0)
        np.testing.assert_array_equal(toks, logits.argmax(-1))


def test_sampling_temperature_spreads():
    logits = np.zeros((1, 8), np.float32)
    logits[0, 0] = 2.0
    seen = {int(_sample(logits, jax.random.PRNGKey(i), 5.0, 0, 1.0)[0])
            for i in range(64)}
    assert len(seen) > 1               # high temperature actually samples


def test_sampling_per_slot_params_are_independent():
    logits = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (2, 32)))
    toks = np.asarray(sample(
        jnp.asarray(logits), jax.random.PRNGKey(7),
        jnp.asarray([0.0, 2.0], jnp.float32),       # slot0 greedy
        jnp.asarray([0, 0], jnp.int32),
        jnp.asarray([1.0, 1.0], jnp.float32)))
    assert toks[0] == logits[0].argmax()


# ---------------------------------------------------------------------------
# scheduling
# ---------------------------------------------------------------------------

def test_fifo_scheduler_order():
    s = FIFOScheduler()
    for i in (3, 1, 2):
        s.add(Request(id=i, prompt=[0] * (i + 1)))
    assert [s.pop_next().id for _ in range(3)] == [3, 1, 2]
    assert s.pop_next() is None


def test_shortest_prompt_first():
    s = ShortestPromptFirst()
    for i, n in enumerate((5, 2, 9, 3)):
        s.add(Request(id=i, prompt=[0] * n))
    assert [s.pop_next().id for _ in range(4)] == [1, 3, 0, 2]
    assert s.pop_next() is None


def test_shortest_prompt_first_reevaluates_on_arrival():
    """A short prompt submitted mid-run must win the very next admission,
    not queue behind the ordering frozen when the run started."""
    s = ShortestPromptFirst()
    for i, n in enumerate((5, 9)):
        s.add(Request(id=i, prompt=[0] * n))
    assert s.pop_next().id == 0
    s.add(Request(id=2, prompt=[0] * 2))          # arrives mid-run
    assert s.pop_next().id == 2                   # beats the older, longer 1
    assert s.pop_next().id == 1


def test_shortest_prompt_first_fifo_tiebreak():
    s = ShortestPromptFirst()
    for i in range(4):
        s.add(Request(id=i, prompt=[0] * 3))
    assert [s.pop_next().id for _ in range(4)] == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# interleaved chunked prefill + slot-state store
# ---------------------------------------------------------------------------

def _full_cfg(segments, **kw):
    base = dict(name="t", d_model=32, vocab_size=64, segments=segments,
                d_ff=64,
                mamba=MambaConfig(d_state=4, chunk=8),
                mamba2=Mamba2Config(d_state=8, head_dim=16, chunk=8),
                gdn=GDNConfig(num_heads=2, head_dim=8),
                rglru=RGLRUConfig(num_heads=2),
                xlstm=XLSTMConfig(num_heads=2, chunk=8),
                attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                          head_dim=8),
                rom=RoMConfig(num_experts=4, top_k=2, jitter_eps=0.0,
                              capacity_factor=8.0, impl="capacity"),
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


PATTERNS = [("mamba", "attn"), ("mamba2",), ("gdn",), ("rglru",),
            ("mlstm",), ("slstm",), ("rom_mamba", "mlp")]


@pytest.mark.parametrize("pattern", PATTERNS,
                         ids=["+".join(p) for p in PATTERNS])
def test_interleaved_admission_matches_sequential(pattern):
    """Chunked prefill interleaved with decode — including batched prefill
    lanes — must produce bit-identical greedy tokens to the sequential
    engine.  4 mixed-length requests on 2 slots force requests 2 and 3 to be
    admitted while the first two are mid-decode."""
    cfg = _full_cfg(((pattern, 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    reqs = [Request(id=i,
                    prompt=rng.integers(2, cfg.vocab_size, size=(n,)).tolist(),
                    max_new_tokens=5)
            for i, n in enumerate([5, 11, 3, 7])]
    kw = dict(max_slots=2, max_len=32, seed=0, max_prefill_chunk=8)
    seq = ServeEngine(cfg, params, admission="sequential", **kw)
    ref = {r.id: r for r in seq.run(reqs)}
    inter = ServeEngine(cfg, params, admission="interleaved", **kw)
    got = {r.id: r for r in inter.run(reqs)}
    assert set(got) == set(ref) == {0, 1, 2, 3}
    for i in ref:
        assert got[i].tokens == ref[i].tokens, (pattern, i)
        assert got[i].finish_reason == ref[i].finish_reason
    # the interleaved engine must actually have mixed decode with prefill,
    # and every tick that began with live decode lanes must have advanced
    # decode (the measured stall-free invariant; sequential mode breaks it
    # in stall_s whenever admission prefills while lanes are live)
    assert inter.stats["mixed_steps"] > 0
    assert inter.stats["active_ticks"] == inter.stats["decode_steps"]
    assert inter.stats["stall_s"] == 0.0
    assert seq.stats["stall_s"] > 0.0


def test_interleaved_mid_run_submission_matches_reference():
    """A request submitted while decode is running is admitted via the mixed
    step and still decodes exactly its isolated greedy reference."""
    cfg = _cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(2, cfg.vocab_size, size=(n,)).tolist()
               for n in (6, 9, 4)]
    eng = ServeEngine(cfg, params, max_slots=2, max_len=32, seed=0,
                      max_prefill_chunk=8)
    eng.submit(Request(id=0, prompt=prompts[0], max_new_tokens=8))
    eng.submit(Request(id=1, prompt=prompts[1], max_new_tokens=8))
    results = []
    for _ in range(3):                             # decode is now active
        results.extend(eng.tick())
    eng.submit(Request(id=2, prompt=prompts[2], max_new_tokens=8))
    while eng.busy():
        results.extend(eng.tick())
    got = {r.id: r for r in results}
    assert set(got) == {0, 1, 2}
    for i, p in enumerate(prompts):
        assert got[i].tokens == _greedy_reference(cfg, params, p, 8, 32), i


def test_state_store_gather_insert_roundtrip():
    """Generic slot gather/insert over a hybrid model incl. a scan-stacked
    segment: adopted rows read back exactly; untouched slots keep their
    initial state."""
    cfg = _full_cfg(((("mamba", "attn"), 1), (("mamba",), 2)))
    store = StateStore(cfg, 4, 16, jnp.float32)
    k = jax.random.PRNGKey(0)
    src = jax.tree_util.tree_map(
        lambda a: jax.random.normal(k, a.shape).astype(a.dtype),
        store.fresh(2))
    store.adopt(src, rows=[0, 1], slots=[3, 1])
    got = store.gather([3, 1])
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(src)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    untouched = store.gather([0, 2])
    for a, b in zip(jax.tree_util.tree_leaves(untouched),
                    jax.tree_util.tree_leaves(store.fresh(2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
