"""Serving engine behaviour: continuous batching, per-slot positions,
admission/eviction (interleaved + sequential), slot-state store, sampling,
scheduling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from identity import PATTERNS, full_cfg as _full_cfg, \
    greedy_reference as _greedy_reference, small_cfg as _cfg
from repro.models import lm
from repro.serve import (FIFOScheduler, Request, SamplingParams, ServeEngine,
                         StateStore, sample)
from repro.serve.engine import prefill_chunks
from repro.serve.scheduler import ShortestPromptFirst


def test_engine_continuous_batching_matches_pertoken_greedy():
    """5 mixed-length requests on 3 slots (forces slot reuse): every
    request's greedy output must equal its isolated per-token decode."""
    cfg = _cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    max_len = 32
    rng = np.random.default_rng(0)
    lens = [4, 9, 3, 7, 11]
    reqs = [Request(id=i,
                    prompt=rng.integers(2, cfg.vocab_size, size=(n,)).tolist(),
                    max_new_tokens=6)
            for i, n in enumerate(lens)]
    eng = ServeEngine(cfg, params, max_slots=3, max_len=max_len, seed=0)
    results = {r.id: r for r in eng.run(reqs)}
    assert set(results) == set(range(5))
    for req in reqs:
        ref = _greedy_reference(cfg, params, req.prompt, 6, max_len)
        assert results[req.id].tokens == ref, req.id
        assert results[req.id].finish_reason == "length"
        assert results[req.id].ttft_s >= 0.0


def test_engine_eos_and_maxlen_eviction():
    cfg = _cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = [5, 6, 7]
    ref = _greedy_reference(cfg, params, prompt, 8, 32)
    eos = ref[2]                       # force an EOS hit at the 3rd token
    eng = ServeEngine(cfg, params, max_slots=2, max_len=32, seed=0)
    res = eng.run([Request(id=0, prompt=prompt, max_new_tokens=8,
                           eos_id=eos)])[0]
    assert res.finish_reason == "eos"
    assert res.tokens == ref[:3]
    # cache exhaustion: prompt 3 + decode to max_len ends the request
    eng2 = ServeEngine(cfg, params, max_slots=1, max_len=8, seed=0)
    res2 = eng2.run([Request(id=1, prompt=prompt, max_new_tokens=100)])[0]
    assert res2.finish_reason == "max_len"
    assert len(res2.tokens) == 8 - 3


def test_engine_rejects_bad_requests():
    cfg = _cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_slots=1, max_len=8)
    with pytest.raises(ValueError):
        eng.submit(Request(id=0, prompt=[]))
    with pytest.raises(ValueError):
        eng.submit(Request(id=1, prompt=list(range(8))))


def test_prefill_chunks_power_of_two():
    assert prefill_chunks(13, 64) == [8, 4, 1]
    assert prefill_chunks(64, 16) == [16, 16, 16, 16]
    assert prefill_chunks(1, 64) == [1]
    for n in range(1, 200):
        cs = prefill_chunks(n, 32)
        assert sum(cs) == n
        assert all(c & (c - 1) == 0 and c <= 32 for c in cs)


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def _sample(logits, rng, t, k, p):
    B = logits.shape[0]
    return np.asarray(sample(
        jnp.asarray(logits), rng,
        jnp.full((B,), t, jnp.float32),
        jnp.full((B,), k, jnp.int32),
        jnp.full((B,), p, jnp.float32)))


def test_sampling_greedy_is_argmax():
    logits = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (4, 32)))
    toks = _sample(logits, jax.random.PRNGKey(1), 0.0, 0, 1.0)
    np.testing.assert_array_equal(toks, logits.argmax(-1))


def test_sampling_topk_restricts_support():
    logits = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (2, 64)))
    top2 = np.argsort(logits, -1)[:, -2:]
    for i in range(20):
        toks = _sample(logits, jax.random.PRNGKey(i), 1.5, 2, 1.0)
        for b in range(2):
            assert toks[b] in top2[b]


def test_sampling_topp_restricts_support():
    # one dominant token (p=0.99 mass): nucleus 0.5 must always pick it
    logits = np.zeros((1, 16), np.float32)
    logits[0, 3] = 10.0
    for i in range(20):
        toks = _sample(logits, jax.random.PRNGKey(i), 1.0, 0, 0.5)
        assert toks[0] == 3


def test_sampling_topp_zero_is_top1():
    """top_p=0 must degenerate to top-1, not mask every token."""
    logits = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (3, 32)))
    for i in range(10):
        toks = _sample(logits, jax.random.PRNGKey(i), 1.0, 0, 0.0)
        np.testing.assert_array_equal(toks, logits.argmax(-1))


def test_sampling_temperature_spreads():
    logits = np.zeros((1, 8), np.float32)
    logits[0, 0] = 2.0
    seen = {int(_sample(logits, jax.random.PRNGKey(i), 5.0, 0, 1.0)[0])
            for i in range(64)}
    assert len(seen) > 1               # high temperature actually samples


def test_sampling_per_slot_params_are_independent():
    logits = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (2, 32)))
    toks = np.asarray(sample(
        jnp.asarray(logits), jax.random.PRNGKey(7),
        jnp.asarray([0.0, 2.0], jnp.float32),       # slot0 greedy
        jnp.asarray([0, 0], jnp.int32),
        jnp.asarray([1.0, 1.0], jnp.float32)))
    assert toks[0] == logits[0].argmax()


# ---------------------------------------------------------------------------
# scheduling
# ---------------------------------------------------------------------------

def test_fifo_scheduler_order():
    s = FIFOScheduler()
    for i in (3, 1, 2):
        s.add(Request(id=i, prompt=[0] * (i + 1)))
    assert [s.pop_next().id for _ in range(3)] == [3, 1, 2]
    assert s.pop_next() is None


def test_shortest_prompt_first():
    s = ShortestPromptFirst()
    for i, n in enumerate((5, 2, 9, 3)):
        s.add(Request(id=i, prompt=[0] * n))
    assert [s.pop_next().id for _ in range(4)] == [1, 3, 0, 2]
    assert s.pop_next() is None


def test_shortest_prompt_first_reevaluates_on_arrival():
    """A short prompt submitted mid-run must win the very next admission,
    not queue behind the ordering frozen when the run started."""
    s = ShortestPromptFirst()
    for i, n in enumerate((5, 9)):
        s.add(Request(id=i, prompt=[0] * n))
    assert s.pop_next().id == 0
    s.add(Request(id=2, prompt=[0] * 2))          # arrives mid-run
    assert s.pop_next().id == 2                   # beats the older, longer 1
    assert s.pop_next().id == 1


def test_shortest_prompt_first_fifo_tiebreak():
    s = ShortestPromptFirst()
    for i in range(4):
        s.add(Request(id=i, prompt=[0] * 3))
    assert [s.pop_next().id for _ in range(4)] == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# interleaved chunked prefill + slot-state store
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pattern", PATTERNS,
                         ids=["+".join(p) for p in PATTERNS])
def test_interleaved_admission_matches_sequential(pattern):
    """Chunked prefill interleaved with decode — including batched prefill
    lanes — must produce bit-identical greedy tokens to the sequential
    engine.  4 mixed-length requests on 2 slots force requests 2 and 3 to be
    admitted while the first two are mid-decode."""
    cfg = _full_cfg(((pattern, 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    reqs = [Request(id=i,
                    prompt=rng.integers(2, cfg.vocab_size, size=(n,)).tolist(),
                    max_new_tokens=5)
            for i, n in enumerate([5, 11, 3, 7])]
    kw = dict(max_slots=2, max_len=32, seed=0, max_prefill_chunk=8)
    seq = ServeEngine(cfg, params, admission="sequential", **kw)
    ref = {r.id: r for r in seq.run(reqs)}
    inter = ServeEngine(cfg, params, admission="interleaved", **kw)
    got = {r.id: r for r in inter.run(reqs)}
    assert set(got) == set(ref) == {0, 1, 2, 3}
    for i in ref:
        assert got[i].tokens == ref[i].tokens, (pattern, i)
        assert got[i].finish_reason == ref[i].finish_reason
    # the interleaved engine must actually have mixed decode with prefill,
    # and every tick that began with live decode lanes must have advanced
    # decode (the measured stall-free invariant; sequential mode breaks it
    # in stall_s whenever admission prefills while lanes are live)
    assert inter.stats["mixed_steps"] > 0
    assert inter.stats["active_ticks"] == inter.stats["decode_steps"]
    assert inter.stats["stall_s"] == 0.0
    assert seq.stats["stall_s"] > 0.0


def test_interleaved_mid_run_submission_matches_reference():
    """A request submitted while decode is running is admitted via the mixed
    step and still decodes exactly its isolated greedy reference."""
    cfg = _cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(2, cfg.vocab_size, size=(n,)).tolist()
               for n in (6, 9, 4)]
    eng = ServeEngine(cfg, params, max_slots=2, max_len=32, seed=0,
                      max_prefill_chunk=8)
    eng.submit(Request(id=0, prompt=prompts[0], max_new_tokens=8))
    eng.submit(Request(id=1, prompt=prompts[1], max_new_tokens=8))
    results = []
    for _ in range(3):                             # decode is now active
        results.extend(eng.tick())
    eng.submit(Request(id=2, prompt=prompts[2], max_new_tokens=8))
    while eng.busy():
        results.extend(eng.tick())
    got = {r.id: r for r in results}
    assert set(got) == {0, 1, 2}
    for i, p in enumerate(prompts):
        assert got[i].tokens == _greedy_reference(cfg, params, p, 8, 32), i


# ---------------------------------------------------------------------------
# self-speculative decoding
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pattern", PATTERNS,
                         ids=["+".join(p) for p in PATTERNS])
def test_speculative_greedy_bit_identical(pattern):
    """Greedy speculative decoding must emit bit-identical tokens to the
    non-speculative engine for every mixer pattern (incl. RoM).  Two-block
    models with draft stride 2 make the draft a genuinely reduced model
    (block 1 skipped), so rejections actually occur."""
    cfg = _full_cfg(((pattern, 2),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    def reqs():
        return [Request(id=i,
                        prompt=rng.integers(2, cfg.vocab_size,
                                            size=(n,)).tolist(),
                        max_new_tokens=6)
                for i, n in enumerate([5, 11, 3, 7])]
    rng = np.random.default_rng(7)
    kw = dict(max_slots=2, max_len=48, seed=0, max_prefill_chunk=8)
    ref = {r.id: r for r in ServeEngine(cfg, params, **kw).run(reqs())}
    rng = np.random.default_rng(7)
    spec = ServeEngine(cfg, params, speculative=3, draft_stride=2, **kw)
    got = {r.id: r for r in spec.run(reqs())}
    assert set(got) == set(ref) == {0, 1, 2, 3}
    for i in ref:
        assert got[i].tokens == ref[i].tokens, (pattern, i)
        assert got[i].finish_reason == ref[i].finish_reason
    assert spec.stats["spec_rounds"] > 0
    assert spec.stats["spec_drafted"] > 0


def test_speculative_k1_degenerates_to_baseline():
    """K=1 is the smallest window: one draft token, a two-step verify, and
    1-2 emitted tokens per round — still bit-identical to baseline."""
    cfg = _cfg(segments=((("mamba", "attn"), 2),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = [5, 6, 7, 9, 11]
    ref = _greedy_reference(cfg, params, prompt, 8, 32)
    eng = ServeEngine(cfg, params, max_slots=1, max_len=32, seed=0,
                      speculative=1, draft_stride=2)
    res = eng.run([Request(id=0, prompt=prompt, max_new_tokens=8)])[0]
    assert res.tokens == ref
    s = eng.stats
    # every round proposes exactly 1 token and emits 1 (reject) or 2
    assert s["spec_drafted"] == s["spec_rounds"]
    assert s["spec_rounds"] <= s["spec_emitted"] <= 2 * s["spec_rounds"]


def test_speculative_stride1_draft_is_full_model():
    """draft_stride=1 makes the draft the full model: greedy drafts always
    match the verify argmax, so every round accepts all K drafts and emits
    K+1 tokens (except a truncated final round)."""
    cfg = _cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = [5, 6, 7]
    ref = _greedy_reference(cfg, params, prompt, 9, 32)
    eng = ServeEngine(cfg, params, max_slots=1, max_len=32, seed=0,
                      speculative=2, draft_stride=1)
    res = eng.run([Request(id=0, prompt=prompt, max_new_tokens=9)])[0]
    assert res.tokens == ref
    s = eng.stats
    assert s["spec_accepted"] == s["spec_drafted"]   # full acceptance
    # 9 tokens: first from prefill, then 8 more in ceil(8/3) = 3 rounds
    assert s["spec_rounds"] == 3


def test_speculative_eos_inside_draft_window():
    """EOS proposed (and accepted) inside a draft window must truncate
    emission at the EOS token and retire the request, exactly like the
    baseline engine."""
    cfg = _cfg(segments=((("mamba", "attn"), 2),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = [5, 6, 7]
    ref = _greedy_reference(cfg, params, prompt, 8, 32)
    eos = ref[4]                     # EOS lands mid-window for K=3
    base = ServeEngine(cfg, params, max_slots=1, max_len=32, seed=0)
    want = base.run([Request(id=0, prompt=prompt, max_new_tokens=8,
                             eos_id=eos)])[0]
    eng = ServeEngine(cfg, params, max_slots=1, max_len=32, seed=0,
                      speculative=3, draft_stride=2)
    res = eng.run([Request(id=0, prompt=prompt, max_new_tokens=8,
                           eos_id=eos)])[0]
    assert res.finish_reason == "eos"
    assert res.tokens == want.tokens
    # the window's post-EOS suffix was dropped, not emitted
    assert res.tokens[-1] == eos
    assert eos not in res.tokens[:-1]


def test_speculative_maxlen_inside_draft_window():
    """Cache exhaustion mid-window: emission truncates at max_len and the
    tokens match the baseline engine's max_len-truncated output."""
    cfg = _cfg(segments=((("mamba", "attn"), 2),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = [5, 6, 7]
    base = ServeEngine(cfg, params, max_slots=1, max_len=10, seed=0)
    want = base.run([Request(id=1, prompt=prompt, max_new_tokens=100)])[0]
    eng = ServeEngine(cfg, params, max_slots=1, max_len=10, seed=0,
                      speculative=4, draft_stride=2)
    res = eng.run([Request(id=1, prompt=prompt, max_new_tokens=100)])[0]
    assert res.finish_reason == "max_len"
    assert res.tokens == want.tokens
    assert len(res.tokens) == 10 - 3


def test_spec_accept_full_rejection_and_acceptance():
    """Unit test of the acceptance rule: a draft disagreeing everywhere
    emits exactly 1 token (the full model's argmax — the baseline step);
    a draft agreeing everywhere emits K+1."""
    from repro.serve.sampling import spec_accept
    B, K, V = 2, 3, 16
    rng = np.random.default_rng(0)
    t_logits = jnp.asarray(rng.normal(size=(B, K + 1, V)).astype(np.float32))
    tgt = np.asarray(jnp.argmax(t_logits, -1))                 # (B,K+1)
    greedy = (jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.int32),
              jnp.ones((B,), jnp.float32))

    # full rejection: propose argmax+1 (mod V) everywhere
    bad = jnp.asarray((tgt[:, :K] + 1) % V, jnp.int32)
    toks, n = spec_accept(t_logits, t_logits[:, :K], bad,
                          jax.random.PRNGKey(0), *greedy)
    np.testing.assert_array_equal(np.asarray(n), [1, 1])
    np.testing.assert_array_equal(np.asarray(toks)[:, 0], tgt[:, 0])

    # full acceptance: propose the argmax chain itself
    good = jnp.asarray(tgt[:, :K], jnp.int32)
    toks, n = spec_accept(t_logits, t_logits[:, :K], good,
                          jax.random.PRNGKey(0), *greedy)
    np.testing.assert_array_equal(np.asarray(n), [K + 1, K + 1])
    np.testing.assert_array_equal(np.asarray(toks), tgt)

    # partial: slot 0 diverges at draft index 1 -> accepts 1 draft + fixup
    mixed = good.at[0, 1].set((tgt[0, 1] + 1) % V)
    toks, n = spec_accept(t_logits, t_logits[:, :K], mixed,
                          jax.random.PRNGKey(0), *greedy)
    np.testing.assert_array_equal(np.asarray(n), [2, K + 1])
    np.testing.assert_array_equal(np.asarray(toks)[0, :2], tgt[0, :2])


def test_spec_accept_sampled_restricts_support():
    """Sampled acceptance: every emitted token must lie in the *filtered*
    target support (top-k), whatever the draft proposed."""
    from repro.serve.sampling import spec_accept
    B, K, V = 3, 2, 32
    rng = np.random.default_rng(1)
    t_logits = jnp.asarray(rng.normal(size=(B, K + 1, V)).astype(np.float32))
    d_logits = jnp.asarray(rng.normal(size=(B, K, V)).astype(np.float32))
    top2 = np.argsort(np.asarray(t_logits), -1)[..., -2:]      # (B,K+1,2)
    params = (jnp.full((B,), 1.3, jnp.float32), jnp.full((B,), 2, jnp.int32),
              jnp.ones((B,), jnp.float32))
    for i in range(16):
        d_toks = jnp.asarray(rng.integers(0, V, size=(B, K)), jnp.int32)
        toks, n = spec_accept(t_logits, d_logits, d_toks,
                              jax.random.PRNGKey(i), *params)
        toks, n = np.asarray(toks), np.asarray(n)
        for b in range(B):
            m = n[b] - 1
            # accepted drafts passed a p(d)/q(d) test against top-2-filtered
            # p, so they lie in the target's top-2; so does the tail token
            for j in range(m):
                assert toks[b, j] in top2[b, j], (b, j)
            assert toks[b, m] in top2[b, m], b


def test_speculative_draft_layers_layout():
    from repro.models import lm as lm_mod
    cfg = _cfg(segments=((("mamba",), 3), (("attn",), 2)))
    assert lm_mod.draft_layers(cfg, 2) == ((True, False, True),
                                           (False, True))
    assert lm_mod.draft_layers(cfg, 1) == ((True, True, True), (True, True))
    with pytest.raises(ValueError):
        lm_mod.draft_layers(cfg, 0)


def test_speculative_interleaved_admission_matches_baseline():
    """Speculative decode composed with interleaved admission (the spec
    mixed step): mid-run arrivals prefill while other slots advance by
    multi-token windows; greedy tokens still match the plain engine."""
    cfg = _cfg(segments=((("mamba", "attn"), 2),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(2, cfg.vocab_size, size=(n,)).tolist()
               for n in (6, 9, 4, 5)]
    def reqs():
        return [Request(id=i, prompt=p, max_new_tokens=8)
                for i, p in enumerate(prompts)]
    kw = dict(max_slots=2, max_len=32, seed=0, max_prefill_chunk=8)
    ref = {r.id: r for r in ServeEngine(cfg, params, **kw).run(reqs())}
    spec = ServeEngine(cfg, params, speculative=3, draft_stride=2, **kw)
    got = {r.id: r for r in spec.run(reqs())}
    assert spec.stats["mixed_steps"] > 0      # admission actually interleaved
    for i in ref:
        assert got[i].tokens == ref[i].tokens, i


def test_state_store_gather_insert_roundtrip():
    """Generic slot gather/insert over a hybrid model incl. a scan-stacked
    segment: adopted rows read back exactly; untouched slots keep their
    initial state."""
    cfg = _full_cfg(((("mamba", "attn"), 1), (("mamba",), 2)))
    store = StateStore(cfg, 4, 16, jnp.float32)
    k = jax.random.PRNGKey(0)
    src = jax.tree_util.tree_map(
        lambda a: jax.random.normal(k, a.shape).astype(a.dtype),
        store.fresh(2))
    store.adopt(src, rows=[0, 1], slots=[3, 1])
    got = store.gather([3, 1])
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(src)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    untouched = store.gather([0, 2])
    for a, b in zip(jax.tree_util.tree_leaves(untouched),
                    jax.tree_util.tree_leaves(store.fresh(2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
