"""Multi-device semantics, run in subprocesses with fake device counts
(the main process must keep 1 device — see conftest)."""
import pytest

pytestmark = pytest.mark.slow


def test_ep_matches_capacity_8dev(subproc):
    """Expert-parallel shard_map path == replicated capacity path."""
    subproc("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs.base import *
from repro.core import rom_ffn
from repro.distributed.sharding import ShardCtx
from repro.nn.layers import Runtime

mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = ModelConfig(name="t", d_model=16, vocab_size=32,
                  segments=((("moe",), 1),),
                  moe=MoEConfig(num_experts=8, top_k=2, d_ff=24, impl="ep",
                                capacity_factor=8.0))
p = rom_ffn.moe_ffn_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 16)) * 0.5
rt = Runtime(shard=ShardCtx(mesh))
y_ep = jax.jit(lambda p, x: rom_ffn.moe_ffn_apply(p, x, cfg, rt)[0])(p, x)
alias = {k.replace("ep_w", "e_w"): v for k, v in p.items()}
cfg_c = cfg.replace(moe=dataclasses.replace(cfg.moe, impl="capacity"))
rt0 = Runtime(shard=ShardCtx())
y_c, _ = rom_ffn.moe_ffn_apply(alias, x, cfg_c, rt0)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_c),
                           atol=2e-4, rtol=2e-4)
print("EP == capacity OK")
""", n_devices=8)


def test_compressed_psum_error_feedback(subproc):
    """bf16 all-reduce with EF: single step close to exact; accumulated sum
    over steps is closer than without EF."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.optim.compression import compressed_psum_grads, ef_init_stacked

mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
R = 8
key = jax.random.PRNGKey(0)
params = {"w": jnp.zeros((64,))}
err = ef_init_stacked(params, R)
acc_c, acc_e = np.zeros(64), np.zeros(64)
for step in range(20):
    g = {"w": jax.random.normal(jax.random.fold_in(key, step), (R, 64))
         * (1.0 + 1000.0 * (step % 3 == 0))}
    exact = np.asarray(g["w"].mean(0))
    red, err = compressed_psum_grads(g, err, mesh, dp_axes=("data",))
    acc_c += np.asarray(red["w"]); acc_e += exact
rel = np.abs(acc_c - acc_e).max() / np.abs(acc_e).max()
assert rel < 0.01, rel
print("EF compression OK, rel:", rel)
""", n_devices=8)


def test_train_step_multidevice_matches_single(subproc):
    """pjit train step on a (2,2) mesh == single-device step (same math)."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro import train as tr
from repro.configs.all_configs import reduce_for_smoke
from repro.configs.base import get_config
from repro.data.pipeline import corpus_for

cfg = reduce_for_smoke(get_config("rom-mamba-115m"))
mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
state = tr.init_train_state(cfg)
corpus = corpus_for(cfg, 32, 4)
batch = {k: jnp.asarray(v) for k, v in corpus.batch_at(0).items()}
s1, m1 = jax.jit(tr.make_train_fn(cfg))(state, batch)
step2 = tr.make_train_step(cfg, mesh, donate=False)
s2, m2 = step2(state, batch)
np.testing.assert_allclose(float(m1["ce"]), float(m2["ce"]), rtol=1e-5)
for a, b in zip(jax.tree_util.tree_leaves(s1["params"]),
                jax.tree_util.tree_leaves(s2["params"])):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=2e-3)
print("multidevice == single OK, ce:", float(m2["ce"]))
""", n_devices=4)


def test_elastic_restore_across_device_counts(subproc, tmp_path):
    """Checkpoint written under a 4-device mesh restores under 2 devices."""
    d = str(tmp_path)
    subproc(f"""
import jax, jax.numpy as jnp
from repro import checkpoint as ckpt, train as tr
from repro.configs.all_configs import reduce_for_smoke
from repro.configs.base import get_config

cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
mesh = jax.make_mesh((4, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
state = tr.init_train_state(cfg, seed=11)
shapes = tr.train_state_shapes(cfg)
sh = tr.state_shardings(shapes, mesh)
state = jax.device_put(state, sh)
ckpt.save({d!r}, 5, state)
print("saved under 4-dev mesh")
""", n_devices=4)
    subproc(f"""
import jax, numpy as np
from repro import checkpoint as ckpt, train as tr
from repro.configs.all_configs import reduce_for_smoke
from repro.configs.base import get_config

cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
mesh = jax.make_mesh((2, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
shapes = tr.train_state_shapes(cfg)
sh = tr.state_shardings(shapes, mesh)
restored, step = ckpt.restore({d!r}, shapes, shardings=sh)
assert step == 5
leaf = jax.tree_util.tree_leaves(restored["params"])[0]
assert len(leaf.sharding.device_set) in (1, 2)
print("elastic restore to 2-dev mesh OK")
""", n_devices=2)


def test_flash_decode_matches_dus(subproc):
    """shard_map flash-decoding (seq-sharded cache, §Perf cell C) computes
    exactly the DUS baseline, full and windowed."""
    subproc("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs.base import AttentionConfig, ModelConfig
from repro.distributed.sharding import ShardCtx
from repro.nn import attention as attn
from repro.nn.layers import Runtime

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
for window in (None, 8):
    cfg = ModelConfig(
        name="t", d_model=32, vocab_size=64, segments=((("attn",), 1),),
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=8,
                                  window=window, decode="flash"),
        dtype="float32")
    cfg_d = cfg.replace(attention=dataclasses.replace(cfg.attention,
                                                      decode="dus"))
    params = attn.attention_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 32))
    rt = Runtime(shard=ShardCtx(mesh))
    rt0 = Runtime(shard=ShardCtx())
    st_f = attn.attention_init_state(cfg, B, S, jnp.float32)
    st_d = attn.attention_init_state(cfg_d, B, S, jnp.float32)
    for t in range(S):
        yf, st_f, _ = attn.attention_step(params, x[:, t:t+1], st_f,
                                          jnp.int32(t), cfg, rt)
        yd, st_d, _ = attn.attention_step(params, x[:, t:t+1], st_d,
                                          jnp.int32(t), cfg_d, rt0)
        np.testing.assert_allclose(np.asarray(yf), np.asarray(yd),
                                   atol=1e-5)
print("flash decode == dus OK")
""", n_devices=8)


def test_rom_dispatch_stays_local_under_dp(subproc):
    """Paper's no-EP design: RoM layer lowered under pure DP must emit ZERO
    all-to-all collectives (dispatch groups align with batch shards)."""
    subproc("""
import jax, jax.numpy as jnp
from repro.configs.base import *
from repro.core import rom
from repro.distributed.sharding import ShardCtx
from repro.nn.layers import Runtime
from jax.sharding import NamedSharding, PartitionSpec as P

mesh = jax.make_mesh((8, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = ModelConfig(name="t", d_model=32, vocab_size=64,
                  segments=((("rom_mamba",), 1),),
                  mamba=MambaConfig(d_state=4, chunk=8),
                  rom=RoMConfig(num_experts=8, top_k=1, jitter_eps=0.0))
p = rom.rom_mamba_init(jax.random.PRNGKey(0), cfg)
rt = Runtime(shard=ShardCtx(mesh))
x = jax.ShapeDtypeStruct((16, 32, 32), jnp.float32)
f = jax.jit(lambda p, x: rom.rom_mamba_apply(p, x, cfg, rt)[0],
            in_shardings=(None, NamedSharding(mesh, P("data", None, None))))
txt = f.lower(jax.eval_shape(lambda: p), x).compile().as_text()
assert "all-to-all" not in txt, "dispatch crossed device boundaries!"
print("RoM dispatch is DP-local (no all-to-all) OK")
""", n_devices=8)
