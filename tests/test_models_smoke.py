"""Per-assigned-architecture smoke tests (spec deliverable f):
reduced same-family config, one forward/train step on CPU, asserting output
shapes and finiteness; decode smoke where the family supports it."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import train as tr
from repro.configs.all_configs import reduce_for_smoke
from repro.configs.base import ASSIGNED_ARCHS, get_config
from repro.data.pipeline import corpus_for
from repro.models import lm

PAPER_SMOKE = ["rom-mamba-115m", "samba-421m-rom", "samba-511m-rom-ffnmoe",
               "samba-421m-moemamba", "samba-421m-moa",
               "samba-421m-switchhead", "mamba2-rom-353m", "gdn-rom-343m",
               "rom-xlstm-350m", "rom-recurrentgemma-2b"]


@pytest.mark.parametrize("arch", list(ASSIGNED_ARCHS) + PAPER_SMOKE)
def test_arch_train_step(arch):
    cfg = reduce_for_smoke(get_config(arch))
    B, S = 4, 32
    state = tr.init_train_state(cfg)
    corpus = corpus_for(cfg, S, B)
    batch = {k: jnp.asarray(v) for k, v in corpus.batch_at(0).items()}
    hp = tr.TrainHParams(base_lr=1e-2, warmup_steps=1, total_steps=10)
    step = jax.jit(tr.make_train_fn(cfg, hp=hp))
    new_state, metrics = step(state, batch)
    assert int(new_state["step"]) == 1
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert float(metrics["grad_norm"]) > 0
    # params actually changed (embedding always receives gradient)
    d0 = np.asarray(state["params"]["embed"])
    d1 = np.asarray(new_state["params"]["embed"])
    assert not np.allclose(d0, d1)


DECODE_ARCHS = [a for a in ASSIGNED_ARCHS
                if get_config(a).kind != "encoder"] + ["samba-421m-rom"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_arch_decode_step(arch):
    cfg = reduce_for_smoke(get_config(arch))
    if any(k in ("moa", "switchhead")
           for p, _ in cfg.segments for k in p):
        pytest.skip("attention-MoE baselines are train/prefill-only")
    B = 2
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    state = lm.init_state(cfg, B, 16, jnp.dtype(cfg.dtype))
    serve = jax.jit(tr.make_serve_fn(cfg))
    tok = jnp.ones((B, 1), jnp.int32)
    for pos in range(3):
        nxt, logits, state = serve(params, state, tok, jnp.int32(pos))
        tok = nxt[:, None]
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_encoder_masked_loss_only_on_masked():
    cfg = reduce_for_smoke(get_config("hubert-xlarge"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    corpus = corpus_for(cfg, 32, 2)
    b = {k: jnp.asarray(v) for k, v in corpus.batch_at(0).items()}
    from repro.distributed.sharding import ShardCtx
    rt = lm.Runtime(shard=ShardCtx())
    loss1, _ = lm.loss_fn(params, b, cfg, rt)
    # changing labels at UNmasked positions must not change the loss
    b2 = dict(b)
    b2["labels"] = jnp.where(b["mask"], b["labels"],
                             (b["labels"] + 7) % cfg.vocab_size)
    loss2, _ = lm.loss_fn(params, b2, cfg, rt)
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-6)


def test_vlm_prefix_changes_text_logits():
    cfg = reduce_for_smoke(get_config("pixtral-12b"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    corpus = corpus_for(cfg, 32, 2)
    b = {k: jnp.asarray(v) for k, v in corpus.batch_at(0).items()}
    from repro.distributed.sharding import ShardCtx
    rt = lm.Runtime(shard=ShardCtx())
    logits1, _ = lm.forward(params, b, cfg, rt)
    b2 = dict(b)
    b2["patches"] = b["patches"] + 1.0
    logits2, _ = lm.forward(params, b2, cfg, rt)
    assert logits1.shape[1] == b["tokens"].shape[1]     # text positions only
    assert not np.allclose(np.asarray(logits1), np.asarray(logits2))


def test_long_context_skip_rules():
    from repro.configs.base import applicable_shapes
    qwen = applicable_shapes(get_config("qwen1.5-4b"))
    assert qwen["long_500k"][0] is None                  # full attn: skipped
    assert qwen["decode_32k"][0] is not None
    xl = applicable_shapes(get_config("xlstm-350m"))
    assert xl["long_500k"][0] is not None                # ssm: runs
    rg = applicable_shapes(get_config("recurrentgemma-2b"))
    assert rg["long_500k"][0] is not None                # swa hybrid: runs
    hb = applicable_shapes(get_config("hubert-xlarge"))
    assert hb["decode_32k"][0] is None                   # encoder: no decode
    assert hb["long_500k"][0] is None
    samba = applicable_shapes(get_config("samba-421m"))
    assert samba["long_500k"][0] is not None             # swa: sub-quadratic


def test_grad_accum_matches_single_batch():
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    state = tr.init_train_state(cfg)
    corpus = corpus_for(cfg, 16, 8)
    batch = {k: jnp.asarray(v) for k, v in corpus.batch_at(0).items()}
    s1, m1 = jax.jit(tr.make_train_fn(cfg))(state, batch)
    hp = tr.TrainHParams(grad_accum=4)
    s2, m2 = jax.jit(tr.make_train_fn(cfg, hp=hp))(state, batch)
    np.testing.assert_allclose(float(m1["ce"]), float(m2["ce"]), rtol=1e-4)
    l1 = jax.tree_util.tree_leaves(s1["params"])
    l2 = jax.tree_util.tree_leaves(s2["params"])
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3,
                                   rtol=5e-2)
