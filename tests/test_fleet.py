"""Disaggregated fleet serving (serve/fleet/): codec, tier, workers, router.

Fast tests (model-free, no jax dispatch): the snapshot codec's strict
round-trip/rejection contract, fleet message framing, the SharedCacheTier
probe/LRU/persistence behavior, the PrefixCache tier fall-through, and
the inspect CLI.

Engine-level tests (single device, small configs): prefill-to-snapshot /
admit-from-snapshot identity against the monolithic engine, the full
router fleet — cooperative and threaded — bit-identical per mixer
pattern (incl. rom_mamba and multi-tenant expert-set routing), retry /
requeue on drained workers, and cache persistence round-trips with
bit-identical continuations.

Cross-mesh parity (slow, subprocess with a forced 8-device host): a
prefill replica on ``data=2`` feeding a single-device decode replica
through codec bytes, and a cache file saved on one mesh serving hits on
another — CI runs these in the 8-virtual-device job.
"""
import os

import numpy as np
import pytest

from repro.serve.fleet import inspect as fleet_inspect
from repro.serve.fleet.cache_tier import (SharedCacheTier, load_prefix_cache,
                                          save_prefix_cache)
from repro.serve.fleet.codec import (CODEC_VERSION, CorruptError,
                                     FingerprintError, SchemaError,
                                     SnapshotCodec, config_fingerprint,
                                     pack_message, read_header,
                                     unpack_message)

# ---------------------------------------------------------------------------
# codec: round-trip and strict rejection (model-free)
# ---------------------------------------------------------------------------


def _demo_snap():
    rng = np.random.default_rng(0)
    return {
        "segments": [
            {"conv": rng.standard_normal((1, 4, 8)).astype(np.float32),
             "ssm": rng.standard_normal((1, 2, 4)).astype(np.float16)},
            {"kv": rng.integers(-5, 5, (1, 3, 2)).astype(np.int8)},
        ],
        "pos": np.asarray([7], np.int32),
    }


def _tree_equal(a, b):
    if isinstance(a, dict):
        return (isinstance(b, dict) and a.keys() == b.keys()
                and all(_tree_equal(a[k], b[k]) for k in a))
    if isinstance(a, list):
        return (isinstance(b, list) and len(a) == len(b)
                and all(_tree_equal(x, y) for x, y in zip(a, b)))
    return (a.dtype == b.dtype and a.shape == b.shape
            and bool(np.array_equal(a, b)))


def test_codec_round_trip_bit_exact():
    codec = SnapshotCodec("f" * 16)
    snap = _demo_snap()
    blob = codec.encode(snap)
    assert blob[:4] == b"RMSN"
    out = codec.decode(blob)
    assert _tree_equal(snap, out)
    # encode is deterministic: same snapshot -> same bytes
    assert codec.encode(snap) == blob


def test_codec_header_is_self_describing():
    codec = SnapshotCodec("a" * 16)
    hdr = read_header(codec.encode(_demo_snap()))
    assert hdr["version"] == CODEC_VERSION
    assert hdr["fingerprint"] == "a" * 16
    paths = {e["path"] for e in hdr["leaves"]}
    assert "/segments/0/conv" in paths and "/pos" in paths
    by_path = {e["path"]: e for e in hdr["leaves"]}
    assert by_path["/segments/0/ssm"]["dtype"] == np.dtype(np.float16).str
    assert by_path["/segments/1/kv"]["shape"] == [1, 3, 2]


def test_codec_rejects_wrong_fingerprint():
    blob = SnapshotCodec("a" * 16).encode(_demo_snap())
    with pytest.raises(FingerprintError):
        SnapshotCodec("b" * 16).decode(blob)


def test_codec_rejects_wrong_magic_and_version():
    codec = SnapshotCodec("a" * 16)
    blob = codec.encode(_demo_snap())
    with pytest.raises(SchemaError):
        codec.decode(b"XXXX" + blob[4:])
    with pytest.raises(SchemaError):        # a message is not a snapshot
        codec.decode(pack_message({"kind": "request"}))


def test_codec_rejects_truncation_and_tamper():
    codec = SnapshotCodec("a" * 16)
    blob = codec.encode(_demo_snap())
    for cut in (0, 3, 11, len(blob) // 2, len(blob) - 1):
        with pytest.raises(CorruptError):
            codec.decode(blob[:cut])
    # flip one payload byte -> leaf crc catches it
    tampered = bytearray(blob)
    tampered[-1] ^= 0xFF
    with pytest.raises(CorruptError):
        codec.decode(bytes(tampered))
    # flip one header byte -> header crc catches it
    tampered = bytearray(blob)
    tampered[14] ^= 0xFF
    with pytest.raises(CorruptError):
        codec.decode(bytes(tampered))


def test_codec_rejects_unencodable_leaves():
    from repro.serve.fleet.codec import CodecError
    codec = SnapshotCodec("a" * 16)
    with pytest.raises(CorruptError):
        codec.decode(b"")
    with pytest.raises(CodecError):
        codec.encode({"bad": object()})


def test_codec_append_only_flags_travel_and_are_enforced():
    snap = {"conv": np.zeros((2, 3), np.float32),
            "kv": np.zeros((4,), np.float32)}
    flags = {"conv": False, "kv": True}
    codec = SnapshotCodec("a" * 16, flags=flags)
    blob = codec.encode(snap)
    by_path = {e["path"]: e for e in read_header(blob)["leaves"]}
    assert by_path["/kv"]["append_only"] is True
    assert by_path["/conv"]["append_only"] is False
    assert _tree_equal(codec.decode(blob), snap)
    # an engine whose StateSpec disagrees on the flag refuses the blob
    other = SnapshotCodec("a" * 16, flags={"conv": True, "kv": True})
    with pytest.raises(CorruptError):
        other.decode(blob)


def test_config_fingerprint_sensitivity():
    from identity import small_cfg
    cfg = small_cfg()
    fp = config_fingerprint(cfg, 32, "float32")
    assert fp == config_fingerprint(cfg, 32, "float32")
    assert fp != config_fingerprint(cfg, 64, "float32")
    assert fp != config_fingerprint(cfg, 32, "float16")
    assert fp != config_fingerprint(small_cfg(d_model=64), 32, "float32")


def test_message_framing_round_trip_and_rejection():
    meta = {"kind": "admit", "first_token": 7, "request": {"id": 3}}
    data = pack_message(meta, b"payload-bytes")
    got_meta, got_blob = unpack_message(data)
    assert got_meta == meta and got_blob == b"payload-bytes"
    with pytest.raises(CorruptError):
        unpack_message(data[:-1])
    tam = bytearray(data)
    tam[-1] ^= 1
    with pytest.raises(CorruptError):
        unpack_message(bytes(tam))
    with pytest.raises(SchemaError):
        unpack_message(SnapshotCodec("a" * 16).encode(_demo_snap()))


# ---------------------------------------------------------------------------
# SharedCacheTier (model-free)
# ---------------------------------------------------------------------------


def test_tier_longest_prefix_probe_and_cap():
    tier = SharedCacheTier(budget_mb=1.0)
    assert tier.put((1, 2, 3), b"abc")
    assert tier.put((1, 2, 3, 4, 5), b"abcde")
    # full prompt never restorable: cap = len - 1
    assert tier.longest_prefix([1, 2, 3]) == (0, None) or \
        tier.longest_prefix([1, 2, 3])[0] < 3
    n, blob = tier.longest_prefix([1, 2, 3, 9])
    assert (n, blob) == (3, b"abc")
    n, blob = tier.longest_prefix([1, 2, 3, 4, 5, 6])
    assert (n, blob) == (5, b"abcde")
    assert tier.peek_len([1, 2, 3, 4, 5, 6]) == 5
    assert tier.longest_prefix([7, 8]) == (0, None)
    # namespaces are isolated
    assert tier.peek_len([1, 2, 3, 9], ns="a") == 0
    assert tier.put((1, 2), b"xy", ns="a")
    assert tier.peek_len([1, 2, 9], ns="a") == 2


def test_tier_dedup_lru_eviction_and_oversize():
    budget = 3 * 100 / (1 << 20)
    tier = SharedCacheTier(budget_mb=budget)
    assert tier.put((1,), b"a" * 100)
    assert not tier.put((1,), b"a" * 100)          # dedup, no overwrite
    assert tier.put((2,), b"b" * 100)
    assert tier.put((3,), b"c" * 100)
    assert tier.get([1]) is not None               # touch (1): now MRU
    assert tier.put((4,), b"d" * 100)              # evicts LRU = (2)
    assert tier.get([2]) is None
    assert tier.get([1]) is not None
    assert not tier.put((5,), b"x" * 400)          # oversize refused
    s = tier.summary()
    assert s["entries"] == len(tier) == 3
    assert s["evictions"] == 1 and s["bytes_used"] == tier.bytes_used


def test_tier_save_load_round_trip(tmp_path):
    tier = SharedCacheTier(budget_mb=1.0)
    tier.put((1, 2), b"ab")
    tier.put((1, 2, 3), b"abc", ns="tenant0")
    path = str(tmp_path / "tier.rmct")
    assert tier.save(path, "f" * 16) == 2
    fresh = SharedCacheTier(budget_mb=1.0)
    assert fresh.load(path, "f" * 16) == 2
    assert fresh.get([1, 2]) == b"ab"
    assert fresh.get([1, 2, 3], ns="tenant0") == b"abc"
    # loading again dedups, not duplicates
    assert fresh.load(path, "f" * 16) == 0
    with pytest.raises(FingerprintError):
        SharedCacheTier(budget_mb=1.0).load(path, "0" * 16)
    with open(path, "r+b") as f:                   # corrupt one byte
        f.seek(-1, os.SEEK_END)
        f.write(b"\xff")
    with pytest.raises(CorruptError):
        SharedCacheTier(budget_mb=1.0).load(path, "f" * 16)


# ---------------------------------------------------------------------------
# PrefixCache <-> tier fall-through and per-namespace summary (model-free)
# ---------------------------------------------------------------------------


def _snap_of(nbytes):
    return {"h": np.zeros((nbytes,), np.uint8)}


def _make_cached_pair(budget_mb=1.0):
    from repro.serve.cache import PrefixCache
    cache = PrefixCache(budget_mb=budget_mb)
    tier = SharedCacheTier(budget_mb=budget_mb)
    codec = SnapshotCodec("f" * 16)
    cache.attach_tier(tier, codec)
    return cache, tier, codec


def test_cache_publishes_inserts_to_tier():
    cache, tier, codec = _make_cached_pair()
    assert cache.insert((1, 2, 3), lambda: _snap_of(64))
    assert tier.peek_len([1, 2, 3, 9]) == 3
    assert _tree_equal(codec.decode(tier.get([1, 2, 3])), _snap_of(64))


def test_cache_falls_through_to_tier_and_promotes():
    cache, tier, codec = _make_cached_pair()
    tier.put((5, 6, 7), codec.encode(_snap_of(32)))
    assert len(cache) == 0
    assert cache.peek_len([5, 6, 7, 8]) == 3       # peek sees the tier
    depth, snap = cache.lookup([5, 6, 7, 8])
    assert depth == 3 and _tree_equal(snap, _snap_of(32))
    assert cache.stats["hits"] == 1
    # promoted: now a local radix hit, tier probe no longer needed
    assert cache.contains([5, 6, 7])
    local_depth, _ = cache.lookup([5, 6, 7, 8])
    assert local_depth == 3


def test_cache_prefers_longer_tier_prefix_over_local():
    cache, tier, codec = _make_cached_pair()
    cache.insert((1, 2), lambda: _snap_of(16))
    tier.put((1, 2, 3, 4), codec.encode(_snap_of(16)))
    depth, _ = cache.lookup([1, 2, 3, 4, 5])
    assert depth == 4                              # tier wins: longer
    depth, _ = cache.lookup([1, 2, 9])
    assert depth == 2                              # local wins: tier misses


def test_cache_per_namespace_summary_and_gauges():
    from repro.serve.cache import PrefixCache
    from repro.serve.telemetry import MetricsRegistry
    reg = MetricsRegistry()
    cache = PrefixCache(budget_mb=1.0, registry=reg)
    cache.insert((1, 2), lambda: _snap_of(64))
    cache.insert((1, 2, 3), lambda: _snap_of(64))
    cache.insert((9, 9), lambda: _snap_of(128), ns="tenant0")
    per = cache.summary()["per_namespace"]
    assert per["default"]["snapshots"] == 2
    assert per["default"]["bytes_used"] == 2 * 64
    assert per["tenant0"]["snapshots"] == 1
    assert per["tenant0"]["bytes_used"] == 128
    assert per["default"]["nodes"] >= 2
    assert reg.value("cache_ns_snapshots_default") == 2
    assert reg.value("cache_ns_bytes_used_tenant0") == 128


def test_cache_adopt_snapshot_respects_budget():
    from repro.serve.cache import PrefixCache
    cache = PrefixCache(budget_mb=100 / (1 << 20))
    assert cache.adopt_snapshot((1, 2), _snap_of(64))
    assert not cache.adopt_snapshot((1, 2), _snap_of(64))   # dedup
    assert not cache.adopt_snapshot((3,), _snap_of(400))    # oversize
    assert cache.adopt_snapshot((4, 5), _snap_of(64))       # evicts (1,2)
    assert cache.contains([4, 5]) and not cache.contains([1, 2])


def test_prefix_cache_save_load_round_trip(tmp_path):
    from repro.serve.cache import PrefixCache
    codec = SnapshotCodec("f" * 16)
    src = PrefixCache(budget_mb=1.0)
    src.insert((1, 2), lambda: _snap_of(64))
    src.insert((1, 2, 3, 4), lambda: _snap_of(64))
    src.insert((7,), lambda: _snap_of(32), ns="tenant0")
    path = str(tmp_path / "cache.rmct")
    assert save_prefix_cache(src, codec, path) == 3
    dst = PrefixCache(budget_mb=1.0)
    assert load_prefix_cache(dst, codec, path) == 3
    assert dst.snapshot_prefixes() == src.snapshot_prefixes()
    assert dst.snapshot_prefixes(ns="tenant0") == \
        src.snapshot_prefixes(ns="tenant0")
    depth, snap = dst.lookup([1, 2, 3, 4, 5])
    assert depth == 4 and _tree_equal(snap, _snap_of(64))
    with pytest.raises(FingerprintError):
        load_prefix_cache(PrefixCache(budget_mb=1.0),
                          SnapshotCodec("0" * 16), path)


# ---------------------------------------------------------------------------
# inspect CLI (model-free)
# ---------------------------------------------------------------------------


def test_inspect_snapshot_message_and_cache_file(tmp_path, capsys):
    codec = SnapshotCodec("a" * 16)
    blob = codec.encode(_demo_snap())
    snap_path = str(tmp_path / "s.rmsn")
    with open(snap_path, "wb") as f:
        f.write(blob)
    assert fleet_inspect.main([snap_path]) == 0
    out = capsys.readouterr().out
    assert "codec v1" in out and "/segments/0/conv" in out

    msg_path = str(tmp_path / "m.rmms")
    with open(msg_path, "wb") as f:
        f.write(pack_message({"kind": "admit", "first_token": 5,
                              "request": {"id": 3, "prompt": [1, 2]}}, blob))
    assert fleet_inspect.main([msg_path]) == 0
    out = capsys.readouterr().out
    assert "kind=admit" in out and "id=3" in out and "codec v1" in out

    tier = SharedCacheTier(budget_mb=1.0)
    tier.put((1, 2), blob)
    tier.put((3,), blob, ns="tenant0")
    tier_path = str(tmp_path / "c.rmct")
    tier.save(tier_path, "a" * 16)
    assert fleet_inspect.main([tier_path]) == 0
    out = capsys.readouterr().out
    assert "2 entries" in out and "tenant0" in out

    bad = str(tmp_path / "bad.bin")
    with open(bad, "wb") as f:
        f.write(b"not a fleet artifact")
    assert fleet_inspect.main([bad]) == 2


# ---------------------------------------------------------------------------
# engine-level: snapshot admission and fleet identity (single device)
# ---------------------------------------------------------------------------


def _fleet_run(cfg, params, reqs, n_decode=2, threaded=False,
               tier_mb=None, library=None, prefill_slots=2,
               decode_slots=2, max_len=32):
    """Build a 1-prefill + n-decode fleet over fresh engines and run."""
    from repro.serve import (EngineConfig, PrefixCache, ServeEngine,
                             Telemetry)
    from repro.serve.fleet import (DecodeWorker, FleetRouter, PrefillWorker,
                                   SnapshotCodec)
    telem = Telemetry()
    ec = EngineConfig(max_slots=prefill_slots, max_len=max_len, seed=0,
                      max_prefill_chunk=8)
    peng = ServeEngine(cfg, params, engine=ec,
                       prefix_cache=PrefixCache(budget_mb=16.0,
                                                registry=telem.registry),
                       expert_library=library, telemetry=telem)
    codec = SnapshotCodec.for_store(peng.store)
    if tier_mb:
        tier = SharedCacheTier(budget_mb=tier_mb, registry=telem.registry)
        peng.cache.attach_tier(tier, codec)
    dec = EngineConfig(max_slots=decode_slots, max_len=max_len, seed=0)
    dws = []
    for i in range(n_decode):
        deng = ServeEngine(cfg, params, engine=dec, expert_library=library,
                           telemetry=telem)
        dws.append(DecodeWorker(f"d{i}", deng, codec,
                                registry=telem.registry))
    pw = PrefillWorker("p0", peng, codec, registry=telem.registry)
    router = FleetRouter([pw], dws, telemetry=telem)
    results = router.run(reqs, threaded=threaded)
    return {r.id: r.tokens for r in results}, telem, router


@pytest.mark.parametrize("threaded", [False, True],
                         ids=["cooperative", "threaded"])
def test_fleet_greedy_identical_small(threaded):
    """1 prefill + 2 decode replicas == one monolithic engine, greedy
    tokens bit-identical, in both drive modes."""
    import jax
    from identity import random_prompts, run_tokens, small_cfg
    from repro.models import lm
    from repro.serve import EngineConfig, Request, ServeEngine

    cfg = small_cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = random_prompts(cfg, [5, 11, 3, 7, 4, 6])
    reqs = [Request(id=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    mono = ServeEngine(cfg, params,
                       engine=EngineConfig(max_slots=4, max_len=32, seed=0))
    ref = run_tokens(mono, reqs)
    got, telem, _ = _fleet_run(cfg, params, reqs, threaded=threaded,
                               tier_mb=16.0)
    assert got == ref
    v = telem.registry.value
    assert v("fleet_admits_total") == len(reqs)
    assert v("fleet_results_total") == len(reqs)
    assert v("fleet_snapshot_bytes_total") > 0


@pytest.mark.parametrize("pattern", [("mamba2",), ("gdn",), ("rglru",),
                                     ("mlstm",), ("slstm",),
                                     ("rom_mamba", "mlp")],
                         ids=lambda p: "+".join(p))
def test_fleet_greedy_identical_patterns(pattern):
    """Per mixer family: the fleet reproduces the monolithic greedy tokens
    bit-exactly (the disaggregation hard invariant)."""
    import jax
    from identity import full_cfg, random_prompts, run_tokens
    from repro.models import lm
    from repro.serve import EngineConfig, Request, ServeEngine

    cfg = full_cfg(((pattern, 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = random_prompts(cfg, [5, 9, 3, 7])
    reqs = [Request(id=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    mono = ServeEngine(cfg, params,
                       engine=EngineConfig(max_slots=4, max_len=32, seed=0))
    ref = run_tokens(mono, reqs)
    got, _, _ = _fleet_run(cfg, params, reqs)
    assert got == ref, pattern


def test_fleet_multi_tenant_expert_routing_identical():
    """Multi-tenant fleet: requests routed by expert set through a shared
    ExpertLibrary on every replica match per-tenant dedicated engines."""
    import jax
    from identity import (dedicated_params, full_cfg, random_prompts,
                          run_tokens)
    from repro.models import lm
    from repro.serve import (EngineConfig, ExpertLibrary, Request,
                             ServeEngine)

    cfg = full_cfg(((("rom_mamba", "mlp"), 1),))
    base = lm.init_params(jax.random.PRNGKey(0), cfg)
    tenants = {f"tenant{i}": lm.init_params(jax.random.PRNGKey(100 + i), cfg)
               for i in range(2)}

    def make_library():
        lib = ExpertLibrary(cfg, base, max_bound=2)
        for name, p in tenants.items():
            lib.add(name, p)
        return lib

    prompts = random_prompts(cfg, [5, 8, 4, 6])
    names = [None, "tenant0", "tenant1", "tenant0"]
    reqs = [Request(id=i, prompt=p, max_new_tokens=5, expert_set=names[i])
            for i, p in enumerate(prompts)]
    got, _, _ = _fleet_run(cfg, base, reqs, library=make_library())
    # per-tenant references on dedicated single-set engines
    for i, req in enumerate(reqs):
        p = base if names[i] is None else dedicated_params(
            cfg, base, tenants[names[i]])
        ded = ServeEngine(cfg, p, engine=EngineConfig(
            max_slots=2, max_len=32, seed=0))
        ref = run_tokens(ded, [Request(id=0, prompt=req.prompt,
                                       max_new_tokens=5)])
        assert got[i] == ref[0], names[i]


def test_admit_from_snapshot_capacity_refusal_and_validation():
    import jax
    from identity import random_prompts, small_cfg
    from repro.models import lm
    from repro.serve import EngineConfig, Request, ServeEngine

    cfg = small_cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    pre = ServeEngine(cfg, params,
                      engine=EngineConfig(max_slots=1, max_len=32, seed=0))
    dec = ServeEngine(cfg, params,
                      engine=EngineConfig(max_slots=1, max_len=32, seed=0))
    prompts = random_prompts(cfg, [5, 6])
    r0, r1 = (Request(id=i, prompt=p, max_new_tokens=4)
              for i, p in enumerate(prompts))
    tok0, snap0 = pre.prefill_to_snapshot(r0)
    tok1, snap1 = pre.prefill_to_snapshot(r1)
    assert dec.admit_from_snapshot(r0, snap0, tok0)
    assert not dec.admit_from_snapshot(r1, snap1, tok1)    # 1 slot: full
    while dec.busy():
        dec.tick()
    assert dec.admit_from_snapshot(r1, snap1, tok1)        # slot retired
    with pytest.raises(KeyError):                          # unknown tenant
        dec.admit_from_snapshot(
            Request(id=9, prompt=prompts[0], max_new_tokens=2,
                    expert_set="nope"), snap0, tok0)
    with pytest.raises(ValueError):                        # prompt too long
        pre.prefill_to_snapshot(Request(id=8, prompt=[1] * 40,
                                        max_new_tokens=2))


def test_fleet_drained_workers_requeue_and_exhaust():
    import jax
    from identity import random_prompts, run_tokens, small_cfg
    from repro.models import lm
    from repro.serve import (EngineConfig, PrefixCache, Request, ServeEngine,
                             Telemetry)
    from repro.serve.fleet import (DecodeWorker, FleetRouter, PrefillWorker,
                                   SnapshotCodec)

    cfg = small_cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    reqs = [Request(id=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(random_prompts(cfg, [5, 7]))]
    mono = ServeEngine(cfg, params,
                       engine=EngineConfig(max_slots=2, max_len=32, seed=0))
    ref = run_tokens(mono, reqs)

    def build(n_prefill=2, n_decode=2):
        telem = Telemetry()
        ec = EngineConfig(max_slots=2, max_len=32, seed=0)
        pws, dws, codec = [], [], None
        for i in range(n_prefill):
            eng = ServeEngine(cfg, params, engine=ec,
                              prefix_cache=PrefixCache(budget_mb=4.0),
                              telemetry=telem)
            codec = SnapshotCodec.for_store(eng.store)
            pws.append(PrefillWorker(f"p{i}", eng, codec,
                                     registry=telem.registry))
        for i in range(n_decode):
            eng = ServeEngine(cfg, params, engine=ec, telemetry=telem)
            dws.append(DecodeWorker(f"d{i}", eng, codec,
                                    registry=telem.registry))
        return pws, dws, telem

    # one prefill peer drained -> work lands on the live one, identical
    pws, dws, telem = build()
    pws[0].drain()
    router = FleetRouter(pws, dws, telemetry=telem)
    got = {r.id: r.tokens for r in router.run(reqs)}
    assert got == ref
    assert pws[1].load == len(reqs) and pws[0].load == 0

    # every decode worker drained -> retries exhaust, clear error
    pws, dws, telem = build()
    for w in dws:
        w.drain()
    with pytest.raises(RuntimeError):
        FleetRouter(pws, dws, telemetry=telem).run(reqs)
    assert telem.registry.value("fleet_worker_failures_total") > 0


def test_fleet_shared_tier_serves_cross_worker_hits():
    """Two requests sharing a prefix: the second prefill restores the
    boundary the first published through the shared tier."""
    import jax
    from identity import random_prompts, run_tokens, small_cfg
    from repro.models import lm
    from repro.serve import EngineConfig, Request, ServeEngine

    cfg = small_cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    shared = list(range(4, 12))                    # 8-token shared prefix
    tails = random_prompts(cfg, [3, 4], seed=5)
    reqs = [Request(id=i, prompt=shared + t, max_new_tokens=4)
            for i, t in enumerate(tails)]
    mono = ServeEngine(cfg, params,
                       engine=EngineConfig(max_slots=2, max_len=32, seed=0))
    ref = run_tokens(mono, reqs)
    got, telem, _ = _fleet_run(cfg, params, reqs, tier_mb=8.0)
    assert got == ref
    assert telem.registry.value("fleet_tier_inserts_total") > 0
    assert telem.registry.value(
        "serve_cache_hit_tokens_total") >= len(shared)


def test_cache_persistence_bit_identical_continuation(tmp_path):
    """Cold engine vs an engine warmed from a saved cache file: same
    greedy tokens, and the warm run actually skipped prefill work."""
    import jax
    from identity import random_prompts, run_tokens, small_cfg
    from repro.models import lm
    from repro.serve import EngineConfig, PrefixCache, Request, ServeEngine
    from repro.serve.fleet import SnapshotCodec

    cfg = small_cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    shared = list(range(4, 16))                    # spans a chunk boundary
    tails = random_prompts(cfg, [3, 5], seed=9)
    reqs = [Request(id=i, prompt=shared + t, max_new_tokens=5)
            for i, t in enumerate(tails)]
    ec = EngineConfig(max_slots=2, max_len=48, seed=0, max_prefill_chunk=8)

    warm_cache = PrefixCache(budget_mb=8.0)
    first = ServeEngine(cfg, params, engine=ec, prefix_cache=warm_cache)
    codec = SnapshotCodec.for_store(first.store)
    ref = run_tokens(first, reqs)
    path = str(tmp_path / "cache.rmct")
    assert save_prefix_cache(warm_cache, codec, path) > 0

    loaded_cache = PrefixCache(budget_mb=8.0)
    assert load_prefix_cache(loaded_cache, codec, path) > 0
    second = ServeEngine(cfg, params, engine=ec, prefix_cache=loaded_cache)
    got = run_tokens(second, reqs)
    assert got == ref
    assert second.stats["cache_hit_tokens"] >= len(shared)


# ---------------------------------------------------------------------------
# cross-mesh parity (slow, 8 virtual devices in a subprocess)
# ---------------------------------------------------------------------------

_TESTS_DIR = os.path.dirname(os.path.abspath(__file__))

_FLEET_COMMON = f"""
import sys
sys.path.insert(0, {_TESTS_DIR!r})
""" + """
import jax, numpy as np
from identity import full_cfg, random_prompts, run_tokens
from repro.distributed.plan import ParallelPlan
from repro.models import lm
from repro.serve import (EngineConfig, PrefixCache, Request, ServeEngine,
                         Telemetry)
from repro.serve import fleet

cfg = full_cfg(((("rom_mamba", "mlp"), 1),))
params = lm.init_params(jax.random.PRNGKey(0), cfg)
reqs = [Request(id=i, prompt=p, max_new_tokens=5)
        for i, p in enumerate(random_prompts(cfg, [5, 9, 3, 7]))]
mono = ServeEngine(cfg, params,
                   engine=EngineConfig(max_slots=4, max_len=32, seed=0))
ref = run_tokens(mono, reqs)
"""


@pytest.mark.slow
def test_fleet_cross_mesh_prefill_data2_decode_single(subproc, repo_src):
    """Prefill replica on a data=2 mesh, decode replica single-device,
    connected only by codec bytes — greedy tokens bit-identical to the
    monolithic single-device engine."""
    subproc(_FLEET_COMMON + """
ec = EngineConfig(max_slots=2, max_len=32, seed=0, max_prefill_chunk=8)
peng = ServeEngine(cfg, params, plan=ParallelPlan.host(data=2), engine=ec,
                   prefix_cache=PrefixCache(budget_mb=8.0))
codec = fleet.SnapshotCodec.for_store(peng.store)
deng = ServeEngine(cfg, params, plan=ParallelPlan.single_device(), engine=ec)
pw = fleet.PrefillWorker("p0", peng, codec)
dw = fleet.DecodeWorker("d0", deng, codec)
router = fleet.FleetRouter([pw], [dw])
got = {r.id: r.tokens for r in router.run(reqs)}
assert got == ref, (got, ref)
print("cross-mesh fleet parity OK")
""", n_devices=8)


@pytest.mark.slow
def test_fleet_cache_file_crosses_meshes(subproc, tmp_path):
    """A cache saved from a data=2 engine warms a single-device engine
    (and vice versa): continuations stay bit-identical and the warm run
    serves hits — the snapshots inside the file are topology-portable."""
    path = str(tmp_path / "xmesh.rmct")
    subproc(_FLEET_COMMON + f"""
path = {path!r}
shared = list(range(4, 16))
tails = random_prompts(cfg, [3, 5], seed=9)
sreqs = [Request(id=i, prompt=shared + t, max_new_tokens=5)
         for i, t in enumerate(tails)]
ec = EngineConfig(max_slots=2, max_len=48, seed=0, max_prefill_chunk=8)
mref = run_tokens(ServeEngine(cfg, params, engine=ec), sreqs)

src_cache = PrefixCache(budget_mb=8.0)
src = ServeEngine(cfg, params, plan=ParallelPlan.host(data=2), engine=ec,
                  prefix_cache=src_cache)
codec = fleet.SnapshotCodec.for_store(src.store)
assert run_tokens(src, sreqs) == mref
assert fleet.save_prefix_cache(src_cache, codec, path) > 0

dst_cache = PrefixCache(budget_mb=8.0)
assert fleet.load_prefix_cache(dst_cache, codec, path) > 0
dst = ServeEngine(cfg, params, plan=ParallelPlan.single_device(), engine=ec,
                  prefix_cache=dst_cache)
assert run_tokens(dst, sreqs) == mref
assert dst.stats["cache_hit_tokens"] >= len(shared)

back_cache = PrefixCache(budget_mb=8.0)
assert fleet.load_prefix_cache(back_cache, codec, path) > 0
back = ServeEngine(cfg, params, plan=ParallelPlan.host(data=2), engine=ec,
                   prefix_cache=back_cache)
assert run_tokens(back, sreqs) == mref
assert back.stats["cache_hit_tokens"] >= len(shared)
print("cross-mesh cache persistence OK")
""", n_devices=8)
