"""Router + dispatch invariants, including hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt); the rest of the suite collects without it")
from hypothesis import given, settings, strategies as st

from repro.core import moe_dispatch as md
from repro.core import router as rtr


def _route(key, G, g, D, E, K, **kw):
    ks = jax.random.split(key, 2)
    x = jax.random.normal(ks[0], (G, g, D))
    w = rtr.router_init(ks[1], D, E)
    return x, rtr.route(w, x, num_experts=E, top_k=K, **kw)


def test_topk_weights_are_selected_probs():
    x, r = _route(jax.random.PRNGKey(0), 2, 32, 8, 8, 2)
    probs = np.asarray(r.probs)
    idx = np.asarray(r.expert_idx)
    w = np.asarray(r.weights)
    for gi in range(2):
        for t in range(32):
            top = np.sort(probs[gi, t])[::-1][:2]
            np.testing.assert_allclose(np.sort(w[gi, t])[::-1], top,
                                       rtol=1e-5)
            assert len(set(idx[gi, t])) == 2            # distinct experts


def test_normalized_weights_sum_to_one():
    x, r = _route(jax.random.PRNGKey(1), 1, 16, 8, 4, 3,
                  normalize_weights=True)
    np.testing.assert_allclose(np.asarray(r.weights.sum(-1)), 1.0, rtol=1e-5)


def test_jitter_changes_routing_only_in_train():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (1, 64, 16))
    w = rtr.router_init(key, 16, 8)
    r_eval = rtr.route(w, x, num_experts=8, top_k=1, jitter_eps=0.5,
                       rng=jax.random.PRNGKey(3), train=False)
    r_eval2 = rtr.route(w, x, num_experts=8, top_k=1, jitter_eps=0.5,
                        rng=jax.random.PRNGKey(4), train=False)
    assert np.array_equal(np.asarray(r_eval.expert_idx),
                          np.asarray(r_eval2.expert_idx))
    r_tr = rtr.route(w, x, num_experts=8, top_k=1, jitter_eps=0.5,
                     rng=jax.random.PRNGKey(3), train=True)
    r_tr2 = rtr.route(w, x, num_experts=8, top_k=1, jitter_eps=0.5,
                      rng=jax.random.PRNGKey(4), train=True)
    assert not np.array_equal(np.asarray(r_tr.expert_idx),
                              np.asarray(r_tr2.expert_idx))


@settings(deadline=None, max_examples=25)
@given(g=st.integers(4, 64), E=st.integers(2, 8), K=st.integers(1, 3),
       seed=st.integers(0, 2**31 - 1), cf=st.floats(0.5, 4.0))
def test_dispatch_combine_matches_dense_when_no_drops(g, E, K, seed, cf):
    K = min(K, E)
    D, F = 8, 6
    key = jax.random.PRNGKey(seed)
    x, r = _route(key, 1, g, D, E, K)
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (E, D, F)) * 0.3
    dsp = md.make_dispatch(r, capacity_factor=float(E))  # capacity >= g*K
    lin = md.SharedMoELinear(dsp)
    y_cap = lin(x, w, weighted=True)
    y_dense = md.dense_moe_linear(r, x, w, weighted=True)
    assert float(dsp.drop_frac) < 1e-6        # f32 mean noise only
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_dense),
                               atol=1e-4, rtol=1e-4)
    # tight capacity never corrupts non-dropped assignments: each kept token
    # differs from dense only by the dropped assignments' contributions
    dsp_t = md.make_dispatch(r, capacity_factor=cf)
    lin_t = md.SharedMoELinear(dsp_t)
    y_t = lin_t(x, w, weighted=True)
    assert np.all(np.isfinite(np.asarray(y_t)))


@settings(deadline=None, max_examples=20)
@given(g=st.integers(4, 48), E=st.integers(2, 8), seed=st.integers(0, 10**6))
def test_dispatch_slot_accounting(g, E, seed):
    """Every non-dropped assignment occupies exactly one slot of its expert,
    in token order; group_sizes match the routing histogram."""
    key = jax.random.PRNGKey(seed)
    x, r = _route(key, 1, g, 8, E, 1)
    dsp = md.make_dispatch(r, capacity_factor=float(E))
    idx = np.asarray(r.expert_idx)[0, :, 0]
    sizes = np.asarray(dsp.group_sizes)[0]
    hist = np.bincount(idx, minlength=E)
    np.testing.assert_array_equal(sizes, hist)
    tfs = np.asarray(dsp.token_for_slot)[0].reshape(E, dsp.capacity)
    valid = np.asarray(dsp.slot_valid)[0].reshape(E, dsp.capacity)
    for e in range(E):
        toks = tfs[e][valid[e]]
        expect = np.where(idx == e)[0]
        np.testing.assert_array_equal(toks, expect)   # stable token order


def test_ragged_matches_capacity():
    x, r = _route(jax.random.PRNGKey(7), 1, 40, 8, 4, 2)
    w = jax.random.normal(jax.random.PRNGKey(8), (4, 8, 6)) * 0.3
    dsp = md.make_dispatch(r, capacity_factor=4.0)
    y_cap = md.SharedMoELinear(dsp)(x, w, weighted=True)
    y_rag = md.ragged_moe_linear(dsp, x, w, weighted=True)
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_rag),
                               atol=1e-4, rtol=1e-4)


def test_grouped_impl_matches_capacity():
    x, r = _route(jax.random.PRNGKey(9), 2, 32, 8, 4, 1)
    w = jax.random.normal(jax.random.PRNGKey(10), (4, 8, 6)) * 0.3
    dsp = md.make_dispatch(r, capacity_factor=4.0)
    y_cap = md.SharedMoELinear(dsp, impl="capacity")(x, w, weighted=False)
    y_grp = md.SharedMoELinear(dsp, impl="grouped")(x, w, weighted=False)
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_grp),
                               atol=1e-4, rtol=1e-4)


def test_aux_loss_balanced_vs_collapsed():
    """Uniform routing minimizes the Switch aux loss; collapse maximizes."""
    E, g = 4, 256
    probs_bal = jnp.full((1, g, E), 1.0 / E)
    idx_bal = jnp.tile(jnp.arange(E), g // E).reshape(1, g, 1)
    probs_col = jnp.zeros((1, g, E)).at[..., 0].set(1.0)
    idx_col = jnp.zeros((1, g, 1), jnp.int32)

    def aux(probs, idx):
        onehot = jax.nn.one_hot(idx, E)
        load = onehot.sum((1, 2)) / g
        return float(E * jnp.mean(jnp.sum(load * probs.mean(1), -1)))

    assert abs(aux(probs_bal, idx_bal) - 1.0) < 1e-5
    assert aux(probs_col, idx_col) > 3.9
