"""The serve-package coverage ratchet (tests/check_coverage.py): floor
comparison, missing-module detection, clean skip without a report, and
--update banking.  Runs on synthetic coverage.py JSON so the gate logic
is tested even where pytest-cov itself is not installed."""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
import check_coverage  # noqa: E402


def _report(files):
    """coverage.py JSON shape: files -> summary percent/covered/statements.
    ``files`` maps a repro/serve-relative name to (covered, statements)."""
    return {"files": {
        f"src/repro/serve/{name}": {"summary": {
            "percent_covered": 100.0 * cov / max(n, 1),
            "covered_lines": cov, "num_statements": n}}
        for name, (cov, n) in files.items()}}


def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


FILES = {"cache.py": (90, 100), "engine.py": (50, 100)}
FLOORS = {"floors": {"repro/serve/cache.py": 80,
                     "repro/serve/engine.py": 45, "TOTAL": 60}}


def test_green_when_at_or_above_floor(tmp_path, capsys):
    r = _write(tmp_path, "cov.json", _report(FILES))
    f = _write(tmp_path, "floors.json", FLOORS)
    assert check_coverage.main(["--report", r, "--floors", f]) == 0
    assert "all at or above floor" in capsys.readouterr().out


def test_regression_below_floor_fails(tmp_path, capsys):
    dropped = dict(FILES, **{"cache.py": (70, 100)})   # 70% < floor 80
    r = _write(tmp_path, "cov.json", _report(dropped))
    f = _write(tmp_path, "floors.json", FLOORS)
    assert check_coverage.main(["--report", r, "--floors", f]) == 1
    assert "BELOW FLOOR" in capsys.readouterr().out


def test_module_missing_from_report_fails(tmp_path, capsys):
    """A floored module that vanishes from the report (deleted, or no
    longer imported by the covered tests) is a regression, not a pass."""
    r = _write(tmp_path, "cov.json", _report({"cache.py": (90, 100)}))
    f = _write(tmp_path, "floors.json", FLOORS)
    assert check_coverage.main(["--report", r, "--floors", f]) == 1
    assert "MISSING from report" in capsys.readouterr().out


def test_files_outside_serve_are_ignored():
    rep = _report(FILES)
    rep["files"]["src/repro/models/lm.py"] = {"summary": {
        "percent_covered": 1.0, "covered_lines": 1, "num_statements": 100}}
    cov = check_coverage.serve_coverage(rep)
    assert set(cov) == {"repro/serve/cache.py", "repro/serve/engine.py",
                        "TOTAL"}
    assert cov["TOTAL"] == 70.0                     # (90+50)/(100+100)


def test_missing_report_skips_cleanly(tmp_path, capsys):
    """pytest-cov is CI-only: without its report the gate must exit 0
    with a skip message, never fail a local run."""
    f = _write(tmp_path, "floors.json", FLOORS)
    missing = str(tmp_path / "nope.json")
    assert check_coverage.main(["--report", missing, "--floors", f]) == 0
    assert "skipping" in capsys.readouterr().out


def test_update_banks_current_coverage(tmp_path):
    r = _write(tmp_path, "cov.json", _report(FILES))
    f = _write(tmp_path, "floors.json", FLOORS)
    assert check_coverage.main(["--report", r, "--floors", f,
                                "--update"]) == 0
    doc = json.loads(Path(f).read_text())
    assert doc["floors"] == {"repro/serve/cache.py": 90,
                             "repro/serve/engine.py": 50, "TOTAL": 70}
    # banked floors gate green against the same report
    assert check_coverage.main(["--report", r, "--floors", f]) == 0
