"""Telemetry subsystem: registry/tracer units, exporters, and the
engine-integration invariants the observability PR promises —

* every admitted request reaches a terminal ``finish`` span, with spans
  nested inside the request root and timestamps monotonic, across
  interleaved / sequential / speculative / cache-hit / multi-tenant
  serving modes;
* the legacy ``ServeEngine.stats`` dict is a pure view of the registry
  (parity per key, ``reset_stats`` re-baselines without zeroing);
* greedy decode tokens are bit-identical with telemetry on vs off
  (telemetry is host-side only);
* ``_submit_t`` bookkeeping drains at finish/evict (no per-request leak).
"""
import contextlib
import json

import jax
import pytest

from identity import TENANT_PATTERNS, full_cfg as _full_cfg, \
    random_prompts, run_tokens, small_cfg as _cfg
from repro import obs
from repro.models import lm
from repro.serve import (MetricsRegistry, PrefixCache, Request, ServeEngine,
                         Telemetry, Tracer, hist_mean, hist_quantile,
                         log_buckets)
from repro.serve.telemetry import LATENCY_BUCKETS_S, EngineInstruments, _NULL


# ---------------------------------------------------------------------------
# registry units (model-free)
# ---------------------------------------------------------------------------

def test_log_buckets_shape_and_determinism():
    b = log_buckets(1e-5, 100.0, per_decade=3)
    assert b == LATENCY_BUCKETS_S
    assert b[0] == 1e-5 and b[-1] >= 100.0
    assert all(x < y for x, y in zip(b, b[1:]))
    assert len(b) == 22
    with pytest.raises(ValueError):
        log_buckets(0.0, 1.0)
    with pytest.raises(ValueError):
        log_buckets(1.0, 1.0)


def test_counter_int_typing_and_gauge():
    reg = MetricsRegistry()
    c = reg.counter("toks", "help")
    c.inc(3)
    c.inc()
    assert c.value == 4 and isinstance(c.value, int)
    s = reg.counter("secs")
    s.inc(0.5)
    assert isinstance(s.value, float)
    g = reg.gauge("depth")
    g.set(7)
    g.inc(-2)
    assert g.value == 5


def test_histogram_counts_and_overflow():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    for v in (1e-6, 2e-4, 0.5, 1e5):        # underflow bucket .. overflow
        h.observe(v)
    assert h.count == 4 == sum(h.counts)
    assert h.counts[-1] == 1                # 1e5 > last finite boundary
    assert h.min == 1e-6 and h.max == 1e5
    assert len(h.counts) == len(h.buckets) + 1


def test_registry_find_or_create_and_kind_conflict():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(ValueError):
        reg.gauge("x")
    assert reg.value("x") == 0
    assert reg.value("missing", default=3) == 3


def test_disabled_registry_is_noop():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("x")
    assert c is _NULL is reg.histogram("y") is reg.gauge("z")
    c.inc(5)
    reg.histogram("y").observe(1.0)
    assert reg.value("x") == 0
    assert reg.snapshot() == {}


def test_snapshot_delta_algebra():
    reg = MetricsRegistry()
    reg.counter("c").inc(5)
    reg.gauge("g").set(2)
    reg.histogram("h").observe(0.01)
    pre = reg.snapshot()
    reg.counter("c").inc(3)
    reg.gauge("g").set(9)
    reg.histogram("h").observe(0.01)
    reg.histogram("h").observe(10.0)
    reg.counter("born_late").inc(1)
    d = reg.delta(pre)
    assert d["c"]["value"] == 3
    assert d["g"]["value"] == 9              # gauges pass through
    assert d["h"]["count"] == 2
    assert sum(d["h"]["counts"]) == 2
    assert d["born_late"]["value"] == 1      # absent from prev -> vs zero
    # delta + prev reconstructs the current cumulative state
    cur = reg.snapshot()
    assert cur["c"]["value"] == pre["c"]["value"] + d["c"]["value"]
    assert cur["h"]["count"] == pre["h"]["count"] + d["h"]["count"]
    # immediately-taken delta is all-zero for counters/histograms
    z = reg.delta(reg.snapshot())
    assert z["c"]["value"] == 0 and z["h"]["count"] == 0


def test_hist_quantile_properties():
    reg = MetricsRegistry()
    h = reg.histogram("h")
    assert hist_quantile(h.snap(), 0.5) == 0.0      # empty
    for _ in range(10):
        h.observe(0.25)
    snap = h.snap()
    # single-valued distribution: min/max clamp defeats bucket smearing
    assert hist_quantile(snap, 0.0) == 0.25
    assert hist_quantile(snap, 0.5) == 0.25
    assert hist_quantile(snap, 1.0) == 0.25
    assert hist_mean(snap) == pytest.approx(0.25)
    with pytest.raises(ValueError):
        hist_quantile(snap, 1.5)
    h2 = reg.histogram("h2")
    for v in (0.001, 0.01, 0.1, 1.0, 10.0):
        h2.observe(v)
    s2 = h2.snap()
    qs = [hist_quantile(s2, q) for q in (0.1, 0.5, 0.9, 1.0)]
    assert qs == sorted(qs)                         # monotone in q
    assert 0.001 <= qs[0] and qs[-1] <= 10.0        # clamped to extremes


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("serve_toks_total", "tokens").inc(7)
    reg.gauge("serve_depth").set(3)
    h = reg.histogram("serve_lat_seconds", "latency")
    h.observe(2e-5)
    h.observe(1e9)
    text = reg.to_prometheus()
    lines = text.splitlines()
    assert "# HELP serve_toks_total tokens" in lines
    assert "# TYPE serve_toks_total counter" in lines
    assert "serve_toks_total 7" in lines
    assert "serve_depth 3" in lines
    # bucket lines are cumulative and end at +Inf == count
    cums = [int(ln.rsplit(" ", 1)[1]) for ln in lines
            if ln.startswith("serve_lat_seconds_bucket")]
    assert cums == sorted(cums)
    assert cums[-1] == 2
    assert 'serve_lat_seconds_bucket{le="+Inf"} 2' in lines
    assert "serve_lat_seconds_count 2" in lines


# ---------------------------------------------------------------------------
# tracer units (model-free)
# ---------------------------------------------------------------------------

def test_tracer_lifecycle_and_invariants():
    tr = Tracer()
    tr.begin(1, 10.0, prompt_len=4)
    tr.admitted(1, 11.0, 11.5, hit=0, mode="interleaved")
    tr.add(1, "prefill_chunk", 11.0, 11.5, tokens=4)
    tr.event(1, "first_token", 11.5)
    tr.add(1, "decode", 11.5, 12.0, pos=5)
    assert tr.live() == [1]
    tr.finish(1, "length", 12.5)
    assert tr.live() == []
    (tl,) = tr.timelines()
    names = [s.name for s in tl.spans]
    assert names[0] == "request"
    assert names.index("queued") < names.index("admitted")
    assert tl.terminal().attrs == {"reason": "length"}
    assert not tl.open
    root = tl.root
    assert root.t1 == 12.5
    for s in tl.spans:
        assert s.t1 is not None and root.t0 <= s.t0 <= s.t1 <= root.t1
        assert s.parent is None or s.parent == root.sid
    q = next(s for s in tl.spans if s.name == "queued")
    assert q.t1 == 11.0                     # closed where admitted began


def test_tracer_rebegin_drops_and_deque_bounds():
    tr = Tracer(max_traces=2)
    tr.begin(7, 1.0)
    tr.begin(7, 2.0)                         # same id re-begun
    assert tr.dropped == 1
    for rid in ("a", "b", "c"):
        tr.begin(rid, 1.0)
        tr.finish(rid, "eos", 2.0)
    assert len(tr.timelines()) == 2          # bounded retention
    assert [tl.req for tl in tr.timelines()] == ["b", "c"]


def test_tracer_disabled_records_nothing():
    tr = Tracer(enabled=False)
    tr.begin(1)
    tr.add(1, "decode", 0.0, 1.0)
    tr.finish(1, "eos")
    assert tr.live() == [] and tr.timelines() == []


def test_chrome_trace_structure():
    tr = Tracer()
    tr.begin("req-a", 5.0)
    tr.admitted("req-a", 5.1, 5.2)
    tr.finish("req-a", "eos", 6.0)
    out = tr.chrome_trace()
    json.dumps(out)                          # must be JSON-serializable
    evs = out["traceEvents"]
    metas = [e for e in evs if e["ph"] == "M"]
    spans = [e for e in evs if e["ph"] == "X"]
    assert len(metas) == 1 and metas[0]["args"]["name"] == "request req-a"
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in spans)
    assert {e["name"] for e in spans} >= {"request", "queued", "admitted",
                                          "finish"}
    assert tr.chrome_trace()["traceEvents"] is not evs  # fresh each call


def test_telemetry_bundle_flags():
    t = Telemetry()
    assert t.enabled and t.tracer.enabled and not t.profiler
    assert t.annotate("x") is t.annotate("y")         # shared no-op ctx
    with t.annotate("x"):
        pass
    off = Telemetry(enabled=False)
    assert not off.tracer.enabled and not off.registry.enabled
    metrics_only = Telemetry(trace=False)
    assert metrics_only.registry.enabled
    assert not metrics_only.tracer.enabled
    assert Telemetry(profiler=True).describe() == {
        "enabled": True, "trace": True, "profiler": True}
    # profiler annotations are real context managers
    ann = Telemetry(profiler=True).annotate("region")
    assert not isinstance(ann, contextlib.nullcontext)
    with ann:
        pass
    # repro.obs re-exports the same objects
    assert obs.Telemetry is Telemetry
    assert obs.LATENCY_BUCKETS_S == LATENCY_BUCKETS_S


# ---------------------------------------------------------------------------
# engine integration: span invariants across serving modes
# ---------------------------------------------------------------------------

def _check_timelines(tracer, req_ids):
    """The tentpole invariants: every admitted request reaches a terminal
    span; spans nest under the request root; timestamps are monotonic and
    contained in the root interval; nothing is left open."""
    tls = {tl.req: tl for tl in tracer.timelines()}
    assert set(req_ids) <= set(tls)
    for rid in req_ids:
        tl = tls[rid]
        names = [s.name for s in tl.spans]
        assert names[0] == "request"
        assert "queued" in names and "admitted" in names
        assert tl.terminal() is not None
        assert not tl.open
        root = tl.root
        assert root.t1 is not None
        for s in tl.spans:
            assert s.t1 is not None
            assert root.t0 <= s.t0 <= s.t1 <= root.t1
            assert s.parent is None or s.parent == root.sid
        q = next(s for s in tl.spans if s.name == "queued")
        a = next(s for s in tl.spans if s.name == "admitted")
        assert q.t1 == a.t0
        assert names.index("admitted") < names.index("finish")
    return tls


@pytest.mark.parametrize("admission", ["interleaved", "sequential"])
def test_timelines_and_stats_parity(admission):
    """4 requests on 2 slots (forces queueing + slot reuse) under both
    admission modes: span invariants hold, the admitted span records its
    mode, and the legacy stats dict is key-for-key a registry view."""
    cfg = _cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    telem = Telemetry()
    eng = ServeEngine(cfg, params, max_slots=2, max_len=32, seed=0,
                      max_prefill_chunk=8, admission=admission,
                      telemetry=telem)
    prompts = random_prompts(cfg, [4, 7, 5, 9])
    reqs = [Request(id=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    results = eng.run(reqs)
    assert len(results) == 4
    tls = _check_timelines(telem.tracer, range(4))
    for rid, tl in tls.items():
        a = next(s for s in tl.spans if s.name == "admitted")
        assert a.attrs["mode"] == admission
        names = [s.name for s in tl.spans]
        assert "prefill_chunk" in names
        assert "first_token" in names
    # stats parity: every legacy key is exactly its registry counter
    s = eng.stats
    reg = telem.registry
    for key, (name, is_int) in EngineInstruments.STAT_COUNTERS.items():
        assert s[key] == reg.value(name), key
        assert isinstance(s[key], int if is_int else float), key
    assert reg.value("serve_requests_submitted_total") == 4
    assert reg.value("serve_requests_finished_total") == 4
    snap = reg.snapshot()
    assert snap["serve_ttft_seconds"]["count"] == 4
    assert snap["serve_e2e_seconds"]["count"] == 4
    # reset_stats re-baselines the view without touching the registry
    eng.reset_stats()
    assert all(v == 0 for v in eng.stats.values())
    assert reg.value("serve_requests_finished_total") == 4
    # satellite: per-request submit bookkeeping drains at finish
    assert eng._submit_t == {}


def test_greedy_identity_and_true_zero_off():
    """Bit-identical greedy tokens with telemetry on vs off — telemetry
    never enters jitted computation — and the off engine reads all-zero
    stats with no retained timelines."""
    cfg = _cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = random_prompts(cfg, [5, 8, 3])
    def reqs():
        return [Request(id=i, prompt=p, max_new_tokens=5)
                for i, p in enumerate(prompts)]
    on = ServeEngine(cfg, params, max_slots=2, max_len=32, seed=0,
                     max_prefill_chunk=8, telemetry=Telemetry())
    off_t = Telemetry(enabled=False)
    off = ServeEngine(cfg, params, max_slots=2, max_len=32, seed=0,
                      max_prefill_chunk=8, telemetry=off_t)
    assert run_tokens(on, reqs()) == run_tokens(off, reqs())
    assert all(v == 0 for v in off.stats.values())
    assert off_t.tracer.timelines() == []
    assert off_t.registry.snapshot() == {}
    assert off._submit_t == {}


def test_speculative_timeline_spans():
    """Speculative decoding: spec_round spans carry drafted/accepted/
    emitted attrs with accepted <= drafted, and the spec registry
    counters agree with the span attributes."""
    cfg = _cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    telem = Telemetry()
    eng = ServeEngine(cfg, params, max_slots=2, max_len=48, seed=0,
                      max_prefill_chunk=8, speculative=2,
                      telemetry=telem)
    prompts = random_prompts(cfg, [4, 6])
    eng.run([Request(id=i, prompt=p, max_new_tokens=6)
             for i, p in enumerate(prompts)])
    tls = _check_timelines(telem.tracer, range(2))
    rounds = [s for tl in tls.values() for s in tl.spans
              if s.name == "spec_round"]
    assert rounds
    for s in rounds:
        assert 0 <= s.attrs["accepted"] <= s.attrs["drafted"]
        assert s.attrs["emitted"] >= 0
    emitted = sum(s.attrs["emitted"] for s in rounds)
    assert emitted == telem.registry.value("serve_spec_emitted_total")
    assert telem.registry.value("serve_spec_rounds_total") > 0


def test_cache_hit_recorded_in_admitted_span():
    """A warm PrefixCache sharing the engine's registry: the second run's
    admitted spans carry the restored prefix length, and cache counters
    land in the same registry as the engine's."""
    cfg = _cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    telem = Telemetry()
    cache = PrefixCache(budget_mb=8.0, registry=telem.registry)
    eng = ServeEngine(cfg, params, max_slots=2, max_len=48, seed=0,
                      max_prefill_chunk=8, prefix_cache=cache,
                      telemetry=telem)
    shared = random_prompts(cfg, [16])[0]
    eng.run([Request(id=0, prompt=shared + [7], max_new_tokens=2)])  # warm
    eng.run([Request(id=1, prompt=shared + [9, 11], max_new_tokens=3)])
    tls = _check_timelines(telem.tracer, [1])
    a = next(s for s in tls[1].spans if s.name == "admitted")
    assert a.attrs["hit"] > 0
    assert telem.registry.value("cache_hits_total") > 0
    assert telem.registry.value("serve_cache_hit_tokens_total") == \
        eng.stats["cache_hit_tokens"] > 0


def test_multi_tenant_swap_events_in_timeline():
    """Two tenants on one binding row force hot swaps: expert_swap events
    appear in the swapping requests' timelines, and the library's fault
    counters flow into the shared registry."""
    from repro.serve import ExpertLibrary
    cfg = _full_cfg(((TENANT_PATTERNS[0], 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    telem = Telemetry()
    lib = ExpertLibrary(cfg, params, max_bound=1, registry=telem.registry)
    lib.add("t0", lm.init_params(jax.random.PRNGKey(1), cfg))
    lib.add("t1", lm.init_params(jax.random.PRNGKey(2), cfg))
    eng = ServeEngine(cfg, params, max_slots=1, max_len=24, seed=0,
                      max_prefill_chunk=8, expert_library=lib,
                      admission="sequential", telemetry=telem)
    prompts = random_prompts(cfg, [4, 5, 4])
    sets = [None, "t0", "t1"]
    eng.run([Request(id=i, prompt=p, max_new_tokens=2, expert_set=sets[i])
             for i, p in enumerate(prompts)])
    tls = _check_timelines(telem.tracer, range(3))
    swaps = [s for tl in tls.values() for s in tl.spans
             if s.name == "expert_swap"]
    assert swaps
    assert {s.attrs["set"] for s in swaps} >= {"t0", "t1"}
    assert telem.registry.value("serve_expert_swaps_total") == \
        eng.stats["expert_swaps"] >= 2
    assert telem.registry.value("lib_faults_total") >= 2
