"""Shared greedy bit-identity harness for the serving test suite.

Four test modules (engine, prefix cache, decode kernels, mixer step
kernels) assert the same property — a serving-stack feature must not
change greedy outputs — and had each re-spelled the same scaffolding:
the small hybrid config, the all-mixers config, the mixer-pattern sweep,
and the isolated per-token greedy reference.  This module is the single
spelling.  The per-tenant expert-library tests reuse it too: a shared
multi-tenant engine must be bit-identical to a dedicated engine loaded
with only that tenant's expert set, and ``dedicated_params`` builds
exactly that dedicated tree.

Importable as a plain module from any test file (pytest puts ``tests/``
on ``sys.path`` for its rootdir imports): ``from identity import
full_cfg, PATTERNS, greedy_reference``.
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.train as tr
from repro.configs.base import (AttentionConfig, GDNConfig, Mamba2Config,
                                MambaConfig, ModelConfig, RGLRUConfig,
                                RoMConfig, XLSTMConfig)
from repro.models import lm

#: Mixer-pattern sweep shared by the identity-style tests: one pattern per
#: recurrence family plus a hybrid and a RoM block.
PATTERNS = [("mamba", "attn"), ("mamba2",), ("gdn",), ("rglru",),
            ("mlstm",), ("slstm",), ("rom_mamba", "mlp")]

#: Expert-bearing patterns for the multi-tenant identity sweep: every
#: swappable mixer family (rom_* share one projection scheme; moemamba
#: carries nested per-projection routers).
TENANT_PATTERNS = [("rom_mamba", "mlp"), ("moemamba",)]


def small_cfg(**kw):
    """The minimal hybrid config (mamba + attn) for fast engine tests."""
    base = dict(name="t", d_model=32, vocab_size=64,
                segments=((("mamba", "attn"), 1),),
                mamba=MambaConfig(d_state=4, chunk=8),
                attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                          head_dim=8),
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def full_cfg(segments, window=None, **kw):
    """A config with every mixer family parameterized, so any ``PATTERNS``
    entry (or hybrid of them) builds.  RoM runs the deterministic capacity
    path (jitter 0, generous capacity) so greedy decode is reproducible."""
    base = dict(name="t", d_model=32, vocab_size=64, segments=segments,
                d_ff=64,
                mamba=MambaConfig(d_state=4, chunk=8),
                mamba2=Mamba2Config(d_state=8, head_dim=16, chunk=8),
                gdn=GDNConfig(num_heads=2, head_dim=8),
                rglru=RGLRUConfig(num_heads=2),
                xlstm=XLSTMConfig(num_heads=2, chunk=8),
                attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                          head_dim=8, window=window),
                rom=RoMConfig(num_experts=4, top_k=2, jitter_eps=0.0,
                              capacity_factor=8.0, impl="capacity"),
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def greedy_reference(cfg, params, prompt, gen, max_len):
    """Isolated per-token greedy decode: the oracle every engine-level
    feature (batching, chunked admission, caching, speculation, expert
    swapping) must reproduce bit-exactly."""
    serve = jax.jit(tr.make_serve_fn(cfg))
    st = lm.init_state(cfg, 1, max_len, jnp.dtype(cfg.dtype))
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    for t in range(toks.shape[1]):
        nxt, _, st = serve(params, st, toks[:, t:t + 1], jnp.int32(t))
    out, pos = [int(nxt[0])], toks.shape[1]
    while len(out) < gen:
        nxt, _, st = serve(params, st, nxt[:, None], jnp.int32(pos))
        out.append(int(nxt[0]))
        pos += 1
    return out


def random_prompts(cfg, lens, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, cfg.vocab_size, size=(n,)).tolist()
            for n in lens]


def dedicated_params(cfg, base_params, tenant_params):
    """The param tree a dedicated single-tenant engine would hold: the
    base model with its swappable expert leaves (``e_w_*``/``w_router``
    of rom_*/moemamba blocks) replaced by ``tenant_params``'s — i.e. a
    host-side single-set graft.  The multi-tenant identity tests compare
    a shared ExpertLibrary engine against an engine built on this."""
    from repro.serve.expert_library import ExpertLibrary
    lib = ExpertLibrary(cfg, base_params, max_bound=1)
    lib.add("tenant", tenant_params)
    lib.acquire("tenant")
    return lib.graft(base_params, ["tenant"])


def run_tokens(engine, requests):
    """Drive an engine over ``requests`` and map id -> generated tokens."""
    return {r.id: r.tokens for r in engine.run(requests)}
