"""RoM-layer behaviour: the paper's core claims as executable checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (GDNConfig, Mamba2Config, MambaConfig,
                                ModelConfig, MoEConfig, RGLRUConfig,
                                RoMConfig, XLSTMConfig)
from repro.core import moe_mamba, rom, rom_ffn
from repro.distributed.sharding import ShardCtx
from repro.nn.layers import Runtime

RT0 = Runtime(shard=ShardCtx(), rng=None, train=False)


def _cfg(**kw):
    base = dict(
        name="t", d_model=32, vocab_size=64, segments=((("rom_mamba",), 1),),
        d_ff=64,
        mamba=MambaConfig(d_state=4, chunk=8),
        mamba2=Mamba2Config(d_state=8, head_dim=16, chunk=8),
        gdn=GDNConfig(num_heads=2, head_dim=8),
        rglru=RGLRUConfig(num_heads=2),
        xlstm=XLSTMConfig(num_heads=2, chunk=8),
        rom=RoMConfig(num_experts=4, top_k=1, jitter_eps=0.0,
                      capacity_factor=4.0, impl="capacity"),
        moe=MoEConfig(num_experts=4, top_k=1, d_ff=48, jitter_eps=0.0,
                      capacity_factor=4.0, impl="capacity"),
        dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


ROM_LAYERS = [
    ("rom_mamba", rom.rom_mamba_init, rom.rom_mamba_apply),
    ("rom_mamba2", rom.rom_mamba2_init, rom.rom_mamba2_apply),
    ("rom_gdn", rom.rom_gdn_init, rom.rom_gdn_apply),
    ("rom_rglru", rom.rom_rglru_init, rom.rom_rglru_apply),
    ("rom_mlstm", rom.rom_mlstm_init, rom.rom_mlstm_apply),
]


@pytest.mark.parametrize("name,init,apply", ROM_LAYERS)
@pytest.mark.parametrize("impl", ["dense", "capacity", "ragged", "grouped"])
def test_rom_impls_agree(name, init, apply, impl):
    """All dispatch engines compute the same function (B=1 for ragged)."""
    cfg = _cfg()
    params = init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32)) * 0.5
    y_ref, _ = apply(params, x, cfg, RT0)
    cfg_i = _cfg(rom=dataclasses.replace(cfg.rom, impl=impl))
    y, _ = apply(params, x, cfg_i, RT0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4,
                               rtol=2e-4)


@pytest.mark.parametrize("K", [1, 2])
def test_rom_topk_weighting_only_on_out(K):
    """Eq. 10-13: Conv/Gate combine unweighted; Out applies router weights.
    With all-identical experts, the layer must equal dense Mamba whose Out
    output is scaled by sum of top-K weights."""
    cfg = _cfg(rom=RoMConfig(num_experts=4, top_k=K, jitter_eps=0.0,
                             capacity_factor=4.0))
    params = rom.rom_mamba_init(jax.random.PRNGKey(0), cfg)
    # make all experts identical
    for n in ("e_w_in", "e_w_gate", "e_w_out"):
        params[n] = jnp.broadcast_to(params[n][:1], params[n].shape)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32)) * 0.5
    y, _ = rom.rom_mamba_apply(params, x, cfg, RT0)

    from repro.core.router import route
    from repro.nn import ssm
    r = route(params["w_router"], x.reshape(1, 16, 32), num_experts=4,
              top_k=K)
    wsum = r.weights.sum(-1).reshape(2, 8)        # sum of selected probs
    dense_params = dict(params)
    dense_params["w_in"] = params["e_w_in"][0] * K      # K unweighted copies
    dense_params["w_gate"] = params["e_w_gate"][0] * K
    dense_params["w_out"] = params["e_w_out"][0]
    # gate is SiLU(K * X W_g); conv-proj input is K * X W_in
    y_dense, _ = ssm.mamba_apply(dense_params, x, cfg, RT0)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(y_dense * wsum[..., None]),
                               atol=2e-4, rtol=2e-4)


def test_shared_routing_single_router_param():
    """RoM has exactly ONE router; MoE-Mamba has one per projection."""
    cfg = _cfg()
    p_rom = rom.rom_mamba_init(jax.random.PRNGKey(0), cfg)
    p_nv = moe_mamba.moemamba_init(jax.random.PRNGKey(0), cfg)
    rom_routers = [k for k in jax.tree_util.tree_flatten_with_path(p_rom)[0]
                   if "w_router" in jax.tree_util.keystr(k[0])]
    nv_routers = [k for k in jax.tree_util.tree_flatten_with_path(p_nv)[0]
                  if "w_router" in jax.tree_util.keystr(k[0])]
    assert len(rom_routers) == 1
    assert len(nv_routers) == 3


def test_rom_targets_ablation_param_shapes():
    """targets=('conv','gate','dt','x','out') expertizes dt/x as in Table 1."""
    cfg = _cfg(rom=RoMConfig(num_experts=4, top_k=1,
                             targets=("conv", "gate", "dt", "x", "out")))
    p = rom.rom_mamba_init(jax.random.PRNGKey(0), cfg)
    assert "e_w_x" in p and "e_w_dt" in p and "w_x" not in p
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32)) * 0.5
    y, _ = rom.rom_mamba_apply(p, x, cfg, RT0)
    assert y.shape == (2, 8, 32) and bool(jnp.all(jnp.isfinite(y)))


def test_hybrid_shared_routing_eq14_15():
    """FFN-MoE with share_rom_router reuses the RoM layer's decision:
    identical expert indices, no separate router parameters."""
    cfg = _cfg(moe=MoEConfig(num_experts=4, top_k=1, d_ff=48,
                             share_rom_router=True, capacity_factor=4.0))
    p_rom = rom.rom_mamba_init(jax.random.PRNGKey(0), cfg)
    p_ffn = rom_ffn.moe_ffn_init(jax.random.PRNGKey(1), cfg)
    assert "w_router" not in p_ffn
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 32)) * 0.5
    ctx = {}
    y1, _ = rom.rom_mamba_apply(p_rom, x, cfg, RT0, ctx)
    assert "rom_routing" in ctx
    y2, _ = rom_ffn.moe_ffn_apply(p_ffn, x, cfg, RT0, ctx)
    assert y2.shape == x.shape and bool(jnp.all(jnp.isfinite(y2)))
    # and the decision really is the RoM one: perturbing the RoM router
    # weights changes the FFN output even with FFN weights fixed
    p_rom2 = dict(p_rom)
    p_rom2["w_router"] = p_rom["w_router"] + 10.0 * jax.random.normal(
        jax.random.PRNGKey(3), p_rom["w_router"].shape)
    ctx2 = {}
    rom.rom_mamba_apply(p_rom2, x, cfg, RT0, ctx2)
    y3, _ = rom_ffn.moe_ffn_apply(p_ffn, x, cfg, RT0, ctx2)
    assert not np.allclose(np.asarray(y2), np.asarray(y3))


def test_moe_ffn_dense_vs_capacity():
    cfg = _cfg()
    p = rom_ffn.moe_ffn_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32)) * 0.5
    y_cap, _ = rom_ffn.moe_ffn_apply(p, x, cfg, RT0)
    cfg_d = _cfg(moe=dataclasses.replace(cfg.moe, impl="dense"))
    y_dense, _ = rom_ffn.moe_ffn_apply(p, x, cfg_d, RT0)
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_dense),
                               atol=2e-4, rtol=2e-4)


def test_ep_fallback_matches_capacity():
    """EP path on a single device falls back to the capacity engine."""
    cfg = _cfg(moe=MoEConfig(num_experts=4, top_k=2, d_ff=48, impl="ep",
                             capacity_factor=4.0))
    p = rom_ffn.moe_ffn_init(jax.random.PRNGKey(0), cfg)
    assert "ep_w_up" in p
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32)) * 0.5
    y, m = rom_ffn.moe_ffn_apply(p, x, cfg, RT0)
    alias = {k.replace("ep_w", "e_w"): v for k, v in p.items()}
    cfg_c = _cfg(moe=dataclasses.replace(cfg.moe, impl="capacity"))
    y_cap, _ = rom_ffn.moe_ffn_apply(alias, x, cfg_c, RT0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_cap), atol=2e-4,
                               rtol=2e-4)


def test_load_balance_without_aux_loss():
    """Paper §4.3/Table 6: RoM trains without a balance loss; check the
    router at init doesn't collapse (max load < 2/E on random inputs is too
    strict; assert it's below 0.75 and every expert sees traffic across a
    large batch)."""
    cfg = _cfg(rom=RoMConfig(num_experts=8, top_k=1, jitter_eps=0.01,
                             capacity_factor=2.0))
    p = rom.rom_mamba_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 128, 32))
    rt = Runtime(shard=ShardCtx(), rng=jax.random.PRNGKey(2), train=True)
    y, m = rom.rom_mamba_apply(p, x, cfg, rt)
    assert float(m["load_max"]) < 0.75
    assert float(m["drop_frac"]) < 0.25
