"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes (spec requirement c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.grouped_matmul import grouped_matmul_pallas
from repro.kernels.selective_scan import selective_scan_pallas


def _scan_inputs(key, B, S, De, N, dtype):
    ks = jax.random.split(key, 5)
    u = jax.random.normal(ks[0], (B, S, De)).astype(dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (B, S, De)) - 1.0)
          ).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (De, N)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N)).astype(dtype)
    Cm = jax.random.normal(ks[4], (B, S, N)).astype(dtype)
    D = jnp.ones((De,), jnp.float32) * 0.5
    return u, dt, A, Bm, Cm, D


@pytest.mark.parametrize("B,S,De,N,chunk", [
    (1, 32, 8, 4, 8), (2, 64, 16, 16, 16), (2, 128, 32, 16, 64),
    (1, 96, 8, 8, 32),   # S % chunk == 0 held by construction below
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_selective_scan_pallas_vs_ref(B, S, De, N, chunk, dtype):
    u, dt, A, Bm, Cm, D = _scan_inputs(jax.random.PRNGKey(0), B, S, De, N,
                                       dtype)
    y_ref = ref.selective_scan_ref(u, dt, A, Bm, Cm, D, chunk=chunk)
    y_pal = ops.selective_scan(u, dt, A, Bm, Cm, D, chunk=chunk,
                               impl="interpret")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(y_pal, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=tol, rtol=tol)


def test_selective_scan_ref_vs_naive():
    u, dt, A, Bm, Cm, D = _scan_inputs(jax.random.PRNGKey(1), 2, 48, 8, 4,
                                       jnp.float32)
    y_ref = ref.selective_scan_ref(u, dt, A, Bm, Cm, None, chunk=16)
    y_naive = ref.selective_scan_naive(u, dt, A, Bm, Cm, None)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_naive),
                               atol=1e-4, rtol=1e-4)


def test_selective_scan_step_consistency():
    u, dt, A, Bm, Cm, D = _scan_inputs(jax.random.PRNGKey(2), 2, 16, 8, 4,
                                       jnp.float32)
    y_full = ref.selective_scan_ref(u, dt, A, Bm, Cm, D, chunk=8)
    h = jnp.zeros((2, 8, 4), jnp.float32)
    ys = []
    for t in range(16):
        h, y = ref.selective_scan_step(h, u[:, t], dt[:, t], A, Bm[:, t],
                                       Cm[:, t], D)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("E,C,D,F", [
    (2, 8, 16, 8), (4, 32, 64, 32), (3, 16, 40, 24), (8, 8, 8, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_pallas_vs_ref(E, C, D, F, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(ks[0], (E, C, D)).astype(dtype)
    w = jax.random.normal(ks[1], (E, D, F)).astype(dtype) * 0.1
    gs = jax.random.randint(ks[2], (E,), 0, C + 1)
    y_ref = ref.grouped_matmul_ref(x, w, gs)
    y_pal = grouped_matmul_pallas(x, w, gs, interpret=True)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(y_pal, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=tol, rtol=tol)


def test_grouped_matmul_zero_and_full_groups():
    x = jnp.ones((3, 8, 16))
    w = jnp.ones((3, 16, 8))
    gs = jnp.array([0, 8, 3])
    y = grouped_matmul_pallas(x, w, gs, interpret=True)
    assert float(jnp.abs(y[0]).max()) == 0.0
    np.testing.assert_allclose(np.asarray(y[1]), 16.0)
    assert float(jnp.abs(y[2, 3:]).max()) == 0.0
    np.testing.assert_allclose(np.asarray(y[2, :3]), 16.0)


def test_selective_scan_bf16_accumulation_close():
    """scan_dtype=bfloat16 (perf knob, §Perf) stays near the f32 scan."""
    u, dt, A, Bm, Cm, D = _scan_inputs(jax.random.PRNGKey(5), 2, 64, 16, 8,
                                       jnp.bfloat16)
    y32 = ref.selective_scan_ref(u, dt, A, Bm, Cm, D, chunk=16)
    y16 = ref.selective_scan_ref(u, dt, A, Bm, Cm, D, chunk=16,
                                 acc_dtype=jnp.bfloat16)
    err = np.abs(np.asarray(y16, np.float32) - np.asarray(y32, np.float32))
    scale = np.abs(np.asarray(y32, np.float32)).max()
    assert err.max() / scale < 0.05


def test_diag_recurrence_vs_naive():
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    B, S, D = 2, 40, 8
    log_a = -jax.nn.softplus(jax.random.normal(ks[0], (B, S, D)))
    b = jax.random.normal(ks[1], (B, S, D))
    y = ref.diag_recurrence(log_a, b, chunk=16)
    h = np.zeros((B, D), np.float32)
    outs = []
    la, bb = np.asarray(log_a), np.asarray(b)
    for t in range(S):
        h = np.exp(la[:, t]) * h + bb[:, t]
        outs.append(h.copy())
    np.testing.assert_allclose(np.asarray(y), np.stack(outs, 1), atol=1e-4,
                               rtol=1e-4)
