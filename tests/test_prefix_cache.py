"""Prefix cache over the StateStore: radix-tree semantics, byte-budgeted
LRU eviction, snapshot/restore round-trips, and — the contract that
matters — bit-identical greedy decode after a cache hit, per mixer pattern
and composed with interleaved admission and speculative decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from identity import PATTERNS, full_cfg as _full_cfg
from repro.models import lm
from repro.serve import (CachedSuffixFirst, PrefixCache, Request,
                         ServeEngine, StateStore, state_nbytes)
from repro.serve.state import (append_only_mask, restore_slots,
                               snapshot_slots)


# ---------------------------------------------------------------------------
# radix tree unit tests (model-free: snapshots are plain numpy pytrees)
# ---------------------------------------------------------------------------

def _snap(nbytes=64):
    return {"h": np.zeros((nbytes // 8,), np.float64)}


def test_radix_insert_lookup_longest_prefix():
    c = PrefixCache(budget_mb=1.0)
    assert c.peek_len([1, 2, 3]) == 0
    assert c.lookup([1, 2, 3]) == (0, None)
    c.insert((1, 2, 3, 4), _snap)
    c.insert((1, 2), _snap)
    assert len(c) == 2
    # longest cached prefix, capped strictly below the prompt length
    assert c.peek_len([1, 2, 3, 4, 9]) == 4
    assert c.peek_len([1, 2, 3, 4]) == 2          # own length excluded
    assert c.peek_len([1, 2, 9]) == 2
    assert c.peek_len([1, 9]) == 0
    assert c.peek_len([2, 2, 3]) == 0
    n, snap = c.lookup([1, 2, 3, 4, 9])
    assert n == 4 and snap is not None
    assert c.stats["hits"] == 1 and c.stats["hit_tokens"] == 4


def test_radix_edge_split_and_divergence():
    c = PrefixCache(budget_mb=1.0)
    c.insert((5, 6, 7, 8), _snap)
    # diverging insert splits the edge mid-way; both snapshots remain
    c.insert((5, 6, 9), _snap)
    assert c.peek_len([5, 6, 7, 8, 1]) == 4
    assert c.peek_len([5, 6, 9, 1]) == 3
    # the split node (5,6) holds no snapshot: no spurious hit at depth 2
    assert c.peek_len([5, 6, 1]) == 0
    assert not c.contains((5, 6))
    assert c.contains((5, 6, 9))
    # inserting onto the split point works
    c.insert((5, 6), _snap)
    assert c.peek_len([5, 6, 1]) == 2


def test_radix_dedup_skips_recapture():
    c = PrefixCache(budget_mb=1.0)
    calls = []

    def snap_fn():
        calls.append(1)
        return _snap()

    assert c.insert((1, 2), snap_fn) is True
    assert c.insert((1, 2), snap_fn) is False     # dedup: no second copy
    assert len(calls) == 1
    assert c.stats["dedup_skips"] == 1


def test_eviction_respects_byte_budget_lru():
    c = PrefixCache(budget_mb=1e-3)               # 1048 bytes
    big = 400
    c.insert((1,), lambda: _snap(big))
    c.insert((2,), lambda: _snap(big))
    c.lookup([1, 9])                              # touch (1,): now MRU
    c.insert((3,), lambda: _snap(big))            # exceeds budget -> evict
    assert c.bytes_used <= c.budget_bytes
    assert c.stats["evictions"] == 1
    assert c.peek_len([2, 9]) == 0                # LRU victim was (2,)
    assert c.peek_len([1, 9]) == 1
    assert c.peek_len([3, 9]) == 1


def test_eviction_prunes_and_merges_radix_nodes():
    c = PrefixCache(budget_mb=1.0)
    c.insert((1, 2, 3), _snap)
    c.insert((1, 2, 3, 4, 5), _snap)
    c.insert((1, 2, 3, 9), _snap)                 # split below (1,2,3)
    # evict the deep chain; tree must stay consistent for the others
    c._evict(c._ensure_node((1, 2, 3, 4, 5)))
    assert c.peek_len([1, 2, 3, 4, 5, 7]) == 3
    assert c.peek_len([1, 2, 3, 9, 7]) == 4
    assert [p for p, _ in c.snapshot_prefixes()] == [(1, 2, 3), (1, 2, 3, 9)]


def test_oversize_snapshot_refused():
    c = PrefixCache(budget_mb=1e-3)
    assert c.insert((1, 2), lambda: _snap(4096)) is False
    assert len(c) == 0 and c.bytes_used == 0
    assert c.stats["oversize"] == 1


def test_capture_flag_and_min_tokens():
    c = PrefixCache(budget_mb=1.0, min_tokens=4)
    assert c.insert((1, 2), _snap) is False       # below min_tokens
    assert c.insert((1, 2, 3, 4), _snap) is True
    frozen = PrefixCache(budget_mb=1.0, capture=False)
    assert frozen.insert((1, 2, 3, 4), _snap) is False


# ---------------------------------------------------------------------------
# scheduler: cache-aware ranking
# ---------------------------------------------------------------------------

def test_cached_suffix_first_ranks_by_uncached_suffix():
    c = PrefixCache(budget_mb=1.0)
    c.insert((7, 7, 7, 7, 7, 7), _snap)
    s = CachedSuffixFirst(c)
    s.add(Request(id=0, prompt=[1, 2, 3]))                  # cold, suffix 3
    s.add(Request(id=1, prompt=[7] * 6 + [8, 9]))           # hit 6, suffix 2
    s.add(Request(id=2, prompt=[7] * 6 + [1, 2, 3, 4]))     # hit 6, suffix 4
    assert s.peek_next().id == 1
    assert [s.pop_next().id for _ in range(3)] == [1, 0, 2]
    assert s.pop_next() is None and s.peek_next() is None


def test_cached_suffix_first_reranks_as_tree_fills():
    c = PrefixCache(budget_mb=1.0)
    s = CachedSuffixFirst(c)
    s.add(Request(id=0, prompt=[1, 2, 3]))                  # suffix 3
    s.add(Request(id=1, prompt=[7] * 6 + [8]))              # cold suffix 7
    assert s.peek_next().id == 0
    c.insert((7,) * 6, _snap)                     # prefix lands mid-queue
    assert s.pop_next().id == 1                   # suffix now 1: re-ranked


def test_cached_suffix_first_caps_hit_at_len_minus_one():
    """Ranking must clamp the reported hit to len(prompt)-1, exactly like
    admission's ``lookup``: a full-prompt snapshot still costs one token of
    prefill (fresh logits for the first sampled token), so a cache that
    reports a full-length hit must not let that request outrank an earlier
    one whose *restorable* suffix is the same."""
    class OverReportingCache:
        version = 0

        def peek_len(self, tokens, ns=None):
            # uncapped longest leading run of 7s (PrefixCache.peek_len
            # itself caps; this models a cache that does not)
            n = 0
            for t in tokens:
                if t != 7:
                    break
                n += 1
            return n

    s = CachedSuffixFirst(OverReportingCache())
    s.add(Request(id=0, prompt=[7, 7, 1]))      # hit 2, suffix 1
    s.add(Request(id=1, prompt=[7, 7]))         # reported 2 -> capped 1:
    s.add(Request(id=2, prompt=[5, 6]))         # ties id=0, FIFO keeps it
    assert s.peek_next().id == 0                # unclamped would pick id=1
    assert [s.pop_next().id for _ in range(3)] == [0, 1, 2]


# ---------------------------------------------------------------------------
# snapshot / restore round-trip + leaf classification
# ---------------------------------------------------------------------------

def test_snapshot_restore_roundtrip_host_copy():
    cfg = _full_cfg(((("mamba", "attn"), 1), (("mamba",), 2)))
    store = StateStore(cfg, 4, 16, jnp.float32)
    k = jax.random.PRNGKey(0)
    src = jax.tree_util.tree_map(
        lambda a: jax.random.normal(k, a.shape).astype(a.dtype),
        store.fresh(2))
    snap = snapshot_slots(src, store.axes, [1])
    for leaf in jax.tree_util.tree_leaves(snap):
        assert isinstance(leaf, np.ndarray)       # host-side copy
    assert state_nbytes(snap) == sum(
        leaf.nbytes for leaf in jax.tree_util.tree_leaves(snap))
    dst = restore_slots(store.fresh(4), snap, store.axes, [3])
    back = snapshot_slots(dst, store.axes, [3])
    for a, b in zip(jax.tree_util.tree_leaves(back),
                    jax.tree_util.tree_leaves(snap)):
        np.testing.assert_array_equal(a, b)
    # store convenience wrappers agree
    snap2 = store.snapshot_rows(src, [1])
    for a, b in zip(jax.tree_util.tree_leaves(snap2),
                    jax.tree_util.tree_leaves(snap)):
        np.testing.assert_array_equal(a, b)


def test_append_only_mask_classifies_leaves():
    cfg = _full_cfg(((("mamba", "attn"), 1),))
    store = StateStore(cfg, 2, 16, jnp.float32)
    mask = store.append_only
    blk = mask["segments"][0][0]
    assert blk["l1_attn"] == {"k": True, "v": True, "kpos": True}
    assert all(v is False for v in
               jax.tree_util.tree_leaves(blk["l0_mamba"]))
    assert jax.tree_util.tree_structure(mask) == \
        jax.tree_util.tree_structure(store.axes)
    # sliding-window attention is a ring buffer: rejected speculative
    # writes clobber live entries, so it must NOT be append-only
    wcfg = _full_cfg(((("attn",),  1),), window=8)
    wmask = append_only_mask(wcfg, StateStore(wcfg, 2, 16, jnp.float32).state)
    assert all(v is False for v in jax.tree_util.tree_leaves(wmask))


# ---------------------------------------------------------------------------
# engine integration: cache-hit greedy decode is bit-identical to cold
# ---------------------------------------------------------------------------

def _shared_prefix_requests(cfg, shared_len=12, tails=(3, 5, 4), seed=3):
    rng = np.random.default_rng(seed)
    shared = rng.integers(2, cfg.vocab_size, size=(shared_len,)).tolist()
    return [Request(id=i,
                    prompt=shared + rng.integers(
                        2, cfg.vocab_size, size=(n,)).tolist(),
                    max_new_tokens=5)
            for i, n in enumerate(tails)]


@pytest.mark.parametrize("pattern", PATTERNS,
                         ids=["+".join(p) for p in PATTERNS])
def test_cache_hit_bit_identical_to_cold_prefill(pattern):
    """Requests sharing a prompt prefix, decoded greedily: a warm cache
    (populated by a previous run over the same prefixes) must change
    nothing about the outputs — only skip prefill work."""
    cfg = _full_cfg(((pattern, 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    kw = dict(max_slots=2, max_len=48, seed=0, max_prefill_chunk=8)
    reqs = _shared_prefix_requests(cfg)
    ref = {r.id: r for r in ServeEngine(cfg, params, **kw).run(reqs)}

    cache = PrefixCache(budget_mb=16.0)
    warm = ServeEngine(cfg, params, prefix_cache=cache,
                       scheduler=CachedSuffixFirst(cache), **kw)
    warm.run(_shared_prefix_requests(cfg))        # populate the tree
    assert len(cache) > 0
    hot = ServeEngine(cfg, params, prefix_cache=cache,
                      scheduler=CachedSuffixFirst(cache), **kw)
    got = {r.id: r for r in hot.run(_shared_prefix_requests(cfg))}
    assert set(got) == set(ref)
    for i in ref:
        assert got[i].tokens == ref[i].tokens, (pattern, i)
        assert got[i].finish_reason == ref[i].finish_reason
    # the cache actually skipped prefill work on the warm run
    assert hot.stats["cache_hit_tokens"] > 0
    assert hot.stats["prefill_tokens"] < sum(len(r.prompt) for r in reqs)
    assert cache.stats["hits"] > 0


def test_cache_hit_matches_cold_in_sequential_admission():
    cfg = _full_cfg(((("mamba", "attn"), 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    kw = dict(max_slots=2, max_len=48, seed=0, max_prefill_chunk=8,
              admission="sequential")
    ref = {r.id: r for r in ServeEngine(cfg, params, **kw).run(
        _shared_prefix_requests(cfg))}
    cache = PrefixCache(budget_mb=16.0)
    eng = ServeEngine(cfg, params, prefix_cache=cache, **kw)
    got = {r.id: r for r in eng.run(_shared_prefix_requests(cfg))}
    for i in ref:
        assert got[i].tokens == ref[i].tokens, i
    assert eng.stats["cache_hit_tokens"] > 0      # later requests hit


def test_cache_composes_with_speculative_and_interleaved():
    """Prefix cache + speculative decoding + interleaved admission in one
    engine: mid-run submissions hit cached prefixes while other slots
    advance by multi-token speculative windows; outputs stay exact."""
    cfg = _full_cfg(((("mamba", "attn"), 2),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    kw = dict(max_slots=2, max_len=64, seed=0, max_prefill_chunk=8)
    reqs = _shared_prefix_requests(cfg, shared_len=16, tails=(3, 5, 4, 6))
    ref = {r.id: r for r in ServeEngine(cfg, params, **kw).run(list(reqs))}

    cache = PrefixCache(budget_mb=16.0)
    eng = ServeEngine(cfg, params, prefix_cache=cache,
                      scheduler=CachedSuffixFirst(cache),
                      speculative=3, draft_stride=2, **kw)
    eng.submit(reqs[0])
    eng.submit(reqs[1])
    results = []
    for _ in range(3):                            # decode is now active
        results.extend(eng.tick())
    eng.submit(reqs[2])                           # arrives mid-run: its
    eng.submit(reqs[3])                           # prefix is cached by now
    while eng.busy():
        results.extend(eng.tick())
    got = {r.id: r for r in results}
    assert set(got) == set(ref)
    for i in ref:
        assert got[i].tokens == ref[i].tokens, i
    assert eng.stats["cache_hit_tokens"] > 0
    assert eng.stats["spec_rounds"] > 0
    assert eng.stats["mixed_steps"] > 0


def test_batched_admission_groups_by_hit_length():
    """4 free slots, 3 queued hits + 1 cold request: the job takes the
    equal-hit-length prefix group and leaves the cold request for the next
    job (lanes advance in lockstep from one shared position)."""
    cfg = _full_cfg(((("mamba", "attn"), 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    shared = rng.integers(2, cfg.vocab_size, size=(8,)).tolist()
    cache = PrefixCache(budget_mb=16.0)
    kw = dict(max_slots=4, max_len=48, seed=0, max_prefill_chunk=8)
    ServeEngine(cfg, params, prefix_cache=cache, **kw).run(
        [Request(id=9, prompt=shared + [7, 8], max_new_tokens=2)])
    assert cache.contains(tuple(shared))

    eng = ServeEngine(cfg, params, prefix_cache=cache, **kw)
    hits = [Request(id=i, prompt=shared + rng.integers(
        2, cfg.vocab_size, size=(3,)).tolist(), max_new_tokens=4)
        for i in range(3)]
    cold = Request(id=3, prompt=rng.integers(
        2, cfg.vocab_size, size=(6,)).tolist(), max_new_tokens=4)
    for r in hits + [cold]:
        eng.submit(r)
    eng.tick()                                    # first job: the hit group
    job = eng._job
    assert job is not None
    assert sorted(l.req.id for l in job.lanes) == [0, 1, 2]
    assert job.pos >= len(shared)                 # started at the hit depth
    results = []
    while eng.busy():
        results.extend(eng.tick())
    assert {r.id for r in results} | {r.id for r in eng._drain()} >= \
        {0, 1, 2}


def test_cache_eviction_under_pressure_keeps_outputs_exact():
    """A tiny byte budget forces constant eviction; hits become rare but
    outputs must stay bit-identical to the cold engine."""
    cfg = _full_cfg(((("mamba", "attn"), 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    kw = dict(max_slots=2, max_len=48, seed=0, max_prefill_chunk=8)
    reqs = _shared_prefix_requests(cfg, shared_len=16, tails=(3, 5, 4, 6))
    ref = {r.id: r for r in ServeEngine(cfg, params, **kw).run(list(reqs))}
    store = StateStore(cfg, 1, 48, jnp.float32)
    one = state_nbytes(store.snapshot_rows(store.state, [0]))
    cache = PrefixCache(budget_mb=2.5 * one / (1 << 20))  # ~2 snapshots
    eng = ServeEngine(cfg, params, prefix_cache=cache,
                      scheduler=CachedSuffixFirst(cache), **kw)
    got = {r.id: r for r in eng.run(list(reqs))}
    for i in ref:
        assert got[i].tokens == ref[i].tokens, i
    assert cache.stats["evictions"] > 0
    assert cache.bytes_used <= cache.budget_bytes
