"""Shared fixtures.  NOTE: device count stays 1 here (smoke tests and
benches must see the real host); only launch/dryrun.py forces 512 devices,
and multi-device tests spawn subprocesses with their own XLA_FLAGS."""
import os
import subprocess
import sys

import pytest

try:
    # Deterministic, CI-friendly fuzzing profile.  The fuzz tests
    # themselves run with or without hypothesis (each has a seeded
    # stdlib-random fallback path); this only tunes the hypothesis side
    # where it is installed.
    from hypothesis import HealthCheck, settings

    settings.register_profile("repro", settings(
        max_examples=40, deadline=None, derandomize=True,
        suppress_health_check=[HealthCheck.too_slow]))
    settings.load_profile("repro")
except ImportError:
    pass


@pytest.fixture(scope="session")
def repo_src():
    return os.path.join(os.path.dirname(__file__), "..", "src")


def run_in_subprocess(code: str, n_devices: int = 8, timeout: int = 600):
    """Run python code in a fresh process with a fake device count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout)
    if r.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}")
    return r.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_in_subprocess
