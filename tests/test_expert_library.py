"""ExpertLibrary: multi-tenant RoM serving with hot-swappable expert sets.

The contract under test — the per-tenant greedy bit-identity: a shared
engine serving tenant X through an :class:`~repro.serve.expert_library.
ExpertLibrary` must emit tokens identical to a *dedicated* engine loaded
with only X's expert set, including after hot-swap / evict / fault-in
mid-run, composed with speculative decoding, prefix caching (per-set
namespaces) and sequential admission, and (slow, subprocess) under a
``data=2,model=2`` plan.  Plus the library's own unit semantics:
extraction, mirror congruence, merge/subset transforms, and byte-budgeted
LRU residency with binding-row pins.
"""
import jax
import numpy as np
import pytest

from identity import (TENANT_PATTERNS, dedicated_params, full_cfg,
                      random_prompts, run_tokens)
from repro.models import lm
from repro.serve import ExpertLibrary, PrefixCache, Request, ServeEngine
from repro.serve.scheduler import CachedSuffixFirst


def _library(cfg, params, names=("b",), seeds=(7,), **kw):
    lib = ExpertLibrary(cfg, params, **kw)
    for name, seed in zip(names, seeds):
        lib.add(name, lm.init_params(jax.random.PRNGKey(seed), cfg))
    return lib


def _dedicated_tokens(cfg, params, tenant_seed, prompt, gen, **kw):
    """Tokens from an engine holding ONLY this tenant's expert set."""
    if tenant_seed is None:
        ded = params
    else:
        ded = dedicated_params(
            cfg, params, lm.init_params(jax.random.PRNGKey(tenant_seed), cfg))
    eng = ServeEngine(cfg, ded, max_slots=2, max_len=48, seed=0, **kw)
    return eng.run([Request(id=0, prompt=prompt, max_new_tokens=gen)])[0] \
        .tokens


# ---------------------------------------------------------------------------
# library unit semantics
# ---------------------------------------------------------------------------

def test_extract_is_sparse_swappable_mirror():
    cfg = full_cfg(((("rom_mamba", "mlp"), 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    lib = ExpertLibrary(cfg, params)
    mirror = lib.extract(params)
    names = set()

    def walk(d):
        for k, v in d.items():
            if isinstance(v, dict):
                walk(v)
            else:
                names.add(k)
    walk(mirror["segments"][0][0]["l0_rom_mamba"])
    assert all(n.startswith("e_w_") or n == "w_router" for n in names)
    assert "w_router" in names and any(n.startswith("e_w_") for n in names)
    # the mlp block carries no experts and is absent from the mirror
    assert set(mirror["segments"][0][0]) == {"l0_rom_mamba"}
    # extracted values are the base leaves themselves (same numbers)
    base = params["segments"][0][0]["l0_rom_mamba"]["w_router"]
    np.testing.assert_array_equal(
        np.asarray(base), mirror["segments"][0][0]["l0_rom_mamba"]["w_router"])


def test_moemamba_mirror_keeps_nested_routers():
    cfg = full_cfg(((("moemamba",), 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    lib = ExpertLibrary(cfg, params)
    blk = lib.extract(params)["segments"][0][0]["l0_moemamba"]
    routers = [k for k, v in blk.items()
               if isinstance(v, dict) and "w_router" in v]
    assert routers, blk.keys()          # conv/gate/out router dicts survive


def test_add_accepts_full_params_and_mirrors():
    cfg = full_cfg(((("rom_mamba", "mlp"), 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    alt = lm.init_params(jax.random.PRNGKey(1), cfg)
    lib = ExpertLibrary(cfg, params)
    lib.add("full", alt)                         # full tree: extracted
    lib.add("mirror", lib.extract(alt))          # mirror: stored as-is
    a = jax.tree_util.tree_leaves(lib._host["full"])
    b = jax.tree_util.tree_leaves(lib._host["mirror"])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_add_rejects_incongruent_set():
    cfg = full_cfg(((("rom_mamba", "mlp"), 1),))
    big = full_cfg(((("rom_mamba", "mlp"), 1),), d_model=64)
    lib = ExpertLibrary(cfg, lm.init_params(jax.random.PRNGKey(0), cfg))
    with pytest.raises(ValueError, match="congruent"):
        lib.add("bad", lm.init_params(jax.random.PRNGKey(1), big))


def test_library_requires_swappable_blocks():
    cfg = full_cfg(((("mamba", "attn"), 1),))
    with pytest.raises(ValueError, match="swappable"):
        ExpertLibrary(cfg, lm.init_params(jax.random.PRNGKey(0), cfg))


def test_merge_is_weighted_average():
    cfg = full_cfg(((("rom_mamba", "mlp"), 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    lib = _library(cfg, params, names=("b",), seeds=(7,))
    lib.merge("m", ["base", "b"], weights=[3.0, 1.0])
    for base_l, b_l, m_l in zip(
            jax.tree_util.tree_leaves(lib._host["base"]),
            jax.tree_util.tree_leaves(lib._host["b"]),
            jax.tree_util.tree_leaves(lib._host["m"])):
        want = 0.75 * base_l.astype(np.float32) + 0.25 * b_l.astype(
            np.float32)
        np.testing.assert_allclose(m_l, want.astype(base_l.dtype), rtol=1e-6)


def test_subset_takes_expert_rows_from_source():
    cfg = full_cfg(((("rom_mamba", "mlp"), 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    lib = _library(cfg, params)
    lib.subset("s", "b", [1, 3])

    def leaves_named(tree):
        out = {}

        def walk(node, path):
            items = (node.items() if isinstance(node, dict)
                     else enumerate(node))
            for k, v in items:
                if isinstance(v, (dict, list)):
                    walk(v, path + (k,))
                else:
                    out[path + (k,)] = v
        walk(tree, ())
        return out

    base = leaves_named(lib._host["base"])
    src = leaves_named(lib._host["b"])
    got = leaves_named(lib._host["s"])
    for key, leaf in got.items():
        name = key[-1]
        ax = leaf.ndim - 1 if name == "w_router" else leaf.ndim - 3
        for e in range(leaf.shape[ax]):
            sl = [slice(None)] * leaf.ndim
            sl[ax] = e
            want = src[key] if e in (1, 3) else base[key]
            np.testing.assert_array_equal(leaf[tuple(sl)], want[tuple(sl)])
    with pytest.raises(ValueError, match="out of range"):
        lib.subset("oob", "b", [99])


def test_residency_lru_budget_and_pins():
    cfg = full_cfg(((("rom_mamba", "mlp"), 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    one_set_mb = ExpertLibrary(cfg, params).nbytes("base") / (1 << 20)
    # budget fits ~2 sets: acquiring a third evicts the unpinned LRU one
    lib = _library(cfg, params, names=("b", "c"), seeds=(7, 11),
                   budget_mb=2.5 * one_set_mb, max_bound=2)
    lib.acquire("base")
    lib.acquire("b")
    lib.release("b")                    # unpinned: eviction candidate
    lib.acquire("c")
    assert "b" not in lib.resident()
    assert lib.stats["evictions"] == 1
    # host copy survives eviction: faulting back in works
    lib.release("c")
    lib.acquire("b")
    assert "b" in lib.resident()
    assert lib.stats["faults"] >= 3
    # pinned sets are never evicted even over budget: overcommit instead
    lib.acquire("c")
    assert lib.bytes_device > lib.budget_bytes
    assert lib.stats["overcommit"] >= 1
    assert set(lib.resident()) == {"base", "b", "c"}
    with pytest.raises(ValueError, match="unpinned"):
        lib.release("b")
        lib.release("b")
    with pytest.raises(ValueError, match="pin"):
        lib.add("base", params)         # replacing a pinned set refused
    with pytest.raises(KeyError):
        lib.acquire("missing")


def test_graft_single_vs_tuple_leaves():
    cfg = full_cfg(((("rom_mamba", "mlp"), 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    lib = _library(cfg, params, max_bound=2)
    lib.acquire("base")
    lib.acquire("b")
    single = lib.graft(params, ["b"])
    blk = single["segments"][0][0]["l0_rom_mamba"]
    assert not isinstance(blk["w_router"], tuple)
    multi = lib.graft(params, ["base", "b"])
    blk = multi["segments"][0][0]["l0_rom_mamba"]
    assert isinstance(blk["w_router"], tuple) and len(blk["w_router"]) == 2
    # non-swapped leaves stay the base arrays in both grafts
    assert single["embed"] is params["embed"]
    assert multi["embed"] is params["embed"]


# ---------------------------------------------------------------------------
# engine integration: per-tenant greedy bit-identity
# ---------------------------------------------------------------------------

def test_submit_validates_expert_set():
    cfg = full_cfg(((("rom_mamba", "mlp"), 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_slots=1, max_len=16)
    with pytest.raises(ValueError, match="no ExpertLibrary"):
        eng.submit(Request(id=0, prompt=[1, 2], expert_set="b"))
    lib = _library(cfg, params)
    eng2 = ServeEngine(cfg, params, max_slots=1, max_len=16,
                       expert_library=lib)
    with pytest.raises(KeyError, match="unknown expert set"):
        eng2.submit(Request(id=0, prompt=[1, 2], expert_set="nope"))


@pytest.mark.parametrize("pattern", TENANT_PATTERNS,
                         ids=["+".join(p) for p in TENANT_PATTERNS])
def test_multi_tenant_greedy_identical_to_dedicated(pattern):
    """The headline gate: a shared engine interleaving tenants through one
    ExpertLibrary emits, for every request, exactly the tokens a dedicated
    engine loaded with only that tenant's expert set emits — for every
    swappable mixer family (rom_* projections; moemamba's nested
    per-projection routers)."""
    cfg = full_cfg(((pattern, 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    lib = _library(cfg, params, max_bound=2)
    prompts = random_prompts(cfg, [5, 9, 4, 7], seed=1)
    tenants = [None, "b", "b", None]
    shared = ServeEngine(cfg, params, max_slots=2, max_len=48, seed=0,
                         expert_library=lib)
    res = run_tokens(shared, [
        Request(id=i, prompt=p, max_new_tokens=6, expert_set=t)
        for i, (p, t) in enumerate(zip(prompts, tenants))])
    for i, t in enumerate(tenants):
        ref = _dedicated_tokens(cfg, params, 7 if t else None,
                                prompts[i], 6)
        assert res[i] == ref, (pattern, i, t)
    assert shared.stats["expert_swaps"] >= 1
    # the sets genuinely differ: tenant b's tokens != base on b's prompt
    assert res[1] != _dedicated_tokens(cfg, params, None, prompts[1], 6)


def test_hot_swap_evict_fault_in_mid_run_stays_identical():
    """More tenants than binding rows + a budget of well under one set:
    admission rebinds rows mid-run and the library evicts/faults sets
    continuously — outputs must not change."""
    cfg = full_cfg(((("rom_mamba", "mlp"), 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    seeds = {"t0": 3, "t1": 7, "t2": 11}
    lib = _library(cfg, params, names=tuple(seeds), seeds=tuple(
        seeds.values()), budget_mb=0.2, max_bound=2)
    prompts = random_prompts(cfg, [4 + i % 5 for i in range(9)], seed=2)
    tenants = [[None, "t0", "t1", "t2"][i % 4] for i in range(9)]
    eng = ServeEngine(cfg, params, max_slots=2, max_len=48, seed=0,
                      expert_library=lib)
    res = run_tokens(eng, [
        Request(id=i, prompt=p, max_new_tokens=5, expert_set=t)
        for i, (p, t) in enumerate(zip(prompts, tenants))])
    assert eng.stats["expert_swaps"] >= 3
    assert lib.stats["evictions"] >= 1          # residency actually churned
    assert lib.stats["faults"] > len(seeds) + 1  # sets faulted back in
    for i, t in enumerate(tenants):
        ref = _dedicated_tokens(cfg, params, seeds.get(t), prompts[i], 5)
        assert res[i] == ref, (i, t)


def test_tenant_identity_composes_with_speculative_and_sequential():
    cfg = full_cfg(((("rom_mamba", "mlp"), 2),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = random_prompts(cfg, [5, 9, 4], seed=4)
    tenants = [None, "b", "b"]
    for kw in (dict(speculative=2, draft_stride=2),
               dict(admission="sequential")):
        lib = _library(cfg, params, max_bound=2)
        eng = ServeEngine(cfg, params, max_slots=2, max_len=48, seed=0,
                          expert_library=lib, **kw)
        res = run_tokens(eng, [
            Request(id=i, prompt=p, max_new_tokens=5, expert_set=t)
            for i, (p, t) in enumerate(zip(prompts, tenants))])
        for i, t in enumerate(tenants):
            ref = _dedicated_tokens(cfg, params, 7 if t else None,
                                    prompts[i], 5)
            assert res[i] == ref, (kw, i, t)


def test_prefix_cache_namespaces_isolate_tenants():
    """One prompt served under two tenants: snapshots must not cross
    expert-set namespaces (a prefix prefilled with X's weights is wrong
    for Y), while repeat requests within a tenant do hit."""
    cfg = full_cfg(((("rom_mamba", "mlp"), 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    lib = _library(cfg, params, max_bound=2)
    cache = PrefixCache(budget_mb=32.0, grain=4)
    eng = ServeEngine(cfg, params, max_slots=2, max_len=48, seed=0,
                      expert_library=lib, prefix_cache=cache,
                      scheduler=CachedSuffixFirst(cache))
    prompt = random_prompts(cfg, [12], seed=5)[0]
    r0 = eng.run([Request(id=0, prompt=prompt, max_new_tokens=6)])[0]
    r1 = eng.run([Request(id=1, prompt=prompt, max_new_tokens=6,
                          expert_set="b")])[0]
    r2 = eng.run([Request(id=2, prompt=prompt, max_new_tokens=6,
                          expert_set="b")])[0]
    assert cache.summary()["namespaces"] == 2
    assert eng.stats["cache_hit_tokens"] > 0     # r2 hit r1's snapshots
    assert r1.tokens == r2.tokens
    ref_b = _dedicated_tokens(cfg, params, 7, prompt, 6)
    assert r1.tokens == ref_b                   # incl. the cache-hit run
    assert r0.tokens == _dedicated_tokens(cfg, params, None, prompt, 6)
    assert r0.tokens != ref_b


def test_derived_sets_serve_and_differ():
    """merge/subset-derived sets are first-class tenants: they serve, and
    a merged set's outputs differ from both parents (the weights really
    are interpolated)."""
    cfg = full_cfg(((("rom_mamba", "mlp"), 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    lib = _library(cfg, params, max_bound=2)
    lib.merge("m", ["base", "b"])
    prompt = random_prompts(cfg, [10], seed=6)[0]
    eng = ServeEngine(cfg, params, max_slots=2, max_len=48, seed=0,
                      expert_library=lib)
    res = run_tokens(eng, [
        Request(id=0, prompt=prompt, max_new_tokens=6, expert_set="m"),
        Request(id=1, prompt=prompt, max_new_tokens=6),
        Request(id=2, prompt=prompt, max_new_tokens=6, expert_set="b")])
    assert res[0] != res[1] and res[0] != res[2]


def test_merged_set_dedicated_identity():
    cfg = full_cfg(((("rom_mamba", "mlp"), 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    lib = _library(cfg, params, max_bound=2)
    lib.merge("m", ["base", "b"])
    prompt = random_prompts(cfg, [10], seed=6)[0]
    eng = ServeEngine(cfg, params, max_slots=2, max_len=48, seed=0,
                      expert_library=lib)
    got = eng.run([Request(id=0, prompt=prompt, max_new_tokens=6,
                           expert_set="m")])[0].tokens
    ref_lib = _library(cfg, params, max_bound=1)
    ref_lib.merge("m", ["base", "b"])
    ref_lib.acquire("m")
    ded = ref_lib.graft(params, ["m"])
    ref = ServeEngine(cfg, ded, max_slots=2, max_len=48, seed=0).run(
        [Request(id=0, prompt=prompt, max_new_tokens=6)])[0].tokens
    assert got == ref


# ---------------------------------------------------------------------------
# sharded: data=2,model=2 (subprocess, 8 forced host devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_multi_tenant_sharded_identity(subproc):
    """Per-tenant greedy identity under a ``data=2,model=2`` plan: slots
    shard over data, expert leaves (all bound sets alike, via the
    name-based sharding rules) over model — outputs still match the
    dedicated single-device engines."""
    subproc("""
import jax, numpy as np
from repro.configs.base import (AttentionConfig, MambaConfig, ModelConfig,
                                RoMConfig)
from repro.distributed.plan import ParallelPlan
from repro.models import lm
from repro.serve import ExpertLibrary, Request, ServeEngine

cfg = ModelConfig(name="t", d_model=32, vocab_size=64,
                  segments=((("rom_mamba", "mlp"), 1),), d_ff=64,
                  mamba=MambaConfig(d_state=4, chunk=8),
                  attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                            head_dim=8),
                  rom=RoMConfig(num_experts=4, top_k=2, jitter_eps=0.0,
                                capacity_factor=8.0, impl="capacity"),
                  dtype="float32")
params = lm.init_params(jax.random.PRNGKey(0), cfg)
alt = lm.init_params(jax.random.PRNGKey(7), cfg)
rng = np.random.default_rng(1)
prompts = [rng.integers(2, cfg.vocab_size, size=(n,)).tolist()
           for n in [5, 9, 4, 7]]
tenants = [None, "b", "b", None]

def tokens(engine, reqs):
    return {r.id: r.tokens for r in engine.run(reqs)}

plan = ParallelPlan.host(data=2, model=2)
lib = ExpertLibrary(cfg, params, budget_mb=64.0, max_bound=2)
lib.add("b", alt)
eng = ServeEngine(cfg, params, plan=plan, max_slots=2, max_len=48, seed=0,
                  expert_library=lib)
res = tokens(eng, [Request(id=i, prompt=p, max_new_tokens=6, expert_set=t)
                   for i, (p, t) in enumerate(zip(prompts, tenants))])
assert eng.stats["expert_swaps"] >= 1
# faulted-in sets landed with the plan's expert partition applied
leaf = jax.tree_util.tree_leaves(lib.device_tree("b"))[0]
assert leaf.sharding.spec != (None,) * leaf.ndim, leaf.sharding

ref_lib = ExpertLibrary(cfg, params, budget_mb=64.0, max_bound=1)
ref_lib.add("b", alt)
ref_lib.acquire("b")
ded_b = ref_lib.graft(params, ["b"])
for i, t in enumerate(tenants):
    ded = ServeEngine(cfg, params if t is None else ded_b, max_slots=2,
                      max_len=48, seed=0)
    ref = ded.run([Request(id=0, prompt=prompts[i], max_new_tokens=6)])[0]
    assert res[i] == ref.tokens, (i, t, res[i], ref.tokens)
print("sharded tenant identity OK")
""", n_devices=8)
