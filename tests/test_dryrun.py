"""Dry-run machinery tests: HLO collective parsing + one real (tiny) cell
lowered on fake 8-device production-mesh-shaped topology (the 512-chip
cells run via launch/dryrun.py; this keeps CI minutes sane)."""
import pytest

from repro.distributed import hlo_analysis as hlo

pytestmark = []


HLO_SAMPLE = """
  %all-reduce = f32[128,1024]{1,0} all-reduce(%x), channel_id=1, replica_groups=[32,16]<=[512], use_global_device_ids=true, to_apply=%add
  %ag = bf16[256,512]{1,0} all-gather(%y), channel_id=2, replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %rs = f32[64]{0} reduce-scatter(%z), channel_id=3, replica_groups=[2,256]<=[512], dimensions={0}, to_apply=%add
  %a2a = bf16[16,32]{1,0} all-to-all(%w), channel_id=4, replica_groups=[64,8]<=[512], dimensions={0}
  %cp = f32[8,8]{1,0} collective-permute(%v), channel_id=5, source_target_pairs={{0,1},{1,0}}
  %noop = f32[4]{0} add(%a, %b)
"""


def test_parse_collectives_kinds_and_bytes():
    st = hlo.parse_collectives(HLO_SAMPLE)
    assert st.counts == {"all-reduce": 1, "all-gather": 1,
                         "reduce-scatter": 1, "all-to-all": 1,
                         "collective-permute": 1}
    assert st.bytes_by_kind["all-reduce"] == 128 * 1024 * 4
    assert st.bytes_by_kind["all-gather"] == 256 * 512 * 2
    # ring models
    g = 16
    ar = st.wire_bytes_by_kind["all-reduce"]
    assert abs(ar - 2 * 128 * 1024 * 4 * (g - 1) / g) < 1
    ag = st.wire_bytes_by_kind["all-gather"]
    assert abs(ag - 256 * 512 * 2 * 3 / 4) < 1
    rs = st.wire_bytes_by_kind["reduce-scatter"]
    assert abs(rs - 64 * 4 * 255) < 1
    # group of 256 uses ICI; collective seconds are positive and finite
    assert st.seconds > 0


def test_cross_pod_uses_dcn_rate():
    line = ("  %ar = f32[1024]{0} all-reduce(%x), channel_id=9, "
            "replica_groups=[1,512]<=[512], to_apply=%add")
    st = hlo.parse_collectives(line)
    w = st.wire_bytes_by_kind["all-reduce"]
    assert abs(st.seconds - w / hlo.DCN_BW) < 1e-12   # 512 > pod size


def test_roofline_terms_bottleneck():
    st = hlo.parse_collectives("")
    terms = hlo.roofline_terms({"flops": 197e12, "bytes accessed": 1e9}, st)
    assert terms["bottleneck"] == "compute"
    assert abs(terms["compute_s"] - 1.0) < 1e-9
    terms = hlo.roofline_terms({"flops": 1e12, "bytes accessed": 819e9}, st)
    assert terms["bottleneck"] == "memory"
    assert abs(terms["memory_s"] - 1.0) < 1e-9


def test_model_flops_conventions():
    from repro.configs.base import get_config, SHAPES
    cfg = get_config("qwen1.5-0.5b")
    f_train = hlo.model_flops(cfg, SHAPES["train_4k"], 256)
    f_decode = hlo.model_flops(cfg, SHAPES["decode_32k"], 256)
    assert f_train > 100 * f_decode          # 6N*S vs 2N*1 per sequence
    assert f_train > 0 and f_decode > 0


@pytest.mark.slow
def test_tiny_cell_lowers_on_8_devices(subproc):
    """The full lower->compile->analyse pipeline on a mesh-shaped topology
    (2x2x2 pod/data/model) with a reduced config."""
    subproc("""
import os
import jax, jax.numpy as jnp
from repro.configs.all_configs import reduce_for_smoke
from repro.configs.base import get_config, InputShape
from repro import train as tr
from repro.launch import specs as sp
from repro.distributed import hlo_analysis as hlo
from repro.distributed.sharding import ShardingRules

cfg = reduce_for_smoke(get_config("rom-mamba-115m"))
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
shape = InputShape("tiny", 64, 8, "train")
fn = tr.make_train_fn(cfg, mesh, ShardingRules())
st_shapes = tr.train_state_shapes(cfg)
st_sh = tr.state_shardings(st_shapes, mesh)
batch = sp.input_specs(cfg, shape)
b_sh = tr.batch_shardings(batch, mesh)
lowered = jax.jit(fn, in_shardings=(st_sh, b_sh),
                  out_shardings=(st_sh, None)).lower(st_shapes, batch)
compiled = lowered.compile()
cost = compiled.cost_analysis()
colls = hlo.parse_collectives(compiled.as_text())
terms = hlo.roofline_terms(cost, colls)
assert terms["hlo_flops_per_device"] > 0
assert compiled.memory_analysis().temp_size_in_bytes > 0
print("tiny multi-pod cell OK:", terms["bottleneck"],
      sorted(colls.counts.items()))
""", n_devices=8, timeout=900)
