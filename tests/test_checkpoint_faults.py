"""Checkpoint atomicity + fault-tolerant restart determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro import train as tr
from repro.configs.all_configs import reduce_for_smoke
from repro.configs.base import get_config
from repro.data.pipeline import corpus_for
from repro.distributed.fault_tolerance import RunManager


def _tiny_cfg():
    return reduce_for_smoke(get_config("rom-mamba-115m"))


def test_save_restore_roundtrip(tmp_path):
    cfg = _tiny_cfg()
    state = tr.init_train_state(cfg)
    ckpt.save(str(tmp_path), 7, state)
    assert ckpt.latest_step(str(tmp_path)) == 7
    target = jax.eval_shape(lambda: tr.init_train_state(cfg))
    restored, step = ckpt.restore(str(tmp_path), target)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_commit_no_tmp_visible(tmp_path):
    cfg = _tiny_cfg()
    state = tr.init_train_state(cfg)
    t = ckpt.save(str(tmp_path), 1, state, async_=True)
    t.join()
    names = os.listdir(tmp_path)
    assert not any(n.startswith(".tmp") for n in names)
    assert ckpt.available_steps(str(tmp_path)) == [1]


def test_restart_resumes_exactly(tmp_path):
    """A run interrupted by an injected failure must produce the SAME final
    state as an uninterrupted run (stateless-deterministic data pipeline +
    checkpoint restart)."""
    cfg = _tiny_cfg()
    corpus = corpus_for(cfg, 32, 4)

    def data_ok(step):
        return {k: jnp.asarray(v) for k, v in corpus.batch_at(step).items()}

    def init_fn():
        return tr.init_train_state(cfg, seed=3)

    step_fn = jax.jit(tr.make_train_fn(cfg))

    # uninterrupted reference
    mgr_a = RunManager(str(tmp_path / "a"), save_every=2, async_save=False)
    ref_state, _ = mgr_a.run(init_fn=init_fn, step_fn=step_fn,
                             data_fn=data_ok, num_steps=6)

    # interrupted at step 4 (after a checkpoint at step 4? save_every=2)
    boom = {"armed": True}

    def data_fail(step):
        if step == 4 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")
        return data_ok(step)

    mgr_b = RunManager(str(tmp_path / "b"), save_every=2, async_save=False)
    state_b, _ = mgr_b.run(init_fn=init_fn, step_fn=step_fn,
                           data_fn=data_fail, num_steps=6)
    assert mgr_b.restarts == 1
    for a, b in zip(jax.tree_util.tree_leaves(ref_state["params"]),
                    jax.tree_util.tree_leaves(state_b["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_run_manager_gives_up_after_max_failures(tmp_path):
    cfg = _tiny_cfg()

    def init_fn():
        return tr.init_train_state(cfg)

    def bad_data(step):
        raise RuntimeError("always failing")

    mgr = RunManager(str(tmp_path), save_every=1, max_failures=2,
                     async_save=False)
    with pytest.raises(RuntimeError):
        mgr.run(init_fn=init_fn, step_fn=lambda s, b: (s, {}),
                data_fn=bad_data, num_steps=3)
    assert mgr.failures == 3


def test_straggler_monitor_flags_slow_steps():
    from repro.distributed.fault_tolerance import StragglerMonitor
    mon = StragglerMonitor(factor=2.0, window=16)
    for i in range(10):
        assert mon.record(0.1, i) is None
    lag = mon.record(0.5, 10)
    assert lag is not None and lag > 2.0
    assert mon.flags and mon.flags[0][0] == 10


def test_corrupt_latest_falls_back(tmp_path):
    """A half-written (crashed) checkpoint dir is never visible as latest."""
    cfg = _tiny_cfg()
    state = tr.init_train_state(cfg)
    ckpt.save(str(tmp_path), 2, state)
    # simulate crash: tmp dir exists but was never renamed
    os.makedirs(tmp_path / ".tmp_step_00000005")
    assert ckpt.latest_step(str(tmp_path)) == 2
