"""Decode fast path, phase 2: per-mixer fused step kernels (interpret mode)
vs the kernels/ref.py oracles, the in-kernel sampling epilogue, the tile
autotuner plumbing, the registry resolution-order contract, and engine-level
greedy identity of ``EngineConfig(kernels=...)`` for every recurrent mixer
across admission / speculative / prefix-cache serving modes."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops, ref
from repro.models import lm
from repro.serve import EngineConfig, PrefixCache, Request, ServeEngine
from test_decode_kernels import _full_cfg


# ---------------------------------------------------------------------------
# per-mixer kernels vs oracle (interpret mode, dtype sweep, multi-tile)
# ---------------------------------------------------------------------------

def _tol(dtype):
    return 5e-2 if dtype == jnp.bfloat16 else 1e-4


def _assert_close(got, want, dtype):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("fused", [False, True], ids=["core", "epilogue"])
def test_mamba2_step_kernel_vs_ref(dtype, fused):
    B, H, P, N, Dm = 2, 4, 16, 8, 24
    De = H * P
    ks = jax.random.split(jax.random.PRNGKey(0), 9)
    h = jax.random.normal(ks[0], (B, H, P, N), jnp.float32)
    xh = jax.random.normal(ks[1], (B, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[2], (B, H), jnp.float32))
    A_log = jax.random.normal(ks[3], (H,), jnp.float32) * 0.1
    Bt = jax.random.normal(ks[4], (B, N)).astype(dtype)
    Ct = jax.random.normal(ks[5], (B, N)).astype(dtype)
    Dh = jax.random.normal(ks[6], (H,), jnp.float32)
    z = jax.random.normal(ks[7], (B, De)).astype(dtype)
    scale = jnp.ones((De,), jnp.float32)
    w = ((jax.random.normal(ks[8], (De, Dm)) * 0.1).astype(dtype)
         if fused else None)
    h_r, y_r = ref.mamba2_step(h, xh, dt, A_log, Bt, Ct, Dh, z, scale, 1e-6,
                               w_out=w)
    # de_tile=16 forces a 4-tile sweep through the global-rmsnorm factoring
    from repro.kernels.mixer_steps import mamba2_step_pallas
    a = jnp.exp(dt * -jnp.exp(A_log))
    a_ch = jnp.broadcast_to(a[..., None], (B, H, P)).reshape(B, De)
    dt_ch = jnp.broadcast_to(dt[..., None], (B, H, P)).reshape(B, De)
    D_ch = jnp.broadcast_to(Dh[:, None], (H, P)).reshape(De)
    h_p, y_p = mamba2_step_pallas(h.reshape(B, De, N), xh.reshape(B, De),
                                  a_ch, dt_ch, Bt, Ct, D_ch, z, scale, 1e-6,
                                  w, de_tile=16, interpret=True)
    _assert_close(h_p.reshape(B, H, P, N), h_r, dtype)
    _assert_close(y_p, y_r, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("fused", [False, True], ids=["core", "epilogue"])
def test_gdn_step_kernel_vs_ref(dtype, fused):
    B, H, K, V, Dm = 2, 4, 8, 16, 24
    ks = jax.random.split(jax.random.PRNGKey(1), 9)
    S = jax.random.normal(ks[0], (B, H, K, V), jnp.float32)
    q = jax.random.normal(ks[1], (B, H, K)).astype(dtype)
    k = jax.random.normal(ks[2], (B, H, K)).astype(dtype)
    v = jax.random.normal(ks[3], (B, H, V)).astype(dtype)
    a = jax.nn.sigmoid(jax.random.normal(ks[4], (B, H), jnp.float32))
    b = jax.nn.sigmoid(jax.random.normal(ks[5], (B, H), jnp.float32))
    z = jax.random.normal(ks[6], (B, H * V)).astype(dtype)
    scale = jnp.ones((H * V,), jnp.float32)
    w = ((jax.random.normal(ks[7], (H * V, Dm)) * 0.1).astype(dtype)
         if fused else None)
    S_r, y_r = ref.gdn_step(S, q, k, v, a, b, z, scale, 1e-6, w_out=w)
    # h_tile=2 forces a 2-tile head sweep through the global-rmsnorm
    from repro.kernels.mixer_steps import gdn_step_pallas
    S_p, y_p = gdn_step_pallas(S, q, k, v, a, b, z, scale, 1e-6, w,
                               h_tile=2, interpret=True)
    _assert_close(S_p, S_r, dtype)
    _assert_close(y_p, y_r, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("fused", [False, True], ids=["core", "epilogue"])
def test_rglru_step_kernel_vs_ref(dtype, fused):
    B, D, Dm = 2, 64, 24
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    h = jax.random.normal(ks[0], (B, D), jnp.float32)
    u = jax.random.normal(ks[1], (B, D)).astype(dtype)
    log_a = -jax.nn.softplus(jax.random.normal(ks[2], (B, D), jnp.float32))
    ig = jax.nn.sigmoid(jax.random.normal(ks[3], (B, D), jnp.float32))
    gate = jax.nn.gelu(u) if fused else None
    w = ((jax.random.normal(ks[4], (D, Dm)) * 0.1).astype(dtype)
         if fused else None)
    h_r, y_r = ref.rglru_step(h, u, log_a, ig, gate=gate, w_out=w)
    from repro.kernels.mixer_steps import rglru_step_pallas
    h_p, y_p = rglru_step_pallas(h, u, log_a, ig, gate, w, d_tile=16,
                                 interpret=True)
    _assert_close(h_p, h_r, dtype)
    _assert_close(y_p, y_r, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("fused", [False, True], ids=["core", "epilogue"])
def test_mlstm_step_kernel_vs_ref(dtype, fused):
    B, H, K, V, Dm = 2, 4, 8, 16, 24
    ks = jax.random.split(jax.random.PRNGKey(3), 10)
    C = jax.random.normal(ks[0], (B, H, K, V), jnp.float32)
    n = jax.random.normal(ks[1], (B, H, K), jnp.float32)
    m = jax.random.normal(ks[2], (B, H), jnp.float32) * 0.1
    q = jax.random.normal(ks[3], (B, H, K), jnp.float32)
    k = jax.random.normal(ks[4], (B, H, K), jnp.float32)
    v = jax.random.normal(ks[5], (B, H, V), jnp.float32)
    il = jax.random.normal(ks[6], (B, H), jnp.float32)
    fl = -jax.nn.softplus(jax.random.normal(ks[7], (B, H), jnp.float32))
    z = jax.random.normal(ks[8], (B, H * V)).astype(dtype)
    gn = jnp.ones((H * V,), jnp.float32)
    w = ((jax.random.normal(ks[9], (H * V, Dm)) * 0.1).astype(dtype)
         if fused else None)
    r = ref.mlstm_step(C, n, m, q, k, v, il, fl, z, gn, 1e-6, w_out=w)
    from repro.kernels.mixer_steps import mlstm_step_pallas
    p = mlstm_step_pallas(C, n, m, q, k, v, il, fl, z, gn, 1e-6, w,
                          h_tile=2, interpret=True)
    for got, want in zip(p, r):
        _assert_close(got, want, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("fused", [False, True], ids=["core", "ffn"])
def test_slstm_step_kernel_vs_ref(dtype, fused):
    B, H, Dh, F, Dm = 2, 2, 16, 48, 24
    inner = H * Dh
    ks = jax.random.split(jax.random.PRNGKey(4), 10)
    c = jax.random.normal(ks[0], (B, H, Dh), jnp.float32)
    n = jnp.abs(jax.random.normal(ks[1], (B, H, Dh), jnp.float32)) + 1.0
    h = jax.random.normal(ks[2], (B, H, Dh), jnp.float32)
    m = jax.random.normal(ks[3], (B, H, Dh), jnp.float32) * 0.1
    gx = jax.random.normal(ks[4], (B, 4 * inner)).astype(dtype)
    rw = jax.random.normal(ks[5], (H, Dh, 4 * Dh), jnp.float32) * 0.1
    b = jax.random.normal(ks[6], (4 * inner,), jnp.float32) * 0.1
    gn = jnp.ones((inner,), jnp.float32)
    kw = {}
    if fused:
        kw = dict(
            w_up=(jax.random.normal(ks[7], (inner, F)) * 0.1).astype(dtype),
            w_gate=(jax.random.normal(ks[8], (inner, F)) * 0.1).astype(dtype),
            w_down=(jax.random.normal(ks[9], (F, Dm)) * 0.1).astype(dtype))
    r = ref.slstm_step(c, n, h, m, gx, rw, b, gn, 1e-6, **kw)
    # h_tile=1 forces a 2-tile head sweep through the dual FFN accumulators
    from repro.kernels.mixer_steps import slstm_step_pallas
    p = slstm_step_pallas(c, n, h, m, gx, rw, b.reshape(H, 4 * Dh), gn,
                          1e-6, **kw, h_tile=1, interpret=True)
    for got, want in zip(p, r):
        _assert_close(got, want, dtype)


@pytest.mark.parametrize("op", ["mamba2_step", "gdn_step", "rglru_step",
                                "mlstm_step", "slstm_step", "logits_step"])
def test_step_ops_offer_all_four_impls(op):
    """Every new step op offers ref/fused/pallas/interpret, with off-TPU
    'pallas' aliasing 'fused' (== the ref composition) — the invariant the
    engine-level greedy bit-identity tests ride on."""
    assert ops.resolve_impl(op, "pallas") == "fused"
    assert ops.resolve_impl(op, "fused") == "fused"
    assert ops.resolve_impl(op, "ref") == "ref"
    # 'interpret' must never be remapped (it is the CPU kernel test path)
    assert ops.resolve_impl(op, "interpret") == "interpret"


# ---------------------------------------------------------------------------
# fused sampling epilogue
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tied", [True, False])
@pytest.mark.parametrize("cap", [0.0, 30.0])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_logits_step_kernel_vs_ref(tied, cap, dtype):
    B, D, V = 3, 32, 256
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    hidden = jax.random.normal(ks[0], (B, D)).astype(dtype)
    table = jax.random.normal(ks[1], (V, D)).astype(dtype)
    t = table if tied else table.T
    i_r, m_r, s_r = ref.logits_step(hidden, t, tied=tied, softcap=cap)
    i_p, m_p, s_p = ops.logits_step(hidden, t, tied=tied, softcap=cap,
                                    impl="interpret")
    assert np.array_equal(np.asarray(i_p), np.asarray(i_r))
    np.testing.assert_allclose(np.asarray(m_p), np.asarray(m_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_r), rtol=1e-4)


def test_logits_step_tie_breaking_matches_argmax():
    """Duplicated logit maxima across vocab tiles must keep the earliest
    index — jnp.argmax's first-occurrence rule, which ``sample``'s greedy
    branch (and therefore greedy bit-identity) depends on."""
    B, D, V = 2, 8, 64
    hidden = jnp.ones((B, D), jnp.float32)
    # identical rows at 3, 19 and 40 -> tied maxima in different v-tiles
    table = jnp.zeros((V, D), jnp.float32)
    row = jnp.ones((D,), jnp.float32)
    table = table.at[3].set(row).at[19].set(row).at[40].set(row)
    i_r, _, _ = ref.logits_step(hidden, table, tied=True)
    from repro.kernels.sampling_epilogue import logits_step_pallas
    i_p, _, _ = logits_step_pallas(hidden, table, tied=True, v_tile=16,
                                   interpret=True)
    want = jnp.argmax(jnp.einsum("bd,vd->bv", hidden, table), axis=-1)
    assert np.array_equal(np.asarray(i_r), np.asarray(want))
    assert np.array_equal(np.asarray(i_p), np.asarray(want))


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_logits_step_need_stats_false_same_token(impl):
    """``need_stats=False`` (the greedy fast path) must return the same
    argmax as the full call, with the stats slots as None — for both the
    jnp fallback (which skips the max/sum-exp work) and the kernel (which
    just drops them)."""
    B, D, V = 3, 32, 256
    ks = jax.random.split(jax.random.PRNGKey(8), 2)
    hidden = jax.random.normal(ks[0], (B, D), jnp.float32)
    table = jax.random.normal(ks[1], (V, D), jnp.float32)
    i_full, _, _ = ops.logits_step(hidden, table, tied=True, impl=impl)
    i_fast, vmax, sumexp = ops.logits_step(hidden, table, tied=True,
                                           need_stats=False, impl=impl)
    assert vmax is None and sumexp is None
    assert np.array_equal(np.asarray(i_fast), np.asarray(i_full))


def test_sample_fused_greedy_and_sampled_paths():
    """All-greedy batches take the in-kernel argmax; any sampled slot falls
    back to the full-logits path — both must agree with ``sample``."""
    from repro.serve.sampling import sample, sample_fused
    B, D, V = 2, 16, 64
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    hidden = jax.random.normal(ks[0], (B, D), jnp.float32)
    table = jax.random.normal(ks[1], (V, D), jnp.float32)
    logits = jnp.einsum("bd,vd->bv", hidden, table,
                        preferred_element_type=jnp.float32)
    topk = jnp.zeros((B,), jnp.int32)
    topp = jnp.ones((B,), jnp.float32)
    for temp in (jnp.zeros((B,)), jnp.full((B,), 0.8)):
        want = sample(logits, ks[2], temp, topk, topp)
        got = sample_fused(hidden, table, True, 0.0, lambda: logits,
                           ks[2], temp, topk, topp)
        assert np.array_equal(np.asarray(got), np.asarray(want)), temp


# ---------------------------------------------------------------------------
# autotuner plumbing (off-TPU behavior + table round-trip)
# ---------------------------------------------------------------------------

def test_autotune_bucket_and_clamp():
    assert autotune.bucket(1) == 1
    assert autotune.bucket(129) == 256
    assert autotune.bucket(1024) == 1024
    assert autotune._clamp(512, 384) == 128       # largest pow2 divisor <= 512
    assert autotune._clamp(7, 64) == 1
    assert autotune.pow2_divisors(256, 64) == [64, 128, 256]
    assert autotune.table_key("mamba2_step", jnp.bfloat16, 300) == \
        "mamba2_step/bfloat16/512"


def test_tile_for_returns_clamped_default_off_tpu():
    """CPU/interpret runs never consult or write the table — they take the
    static default, clamped to divide the dim."""
    assert jax.default_backend() != "tpu"
    assert autotune.tile_for("mamba2_step", jnp.float32, 128, 256) == 128
    assert autotune.tile_for("rglru_step", jnp.float32, 96, 512) == 32


def test_autotune_record_round_trip(tmp_path):
    path = tmp_path / "table.json"
    autotune.record("gdn_step", jnp.float32, 8, 4, path=path)
    tab = json.loads(path.read_text())
    assert tab["entries"]["gdn_step/float32/8"] == {"tile": 4}


def test_autotune_cli_refuses_off_tpu(capsys):
    assert autotune.main([]) == 1
    assert "no TPU backend" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# registry resolution-order contract (satellite sweep over every op)
# ---------------------------------------------------------------------------

STEP_OPS = ("selective_scan_step", "routed_matmul", "mamba2_step",
            "gdn_step", "rglru_step", "mlstm_step", "slstm_step",
            "logits_step")


@pytest.mark.parametrize("op", sorted(
    ["selective_scan", "grouped_matmul", *STEP_OPS]))
def test_resolution_order_per_op(op):
    """explicit impl > default_impl context > backend auto > per-op
    fallback, for every registered op; 'interpret' is never remapped."""
    assert op in ops.registered_ops()
    auto_fb = "fused" if op in STEP_OPS else "ref"
    # backend auto on CPU
    assert ops.resolve_impl(op) == "ref"
    # context default applies, with the off-TPU per-op fallback
    with ops.default_impl("pallas"):
        assert ops.resolve_impl(op) == auto_fb
        # explicit impl beats the context default
        assert ops.resolve_impl(op, "ref") == "ref"
        assert ops.resolve_impl(op, "interpret") == "interpret"
        # nested contexts shadow and restore
        with ops.default_impl("ref"):
            assert ops.resolve_impl(op) == "ref"
        assert ops.resolve_impl(op) == auto_fb
    assert ops.active_default() is None
    assert ops.resolve_impl(op, "pallas") == auto_fb
    assert ops.resolve_impl(op, "interpret") == "interpret"


# ---------------------------------------------------------------------------
# engine-level greedy identity per mixer: kernels='pallas' vs 'ref'
# ---------------------------------------------------------------------------

MIXER_PATTERNS = [("mamba2",), ("gdn",), ("rglru",), ("mlstm",), ("slstm",)]
_IDS = [p[0] for p in MIXER_PATTERNS]


def _run_tokens(cfg, params, kernels, *, admission="interleaved",
                speculative=0, cache=None, scheduler=None):
    eng = ServeEngine(cfg, params,
                      engine=EngineConfig(max_slots=2, max_len=32, seed=0,
                                          max_prefill_chunk=8,
                                          admission=admission,
                                          speculative=speculative,
                                          kernels=kernels),
                      prefix_cache=cache, scheduler=scheduler)
    rng = np.random.default_rng(5)
    reqs = [Request(id=i,
                    prompt=rng.integers(2, cfg.vocab_size,
                                        size=(n,)).tolist(),
                    max_new_tokens=4)
            for i, n in enumerate([5, 9, 3])]
    res = eng.run(reqs)
    return {r.id: (r.tokens, r.finish_reason) for r in res}


@pytest.mark.parametrize("mode", ["interleaved", "sequential", "speculative"])
@pytest.mark.parametrize("pattern", MIXER_PATTERNS, ids=_IDS)
def test_engine_greedy_identity_per_mixer(pattern, mode):
    """Every fused recurrent mixer must emit greedy tokens bit-identical to
    kernels='ref' through interleaved, sequential and speculative serving
    (3 mixed-length requests on 2 slots force admission mid-decode).  The
    'pallas' run also exercises the fused sampling epilogue via
    decode_core's hidden-row path."""
    cfg = _full_cfg(((pattern, 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    kw = (dict(speculative=3) if mode == "speculative"
          else dict(admission=mode))
    a = _run_tokens(cfg, params, "ref", **kw)
    b = _run_tokens(cfg, params, "pallas", **kw)
    assert a == b


@pytest.mark.parametrize("pattern", MIXER_PATTERNS, ids=_IDS)
def test_engine_greedy_identity_per_mixer_cache_hits(pattern):
    """Cache-hit admission (restored prefix snapshots) under each fused
    mixer: same greedy tokens as kernels='ref', with the cache actually
    serving hits in both runs."""
    from repro.serve import CachedSuffixFirst
    cfg = _full_cfg(((pattern, 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    shared = rng.integers(2, cfg.vocab_size, size=(10,)).tolist()
    outs = {}
    for impl in ("ref", "pallas"):
        cache = PrefixCache(budget_mb=8.0)
        eng = ServeEngine(cfg, params,
                          engine=EngineConfig(max_slots=2, max_len=32,
                                              seed=0, max_prefill_chunk=4,
                                              kernels=impl),
                          prefix_cache=cache,
                          scheduler=CachedSuffixFirst(cache))
        eng.run([Request(id=-1, prompt=shared + [1], max_new_tokens=1)])
        res = eng.run([Request(id=i, prompt=shared + [40 + i],
                               max_new_tokens=4) for i in range(2)])
        assert eng.stats["cache_hit_tokens"] > 0, impl
        outs[impl] = {r.id: r.tokens for r in res}
    assert outs["ref"] == outs["pallas"]


def test_engine_sampled_identity_under_kernels():
    """temperature > 0 slots force sample_fused onto the full-logits branch;
    with identical rng streams the sampled tokens must match kernels='ref'
    exactly (fused == ref math keeps the logits bitwise equal)."""
    from repro.serve.sampling import SamplingParams
    cfg = _full_cfg((((("mamba2",)), 1),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    outs = {}
    for impl in ("ref", "pallas"):
        eng = ServeEngine(cfg, params,
                          engine=EngineConfig(max_slots=2, max_len=32,
                                              seed=0, max_prefill_chunk=8,
                                              kernels=impl))
        res = eng.run([Request(id=i, prompt=[3 + i, 7, 11], max_new_tokens=4,
                               sampling=SamplingParams(temperature=0.8,
                                                       top_k=8))
                       for i in range(2)])
        outs[impl] = {r.id: r.tokens for r in res}
    assert outs["ref"] == outs["pallas"]
