"""Logical-axis sharding resolution — uses AbstractMesh, so the production
(16,16) and (2,16,16) topologies are checked without 512 devices."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro import train as tr
from repro.configs.base import ASSIGNED_ARCHS, get_config, SHAPES
from repro.distributed import sharding as shd
from repro.launch import specs as sp
from repro.models import lm

def _abstract_mesh(shape, names):
    """AbstractMesh's constructor changed across JAX versions: newer takes
    (shape, axis_names); 0.4.37 takes one ((name, size), ...) tuple."""
    try:
        return AbstractMesh(shape, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, shape)))


SINGLE = _abstract_mesh((16, 16), ("data", "model"))
MULTI = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_resolver_divisibility_fallback():
    rules = shd.ShardingRules()
    # kv_heads=8 with model=16: not divisible -> replicated
    spec = shd.resolve_spec((8, 128), ("heads", "head_dim"), SINGLE, rules)
    assert spec == P(None, "model")       # falls through to head_dim
    spec = shd.resolve_spec((32, 128), ("heads", None), SINGLE, rules)
    assert spec == P("model")
    # same mesh axis never used twice in one tensor
    spec = shd.resolve_spec((4096, 4096), ("mlp", "qkv"), SINGLE, rules)
    assert spec == P("model")             # second dim falls to None


def test_batch_axis_uses_pod_and_data():
    rules = shd.ShardingRules()
    spec = shd.resolve_spec((256, 4096), ("act_batch", "act_seq"), MULTI,
                            rules)
    assert spec == P(("pod", "data"))
    # batch=1 (long_500k): replicated
    spec = shd.resolve_spec((1, 4096), ("act_batch", "act_seq"), MULTI,
                            rules)
    assert spec == P()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
def test_every_param_leaf_resolves(arch, mesh):
    """Catches any param leaf missing from AXES_BY_NAME, for every arch."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0),
                                                   cfg))
    specs = shd.param_specs(shapes, mesh, shd.ShardingRules())  # no lenient
    n = len(jax.tree_util.tree_leaves(specs,
                                      is_leaf=lambda x: isinstance(x, P)))
    assert n == len(jax.tree_util.tree_leaves(
        shapes, is_leaf=lambda x: hasattr(x, "shape")))


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "xlstm-350m",
                                  "recurrentgemma-2b", "rom-mamba-1.3b"])
def test_decode_state_leaves_resolve(arch):
    cfg = get_config(arch)
    from repro.configs.base import applicable_shapes
    shp = applicable_shapes(cfg)["decode_32k"][0]
    if shp is None:
        pytest.skip("no decode for this arch")
    st = sp.decode_state_shapes(cfg, shp)

    def one(path, leaf):
        la = lm.state_logical(path, leaf)
        return shd.resolve_spec(leaf.shape, la, SINGLE, shd.ShardingRules())

    specs = jax.tree_util.tree_map_with_path(one, st)
    assert jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))


def test_expert_weights_replicated_rom_sharded_ep():
    """Paper: RoM experts replicated (no EP); llama4 EP experts sharded."""
    rules = shd.ShardingRules()
    spec = shd.resolve_spec((8, 2048, 4096), ("experts", "embed", "inner"),
                            SINGLE, rules)
    assert spec[0] is None                        # experts replicated
    spec = shd.resolve_spec((128, 5120, 8192),
                            ("experts_ep", "embed", "mlp"), SINGLE, rules)
    assert spec[0] == "data" and spec[2] == "model"


def test_zero3_weight_sharding():
    rules = shd.ShardingRules()
    spec = shd.resolve_spec((5120, 13824), ("embed", "mlp"), SINGLE, rules)
    assert spec == P("data", "model")             # ZeRO-3 + TP


def test_rules_override():
    rules = shd.ShardingRules().override(act_seq=("model", None))
    spec = shd.resolve_spec((1, 524288, 2560),
                            ("act_batch", "act_seq", "act_embed"),
                            SINGLE, rules)
    assert spec == P(None, "model")               # SP for B=1 long-context
