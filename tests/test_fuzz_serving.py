"""Fuzz-hardening for the serving data structures (model-free: no jax).

Five subjects, each checked against an executable reference model:

* :class:`~repro.serve.cache.PrefixCache` vs a naive dict-of-prefixes —
  same hits/misses/dedup/eviction order/stats after every operation, with
  the radix-tree structural invariants re-verified each step.
* The schedulers vs their documented rankings recomputed from scratch at
  every pop, under randomized mid-run arrivals; ``peek_next`` must agree
  with the subsequent ``pop_next``.
* The telemetry registry/tracer vs naive dict accumulation — snapshot/
  delta algebra, Prometheus parse-back, quantile bounds, and span
  lifecycle invariants under random operation sequences.
* The fleet snapshot codec (``serve/fleet/codec.py``) — bit-exact
  round-trips over arbitrary pytrees, and the never-mis-restore property:
  ANY single-byte flip or truncation of a blob raises, and a mismatched
  fingerprint is rejected before payload bytes are touched.
* :class:`~repro.serve.fleet.cache_tier.SharedCacheTier` equivalence —
  a small PrefixCache backed by a big shared tier answers every lookup /
  peek with the same prefix depth as one big local cache, under random
  insert/lookup interleavings (local evictions recover through the tier).

Every property runs twice: through ``hypothesis`` when it is installed
(the CI path — ``requirements-dev.txt`` pins it, ``conftest.py`` loads a
deterministic profile), and always through a seeded stdlib-``random``
driver, so the suite fuzzes even on environments without hypothesis.
"""
import dataclasses
import random
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import pytest

from repro.serve.cache import PrefixCache, _Node
from repro.serve.scheduler import (CachedSuffixFirst, FIFOScheduler,
                                   ShortestPromptFirst)
from repro.serve.telemetry import (MetricsRegistry, Tracer, hist_mean,
                                   hist_quantile)

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# PrefixCache reference model: a flat dict of prefixes
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Entry:
    nbytes: int
    used: int


class DictCache:
    """The naive spelling of PrefixCache's contract: a dict mapping
    (namespace, prefix tuple) -> (nbytes, LRU stamp), with the same
    budget/min_tokens/capture/grain gates, dedup, LRU eviction order and
    stats counters.  No radix tree, no pruning — everything the tree
    optimizes, done by linear scan."""

    def __init__(self, budget_mb=64.0, min_tokens=1, capture=True, grain=1):
        self.budget_bytes = int(budget_mb * (1 << 20))
        self.min_tokens = min_tokens
        self.capture = capture
        self.grain = grain
        self.entries: Dict[Tuple[Any, Tuple[int, ...]], _Entry] = {}
        self.bytes = 0
        self.clock = 0
        self.stats = {k: 0 for k in (
            "hits", "misses", "hit_tokens", "lookup_tokens", "inserts",
            "dedup_skips", "evictions", "oversize", "grain_skips")}

    def _best(self, tokens, cap, ns):
        best = None
        for (ens, p), e in self.entries.items():
            if ens != ns or len(p) > cap:
                continue
            if tuple(tokens[:len(p)]) == p:
                if best is None or len(p) > len(best[0]):
                    best = (p, e)
        return best

    def peek_len(self, tokens, ns=None):
        best = self._best(tokens, max(len(tokens) - 1, 0), ns)
        return len(best[0]) if best else 0

    def lookup(self, tokens, ns=None):
        self.stats["lookup_tokens"] += len(tokens)
        best = self._best(tokens, max(len(tokens) - 1, 0), ns)
        if best is None:
            self.stats["misses"] += 1
            return 0
        self.clock += 1
        best[1].used = self.clock
        self.stats["hits"] += 1
        self.stats["hit_tokens"] += len(best[0])
        return len(best[0])

    def contains(self, tokens, ns=None):
        return (ns, tuple(tokens)) in self.entries

    def wants(self, tokens):
        if not self.capture or len(tokens) < self.min_tokens:
            return False
        if len(tokens) % self.grain != 0:
            self.stats["grain_skips"] += 1
            return False
        return True

    def insert(self, tokens, nbytes, ns=None):
        if not self.wants(tokens):
            return False
        key = (ns, tuple(tokens))
        self.clock += 1
        if key in self.entries:
            self.entries[key].used = self.clock
            self.stats["dedup_skips"] += 1
            return False
        if nbytes > self.budget_bytes:
            self.stats["oversize"] += 1
            return False
        self.entries[key] = _Entry(nbytes=nbytes, used=self.clock)
        self.bytes += nbytes
        self.stats["inserts"] += 1
        while self.bytes > self.budget_bytes:
            victims = [k for k in self.entries if k != key]
            if not victims:
                break
            victim = min(victims, key=lambda k: self.entries[k].used)
            self.bytes -= self.entries.pop(victim).nbytes
            self.stats["evictions"] += 1
        return True

    def prefixes(self, ns=None):
        return sorted((p, e.nbytes) for (ens, p), e in self.entries.items()
                      if ens == ns)


def _check_tree_invariants(cache: PrefixCache):
    """Radix structure: child keyed by its edge's first token, depth
    consistent, no empty non-root edges, every snap-less non-root node has
    >= 2 children (pruned/merged), byte/snap accounting exact."""
    seen_bytes = 0
    seen_snaps = 0
    roots = [cache._root] + list(cache._ns_roots.values())

    def rec(node: _Node):
        nonlocal seen_bytes, seen_snaps
        if node.parent is not None:
            assert node.edge, "non-root node with empty edge"
            assert node.depth == node.parent.depth + len(node.edge)
            if node.snap is None:
                assert len(node.children) >= 2, \
                    "pass-through snap-less node survived pruning"
        if node.snap is not None:
            assert node in cache._snaps
            seen_bytes += node.nbytes
            seen_snaps += 1
        else:
            assert node.nbytes == 0
        for tok, child in node.children.items():
            assert child.edge[0] == tok
            assert child.parent is node
            rec(child)

    for root in roots:
        assert root.depth == 0 and root.parent is None
        rec(root)
    assert seen_bytes == cache.bytes_used
    assert seen_snaps == len(cache._snaps) == len(cache)
    assert cache.bytes_used <= cache.budget_bytes


def _snap_of(nbytes):
    return {"h": np.zeros((nbytes,), np.uint8)}


def run_cache_ops(ops, budget_bytes=4096, min_tokens=1, grain=1):
    """Drive the real cache and the dict reference through ``ops`` and
    compare contents, stats and structure after every single step.

    op := ("insert", tokens, nbytes, ns) | ("lookup", tokens, ns)
        | ("peek", tokens, ns) | ("contains", tokens, ns)
    """
    mb = budget_bytes / (1 << 20)
    real = PrefixCache(budget_mb=mb, min_tokens=min_tokens, grain=grain)
    ref = DictCache(budget_mb=mb, min_tokens=min_tokens, grain=grain)
    namespaces = {None}
    for op in ops:
        kind = op[0]
        if kind == "insert":
            _, tokens, nbytes, ns = op
            got = real.insert(tokens, lambda n=nbytes: _snap_of(n), ns=ns)
            want = ref.insert(tokens, nbytes, ns=ns)
            assert got == want, op
        elif kind == "lookup":
            _, tokens, ns = op
            got_len, got_snap = real.lookup(tokens, ns=ns)
            want_len = ref.lookup(tokens, ns=ns)
            assert got_len == want_len, op
            assert (got_snap is not None) == (want_len > 0), op
        elif kind == "peek":
            _, tokens, ns = op
            assert real.peek_len(tokens, ns=ns) == \
                ref.peek_len(tokens, ns=ns), op
        else:
            _, tokens, ns = op
            assert real.contains(tokens, ns=ns) == \
                ref.contains(tokens, ns=ns), op
        namespaces.add(op[-1])
        for ns in namespaces:
            assert real.snapshot_prefixes(ns=ns) == ref.prefixes(ns=ns), op
        assert real.stats == ref.stats, op
        assert real.bytes_used == ref.bytes
        _check_tree_invariants(real)


def _random_cache_ops(rng: random.Random, n_ops=120):
    """Token sequences drawn from a tiny alphabet with shared prefixes
    (extend-a-previous-prompt bias), so radix splits, mid-edge divergence,
    dedup and eviction all actually trigger."""
    ops = []
    prompts: List[Tuple[int, ...]] = []
    last_insert = None
    for _ in range(n_ops):
        if last_insert is not None and rng.random() < 0.15:
            ops.append(last_insert)     # immediate re-insert -> dedup path
            continue
        ns = rng.choice([None, "a", "b"])
        if prompts and rng.random() < 0.6:
            base = list(rng.choice(prompts))
            cut = rng.randint(0, len(base))
            tokens = tuple(base[:cut]) + tuple(
                rng.randrange(4) for _ in range(rng.randint(0, 6)))
        else:
            tokens = tuple(rng.randrange(4)
                           for _ in range(rng.randint(1, 10)))
        if not tokens:
            tokens = (0,)
        prompts.append(tokens)
        kind = rng.choice(["insert", "insert", "lookup", "peek", "contains"])
        if kind == "insert":
            last_insert = ("insert", tokens, rng.choice([64, 256, 1024]), ns)
            ops.append(last_insert)
        else:
            ops.append((kind, tokens, ns))
    return ops


@pytest.mark.fuzz
@pytest.mark.parametrize("seed", range(8))
def test_cache_fuzz_stdlib(seed):
    rng = random.Random(seed)
    run_cache_ops(_random_cache_ops(rng),
                  budget_bytes=rng.choice([1024, 2048, 4096]),
                  min_tokens=rng.choice([1, 2]),
                  grain=rng.choice([1, 2, 4]))


def test_cache_fuzz_exercises_every_path():
    """The stdlib fuzz corpus genuinely reaches dedup, eviction, grain
    refusals and namespace isolation (guards against a corpus that decays
    into no-ops)."""
    totals = {k: 0 for k in ("inserts", "dedup_skips", "evictions",
                             "grain_skips", "hits", "misses")}
    for seed in range(8):
        rng = random.Random(seed)
        ops = _random_cache_ops(rng)
        mb = rng.choice([1024, 2048, 4096]) / (1 << 20)
        c = PrefixCache(budget_mb=mb, min_tokens=rng.choice([1, 2]),
                        grain=rng.choice([1, 2, 4]))
        for op in ops:
            if op[0] == "insert":
                c.insert(op[1], lambda n=op[2]: _snap_of(n), ns=op[3])
            elif op[0] == "lookup":
                c.lookup(op[1], ns=op[2])
        for k in totals:
            totals[k] += c.stats[k]
    assert all(v > 0 for v in totals.values()), totals


if HAVE_HYPOTHESIS:
    _tokens_st = st.lists(st.integers(0, 3), min_size=1,
                          max_size=10).map(tuple)
    _ns_st = st.sampled_from([None, "a", "b"])
    _op_st = st.one_of(
        st.tuples(st.just("insert"), _tokens_st,
                  st.sampled_from([64, 256, 1024]), _ns_st),
        st.tuples(st.just("lookup"), _tokens_st, _ns_st),
        st.tuples(st.just("peek"), _tokens_st, _ns_st),
        st.tuples(st.just("contains"), _tokens_st, _ns_st),
    )

    @pytest.mark.fuzz
    @given(ops=st.lists(_op_st, max_size=60),
           budget=st.sampled_from([512, 2048, 8192]),
           grain=st.sampled_from([1, 2, 3]))
    def test_cache_fuzz_hypothesis(ops, budget, grain):
        run_cache_ops(ops, budget_bytes=budget, grain=grain)


# ---------------------------------------------------------------------------
# scheduler pop-order property: documented ranking, recomputed from scratch
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Req:
    id: int
    prompt: List[int]
    expert_set: Optional[str] = None


def _expected_next(kind, waiting, cache):
    """The documented ranking, recomputed naively over everything waiting:
    FIFO = arrival; SPF = (len, arrival); CachedSuffixFirst =
    (len - clamped cached-prefix hit in the request's namespace,
    arrival)."""
    if kind == "fifo":
        return min(waiting, key=lambda e: e[0])
    if kind == "spf":
        return min(waiting, key=lambda e: (len(e[1].prompt), e[0]))
    def key(e):
        order, req = e
        hit = min(cache.peek_len(req.prompt, ns=req.expert_set),
                  len(req.prompt) - 1)
        return (len(req.prompt) - max(hit, 0), order)
    return min(waiting, key=key)


def run_scheduler_ops(kind, ops):
    """ops := ("add", prompt, ns) | ("pop",) | ("insert", prefix, ns)
    (cache mutation mid-run, exercising pop-time re-ranking)."""
    cache = PrefixCache(budget_mb=1.0)
    sched = {"fifo": FIFOScheduler, "spf": ShortestPromptFirst,
             "csf": lambda: CachedSuffixFirst(cache)}[kind]()
    waiting: List[Tuple[int, _Req]] = []
    order = 0
    for op in ops:
        if op[0] == "add":
            req = _Req(id=order, prompt=list(op[1]), expert_set=op[2])
            sched.add(req)
            waiting.append((order, req))
            order += 1
        elif op[0] == "insert":
            cache.insert(op[1], lambda: _snap_of(16), ns=op[2])
        else:
            assert bool(sched) == bool(waiting)
            assert len(sched) == len(waiting)
            if not waiting:
                assert sched.peek_next() is None
                assert sched.pop_next() is None
                continue
            expect = _expected_next(kind, waiting, cache)[1]
            peeked = sched.peek_next()
            popped = sched.pop_next()
            assert peeked is popped, (kind, op)
            assert popped.id == expect.id, (kind, popped.id, expect.id)
            waiting.remove(next(e for e in waiting if e[1] is popped))
    # drain: full pop order must keep matching the from-scratch ranking
    while waiting:
        expect = _expected_next(kind, waiting, cache)[1]
        peeked = sched.peek_next()
        popped = sched.pop_next()
        assert peeked is popped, kind
        assert popped.id == expect.id, (kind, popped.id, expect.id)
        waiting.remove(next(e for e in waiting if e[1] is popped))
    assert sched.pop_next() is None


def _random_sched_ops(rng: random.Random, n_ops=80):
    ops = []
    prefixes = [tuple(rng.randrange(4) for _ in range(rng.randint(2, 6)))
                for _ in range(4)]
    for _ in range(n_ops):
        r = rng.random()
        ns = rng.choice([None, "a"])
        if r < 0.45:
            base = rng.choice(prefixes) if rng.random() < 0.5 else ()
            prompt = list(base) + [rng.randrange(4)
                                   for _ in range(rng.randint(1, 5))]
            ops.append(("add", prompt, ns))
        elif r < 0.65:
            ops.append(("insert", rng.choice(prefixes), ns))
        else:
            ops.append(("pop",))
    return ops


@pytest.mark.fuzz
@pytest.mark.parametrize("kind", ["fifo", "spf", "csf"])
@pytest.mark.parametrize("seed", range(6))
def test_scheduler_fuzz_stdlib(kind, seed):
    rng = random.Random(100 * seed + 17)
    run_scheduler_ops(kind, _random_sched_ops(rng))


if HAVE_HYPOTHESIS:
    _prompt_st = st.lists(st.integers(0, 3), min_size=1, max_size=8)
    _sched_op_st = st.one_of(
        st.tuples(st.just("add"), _prompt_st, _ns_st),
        st.tuples(st.just("insert"),
                  st.lists(st.integers(0, 3), min_size=1,
                           max_size=6).map(tuple), _ns_st),
        st.tuples(st.just("pop")),
    )

    @pytest.mark.fuzz
    @pytest.mark.parametrize("kind", ["fifo", "spf", "csf"])
    @given(ops=st.lists(_sched_op_st, max_size=50))
    def test_scheduler_fuzz_hypothesis(kind, ops):
        run_scheduler_ops(kind, ops)


# ---------------------------------------------------------------------------
# telemetry registry/tracer: snapshot-delta algebra and span lifecycle
# ---------------------------------------------------------------------------

def _random_metric_ops(rng: random.Random, n_ops=150):
    """op := ("c", name, int|float inc) | ("g", name, value)
    | ("h", name, observation) over a small shared name pool."""
    names = ["a", "b", "c"]
    ops = []
    for _ in range(n_ops):
        kind = rng.choice(["c", "c", "g", "h", "h"])
        name = f"{kind}_{rng.choice(names)}"
        if kind == "c":
            v = rng.choice([1, 2, 5, 0.25, 1.5])
        elif kind == "g":
            v = rng.randint(-4, 12)
        else:
            v = 10.0 ** rng.uniform(-6, 3)
        ops.append((kind, name, v))
    return ops


def _apply_metric_ops(reg: MetricsRegistry, ops):
    """Drive the registry and a naive dict reference in lockstep; return
    the reference (counters summed, gauges last-write, observations
    listed)."""
    ref = {"c": {}, "g": {}, "h": {}}
    for kind, name, v in ops:
        if kind == "c":
            reg.counter(name).inc(v)
            ref["c"][name] = ref["c"].get(name, 0) + v
        elif kind == "g":
            reg.gauge(name).set(v)
            ref["g"][name] = v
        else:
            reg.histogram(name).observe(v)
            ref["h"].setdefault(name, []).append(v)
    return ref


@pytest.mark.fuzz
@pytest.mark.parametrize("seed", range(6))
def test_registry_fuzz_matches_naive_accumulation(seed):
    """Cumulative snapshot == naive accumulation, and for any cut point
    prev + delta(prev) == current, element-wise, for every instrument
    kind (the windowing contract reset_stats/benchmarks rely on)."""
    rng = random.Random(seed)
    ops = _random_metric_ops(rng)
    cut = rng.randint(0, len(ops))
    reg = MetricsRegistry()
    ref_pre = _apply_metric_ops(reg, ops[:cut])
    pre = reg.snapshot()
    _apply_metric_ops(reg, ops[cut:])
    # replay everything into a fresh reference for the cumulative check
    ref_all = _apply_metric_ops(MetricsRegistry(), ops)
    cur, d = reg.snapshot(), reg.delta(pre)
    for name, want in ref_all["c"].items():
        assert cur[name]["value"] == pytest.approx(want)
        assert d[name]["value"] == pytest.approx(
            want - ref_pre["c"].get(name, 0))
    for name, want in ref_all["g"].items():
        assert cur[name]["value"] == want == d[name]["value"]
    for name, obs in ref_all["h"].items():
        assert cur[name]["count"] == len(obs) == sum(cur[name]["counts"])
        assert cur[name]["sum"] == pytest.approx(sum(obs))
        assert cur[name]["min"] == min(obs)
        assert cur[name]["max"] == max(obs)
        n_pre = len(ref_pre["h"].get(name, []))
        assert d[name]["count"] == len(obs) - n_pre
        # bucket-wise: delta counts equal prev..current difference
        if name in pre:
            assert all(dc == cc - pc for dc, cc, pc in zip(
                d[name]["counts"], cur[name]["counts"],
                pre[name]["counts"]))
        assert hist_mean(cur[name]) == pytest.approx(
            sum(obs) / len(obs))
        # quantiles: clamped to observed extremes, monotone in q
        qs = [hist_quantile(cur[name], q) for q in (0.0, 0.5, 0.95, 1.0)]
        assert qs == sorted(qs)
        assert min(obs) <= qs[0] and qs[-1] <= max(obs)


@pytest.mark.fuzz
@pytest.mark.parametrize("seed", range(4))
def test_prometheus_fuzz_parse_back(seed):
    """The Prometheus text export parses back to the snapshot: counter/
    gauge sample lines match values, histogram bucket lines are
    cumulative and end at +Inf == count."""
    rng = random.Random(1000 + seed)
    reg = MetricsRegistry()
    _apply_metric_ops(reg, _random_metric_ops(rng, n_ops=80))
    snap = reg.snapshot()
    lines = reg.to_prometheus(snap).splitlines()
    samples = {}
    for ln in lines:
        if ln.startswith("#") or not ln:
            continue
        key, val = ln.rsplit(" ", 1)
        samples[key] = float(val)
    for name, s in snap.items():
        if s["type"] in ("counter", "gauge"):
            assert samples[name] == pytest.approx(s["value"])
            continue
        assert samples[f"{name}_count"] == s["count"]
        assert samples[f"{name}_sum"] == pytest.approx(s["sum"])
        buckets = [v for k, v in samples.items()
                   if k.startswith(f"{name}_bucket{{")]
        assert buckets == sorted(buckets)          # cumulative
        assert samples[f'{name}_bucket{{le="+Inf"}}'] == s["count"]


def _drive_tracer(ops):
    """ops := ("begin", rid) | ("admit", rid) | ("add", rid)
    | ("finish", rid); returns the tracer after applying them with
    synthetic monotonic timestamps."""
    tr = Tracer(max_traces=16)
    t = 0.0
    for kind, rid in ops:
        t += 1.0
        if kind == "begin":
            tr.begin(rid, t)
        elif kind == "admit":
            tr.admitted(rid, t, t + 0.5)
        elif kind == "add":
            tr.add(rid, "decode", t, t + 0.5)
        else:
            tr.finish(rid, "eos", t)
    return tr


@pytest.mark.fuzz
@pytest.mark.parametrize("seed", range(6))
def test_tracer_fuzz_lifecycle_invariants(seed):
    """Random begin/admit/add/finish interleavings over few ids: live and
    finished stay disjoint, finished timelines are fully closed with
    monotonic contained spans, ops on unknown ids are safe no-ops, and
    re-begins are counted dropped."""
    rng = random.Random(10 + seed)
    ids = ["r0", "r1", "r2"]
    live = set()
    begun = finished = dropped = 0
    ops = []
    for _ in range(120):
        rid = rng.choice(ids)
        kind = rng.choice(["begin", "admit", "add", "add", "finish"])
        ops.append((kind, rid))
        if kind == "begin":
            begun += 1
            if rid in live:
                dropped += 1
            live.add(rid)
        elif kind == "finish" and rid in live:
            finished += 1
            live.discard(rid)
    tr = _drive_tracer(ops)
    assert set(tr.live()) == live
    assert tr.dropped == dropped
    done = tr.timelines()
    assert len(done) == min(finished, tr.max_traces)
    assert not live & {tl.req for tl in done} - set(tr.live()) or True
    for tl in done:
        assert not tl.open
        assert tl.spans[0].name == "request"
        assert tl.terminal() is not None
        root = tl.root
        for s in tl.spans:
            assert s.t1 is not None
            assert root.t0 <= s.t0 <= s.t1 <= root.t1
            assert s.parent is None or s.parent == root.sid
    # the chrome export of whatever happened is always serializable
    out = tr.chrome_trace()
    assert all(e["ts"] >= 0 and e.get("dur", 0) >= 0
               for e in out["traceEvents"] if e["ph"] == "X")


if HAVE_HYPOTHESIS:
    _mop_st = st.one_of(
        st.tuples(st.just("c"), st.sampled_from(["c_a", "c_b"]),
                  st.sampled_from([1, 3, 0.5])),
        st.tuples(st.just("g"), st.sampled_from(["g_a"]),
                  st.integers(-5, 20)),
        st.tuples(st.just("h"), st.sampled_from(["h_a", "h_b"]),
                  st.floats(1e-6, 1e3, allow_nan=False,
                            allow_infinity=False)),
    )

    @pytest.mark.fuzz
    @given(ops=st.lists(_mop_st, max_size=60),
           cut_frac=st.floats(0.0, 1.0))
    def test_registry_fuzz_hypothesis(ops, cut_frac):
        cut = int(cut_frac * len(ops))
        reg = MetricsRegistry()
        _apply_metric_ops(reg, ops[:cut])
        pre = reg.snapshot()
        _apply_metric_ops(reg, ops[cut:])
        cur, d = reg.snapshot(), reg.delta(pre)
        for name, s in cur.items():
            if s["type"] == "counter":
                assert d[name]["value"] == pytest.approx(
                    s["value"] - pre.get(name, {"value": 0})["value"])
            elif s["type"] == "histogram":
                p = pre.get(name)
                assert d[name]["count"] == s["count"] - (
                    p["count"] if p else 0)
                assert sum(d[name]["counts"]) == d[name]["count"]


# ---------------------------------------------------------------------------
# fleet codec: bit-exact round-trip and the never-mis-restore property
# ---------------------------------------------------------------------------

_DTYPES = [np.float32, np.float16, np.int32, np.int8, np.uint8, np.bool_]


def _random_pytree(rng: random.Random, depth=0):
    """Arbitrary nested dict/list pytree of small numpy leaves, the full
    shape space StateStore snapshots live in (incl. 0-d and empty axes)."""
    if depth >= 2 or rng.random() < 0.4:
        dt = rng.choice(_DTYPES)
        shape = tuple(rng.randint(0, 3)
                      for _ in range(rng.randint(0, 3)))
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        flat = np.arange(n) % 13 - 5 + rng.randint(0, 7)
        return flat.reshape(shape).astype(dt)
    if rng.random() < 0.5:
        return {f"k{i}": _random_pytree(rng, depth + 1)
                for i in range(rng.randint(1, 3))}
    return [_random_pytree(rng, depth + 1)
            for _ in range(rng.randint(1, 3))]


def _trees_equal(a, b):
    if isinstance(a, dict):
        return (isinstance(b, dict) and a.keys() == b.keys()
                and all(_trees_equal(a[k], b[k]) for k in a))
    if isinstance(a, list):
        return (isinstance(b, list) and len(a) == len(b)
                and all(_trees_equal(x, y) for x, y in zip(a, b)))
    return (a.dtype == b.dtype and a.shape == b.shape
            and bool(np.array_equal(a, b)))


@pytest.mark.fuzz
@pytest.mark.parametrize("seed", range(10))
def test_codec_fuzz_round_trip_bit_exact(seed):
    from repro.serve.fleet.codec import SnapshotCodec
    rng = random.Random(seed)
    codec = SnapshotCodec("f" * 16)
    for _ in range(10):
        snap = _random_pytree(rng)
        blob = codec.encode(snap)
        assert _trees_equal(codec.decode(blob), snap)
        assert codec.encode(snap) == blob          # deterministic bytes


@pytest.mark.fuzz
@pytest.mark.parametrize("seed", range(10))
def test_codec_fuzz_never_mis_restores(seed):
    """Exhaustive over small blobs, sampled over large ones: every
    single-byte corruption and every strict-prefix truncation raises a
    CodecError — a tampered blob NEVER decodes (to anything, right or
    wrong); a valid blob with the wrong fingerprint is always rejected."""
    from repro.serve.fleet.codec import (CodecError, FingerprintError,
                                         SnapshotCodec)
    rng = random.Random(1000 + seed)
    codec = SnapshotCodec("f" * 16)
    snap = _random_pytree(rng)
    blob = codec.encode(snap)

    positions = (range(len(blob)) if len(blob) <= 300
                 else sorted(rng.sample(range(len(blob)), 120)))
    for i in positions:
        tampered = bytearray(blob)
        tampered[i] ^= (1 << rng.randint(0, 7))
        with pytest.raises(CodecError):
            codec.decode(bytes(tampered))
    cuts = (range(len(blob)) if len(blob) <= 300
            else sorted(rng.sample(range(len(blob)), 60)))
    for i in cuts:
        with pytest.raises(CodecError):
            codec.decode(blob[:i])
    with pytest.raises(FingerprintError):
        SnapshotCodec("0" * 16).decode(blob)
    assert _trees_equal(codec.decode(blob), snap)  # the original still does


if HAVE_HYPOTHESIS:
    _leaf_st = st.builds(
        lambda dt, shape, fill: np.full(shape, fill % 7, dtype=dt),
        st.sampled_from(_DTYPES),
        st.lists(st.integers(0, 3), max_size=3).map(tuple),
        st.integers(0, 100))
    _pytree_st = st.recursive(
        _leaf_st,
        lambda kids: st.one_of(
            st.lists(kids, min_size=1, max_size=3),
            st.dictionaries(st.sampled_from(["a", "b", "c"]), kids,
                            min_size=1, max_size=3)),
        max_leaves=8)

    @pytest.mark.fuzz
    @given(snap=_pytree_st, flip=st.integers(0, 10 ** 9),
           cut_frac=st.floats(0.0, 1.0))
    def test_codec_fuzz_hypothesis(snap, flip, cut_frac):
        from repro.serve.fleet.codec import CodecError, SnapshotCodec
        codec = SnapshotCodec("f" * 16)
        blob = codec.encode(snap)
        assert _trees_equal(codec.decode(blob), snap)
        tampered = bytearray(blob)
        tampered[flip % len(blob)] ^= 1 << (flip % 8 or 1)
        with pytest.raises(CodecError):
            codec.decode(bytes(tampered))
        cut = int(cut_frac * (len(blob) - 1))
        with pytest.raises(CodecError):
            codec.decode(blob[:cut])


# ---------------------------------------------------------------------------
# SharedCacheTier: tiered small cache == one big local cache (lookup depths)
# ---------------------------------------------------------------------------


def run_tier_equivalence_ops(ops, local_budget=2048, big_budget=1 << 20):
    """Drive (small local PrefixCache + big SharedCacheTier) and a big
    local-only PrefixCache through the same ops; every lookup / peek must
    return the same prefix depth — local evictions on the small cache are
    recovered through the tier, so the pair behaves like one big cache.
    Blob sizes stay <= local_budget (a local-oversize insert skips the
    tier publish by design, which genuinely diverges).  Insert *return
    values* are not compared: re-inserting a locally-evicted prefix is a
    fresh store on the small cache but a dedup skip on the big one —
    only the serving surface (lookup / peek depths) must agree."""
    from repro.serve.fleet.cache_tier import SharedCacheTier
    from repro.serve.fleet.codec import SnapshotCodec
    codec = SnapshotCodec("f" * 16)
    tiered = PrefixCache(budget_mb=local_budget / (1 << 20))
    tiered.attach_tier(SharedCacheTier(budget_mb=big_budget / (1 << 20)),
                       codec)
    ref = PrefixCache(budget_mb=big_budget / (1 << 20))
    for op in ops:
        if op[0] == "insert":
            _, tokens, nbytes, ns = op
            tiered.insert(tokens, lambda n=nbytes: _snap_of(n), ns=ns)
            ref.insert(tokens, lambda n=nbytes: _snap_of(n), ns=ns)
        elif op[0] == "lookup":
            _, tokens, ns = op
            got_len, got_snap = tiered.lookup(tokens, ns=ns)
            want_len, want_snap = ref.lookup(tokens, ns=ns)
            assert got_len == want_len, op
            if want_snap is not None:
                assert got_snap["h"].shape == want_snap["h"].shape, op
        else:
            _, tokens, ns = op
            assert tiered.peek_len(tokens, ns=ns) == \
                ref.peek_len(tokens, ns=ns), op


def _random_tier_ops(rng: random.Random, n_ops=80):
    ops = []
    prompts: List[Tuple[int, ...]] = []
    for _ in range(n_ops):
        ns = rng.choice([None, "a"])
        if prompts and rng.random() < 0.6:
            base = list(rng.choice(prompts))
            cut = rng.randint(0, len(base))
            tokens = tuple(base[:cut]) + tuple(
                rng.randrange(4) for _ in range(rng.randint(0, 5)))
        else:
            tokens = tuple(rng.randrange(4)
                           for _ in range(rng.randint(1, 9)))
        if not tokens:
            tokens = (1,)
        prompts.append(tokens)
        kind = rng.choice(["insert", "insert", "lookup", "peek"])
        if kind == "insert":
            ops.append(("insert", tokens, rng.choice([64, 256, 512]), ns))
        else:
            ops.append((kind, tokens, ns))
    return ops


@pytest.mark.fuzz
@pytest.mark.parametrize("seed", range(8))
def test_tier_equivalence_fuzz_stdlib(seed):
    rng = random.Random(50 + seed)
    run_tier_equivalence_ops(_random_tier_ops(rng),
                             local_budget=rng.choice([600, 1024, 2048]))


def test_tier_equivalence_fuzz_exercises_eviction():
    """The corpus genuinely forces local evictions (so the equivalence is
    carried by tier fall-through, not by the local tree alone)."""
    from repro.serve.fleet.cache_tier import SharedCacheTier
    from repro.serve.fleet.codec import SnapshotCodec
    evictions = tier_hits = 0
    for seed in range(8):
        rng = random.Random(50 + seed)
        ops = _random_tier_ops(rng)
        local = rng.choice([600, 1024, 2048])
        cache = PrefixCache(budget_mb=local / (1 << 20))
        tier = SharedCacheTier(budget_mb=1.0)
        cache.attach_tier(tier, SnapshotCodec("f" * 16))
        for op in ops:
            if op[0] == "insert":
                cache.insert(op[1], lambda n=op[2]: _snap_of(n), ns=op[3])
            elif op[0] == "lookup":
                cache.lookup(op[1], ns=op[2])
        evictions += cache.stats["evictions"]
        tier_hits += tier.summary()["hits"]
    assert evictions > 0 and tier_hits > 0


if HAVE_HYPOTHESIS:
    _tier_op_st = st.one_of(
        st.tuples(st.just("insert"), _tokens_st,
                  st.sampled_from([64, 256, 512]), _ns_st),
        st.tuples(st.just("lookup"), _tokens_st, _ns_st),
        st.tuples(st.just("peek"), _tokens_st, _ns_st),
    )

    @pytest.mark.fuzz
    @given(ops=st.lists(_tier_op_st, max_size=50),
           local_budget=st.sampled_from([600, 1024, 4096]))
    def test_tier_equivalence_fuzz_hypothesis(ops, local_budget):
        run_tier_equivalence_ops(ops, local_budget=local_budget)
