"""Data-pipeline determinism + optimizer correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.data.pipeline import (EncoderCorpus, MarkovCorpus, TokenCorpus,
                                 VLMCorpus)


def test_corpus_determinism_and_restart_safety():
    c1 = TokenCorpus(vocab_size=100, seq_len=64, batch=4, seed=7)
    c2 = TokenCorpus(vocab_size=100, seq_len=64, batch=4, seed=7)
    for step in (0, 5, 1000):
        b1, b2 = c1.batch_at(step), c2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(c1.batch_at(0)["tokens"],
                              c1.batch_at(1)["tokens"])


def test_markov_corpus_structure():
    c = MarkovCorpus(vocab_size=64, seq_len=128, batch=8, seed=0,
                     num_regimes=4, branching=3)
    b = c.batch_at(0)
    # every transition must be one of the regime's 'branching' targets
    toks, labels = b["tokens"], b["labels"]
    allowed = c.targets            # (R, V, B)
    ok = np.zeros(toks.shape, bool)
    for r in range(4):
        ok |= (allowed[r, toks] == labels[..., None]).any(-1)
    assert ok.all()


def test_encoder_vlm_batches():
    e = EncoderCorpus(vocab_size=32, seq_len=64, batch=2, frontend_dim=16)
    b = e.batch_at(3)
    assert b["frames"].shape == (2, 64, 16) and b["mask"].dtype == bool
    assert 0.0 < b["mask"].mean() < 0.5
    v = VLMCorpus(vocab_size=32, seq_len=48, batch=2, num_patches=8,
                  frontend_dim=16)
    b = v.batch_at(0)
    assert b["patches"].shape == (2, 8, 16)
    assert b["tokens"].shape == (2, 48)


def test_adamw_quadratic_convergence():
    params = {"w": jnp.array([3.0, -2.0])}
    opt = optim.adamw_init(params)
    cfg = optim.AdamWConfig(weight_decay=0.0)
    for i in range(300):
        g = {"w": 2 * params["w"]}
        params, opt = optim.adamw_update(g, opt, params, 0.05, cfg,
                                         jnp.int32(i))
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adafactor_quadratic_convergence_matrix():
    params = {"w": jnp.ones((4, 3)) * 2.0, "b": jnp.ones((3,))}
    opt = optim.adafactor_init(params)
    assert "vr" in opt["stats"]["w"] and "v" in opt["stats"]["b"]
    cfg = optim.AdafactorConfig(weight_decay=0.0)
    for i in range(300):
        g = jax.tree_util.tree_map(lambda p: 2 * p, params)
        params, opt = optim.adafactor_update(g, opt, params, 0.05, cfg,
                                             jnp.int32(i))
    assert float(jnp.abs(params["w"]).max()) < 5e-2


def test_adafactor_stacked_layers_leaf():
    """3-D (layers-stacked) leaves must factor over the last two dims."""
    params = {"w": jnp.ones((24, 8, 6))}
    opt = optim.adafactor_init(params)
    assert opt["stats"]["w"]["vr"].shape == (24, 8)
    assert opt["stats"]["w"]["vc"].shape == (24, 6)
    g = {"w": jnp.ones((24, 8, 6))}
    p2, _ = optim.adafactor_update(g, opt, params, 0.01,
                                   optim.AdafactorConfig(), jnp.int32(0))
    assert p2["w"].shape == (24, 8, 6)
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, gn = optim.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(gn), np.sqrt(9 * 10 + 16 * 10),
                               rtol=1e-6)
    cn = optim.global_norm(clipped)
    np.testing.assert_allclose(float(cn), 1.0, rtol=1e-5)


def test_cosine_schedule():
    lr0 = optim.cosine_lr(jnp.int32(0), base_lr=4e-4, warmup_steps=100,
                          total_steps=1000)
    lr_w = optim.cosine_lr(jnp.int32(100), base_lr=4e-4, warmup_steps=100,
                           total_steps=1000)
    lr_end = optim.cosine_lr(jnp.int32(1000), base_lr=4e-4, warmup_steps=100,
                             total_steps=1000)
    assert 0.0 < float(lr0) <= 4e-4 / 50      # warm from step+1, never 0
    np.testing.assert_allclose(float(lr_w), 4e-4, rtol=2e-2)
    np.testing.assert_allclose(float(lr_end), 4e-5, rtol=1e-4)
